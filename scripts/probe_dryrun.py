"""De-risk probe: 512 host devices, (16,16) mesh, scanned transformer compile.

Verifies:
  1. jax.make_mesh((16,16)) over 512 fake CPU devices (256 used) works.
  2. jit(...).lower(ShapeDtypeStruct).compile() succeeds under SPMD.
  3. compiled.cost_analysis() exposes flops / bytes accessed.
  4. compiled.as_text() contains parseable collective ops.
  5. Rough compile wall-time for a scanned 8-layer transformer.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import time

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def main():
    print("devices:", len(jax.devices()))
    devs = jax.devices()[:256]
    import numpy as np

    mesh = Mesh(np.asarray(devs).reshape(16, 16), ("data", "model"))
    print("mesh:", mesh)

    D, F, L, V = 1024, 4096, 8, 32000
    B, S = 32, 512

    def init_shapes():
        return {
            "emb": jax.ShapeDtypeStruct((V, D), jnp.bfloat16),
            "wi": jax.ShapeDtypeStruct((L, D, F), jnp.bfloat16),
            "wo": jax.ShapeDtypeStruct((L, F, D), jnp.bfloat16),
        }

    param_specs = {
        "emb": P("model", None),
        "wi": P(None, None, "model"),
        "wo": P(None, "model", None),
    }

    def loss_fn(params, tokens):
        x = params["emb"][tokens] * 1.0

        def body(h, w):
            wi, wo = w
            h = h + jnp.einsum("bsd,df->bsf", h, wi).astype(jnp.bfloat16) @ wo
            return h, ()

        x, _ = jax.lax.scan(body, x, (params["wi"], params["wo"]))
        logits = jnp.einsum("bsd,vd->bsv", x, params["emb"])
        return jnp.mean(jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1))

    def train_step(params, tokens):
        g = jax.grad(loss_fn)(params, tokens)
        return jax.tree.map(lambda p, gg: (p - 1e-3 * gg).astype(p.dtype), params, g)

    in_shardings = (
        {k: NamedSharding(mesh, s) for k, s in param_specs.items()},
        NamedSharding(mesh, P("data", None)),
    )
    t0 = time.time()
    lowered = jax.jit(
        train_step,
        in_shardings=in_shardings,
        out_shardings=in_shardings[0],
    ).lower(init_shapes(), jax.ShapeDtypeStruct((B, S), jnp.int32))
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    print(f"lower: {t1-t0:.1f}s  compile: {t2-t1:.1f}s")

    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    print("cost keys sample:", {k: v for k, v in list(ca.items())[:8]})
    print("flops:", ca.get("flops"), "bytes:", ca.get("bytes accessed"))
    ma = compiled.memory_analysis()
    print("memory_analysis:", ma)

    txt = compiled.as_text()
    import re

    colls = re.findall(r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)[^\n]*", txt)
    print("n collective lines:", len(colls))
    for c in colls[:5]:
        print("  ", c[:160])


if __name__ == "__main__":
    main()
