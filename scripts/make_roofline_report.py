"""Build the §Dry-run / §Roofline tables for EXPERIMENTS.md from
results/dryrun/*.json."""
from __future__ import annotations

import glob
import json
import os
import sys


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    return f"{x*1e3:.1f}ms"


def fmt_b(x):
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x/div:.2f}{unit}"
    return f"{x:.0f}B"


def load(out_dir="results/dryrun"):
    recs = []
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def main():
    recs = load(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun")
    ok = [r for r in recs if r.get("status") == "ok"]
    skip = [r for r in recs if r.get("status") == "skipped"]
    err = [r for r in recs if r.get("status") == "error"]
    print(f"<!-- {len(ok)} ok / {len(skip)} skipped / {len(err)} error -->\n")

    # ---- §Dry-run table (both meshes) ----
    print("### Dry-run status (all cells × both meshes)\n")
    print("| arch | shape | mesh | status | peak HBM/chip | collectives (per-chip bytes/step) |")
    print("|---|---|---|---|---|---|")
    for r in recs:
        mem = r.get("memory", {})
        peak = fmt_b(mem["peak_estimate_bytes"]) if mem else "-"
        colls = ", ".join(f"{k}:{fmt_b(v)}" for k, v in sorted(r.get("collectives", {}).items(), key=lambda kv: -kv[1])[:3]) or "-"
        status = r["status"] + ("" if r["status"] != "skipped" else " (sub-quadratic-attn shape on full-attn arch)")
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {status} | {peak} | {colls} |")

    # ---- §Roofline table (single-pod only) ----
    print("\n### Roofline (single-pod 16x16, per chip per step)\n")
    print("| arch | shape | compute | memory | collective | dominant | MODEL/HLO flops | roofline frac |")
    print("|---|---|---|---|---|---|---|---|")
    singles = [r for r in ok if r["mesh"] == "single"]
    for r in sorted(singles, key=lambda r: (r["arch"], r["shape"])):
        print(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} | "
            f"{fmt_s(r['collective_s'])} | **{r['dominant']}** | {r['useful_ratio']:.3f} | {r['roofline_fraction']:.4f} |"
        )

    # ---- hillclimb candidates ----
    print("\n### Hillclimb candidate ranking\n")
    worst_frac = sorted(singles, key=lambda r: r["roofline_fraction"])[:5]
    coll_bound = sorted([r for r in singles if r["dominant"] == "collective"],
                        key=lambda r: -(r["collective_s"] / max(r["compute_s"], 1e-12)))[:5]
    print("worst roofline fraction:")
    for r in worst_frac:
        print(f"  {r['arch']}/{r['shape']}: frac={r['roofline_fraction']:.5f} dominant={r['dominant']}")
    print("most collective-bound (coll/compute ratio):")
    for r in coll_bound:
        print(f"  {r['arch']}/{r['shape']}: coll/comp={r['collective_s']/max(r['compute_s'],1e-12):.1f}x")


if __name__ == "__main__":
    main()
