"""Probe: compile time for fully-unrolled 64-layer qwen-scale train step on (16,16).

Worst-case cell for the dry-run analysis path (unrolled layers so that
cost_analysis counts every layer; XLA counts while-bodies only once).
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

L, D, FF, H, DH, V = 64, 5120, 27392, 32, 160, 152064
B, S = 256, 4096  # global


def layer(x, w):
    # pre-norm attn (full, S=4k scores fit per-shard) + swiglu ffn
    h = x * jax.lax.rsqrt(jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True) + 1e-6).astype(x.dtype)
    q = jnp.einsum("bsd,dhk->bshk", h, w["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, w["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, w["wv"])
    s = jnp.einsum("bqhk,bkhd->bhqd", q, k) / np.sqrt(DH)  # wrong einsum spelled; fix below
    return x


def layer2(x, w):
    h = x * jax.lax.rsqrt(jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True) + 1e-6).astype(x.dtype)
    q = jnp.einsum("bsd,dhk->bshk", h, w["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, w["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, w["wv"])
    scores = jnp.einsum("bqhk,bshk->bhqs", q, k).astype(jnp.float32) / np.sqrt(DH)
    mask = jnp.tril(jnp.ones((S, S), jnp.bool_))
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o = jnp.einsum("bhqs,bshk->bqhk", p, v)
    x = x + jnp.einsum("bqhk,hkd->bqd", o, w["wo"])
    h = x * jax.lax.rsqrt(jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True) + 1e-6).astype(x.dtype)
    g = jnp.einsum("bsd,df->bsf", h, w["wg"])
    u = jnp.einsum("bsd,df->bsf", h, w["wu"])
    x = x + jnp.einsum("bsf,fd->bsd", jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u, w["wd"])
    return x


def make_shapes():
    wl = {
        "wq": jax.ShapeDtypeStruct((L, D, H, DH), jnp.bfloat16),
        "wk": jax.ShapeDtypeStruct((L, D, H, DH), jnp.bfloat16),
        "wv": jax.ShapeDtypeStruct((L, D, H, DH), jnp.bfloat16),
        "wo": jax.ShapeDtypeStruct((L, H, DH, D), jnp.bfloat16),
        "wg": jax.ShapeDtypeStruct((L, D, FF), jnp.bfloat16),
        "wu": jax.ShapeDtypeStruct((L, D, FF), jnp.bfloat16),
        "wd": jax.ShapeDtypeStruct((L, FF, D), jnp.bfloat16),
    }
    return {"emb": jax.ShapeDtypeStruct((V, D), jnp.bfloat16), **wl}


SPECS = {
    "emb": P(None, "model"),
    "wq": P(None, None, "model", None),
    "wk": P(None, None, "model", None),
    "wv": P(None, None, "model", None),
    "wo": P(None, "model", None, None),
    "wg": P(None, None, "model"),
    "wu": P(None, None, "model"),
    "wd": P(None, "model", None),
}


def loss_fn(params, tokens):
    x = jnp.take(params["emb"], tokens, axis=0)
    for i in range(L):
        w = {k: params[k][i] for k in ("wq", "wk", "wv", "wo", "wg", "wu", "wd")}
        x = jax.checkpoint(layer2)(x, w)
    logits = jnp.einsum("bsd,vd->bsv", x, params["emb"]).astype(jnp.float32)
    return jnp.mean(jax.nn.logsumexp(logits, axis=-1))


def train_step(params, tokens):
    g = jax.grad(loss_fn)(params, tokens)
    return jax.tree.map(lambda p, gg: (p - 1e-3 * gg).astype(p.dtype), params, g)


def main():
    devs = jax.devices()[:256]
    mesh = Mesh(np.asarray(devs).reshape(16, 16), ("data", "model"))
    ins = (
        {k: NamedSharding(mesh, SPECS[k]) for k in make_shapes()},
        NamedSharding(mesh, P("data", None)),
    )
    t0 = time.time()
    lowered = jax.jit(train_step, in_shardings=ins, out_shardings=ins[0]).lower(
        make_shapes(), jax.ShapeDtypeStruct((B, S), jnp.int32)
    )
    t1 = time.time()
    print(f"lower: {t1-t0:.1f}s", flush=True)
    compiled = lowered.compile()
    t2 = time.time()
    print(f"compile: {t2-t1:.1f}s", flush=True)
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    print("flops:", ca.get("flops"), "bytes:", ca.get("bytes accessed"))
    ma = compiled.memory_analysis()
    print("temp GB:", ma.temp_size_in_bytes / 1e9, "args GB:", ma.argument_size_in_bytes / 1e9)


if __name__ == "__main__":
    main()
