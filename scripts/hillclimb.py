import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimb runner (assignment §PERFORMANCE HILLCLIMBING).

Measures one (arch, shape) cell under a sequence of named configurations
(each = ParallelPlan/OptimConfig overrides), using the same diff-method
cost extraction as dryrun. Writes results/perf/<cell>__<tag>.json.

  PYTHONPATH=src python scripts/hillclimb.py arctic-480b train_4k \
      baseline moe_grouped ...
"""
import dataclasses
import json
import sys
import time

import numpy as np

# tag -> (plan_overrides, ocfg_overrides)
CONFIGS = {
    "baseline": ({}, {}),
    "moe_grouped": ({"moe_grouped_dispatch": True}, {}),
    "noclip": ({}, {"clip_norm": 0.0}),
    "moe_grouped_noclip": ({"moe_grouped_dispatch": True}, {"clip_norm": 0.0}),
    "fuse_qkv": ({"fuse_qkv": True}, {}),
    "all_train": ({"moe_grouped_dispatch": True, "fuse_qkv": True}, {"clip_norm": 0.0}),
    "kv_fold": ({"kv_scale_fold": True}, {}),
    "pad_off": ({"pad_attention_heads": False}, {}),
    "kv_fold_pad_off": ({"kv_scale_fold": True, "pad_attention_heads": False}, {}),
    "mla_absorb": ({"mla_absorb": True}, {}),
    "sp_attn": ({"attn_mode": "sp", "pad_attention_heads": False}, {}),
    "chunk4k": ({"attn_chunk": 4096}, {}),
    "fuse_qkv_chunk4k": ({"fuse_qkv": True, "attn_chunk": 4096}, {}),
    "kv_bf16": ({"kv_cache_dtype": "bf16"}, {}),
}


def measure(arch_id, shape_name, plan_overrides, ocfg_overrides):
    from repro.configs.base import get_arch
    from repro.launch import roofline as rl
    from repro.launch.cells import build_cell, lower_cell
    from repro.launch.mesh import make_production_mesh

    spec = get_arch(arch_id)
    shape = spec.shapes[shape_name]
    mesh = make_production_mesh()
    full = spec.full
    is_lm = spec.family in ("lm", "moe-lm")

    if is_lm and shape.kind in ("train", "prefill"):
        fkd = full.moe.first_k_dense if full.moe is not None else 0
        La, Lb = fkd + 1, fkd + 2
        costs, colls = [], []
        for L in (La, Lb):
            cell = build_cell(arch_id, shape_name, mesh, analysis=True,
                              plan_overrides=plan_overrides or None,
                              cfg_override=dataclasses.replace(full, n_layers=L),
                              ocfg_overrides=ocfg_overrides or None)
            lo, co = lower_cell(cell)
            costs.append(rl.cost_summary(co))
            colls.append(rl.parse_collectives(co.as_text()))
            del lo, co
        n_extra = full.n_layers - La
        flops = costs[0]["flops"] + n_extra * (costs[1]["flops"] - costs[0]["flops"])
        bytes_ = costs[0]["bytes"] + n_extra * (costs[1]["bytes"] - costs[0]["bytes"])
        coll = {}
        for k in set(colls[0]) | set(colls[1]):
            d = colls[1].get(k, 0) - colls[0].get(k, 0)
            coll[k] = colls[0].get(k, 0) + n_extra * d
        mem = None
    else:
        cell = build_cell(arch_id, shape_name, mesh, analysis=True,
                          plan_overrides=plan_overrides or None,
                          ocfg_overrides=ocfg_overrides or None)
        lo, co = lower_cell(cell)
        cs = rl.cost_summary(co)
        flops, bytes_ = cs["flops"], cs["bytes"]
        coll = rl.parse_collectives(co.as_text())
        mem = rl.memory_summary(co)
        del lo, co
    terms = rl.roofline_terms(flops, bytes_, float(sum(coll.values())))
    return {
        "flops": flops, "bytes": bytes_, "coll_bytes": float(sum(coll.values())),
        "collectives": coll, "compute_s": terms.compute_s, "memory_s": terms.memory_s,
        "collective_s": terms.collective_s, "dominant": terms.dominant,
        "bound_s": terms.bound_s, "memory": mem,
    }


def main():
    arch_id, shape_name = sys.argv[1], sys.argv[2]
    tags = sys.argv[3:] or ["baseline"]
    os.makedirs("results/perf", exist_ok=True)
    for tag in tags:
        po, oo = CONFIGS[tag]
        out_path = f"results/perf/{arch_id}__{shape_name}__{tag}.json"
        if os.path.exists(out_path):
            print(f"[cached] {tag}")
            continue
        t0 = time.time()
        try:
            rec = measure(arch_id, shape_name, po, oo)
            rec.update(tag=tag, arch=arch_id, shape=shape_name, wall_s=round(time.time() - t0, 1))
        except Exception as e:
            rec = {"tag": tag, "arch": arch_id, "shape": shape_name, "error": str(e)[:500]}
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=2)
        if "error" in rec:
            print(f"[{tag}] ERROR {rec['error'][:150]}", flush=True)
        else:
            print(f"[{tag}] compute={rec['compute_s']:.3f}s memory={rec['memory_s']:.3f}s "
                  f"collective={rec['collective_s']:.3f}s dominant={rec['dominant']} ({rec['wall_s']}s)", flush=True)


if __name__ == "__main__":
    main()
