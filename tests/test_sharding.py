"""Sharding rules, FSDP spec derivation, MoE dispatch correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.layers import pad_heads
from repro.models.ptree import TensorSpec, tree_pspec, ts
from repro.sharding.axes import DEFAULT_RULES, shard, sharding_ctx
from repro.sharding.fsdp import fsdp_spec


class _FakeMesh:
    def __init__(self, sizes):
        self.axis_names = tuple(sizes)
        import numpy as _np

        self.devices = _np.empty(tuple(sizes.values()))


def test_tree_pspec_drops_non_divisible():
    rules = dict(DEFAULT_RULES)
    rules["_sizes"] = {"data": 16, "model": 16}
    spec = {
        "wq": ts((512, "embed"), (40, "q_heads"), (128, "head_dim")),  # 40 % 16 != 0
        "wg": ts((512, "embed"), (1408, "mlp")),
    }
    ps = tree_pspec(spec, rules)
    assert ps["wq"] == P(None, None, None)  # dropped, replicated
    assert ps["wg"] == P(None, "model")


def test_tree_pspec_no_axis_reuse():
    rules = dict(DEFAULT_RULES)
    rules["_sizes"] = {"model": 16}
    spec = ts((64, "q_heads"), (64, "mlp"))  # both map to model
    ps = tree_pspec(spec, rules)
    assert ps == P("model", None)  # first dim wins, no double use


def test_fsdp_spec_adds_data_axis():
    mesh = _FakeMesh({"data": 16, "model": 16})
    out = fsdp_spec(P(None, "model"), (4096, 1408), mesh)
    assert out == P("data", "model")
    # non-divisible first dim falls through to another dim
    out2 = fsdp_spec(P(None, None), (30, 4096), mesh)
    assert out2 == P(None, "data")


def test_pad_heads():
    assert pad_heads(40, 16) == 48
    assert pad_heads(56, 16) == 64
    assert pad_heads(32, 16) == 32
    assert pad_heads(7, 1) == 7


def test_shard_is_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = shard(x, "batch", None)
    assert y is x


def test_shard_applies_constraint_on_mesh():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with sharding_ctx(mesh):
        @jax.jit
        def f(x):
            return shard(x, "batch", "mlp_act") * 2
        out = f(jnp.ones((4, 8)))
    np.testing.assert_array_equal(np.asarray(out), 2 * np.ones((4, 8)))


def test_padded_head_lm_matches_unpadded():
    """Dead padded heads (zero wo rows) must not change the logits."""
    from repro.configs.base import get_arch
    from repro.models import transformer as tr
    from repro.models.ptree import tree_init

    cfg = get_arch("qwen1.5-32b").smoke  # 4 heads
    plan_p = tr.ParallelPlan(model_axis=3, pad_attention_heads=True, remat=False)  # pads 4 -> 6
    plan_n = tr.ParallelPlan(model_axis=1, remat=False)
    h_p, _ = tr.effective_heads(cfg, plan_p)
    assert h_p == 6
    params_p = tree_init(tr.lm_param_spec(cfg, plan_p), jax.random.PRNGKey(0), dtype=jnp.float32)
    # build unpadded params from the padded ones (slice the first 4 heads)
    params_n = tree_init(tr.lm_param_spec(cfg, plan_n), jax.random.PRNGKey(0), dtype=jnp.float32)

    def crop(stacked_p):
        out = jax.tree.map(lambda x: x, stacked_p)
        a = stacked_p["attn"]
        for k in ("wq", "wk", "wv"):
            out["attn"][k] = a[k][:, :, :4, :]
        for k in ("bq", "bk", "bv"):
            out["attn"][k] = a[k][:, :4, :]
        out["attn"]["wo"] = a["wo"][:, :4, :, :]
        return out

    params_c = dict(params_p)
    params_c["layers"] = {"all": crop(params_p["layers"]["all"])}
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    # zero the dead wo rows in the padded model -> outputs must match crop
    wo = params_p["layers"]["all"]["attn"]["wo"]
    params_p["layers"]["all"]["attn"]["wo"] = wo.at[:, 4:].set(0.0)
    for k in ("bq", "bk", "bv"):
        b = params_p["layers"]["all"]["attn"][k]
        params_p["layers"]["all"]["attn"][k] = b.at[:, 4:].set(0.0)
    lg_p, _ = tr.lm_forward(params_p, toks, cfg, plan_p)
    lg_c, _ = tr.lm_forward(params_c, toks, cfg, plan_n)
    np.testing.assert_allclose(np.asarray(lg_p), np.asarray(lg_c), rtol=2e-4, atol=2e-4)


def test_moe_matches_dense_oracle_when_capacity_unbounded():
    """Sort-based capacity dispatch == per-token dense top-k mix (no drops)."""
    from repro.configs.base import MoEConfig
    from repro.models.moe import apply_moe, moe_spec
    from repro.models.ptree import tree_init

    cfg = MoEConfig(n_routed=4, top_k=2, d_ff_expert=16, capacity_factor=8.0)
    d = 8
    spec = moe_spec(d, cfg, "swiglu")
    params = tree_init(spec, jax.random.PRNGKey(0), dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, d), jnp.float32)
    out, aux = apply_moe(params, x, cfg, "swiglu")

    # dense oracle
    xf = x.reshape(-1, d)
    gates = jax.nn.softmax(xf @ params["router"], -1)
    top_v, top_i = jax.lax.top_k(gates, 2)
    top_v = top_v / top_v.sum(-1, keepdims=True)
    def expert(e, t):
        g = xf[t] @ params["wg"][e]
        u = xf[t] @ params["wu"][e]
        return (jax.nn.silu(g) * u) @ params["wd"][e]
    ref = np.zeros_like(np.asarray(xf))
    for t in range(xf.shape[0]):
        for j in range(2):
            ref[t] += float(top_v[t, j]) * np.asarray(expert(int(top_i[t, j]), t))
    np.testing.assert_allclose(np.asarray(out.reshape(-1, d)), ref, rtol=2e-4, atol=2e-4)
    assert float(aux) > 0


def test_moe_drops_when_capacity_tight():
    from repro.configs.base import MoEConfig
    from repro.models.moe import apply_moe, moe_spec
    from repro.models.ptree import tree_init

    cfg = MoEConfig(n_routed=2, top_k=1, d_ff_expert=8, capacity_factor=0.02)
    spec = moe_spec(4, cfg, "swiglu")
    params = tree_init(spec, jax.random.PRNGKey(0), dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 4), jnp.float32)
    out, _ = apply_moe(params, x, cfg, "swiglu")
    # capacity 8 slots per expert << 256 tokens: most outputs are dropped zeros
    frac_zero = float((jnp.abs(out) < 1e-9).mean())
    assert frac_zero > 0.5
