"""Split-computation offloading (``src/repro/split`` + the action plane).

Five layers:

* **catalog** — per-family cut points carry the exact int8+scales wire
  size (pinned against a materialized ``quantize_tensor`` QTensor), FLOP
  prefixes are monotone, and ``subsample`` thins evenly;
* **costs** — roofline device-prefix seconds and server-suffix fractions,
  and the ``build_action_table`` packing invariants;
* **planner** — a degenerate (frames-only) ``ActionTable`` reproduces the
  table-free planner bit-for-bit on both ``cbo_plan`` and
  ``cbo_plan_many``; with splits, the batched planner stays bit-equal to
  the looped one, and a feature cut rescues frames no resolution can land;
* **engine** — the planner, the numpy engine, and the wire all read ONE
  action→bytes table: every transmitted (payload, service_scale) pair is
  a row of the table at the planned action;
* **differential** — the full numpy↔jax round loop stays
  decision-for-decision equal with a split-enabled table (the
  ``tests/_diff.py`` exactness policy), including churn + 2-cell fabric.
"""
from __future__ import annotations

import numpy as np
import pytest

from _diff import canonical_actions, make_server, run_differential

from repro.policy.frontier import cbo_plan, cbo_plan_many
from repro.policy.types import ActionTable, Env, EnvBatch, Frame
from repro.split import (
    DEFAULT_NPU_PEAK,
    activation_payload_nbytes,
    build_action_table,
    catalog_for,
    split_costs,
)


# --------------------------------------------------------------------- #
# catalog
# --------------------------------------------------------------------- #


def test_vit_catalog_shapes_and_payloads():
    cat = catalog_for("vit-s16")
    assert cat.family == "vit" and cat.img_res == 224
    assert len(cat) == 11  # 12 layers, no cut after the last
    for p in cat:
        assert p.act_shape == (197, 384)  # 14*14 patches + cls, d_model
        assert p.payload_nbytes == 197 * 384 + 197 * 4 == 76436
        assert p.raw_nbytes == 197 * 384 * 4
        assert 3.5 < p.compression < 4.0  # int8 + per-row scales vs f32


def test_resnet_catalog_spatial_shrink():
    cat = catalog_for("resnet-50")
    assert cat.family == "resnet" and len(cat) == 3 + 4 + 6 + 3 - 1
    first, last = cat.points[0], cat.points[-1]
    assert first.act_shape == (56, 56, 256)
    assert last.act_shape == (7, 7, 2048)
    assert last.payload_nbytes == 7 * 7 * 2048 + 7 * 7 * 4 == 100548
    # stages shrink spatially faster than channels grow: payloads descend
    assert last.payload_nbytes < first.payload_nbytes


def test_swin_catalog_stage4_is_cheap_to_finish():
    cat = catalog_for("swin-b")
    assert cat.family == "swin" and len(cat) == 2 + 2 + 18 + 2 - 1
    s4 = [p for p in cat if "/s4" in p.name]
    assert s4 and s4[0].act_shape == (49, 1024)
    assert s4[0].payload_nbytes == 49 * 1024 + 49 * 4 == 50372
    # cutting entering stage 4 leaves only a sliver of server work
    assert s4[0].suffix_fraction < 0.15


@pytest.mark.parametrize("arch", ("vit-s16", "resnet-50", "swin-b"))
def test_catalog_flop_accounting(arch):
    cat = catalog_for(arch)
    prefixes = np.array([p.prefix_flops for p in cat])
    assert (np.diff(prefixes) > 0).all()  # strictly deeper cuts cost more
    for p in cat:
        assert p.total_flops == cat.total_flops
        assert 0.0 < p.suffix_fraction < 1.0
        assert p.payload_nbytes == activation_payload_nbytes(p.act_shape)


def test_catalog_rejects_unsupported_family():
    with pytest.raises(ValueError, match="no split catalog"):
        catalog_for("dit-b2")


def test_subsample_thins_and_reindexes():
    cat = catalog_for("swin-b")
    sub = cat.subsample(4)
    assert len(sub) == 4
    assert [p.cut_id for p in sub] == [0, 1, 2, 3]  # re-indexed densely
    # evenly spread, endpoints kept
    assert sub.points[0].name == cat.points[0].name
    assert sub.points[-1].name == cat.points[-1].name
    assert cat.subsample(0) is cat and cat.subsample(99) is cat


# --------------------------------------------------------------------- #
# costs + table packing
# --------------------------------------------------------------------- #


def test_split_costs_are_roofline_compute_bounds():
    cat = catalog_for("vit-s16", max_cuts=4)
    costs = split_costs(cat, device_peak=DEFAULT_NPU_PEAK)
    for p, c in zip(cat, costs):
        assert c.t_dev == p.prefix_flops / DEFAULT_NPU_PEAK  # 0-byte roofline
        assert c.srv_frac == p.suffix_fraction
    t_dev = np.array([c.t_dev for c in costs])
    frac = np.array([c.srv_frac for c in costs])
    assert (np.diff(t_dev) > 0).all() and (np.diff(frac) < 0).all()


def test_build_action_table_packing():
    cat = catalog_for("swin-b", max_cuts=3)
    size_of = lambda r: 100.0 * r * r
    acc = (0.7, 0.99)
    table = build_action_table(cat, resolutions=(4, 8), size_of=size_of,
                               acc_server=acc, acc_drop=0.01)
    m = 2
    assert table.n_frame_actions == m and table.n_actions == m + 3
    assert table.has_splits
    np.testing.assert_array_equal(table.kind, [0, 0, 1, 1, 1])
    np.testing.assert_array_equal(table.res[m:], [m - 1] * 3)  # full res
    np.testing.assert_array_equal(table.cut[m:], [0, 1, 2])
    np.testing.assert_array_equal(table.sizes[m:], cat.payload_bytes())
    np.testing.assert_array_equal(table.acc[m:], [0.99 - 0.01] * 3)
    costs = split_costs(cat)
    np.testing.assert_array_equal(table.t_dev[m:], [c.t_dev for c in costs])
    np.testing.assert_array_equal(table.srv_frac[m:], [c.srv_frac for c in costs])
    assert table.names == tuple(p.name for p in cat)  # per-split labels
    # per-action rtt: frames pay full server time, splits a fraction
    rtt = table.rtt(0.1, 0.01)
    np.testing.assert_array_equal(rtt[:m], 0.11)
    assert (rtt[m:] < 0.11).all()


def test_build_action_table_none_catalog_is_frames_only():
    size_of = lambda r: 100.0 * r * r
    t = build_action_table(None, resolutions=(4, 8), size_of=size_of,
                           acc_server=(0.7, 0.99))
    ref = ActionTable.frames_only(sizes=[1600.0, 6400.0], acc=[0.7, 0.99])
    assert not t.has_splits
    np.testing.assert_array_equal(t.sizes, ref.sizes)
    np.testing.assert_array_equal(t.acc, ref.acc)


# --------------------------------------------------------------------- #
# planner: degenerate table == no table, looped == batched, splits win
# --------------------------------------------------------------------- #

_SIZES = (2500.0, 60000.0)
_ACC = (0.7, 0.99)


def _rand_frames(rng, k, sizes=_SIZES):
    return [Frame(arrival=float(i) / 32.0, conf=float(rng.integers(20, 99)) / 100.0,
                  sizes=sizes) for i in range(k)]


@pytest.mark.parametrize("seed", range(6))
def test_degenerate_table_is_bitwise_noop_cbo_plan(seed):
    rng = np.random.default_rng(seed)
    frames = _rand_frames(rng, int(rng.integers(1, 24)))
    table = ActionTable.frames_only(sizes=np.asarray(_SIZES), acc=np.asarray(_ACC))
    kw = dict(bandwidth=float(rng.uniform(2e4, 5e5)), latency=0.05,
              server_time=0.037, deadline=0.2, acc_server=_ACC)
    a = cbo_plan(frames, Env(**kw))
    b = cbo_plan(frames, Env(**kw, actions=table))
    assert a.offloads == b.offloads
    assert a.theta == b.theta and a.resolution == b.resolution
    assert a.total_gain == b.total_gain  # bitwise: same float ops ran


@pytest.mark.parametrize("seed", range(4))
def test_degenerate_table_is_bitwise_noop_cbo_plan_many(seed):
    from repro.policy.fleet import FleetState

    rng = np.random.default_rng(100 + seed)
    S = int(rng.integers(2, 6))
    state = FleetState(S, max_backlog=64)
    for s in range(S):
        k = int(rng.integers(0, 16))
        if k:
            state.extend(np.full(k, s, dtype=np.int64), np.arange(k) / 32.0,
                         rng.integers(20, 99, size=k) / 100.0)
    table = ActionTable.frames_only(sizes=np.asarray(_SIZES), acc=np.asarray(_ACC))
    kw = dict(bandwidth=rng.uniform(2e4, 5e5, size=S), latency=0.05,
              server_time=0.037, deadline=0.2, acc_server=_ACC,
              sizes=np.asarray(_SIZES))
    now = np.zeros(S)
    a = cbo_plan_many(state, EnvBatch(**kw), now)
    b = cbo_plan_many(state, EnvBatch(**kw, actions=table), now)
    for name in ("theta", "resolution", "n_offloads", "off_stream", "off_pos",
                 "off_res", "total_gain"):
        np.testing.assert_array_equal(getattr(a, name), getattr(b, name), err_msg=name)
    assert not b.off_kind.any() and (b.off_cut == -1).all()


def _split_table():
    """Frames (2) + two cuts; the deep cut is tiny on the wire and leaves
    the server only a 10% suffix."""
    base = ActionTable.frames_only(sizes=np.asarray(_SIZES), acc=np.asarray(_ACC))
    return ActionTable(
        kind=np.r_[base.kind, np.ones(2, dtype=np.int8)],
        res=np.r_[base.res, np.full(2, 1, dtype=np.int64)],
        cut=np.r_[base.cut, np.arange(2, dtype=np.int64)],
        sizes=np.r_[base.sizes, [30000.0, 8000.0]],
        acc=np.r_[base.acc, [0.98, 0.95]],
        t_dev=np.r_[base.t_dev, [0.002, 0.004]],
        srv_frac=np.r_[base.srv_frac, [0.5, 0.1]])


def test_split_action_rescues_deadline_no_frame_can_meet():
    # 0.1 MB/s uplink: the 60 kB frame needs 0.6 s, the 2.5 kB thumb gains
    # nothing over conf=0.9 — only the 8 kB deep-cut payload (tx 0.08 s,
    # rtt 0.02 s, t_dev 4 ms) lands inside the 0.2 s window.
    env = Env(bandwidth=1e5, latency=0.01, server_time=0.1, deadline=0.2,
              acc_server=_ACC, actions=_split_table())
    frames = [Frame(arrival=0.0, conf=0.9, sizes=_SIZES)]
    plan = cbo_plan(frames, env)
    assert plan.offloads == [(0, 3)]  # the features@cut1 action (index 3)
    assert env.actions.kind[plan.resolution] == 1
    # frame-only on the same instance: nothing lands
    frame_env = Env(bandwidth=1e5, latency=0.01, server_time=0.1, deadline=0.2,
                    acc_server=_ACC)
    assert cbo_plan(frames, frame_env).offloads == []


@pytest.mark.parametrize("seed", range(6))
def test_batched_planner_matches_looped_with_splits(seed):
    from repro.policy.fleet import FleetState

    rng = np.random.default_rng(200 + seed)
    S = int(rng.integers(2, 6))
    state = FleetState(S, max_backlog=64)
    for s in range(S):
        k = int(rng.integers(0, 16))
        if k:
            state.extend(np.full(k, s, dtype=np.int64), np.arange(k) / 32.0,
                         rng.integers(20, 99, size=k) / 100.0)
    table = _split_table()
    env = EnvBatch(bandwidth=rng.uniform(3e4, 3e5, size=S), latency=0.05,
                   server_time=0.037, deadline=0.2, acc_server=_ACC,
                   sizes=np.asarray(_SIZES), actions=table)
    now = np.zeros(S)
    batch = cbo_plan_many(state, env, now)
    offs = state.offsets
    for s in range(S):
        frames = [Frame(arrival=float(a), conf=float(c), sizes=_SIZES)
                  for a, c in zip(state.arrival[offs[s]:offs[s + 1]],
                                  state.conf[offs[s]:offs[s + 1]])]
        p = cbo_plan(frames, env.for_stream(s))
        assert batch.plan(s).offloads == p.offloads, f"stream {s}"
        assert batch.theta[s] == p.theta and batch.resolution[s] == p.resolution
        np.testing.assert_allclose(batch.total_gain[s], p.total_gain, rtol=1e-12)
    # the annotation columns agree with the table at the chosen actions
    np.testing.assert_array_equal(batch.off_kind, table.kind[batch.off_res])
    np.testing.assert_array_equal(batch.off_cut, table.cut[batch.off_res])


# --------------------------------------------------------------------- #
# engine: one shared action→bytes table end to end
# --------------------------------------------------------------------- #


def test_engine_transmits_table_bytes_and_service_scale():
    """Regression for the shared table: every (payload, service_scale) pair
    the numpy engine puts on the wire is a row of the planner's
    ``ActionTable`` — planner-assumed bytes == transmitted bytes."""
    from repro.serving.synthetic import synthetic_streams

    act = canonical_actions()
    srv, _cfg = make_server("numpy", S=3, actions=act, bw_mbps=2.0)
    calls = []
    orig = srv.fabric.transmit

    def spy(stream, payload, t_submit, *, service_scale=None, **kw):
        calls.append((np.atleast_1d(np.asarray(payload, dtype=np.float64)).copy(),
                      np.atleast_1d(np.asarray(service_scale, dtype=np.float64)).copy()))
        return orig(stream, payload, t_submit, service_scale=service_scale, **kw)

    srv.fabric.transmit = spy
    imgs, labels = synthetic_streams(3, 48, seed=0)
    m = srv.process_streams(imgs, labels)
    assert m.n_offloaded > 0 and calls
    rows = {(float(s), float(f)) for s, f in zip(act.sizes, act.srv_frac)}
    seen_split = False
    for payload, scale in calls:
        for p, f in zip(payload, np.broadcast_to(scale, payload.shape)):
            assert (float(p), float(f)) in rows, (p, f)
            seen_split |= f != 1.0
    assert seen_split  # at least one feature-cut action actually shipped


def test_service_scale_rejected_under_live_batching():
    from repro.net.replicas import ReplicaPool
    from repro.slowtier import ContinuousBatching, LinearBatch

    pool = ReplicaPool(1, 0.05, serial=True,
                       batching=ContinuousBatching(LinearBatch(0.01, 0.002),
                                                   window_s=0.01))
    with pytest.raises(ValueError, match="continuous batching"):
        pool.process(np.array([0.0]), np.array([0]),
                     service_scale=np.array([0.5]))
    # scale 1.0 is the float no-op — allowed even with live batching
    pool.process(np.array([0.0]), np.array([0]), service_scale=np.array([1.0]))


def test_jax_unsupported_flags_splits_with_live_batching():
    from repro.serving.engine_jax import jax_unsupported
    from repro.slowtier import ContinuousBatching, LinearBatch

    srv, _ = make_server("numpy", S=2, actions=canonical_actions(), bw_mbps=2.0)
    assert not jax_unsupported(srv)  # split tables alone are supported
    srv.fabric.pool.batching = ContinuousBatching(LinearBatch(0.01, 0.002),
                                                  window_s=0.01)
    reasons = jax_unsupported(srv)
    assert reasons and any("batching" in r for r in reasons)


# --------------------------------------------------------------------- #
# numpy <-> jax differential with a split-enabled table
# --------------------------------------------------------------------- #


def test_split_differential_degenerate_topology():
    mn, _mj = run_differential(S=3, n_frames=48, bw_mbps=2.0,
                               actions=canonical_actions())
    assert mn.n_offloaded > 0  # splits actually exercised, not planned away


def test_split_differential_churn_two_cells():
    mn, _mj = run_differential(S=4, n_frames=48, bw_mbps=2.0, churn=True,
                               topology="cells", placement="jsq",
                               actions=canonical_actions())
    assert mn.n_frames > 0
