"""Regenerate the golden fixtures in this directory.

  PYTHONPATH=src:.:tests python tests/data/regenerate_fixtures.py

History: both files were originally generated from the PRE-migration code
(the seven hand-rolled replay loops in ``benchmarks/approaches.py`` and the
``AdaptiveController``-wired engines — see the parent commit of the policy
plane PR), so the regression tests in ``tests/test_policy.py`` prove the
unified replay engine and the ``policy=`` serving path reproduce the old
behavior.  Re-running this script regenerates them from the CURRENT code:
do that only when an intentional behavior change (e.g. a new resolution
ladder shape in ``benchmarks/common.py``) invalidates the old baseline —
it rebases the regression guarantee onto today's implementation.
"""
from __future__ import annotations

import json
import os
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.join(HERE, "..", "..")
for p in (os.path.join(ROOT, "src"), ROOT, os.path.join(ROOT, "tests")):
    sys.path.insert(0, p)


def replay_fixture():
    from _replay_fixture import FIXTURE_NETS, make_synthetic_trace
    from benchmarks.approaches import APPROACHES, NetCfg

    trace = make_synthetic_trace()
    rows = []
    for net_kw in FIXTURE_NETS:
        net = NetCfg(**net_kw)
        row = {"net": net_kw}
        for name, fn in APPROACHES.items():
            row[name] = fn(trace, net)
        rows.append(row)
    with open(os.path.join(HERE, "replay_fixture.json"), "w") as f:
        json.dump(rows, f, indent=1)
    print(f"replay_fixture.json: {len(rows)} net configs x {len(rows[0]) - 1} approaches")


def multistream_snapshot():
    from repro.core.netsim import Uplink, mbps
    from repro.serving import CascadeServer, MultiStreamServer, ServeConfig
    from repro.serving.synthetic import synthetic_streams, synthetic_tiers

    fast, slow, cal = synthetic_tiers()
    cfg = ServeConfig(resolutions=(4, 8), acc_server=(0.7, 0.99), batch_size=16,
                      frame_rate=30.0, deadline=0.2)
    imgs, labels = synthetic_streams(4, 64)
    up = Uplink(bandwidth_bps=mbps(50.0), latency=0.05, server_time=cfg.server_time)
    agg = MultiStreamServer(cfg, fast, slow, cal, up, n_streams=4).process_streams(imgs, labels)
    snap = {"per_stream": [{"accuracy": m.accuracy, "offload_frac": m.offload_frac,
                            "deadline_miss_frac": m.deadline_miss_frac, "n_frames": m.n_frames}
                           for m in agg.per_stream],
            "accuracy": agg.accuracy, "n_offloaded": int(agg.n_offloaded)}
    imgs1, labels1 = synthetic_streams(1, 64)
    ref = CascadeServer(cfg, fast, slow, cal,
                        Uplink(bandwidth_bps=mbps(50.0), latency=0.05,
                               server_time=cfg.server_time)).process_stream(imgs1[0], labels1[0])
    snap["cascade_single"] = {"accuracy": ref.accuracy, "offload_frac": ref.offload_frac,
                              "deadline_miss_frac": ref.deadline_miss_frac,
                              "n_frames": ref.n_frames}
    with open(os.path.join(HERE, "multistream_snapshot.json"), "w") as f:
        json.dump(snap, f, indent=1)
    print("multistream_snapshot.json: 4-stream aggregate + single-stream reference")


def fabric_snapshot():
    """Non-degenerate golden snapshot pinned by BOTH backends.

    Two configs at ``frame_rate=32`` (the tie-free arrival grid from the
    exactness policy in ``tests/_diff.py`` — fr=30 puts ``arr + deadline``
    exactly on a frame boundary, which f64 and f32 round differently):
    the degenerate single-uplink fabric and a C=2-cell / K=2-replica
    (heterogeneous serial, JSQ placement) fabric.  Generated from the
    numpy path; ``tests/test_fleet_jax.py`` pins numpy AND jax to it.
    """
    from _diff import make_server
    from repro.serving.synthetic import synthetic_streams

    # S=12 on the 2-replica serial pool saturates the server tier (deadline
    # misses + EWMA-driven offload backoff), so the fabric entry pins real
    # queueing behavior, not a copy of the degenerate one
    snap = {}
    for topology, S in (("degenerate", 4), ("fabric", 12)):
        imgs, labels = synthetic_streams(S, 64)
        srv, _cfg = make_server("numpy", S=S, topology=topology)
        agg = srv.process_streams(imgs, labels)
        snap[topology] = {
            "per_stream": [{"accuracy": m.accuracy, "offload_frac": m.offload_frac,
                            "deadline_miss_frac": m.deadline_miss_frac,
                            "n_frames": m.n_frames}
                           for m in agg.per_stream],
            "accuracy": agg.accuracy, "n_offloaded": int(agg.n_offloaded),
            "n_deadline_miss": int(agg.n_deadline_miss)}
    with open(os.path.join(HERE, "fabric_snapshot.json"), "w") as f:
        json.dump(snap, f, indent=1)
    print("fabric_snapshot.json: degenerate + C2/K2-jsq configs at frame_rate=32")


if __name__ == "__main__":
    replay_fixture()
    multistream_snapshot()
    fabric_snapshot()
