"""Quantization substrate ("NPU" simulation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.quant.quantize import (
    QTensor,
    dequantize_tree,
    fp16_tree,
    qdq_tree,
    quantization_error,
    quantize_tensor,
    quantize_tree,
)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_roundtrip_error_bound(seed):
    """|x - deq(q(x))| <= scale/2 elementwise (symmetric int8)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (64, 128), jnp.float32)
    q = quantize_tensor(x, axis=-1)
    err = jnp.abs(q.dequantize(jnp.float32) - x)
    assert bool(jnp.all(err <= q.scale / 2 + 1e-6))


def test_qdq_preserves_structure_and_dtypes():
    tree = {
        "w": jax.random.normal(jax.random.PRNGKey(0), (128, 64), jnp.float32),
        "scale": jnp.ones((64,), jnp.float32),
        "bias": jnp.zeros((64,), jnp.float32),
    }
    out = qdq_tree(tree)
    assert jax.tree.structure(out) == jax.tree.structure(tree)
    assert all(a.dtype == b.dtype and a.shape == b.shape
               for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)))
    # weights change, small leaves do not
    assert not np.allclose(np.asarray(tree["w"]), np.asarray(out["w"]))
    np.testing.assert_array_equal(np.asarray(tree["scale"]), np.asarray(out["scale"]))


def test_quantize_tree_roundtrip():
    tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (256, 128), jnp.float32)}
    qt = quantize_tree(tree)
    assert isinstance(qt["w"], QTensor) and qt["w"].values.dtype == jnp.int8
    deq = dequantize_tree(qt, jnp.float32)
    rel = quantization_error(tree, deq)
    assert 0 < rel < 0.01


def test_quantization_hurts_a_trained_model_less_at_8bit_than_4bit():
    x = jax.random.normal(jax.random.PRNGKey(0), (128, 64), jnp.float32)
    e8 = float(jnp.abs(quantize_tensor(x, bits=8).dequantize(jnp.float32) - x).mean())
    e4 = float(jnp.abs(quantize_tensor(x, bits=4).dequantize(jnp.float32) - x).mean())
    assert e8 < e4 / 4


def test_fp16_tree_is_roundtrip_cast():
    tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (32, 32), jnp.float32) * 1e-3}
    out = fp16_tree(tree)
    assert out["w"].dtype == jnp.float32
    assert float(jnp.abs(out["w"] - tree["w"]).max()) > 0  # precision was lost
