"""Quantization substrate ("NPU" simulation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.quant.quantize import (
    QTensor,
    dequantize_tree,
    fp16_tree,
    qdq_tree,
    quantization_error,
    quantize_tensor,
    quantize_tree,
)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_roundtrip_error_bound(seed):
    """|x - deq(q(x))| <= scale/2 elementwise (symmetric int8)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (64, 128), jnp.float32)
    q = quantize_tensor(x, axis=-1)
    err = jnp.abs(q.dequantize(jnp.float32) - x)
    assert bool(jnp.all(err <= q.scale / 2 + 1e-6))


def test_qdq_preserves_structure_and_dtypes():
    tree = {
        "w": jax.random.normal(jax.random.PRNGKey(0), (128, 64), jnp.float32),
        "scale": jnp.ones((64,), jnp.float32),
        "bias": jnp.zeros((64,), jnp.float32),
    }
    out = qdq_tree(tree)
    assert jax.tree.structure(out) == jax.tree.structure(tree)
    assert all(a.dtype == b.dtype and a.shape == b.shape
               for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)))
    # weights change, small leaves do not
    assert not np.allclose(np.asarray(tree["w"]), np.asarray(out["w"]))
    np.testing.assert_array_equal(np.asarray(tree["scale"]), np.asarray(out["scale"]))


def test_quantize_tree_roundtrip():
    tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (256, 128), jnp.float32)}
    qt = quantize_tree(tree)
    assert isinstance(qt["w"], QTensor) and qt["w"].values.dtype == jnp.int8
    deq = dequantize_tree(qt, jnp.float32)
    rel = quantization_error(tree, deq)
    assert 0 < rel < 0.01


def test_quantization_hurts_a_trained_model_less_at_8bit_than_4bit():
    x = jax.random.normal(jax.random.PRNGKey(0), (128, 64), jnp.float32)
    e8 = float(jnp.abs(quantize_tensor(x, bits=8).dequantize(jnp.float32) - x).mean())
    e4 = float(jnp.abs(quantize_tensor(x, bits=4).dequantize(jnp.float32) - x).mean())
    assert e8 < e4 / 4


def test_fp16_tree_is_roundtrip_cast():
    tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (32, 32), jnp.float32) * 1e-3}
    out = fp16_tree(tree)
    assert out["w"].dtype == jnp.float32
    assert float(jnp.abs(out["w"] - tree["w"]).max()) > 0  # precision was lost


# --------------------------------------------------------------------------- #
# Activation tensors (the split-offloading wire format, split/points.py):
# the device quantizes the boundary activation with quantize_tensor(axis=-1)
# and ships values + per-row scales; the catalog's analytic payload formula
# must match the materialized QTensor byte-for-byte.
# --------------------------------------------------------------------------- #

# token-grid (ViT/Swin) and spatial (ResNet) activation shapes
ACT_SHAPES = ((197, 384), (50, 768), (7, 7, 2048), (16, 16, 512), (64,))


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(0, len(ACT_SHAPES) - 1))
def test_activation_roundtrip_error_bound(seed, shape_idx):
    """Activation round-trip obeys the same |err| <= scale/2 bound as
    weights — heavy-tailed GELU-like activations included."""
    shape = ACT_SHAPES[shape_idx]
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, shape, jnp.float32)
    x = x * jax.nn.sigmoid(1.702 * x)  # GELU-ish: skewed, heavy right tail
    q = quantize_tensor(x, axis=-1)
    err = jnp.abs(q.dequantize(jnp.float32) - x)
    assert bool(jnp.all(err <= q.scale / 2 + 1e-6))


@pytest.mark.parametrize("shape", ACT_SHAPES)
def test_activation_scale_is_per_leading_row(shape):
    """axis=-1 symmetric quantization keeps one f32 scale per leading row:
    scale.shape == shape[:-1] + (1,) — the shape the split catalog's
    payload formula assumes."""
    x = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
    q = quantize_tensor(x, axis=-1)
    assert q.values.dtype == jnp.int8 and q.values.shape == shape
    assert q.scale.dtype == jnp.float32
    assert q.scale.shape == tuple(shape[:-1]) + (1,)


@pytest.mark.parametrize("shape", ACT_SHAPES)
def test_activation_payload_nbytes_matches_qtensor(shape):
    """The catalog's analytic wire size equals the materialized QTensor's
    actual bytes (values.nbytes + scale.nbytes), exactly."""
    from repro.split.points import activation_payload_nbytes, qtensor_nbytes

    x = jax.random.normal(jax.random.PRNGKey(2), shape, jnp.float32)
    q = quantize_tensor(x, axis=-1)
    assert qtensor_nbytes(q) == activation_payload_nbytes(shape)


def test_activation_payload_nbytes_seeded_fuzz():
    """Seeded fuzz over random activation shapes/ranks (runs even without
    hypothesis): analytic == materialized for every draw."""
    from repro.split.points import activation_payload_nbytes, qtensor_nbytes

    rng = np.random.default_rng(7)
    for _ in range(20):
        rank = int(rng.integers(1, 4))
        shape = tuple(int(s) for s in rng.integers(1, 48, size=rank))
        x = jnp.asarray(rng.standard_normal(shape), jnp.float32)
        q = quantize_tensor(x, axis=-1)
        assert qtensor_nbytes(q) == activation_payload_nbytes(shape), shape
        # int8 elements + one f32 scale per leading row, explicitly:
        rows = int(np.prod(shape[:-1])) if rank > 1 else 1
        assert qtensor_nbytes(q) == int(np.prod(shape)) + 4 * rows
