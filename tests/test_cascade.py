"""Cascade data-plane invariants (core/cascade.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cascade import cascade_classify, degrade_resolution

B, R, C = 16, 16, 4


def _fake_tiers():
    """fast tier: noisy classifier; slow tier: perfect oracle planted in px 0."""

    def fast(images):
        # class signal in pixel (0,0,0..C); noise makes some wrong
        sig = images[:, 0, 0, :C] + 0.8 * images[:, 1, 1, :C]
        return sig

    def slow(images):
        return images[:, 0, 0, :C] * 10.0

    return fast, slow


def _batch(key):
    labels = jax.random.randint(key, (B,), 0, C)
    base = jax.random.normal(jax.random.PRNGKey(1), (B, R, R, C)) * 0.3
    imgs = base.at[jnp.arange(B), 0, 0, labels].set(2.0)
    return imgs, labels


def test_capacity_zero_returns_fast_preds():
    fast, slow = _fake_tiers()
    imgs, labels = _batch(jax.random.PRNGKey(0))
    out = cascade_classify(fast, slow, lambda s: s, imgs, threshold=1.0, capacity=1, resolution=R)
    out0 = cascade_classify(fast, slow, lambda s: s, imgs, threshold=0.0, capacity=B, resolution=R)
    assert not bool(out0.escalated.any())
    assert np.array_equal(np.asarray(out0.preds), np.asarray(out0.fast_preds))


def test_full_escalation_matches_slow_tier():
    fast, slow = _fake_tiers()
    imgs, labels = _batch(jax.random.PRNGKey(0))
    out = cascade_classify(fast, slow, lambda s: s, imgs, threshold=1.1, capacity=B, resolution=R)
    assert bool(out.escalated.all())
    slow_preds = jnp.argmax(slow(imgs), -1)
    assert np.array_equal(np.asarray(out.preds), np.asarray(slow_preds))
    assert np.asarray(out.preds == labels).mean() == 1.0


def test_escalation_improves_accuracy_monotonically():
    fast, slow = _fake_tiers()
    imgs, labels = _batch(jax.random.PRNGKey(2))
    accs = []
    for cap in (0, 4, 8, B):
        out = cascade_classify(fast, slow, lambda s: s, imgs,
                               threshold=1.1, capacity=max(cap, 1), resolution=R)
        preds = np.asarray(out.preds) if cap else np.asarray(out.fast_preds)
        accs.append((preds == np.asarray(labels)).mean())
    assert accs == sorted(accs), accs  # slow tier is an oracle here


def test_escalated_subset_of_gate_and_lowest_conf():
    fast, slow = _fake_tiers()
    imgs, _ = _batch(jax.random.PRNGKey(3))
    out = cascade_classify(fast, slow, lambda s: s, imgs, threshold=0.6, capacity=4, resolution=R)
    conf = np.asarray(out.conf)
    esc = np.asarray(out.escalated)
    assert esc.sum() <= 4
    if esc.any():
        assert conf[esc].max() < 0.6  # only gated frames escalate
        # escalated are the lowest-confidence gated frames
        gated = conf < 0.6
        n_esc = int(esc.sum())
        worst = np.sort(conf[gated])[:n_esc]
        np.testing.assert_allclose(np.sort(conf[esc]), worst, rtol=1e-6)


def test_fused_fast_pass_matches_unfused():
    """fast_pass(use_fused=True) — the Pallas softmax-max→Platt→gate kernel
    (interpret mode off-TPU) — must match the unfused
    softmax→calibrate path."""
    from repro.core.calibration import PlattCalibrator
    from repro.core.cascade import fast_pass

    a, b = -5.0, 2.0
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(48, 12)).astype(np.float32) * 3.0)
    fwd = lambda x: x  # "images" are the logits for this test
    p_ref, c_ref = fast_pass(fwd, PlattCalibrator(a, b), logits)
    p_fused, c_fused = fast_pass(fwd, None, logits, use_fused=True, platt_ab=(a, b))
    assert np.array_equal(np.asarray(p_ref), np.asarray(p_fused))
    np.testing.assert_allclose(np.asarray(c_fused), np.asarray(c_ref), atol=1e-6)
    with pytest.raises(ValueError, match="platt_ab"):
        fast_pass(fwd, None, logits, use_fused=True)


def test_fused_cascade_classify_matches_unfused():
    """The full cascade with the fused fast pass agrees with the unfused
    cascade when calibration is the same Platt transform."""
    from repro.core.calibration import PlattCalibrator

    a, b = -4.0, 1.5
    platt = PlattCalibrator(a, b)
    fast, slow = _fake_tiers()
    imgs, _ = _batch(jax.random.PRNGKey(5))
    cal = lambda s: platt(s)
    ref = cascade_classify(fast, slow, cal, imgs, threshold=0.6, capacity=4, resolution=R)
    fused = cascade_classify(fast, slow, cal, imgs, threshold=0.6, capacity=4,
                             resolution=R, use_fused=True, platt_ab=(a, b))
    assert np.array_equal(np.asarray(ref.preds), np.asarray(fused.preds))
    assert np.array_equal(np.asarray(ref.escalated), np.asarray(fused.escalated))
    np.testing.assert_allclose(np.asarray(fused.conf), np.asarray(ref.conf), atol=1e-6)


def test_degrade_resolution_roundtrip_shapes():
    imgs = jax.random.normal(jax.random.PRNGKey(0), (2, 32, 32, 3))
    lo = degrade_resolution(imgs, 8)
    assert lo.shape == imgs.shape
    # degrading loses information
    assert float(jnp.abs(lo - imgs).mean()) > 1e-3
    same = degrade_resolution(imgs, 32)
    np.testing.assert_allclose(np.asarray(same), np.asarray(imgs))
