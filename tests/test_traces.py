"""Coverage for ``net/traces.py``: validation, searchsorted replay
boundaries, loop wraparound, and seeded-generator determinism."""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.netsim import mbps
from repro.net.traces import (BandwidthTrace, lte_trace, regime_shift_trace,
                              wifi_trace)


# --------------------------------------------------------------------- #
# constructor validation
# --------------------------------------------------------------------- #

def test_rejects_nonzero_start():
    with pytest.raises(ValueError, match="start at 0.0"):
        BandwidthTrace(t=np.asarray([1.0, 2.0]), bps=np.asarray([1e6, 2e6]))


def test_rejects_zero_duration_segment():
    with pytest.raises(ValueError, match="ascending"):
        BandwidthTrace(t=np.asarray([0.0, 1.0, 1.0]),
                       bps=np.asarray([1e6, 2e6, 3e6]))


def test_rejects_negative_duration_segment():
    with pytest.raises(ValueError, match="ascending"):
        BandwidthTrace(t=np.asarray([0.0, 2.0, 1.0]),
                       bps=np.asarray([1e6, 2e6, 3e6]))


def test_rejects_nonpositive_bandwidth():
    with pytest.raises(ValueError, match="positive"):
        BandwidthTrace(t=np.asarray([0.0, 1.0]), bps=np.asarray([1e6, 0.0]))
    with pytest.raises(ValueError, match="positive"):
        BandwidthTrace(t=np.asarray([0.0, 1.0]), bps=np.asarray([1e6, -5.0]))


def test_rejects_shape_mismatch():
    with pytest.raises(ValueError, match="matching"):
        BandwidthTrace(t=np.asarray([0.0, 1.0]), bps=np.asarray([1e6]))
    with pytest.raises(ValueError, match="matching"):
        BandwidthTrace(t=np.zeros(0), bps=np.zeros(0))


def test_rejects_loop_duration_short_of_last_breakpoint():
    with pytest.raises(ValueError, match="cover every breakpoint"):
        BandwidthTrace(t=np.asarray([0.0, 5.0]), bps=np.asarray([1e6, 2e6]),
                       loop=True, duration=4.0)


def test_default_duration_is_last_plus_median_gap():
    tr = BandwidthTrace(t=np.asarray([0.0, 1.0, 2.0]),
                        bps=np.asarray([1e6, 2e6, 3e6]))
    assert tr.duration == pytest.approx(3.0)
    # single-segment trace: falls back to a 1 s period
    assert BandwidthTrace(t=np.zeros(1), bps=np.ones(1)).duration == pytest.approx(1.0)


# --------------------------------------------------------------------- #
# searchsorted replay: boundary and wraparound semantics
# --------------------------------------------------------------------- #

def test_breakpoint_boundaries():
    tr = BandwidthTrace(t=np.asarray([0.0, 1.0, 3.0]),
                        bps=np.asarray([10.0, 20.0, 30.0]))
    # exactly AT a breakpoint the new segment's rate is in effect
    # (side="right": bps[i] rules [t[i], t[i+1]))
    np.testing.assert_array_equal(
        tr.bandwidth_at([0.0, 1.0, 3.0]), [10.0, 20.0, 30.0])
    # just below a breakpoint the previous segment still rules
    np.testing.assert_array_equal(
        tr.bandwidth_at([1.0 - 1e-9, 3.0 - 1e-9]), [10.0, 20.0])
    # last segment holds forever when not looping
    np.testing.assert_array_equal(tr.bandwidth_at([100.0]), [30.0])
    # times before t=0 clamp to the first segment
    np.testing.assert_array_equal(tr.bandwidth_at([-0.5]), [10.0])


def test_loop_wraparound():
    tr = BandwidthTrace(t=np.asarray([0.0, 1.0]), bps=np.asarray([10.0, 20.0]),
                        loop=True, duration=2.0)
    # t mod duration: 2.0 -> 0.0, 3.0 -> 1.0, 3.5 -> 1.5
    np.testing.assert_array_equal(
        tr.bandwidth_at([0.5, 1.5, 2.0, 3.0, 3.5, 4.0]),
        [10.0, 20.0, 10.0, 20.0, 20.0, 10.0])


def test_lookup_is_vectorized_and_shape_preserving():
    tr = regime_shift_trace((20.0, 2.0), period=10.0)
    ts = np.linspace(0.0, 60.0, 121).reshape(11, 11)
    out = tr.bandwidth_at(ts)
    assert out.shape == ts.shape
    assert set(np.unique(out)) == {mbps(2.0), mbps(20.0)}


def test_mean_bps_is_time_weighted():
    tr = BandwidthTrace(t=np.asarray([0.0, 3.0]), bps=np.asarray([10.0, 40.0]),
                        duration=4.0)
    # 3 s at 10 + 1 s at 40 over a 4 s period
    assert tr.mean_bps == pytest.approx((3 * 10 + 1 * 40) / 4)


def test_from_mbps_converts_units():
    tr = BandwidthTrace.from_mbps([0.0, 1.0], [8.0, 16.0])
    np.testing.assert_allclose(tr.bps, [mbps(8.0), mbps(16.0)])


# --------------------------------------------------------------------- #
# numpy <-> jax parity: the padded grid + in-scan searchsorted lookup
# --------------------------------------------------------------------- #

def _jax_lookup(tr, ts, pad_to=None):
    """Replicate what the compiled engine does per cell: padded grid,
    ``jnp.mod`` wraparound when looping, right-searchsorted minus one —
    all in float32, the engine's working precision (query times below are
    f32-exact per the exactness policy, so results must be bit-equal)."""
    import jax.numpy as jnp

    from repro.serving.engine_jax import trace_lookup

    t, bps = tr.grid(pad_to=pad_to)
    tj = jnp.asarray(ts, dtype=jnp.float32)
    if tr.loop:
        tj = jnp.mod(tj, jnp.float32(tr.duration))
    return np.asarray(trace_lookup(jnp.asarray(t, dtype=jnp.float32),
                                   jnp.asarray(bps, dtype=jnp.float32), tj))


@pytest.mark.parametrize("pad_to", [None, 7])
def test_jax_lookup_matches_numpy_boundaries(pad_to):
    # exact breakpoints, f32-exact just-below values, pre-zero clamp, far
    # future — the padded +inf breakpoints must never capture a finite time
    tr = BandwidthTrace(t=np.asarray([0.0, 1.0, 3.0]),
                        bps=np.asarray([10.0, 20.0, 30.0]))
    ts = np.asarray([-0.5, 0.0, 0.5, 0.96875, 1.0, 2.96875, 3.0, 100.0])
    np.testing.assert_array_equal(_jax_lookup(tr, ts, pad_to=pad_to),
                                  tr.bandwidth_at(ts))


def test_jax_lookup_matches_numpy_wraparound():
    tr = BandwidthTrace(t=np.asarray([0.0, 1.0]), bps=np.asarray([10.0, 20.0]),
                        loop=True, duration=2.0)
    ts = np.asarray([0.5, 1.5, 2.0, 3.0, 3.5, 4.0, 17.25])
    np.testing.assert_array_equal(_jax_lookup(tr, ts),
                                  tr.bandwidth_at(ts))


def test_jax_lookup_matches_numpy_regime_shift():
    tr = regime_shift_trace((20.0, 2.0), period=0.75, loop=True)
    # a dense f32-representable grid spanning several loop periods
    ts = np.arange(0, 256) / 32.0
    np.testing.assert_array_equal(_jax_lookup(tr, ts, pad_to=5),
                                  tr.bandwidth_at(ts))


def test_grid_padding_validates():
    tr = regime_shift_trace((20.0, 2.0))
    t, bps = tr.grid(pad_to=6)
    assert t.shape == bps.shape == (6,)
    assert np.isinf(t[2:]).all() and (bps[2:] == bps[1]).all()
    with pytest.raises(ValueError, match="pad_to"):
        tr.grid(pad_to=1)


# --------------------------------------------------------------------- #
# generators: deterministic per seed, distinct across seeds
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("gen,kw", [
    (lte_trace, {"duration": 30.0, "seed": 3}),
    (wifi_trace, {"duration": 30.0, "seed": 3}),
])
def test_generators_deterministic_per_seed(gen, kw):
    a, b = gen(**kw), gen(**kw)
    np.testing.assert_array_equal(a.t, b.t)
    np.testing.assert_array_equal(a.bps, b.bps)
    c = gen(**{**kw, "seed": 4})
    assert not np.array_equal(a.bps, c.bps)


def test_generators_emit_valid_looping_traces():
    for tr, step in ((lte_trace(duration=20.0, step=1.0), 1.0),
                     (wifi_trace(duration=20.0, step=0.5), 0.5)):
        assert tr.loop and tr.t[0] == 0.0
        assert (np.diff(tr.t) > 0).all() and (tr.bps > 0).all()
        assert tr.duration == pytest.approx(tr.t[-1] + step)


def test_regime_shift_square_wave():
    tr = regime_shift_trace((20.0, 2.0), period=10.0)
    np.testing.assert_array_equal(tr.bandwidth_at([0.0, 10.0, 20.0, 30.0]),
                                  [mbps(20.0), mbps(2.0), mbps(20.0), mbps(2.0)])
    with pytest.raises(ValueError, match="two levels"):
        regime_shift_trace((20.0,))
