"""Telemetry subsystem tests: recorder, tracer, profiler, and the
cross-backend parity + observer-effect guarantees (docs/observability.md).

The two load-bearing invariants:

  * observer effect is zero — an engine run with telemetry on produces
    exactly the metrics of a run with telemetry off (both backends);
  * the recorded series are backend-comparable — integer series bit-equal,
    floats at the established tolerance policy (``FleetRecorder
    .assert_close`` mirrors tests/_diff.py's EXACT_KEYS split).
"""
import json

import numpy as np
import pytest

from _diff import make_server
from repro.obs import FleetRecorder, PhaseProfiler, Telemetry, relock_lags
from repro.obs.profile import aot_split
from repro.serving.metrics import AggregateMetrics, ServeMetrics, jain_index
from repro.serving.synthetic import synthetic_streams


def _run(backend, *, S=6, n=48, telemetry=None, **kw):
    imgs, labels = synthetic_streams(S, n, seed=0)
    srv, cfg = make_server(backend, S=S, telemetry=telemetry, **kw)
    return srv.process_streams(imgs, labels), srv


# --------------------------------------------------------------------------- #
# FleetRecorder unit behavior
# --------------------------------------------------------------------------- #


def _record_one(rec, t=0.0, **over):
    S, C, K, A = rec.n_streams, rec.n_cells, rec.n_replicas, rec.n_actions
    row = dict(t=t, frames=np.ones(S), offloads=np.zeros(S),
               misses=np.zeros(S), correct=np.zeros(S),
               bw_est=np.full(S, 1e6), bw_true=np.full(S, 1e6),
               cell_busy_s=np.zeros(C), cell_queued_s=np.zeros(C),
               rep_busy_s=np.zeros(K), rep_queued_s=np.zeros(K),
               avg_batch=1.0, server_time=0.037, action_off=np.zeros(A))
    row.update(over)
    rec.record_round(**row)


def test_recorder_growth_and_views():
    rec = FleetRecorder(3, n_actions=2, capacity=2)
    for r in range(5):  # forces two capacity doublings
        _record_one(rec, t=float(r), offloads=np.full(3, r))
    assert rec.n_rounds == 5
    assert rec.series("t").tolist() == [0.0, 1.0, 2.0, 3.0, 4.0]
    assert rec.series("offloads").shape == (5, 3)
    assert rec.series("offloads")[-1].tolist() == [4, 4, 4]
    d = rec.as_dict()
    assert set(d) == set(rec._schema())
    assert all(len(v) == 5 for v in d.values())


def test_recorder_rejects_schema_mismatch():
    rec = FleetRecorder(2)
    with pytest.raises(ValueError, match="missing"):
        rec.record_round(t=0.0)
    with pytest.raises(ValueError, match="unknown"):
        _record_one(rec, bogus=1.0)


def test_recorder_derived_views():
    rec = FleetRecorder(2)
    _record_one(rec, offloads=np.array([1, 1]),
                bw_est=np.array([2e6, 1e6]), bw_true=np.array([1e6, 1e6]))
    _record_one(rec, t=1.0, offloads=np.array([4, 0]))
    jain = rec.jain_series()
    assert jain[0] == pytest.approx(1.0)
    assert jain[1] == pytest.approx(jain_index([4, 0]))
    err = rec.bw_error()
    assert err[0].tolist() == [1.0, 0.0]
    s = rec.summary()
    assert s["rounds"] == 2 and s["streams"] == 2
    assert FleetRecorder(2).summary() == {"rounds": 0}


def test_recorder_assert_close_catches_divergence():
    a, b = FleetRecorder(2), FleetRecorder(2)
    _record_one(a)
    _record_one(b)
    a.assert_close(b)
    _record_one(a)
    with pytest.raises(AssertionError, match="round counts"):
        a.assert_close(b)
    c = FleetRecorder(2)
    _record_one(c)
    _record_one(c, offloads=np.array([1, 0]))
    with pytest.raises(AssertionError, match="offloads"):
        a.assert_close(c)


def test_relock_lags_detects_shift_and_recovery():
    rec = FleetRecorder(1)
    # regime: 1e6 for 3 rounds (estimate locked), shift to 2e6, estimate
    # catches up 2 rounds later
    for r, (true, est) in enumerate([(1e6, 1e6), (1e6, 1e6), (1e6, 1e6),
                                     (2e6, 1e6), (2e6, 1.2e6), (2e6, 1.9e6)]):
        _record_one(rec, t=float(r), bw_true=np.array([true]),
                    bw_est=np.array([est]))
    lags = relock_lags(rec, rtol=0.25, shift_rtol=0.2)
    assert lags == [(3, 2)]
    assert relock_lags(FleetRecorder(1)) == []


# --------------------------------------------------------------------------- #
# engine wiring: parity, observer effect, tracing, profiling
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("topology", ["degenerate", "fabric"])
def test_recorder_parity_numpy_vs_jax(topology):
    tel_np = Telemetry(record=True)
    m_np, _ = _run("numpy", topology=topology, telemetry=tel_np)
    tel_jx = Telemetry(record=True)
    m_jx, _ = _run("jax", topology=topology, telemetry=tel_jx)
    assert tel_np.recorder.n_rounds == tel_jx.recorder.n_rounds > 0
    tel_np.recorder.assert_close(tel_jx.recorder, ctx=topology)
    assert m_np.summary() == m_jx.summary()


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_zero_observer_effect(backend):
    """Telemetry on/off must not change a single reported metric."""
    m_off, _ = _run(backend, topology="fabric")
    m_on, _ = _run(backend, topology="fabric",
                   telemetry=Telemetry(record=True, profile=True))
    assert m_off.summary() == m_on.summary()
    np.testing.assert_array_equal(m_off._frames, m_on._frames)
    np.testing.assert_array_equal(m_off._offloaded, m_on._offloaded)
    np.testing.assert_array_equal(m_off._missed, m_on._missed)
    np.testing.assert_array_equal(m_off._correct, m_on._correct)


def test_recorder_semantics_match_final_metrics():
    tel = Telemetry(record=True)
    m, srv = _run("numpy", topology="fabric", telemetry=tel)
    rec = tel.recorder
    # last row of the cumulative series == the end-of-run SoA counters
    np.testing.assert_array_equal(rec.series("frames")[-1], m._frames)
    np.testing.assert_array_equal(rec.series("offloads")[-1], m._offloaded)
    np.testing.assert_array_equal(rec.series("misses")[-1], m._missed)
    np.testing.assert_array_equal(rec.series("correct")[-1], m._correct)
    assert rec.jain_series()[-1] == pytest.approx(m.offload_fairness)
    fs = srv.fabric.summary()
    np.testing.assert_allclose(rec.series("cell_busy_s")[-1], fs["cell_busy_s"])
    np.testing.assert_allclose(rec.series("rep_queued_s")[-1],
                               fs["replica_queued_s"])
    # cumulative counters are monotone
    for k in ("frames", "offloads", "misses", "correct"):
        assert (np.diff(rec.series(k), axis=0) >= 0).all(), k


def test_tracer_records_lifecycle_and_exports_chrome_trace(tmp_path):
    tel = Telemetry(record=True, trace=True)
    m, srv = _run("numpy", topology="fabric", telemetry=tel)
    tr = tel.tracer
    assert tr.n_frames == m.n_offloaded + m.n_deadline_miss
    eps = 1e-9  # up_start is recovered as end - tx (float round-trip)
    for f in tr.frames:  # lifecycle ordering per escalation
        assert f["arrival"] <= f["t_ready"] <= f["up_start"] + eps
        assert f["up_start"] <= f["up_end"] + eps
        assert f["up_end"] <= f["srv_start"] + eps
        assert f["srv_start"] <= f["done"] <= f["land"]
        assert 0 <= f["cell"] < srv.fabric.n_cells
        assert 0 <= f["replica"] < srv.fabric.n_replicas
    att = tr.miss_attribution()
    assert att["misses"] == m.n_deadline_miss
    assert att["radio"] + att["slow_tier"] == att["misses"]
    path = tr.export_chrome_trace(str(tmp_path / "trace.json"))
    with open(path) as fh:
        doc = json.load(fh)
    ev = doc["traceEvents"]
    assert {e["ph"] for e in ev} <= {"M", "X", "i"}
    spans = [e for e in ev if e["ph"] == "X"]
    assert len(spans) == 6 * tr.n_frames  # device/offload/queue/upload/queue/serve
    assert all(e["dur"] >= 0 for e in spans)
    assert {e["pid"] for e in spans} == {1, 2, 3}


def test_tracer_rejected_on_jax_backend():
    with pytest.raises(ValueError, match="tracing"):
        _run("jax", telemetry=Telemetry(trace=True))


def test_profiler_phases_both_backends():
    tel = Telemetry(record=False, profile=True)
    _run("numpy", telemetry=tel)
    assert {"plan", "serve", "transmit", "fold"} <= set(tel.profiler.totals)
    tel_j = Telemetry(record=False, profile=True)
    _run("jax", telemetry=tel_j)
    assert {"precompute", "scan", "fold"} <= set(tel_j.profiler.totals)
    s = tel_j.profiler.summarize()
    assert s["total_s"] >= s["scan"]["total_s"] > 0


def test_profiler_unit():
    p = PhaseProfiler()
    assert not p and p.summarize() == {}
    p.add("x", 0.25)
    p.add("x", 0.75)
    with p.phase("y"):
        pass
    assert p
    s = p.summarize()
    assert s["x"] == {"total_s": 1.0, "calls": 2, "mean_ms": 500.0}
    assert s["y"]["calls"] == 1
    p.reset()
    assert not p


def test_aot_split_times_compile():
    import jax
    import jax.numpy as jnp

    prof = PhaseProfiler()
    compiled, dt = aot_split(jax.jit(lambda x: x * 2), jnp.ones(4),
                             profiler=prof)
    assert dt > 0 and prof.totals["compile"] == dt
    np.testing.assert_array_equal(np.asarray(compiled(jnp.ones(4))),
                                  np.full(4, 2.0))


# --------------------------------------------------------------------------- #
# metrics satellites: jain edge cases, empty percentiles, gated keys
# --------------------------------------------------------------------------- #


def test_jain_index_edge_cases():
    assert jain_index([]) == 1.0  # no streams: vacuously fair
    assert jain_index([0, 0, 0]) == 1.0  # nobody offloaded: fair
    assert jain_index([7.0]) == 1.0  # single stream
    assert jain_index([1, 0, 0, 0]) == pytest.approx(0.25)  # one stream hogs
    assert jain_index([3, 3, 3]) == pytest.approx(1.0)


def test_empty_latency_percentiles_are_null():
    m = ServeMetrics()
    s = m.summary()
    assert s["p50_latency_ms"] is None and s["p99_latency_ms"] is None
    assert s["frames"] == 0
    agg = AggregateMetrics(2)
    s = agg.summary()
    assert s["p50_latency_ms"] is None and s["p99_latency_ms"] is None
    # with data the percentiles come back as numbers
    agg.update_round([1, 1], [0, 0], [0, 0], [1, 1],
                     np.full((2, 1), 0.03), np.ones((2, 1), bool))
    s = agg.summary()
    assert s["p50_latency_ms"] == pytest.approx(30.0)


def test_wall_time_zero_gates_utilization_keys():
    agg = AggregateMetrics(2)
    assert agg.wall_time == 0.0
    s = agg.summary()
    assert "uplink_utilization" not in s
    assert "replica_utilization" not in s
    # a real run populates wall_time and the keys appear
    m, _ = _run("numpy", S=2, n=16)
    s = m.summary()
    assert m.wall_time > 0 and "uplink_utilization" in s
