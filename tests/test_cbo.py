"""CBO scheduling (paper §IV): optimal DP vs brute force, Algorithm 1 props."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.cbo import Env, Frame, brute_force, cbo_plan, optimal_schedule


def _random_instance(rng, n=None, m=None):
    n = n or int(rng.integers(1, 7))
    m = m or int(rng.integers(1, 4))
    gamma = 1 / 30
    frames = [
        Frame(arrival=i * gamma, conf=float(rng.uniform(0.2, 0.99)),
              sizes=tuple(sorted(rng.uniform(1e3, 2e5, size=m))))
        for i in range(n)
    ]
    env = Env(bandwidth=float(rng.uniform(1e5, 5e6)), latency=0.05, server_time=0.037,
              deadline=0.2, acc_server=tuple(sorted(rng.uniform(0.5, 0.99, size=m))))
    return frames, env


def test_optimal_matches_brute_force_fuzz(rng):
    for trial in range(120):
        frames, env = _random_instance(rng)
        opt = optimal_schedule(frames, env)
        assert opt.base_acc + opt.total_gain == pytest.approx(brute_force(frames, env), abs=1e-9), trial


def test_online_never_beats_optimal(rng):
    for trial in range(120):
        frames, env = _random_instance(rng)
        online = cbo_plan(frames, env)
        bf = brute_force(frames, env)
        assert online.base_acc + online.total_gain <= bf + 1e-9, trial


def test_online_plans_are_feasible(rng):
    """Every planned offload chain must fit the serial uplink + deadlines."""
    for trial in range(80):
        frames, env = _random_instance(rng, n=int(rng.integers(2, 8)))
        plan = cbo_plan(frames, env)
        # replay the chain in confidence order (the DP's schedule order)
        chain = sorted(plan.offloads, key=lambda ij: -frames[ij[0]].conf)
        t = 0.0
        for i, r in chain:
            f = frames[i]
            t = max(t, f.arrival) + f.sizes[r] / env.bandwidth
            assert t + env.server_time + env.latency <= f.arrival + env.deadline + 1e-9


def test_theta_semantics(rng):
    """theta = max confidence among offloaded frames; frames above theta stay."""
    for trial in range(60):
        frames, env = _random_instance(rng, n=5)
        plan = cbo_plan(frames, env)
        if not plan.offloads:
            continue
        off_confs = [frames[i].conf for i, _ in plan.offloads]
        assert plan.theta == pytest.approx(max(off_confs))


def test_zero_bandwidth_offloads_nothing():
    frames = [Frame(0.0, 0.5, (1e4,))]
    env = Env(bandwidth=1e-6, latency=0.05, server_time=0.037, deadline=0.2, acc_server=(0.9,))
    plan = cbo_plan(frames, env)
    assert plan.offloads == []


def test_high_conf_frames_not_offloaded():
    """Offloading a frame with conf > server accuracy can only hurt."""
    env = Env(bandwidth=1e9, latency=0.0, server_time=0.0, deadline=1.0, acc_server=(0.8,))
    frames = [Frame(0.0, 0.95, (1e3,)), Frame(1 / 30, 0.2, (1e3,))]
    plan = cbo_plan(frames, env)
    assert (0, 0) not in plan.offloads
    assert any(i == 1 for i, _ in plan.offloads)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 5), st.integers(1, 3), st.integers(0, 10_000))
def test_optimal_matches_brute_force_hypothesis(n, m, seed):
    rng = np.random.default_rng(seed)
    frames, env = _random_instance(rng, n=n, m=m)
    opt = optimal_schedule(frames, env)
    assert opt.base_acc + opt.total_gain == pytest.approx(brute_force(frames, env), abs=1e-9)
