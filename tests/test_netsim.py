"""Uplink simulator: serialization, determinism, batch/sequential equivalence."""
import numpy as np
import pytest

from repro.core.netsim import Uplink, mbps, png_size_model


def _random_workload(n=50, seed=0):
    rng = np.random.default_rng(seed)
    payloads = rng.uniform(100, 50_000, n)
    # submit times: mostly increasing with occasional bunching
    subs = np.sort(rng.uniform(0, 5, n))
    return payloads, subs


def test_busy_until_monotone_under_transmit():
    up = Uplink(bandwidth_bps=mbps(2.0), latency=0.05, server_time=0.01)
    payloads, subs = _random_workload()
    busy = up._busy_until
    for p, t in zip(payloads, subs):
        up.transmit(float(p), float(t))
        assert up._busy_until >= busy  # the wire never un-busies
        busy = up._busy_until


def test_transmit_lands_after_submit_plus_wire_time():
    up = Uplink(bandwidth_bps=1000.0, latency=0.02, server_time=0.01)
    land = up.transmit(500.0, 1.0)
    assert land == pytest.approx(1.0 + 0.5 + 0.01 + 0.02)


def test_jitter_determinism_for_fixed_seed():
    payloads, subs = _random_workload()

    def lands(seed):
        up = Uplink(bandwidth_bps=mbps(2.0), latency=0.05, server_time=0.01,
                    jitter=0.3, seed=seed)
        return [up.transmit(float(p), float(t)) for p, t in zip(payloads, subs)]

    assert lands(7) == lands(7)  # same seed, same trace
    assert lands(7) != lands(8)  # different seed, different trace


def test_would_land_at_consistent_with_transmit():
    for jitter in (0.0, 0.3):
        up = Uplink(bandwidth_bps=mbps(1.0), latency=0.05, server_time=0.01,
                    jitter=jitter, seed=3)
        payloads, subs = _random_workload(n=20, seed=1)
        for p, t in zip(payloads, subs):
            predicted = up.would_land_at(float(p), float(t))
            actual = up.transmit(float(p), float(t))
            assert actual == pytest.approx(predicted)


@pytest.mark.parametrize("jitter", [0.0, 0.25])
def test_transmit_batch_matches_sequential_transmit(jitter):
    payloads, subs = _random_workload(n=40, seed=2)
    up_seq = Uplink(bandwidth_bps=mbps(1.5), latency=0.05, server_time=0.02,
                    jitter=jitter, seed=5)
    up_bat = Uplink(bandwidth_bps=mbps(1.5), latency=0.05, server_time=0.02,
                    jitter=jitter, seed=5)
    # pre-load both with one transfer so _busy_until starts nonzero
    up_seq.transmit(10_000.0, 0.0)
    up_bat.transmit(10_000.0, 0.0)

    seq = np.array([up_seq.transmit(float(p), float(t)) for p, t in zip(payloads, subs)])
    bat = up_bat.transmit_batch(payloads, subs)
    np.testing.assert_allclose(bat, seq, rtol=0, atol=1e-9)
    assert up_bat._busy_until == pytest.approx(up_seq._busy_until)
    assert up_bat.n_transfers == up_seq.n_transfers == len(payloads) + 1


def test_transmit_batch_empty_and_stats():
    up = Uplink(bandwidth_bps=1000.0, latency=0.0, server_time=0.0)
    assert len(up.transmit_batch([], [])) == 0
    lands = up.transmit_batch([500.0, 500.0], [0.0, 0.0])
    np.testing.assert_allclose(lands, [0.5, 1.0])
    assert up.busy_seconds == pytest.approx(1.0)  # two 0.5 s transfers
    assert up.queued_seconds == pytest.approx(0.5)  # second waited for the first
    assert up.utilization(2.0) == pytest.approx(0.5)
    up.reset()
    assert up._busy_until == 0.0 and up.n_transfers == 0
    assert up.busy_seconds == 0.0 and up.queued_seconds == 0.0


def test_png_size_model_vectorized():
    res = np.array([112, 224])
    np.testing.assert_allclose(png_size_model(res), [15_000.0, 60_000.0])
