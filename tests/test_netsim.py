"""Uplink simulator: serialization, determinism, batch/sequential equivalence."""
import numpy as np
import pytest

from repro.core.netsim import Uplink, mbps, png_size_model


def _random_workload(n=50, seed=0):
    rng = np.random.default_rng(seed)
    payloads = rng.uniform(100, 50_000, n)
    # submit times: mostly increasing with occasional bunching
    subs = np.sort(rng.uniform(0, 5, n))
    return payloads, subs


def test_busy_until_monotone_under_transmit():
    up = Uplink(bandwidth_bps=mbps(2.0), latency=0.05, server_time=0.01)
    payloads, subs = _random_workload()
    busy = up._busy_until
    for p, t in zip(payloads, subs):
        up.transmit(float(p), float(t))
        assert up._busy_until >= busy  # the wire never un-busies
        busy = up._busy_until


def test_transmit_lands_after_submit_plus_wire_time():
    up = Uplink(bandwidth_bps=1000.0, latency=0.02, server_time=0.01)
    land = up.transmit(500.0, 1.0)
    assert land == pytest.approx(1.0 + 0.5 + 0.01 + 0.02)


def test_jitter_determinism_for_fixed_seed():
    payloads, subs = _random_workload()

    def lands(seed):
        up = Uplink(bandwidth_bps=mbps(2.0), latency=0.05, server_time=0.01,
                    jitter=0.3, seed=seed)
        return [up.transmit(float(p), float(t)) for p, t in zip(payloads, subs)]

    assert lands(7) == lands(7)  # same seed, same trace
    assert lands(7) != lands(8)  # different seed, different trace


def test_would_land_at_consistent_with_transmit():
    for jitter in (0.0, 0.3):
        up = Uplink(bandwidth_bps=mbps(1.0), latency=0.05, server_time=0.01,
                    jitter=jitter, seed=3)
        payloads, subs = _random_workload(n=20, seed=1)
        for p, t in zip(payloads, subs):
            predicted = up.would_land_at(float(p), float(t))
            actual = up.transmit(float(p), float(t))
            assert actual == pytest.approx(predicted)


@pytest.mark.parametrize("jitter", [0.0, 0.25])
def test_transmit_batch_matches_sequential_transmit(jitter):
    payloads, subs = _random_workload(n=40, seed=2)
    up_seq = Uplink(bandwidth_bps=mbps(1.5), latency=0.05, server_time=0.02,
                    jitter=jitter, seed=5)
    up_bat = Uplink(bandwidth_bps=mbps(1.5), latency=0.05, server_time=0.02,
                    jitter=jitter, seed=5)
    # pre-load both with one transfer so _busy_until starts nonzero
    up_seq.transmit(10_000.0, 0.0)
    up_bat.transmit(10_000.0, 0.0)

    seq = np.array([up_seq.transmit(float(p), float(t)) for p, t in zip(payloads, subs)])
    bat = up_bat.transmit_batch(payloads, subs)
    np.testing.assert_allclose(bat, seq, rtol=0, atol=1e-9)
    assert up_bat._busy_until == pytest.approx(up_seq._busy_until)
    assert up_bat.n_transfers == up_seq.n_transfers == len(payloads) + 1


def test_transmit_batch_empty_and_stats():
    up = Uplink(bandwidth_bps=1000.0, latency=0.0, server_time=0.0)
    assert len(up.transmit_batch([], [])) == 0
    lands = up.transmit_batch([500.0, 500.0], [0.0, 0.0])
    np.testing.assert_allclose(lands, [0.5, 1.0])
    assert up.busy_seconds == pytest.approx(1.0)  # two 0.5 s transfers
    assert up.queued_seconds == pytest.approx(0.5)  # second waited for the first
    assert up.utilization(2.0) == pytest.approx(0.5)
    up.reset()
    assert up._busy_until == 0.0 and up.n_transfers == 0
    assert up.busy_seconds == 0.0 and up.queued_seconds == 0.0


def test_png_size_model_vectorized():
    res = np.array([112, 224])
    np.testing.assert_allclose(png_size_model(res), [15_000.0, 60_000.0])


def test_would_land_at_pins_next_transmit_exactly():
    """Regression (jittered-bandwidth consistency): ``would_land_at`` must
    predict the *next* ``transmit``'s land time exactly — including when the
    start is clamped by a busy wire into a different jitter second, where
    sampling bandwidth at the unclamped submit time would diverge."""
    from repro.net import regime_shift_trace

    for kw in ({"jitter": 0.5, "seed": 11},
               {"trace": regime_shift_trace((20.0, 1.0), period=2.0)}):
        up = Uplink(bandwidth_bps=mbps(1.0), latency=0.05, server_time=0.01, **kw)
        # park the wire busy until t=3.7: submits at t<3.7 start mid-second 3
        up.transmit(mbps(1.0) * 3.7, 0.0)
        for t_submit in (0.2, 2.9, 3.69, 5.0):
            predicted = up.would_land_at(40_000.0, t_submit)
            assert up.transmit(40_000.0, t_submit) == predicted


def test_jitter_factors_cached_and_stable():
    """The per-second factor cache covers exactly the seconds touched and
    growing it never changes previously observed values (seed-per-second
    semantics) — including far-future instants, which must cost one cache
    entry rather than a dense 0..t table."""
    up = Uplink(bandwidth_bps=1000.0, latency=0.0, server_time=0.0, jitter=0.4, seed=9)
    early = up.bandwidth_at(np.arange(5, dtype=np.float64)).copy()
    far = up.current_bandwidth(1e9)  # must be instant, not a 10^9-entry table
    np.testing.assert_array_equal(up.bandwidth_at(np.arange(5, dtype=np.float64)), early)
    assert len(up._jit_keys) == 6  # seconds 0..4 plus 1e9, nothing else
    assert up.current_bandwidth(1e9) == far
    # and the scalar path reads the same cache
    assert up.current_bandwidth(3.0) == early[3]


def test_jitter_seeds_are_independent_channels():
    """Different seeds must give independent factor sequences — with the
    old additive ``seed + second`` seeding, seed c was just seed 0 shifted
    by c seconds, so multi-cell jitter sweeps measured copies of one
    channel."""
    a = Uplink(bandwidth_bps=1000.0, latency=0.0, server_time=0.0, jitter=0.4, seed=0)
    b = Uplink(bandwidth_bps=1000.0, latency=0.0, server_time=0.0, jitter=0.4, seed=1)
    shifted = a.bandwidth_at(np.arange(1, 21, dtype=np.float64))
    other = b.bandwidth_at(np.arange(0, 20, dtype=np.float64))
    assert not np.allclose(shifted, other)


def test_jittered_batch_bunched_submits_match_sequential():
    """Heavy bunching (all submits inside one second, queue draining across
    many seconds) — the fixed-point iteration must still equal the serial
    recursion."""
    payloads = np.full(60, 30_000.0)
    subs = np.zeros(60)
    up_seq = Uplink(bandwidth_bps=mbps(0.4), latency=0.0, server_time=0.0,
                    jitter=0.3, seed=21)
    up_bat = Uplink(bandwidth_bps=mbps(0.4), latency=0.0, server_time=0.0,
                    jitter=0.3, seed=21)
    seq = np.array([up_seq.transmit(float(p), float(t)) for p, t in zip(payloads, subs)])
    bat = up_bat.transmit_batch(payloads, subs)
    np.testing.assert_allclose(bat, seq, rtol=0, atol=1e-9)
    assert up_bat.queued_seconds == pytest.approx(up_seq.queued_seconds)
    assert up_bat.busy_seconds == pytest.approx(up_seq.busy_seconds)


def test_trace_overrides_base_bandwidth():
    from repro.net import BandwidthTrace

    tr = BandwidthTrace(t=np.array([0.0, 1.0]), bps=np.array([500.0, 2000.0]))
    up = Uplink(bandwidth_bps=999.0, latency=0.0, server_time=0.0, trace=tr)
    assert up.current_bandwidth(0.5) == 500.0
    assert up.current_bandwidth(1.5) == 2000.0
    assert up.transmit(500.0, 0.0) == pytest.approx(1.0)  # 500 B at 500 B/s
