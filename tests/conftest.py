import os
import sys

# Tests see the real single CPU device (the dry-run subprocess sets its own
# XLA_FLAGS; never set device-count flags here — see assignment note).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
