"""Optional-hypothesis shim for the property-based tests.

``hypothesis`` is a dev-only dependency (requirements-dev.txt). When it is
installed this module is a pass-through; when it is not, ``@given`` turns
into a skip marker so the property tests report as skipped while every
plain test in the same module still collects and runs (a bare
``pytest.importorskip`` would throw the whole module away).
"""
try:
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed (see requirements-dev.txt)")

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _AnyStrategy:
        """Stands in for ``strategies.<name>(...)`` inside @given arguments."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    strategies = _AnyStrategy()
