"""int8-KV decode attention kernel: shape/GQA/scale sweeps vs oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.int8_kv_decode.kernel import int8_kv_decode
from repro.kernels.int8_kv_decode.ref import decode_attention_ref


def _inputs(B, S, KH, G, D, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (B, KH * G, D), jnp.float32)
    kq = jax.random.randint(ks[1], (B, S, KH, D), -127, 128, jnp.int8)
    vq = jax.random.randint(ks[2], (B, S, KH, D), -127, 128, jnp.int8)
    kscale = jax.random.uniform(ks[3], (B, S), jnp.float32, 0.005, 0.02)
    vscale = jax.random.uniform(ks[4], (B, S), jnp.float32, 0.005, 0.02)
    return q, kq, kscale, vq, vscale


@pytest.mark.parametrize("B,S,KH,G,D,bs", [
    (1, 512, 1, 1, 64, 256),    # MQA
    (2, 1024, 4, 3, 64, 256),   # GQA
    (2, 512, 8, 1, 128, 512),   # MHA-ish
    (1, 2048, 2, 4, 64, 512),
])
def test_int8_kv_decode_sweep(B, S, KH, G, D, bs):
    args = _inputs(B, S, KH, G, D, seed=S + KH)
    out_k = int8_kv_decode(*args, bs=bs, interpret=True)
    out_r = decode_attention_ref(*args)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=2e-5, atol=2e-5)


def test_matches_model_fold_path():
    """Kernel semantics == the model's kv_scale_fold decode math."""
    B, S, KH, G, D = 2, 256, 2, 2, 32
    q, kq, kscale, vq, vscale = _inputs(B, S, KH, G, D, seed=7)
    out = decode_attention_ref(q, kq, kscale, vq, vscale)
    # manual dequant-first attention
    kf = kq.astype(jnp.float32) * kscale[:, :, None, None]
    vf = vq.astype(jnp.float32) * vscale[:, :, None, None]
    qg = q.reshape(B, KH, G, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, kf) / np.sqrt(D)
    p = jax.nn.softmax(s, -1)
    ref = jnp.einsum("bkgs,bskd->bkgd", p, vf).reshape(B, KH * G, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_extreme_scales_stable():
    B, S, KH, G, D = 1, 256, 1, 2, 32
    q, kq, _, vq, _ = _inputs(B, S, KH, G, D)
    kscale = jnp.full((B, S), 1e-8, jnp.float32)
    vscale = jnp.full((B, S), 10.0, jnp.float32)
    out = int8_kv_decode(q, kq, kscale, vq, vscale, bs=128, interpret=True)
    assert bool(jnp.all(jnp.isfinite(out)))
