"""Data pipeline determinism + confidence-score properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.confidence import margin, max_softmax, neg_entropy, sequence_confidence
from repro.data.pipeline import DeterministicPipeline, PipelineConfig, token_batch_fn
from repro.data.video import VideoDataConfig, make_dataset


def test_video_dataset_deterministic():
    cfg = VideoDataConfig(n_classes=4, img_res=16, frames_per_video=3)
    a = make_dataset(cfg, 5, seed=3)
    b = make_dataset(cfg, 5, seed=3)
    np.testing.assert_array_equal(a["frames"], b["frames"])
    np.testing.assert_array_equal(a["labels"], b["labels"])
    c = make_dataset(cfg, 5, seed=4)
    assert not np.array_equal(a["frames"], c["frames"])


def test_video_difficulty_skew_increases_noise():
    cfg = VideoDataConfig(n_classes=4, img_res=16, frames_per_video=8,
                          class_difficulty=(0.0, 0.3, 0.6, 1.0))
    d = make_dataset(cfg, 60, seed=0)
    # per-class high-frequency energy (noise proxy) grows with difficulty
    def hf(frames):
        return float(np.abs(np.diff(frames, axis=1)).mean())
    e = [hf(d["frames"][d["labels"] == c]) for c in range(4)]
    assert e[0] < e[-1]


def test_pipeline_batch_at_is_pure():
    pipe = DeterministicPipeline(PipelineConfig(global_batch=8, seed=1),
                                 token_batch_fn(100, 16), dataset_size=1000)
    a, b = pipe.batch_at(7), pipe.batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(pipe.batch_at(7)["tokens"], pipe.batch_at(8)["tokens"])


def test_pipeline_sharding_partitions_batch():
    fn = token_batch_fn(100, 8)
    full = DeterministicPipeline(PipelineConfig(global_batch=8, seed=0), fn, 100)
    s0 = DeterministicPipeline(PipelineConfig(global_batch=8, seed=0), fn, 100, shard_index=0, shard_count=2)
    s1 = DeterministicPipeline(PipelineConfig(global_batch=8, seed=0), fn, 100, shard_index=1, shard_count=2)
    assert s0.local_batch == 4 and s1.local_batch == 4
    assert s0.batch_at(3)["tokens"].shape[0] == 4


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 1000), st.integers(2, 20))
def test_confidence_scores_bounded(seed, k):
    logits = jax.random.normal(jax.random.PRNGKey(seed), (8, k)) * 5
    for fn in (max_softmax, margin, neg_entropy):
        c = np.asarray(fn(logits))
        assert np.all(c >= -1e-6) and np.all(c <= 1 + 1e-6), fn.__name__
    # max_softmax lower bound is 1/k (uniform)
    assert np.all(np.asarray(max_softmax(logits)) >= 1.0 / k - 1e-6)


def test_one_hot_logits_give_full_confidence():
    logits = jnp.array([[100.0, 0.0, 0.0]])
    assert float(max_softmax(logits)[0]) == pytest.approx(1.0)
    assert float(margin(logits)[0]) == pytest.approx(1.0)
    assert float(neg_entropy(logits)[0]) == pytest.approx(1.0, abs=1e-5)


def test_sequence_confidence_masked_mean():
    logits = jnp.zeros((1, 4, 5))
    logits = logits.at[0, 0, 0].set(100.0)  # token 0 fully confident
    mask = jnp.array([[1, 0, 0, 0]])
    assert float(sequence_confidence(logits, mask)[0]) == pytest.approx(1.0)
    assert float(sequence_confidence(logits)[0]) < 0.5
