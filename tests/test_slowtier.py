"""Continuous-batching slow tier (``src/repro/slowtier``).

Four layers:

* **formation oracle** — vectorized ``form_batches`` against the
  one-request-at-a-time ``form_batches_looped`` reference, bit-for-bit
  (hypothesis when installed, seeded fuzz always), plus hand-built edge
  cases: window-boundary ties, occupancy-cap spill, paged-capacity caps,
  zero-length rounds;
* **pool delegation** — ``ReplicaPool(batching=...)`` groups per replica
  exactly like its serial path, folds occupancy into the EWMA, and keeps
  the *degenerate* config (FlatService, window 0, cap 1) bit-for-bit with
  a batching-free pool;
* **calibration** — the ``fit_*`` least-squares fitters recover exact
  coefficients from noiseless samples and ``kind="best"`` picks the right
  family;
* **backends** — a degenerate-batching fabric still pins
  ``tests/data/fabric_snapshot.json`` on BOTH engine backends, and a live
  LinearBatch+window fabric stays decision-for-decision equal between the
  numpy and jax round loops (the ``_diff`` exactness policy).
"""
from __future__ import annotations

import json
import os

import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, strategies as st

from repro.slowtier import (ContinuousBatching, FlatService, LinearBatch,
                            StepBatch, fit_flat, fit_latency_model, fit_linear,
                            fit_step, form_batches, form_batches_looped,
                            model_coeffs, model_from_coeffs)

DATA = os.path.join(os.path.dirname(__file__), "data")


# --------------------------------------------------------------------- #
# formation: vectorized == looped, bit-for-bit
# --------------------------------------------------------------------- #

MODELS = [FlatService(0.02), LinearBatch(0.015, 0.004),
          StepBatch(0.01, 0.008, page_size=4),
          StepBatch(0.01, 0.008, page_size=4, max_pages=2)]


def _assert_formation_equal(arr, cfg, busy0):
    got = form_batches(arr, cfg, busy0=busy0)
    ref = form_batches_looped(arr, cfg, busy0=busy0)
    for name, g, r in zip(("done", "service", "batch_size", "batch_id"),
                          got, ref):
        assert np.array_equal(g, r), (name, cfg, arr, g, r)
    return got


def _fuzz_case(rng):
    n = int(rng.integers(1, 50))
    arr = np.sort(rng.exponential(0.02, size=n).cumsum())
    if rng.random() < 0.3:  # quantize: coincident arrivals + boundary ties
        arr = np.round(arr, 2)
    cfg = ContinuousBatching(
        MODELS[int(rng.integers(len(MODELS)))],
        window_s=float(rng.choice([0.0, 0.002, 0.01, 0.05])),
        max_batch=int(rng.integers(1, 10)) if rng.random() < 0.5 else None)
    return arr, cfg, float(rng.uniform(0.0, 0.15))


def test_formation_matches_looped_seeded_fuzz():
    rng = np.random.default_rng(7)
    for _ in range(200):
        arr, cfg, busy0 = _fuzz_case(rng)
        _assert_formation_equal(arr, cfg, busy0)


@settings(max_examples=80, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_formation_matches_looped_hypothesis(seed):
    arr, cfg, busy0 = _fuzz_case(np.random.default_rng(seed))
    _assert_formation_equal(arr, cfg, busy0)


def test_formation_zero_length_round():
    for cfg in (ContinuousBatching(MODELS[1], window_s=0.01),
                ContinuousBatching(MODELS[0])):
        done, service, bsize, bid = form_batches(np.zeros(0), cfg)
        assert done.shape == service.shape == bsize.shape == bid.shape == (0,)


def test_window_boundary_tie_joins():
    # an arrival exactly at t_open + window is admitted (<=, not <)
    w = 0.03125  # f32/f64-exact
    cfg = ContinuousBatching(LinearBatch(0.01, 0.002), window_s=w)
    arr = np.array([0.0, w, w + 1e-9])
    done, service, bsize, bid = form_batches(arr, cfg)
    assert list(bid) == [0, 0, 1]  # boundary joins; epsilon-later spills
    assert bsize[0] == 2
    # batch 0 launches when its window closes, not at the tie's arrival
    assert done[0] == w + float(cfg.model.batch_latency(2))


def test_cap_spill_launches_at_last_member():
    # cap binds -> batch launches at its last member's landing, the excess
    # spills to a batch opening no earlier than the first one's completion
    cfg = ContinuousBatching(LinearBatch(0.01, 0.002), window_s=1.0,
                             max_batch=2)
    arr = np.array([0.0, 0.25, 0.5])
    done, service, bsize, bid = form_batches(arr, cfg)
    assert list(bid) == [0, 0, 1] and list(bsize) == [2, 2, 1]
    f2 = float(cfg.model.batch_latency(2))
    assert done[0] == 0.25 + f2  # launch at arr[1], not window close
    # the spilled request's batch opens at max(busy, its arrival)
    assert done[2] == max(0.25 + f2, 0.5) + 1.0 + float(cfg.model.batch_latency(1))


def test_step_batch_capacity_caps_admission():
    model = StepBatch(0.01, 0.008, page_size=4, max_pages=2)
    assert model.capacity == 8
    cfg = ContinuousBatching(model, window_s=10.0)
    assert cfg.cap == 8.0
    arr = np.zeros(20)  # all land at once: 8 + 8 + 4
    done, service, bsize, bid = form_batches(arr, cfg)
    assert list(np.bincount(bid)) == [8, 8, 4]
    # max_batch tightens the model's cap, never loosens it
    assert ContinuousBatching(model, max_batch=3).cap == 3.0
    assert ContinuousBatching(model, max_batch=99).cap == 8.0


def test_degenerate_predicate():
    flat = FlatService(0.02)
    assert ContinuousBatching(flat, window_s=0.0, max_batch=1).degenerate
    assert not ContinuousBatching(flat, window_s=0.01, max_batch=1).degenerate
    assert not ContinuousBatching(flat, window_s=0.0, max_batch=2).degenerate
    assert not ContinuousBatching(LinearBatch(0.0, 0.02), max_batch=1).degenerate


def test_model_coeffs_roundtrip():
    for m in MODELS[:3]:
        kind, coeffs = model_coeffs(m)
        m2 = model_from_coeffs(kind, coeffs)
        n = np.arange(1, 9, dtype=np.float64)
        assert np.array_equal(m.batch_latency(n), m2.batch_latency(n))


def test_config_validation():
    with pytest.raises(ValueError):
        ContinuousBatching(FlatService(0.02), window_s=-0.1)
    with pytest.raises(ValueError):
        ContinuousBatching(FlatService(0.02), max_batch=0)
    with pytest.raises(ValueError):
        StepBatch(0.01, 0.008, page_size=0)
    with pytest.raises(ValueError):
        StepBatch(0.01, 0.008, page_size=4, max_pages=0)


# --------------------------------------------------------------------- #
# pool delegation
# --------------------------------------------------------------------- #


def _pool_rounds(pool, rng, n_rounds=6, max_batch=30):
    """Drive a pool through seeded rounds; return per-round outputs."""
    outs = []
    t = 0.0
    for _ in range(n_rounds):
        n = int(rng.integers(0, max_batch))
        arr = np.sort(t + rng.uniform(0.0, 0.3, size=n))
        rep = rng.integers(0, pool.n_replicas, size=n)
        outs.append((pool.process(arr, rep), pool.last_service.copy()))
        t += 0.3
    return outs


def test_degenerate_pool_bit_equal_serial():
    from repro.net import ReplicaPool

    st_vec = np.array([0.02, 0.03, 0.025])
    degen = ContinuousBatching(FlatService(0.02), window_s=0.0, max_batch=1)
    for seed in range(4):
        plain = ReplicaPool(3, st_vec, serial=True)
        batched = ReplicaPool(3, st_vec, serial=True, batching=degen)
        assert not batched._batching_live
        outs_p = _pool_rounds(plain, np.random.default_rng(seed))
        outs_b = _pool_rounds(batched, np.random.default_rng(seed))
        for (d_p, s_p), (d_b, s_b) in zip(outs_p, outs_b):
            assert np.array_equal(d_p, d_b)
            assert np.array_equal(s_p, s_b)
        assert np.array_equal(plain.busy_until, batched.busy_until)
        assert np.array_equal(plain.busy_seconds, batched.busy_seconds)
        assert np.array_equal(plain.queued_seconds, batched.queued_seconds)
        assert np.array_equal(plain.n_jobs, batched.n_jobs)
        assert batched.avg_batch == 1.0  # degenerate path never feeds the EWMA


def test_batched_pool_matches_formation_per_replica():
    # the pool's scatter/gather around form_batches must reproduce the raw
    # per-replica formation on the same grouped arrivals
    from repro.net import ReplicaPool

    rng = np.random.default_rng(3)
    cfg = ContinuousBatching(LinearBatch(0.015, 0.004), window_s=0.01)
    pool = ReplicaPool(2, 0.02, serial=True, batching=cfg)
    n = 24
    arr = np.sort(rng.uniform(0.0, 0.4, size=n))
    rep = rng.integers(0, 2, size=n)
    busy0 = pool.busy_until.copy()
    done = pool.process(arr, rep)
    for k in range(2):
        sel = rep == k
        d_ref, f_ref, _, _ = form_batches(arr[sel], cfg, busy0=busy0[k])
        assert np.array_equal(done[sel], d_ref)
        assert np.array_equal(pool.last_service[sel], f_ref)
        assert pool.busy_until[k] == d_ref[-1]


def test_pool_occupancy_ewma_and_expected_server_time():
    from repro.net import ReplicaPool

    cfg = ContinuousBatching(LinearBatch(0.015, 0.005), window_s=1.0)
    pool = ReplicaPool(1, 0.02, serial=True, batching=cfg, batch_beta=0.5)
    assert pool.avg_batch == 1.0
    assert pool.expected_server_time() == cfg.model.per_request(1.0)
    # 4 coincident requests -> one batch of 4 -> EWMA moves halfway to 4
    pool.process(np.zeros(4), np.zeros(4, dtype=np.int64))
    assert pool.avg_batch == 0.5 * 1.0 + 0.5 * 4.0
    assert pool.expected_server_time() == pytest.approx(
        float(cfg.model.per_request(pool.avg_batch)))
    # empty rounds leave the EWMA alone
    pool.process(np.zeros(0), np.zeros(0, dtype=np.int64))
    assert pool.avg_batch == 2.5
    assert pool.last_service.shape == (0,)
    pool.reset()
    assert pool.avg_batch == 1.0
    # without batching the estimate is the nominal mean, untouched
    plain = ReplicaPool(2, np.array([0.02, 0.04]))
    assert plain.expected_server_time() == plain.nominal_server_time


def test_pool_rejects_batching_without_serial():
    from repro.net import ReplicaPool

    with pytest.raises(ValueError):
        ReplicaPool(1, 0.02, serial=False,
                    batching=ContinuousBatching(FlatService(0.02)))
    with pytest.raises(ValueError):
        ReplicaPool(1, 0.02, batch_beta=0.0)


# --------------------------------------------------------------------- #
# calibration
# --------------------------------------------------------------------- #


def test_fit_recovers_exact_coefficients():
    n = np.array([1, 2, 4, 8, 16, 32], dtype=np.float64)
    flat, r0 = fit_flat(n, FlatService(0.0375).batch_latency(n))
    assert flat.server_time == pytest.approx(0.0375) and r0 < 1e-12
    lin, r1 = fit_linear(n, LinearBatch(0.012, 0.0031).batch_latency(n))
    assert lin.base == pytest.approx(0.012)
    assert lin.per_item == pytest.approx(0.0031)
    assert r1 < 1e-12
    step_true = StepBatch(0.01, 0.008, page_size=4)
    stp, r2 = fit_step(n, step_true.batch_latency(n), page_size=4)
    assert stp.base == pytest.approx(0.01)
    assert stp.per_page == pytest.approx(0.008)
    assert r2 < 1e-12


def test_fit_best_picks_generating_family():
    n = np.array([1, 2, 3, 4, 6, 8, 12, 16], dtype=np.float64)
    best, _ = fit_latency_model(n, LinearBatch(0.02, 0.001).batch_latency(n),
                                kind="best")
    assert isinstance(best, LinearBatch)
    best, _ = fit_latency_model(
        n, StepBatch(0.015, 0.01, page_size=4).batch_latency(n),
        kind="best", page_size=4)
    assert isinstance(best, StepBatch)
    with pytest.raises(ValueError):
        fit_latency_model(n, n, kind="nope")
    with pytest.raises(ValueError):
        fit_linear(np.array([0.5]), np.array([0.1]))  # batch sizes >= 1


def test_fit_clamps_negative_base():
    # noise can drive the unconstrained intercept negative; the fitter clamps
    n = np.array([1.0, 2.0, 3.0])
    y = np.array([0.001, 0.0035, 0.006])  # intercept ~ -0.0015
    lin, _ = fit_linear(n, y)
    assert lin.base == 0.0 and lin.per_item > 0.0


# --------------------------------------------------------------------- #
# backends: snapshot pin + numpy/jax differential under live batching
# --------------------------------------------------------------------- #


def _make_batching_server(backend, S, batching, *, bw_mbps=30.0, seed=0):
    from repro.core.netsim import Uplink, mbps
    from repro.net import EdgeFabric, ReplicaPool
    from repro.serving import FairScheduler, MultiStreamServer, ServeConfig
    from repro.serving.synthetic import synthetic_tiers

    fast, slow, cal = synthetic_tiers()
    cfg = ServeConfig(resolutions=(4, 8), acc_server=(0.7, 0.99), batch_size=16,
                      frame_rate=32.0, deadline=0.2)
    ups = [Uplink(bandwidth_bps=mbps(bw_mbps * 0.6), latency=0.05,
                  server_time=cfg.server_time, seed=seed + c)
           for c in range(2)]
    pool = ReplicaPool(2, np.array([cfg.server_time, cfg.server_time * 1.5]),
                       serial=True, batching=batching)
    fab = EdgeFabric(ups, pool, n_streams=S, placement="jsq")
    return MultiStreamServer(cfg, fast, slow, cal, None, n_streams=S,
                             scheduler=FairScheduler("round_robin"), fabric=fab,
                             policy="cbo", backend=backend)


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_degenerate_batching_pins_fabric_snapshot(backend):
    # same topology/workload as test_fleet_jax.py::test_fabric_snapshot's
    # "fabric" case, with a *degenerate* batching config attached: the pin
    # must hold bit-for-bit on both backends
    from repro.serving.synthetic import synthetic_streams

    with open(os.path.join(DATA, "fabric_snapshot.json")) as f:
        snap = json.load(f)["fabric"]
    S = 12
    # any FlatService makes the config degenerate; the pool's own per-replica
    # server_time is what the legacy path actually charges
    degen = ContinuousBatching(FlatService(0.037), window_s=0.0, max_batch=1)
    srv = _make_batching_server(backend, S, degen, bw_mbps=50.0)
    imgs, labels = synthetic_streams(S, 64)
    agg = srv.process_streams(imgs, labels)
    assert agg.accuracy == pytest.approx(snap["accuracy"], abs=1e-12)
    assert int(agg.n_offloaded) == snap["n_offloaded"]
    assert int(agg.n_deadline_miss) == snap["n_deadline_miss"]
    for m, ref in zip(agg.per_stream, snap["per_stream"]):
        assert m.accuracy == pytest.approx(ref["accuracy"], abs=1e-12)


def test_live_batching_differential_numpy_vs_jax():
    # LinearBatch + admission window (f32-exact coefficients): the two
    # round loops must agree decision-for-decision at the _diff tolerances
    from _diff import assert_round_equal
    from repro.serving.synthetic import synthetic_streams

    S = 12
    batching = ContinuousBatching(LinearBatch(0.03125, 0.0078125),
                                  window_s=0.03125)
    imgs, labels = synthetic_streams(S, 64, seed=0)
    records, metrics = {}, {}
    for backend in ("numpy", "jax"):
        srv = _make_batching_server(backend, S, batching)
        recs = []
        srv.round_hook = recs.append
        metrics[backend] = srv.process_streams(imgs, labels)
        records[backend] = recs
    rn, rj = records["numpy"], records["jax"]
    assert len(rn) == len(rj)
    for i, (a, b) in enumerate(zip(rn, rj)):
        assert_round_equal(a, b, ctx=f"live batching round {i}")
    mn, mj = metrics["numpy"], metrics["jax"]
    assert mn.n_frames == mj.n_frames
    assert mn.n_offloaded == mj.n_offloaded
    assert mn.n_deadline_miss == mj.n_deadline_miss
    assert mn.accuracy == mj.accuracy
    assert mn.n_offloaded > 0  # the workload actually exercises the slow tier


def test_live_batching_occupancy_tracks_across_backends():
    from repro.serving.synthetic import synthetic_streams

    S = 12
    batching = ContinuousBatching(LinearBatch(0.03125, 0.0078125),
                                  window_s=0.03125)
    imgs, labels = synthetic_streams(S, 64, seed=0)
    occ = {}
    for backend in ("numpy", "jax"):
        srv = _make_batching_server(backend, S, batching)
        srv.process_streams(imgs, labels)
        occ[backend] = srv.fabric.pool.avg_batch
    assert occ["numpy"] > 1.0  # real batches formed
    assert occ["jax"] == pytest.approx(occ["numpy"], rel=1e-5)
