"""Property tests for ``FleetState``'s segment ops against a per-stream
Python-list reference model.

``FleetState`` vectorizes what ``BacklogPolicy`` does with plain lists
(append, trim to the newest ``max_backlog``, prune expired, consume planned
offloads, clear retired streams) as flat struct-of-arrays segment ops.  The
reference model here IS those lists; every op sequence must leave both
representations identical, and the flat invariants (offsets = cumsum of
lengths, ``stream_id`` grouped ascending) must hold after every op.

Runs as hypothesis properties when hypothesis is installed (dev-only dep,
see ``tests/_hypothesis_compat.py``) and as plain seeded fuzz otherwise.
"""
from __future__ import annotations

import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st


# --------------------------------------------------------------------- #
# reference model: one Python list per stream
# --------------------------------------------------------------------- #

class RefFleet:
    def __init__(self, n_streams, max_backlog):
        self.n = n_streams
        self.mb = list(max_backlog)
        self.streams = [[] for _ in range(n_streams)]

    def extend(self, stream, arrival, conf):
        for s, a, c in zip(stream, arrival, conf):
            self.streams[int(s)].append((float(a), float(c)))
        for s in range(self.n):
            if self.mb[s] is not None and len(self.streams[s]) > self.mb[s]:
                # NB not seg[-mb:]: Python's [-0:] keeps everything
                self.streams[s] = self.streams[s][len(self.streams[s]) - self.mb[s]:]

    def prune_expired(self, now, deadline, mask):
        for s in range(self.n):
            if mask[s]:
                self.streams[s] = [f for f in self.streams[s]
                                   if f[0] + deadline > now[s]]

    def clear(self, mask):
        for s in range(self.n):
            if mask[s]:
                self.streams[s] = []

    def consume(self, off_stream, off_pos, clear_streams):
        drop = {}
        for s, p in zip(off_stream, off_pos):
            drop.setdefault(int(s), set()).add(int(p))
        for s in range(self.n):
            if clear_streams[s]:
                self.streams[s] = []
            elif s in drop:
                self.streams[s] = [f for p, f in enumerate(self.streams[s])
                                   if p not in drop[s]]

    def filter(self, keep):
        i = 0
        for s in range(self.n):
            seg = self.streams[s]
            self.streams[s] = [f for j, f in enumerate(seg) if keep[i + j]]
            i += len(seg)

    def flat(self):
        arr, conf, sid = [], [], []
        for s in range(self.n):
            for a, c in self.streams[s]:
                arr.append(a)
                conf.append(c)
                sid.append(s)
        return np.asarray(arr), np.asarray(conf), np.asarray(sid, dtype=np.int64)


def check(state, ref):
    arr, conf, sid = ref.flat()
    assert len(state) == len(arr)
    assert np.array_equal(state.stream_id, sid)
    assert np.array_equal(state.arrival, arr)
    assert np.array_equal(state.conf, conf)
    # flat invariants
    lens = np.asarray([len(s) for s in ref.streams])
    assert np.array_equal(state.lengths, lens)
    assert state.offsets[0] == 0 and state.offsets[-1] == len(state)
    assert np.array_equal(state.offsets, np.r_[0, np.cumsum(lens)])
    assert np.array_equal(state.stream_id,
                          np.repeat(np.arange(state.n_streams), lens))


# --------------------------------------------------------------------- #
# the op-sequence driver (shared by hypothesis and seeded fuzz)
# --------------------------------------------------------------------- #

def run_ops(seed, n_streams=5, n_ops=40, deadline=0.2):
    from repro.policy.fleet import FleetState

    rng = np.random.default_rng(seed)
    mb = [None, 1, 2, 3, 8][:n_streams]
    rng.shuffle(mb)
    state = FleetState(n_streams, max_backlog=mb)
    ref = RefFleet(n_streams, mb)
    t = 0.0
    for _ in range(n_ops):
        op = rng.integers(0, 5)
        if op == 0:  # extend: arbitrary interleaving, per-stream order kept
            k = int(rng.integers(0, 8))
            stream = rng.integers(0, n_streams, size=k)
            arrival = t + rng.integers(0, 16, size=k) / 32.0
            conf = rng.uniform(0.0, 1.0, size=k)
            state.extend(stream, arrival, conf)
            ref.extend(stream, arrival, conf)
            t += 0.25
        elif op == 1:  # prune_expired on a random stream mask
            now = t + rng.integers(-8, 8, size=n_streams) / 32.0
            mask = rng.random(n_streams) < 0.7
            state.prune_expired(now, deadline, mask)
            ref.prune_expired(now, deadline, mask)
        elif op == 2:  # clear retired streams
            mask = rng.random(n_streams) < 0.3
            state.clear(mask)
            ref.clear(mask)
        elif op == 3:  # consume planned offloads + one-shot clears
            lens = state.lengths
            off_s, off_p = [], []
            for s in range(n_streams):
                if lens[s] and rng.random() < 0.6:
                    npos = int(rng.integers(1, lens[s] + 1))
                    for p in sorted(rng.choice(lens[s], size=npos, replace=False)):
                        off_s.append(s)
                        off_p.append(int(p))
            clear = rng.random(n_streams) < 0.2
            removed = state.consume(np.asarray(off_s, dtype=np.int64),
                                    np.asarray(off_p, dtype=np.int64), clear)
            before = sum(len(s) for s in ref.streams)
            ref.consume(off_s, off_p, clear)
            assert removed == before - sum(len(s) for s in ref.streams)
        else:  # raw filter with an arbitrary keep mask
            keep = rng.random(len(state)) < 0.8
            state.filter(keep)
            ref.filter(keep)
        check(state, ref)


@given(st.integers(min_value=0, max_value=10_000))
@settings(max_examples=50, deadline=None)
def test_segment_ops_match_reference_hypothesis(seed):
    run_ops(seed)


@pytest.mark.parametrize("seed", range(20))
def test_segment_ops_match_reference(seed):
    run_ops(seed * 7919 + 13)


# --------------------------------------------------------------------- #
# targeted edge cases
# --------------------------------------------------------------------- #

def test_extend_trims_to_newest():
    from repro.policy.fleet import FleetState

    state = FleetState(1, max_backlog=3)
    arr = np.arange(7) / 32.0
    state.extend(np.zeros(7, dtype=np.int64), arr, arr)
    assert np.array_equal(state.arrival, arr[-3:])  # newest survive


def test_extend_unbounded_never_trims():
    from repro.policy.fleet import FleetState

    state = FleetState(2, max_backlog=[None, 2])
    arr = np.arange(10) / 32.0
    state.extend(np.repeat([0, 1], 5), np.r_[arr[:5], arr[5:]], arr)
    assert np.array_equal(state.lengths, [5, 2])


def test_extend_interleaved_keeps_per_stream_order():
    from repro.policy.fleet import FleetState

    state = FleetState(2, max_backlog=8)
    # frames for the two streams interleaved in one call: the regroup is
    # stable, so each stream keeps its own relative order
    state.extend(np.asarray([1, 0, 1, 0]), np.asarray([0.1, 0.2, 0.3, 0.4]),
                 np.asarray([1.0, 2.0, 3.0, 4.0]))
    assert np.array_equal(state.arrival, [0.2, 0.4, 0.1, 0.3])
    assert np.array_equal(state.conf, [2.0, 4.0, 1.0, 3.0])


def test_prune_boundary_is_strict():
    from repro.policy.fleet import FleetState

    # the compare is ``arrival + deadline > now``: a frame exactly AT its
    # deadline is expired (matches BacklogPolicy.plan's prune)
    state = FleetState(1, max_backlog=8)
    state.extend(np.zeros(2, dtype=np.int64), np.asarray([0.0, 0.0625]),
                 np.asarray([0.5, 0.5]))
    state.prune_expired(np.asarray([0.2]), 0.2, np.ones(1, dtype=bool))
    assert np.array_equal(state.arrival, [0.0625])


def test_consume_positions_are_pre_plan():
    from repro.policy.fleet import FleetState

    state = FleetState(2, max_backlog=8)
    state.extend(np.asarray([0, 0, 0, 1, 1]), np.arange(5) / 32.0,
                 np.arange(5, dtype=float))
    # positions index the backlog as of planning time, per stream
    n = state.consume(np.asarray([0, 0, 1]), np.asarray([0, 2, 1]),
                      np.zeros(2, dtype=bool))
    assert n == 3
    assert np.array_equal(state.conf, [1.0, 3.0])
    assert np.array_equal(state.lengths, [1, 1])
