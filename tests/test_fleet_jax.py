"""Differential gate for the JAX backend: jax == numpy, decision-for-decision.

Three layers, all driven through ``tests/_diff.py``:

* planner parity — ``FleetRunner(backend="jax")._plan_all_jax`` against the
  numpy ``plan_all`` on identical fuzzed backlogs (four policies, active
  masks, tie-heavy confidences): every integer field of the ``PlanBatch``
  bit-equal, floats at float32 tolerance;
* round-loop parity — ``run_differential`` replays seeded workloads through
  both ``MultiStreamServer`` backends with the ``round_hook`` attached and
  asserts every round record (S in {1, 3, 17}, degenerate + C2/K2 fabric,
  cbo/threshold, round_robin/fifo, churn on/off, jsq/least_land);
* golden pins — BOTH backends must reproduce
  ``tests/data/fabric_snapshot.json`` (frame_rate=32, the tie-free grid).

Plus a sharding smoke: the jax engine under ``sharding_ctx(make_local_mesh())``
must agree with its own off-mesh run (``shard`` constraints are layout
hints, never semantics).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from _diff import (THETA_ATOL, assert_fleet_equal, make_server,
                   run_differential)

DATA = os.path.join(os.path.dirname(__file__), "data")


# --------------------------------------------------------------------- #
# planner parity: FleetRunner(backend="jax") vs numpy plan_all
# --------------------------------------------------------------------- #

def make_runner(backend, policy_name, S, mb=12):
    from repro.core.netsim import png_size_model
    from repro.policy.fleet import FleetRunner
    from repro.policy.registry import make_policy

    kw = {"max_backlog": mb}
    if policy_name == "server":
        kw["frame_interval"] = 1.0 / 32.0
    return FleetRunner([make_policy(policy_name, **kw) for _ in range(S)],
                       resolutions=(4, 8), acc_server=(0.7, 0.99), deadline=0.2,
                       latency=0.05, server_time=0.037, size_of=png_size_model,
                       bw_init=50e6 / 8, backend=backend)


def fuzz_backlog(S, mb, seed, conf_grid=None):
    """One seeded ragged workload: per-stream ascending arrivals on the
    1/32 grid (exactly representable in f32 — tie-free prune compares),
    confidences either uniform or drawn from a coarse tie-heavy grid."""
    rng = np.random.default_rng(seed)
    lens = rng.integers(0, mb + 1, size=S)
    stream = np.repeat(np.arange(S), lens)
    t0 = rng.integers(0, 64, size=S) / 32.0
    pos = np.concatenate([np.arange(n) for n in lens]) if lens.sum() else np.zeros(0)
    arrival = t0[stream] + pos / 32.0
    if conf_grid is None:
        conf = rng.uniform(0.05, 0.95, size=lens.sum())
    else:
        conf = np.asarray(conf_grid)[rng.integers(0, len(conf_grid), size=lens.sum())]
    # plan a fraction of a frame after each stream's newest arrival
    now = t0 + (lens + 0.5) / 32.0
    bw = rng.uniform(2e5, 1e7, size=S)
    active = rng.random(S) < 0.8
    now = np.where(active, now, np.inf)
    return stream, arrival, conf, now, bw, active


def assert_plan_equal(pn, pj, ctx=""):
    for k in ("resolution", "n_offloads", "n_frames", "off_stream", "off_pos",
              "off_res", "planned"):
        assert np.array_equal(getattr(pn, k), getattr(pj, k)), (
            f"{ctx}: {k}: numpy={getattr(pn, k)!r} jax={getattr(pj, k)!r}")
    np.testing.assert_allclose(pj.theta, pn.theta, atol=THETA_ATOL,
                               err_msg=f"{ctx}: theta")
    np.testing.assert_allclose(pj.total_gain, pn.total_gain, atol=1e-4,
                               err_msg=f"{ctx}: total_gain")
    np.testing.assert_allclose(pj.base_acc, pn.base_acc, atol=1e-4,
                               err_msg=f"{ctx}: base_acc")


@pytest.mark.parametrize("policy", ["cbo", "threshold", "local", "server",
                                    "greedy-rate"])
@pytest.mark.parametrize("S", [1, 3, 17])
def test_planner_parity(policy, S):
    for seed in range(4):
        rn = make_runner("numpy", policy, S)
        rj = make_runner("jax", policy, S)
        stream, arrival, conf, now, bw, active = fuzz_backlog(S, 12, 100 * S + seed)
        for r in (rn, rj):
            r.observe_frames(stream, arrival, conf)
            r.bw_est[:] = bw
        pn = rn.plan_all(now, active)
        pj = rj.plan_all(now, active)
        assert_plan_equal(pn, pj, ctx=f"{policy} S={S} seed={seed}")
        assert_fleet_equal(rn.state, rj.state)  # post-prune state agrees too


@pytest.mark.parametrize("policy", ["cbo", "threshold"])
def test_planner_parity_tie_heavy(policy):
    # coarse confidence grid => many exact ties; stable tie-breaking in the
    # DP / threshold selection must match the numpy reference bit-for-bit
    for seed in range(4):
        rn = make_runner("numpy", policy, 9)
        rj = make_runner("jax", policy, 9)
        stream, arrival, conf, now, bw, active = fuzz_backlog(
            9, 12, 7000 + seed, conf_grid=(0.3, 0.5, 0.5, 0.7))
        for r in (rn, rj):
            r.observe_frames(stream, arrival, conf)
            r.bw_est[:] = bw
        assert_plan_equal(rn.plan_all(now, active), rj.plan_all(now, active),
                          ctx=f"tie-heavy {policy} seed={seed}")


def test_planner_parity_heterogeneous():
    # mixed fleet: three policy kinds with DIFFERENT max_backlogs, so the
    # jax path must pad every group to the widest L and trim per stream
    from repro.core.netsim import png_size_model
    from repro.policy.fleet import FleetRunner
    from repro.policy.registry import make_policy

    mix = (("cbo", 12), ("threshold", 8), ("greedy-rate", 10))

    def runner(backend, S):
        pols = [make_policy(name, max_backlog=mb)
                for name, mb in (mix[i % len(mix)] for i in range(S))]
        return FleetRunner(pols, resolutions=(4, 8), acc_server=(0.7, 0.99),
                           deadline=0.2, latency=0.05, server_time=0.037,
                           size_of=png_size_model, bw_init=50e6 / 8,
                           backend=backend)

    for S in (3, 9):
        for seed in range(3):
            rn, rj = runner("numpy", S), runner("jax", S)
            stream, arrival, conf, now, bw, active = fuzz_backlog(
                S, 12, 4200 + 10 * S + seed)
            for r in (rn, rj):
                r.observe_frames(stream, arrival, conf)
                r.bw_est[:] = bw
            pn = rn.plan_all(now, active)
            pj = rj.plan_all(now, active)
            assert_plan_equal(pn, pj, ctx=f"het S={S} seed={seed}")
            assert_fleet_equal(rn.state, rj.state)


def test_runner_backend_validation():
    from repro.core.netsim import png_size_model
    from repro.policy.fleet import FleetRunner
    from repro.policy.registry import make_policy

    common = dict(resolutions=(4, 8), acc_server=(0.7, 0.99), deadline=0.2,
                  latency=0.05, server_time=0.037, size_of=png_size_model)
    with pytest.raises(ValueError, match="backend"):
        FleetRunner([make_policy("cbo", max_backlog=8)], backend="torch", **common)
    # heterogeneous fleets segment per policy group: supported since the
    # sharded scale-out, so mixing plannable kinds must construct cleanly
    FleetRunner([make_policy("cbo", max_backlog=8),
                 make_policy("threshold", max_backlog=8)],
                backend="jax", **common)
    # unbounded backlogs cannot be padded to fixed shapes
    with pytest.raises(ValueError, match="max_backlog"):
        FleetRunner([make_policy("cbo", max_backlog=None)], backend="jax", **common)
    # a policy with no JAX planner AND no bound: the error lists EVERY
    # reason (the "optimal" offline DP trips both at once)
    with pytest.raises(ValueError) as ei:
        FleetRunner([make_policy("optimal")], backend="jax", **common)
    assert "no JAX planner" in str(ei.value)
    assert "max_backlog" in str(ei.value)


# --------------------------------------------------------------------- #
# round-loop parity: MultiStreamServer(backend="jax") vs numpy
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("S", [1, 3, 17])
def test_round_loop_parity_degenerate(S):
    run_differential(S=S, topology="degenerate", seed=S)


@pytest.mark.parametrize("placement", ["jsq", "least_land", "round_robin"])
def test_round_loop_parity_fabric(placement):
    run_differential(S=3, topology="fabric", placement=placement)


def test_round_loop_parity_threshold_fifo():
    run_differential(S=3, policy="threshold", scheduler="fifo")


@pytest.mark.parametrize("topology", ["degenerate", "fabric"])
def test_round_loop_parity_churn(topology):
    run_differential(S=3, topology=topology, churn=True, seed=5)


def test_round_loop_parity_heterogeneous():
    # per-stream policy factory => >1 group => the engine's segmented
    # per-group planning must match the numpy group-merge path round-for-round
    mix = ("cbo", "threshold", "greedy-rate")
    run_differential(S=6, policy=lambda i: mix[i % len(mix)], seed=11)


def test_round_loop_parity_heterogeneous_fabric():
    mix = ("cbo", "threshold")
    run_differential(S=4, policy=lambda i: mix[i % len(mix)],
                     topology="fabric", seed=12)


@pytest.mark.parametrize("topology", ["degenerate", "fabric"])
def test_round_loop_parity_jitter(topology):
    # counter-mode jitter: the PRNG-keyed factors are drawn inside the scan
    # and must reproduce the host rng's draws bit-for-bit (same fold_in
    # chain), so integer decisions stay exact
    run_differential(S=3, topology=topology, jitter=0.3,
                     jitter_mode="counter", seed=7)


def test_round_loop_parity_trace():
    # square-wave trace with a 1.5 s loop period: the ~2 s workload crosses
    # regime boundaries AND wraps the loop, all inside the compiled scan
    from repro.net.traces import regime_shift_trace

    tr = regime_shift_trace(levels_mbps=(20.0, 4.0), period=0.75, loop=True)
    run_differential(S=3, traces=[tr], seed=13)


def test_round_loop_parity_trace_fabric():
    # two cells on different traces; one also jittered — trace lookup and
    # counter jitter compose multiplicatively in-scan
    from repro.net.traces import regime_shift_trace

    trs = [regime_shift_trace(levels_mbps=(25.0, 6.0), period=0.75, loop=True),
           regime_shift_trace(levels_mbps=(12.0, 30.0, 8.0), period=0.5,
                              loop=True)]
    run_differential(S=4, topology="fabric", traces=trs, seed=14)
    run_differential(S=3, topology="fabric", traces=trs, jitter=0.2,
                     jitter_mode="counter", seed=15)


def test_post_run_fleet_state_parity():
    # after a full replay, the residual backlog state (rebuilt from the
    # padded arrays by the jax engine's fold-back) matches the numpy one
    from repro.serving.synthetic import synthetic_streams

    imgs, labels = synthetic_streams(3, 48, seed=9)
    states = {}
    for backend in ("numpy", "jax"):
        srv, _ = make_server(backend, S=3)
        srv.process_streams(imgs, labels)
        states[backend] = srv.fleet.state
    assert_fleet_equal(states["numpy"], states["jax"])


def test_server_backend_fail_fast():
    # unsupported fabric configs must raise at construction, not mid-run —
    # and the shared ``supports_jax`` predicate must agree with the raise
    from repro.core.netsim import Uplink, mbps
    from repro.net import EdgeFabric
    from repro.serving import MultiStreamServer, ServeConfig
    from repro.serving.engine_jax import jax_unsupported, supports_jax
    from repro.serving.synthetic import synthetic_tiers

    fast, slow, cal = synthetic_tiers()
    cfg = ServeConfig(resolutions=(4, 8), acc_server=(0.7, 0.99),
                      frame_rate=32.0, deadline=0.2)

    def server(backend, **up_kw):
        up = Uplink(bandwidth_bps=mbps(50.0), latency=0.05,
                    server_time=cfg.server_time, seed=0, **up_kw)
        return MultiStreamServer(cfg, fast, slow, cal, None, n_streams=2,
                                 fabric=EdgeFabric.degenerate(up, n_streams=2),
                                 backend=backend)

    # legacy "pcg" jitter draws from a host rng the compiled scan cannot
    # reproduce — construction must raise and name the fix
    with pytest.raises(ValueError, match="jitter_mode"):
        server("jax", jitter=0.3)
    # ...but the numpy backend still accepts it, and the predicate reports
    # the same verdict the constructor enforces
    srv = server("numpy", jitter=0.3)
    assert not supports_jax(srv)
    assert any("counter" in r for r in jax_unsupported(srv))
    # counter-mode jitter is expressible in-scan: constructs fine
    srv = server("jax", jitter=0.3, jitter_mode="counter")
    assert supports_jax(srv) and jax_unsupported(srv) == []


# --------------------------------------------------------------------- #
# golden snapshot: both backends pin tests/data/fabric_snapshot.json
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("backend", ["numpy", "jax"])
@pytest.mark.parametrize("topology,S", [("degenerate", 4), ("fabric", 12)])
def test_fabric_snapshot(backend, topology, S):
    from repro.serving.synthetic import synthetic_streams

    with open(os.path.join(DATA, "fabric_snapshot.json")) as f:
        snap = json.load(f)[topology]
    imgs, labels = synthetic_streams(S, 64)
    srv, _ = make_server(backend, S=S, topology=topology)
    agg = srv.process_streams(imgs, labels)
    assert agg.accuracy == pytest.approx(snap["accuracy"], abs=1e-12)
    assert int(agg.n_offloaded) == snap["n_offloaded"]
    assert int(agg.n_deadline_miss) == snap["n_deadline_miss"]
    for m, ref in zip(agg.per_stream, snap["per_stream"]):
        assert m.n_frames == ref["n_frames"]
        assert m.accuracy == pytest.approx(ref["accuracy"], abs=1e-12)
        assert m.offload_frac == pytest.approx(ref["offload_frac"], abs=1e-12)
        assert m.deadline_miss_frac == pytest.approx(ref["deadline_miss_frac"],
                                                     abs=1e-12)


# --------------------------------------------------------------------- #
# sharding smoke: the streams axis under a local mesh
# --------------------------------------------------------------------- #

def test_engine_under_local_mesh():
    from repro.launch.mesh import make_local_mesh
    from repro.serving.synthetic import synthetic_streams
    from repro.sharding.axes import sharding_ctx

    imgs, labels = synthetic_streams(4, 32, seed=3)

    def run():
        srv, _ = make_server("jax", S=4)
        return srv.process_streams(imgs, labels)

    base = run()
    with sharding_ctx(make_local_mesh()):
        meshed = run()
    assert meshed.n_frames == base.n_frames
    assert meshed.n_offloaded == base.n_offloaded
    assert meshed.n_deadline_miss == base.n_deadline_miss
    assert meshed.accuracy == base.accuracy


# --------------------------------------------------------------------- #
# multi-device parity: 8 forced host devices, streams axis really sharded
# --------------------------------------------------------------------- #

REPO = os.path.join(os.path.dirname(__file__), "..")

# subprocess because --xla_force_host_platform_device_count must land
# before jax imports (conftest pins the parent to a single CPU device)
MULTI_DEVICE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import sys
sys.path.insert(0, "tests")
import jax
assert len(jax.devices()) == 8, jax.devices()
from _diff import make_server
from repro.launch.mesh import make_streams_mesh
from repro.sharding.axes import sharding_ctx
from repro.serving.synthetic import synthetic_streams

S = 6  # NOT a multiple of 8: exercises stream padding under the mesh
imgs, labels = synthetic_streams(S, 32, seed=3)

def run(backend, mesh=None, **kw):
    srv, _ = make_server(backend, S=S, topology="fabric", **kw)
    if mesh is None:
        agg = srv.process_streams(imgs, labels)
    else:
        with sharding_ctx(mesh):
            agg = srv.process_streams(imgs, labels)
    return dict(n_frames=int(agg.n_frames), n_off=int(agg.n_offloaded),
                n_miss=int(agg.n_deadline_miss), acc=float(agg.accuracy))

out = {"numpy": run("numpy"), "jax1": run("jax"),
       "jax8": run("jax", make_streams_mesh(8))}
jit = dict(jitter=0.25, jitter_mode="counter")
out["numpy_jit"] = run("numpy", **jit)
out["jax8_jit"] = run("jax", make_streams_mesh(8), **jit)
print("JSON" + json.dumps(out))
"""


def test_multi_device_round_loop_parity():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", MULTI_DEVICE_SCRIPT],
                          capture_output=True, text=True, env=env, cwd=REPO,
                          timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    payload = [l for l in proc.stdout.splitlines() if l.startswith("JSON")][0][4:]
    out = json.loads(payload)
    # multi-device == single-device == numpy, decision-for-decision
    assert out["jax8"] == out["jax1"] == out["numpy"], out
    # ...and with in-scan counter jitter active under the mesh
    assert out["jax8_jit"] == out["numpy_jit"], out
