"""Deterministic synthetic Trace + NetCfg grid shared by the replay
equivalence fixture generator and the regression test.

The trace is model-free (pure numpy): random-but-seeded predictions whose
per-resolution accuracies mimic the real stack. It exists so the unified
policy replay engine can be checked, bit-for-bit, against the accuracy
numbers the seven hand-rolled §V loops produced before the migration.
"""
from __future__ import annotations

import numpy as np

FIXTURE_NETS = (
    dict(bandwidth_mbps=0.5),
    dict(bandwidth_mbps=2.0),
    dict(bandwidth_mbps=5.0),
    dict(bandwidth_mbps=20.0),
    dict(bandwidth_mbps=5.0, frame_rate=10.0),
    dict(bandwidth_mbps=5.0, latency=0.15),
    dict(bandwidth_mbps=2.0, frame_rate=20.0, deadline=0.3),
)


def make_synthetic_trace(seed: int = 0, n: int = 240):
    """A benchmarks.approaches.Trace with planted tier qualities (no models)."""
    from benchmarks import common as C
    from benchmarks.approaches import Trace

    rng = np.random.default_rng(seed)
    n_classes = 10
    labels = rng.integers(0, n_classes, size=n)

    def _pred_with_acc(acc: float, salt: int) -> np.ndarray:
        r = np.random.default_rng(seed + 1000 + salt)
        pred = labels.copy()
        wrong = r.uniform(size=n) >= acc
        pred[wrong] = (labels[wrong] + 1 + r.integers(0, n_classes - 1, size=int(wrong.sum()))) % n_classes
        return pred

    fast_pred = _pred_with_acc(0.60, 0)
    fast_fp_pred = _pred_with_acc(0.66, 1)
    slow_accs = np.linspace(0.55, 0.92, len(C.RESOLUTIONS))
    slow_by_res = {r: _pred_with_acc(float(a), 2 + k)
                   for k, (r, a) in enumerate(zip(C.RESOLUTIONS, slow_accs))}

    conf_raw = rng.uniform(0.25, 0.999, size=n)
    # calibrated = raw nudged toward correctness (monotone-ish, deterministic)
    correct = (fast_pred == labels).astype(float)
    conf_cal = np.clip(0.15 + 0.7 * conf_raw + 0.12 * (correct - 0.5), 0.01, 0.995)

    from repro.core.netsim import png_size_model

    sizes = {r: png_size_model(r, base_res=32, base_bytes=60000.0) for r in C.RESOLUTIONS}
    plan_acc = tuple(float(a) - 0.05 for a in slow_accs)
    return Trace(labels=labels, fast_pred=fast_pred, fast_fp_pred=fast_fp_pred,
                 slow_pred_by_res=slow_by_res, conf_raw=conf_raw, conf_cal=conf_cal,
                 sizes=sizes, plan_acc_by_res=plan_acc, local_acc_mean=0.60)
