"""Calibration (paper §III-B, Table I): metrics + the three calibrators."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.calibration import (
    IsotonicCalibrator,
    PlattCalibrator,
    TemperatureCalibrator,
    ece,
    mce,
    reliability_bins,
)


def _miscalibrated_data(n=4000, seed=0):
    """Scores cluster high while true accuracy is mediocre — the paper's
    Figure 5 pathology (conf 0.9 bin has 0.5 accuracy)."""
    rng = np.random.default_rng(seed)
    true_p = rng.uniform(0.05, 0.95, size=n)  # actual correctness prob
    correct = (rng.uniform(size=n) < true_p).astype(float)
    # strongly overconfident scores (paper: ECE 0.27 uncalibrated)
    conf = np.clip(0.78 + 0.25 * (true_p - 0.5) + 0.08 * rng.standard_normal(n), 0.01, 0.999)
    return conf, correct


def test_ece_perfect_calibration_is_zero():
    rng = np.random.default_rng(1)
    conf = rng.uniform(0.05, 0.95, 200_000)
    correct = (rng.uniform(size=len(conf)) < conf).astype(float)
    assert ece(conf, correct) < 0.02
    assert mce(conf, correct) < 0.05


def test_ece_detects_miscalibration():
    conf, correct = _miscalibrated_data()
    assert ece(conf, correct) > 0.1


def test_platt_reduces_ece_and_mce():
    conf, correct = _miscalibrated_data()
    platt = PlattCalibrator.fit(conf, correct)
    cal = np.asarray(platt(conf))
    assert ece(cal, correct) < ece(conf, correct) * 0.5
    assert mce(cal, correct) < mce(conf, correct)


def test_isotonic_reduces_ece():
    conf, correct = _miscalibrated_data()
    iso = IsotonicCalibrator.fit(conf, correct)
    cal = np.asarray(iso(conf))
    assert ece(cal, correct) < ece(conf, correct) * 0.6


def test_isotonic_overfits_more_than_platt_on_holdout():
    """The paper's Table I finding: Platt generalizes better on small data."""
    conf, correct = _miscalibrated_data(n=300, seed=2)
    conf_te, correct_te = _miscalibrated_data(n=4000, seed=3)
    platt = PlattCalibrator.fit(conf, correct)
    iso = IsotonicCalibrator.fit(conf, correct)
    e_platt = ece(np.asarray(platt(conf_te)), correct_te)
    e_iso = ece(np.asarray(iso(conf_te)), correct_te)
    assert e_platt <= e_iso + 0.02  # platt no worse (usually clearly better)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.floats(0, 1), st.booleans()), min_size=5, max_size=200))
def test_isotonic_is_monotone_and_bounded(pairs):
    scores = np.array([p[0] for p in pairs])
    correct = np.array([float(p[1]) for p in pairs])
    iso = IsotonicCalibrator.fit(scores, correct)
    xs = np.linspace(0, 1, 101)
    ys = np.asarray(iso(xs))
    assert np.all(np.diff(ys) >= -1e-6), "isotonic output must be nondecreasing"
    assert np.all((ys >= 0) & (ys <= 1))


def test_temperature_scaling_reduces_nll_miscalibration():
    rng = np.random.default_rng(4)
    n, k = 5000, 10
    labels = rng.integers(k, size=n)
    logits = rng.standard_normal((n, k)) * 1.0
    logits[np.arange(n), labels] += 1.0
    logits *= 4.0  # overconfident
    t = TemperatureCalibrator.fit(logits, labels)
    assert t.temperature > 1.5  # must cool the overconfident logits
    import jax.numpy as jnp

    conf_raw = np.asarray(jnp.max(jnp.exp(logits - np.max(logits, -1, keepdims=True)) /
                                  np.sum(np.exp(logits - np.max(logits, -1, keepdims=True)), -1, keepdims=True), -1))
    correct = (np.argmax(logits, -1) == labels).astype(float)
    cal = np.asarray(t(logits))
    assert ece(cal, correct) < ece(conf_raw, correct)


def test_reliability_bins_paper_binning():
    conf = np.array([0.05, 0.15, 0.95, 0.95])
    correct = np.array([1.0, 0.0, 1.0, 0.0])
    count, acc, mean_conf = reliability_bins(conf, correct, 10)
    assert count[0] == 1 and count[1] == 1 and count[9] == 2
    assert acc[9] == 0.5
