"""The unified OffloadPolicy decision plane: frontier DP equivalence,
registry, unified replay (vs pre-migration fixtures), serving regression."""
import json
import os
import sys

import numpy as np
import pytest

from repro.core.cbo import brute_force
from repro.core.netsim import Uplink, mbps
from repro.policy import (
    BandwidthEstimator,
    CBOPolicy,
    Env,
    Frame,
    LocalPolicy,
    PolicyRunner,
    available_policies,
    cbo_plan,
    make_policy,
    optimal_schedule,
    replay_trace,
    resolve_policies,
)
from repro.policy.reference import cbo_plan_reference, optimal_schedule_reference

DATA = os.path.join(os.path.dirname(__file__), "data")


def _random_instance(rng, n=None, m=None, deadline=None):
    n = n or int(rng.integers(1, 10))
    m = m or int(rng.integers(1, 5))
    frames = [
        Frame(arrival=i / 30, conf=float(rng.uniform(0.2, 0.99)),
              sizes=tuple(sorted(rng.uniform(1e3, 2e5, size=m))))
        for i in range(n)
    ]
    env = Env(bandwidth=float(rng.uniform(1e5, 5e6)), latency=0.05, server_time=0.037,
              deadline=deadline or float(rng.choice([0.15, 0.2, 0.3, 0.5])),
              acc_server=tuple(sorted(rng.uniform(0.5, 0.99, size=m))))
    return frames, env


# ------------------- vectorized frontier vs reference ---------------------- #


def test_frontier_cbo_matches_reference_fuzz(rng):
    """The vectorized DP must return the reference's *exact* schedule."""
    for trial in range(150):
        frames, env = _random_instance(rng)
        now = float(rng.choice([0.0, rng.uniform(0, 0.3)]))
        a = cbo_plan(frames, env, now=now)
        b = cbo_plan_reference(frames, env, now=now)
        assert a.offloads == b.offloads, trial
        assert a.total_gain == b.total_gain, trial
        assert (a.theta, a.resolution) == (b.theta, b.resolution), trial


def test_frontier_optimal_matches_reference_fuzz(rng):
    for trial in range(150):
        frames, env = _random_instance(rng)
        a = optimal_schedule(frames, env)
        b = optimal_schedule_reference(frames, env)
        assert a.offloads == b.offloads, trial
        assert a.total_gain == b.total_gain, trial


def test_frontier_matches_reference_under_ties(rng):
    """Duplicate sizes/confidences force equal busy-times and gains — the
    pruning tie-breaks must still reproduce the reference schedule."""
    for trial in range(80):
        n = int(rng.integers(2, 9))
        m = int(rng.integers(1, 3))
        sz = tuple(float(rng.choice([1e4, 5e4])) for _ in range(m))
        frames = [Frame(arrival=(i // 2) / 30, conf=float(rng.choice([0.4, 0.6])), sizes=sz)
                  for i in range(n)]
        env = Env(bandwidth=1e6, latency=0.05, server_time=0.037, deadline=0.3,
                  acc_server=tuple(float(rng.choice([0.8, 0.9])) for _ in range(m)))
        a, b = cbo_plan(frames, env), cbo_plan_reference(frames, env)
        assert a.offloads == b.offloads and a.total_gain == b.total_gain, trial
        c, d = optimal_schedule(frames, env), optimal_schedule_reference(frames, env)
        assert c.offloads == d.offloads and c.total_gain == d.total_gain, trial


def test_frontier_optimal_matches_brute_force(rng):
    for trial in range(60):
        frames, env = _random_instance(rng, n=int(rng.integers(1, 6)), m=int(rng.integers(1, 3)))
        opt = optimal_schedule(frames, env)
        assert opt.base_acc + opt.total_gain == pytest.approx(brute_force(frames, env), abs=1e-9), trial


def test_theta_tiebreak_selects_by_frame_index():
    """Two offloaded frames with exactly equal confidence: r° must come from
    the earliest such frame, not whichever float-equality match came first."""
    env = Env(bandwidth=1e9, latency=0.0, server_time=0.0, deadline=1.0,
              acc_server=(0.7, 0.9))
    frames = [Frame(0.0, 0.5, (1e3, 1e6)), Frame(1 / 30, 0.5, (1e3, 2e3))]
    plan = cbo_plan(frames, env)
    assert plan.theta == 0.5
    offs = dict(plan.offloads)
    assert set(offs) == {0, 1}
    # deterministic: the plan's r° is frame 0's resolution
    assert plan.resolution == offs[0]


# ------------------------------ registry ----------------------------------- #


def test_registry_has_all_builtins():
    assert {"cbo", "optimal", "threshold", "local", "server", "greedy-rate"} <= set(
        available_policies()
    )


def test_make_policy_unknown_name_raises():
    with pytest.raises(KeyError, match="unknown policy"):
        make_policy("no-such-policy")


def test_make_policy_passthrough_and_cfg():
    p = CBOPolicy(max_backlog=7)
    assert make_policy(p) is p
    with pytest.raises(TypeError):
        make_policy(p, max_backlog=3)
    q = make_policy("threshold", theta=0.7)
    assert q.theta == 0.7


def test_resolve_policies_specs():
    ps = resolve_policies("cbo", 3)
    assert len(ps) == 3 and len({id(p) for p in ps}) == 3  # fresh instances
    mixed = resolve_policies(lambda s: "local" if s % 2 else "cbo", 4)
    assert isinstance(mixed[0], CBOPolicy) and isinstance(mixed[1], LocalPolicy)
    with pytest.raises(ValueError, match="single policy instance"):
        resolve_policies(CBOPolicy(), 2)


# ------------------------- protocol semantics ------------------------------ #


def _env(m=2, bw=mbps(50.0)):
    return Env(bandwidth=bw, latency=0.01, server_time=0.01, deadline=5.0,
               acc_server=(0.7, 0.99)[:m])


def test_local_policy_never_offloads():
    p = make_policy("local")
    p.observe([Frame(0.0, 0.1, (1e3, 1e4))])
    plan = p.plan(0.0, _env())
    assert plan.offloads == []
    p.consume(i for i, _ in plan.offloads)
    assert p.backlog == []  # one-shot: decided frames never linger


def test_threshold_policy_obeys_theta():
    p = make_policy("threshold", theta=0.5, resolution=1)
    p.observe([Frame(0.0, 0.4, (1e3, 1e4)), Frame(0.01, 0.6, (1e3, 1e4))])
    plan = p.plan(0.02, _env())
    assert plan.offloads == [(0, 1)]


def test_server_policy_caps_resolution_by_sustainable_rate():
    # 8e3 bytes at 1e5 B/s = 80 ms > 1/30 s interval; 1e3 bytes fits
    p = make_policy("server", frame_interval=1 / 30)
    p.observe([Frame(0.0, 0.9, (1e3, 8e3))])
    plan = p.plan(0.0, _env(bw=1e5))
    assert plan.offloads == [(0, 0)]


def test_greedy_rate_policy_respects_local_acc():
    p = make_policy("greedy-rate", local_acc=0.995)  # nothing beats local
    p.observe([Frame(0.0, 0.2, (1e3, 1e4))])
    assert p.plan(0.0, _env()).offloads == []
    q = make_policy("greedy-rate", local_acc=0.5)
    q.observe([Frame(0.0, 0.2, (1e3, 1e4))])
    assert q.plan(0.0, _env()).offloads == [(0, 1)]  # highest beating res


def test_cbo_policy_prunes_expired_frames():
    p = make_policy("cbo")
    env = Env(bandwidth=mbps(50.0), latency=0.01, server_time=0.01, deadline=0.2,
              acc_server=(0.7, 0.99))
    p.observe([Frame(0.0, 0.3, (1e3, 1e4)), Frame(1.0, 0.3, (1e3, 1e4))])
    p.plan(1.0, env)  # frame 0's window [0, 0.2] expired at now=1.0
    assert [f.arrival for f in p.backlog] == [1.0]


def test_policy_runner_floors_dead_bandwidth():
    runner = PolicyRunner("cbo", resolutions=(4, 8), acc_server=(0.7, 0.99),
                          deadline=0.2, latency=0.01, server_time=0.01,
                          size_of=lambda r: 1e3 * r,
                          bw=BandwidthEstimator(estimate_bps=0.0))
    runner.add_frame(0.0, 0.3)
    plan = runner.plan(now=0.0)  # must not divide by zero
    assert plan.offloads == []


# ------------------- unified replay vs pre-migration ----------------------- #


@pytest.fixture(scope="module")
def bench_path():
    root = os.path.join(os.path.dirname(__file__), "..")
    for p in (root, os.path.dirname(__file__)):
        if p not in sys.path:
            sys.path.insert(0, p)
    return root


def test_replay_reproduces_premigration_approaches(bench_path):
    """All seven §V approaches through make_policy + replay_trace must match
    the hand-rolled per-approach loops they replaced, to 1e-9."""
    from _replay_fixture import FIXTURE_NETS, make_synthetic_trace
    from benchmarks.approaches import APPROACHES, NetCfg

    with open(os.path.join(DATA, "replay_fixture.json")) as f:
        fixture = json.load(f)
    trace = make_synthetic_trace()
    assert len(fixture) == len(FIXTURE_NETS)
    for row, net_kw in zip(fixture, FIXTURE_NETS):
        assert row["net"] == net_kw
        net = NetCfg(**net_kw)
        for name, fn in APPROACHES.items():
            assert fn(trace, net) == pytest.approx(row[name], abs=1e-9), (net_kw, name)


def test_replay_trace_local_tier_sheds_under_load():
    """local_time > frame interval: the local tier can't keep up, frames are
    shed (scored wrong) — the Compress baseline's failure mode."""
    n = 30
    labels = np.zeros(n, dtype=np.int64)
    env = Env(bandwidth=1.0, latency=10.0, server_time=0.0, deadline=0.1,
              acc_server=(0.9,))  # uplink useless: everything stays local
    res = replay_trace("local", conf=np.full(n, 0.9), slow_pred=np.zeros((1, n)),
                       sizes=[1e3], env=env, frame_interval=1 / 30,
                       local_pred=labels, local_time=0.5)
    acc_shed = res.accuracy(labels)
    res2 = replay_trace("local", conf=np.full(n, 0.9), slow_pred=np.zeros((1, n)),
                        sizes=[1e3], env=env, frame_interval=1 / 30,
                        local_pred=labels, local_time=0.0)
    assert res2.accuracy(labels) == 1.0
    assert acc_shed < 0.2  # at 0.5 s/frame vs 30 fps, most frames shed


# ---------------------- serving engine regression -------------------------- #


@pytest.fixture(scope="module")
def multistream_snapshot():
    with open(os.path.join(DATA, "multistream_snapshot.json")) as f:
        return json.load(f)


def test_multistream_policy_cbo_reproduces_premigration_metrics(multistream_snapshot):
    """MultiStreamServer(policy="cbo") must reproduce the per-stream metrics
    recorded before the AdaptiveController -> policy-plane migration."""
    from repro.serving import MultiStreamServer, ServeConfig
    from repro.serving.synthetic import synthetic_streams, synthetic_tiers

    fast, slow, cal = synthetic_tiers()
    cfg = ServeConfig(resolutions=(4, 8), acc_server=(0.7, 0.99), batch_size=16,
                      frame_rate=30.0, deadline=0.2)
    imgs, labels = synthetic_streams(4, 64)
    up = Uplink(bandwidth_bps=mbps(50.0), latency=0.05, server_time=cfg.server_time)
    agg = MultiStreamServer(cfg, fast, slow, cal, up, n_streams=4,
                            policy="cbo").process_streams(imgs, labels)
    for m, ref in zip(agg.per_stream, multistream_snapshot["per_stream"]):
        assert m.accuracy == pytest.approx(ref["accuracy"], abs=1e-9)
        assert m.offload_frac == pytest.approx(ref["offload_frac"], abs=1e-9)
        assert m.deadline_miss_frac == pytest.approx(ref["deadline_miss_frac"], abs=1e-9)
        assert m.n_frames == ref["n_frames"]
    assert agg.n_offloaded == multistream_snapshot["n_offloaded"]


def test_cascade_server_policy_cbo_reproduces_premigration_metrics(multistream_snapshot):
    from repro.serving import CascadeServer, ServeConfig
    from repro.serving.synthetic import synthetic_streams, synthetic_tiers

    fast, slow, cal = synthetic_tiers()
    cfg = ServeConfig(resolutions=(4, 8), acc_server=(0.7, 0.99), batch_size=16,
                      frame_rate=30.0, deadline=0.2)
    imgs, labels = synthetic_streams(1, 64)
    up = Uplink(bandwidth_bps=mbps(50.0), latency=0.05, server_time=cfg.server_time)
    m = CascadeServer(cfg, fast, slow, cal, up).process_stream(imgs[0], labels[0])
    ref = multistream_snapshot["cascade_single"]
    assert m.accuracy == pytest.approx(ref["accuracy"], abs=1e-9)
    assert m.offload_frac == pytest.approx(ref["offload_frac"], abs=1e-9)


def test_multistream_heterogeneous_policy_fleet():
    """Per-stream factory: 'local' streams must never offload while 'cbo'
    streams still escalate over the shared uplink."""
    from repro.serving import MultiStreamServer, ServeConfig
    from repro.serving.synthetic import synthetic_streams, synthetic_tiers

    fast, slow, cal = synthetic_tiers()
    cfg = ServeConfig(resolutions=(4, 8), acc_server=(0.7, 0.99), batch_size=16,
                      frame_rate=30.0, deadline=0.2)
    imgs, labels = synthetic_streams(4, 64)
    up = Uplink(bandwidth_bps=mbps(50.0), latency=0.05, server_time=cfg.server_time)
    agg = MultiStreamServer(cfg, fast, slow, cal, up, n_streams=4,
                            policy=lambda s: "local" if s < 2 else "cbo",
                            ).process_streams(imgs, labels)
    per = agg.per_stream
    assert per[0].n_offloaded == 0 and per[1].n_offloaded == 0
    assert per[2].n_offloaded + per[3].n_offloaded > 0
