"""Multi-stream serving: N=1 equivalence, fairness, contention, vector gates."""
import numpy as np
import pytest

from repro.core.netsim import Uplink, mbps
from repro.serving import (
    ArrivalSchedule,
    CascadeServer,
    FairScheduler,
    MultiStreamServer,
    ServeConfig,
    jain_index,
    select_escalations,
)


from repro.serving.synthetic import synthetic_streams, synthetic_tiers


def _tiers():
    fast, slow, _ = synthetic_tiers()
    return fast, slow


def _streams(n_streams, n=64, seed=0):
    return synthetic_streams(n_streams, n, seed=seed)


def _cfg():
    return ServeConfig(resolutions=(4, 8), acc_server=(0.7, 0.99), batch_size=16,
                       frame_rate=30.0, deadline=0.2)


def _uplink(cfg, bw_mbps=50.0, latency=0.05):
    return Uplink(bandwidth_bps=mbps(bw_mbps), latency=latency, server_time=cfg.server_time)


def test_single_stream_equivalence():
    """MultiStreamServer with one stream reproduces CascadeServer."""
    cfg = _cfg()
    fast, slow = _tiers()
    imgs, labels = _streams(1)
    ref = CascadeServer(cfg, fast, slow, lambda s: s, _uplink(cfg)).process_stream(imgs[0], labels[0])
    multi = MultiStreamServer(cfg, fast, slow, lambda s: s, _uplink(cfg), n_streams=1)
    agg = multi.process_streams(imgs, labels)
    assert agg.n_frames == ref.n_frames
    assert agg.accuracy == pytest.approx(ref.accuracy, abs=0.02)
    assert agg.offload_frac == pytest.approx(ref.offload_frac, abs=0.02)
    assert agg.deadline_miss_frac == pytest.approx(ref.deadline_miss_frac, abs=0.02)


def test_multi_stream_improves_over_fast_tier():
    cfg = _cfg()
    fast, slow = _tiers()
    imgs, labels = _streams(4)
    agg = MultiStreamServer(cfg, fast, slow, lambda s: s, _uplink(cfg),
                            n_streams=4).process_streams(imgs, labels)
    import jax.numpy as jnp

    flat = imgs.reshape(-1, *imgs.shape[2:])
    fast_acc = float((np.argmax(np.asarray(fast(jnp.asarray(flat))), -1) == labels.reshape(-1)).mean())
    assert agg.accuracy >= fast_acc - 1e-9
    assert agg.offload_frac > 0
    assert agg.n_frames == 4 * 64


def test_multi_stream_deadline_misses_fall_back():
    """Huge latency: every escalation lands late; fast answers must stand."""
    cfg = _cfg()
    fast, slow = _tiers()
    imgs, labels = _streams(4)
    agg = MultiStreamServer(cfg, fast, slow, lambda s: s, _uplink(cfg, latency=10.0),
                            n_streams=4).process_streams(imgs, labels)
    assert agg.n_offloaded == 0
    assert max(x for m in agg.per_stream for x in m.latencies) <= cfg.deadline + 1e-9


def test_streams_share_one_uplink():
    """The uplink's transfer count must equal total escalations across streams."""
    cfg = _cfg()
    fast, slow = _tiers()
    imgs, labels = _streams(4)
    up = _uplink(cfg)
    agg = MultiStreamServer(cfg, fast, slow, lambda s: s, up, n_streams=4).process_streams(imgs, labels)
    assert up.n_transfers == agg.n_offloaded + agg.n_deadline_miss
    assert up.busy_seconds > 0


def test_select_escalations_matches_naive_loop():
    rng = np.random.default_rng(0)
    conf = rng.uniform(size=(5, 12))
    theta = np.array([0.3, 0.0, 0.9, 0.5, 1.0])
    cap = np.array([2, 3, 4, 0, 100])
    s_idx, slot_idx = select_escalations(conf, theta, cap)
    got = set(zip(s_idx.tolist(), slot_idx.tolist()))
    want = set()
    for s in range(5):
        below = [(conf[s, j], j) for j in range(12) if conf[s, j] < theta[s]]
        for _, j in sorted(below)[: cap[s]]:
            want.add((s, j))
    assert got == want


def test_fair_scheduler_burst_does_not_starve_sparse_stream():
    # stream 0 dumps 5 frames; stream 1 has one frame ready just after.
    stream = np.array([0, 0, 0, 0, 0, 1])
    t_ready = np.array([0.0, 0.001, 0.002, 0.003, 0.004, 0.0045])
    cost = np.full(6, 0.05)  # each transfer far longer than the ready gaps
    fifo_pos = int(np.flatnonzero(FairScheduler("fifo").order(stream, t_ready) == 5)[0])
    rr_pos = int(np.flatnonzero(FairScheduler("round_robin").order(stream, t_ready, cost) == 5)[0])
    assert fifo_pos == 5  # FIFO: the burst goes first, sparse stream waits
    assert rr_pos == 1  # fair queueing: sparse stream's frame goes second


def test_fair_scheduler_weights_bias_the_interleave():
    stream = np.array([0, 0, 0, 1, 1, 1])
    t_ready = np.zeros(6)
    cost = np.full(6, 0.1)
    # stream 1 weighted 3x: it should get ~3 slots before stream 0's second
    order = FairScheduler("round_robin", weights=np.array([1.0, 3.0])).order(stream, t_ready, cost)
    first_four = stream[order][:4]
    assert first_four.sum() == 3  # three of the first four slots go to stream 1


def test_fair_scheduler_rejects_bad_args():
    with pytest.raises(ValueError):
        FairScheduler("lifo")
    with pytest.raises(ValueError):
        FairScheduler("round_robin", weights=np.array([1.0, 0.0]))


def test_arrival_schedule_interleaves_streams():
    sched = ArrivalSchedule.interleaved(4, 32, frame_rate=30.0, deadline=0.2)
    assert sched.arrival.shape == (4, 32)
    # within one slot, streams are phase-staggered and strictly ordered
    assert np.all(np.diff(sched.arrival[:, 0]) > 0)
    # stagger never reorders across slots
    flat = sched.arrival.T.reshape(-1)
    assert np.all(np.diff(flat) > 0)
    rounds = list(sched.rounds(16))
    assert [s for s, _, _ in rounds] == [0, 16]
    assert rounds[0][1].shape == (4, 16)
    assert rounds[0][2].all()  # lockstep: every slot valid
    assert sched.horizon == pytest.approx(sched.arrival.max() + 0.2)
    # trailing partial rounds are yielded, not dropped
    ragged = list(ArrivalSchedule.interleaved(4, 37, frame_rate=30.0, deadline=0.2).rounds(16))
    assert [s for s, _, _ in ragged] == [0, 16, 32]
    assert ragged[-1][1].shape == (4, 5)


def test_jain_index_bounds():
    assert jain_index([5, 5, 5, 5]) == pytest.approx(1.0)
    assert jain_index([10, 0, 0, 0]) == pytest.approx(0.25)
    assert jain_index([]) == 1.0


def test_controller_consume_removes_planned_frames():
    from repro.core.netsim import png_size_model
    from repro.core.policy import AdaptiveController, BandwidthEstimator

    ctrl = AdaptiveController(
        resolutions=(4, 8), acc_server=(0.7, 0.99), deadline=5.0, latency=0.01,
        server_time=0.01, size_of=png_size_model,
        bw=BandwidthEstimator(estimate_bps=mbps(50.0)),
    )
    for i in range(6):
        ctrl.add_frame(arrival=0.01 * i, conf=0.3 + 0.1 * i)
    plan = ctrl.plan(now=0.1)
    assert plan.offloads  # generous env: something must be worth offloading
    before = list(ctrl.backlog)
    removed = ctrl.consume(i for i, _ in plan.offloads)
    assert removed == len(plan.offloads)
    kept = {i for i in range(len(before))} - {i for i, _ in plan.offloads}
    assert ctrl.backlog == [before[i] for i in sorted(kept)]
    # consuming again is a no-op for those indices against the shrunk list
    assert ctrl.consume([]) == 0
    assert ctrl.consume([999]) == 0
