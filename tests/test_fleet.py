"""Fleet control plane: batched plan_many == looped plan for every policy,
churn schedules, partial batches, and the struct-of-arrays state ops."""
import json
import os

import numpy as np
import pytest

from repro.core.netsim import Uplink, mbps, payload_sizes, png_size_model
from repro.policy import (
    BandwidthEstimator,
    FleetRunner,
    FleetState,
    PolicyRunner,
    available_policies,
    make_policy,
)
from repro.serving import ArrivalSchedule, CascadeServer, MultiStreamServer, ServeConfig
from repro.serving.synthetic import synthetic_streams, synthetic_tiers

DATA = os.path.join(os.path.dirname(__file__), "data")

# policies that are exercised through the serving-style fleet path
FLEET_POLICIES = ("cbo", "optimal", "threshold", "local", "server", "greedy-rate")


def _pair(name, n_streams, rng, m=3):
    """A FleetRunner and S equivalent PolicyRunners with identical state."""
    resolutions = tuple(4 * (i + 1) for i in range(m))
    acc = tuple(sorted(rng.uniform(0.5, 0.99, size=m)))
    deadline = float(rng.choice([0.15, 0.2, 0.3, 0.5]))
    kw = dict(resolutions=resolutions, acc_server=acc, deadline=deadline,
              latency=0.05, server_time=0.037,
              size_of=lambda r: png_size_model(r, base_res=16))
    fleet = FleetRunner([make_policy(name) for _ in range(n_streams)], bw_init=1.0, **kw)
    runners = [PolicyRunner(make_policy(name), bw=BandwidthEstimator(estimate_bps=1.0), **kw)
               for _ in range(n_streams)]
    bw = rng.uniform(1e5, 5e6, size=n_streams)
    fleet.bw_est[:] = bw
    for s in range(n_streams):
        runners[s].bw.estimate_bps = bw[s]
        for i in range(int(rng.integers(0, 12))):
            a, c = i / 30.0, float(rng.uniform(0.2, 0.99))
            runners[s].add_frame(a, c)
            fleet.add_frame(s, a, c)
    return fleet, runners


def _assert_plans_match(batch, runners, now):
    for s, runner in enumerate(runners):
        ref = runner.plan(now=now)
        got = batch.plan(s)
        assert got.offloads == ref.offloads, s
        assert got.theta == ref.theta, s
        assert got.resolution == ref.resolution, s
        assert got.n_frames == ref.n_frames, s
        # gains/base accuracies may differ from the looped floats only by
        # summation order (segment reductions vs sequential adds)
        assert got.total_gain == pytest.approx(ref.total_gain, abs=1e-9), s
        assert got.base_acc == pytest.approx(ref.base_acc, abs=1e-9), s


@pytest.mark.parametrize("name", FLEET_POLICIES)
def test_plan_many_matches_looped_plan_fuzz(name, rng):
    """Batched plan_all must reproduce per-stream plan for every registered
    policy on random ragged backlogs and bandwidths."""
    for trial in range(25):
        S = int(rng.integers(1, 9))
        fleet, runners = _pair(name, S, rng)
        now = float(rng.choice([0.0, 0.05]))
        _assert_plans_match(fleet.plan_all(np.full(S, now)), runners, now)


def test_registry_is_covered():
    """Every registered policy is exercised by the fleet fuzz test."""
    assert set(FLEET_POLICIES) == set(available_policies())


def test_cbo_plan_many_matches_under_ties(rng):
    """Duplicate sizes/confidences force equal busy-times and gains across
    chains — the batched merge's tie-breaks must still reproduce the
    per-stream planner's schedule exactly."""
    for trial in range(60):
        S = int(rng.integers(1, 10))
        m = int(rng.integers(1, 3))
        sizes = tuple(float(rng.choice([1e4, 5e4])) for _ in range(m))
        acc = tuple(float(rng.choice([0.8, 0.9])) for _ in range(m))
        kw = dict(resolutions=tuple(range(m)), acc_server=acc, deadline=0.3,
                  latency=0.05, server_time=0.037,
                  size_of=lambda r, s=sizes: np.asarray(s)[np.asarray(r, dtype=np.int64) % m])
        fleet = FleetRunner([make_policy("cbo") for _ in range(S)], bw_init=1e6, **kw)
        runners = [PolicyRunner(make_policy("cbo"),
                                bw=BandwidthEstimator(estimate_bps=1e6), **kw)
                   for _ in range(S)]
        for s in range(S):
            for i in range(int(rng.integers(2, 12))):
                a, c = (i // 2) / 30.0, float(rng.choice([0.4, 0.6]))
                runners[s].add_frame(a, c)
                fleet.add_frame(s, a, c)
        batch = fleet.plan_all(np.zeros(S))
        for s in range(S):
            ref, got = runners[s].plan(now=0.0), batch.plan(s)
            assert got.offloads == ref.offloads and got.theta == ref.theta, (trial, s)
            assert got.total_gain == ref.total_gain, (trial, s)


def test_batched_bandwidth_fold_matches_sequential(rng):
    """observe_bandwidth must be bit-identical to per-transfer EWMA updates
    in array order (including the <=1e-9s skip)."""
    S = 5
    est0 = rng.uniform(1e5, 1e7, size=S)
    fleet = FleetRunner([make_policy("cbo") for _ in range(S)], resolutions=(4,),
                        acc_server=(0.9,), deadline=0.2, latency=0.05,
                        server_time=0.037, size_of=lambda r: 1e3,
                        bw_init=est0.copy())
    seq = [BandwidthEstimator(estimate_bps=float(e)) for e in est0]
    stream = rng.integers(0, S, size=24)
    payload = rng.uniform(1e3, 1e5, size=24)
    seconds = rng.uniform(-0.01, 0.3, size=24)  # a few <= 1e-9 to skip
    for k in range(24):
        seq[stream[k]].observe(float(payload[k]), float(seconds[k]))
    fleet.observe_bandwidth(stream, payload, seconds)
    for s in range(S):
        assert fleet.bw_est[s] == seq[s].estimate_bps, s


def test_fleet_state_consume_extend_invariants():
    st = FleetState(3, max_backlog=4)
    st.extend(np.array([0, 0, 1, 2, 2, 2]), np.arange(6) / 30.0,
              np.linspace(0.2, 0.7, 6))
    assert st.lengths.tolist() == [2, 1, 3]
    # per-stream insertion order is preserved and trimming keeps the newest
    st.extend(np.array([0, 0, 0]), np.array([1.0, 1.1, 1.2]), np.array([0.9, 0.8, 0.7]))
    assert st.lengths.tolist() == [4, 1, 3]  # trimmed to max_backlog=4
    lo, hi = st.offsets[0], st.offsets[1]
    assert st.arrival[lo:hi].tolist() == [1 / 30.0, 1.0, 1.1, 1.2]
    # consume removes planned positions; clear wipes whole streams
    st.consume(np.array([0]), np.array([1]), np.zeros(3, dtype=bool))
    assert st.lengths.tolist() == [3, 1, 3]
    st.clear(np.array([False, False, True]))
    assert st.lengths.tolist() == [3, 1, 0]


def _cfg():
    return ServeConfig(resolutions=(4, 8), acc_server=(0.7, 0.99), batch_size=16,
                       frame_rate=30.0, deadline=0.2)


def _uplink(cfg):
    return Uplink(bandwidth_bps=mbps(50.0), latency=0.05, server_time=cfg.server_time)


@pytest.fixture(scope="module")
def snapshot():
    with open(os.path.join(DATA, "multistream_snapshot.json")) as f:
        return json.load(f)


def test_churn_degenerating_to_lockstep_reproduces_snapshot(snapshot):
    """ArrivalSchedule.churn(join=0, length=N) must reproduce the recorded
    pre-refactor lockstep metrics exactly."""
    fast, slow, cal = synthetic_tiers()
    cfg = _cfg()
    imgs, labels = synthetic_streams(4, 64)
    sched = ArrivalSchedule.churn(4, 64, cfg.frame_rate, cfg.deadline, join=0, length=64)
    agg = MultiStreamServer(cfg, fast, slow, cal, _uplink(cfg), n_streams=4,
                            policy="cbo").process_streams(imgs, labels, schedule=sched)
    for m, ref in zip(agg.per_stream, snapshot["per_stream"]):
        assert m.accuracy == pytest.approx(ref["accuracy"], abs=1e-9)
        assert m.offload_frac == pytest.approx(ref["offload_frac"], abs=1e-9)
        assert m.deadline_miss_frac == pytest.approx(ref["deadline_miss_frac"], abs=1e-9)
        assert m.n_frames == ref["n_frames"]
    assert agg.n_offloaded == snapshot["n_offloaded"]


def test_churn_serves_only_live_slots():
    """Streams join/leave mid-run: per-stream frame counts must equal their
    scheduled lifetimes and the engine must stay consistent."""
    fast, slow, cal = synthetic_tiers()
    cfg = _cfg()
    S, N = 6, 70  # includes a trailing partial round (70 % 16 != 0)
    imgs, labels = synthetic_streams(S, N)
    join = np.array([0, 0, 8, 16, 30, 40])
    length = np.array([70, 50, 40, 30, 40, 30])
    sched = ArrivalSchedule.churn(S, N, cfg.frame_rate, cfg.deadline,
                                  join=join, length=length)
    up = _uplink(cfg)
    agg = MultiStreamServer(cfg, fast, slow, cal, up, n_streams=S).process_streams(
        imgs, labels, schedule=sched)
    assert [m.n_frames for m in agg.per_stream] == length.tolist()
    assert agg.n_frames == int(length.sum())
    assert up.n_transfers == agg.n_offloaded + agg.n_deadline_miss
    # a late-joining stream still gets answers for every live frame
    assert all(len(m.latencies) == l for m, l in zip(agg.per_stream, length))


def test_churn_schedule_validates_lifetimes():
    with pytest.raises(ValueError):
        ArrivalSchedule.churn(2, 10, 30.0, 0.2, join=8, length=5)
    with pytest.raises(ValueError):
        ArrivalSchedule.churn(2, 10, 30.0, 0.2, join=-1)


def test_cascade_server_serves_trailing_partial_batch():
    """len(frames) % batch_size != 0 used to silently drop the tail."""
    fast, slow, cal = synthetic_tiers()
    cfg = _cfg()
    imgs, labels = synthetic_streams(1, 70)
    m = CascadeServer(cfg, fast, slow, cal, _uplink(cfg)).process_stream(imgs[0], labels[0])
    assert m.n_frames == 70
    assert len(m.latencies) == 70


def test_multistream_serves_trailing_partial_batch():
    fast, slow, cal = synthetic_tiers()
    cfg = _cfg()
    imgs, labels = synthetic_streams(3, 37)
    agg = MultiStreamServer(cfg, fast, slow, cal, _uplink(cfg),
                            n_streams=3).process_streams(imgs, labels)
    assert agg.n_frames == 3 * 37


def test_png_size_model_vectorized():
    res = np.array([45, 90, 134, 179, 224])
    out = png_size_model(res)
    assert out.shape == res.shape
    for r, v in zip(res, out):
        assert v == png_size_model(int(r))
    assert isinstance(png_size_model(224), float)


def test_payload_sizes_falls_back_for_scalar_only_callables():
    def scalar_only(r):
        if np.ndim(r):
            raise TypeError("scalar only")
        return float(r) * 2.0

    res = np.array([3, 5, 7])
    np.testing.assert_allclose(payload_sizes(scalar_only, res), [6.0, 10.0, 14.0])
    np.testing.assert_allclose(payload_sizes(png_size_model, res),
                               [png_size_model(int(r)) for r in res])


def test_fleet_runner_groups_heterogeneous_policies():
    policies = [make_policy("cbo"), make_policy("local"), make_policy("cbo"),
                make_policy("threshold", theta=0.4), make_policy("threshold", theta=0.6)]
    fleet = FleetRunner(policies, resolutions=(4, 8), acc_server=(0.7, 0.99),
                        deadline=5.0, latency=0.01, server_time=0.01,
                        size_of=lambda r: 1e3 * r, bw_init=mbps(50.0))
    # cbo streams share one group; distinct threshold configs do not
    assert len(fleet.groups) == 4
    for s in range(5):
        fleet.add_frame(s, 0.0, 0.3)
    batch = fleet.plan_all(np.zeros(5))
    assert batch.plan(1).offloads == []  # local never offloads
    assert batch.plan(0).offloads  # generous env: cbo offloads
    # threshold theta=0.4 keeps conf=0.3 < 0.4 -> offloads; .6 likewise
    assert batch.plan(3).offloads and batch.plan(4).offloads
