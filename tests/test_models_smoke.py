"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
output shapes + finiteness (assignment: ARCHITECTURES block)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch, list_archs
from repro.models import api
from repro.models.transformer import ParallelPlan

KEY = jax.random.PRNGKey(0)


def test_all_ten_archs_registered():
    assert len(list_archs()) == 10


@pytest.mark.parametrize("arch_id", ["deepseek-v2-lite-16b", "arctic-480b", "stablelm-12b", "qwen1.5-32b"])
def test_lm_smoke(arch_id):
    from repro.models import transformer as tr

    spec = get_arch(arch_id)
    cfg = spec.smoke
    plan = ParallelPlan(model_axis=1, remat=False)
    h = api.build(cfg, plan)
    params = h.init(KEY, dtype=jnp.float32)
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)

    loss = h.loss(params, {"tokens": toks, "labels": toks})
    assert loss.shape == () and bool(jnp.isfinite(loss))

    logits, cache = tr.lm_prefill(params, toks, cfg, plan)
    assert logits.shape == (B, cfg.vocab_size)
    lg, cache2 = tr.lm_decode(params, cache, toks[:, -1], S - 1, cfg, plan)
    assert lg.shape == (B, cfg.vocab_size) and bool(jnp.all(jnp.isfinite(lg)))
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)

    # one gradient step moves the loss
    g = jax.grad(lambda p: h.loss(p, {"tokens": toks, "labels": toks}))(params)
    gn = sum(float(jnp.sum(jnp.square(x))) for x in jax.tree.leaves(g))
    assert gn > 0 and np.isfinite(gn)


@pytest.mark.parametrize("arch_id", ["vit-s16", "deit-b", "swin-b", "resnet-50"])
def test_vision_smoke(arch_id):
    spec = get_arch(arch_id)
    cfg = spec.smoke
    h = api.build(cfg, ParallelPlan(model_axis=1, remat=False))
    params = h.init(KEY, dtype=jnp.float32)
    B, R = 2, cfg.img_res
    imgs = jax.random.normal(KEY, (B, R, R, 3), jnp.float32)
    logits = h.forward(params, imgs)
    assert logits.shape == (B, cfg.n_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss = h.loss(params, {"images": imgs, "labels": jnp.array([0, 1])})
    assert bool(jnp.isfinite(loss))


@pytest.mark.parametrize("arch_id", ["dit-b2", "unet-sdxl"])
def test_diffusion_smoke(arch_id):
    spec = get_arch(arch_id)
    cfg = spec.smoke
    h = api.build(cfg, ParallelPlan(model_axis=1, remat=False))
    params = h.init(KEY, dtype=jnp.float32)
    B = 2
    lat = cfg.img_res // cfg.latent_factor
    x0 = jax.random.normal(KEY, (B, lat, lat, cfg.in_channels), jnp.float32)
    t = jnp.array([10, 500])
    if arch_id == "dit-b2":
        cond = jnp.array([1, 2])
        out_ch = cfg.in_channels * 2
    else:
        cond = jax.random.normal(KEY, (B, api.CTX_TOKENS, cfg.ctx_dim), jnp.float32)
        out_ch = cfg.in_channels
    out = h.forward(params, x0, t, cond)
    assert out.shape == (B, lat, lat, out_ch)
    assert bool(jnp.all(jnp.isfinite(out)))
    noise = jax.random.normal(jax.random.PRNGKey(3), x0.shape, jnp.float32)
    loss = h.loss(params, {"latents": x0, "t": t, "noise": noise, "cond": cond})
    assert bool(jnp.isfinite(loss))


def test_full_param_counts_match_published():
    """Sanity-pin the full configs to their published sizes."""
    expected = {
        "arctic-480b": (460e9, 500e9),
        "deepseek-v2-lite-16b": (14e9, 17e9),
        "qwen1.5-32b": (30e9, 38e9),  # kv=40 per assignment (vs GQA release)
        "stablelm-12b": (11e9, 13e9),
        "deit-b": (80e6, 95e6),
        "swin-b": (80e6, 95e6),
        "resnet-50": (23e6, 28e6),
        "vit-s16": (20e6, 24e6),
        "dit-b2": (120e6, 140e6),
        "unet-sdxl": (2.3e9, 2.8e9),
    }
    for arch_id, (lo, hi) in expected.items():
        n = api.build(get_arch(arch_id).full).n_params()
        assert lo <= n <= hi, f"{arch_id}: {n:,} outside [{lo:,.0f}, {hi:,.0f}]"


def test_mla_absorbed_decode_matches_naive():
    """The absorbed MLA decode (beyond-paper opt) must be numerically
    equivalent to expanding K/V from the latent."""
    from repro.models import transformer as tr

    cfg = get_arch("deepseek-v2-lite-16b").smoke
    plan_naive = ParallelPlan(model_axis=1, remat=False, mla_absorb=False)
    plan_abs = ParallelPlan(model_axis=1, remat=False, mla_absorb=True)
    params = api.build(cfg, plan_naive).init(KEY, dtype=jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    _, cache = tr.lm_prefill(params, toks, cfg, plan_naive)
    lg_naive, _ = tr.lm_decode(params, cache, toks[:, -1], 15, cfg, plan_naive)
    lg_abs, _ = tr.lm_decode(params, cache, toks[:, -1], 15, cfg, plan_abs)
    np.testing.assert_allclose(np.asarray(lg_naive), np.asarray(lg_abs), rtol=2e-4, atol=2e-4)


def test_int8_kv_cache_decode_close_to_bf16():
    from repro.models import transformer as tr

    cfg = get_arch("qwen1.5-32b").smoke
    plan = ParallelPlan(model_axis=1, remat=False)
    plan8 = ParallelPlan(model_axis=1, remat=False, kv_cache_dtype="int8")
    params = api.build(cfg, plan).init(KEY, dtype=jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    _, c16 = tr.lm_prefill(params, toks, cfg, plan)
    _, c8 = tr.lm_prefill(params, toks, cfg, plan8)
    assert c8["k"].dtype == jnp.int8 and "k_scale" in c8
    lg16, _ = tr.lm_decode(params, c16, toks[:, -1], 15, cfg, plan)
    lg8, _ = tr.lm_decode(params, c8, toks[:, -1], 15, cfg, plan8)
    p16 = jax.nn.softmax(lg16.astype(jnp.float32), -1)
    p8 = jax.nn.softmax(lg8.astype(jnp.float32), -1)
    # distributions stay close; argmax agrees for this smoke scale
    assert float(jnp.max(jnp.abs(p16 - p8))) < 0.05
    assert bool(jnp.all(jnp.argmax(lg16, -1) == jnp.argmax(lg8, -1)))
