"""Checkpoint manager + trainer fault-tolerance drills."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.manager import CheckpointManager
from repro.data.pipeline import DeterministicPipeline, PipelineConfig
from repro.train import optim
from repro.train.trainer import InjectedFailure, TrainConfig, Trainer


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    params = {"w": jax.random.normal(k, (8, 4)), "b": jnp.zeros((4,))}
    return {"params": params, "opt": optim.init_state(optim.OptimConfig(), params)}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    st = _state()
    mgr.save(10, st, blocking=True)
    out = mgr.restore(10, st)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    st = _state()
    for s in (1, 2, 3, 4):
        mgr.save(s, st)
    mgr.wait()
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_no_tmp_dirs_after_commit(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state(), blocking=True)
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def _tiny_problem():
    """Learnable regression-as-classification: loss must drop."""
    k = jax.random.PRNGKey(0)
    w_true = jax.random.normal(k, (8, 4))
    X = jax.random.normal(jax.random.PRNGKey(1), (512, 8))
    y = jnp.argmax(X @ w_true, -1)
    data = {"x": np.asarray(X), "y": np.asarray(y)}

    def batch_fn(rng, idx):
        return {"x": data["x"][idx], "y": data["y"][idx]}

    def loss_fn(params, batch):
        logits = batch["x"] @ params["w"] + params["b"]
        lse = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, batch["y"][:, None], -1)[:, 0]
        return jnp.mean(lse - gold)

    pipe = DeterministicPipeline(PipelineConfig(global_batch=64, seed=0), batch_fn, 512)
    params = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
    return loss_fn, params, pipe


def test_trainer_loss_decreases(tmp_path):
    loss_fn, params, pipe = _tiny_problem()
    cfg = TrainConfig(n_steps=60, ckpt_every=30, ckpt_dir=str(tmp_path), log_every=30,
                      ocfg=optim.OptimConfig(lr=5e-2, weight_decay=0.0))
    tr = Trainer(cfg, loss_fn, params, pipe)
    first = float(loss_fn(params, jax.tree.map(jnp.asarray, pipe.batch_at(0))))
    out = tr.run()
    assert out["final_loss"] < first * 0.5


def test_trainer_restart_after_injected_failure(tmp_path):
    loss_fn, params, pipe = _tiny_problem()
    cfg = TrainConfig(n_steps=50, ckpt_every=10, ckpt_dir=str(tmp_path), log_every=50,
                      fail_at_step=25, ocfg=optim.OptimConfig(lr=5e-2, weight_decay=0.0))
    tr = Trainer(cfg, loss_fn, params, pipe)
    out = tr.run_with_restarts(max_restarts=1)
    assert out["steps"] == 50
    # restarted from step 20, not from scratch: checkpoints exist for later steps
    assert tr.ckpt.latest_step() == 50


def test_elastic_restore_onto_different_sharding(tmp_path):
    """Checkpoint written unsharded restores onto an explicit sharding
    (single-device here; the mechanism is sharding-agnostic device_put)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mgr = CheckpointManager(str(tmp_path))
    st = _state()
    mgr.save(5, st, blocking=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shardings = jax.tree.map(lambda _: NamedSharding(mesh, P()), st)
    out = mgr.restore(5, st, shardings=shardings)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_grad_compression_error_feedback_converges():
    """int8 grad compression with error feedback still trains (distributed-
    optimization trick; DESIGN.md §5)."""
    loss_fn, params, pipe = _tiny_problem()
    ocfg = optim.OptimConfig(lr=5e-2, weight_decay=0.0, compress_grads=True)
    state = {"params": params, "opt": optim.init_state(ocfg, params)}

    @jax.jit
    def step(state, batch):
        l, g = jax.value_and_grad(loss_fn)(state["params"], batch)
        p, o = optim.apply_updates(ocfg, state["params"], g, state["opt"])
        return {"params": p, "opt": o}, l

    first = last = None
    for s in range(60):
        batch = jax.tree.map(jnp.asarray, pipe.batch_at(s))
        state, l = step(state, batch)
        if s == 0:
            first = float(l)
        last = float(l)
    assert last < first * 0.5
