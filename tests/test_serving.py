"""Serving engine: deadline handling, straggler fallback, netsim."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.netsim import Uplink, mbps, png_size_model
from repro.serving.engine import CascadeServer, ServeConfig


def _tiers():
    def fast(images):  # weak: signal + noise channel
        return images[:, 0, 0, :4] + images[:, 1, 1, :4]

    def slow(images):  # oracle
        return images[:, 0, 0, :4] * 10.0

    return fast, slow


def _stream(n=64, res=8, seed=0):
    key = jax.random.PRNGKey(seed)
    labels = np.asarray(jax.random.randint(key, (n,), 0, 4))
    imgs = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (n, res, res, 4))) * 0.8
    imgs[np.arange(n), 0, 0, labels] = 2.0
    return imgs.astype(np.float32), labels


def _server(bw_mbps, latency=0.05):
    cfg = ServeConfig(resolutions=(4, 8), acc_server=(0.7, 0.99), batch_size=16,
                      frame_rate=30.0, deadline=0.2)
    fast, slow = _tiers()
    up = Uplink(bandwidth_bps=mbps(bw_mbps), latency=latency, server_time=cfg.server_time)
    return CascadeServer(cfg, fast, slow, lambda s: s, up)


def test_serving_improves_over_fast_tier_with_bandwidth():
    imgs, labels = _stream()
    srv = _server(bw_mbps=50.0)
    m = srv.process_stream(imgs, labels)
    fast, _ = _tiers()
    fast_acc = float((np.argmax(np.asarray(fast(jnp.asarray(imgs))), -1) == labels).mean())
    assert m.accuracy >= fast_acc - 1e-9
    assert m.offload_frac > 0


def test_serving_dead_uplink_equals_fast_tier():
    """bw = 0 exactly: the planner must say 'all local', not divide by zero."""
    imgs, labels = _stream()
    srv = _server(bw_mbps=0.0)
    m = srv.process_stream(imgs, labels)
    assert m.offload_frac == 0.0 and m.n_deadline_miss == 0


def test_serving_no_bandwidth_equals_fast_tier():
    imgs, labels = _stream()
    srv = _server(bw_mbps=0.001)
    m = srv.process_stream(imgs, labels)
    fast, _ = _tiers()
    fast_acc = float((np.argmax(np.asarray(fast(jnp.asarray(imgs))), -1) == labels).mean())
    assert abs(m.accuracy - fast_acc) < 1e-9
    assert m.offload_frac == 0.0


def test_deadline_misses_fall_back_not_crash():
    """Huge latency: escalations land late; fast answers must stand."""
    imgs, labels = _stream()
    srv = _server(bw_mbps=50.0, latency=10.0)
    m = srv.process_stream(imgs, labels)
    assert m.n_offloaded == 0  # all replies late -> straggler fallback
    assert max(m.latencies) <= srv.cfg.deadline + 1e-9


def test_offloaded_frames_leave_the_backlog():
    """Regression: escalated frames must not linger in the controller backlog
    and get re-planned every batch (consume() was never called). A long
    deadline keeps the expiry pruning in plan() from masking the leak."""
    imgs, labels = _stream()
    cfg = ServeConfig(resolutions=(4, 8), acc_server=(0.7, 0.99), batch_size=16,
                      frame_rate=30.0, deadline=5.0)
    fast, slow = _tiers()
    up = Uplink(bandwidth_bps=mbps(50.0), latency=0.05, server_time=cfg.server_time)
    srv = CascadeServer(cfg, fast, slow, lambda s: s, up)
    m = srv.process_stream(imgs, labels)
    n_escalated = m.n_offloaded + m.n_deadline_miss
    assert n_escalated > 0
    # pre-fix the backlog held every frame (escalated included); post-fix the
    # escalated frames never enter it and planned offloads are consumed
    assert len(srv.controller.backlog) <= m.n_frames - n_escalated
    backlog_arrivals = {f.arrival for f in srv.controller.backlog}
    assert len(backlog_arrivals) == len(srv.controller.backlog)  # no duplicates


def test_uplink_serializes_transfers():
    up = Uplink(bandwidth_bps=1000.0, latency=0.0, server_time=0.0)
    t1 = up.transmit(500, 0.0)  # 0.5s tx
    t2 = up.transmit(500, 0.0)  # queued behind the first
    assert t1 == pytest.approx(0.5)
    assert t2 == pytest.approx(1.0)


def test_png_size_model_quadratic():
    assert png_size_model(224) == pytest.approx(60_000)
    assert png_size_model(112) == pytest.approx(15_000)
