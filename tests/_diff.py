"""Differential harness: pin the JAX round loop to the numpy reference.

The exactness policy (docs/jax_backend.md): the numpy stack plans and
simulates in float64, the JAX stack in float32 — so every INTEGER outcome
(offload decisions, escalation sets, schedule/placement assignments,
deadline hits, backlog lengths, metric counts) must match bit-for-bit,
while FLOAT state (theta, EWMA bandwidth, latencies) is compared at a
tolerance that covers float32 accumulation of absolute timestamps
(~1e-7 * t catastrophic cancellation against ~1e-5 s wire times — see
``BW_RTOL``).  Workloads use ``frame_rate=32`` so arrival grids are
exactly representable in both precisions and the prune/deadline compares
are tie-free; the two backends are then comparable decision-for-decision.

``run_differential`` replays one seeded workload through both backends of
``MultiStreamServer`` with a ``round_hook`` attached, asserts every round
record pair with ``assert_round_equal``, and returns the two
``AggregateMetrics`` (whose integer counters must already agree).
"""
from __future__ import annotations

import numpy as np

# float tolerances: theta is a copied confidence (f32-exact on both
# sides); bandwidth estimates and latencies accumulate f32 timestamp
# error, which the exactness policy bounds at tolerance, not bit-equality
THETA_ATOL = 1e-6
BW_RTOL = 1e-2
LAT_ATOL = 1e-4

# integer-exact record keys (the regression gate) vs tolerance floats
EXACT_KEYS = ("res_idx", "cap", "n_off", "n_frames", "off_stream", "off_pos",
              "off_res", "off_kind", "off_cut", "lengths", "correct", "esc",
              "ok", "valid")


def assert_fleet_equal(numpy_state, jax_state, atol: float = 1e-6) -> None:
    """Backlog-state equivalence: a ragged ``FleetState`` against a padded
    ``PaddedFleet`` (or another ``FleetState``).  Lengths and per-slot
    order are exact; arrival/conf values compare at ``atol``."""
    from repro.policy.fleet_jax import PaddedFleet, unpad_fleet

    if isinstance(jax_state, PaddedFleet):
        j_arr, j_conf, j_lens = unpad_fleet(jax_state)
    else:
        j_arr, j_conf, j_lens = (np.asarray(jax_state.arrival),
                                 np.asarray(jax_state.conf),
                                 np.asarray(jax_state.lengths))
    n_arr, n_conf, n_lens = (np.asarray(numpy_state.arrival),
                             np.asarray(numpy_state.conf),
                             np.asarray(numpy_state.lengths))
    assert np.array_equal(n_lens, j_lens), (n_lens, j_lens)
    np.testing.assert_allclose(j_arr, n_arr, atol=atol)
    np.testing.assert_allclose(j_conf, n_conf, atol=atol)


def assert_round_equal(numpy_rec: dict, jax_rec: dict, *, ctx="",
                       theta_atol=THETA_ATOL, bw_rtol=BW_RTOL,
                       lat_atol=LAT_ATOL) -> None:
    """One round's record pair (``MultiStreamServer.round_hook`` dicts)."""
    for k in EXACT_KEYS:
        assert np.array_equal(numpy_rec[k], jax_rec[k]), (
            f"{ctx}: integer mismatch on {k!r}:\n"
            f"  numpy={numpy_rec[k]!r}\n  jax={jax_rec[k]!r}")
    np.testing.assert_allclose(jax_rec["theta"], numpy_rec["theta"],
                               atol=theta_atol, err_msg=f"{ctx}: theta")
    np.testing.assert_allclose(jax_rec["bw_est"], numpy_rec["bw_est"],
                               rtol=bw_rtol, err_msg=f"{ctx}: bw_est")
    np.testing.assert_allclose(jax_rec["lat"], numpy_rec["lat"],
                               atol=lat_atol, err_msg=f"{ctx}: lat")
    # the JAX planner flags configurations its float32 eps-window prune or
    # capped frontier cannot represent; differential workloads must be clean
    if "overflow" in jax_rec:
        assert not np.any(jax_rec["overflow"]), f"{ctx}: frontier overflow"
    if "inexact" in jax_rec:
        assert not np.any(jax_rec["inexact"]), f"{ctx}: inexact eps-window prune"


def canonical_actions():
    """Split-enabled action table on the canonical differential config.

    Two synthetic cuts over the (4, 8) frame grid, with every quantity
    exactly representable in float32 (payloads are integer bytes, t_dev
    and srv_frac are dyadic) so the two backends' feasibility compares
    stay tie-free — the same design rule as ``frame_rate=32``.
    """
    from repro.core.netsim import payload_sizes, png_size_model
    from repro.policy.types import ActionTable

    frame_sizes = payload_sizes(png_size_model, np.asarray((4, 8)))
    base = ActionTable.frames_only(sizes=frame_sizes,
                                   acc=np.asarray((0.7, 0.99)))
    t_dev = np.asarray([2.0 ** -10, 2.0 ** -8])  # ~1 ms / ~4 ms prefixes
    srv_frac = np.asarray([0.5, 0.25])
    sizes = np.asarray([np.floor(frame_sizes[1] * 0.75),
                        np.floor(frame_sizes[0] * 1.25)])
    acc = np.asarray([0.984375, 0.99])  # 63/64 and the top-frame accuracy
    return ActionTable(
        kind=np.r_[base.kind, np.ones(2, dtype=np.int8)],
        res=np.r_[base.res, np.full(2, 1, dtype=np.int64)],
        cut=np.r_[base.cut, np.arange(2, dtype=np.int64)],
        sizes=np.r_[base.sizes, sizes],
        acc=np.r_[base.acc, acc],
        t_dev=np.r_[base.t_dev, t_dev],
        srv_frac=np.r_[base.srv_frac, srv_frac],
        names=base.names + ("feat@cut0", "feat@cut1"))


def make_server(backend: str, *, S: int, policy="cbo", scheduler="round_robin",
                topology="degenerate", placement="jsq", frame_rate=32.0,
                bw_mbps=50.0, seed=0, jitter=0.0, jitter_mode="counter",
                traces=None, actions=None, telemetry=None):
    """One ``MultiStreamServer`` on the canonical differential config.

    ``frame_rate=32`` keeps the arrival grid exactly representable in
    float32 — a deliberate part of the exactness policy, not an accident.
    ``policy`` passes through to the server (a registry name or a
    per-stream factory for heterogeneous fleets); ``jitter``/``traces``
    make the cell uplinks time-varying (``traces`` is a sequence cycled
    over the cells; ``jitter_mode="counter"`` is the jax-expressible
    default — pass ``"pcg"`` to exercise the legacy host rng).
    """
    from repro.core.netsim import Uplink, mbps
    from repro.net import EdgeFabric, ReplicaPool
    from repro.serving import FairScheduler, MultiStreamServer, ServeConfig
    from repro.serving.synthetic import synthetic_tiers

    fast, slow, cal = synthetic_tiers()
    cfg = ServeConfig(resolutions=(4, 8), acc_server=(0.7, 0.99), batch_size=16,
                      frame_rate=frame_rate, deadline=0.2, actions=actions)

    def trace_of(c):
        return traces[c % len(traces)] if traces else None

    if topology == "degenerate":
        fab = EdgeFabric.degenerate(
            Uplink(bandwidth_bps=mbps(bw_mbps), latency=0.05,
                   server_time=cfg.server_time, jitter=jitter, seed=seed,
                   jitter_mode=jitter_mode, trace=trace_of(0)), n_streams=S)
    else:  # C=2 cells, K=2 heterogeneous serial replicas
        ups = [Uplink(bandwidth_bps=mbps(bw_mbps * 0.6), latency=0.05,
                      server_time=cfg.server_time, seed=seed + c,
                      jitter=jitter, jitter_mode=jitter_mode, trace=trace_of(c))
               for c in range(2)]
        pool = ReplicaPool(2, np.array([cfg.server_time, cfg.server_time * 1.5]),
                           serial=True)
        fab = EdgeFabric(ups, pool, n_streams=S, placement=placement)
    return MultiStreamServer(cfg, fast, slow, cal, None, n_streams=S,
                             scheduler=FairScheduler(scheduler), fabric=fab,
                             policy=policy, backend=backend,
                             telemetry=telemetry), cfg


def run_differential(*, S: int, policy="cbo", scheduler="round_robin",
                     topology="degenerate", placement="jsq", churn=False,
                     n_frames=64, seed=0, frame_rate=32.0, bw_mbps=50.0,
                     jitter=0.0, jitter_mode="counter", traces=None,
                     actions=None):
    """Replay one seeded workload through both backends and assert every
    round record matches.  Returns (numpy_metrics, jax_metrics)."""
    from repro.serving.events import ArrivalSchedule
    from repro.serving.synthetic import synthetic_streams

    imgs, labels = synthetic_streams(S, n_frames, seed=seed)
    sched = None
    if churn:
        rng = np.random.default_rng(seed + 1)
        join = rng.integers(0, n_frames // 2, size=S)
        length = rng.integers(1, n_frames - join + 1)
        sched = ArrivalSchedule.churn(S, n_frames, frame_rate, 0.2,
                                      join=join, length=length)
    records = {}
    metrics = {}
    for backend in ("numpy", "jax"):
        srv, cfg = make_server(backend, S=S, policy=policy, scheduler=scheduler,
                               topology=topology, placement=placement,
                               frame_rate=frame_rate, bw_mbps=bw_mbps, seed=seed,
                               jitter=jitter, jitter_mode=jitter_mode,
                               traces=traces, actions=actions)
        recs = []
        srv.round_hook = recs.append
        metrics[backend] = srv.process_streams(imgs, labels, schedule=sched)
        records[backend] = recs
    rn, rj = records["numpy"], records["jax"]
    assert len(rn) == len(rj), (len(rn), len(rj))
    desc = f"S={S} {policy}/{scheduler}/{topology}"
    for i, (a, b) in enumerate(zip(rn, rj)):
        assert_round_equal(a, b, ctx=f"{desc} round {i}")
    mn, mj = metrics["numpy"], metrics["jax"]
    assert mn.n_frames == mj.n_frames
    assert mn.n_offloaded == mj.n_offloaded, (mn.n_offloaded, mj.n_offloaded)
    assert mn.n_deadline_miss == mj.n_deadline_miss
    assert mn.accuracy == mj.accuracy
    return mn, mj
