"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.fused_calib_gate.kernel import calib_gate
from repro.kernels.fused_calib_gate.ref import calib_gate_ref
from repro.kernels.int8_matmul import ref as i8ref
from repro.kernels.int8_matmul.kernel import int8_matmul


# ------------------------------- int8 matmul ------------------------------- #


@pytest.mark.parametrize("M,K,N,bm,bn,bk", [
    (128, 256, 128, 128, 128, 128),
    (256, 512, 384, 128, 128, 256),
    (512, 1024, 256, 256, 256, 512),
    (128, 128, 128, 64, 64, 64),
])
@pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
def test_int8_matmul_sweep(M, K, N, bm, bn, bk, out_dtype):
    key = jax.random.PRNGKey(M + K + N)
    x = jax.random.normal(key, (M, K), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32)
    xq, xs = i8ref.quantize_rows(x)
    wq, ws = i8ref.quantize_cols(w)
    out_k = int8_matmul(xq, xs, wq, ws, bm=bm, bn=bn, bk=bk, out_dtype=out_dtype, interpret=True)
    out_r = i8ref.int8_matmul_ref(xq, xs, wq, ws, out_dtype)
    np.testing.assert_allclose(np.asarray(out_k, np.float32), np.asarray(out_r, np.float32),
                               rtol=1e-2 if out_dtype == jnp.bfloat16 else 1e-6, atol=1e-2)


def test_int8_matmul_quantization_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 512), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (512, 256), jnp.float32)
    out = i8ref.matmul_ref(x, w)
    rel = float(jnp.linalg.norm(out - x @ w) / jnp.linalg.norm(x @ w))
    assert rel < 0.05, rel  # W8A8 with per-channel scales ~1% typical


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), st.integers(1, 4), st.integers(1, 4))
def test_int8_matmul_property(mi, ki, ni):
    M, K, N = 64 * mi, 64 * ki, 64 * ni
    x = jax.random.normal(jax.random.PRNGKey(M * K), (M, K), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(K * N + 1), (K, N), jnp.float32)
    xq, xs = i8ref.quantize_rows(x)
    wq, ws = i8ref.quantize_cols(w)
    out_k = int8_matmul(xq, xs, wq, ws, bm=64, bn=64, bk=64, interpret=True)
    out_r = i8ref.int8_matmul_ref(xq, xs, wq, ws)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=1e-6, atol=1e-6)


# ----------------------------- flash attention ----------------------------- #


@pytest.mark.parametrize("B,S,H,D,bq,bk", [
    (1, 256, 2, 64, 128, 128),
    (2, 512, 4, 64, 128, 256),
    (2, 384, 2, 128, 128, 128),
    (1, 1024, 1, 64, 256, 512),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, S, H, D, bq, bk, causal):
    ks = jax.random.split(jax.random.PRNGKey(S + H), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, bq=bq, bk=bk, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (2, 256, 2, 64), jnp.bfloat16)
    k = jax.random.normal(ks[1], (2, 256, 2, 64), jnp.bfloat16)
    v = jax.random.normal(ks[2], (2, 256, 2, 64), jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, bq=128, bk=128, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=3e-2, atol=3e-2)


def test_flash_matches_model_blockwise_oracle():
    """The model's scan-based blockwise path and the kernel must agree."""
    from repro.models.layers import attention_blockwise

    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (1, 512, 2, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 512, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 512, 2, 64), jnp.float32)
    a = flash_attention(q, k, v, causal=True, bq=128, bk=128, interpret=True)
    b = attention_blockwise(q, k, v, causal=True, chunk=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


# ----------------------------- fused calib gate ---------------------------- #


@pytest.mark.parametrize("B,V,bb,bv", [
    (64, 1024, 64, 256),
    (128, 4096, 64, 1024),
    (256, 8192, 128, 2048),
])
def test_calib_gate_sweep(B, V, bb, bv):
    logits = jax.random.normal(jax.random.PRNGKey(B + V), (B, V), jnp.float32) * 3
    for a, b, theta in [(-6.0, 2.0, 0.7), (-1.0, 0.0, 0.5), (-10.0, 5.0, 0.9)]:
        ck, gk = calib_gate(logits, a, b, theta, bb=bb, bv=bv, interpret=True)
        cr, gr = calib_gate_ref(logits, a, b, theta)
        np.testing.assert_allclose(np.asarray(ck), np.asarray(cr), rtol=1e-5, atol=1e-6)
        assert np.array_equal(np.asarray(gk), np.asarray(gr))


def test_calib_gate_extreme_logits_stable():
    logits = jnp.concatenate([
        jnp.full((8, 512), -1e4, jnp.float32),
        jax.random.normal(jax.random.PRNGKey(0), (8, 512)) * 50,
    ], axis=1)
    ck, _ = calib_gate(logits, -6.0, 2.0, 0.5, bb=8, bv=256, interpret=True)
    assert bool(jnp.all(jnp.isfinite(ck)))
