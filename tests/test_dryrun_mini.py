"""Dry-run machinery on a miniature mesh (subprocess: own XLA device count).

Validates the full lower->compile->cost/collective/memory extraction path
without the 512-device production mesh (which the real dryrun CLI uses).
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.join(os.path.dirname(__file__), "..")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from repro.launch.cells import build_cell, lower_cell
from repro.launch import roofline as rl

mesh = jax.make_mesh((2, 4), ("data", "model"))
out = {}
for arch, shape in [("vit-s16", "serve_b128"), ("qwen1.5-32b", "train_4k")]:
    import dataclasses
    from repro.configs.base import get_arch
    cfg = get_arch(arch).full
    if shape == "train_4k":
        cfg = dataclasses.replace(cfg, n_layers=1, d_model=256, n_heads=4, n_kv_heads=4,
                                  d_head=64, d_ff=512, vocab_size=1024)
    cell = build_cell(arch, shape, mesh, analysis=True, cfg_override=cfg if shape == "train_4k" else None)
    lowered, compiled = lower_cell(cell)
    rec = rl.cost_summary(compiled)
    rec["coll"] = rl.parse_collectives(compiled.as_text())
    rec["mem"] = rl.memory_summary(compiled)
    out[f"{arch}/{shape}"] = rec
print("JSON" + json.dumps(out))
"""


@pytest.mark.slow
def test_dryrun_pipeline_mini_mesh():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True, text=True,
                          env=env, cwd=REPO, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-3000:]
    payload = [l for l in proc.stdout.splitlines() if l.startswith("JSON")][0][4:]
    out = json.loads(payload)
    for cell, rec in out.items():
        assert rec["flops"] > 0, cell
        assert rec["mem"]["peak_estimate_bytes"] > 0, cell
    # the sharded train cell must actually communicate
    assert sum(out["qwen1.5-32b/train_4k"]["coll"].values()) > 0


def test_collective_parser_on_synthetic_hlo():
    from repro.launch.roofline import parse_collectives

    hlo = """
  %all-reduce.5 = f32[16,128]{1,0} all-reduce(%x), replica_groups=...
  %ag = bf16[4,256]{1,0} all-gather(%y), dimensions={0}
  %t = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-reduce(%a, %b), to_apply=%add
  %no = f32[2,2]{1,0} add(%p, %q)
"""
    out = parse_collectives(hlo)
    assert out["all-reduce"] == 16 * 128 * 4 + 2 * 8 * 8 * 4
    assert out["all-gather"] == 4 * 256 * 2
