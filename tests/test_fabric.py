"""Edge fabric: replica pool semantics, placement, traces, degenerate anchor."""
import json
import os

import numpy as np
import pytest

from repro.core.netsim import Uplink, mbps, png_size_model
from repro.net import (
    BandwidthTrace,
    EdgeFabric,
    Placement,
    ReplicaPool,
    assign_looped,
    lte_trace,
    regime_shift_trace,
    wifi_trace,
)
from repro.policy import BandwidthEstimator
from repro.serving import MultiStreamServer, ServeConfig
from repro.serving.synthetic import synthetic_streams, synthetic_tiers

DATA = os.path.join(os.path.dirname(__file__), "data")


# ------------------------------ ReplicaPool ------------------------------- #


def test_replica_pool_k1_delay_matches_raw_uplink_server_time():
    """Fuzz: a K=1 infinite-capacity pool is exactly the legacy
    ``+ server_time`` tail of ``Uplink.transmit_batch``."""
    rng = np.random.default_rng(0)
    for trial in range(20):
        st = float(rng.uniform(0.005, 0.08))
        up = Uplink(bandwidth_bps=mbps(rng.uniform(0.5, 20)), latency=0.05, server_time=st)
        pool = ReplicaPool(1, st, serial=False)
        payloads = rng.uniform(100, 50_000, 40)
        subs = np.sort(rng.uniform(0, 5, 40))
        end_tx = up.upload_batch(payloads, subs)
        done = pool.process(end_tx, np.zeros(40, dtype=np.int64))
        assert np.array_equal(done + up.latency, end_tx + st + up.latency)
        assert pool.n_jobs.tolist() == [40]
        assert pool.queued_seconds[0] == 0.0


def test_replica_pool_k1_serial_matches_scalar_recursion():
    """Fuzz: one serial replica == the scalar Lindley loop, including
    busy-state carried across batches."""
    rng = np.random.default_rng(1)
    for trial in range(10):
        st = float(rng.uniform(0.01, 0.1))
        pool = ReplicaPool(1, st)
        busy = 0.0
        for _ in range(3):  # several batches: state must persist
            arr = np.sort(rng.uniform(0, 4, 25))
            got = pool.process(arr, np.zeros(25, dtype=np.int64))
            want = np.empty(25)
            for i, a in enumerate(arr):
                busy = max(a, busy) + st
                want[i] = busy
            np.testing.assert_allclose(got, want, rtol=0, atol=1e-9)
            assert pool.busy_until[0] == pytest.approx(busy)


def test_replica_pool_multi_replica_isolation():
    """Jobs on one replica never delay another replica's jobs."""
    pool = ReplicaPool(2, 1.0)
    done = pool.process(np.zeros(4), np.array([0, 0, 1, 1]))
    np.testing.assert_allclose(done, [1.0, 2.0, 1.0, 2.0])
    assert pool.queued_seconds.tolist() == [1.0, 1.0]
    assert pool.busy_seconds.tolist() == [2.0, 2.0]


def test_replica_pool_heterogeneous_service_times():
    pool = ReplicaPool(2, [0.5, 2.0])
    done = pool.process(np.zeros(2), np.array([0, 1]))
    np.testing.assert_allclose(done, [0.5, 2.0])
    assert pool.nominal_server_time == pytest.approx(1.25)


def test_replica_pool_ties_keep_batch_order():
    """Simultaneous arrivals at one replica serve in batch order."""
    pool = ReplicaPool(1, 0.1)
    done = pool.process(np.zeros(3), np.zeros(3, dtype=np.int64))
    np.testing.assert_allclose(done, [0.1, 0.2, 0.3])


def test_replica_pool_rejects_bad_args():
    with pytest.raises(ValueError):
        ReplicaPool(0, 0.1)
    pool = ReplicaPool(2, 0.1)
    with pytest.raises(ValueError):
        pool.process(np.zeros(2), np.array([0, 2]))  # replica id out of range
    with pytest.raises(ValueError):
        pool.process(np.zeros(2), np.zeros(3, dtype=np.int64))  # shape mismatch


# ------------------------------ Placement --------------------------------- #


@pytest.mark.parametrize("policy", ["round_robin", "jsq", "least_land"])
def test_placement_matches_looped_reference(policy):
    """Fuzz: batched assignment == the per-row reference, homogeneous and
    heterogeneous replicas, warm queue state, unsorted arrivals."""
    rng = np.random.default_rng(2)
    for trial in range(15):
        K = int(rng.integers(1, 6))
        st = rng.uniform(0.01, 0.2, K)
        pool = ReplicaPool(K, st)
        pool.busy_until[:] = rng.uniform(0, 0.5, K)
        arrive = rng.uniform(0, 2, int(rng.integers(0, 30)))
        pl = Placement(policy)
        got = pl.assign(pool, arrive)
        want = assign_looped(policy, pool, arrive)
        assert np.array_equal(got, want), (policy, trial)


def test_jsq_matches_brute_force_simulation():
    """JSQ-picked schedules match an explicit brute-force queue simulation:
    every request joins the replica with the least pending work, and the
    completion times follow."""
    rng = np.random.default_rng(3)
    K = 3
    pool = ReplicaPool(K, 0.05)
    arrive = np.sort(rng.uniform(0, 0.4, 24))
    rep = Placement("jsq").assign(pool, arrive)
    done = pool.process(arrive, rep)
    # brute force: simulate the queues by hand
    busy = np.zeros(K)
    for i, a in enumerate(arrive):
        k = int(np.argmin(busy))
        assert rep[i] == k
        busy[k] = max(a, busy[k]) + 0.05
        assert done[i] == pytest.approx(busy[k])


def test_round_robin_cursor_carries_across_rounds():
    pool = ReplicaPool(3, 0.05)
    pl = Placement("round_robin")
    a = pl.assign(pool, np.zeros(2))
    b = pl.assign(pool, np.zeros(2))
    assert np.concatenate([a, b]).tolist() == [0, 1, 2, 0]


def test_least_land_prefers_fast_replica_under_heterogeneity():
    """A short queue on a slow replica loses to a longer queue on a fast
    one — the case separating least_land from JSQ."""
    pool = ReplicaPool(2, [0.01, 1.0])
    pool.busy_until[:] = [0.05, 0.0]  # replica 1 idle but 100x slower
    jsq = Placement("jsq").assign(pool, np.zeros(1))
    ll = Placement("least_land").assign(pool, np.zeros(1))
    assert jsq[0] == 1  # shortest queue
    assert ll[0] == 0  # earliest completion


def test_placement_rejects_unknown_policy():
    with pytest.raises(ValueError):
        Placement("random")


# ------------------------------ traces ------------------------------------ #


def test_bandwidth_trace_lookup_and_loop():
    tr = BandwidthTrace(t=np.array([0.0, 10.0]), bps=np.array([100.0, 50.0]),
                        loop=True, duration=20.0)
    np.testing.assert_allclose(tr.bandwidth_at([0, 9.9, 10, 19.9, 20, 25]),
                               [100, 100, 50, 50, 100, 100])  # 20/25 wrap to 0/5
    hold = BandwidthTrace(t=np.array([0.0, 10.0]), bps=np.array([100.0, 50.0]))
    np.testing.assert_allclose(hold.bandwidth_at([15, 1e6]), [50, 50])  # holds last
    assert tr.mean_bps == pytest.approx(75.0)


def test_bandwidth_trace_validation():
    with pytest.raises(ValueError):
        BandwidthTrace(t=np.array([1.0, 2.0]), bps=np.array([1.0, 1.0]))  # t[0] != 0
    with pytest.raises(ValueError):
        BandwidthTrace(t=np.array([0.0, 0.0]), bps=np.array([1.0, 1.0]))  # not ascending
    with pytest.raises(ValueError):
        BandwidthTrace(t=np.array([0.0]), bps=np.array([-1.0]))  # negative rate


def test_trace_generators_deterministic():
    for gen in (lte_trace, wifi_trace):
        a, b = gen(30.0, seed=5), gen(30.0, seed=5)
        np.testing.assert_array_equal(a.bps, b.bps)
        assert not np.array_equal(a.bps, gen(30.0, seed=6).bps)
        assert (a.bps > 0).all()


def test_uplink_trace_batch_matches_sequential():
    """Trace-driven transmit_batch (fixed-point Lindley) == serial loop."""
    tr = regime_shift_trace((20.0, 1.0), period=3.0)
    rng = np.random.default_rng(4)
    payloads = rng.uniform(1_000, 80_000, 40)
    subs = np.sort(rng.uniform(0, 12, 40))
    seq_up = Uplink(bandwidth_bps=mbps(5), latency=0.05, server_time=0.02, trace=tr)
    bat_up = Uplink(bandwidth_bps=mbps(5), latency=0.05, server_time=0.02, trace=tr)
    seq = np.array([seq_up.transmit(float(p), float(t)) for p, t in zip(payloads, subs)])
    bat = bat_up.transmit_batch(payloads, subs)
    np.testing.assert_allclose(bat, seq, rtol=0, atol=1e-9)
    assert bat_up._busy_until == pytest.approx(seq_up._busy_until)


def test_ewma_tracks_regime_shift():
    """The EWMA bandwidth estimator must re-lock onto the new rate after a
    regime shift in the trace (the ROADMAP's tracking stress)."""
    hi, lo = mbps(20.0), mbps(2.0)
    tr = regime_shift_trace((20.0, 2.0), period=30.0, loop=False)
    up = Uplink(bandwidth_bps=hi, latency=0.0, server_time=0.0, trace=tr)
    est = BandwidthEstimator(alpha=0.3, estimate_bps=hi)
    payload = 20_000.0
    t, in_hi, in_lo = 0.0, [], []
    for _ in range(200):
        land = up.transmit(payload, t)
        est.observe(payload, land - t)
        (in_hi if t < 30.0 else in_lo).append(est.estimate_bps)
        t = max(t + 0.25, up._busy_until)
    # locked to the high regime before the shift...
    assert in_hi[-1] == pytest.approx(hi, rel=0.05)
    # ...and re-locked to the low regime within the second phase
    assert in_lo[-1] == pytest.approx(lo, rel=0.05)
    # convergence is monotone-ish: estimate falls by >5x across the shift
    assert in_lo[-1] < in_hi[-1] / 5


# ------------------------------ fabric ------------------------------------ #


def _cfg():
    return ServeConfig(resolutions=(4, 8), acc_server=(0.7, 0.99), batch_size=16,
                       frame_rate=30.0, deadline=0.2)


def test_degenerate_fabric_reproduces_multistream_snapshot():
    """1 cell, 1 replica, constant bandwidth: the fabric path must pin the
    recorded pre-fabric lockstep metrics bit-for-bit."""
    with open(os.path.join(DATA, "multistream_snapshot.json")) as f:
        snapshot = json.load(f)
    fast, slow, cal = synthetic_tiers()
    cfg = _cfg()
    imgs, labels = synthetic_streams(4, 64)
    up = Uplink(bandwidth_bps=mbps(50.0), latency=0.05, server_time=cfg.server_time)
    fab = EdgeFabric.degenerate(up, n_streams=4)
    agg = MultiStreamServer(cfg, fast, slow, cal, None, n_streams=4,
                            fabric=fab).process_streams(imgs, labels)
    for m, ref in zip(agg.per_stream, snapshot["per_stream"]):
        assert m.accuracy == ref["accuracy"]
        assert m.offload_frac == ref["offload_frac"]
        assert m.deadline_miss_frac == ref["deadline_miss_frac"]
        assert m.n_frames == ref["n_frames"]
    assert agg.n_offloaded == snapshot["n_offloaded"]


def test_fabric_transmit_equals_legacy_transmit_batch():
    """Degenerate ``EdgeFabric.transmit`` is float-identical to
    ``Uplink.transmit_batch`` on the same workload."""
    rng = np.random.default_rng(6)
    legacy = Uplink(bandwidth_bps=mbps(2.0), latency=0.05, server_time=0.037)
    mirror = Uplink(bandwidth_bps=mbps(2.0), latency=0.05, server_time=0.037)
    fab = EdgeFabric.degenerate(mirror, n_streams=8)
    payloads = rng.uniform(100, 50_000, 60)
    subs = np.sort(rng.uniform(0, 5, 60))
    stream = rng.integers(0, 8, 60)
    a = legacy.transmit_batch(payloads, subs)
    b = fab.transmit(stream, payloads, subs)
    assert np.array_equal(a, b)
    assert legacy._busy_until == mirror._busy_until


def test_fabric_partitions_streams_across_cells():
    """Each cell's uplink carries exactly its own streams' transfers, and
    one cell's burst cannot queue another cell's traffic."""
    ups = [Uplink(bandwidth_bps=1000.0, latency=0.0, server_time=0.0) for _ in range(2)]
    pool = ReplicaPool(1, 0.0, serial=False)
    fab = EdgeFabric(ups, pool, cell_of=np.array([0, 0, 1, 1]))
    # streams 0/1 (cell 0) dump a burst; stream 2 (cell 1) sends one frame
    lands = fab.transmit(np.array([0, 1, 2]), np.array([500.0, 500.0, 500.0]),
                         np.zeros(3))
    np.testing.assert_allclose(lands, [0.5, 1.0, 0.5])  # cell 1 unaffected
    assert ups[0].n_transfers == 2 and ups[1].n_transfers == 1
    assert ups[0].queued_seconds == pytest.approx(0.5)
    assert ups[1].queued_seconds == 0.0


def test_fabric_replica_sharding_relieves_server_contention():
    """Same workload, more replicas => no later completions, and K=2 splits
    a saturated K=1 queue."""
    arrive = np.zeros(8)
    ups = [Uplink(bandwidth_bps=1e9, latency=0.0, server_time=0.1)]
    one = EdgeFabric([Uplink(bandwidth_bps=1e9, latency=0.0, server_time=0.1)],
                     ReplicaPool(1, 0.1), n_streams=4)
    two = EdgeFabric(ups, ReplicaPool(2, 0.1), n_streams=4)
    s = np.zeros(8, dtype=np.int64)
    p = np.full(8, 1.0)
    l1 = one.transmit(s, p, arrive)
    l2 = two.transmit(s, p, arrive)
    assert (l2 <= l1 + 1e-12).all()
    assert l1.max() == pytest.approx(0.8, abs=1e-6)  # 8 jobs serialized
    assert l2.max() == pytest.approx(0.4, abs=1e-6)  # split across 2 replicas


def test_fabric_validation():
    up = Uplink(bandwidth_bps=1e6, latency=0.05, server_time=0.01)
    pool = ReplicaPool(1, 0.01)
    with pytest.raises(ValueError):
        EdgeFabric([], pool, n_streams=4)
    with pytest.raises(ValueError):
        EdgeFabric(up, pool)  # neither cell_of nor n_streams
    with pytest.raises(ValueError):
        EdgeFabric(up, pool, cell_of=np.array([0, 1]))  # cell id out of range
    with pytest.raises(ValueError):  # latency mismatch across cells
        EdgeFabric([up, Uplink(bandwidth_bps=1e6, latency=0.1, server_time=0.01)],
                   pool, n_streams=2)


def test_multistream_engine_rejects_mismatched_fabric():
    fast, slow, cal = synthetic_tiers()
    cfg = _cfg()
    up = Uplink(bandwidth_bps=mbps(50.0), latency=0.05, server_time=cfg.server_time)
    fab = EdgeFabric.degenerate(up, n_streams=2)
    with pytest.raises(ValueError):
        MultiStreamServer(cfg, fast, slow, cal, None, n_streams=4, fabric=fab)
    with pytest.raises(ValueError):
        MultiStreamServer(cfg, fast, slow, cal, None, n_streams=4)  # no uplink either
    with pytest.raises(ValueError):  # both is ambiguous: whose counters?
        other = Uplink(bandwidth_bps=mbps(50.0), latency=0.05, server_time=cfg.server_time)
        fab2 = EdgeFabric.degenerate(up, n_streams=4)
        MultiStreamServer(cfg, fast, slow, cal, other, n_streams=4, fabric=fab2)


def test_multicell_engine_runs_and_splits_load():
    """S=8 across 2 cells + 2 serial replicas: the engine round loop routes
    per-cell batches and the counters land on both cells."""
    fast, slow, cal = synthetic_tiers()
    cfg = ServeConfig(resolutions=(4, 8), acc_server=(0.7, 0.99), batch_size=16,
                      frame_rate=30.0, deadline=0.2,
                      size_of=lambda r: png_size_model(r, base_res=16))
    imgs, labels = synthetic_streams(8, 64)
    fab = EdgeFabric.build(n_streams=8, n_cells=2, n_replicas=2,
                           bandwidth_bps=mbps(2.0), latency=0.05,
                           server_time=cfg.server_time, placement="jsq")
    srv = MultiStreamServer(cfg, fast, slow, cal, None, n_streams=8, fabric=fab)
    agg = srv.process_streams(imgs, labels)
    assert agg.n_frames == 8 * 64
    n_escalated = agg.n_offloaded + agg.n_deadline_miss
    assert fab.n_transfers == n_escalated > 0
    cells = fab.summary()["cell_transfers"]
    assert len(cells) == 2 and all(c > 0 for c in cells)
    assert int(fab.pool.n_jobs.sum()) == n_escalated
    s = agg.summary()
    assert s["cells"] == 2 and s["replicas"] == 2 and s["placement"] == "jsq"
