"""Multi-stream CBO serving: aggregate accuracy / offload / deadline-miss vs
number of concurrent streams sharing one uplink.

Sweeps N ∈ {1, 4, 16, 64, 256, 1024} client streams through
``MultiStreamServer`` on a fixed uplink, so per-stream bandwidth shrinks
as 1/N and the contention / fairness regime opens up. The N=1 row is
cross-checked against the single-stream ``CascadeServer`` on the identical
workload (they must agree within tie-breaking noise — that equivalence is
the refactor's regression anchor).  ``--churn`` adds a dynamic-fleet
scenario at each N: half the streams join mid-run with ragged lifetimes
(``ArrivalSchedule.churn``) — the regime the batched ``FleetRunner``
control plane exists for.

Default stack is a tiny synthetic two-tier pair (runs in seconds, no
training); ``--stack models`` uses the trained int4/fp stack from
``benchmarks.common`` like the other paper benchmarks.

``--cells`` / ``--replicas`` / ``--placement`` rerun the sweep on an edge
fabric (``src/repro/net/``) instead of the legacy single uplink — see
``bench_fabric.py`` for the dedicated topology sweep.

  PYTHONPATH=src:benchmarks python benchmarks/bench_multistream.py
  PYTHONPATH=src:benchmarks python benchmarks/bench_multistream.py --streams 64,256,1024 --churn
  PYTHONPATH=src:benchmarks python benchmarks/bench_multistream.py --bw 0.5 --scheduler fifo
  PYTHONPATH=src:benchmarks python benchmarks/bench_multistream.py --cells 4 --replicas 4
"""
from __future__ import annotations

import argparse
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

STREAM_COUNTS = (1, 4, 16, 64, 256, 1024)


# synthetic stack: planted-signal images, weak fast tier, oracle-ish slow tier
# (canonical definition shared with tests — repro/serving/synthetic.py)
from repro.serving.synthetic import synthetic_streams, synthetic_tiers  # noqa: E402


def synthetic_cfg(args) -> "ServeConfig":
    from repro.core.netsim import png_size_model
    from repro.serving import ServeConfig

    # scale the PNG size model so the 8-px synthetic frames carry the same
    # bytes a full 224-px upload would — otherwise payloads are so small the
    # shared uplink never contends and the sweep is vacuous
    return ServeConfig(
        deadline=args.deadline, frame_rate=args.fps, batch_size=16,
        resolutions=(4, 8), acc_server=(0.9, 0.99),
        size_of=lambda r: png_size_model(r, base_res=16),
    )


def model_setup(args):
    from benchmarks.common import FAST_CFG, RESOLUTIONS, SLOW_CFG, build_stack
    from repro.models import api
    from repro.models.transformer import ParallelPlan
    from repro.serving import ServeConfig

    stack = build_stack()
    fh = api.build(FAST_CFG, ParallelPlan(remat=False))
    sh = api.build(SLOW_CFG, ParallelPlan(remat=False))
    cfg = ServeConfig(deadline=args.deadline, frame_rate=args.fps,
                      resolutions=RESOLUTIONS, acc_server=stack.acc_server_by_res)
    fast = lambda x: fh.forward(stack.fast_params, x)
    slow = lambda x: sh.forward(stack.slow_params, x)

    def streams(n_streams, n_frames):
        frames, labels = stack.test["frames"], stack.test["labels"]
        idx = (np.arange(n_streams)[:, None] * 131 + np.arange(n_frames)[None, :]) % len(labels)
        return frames[idx], labels[idx]

    return cfg, fast, slow, stack.platt, streams


def churn_schedule(S, n_frames, cfg, seed=0):
    """Half the fleet serves the whole run; the rest join mid-run with
    ragged lifetimes (joins staggered over the first half of the run)."""
    from repro.serving import ArrivalSchedule

    rng = np.random.default_rng(seed)
    even = np.arange(S) % 2 == 0
    join = np.where(even, 0, rng.integers(0, max(n_frames // 2, 1), size=S))
    ragged = np.minimum(n_frames - join,
                        rng.integers(max(n_frames // 4, 1), n_frames + 1, size=S))
    length = np.where(even, n_frames, ragged)
    return ArrivalSchedule.churn(S, n_frames, cfg.frame_rate, cfg.deadline,
                                 join=join, length=length)


def run(args=None) -> dict:
    from repro.core.netsim import Uplink, mbps
    from repro.serving import CascadeServer, FairScheduler, MultiStreamServer

    if args is None:
        args = parse_args([])

    if args.stack == "models":
        cfg, fast, slow, calibrate, make_streams = model_setup(args)
    else:
        cfg = synthetic_cfg(args)
        fast, slow, calibrate = synthetic_tiers()
        make_streams = lambda S, N: synthetic_streams(S, N, seed=args.seed)

    def fresh_uplink():
        return Uplink(bandwidth_bps=mbps(args.bw), latency=args.latency,
                      server_time=cfg.server_time, jitter=args.jitter, seed=args.seed)

    def fresh_fabric(S):
        """None when the topology is degenerate (legacy uplink path keeps
        its exact floats); an EdgeFabric otherwise."""
        if args.cells == 1 and args.replicas == 1:
            return None
        from repro.net import EdgeFabric

        return EdgeFabric.build(
            n_streams=S, n_cells=args.cells, n_replicas=args.replicas,
            bandwidth_bps=mbps(args.bw), latency=args.latency,
            server_time=cfg.server_time, placement=args.placement,
            jitter=args.jitter, seed=args.seed, serial_replicas=args.replicas > 1)

    rows = []
    single_row = None
    for S in args.streams:
        frames, labels = make_streams(S, args.frames)
        fab = fresh_fabric(S)
        srv = MultiStreamServer(cfg, fast, slow, calibrate,
                                fresh_uplink() if fab is None else None, n_streams=S,
                                scheduler=FairScheduler(args.scheduler), fabric=fab)
        m = srv.process_streams(frames, labels)
        row = {"n_streams": S, **m.summary()}
        rows.append(row)
        print("bench_multistream," + ",".join(f"{k}={v}" for k, v in row.items()), flush=True)

        if S == 1:  # cross-check: the old single-stream engine, same workload
            ref = CascadeServer(cfg, fast, slow, calibrate, fresh_uplink())
            mr = ref.process_stream(frames[0], labels[0])
            single_row = mr.summary()
            delta = abs(single_row["accuracy"] - row["accuracy"])
            print(f"bench_multistream,singlestream_ref_accuracy={single_row['accuracy']},"
                  f"delta={round(delta, 4)}", flush=True)

        if args.churn and S > 1:  # dynamic fleet: staggered join/leave
            sched = churn_schedule(S, frames.shape[1], cfg, seed=args.seed)
            fab = fresh_fabric(S)
            srv = MultiStreamServer(cfg, fast, slow, calibrate,
                                    fresh_uplink() if fab is None else None, n_streams=S,
                                    scheduler=FairScheduler(args.scheduler), fabric=fab)
            mc = srv.process_streams(frames, labels, schedule=sched)
            crow = {"n_streams": S, "scenario": "churn",
                    "served_frac": round(mc.n_frames / labels.size, 4), **mc.summary()}
            rows.append(crow)
            print("bench_multistream," + ",".join(f"{k}={v}" for k, v in crow.items()),
                  flush=True)

    out = {"config": {"bw_mbps": args.bw, "latency": args.latency, "fps": args.fps,
                      "deadline": args.deadline, "frames": args.frames,
                      "scheduler": args.scheduler, "stack": args.stack,
                      "cells": args.cells, "replicas": args.replicas,
                      "placement": args.placement},
           "sweep": rows, "single_stream_ref": single_row}
    from benchmarks.common import emit_bench_json

    emit_bench_json("BENCH_multistream.json", out, mirror="multistream_sweep.json")
    return out


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--streams", type=lambda s: tuple(int(x) for x in s.split(",")),
                    default=STREAM_COUNTS, help="comma-separated stream counts")
    ap.add_argument("--frames", type=int, default=256, help="frames per stream")
    ap.add_argument("--bw", type=float, default=2.0, help="shared uplink Mbps")
    ap.add_argument("--latency", type=float, default=0.05)
    ap.add_argument("--fps", type=float, default=30.0)
    ap.add_argument("--deadline", type=float, default=0.2)
    ap.add_argument("--jitter", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scheduler", choices=("round_robin", "fifo"), default="round_robin")
    ap.add_argument("--stack", choices=("synthetic", "models"), default="synthetic")
    ap.add_argument("--churn", action="store_true",
                    help="also run a dynamic-fleet scenario per N (staggered "
                         "join/leave, ragged stream lifetimes)")
    ap.add_argument("--cells", type=int, default=1,
                    help="radio cells (edge fabric; 1 = legacy single uplink)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="slow-tier replicas (edge fabric; 1 = legacy fixed delay)")
    ap.add_argument("--placement", choices=("round_robin", "jsq", "least_land"),
                    default="round_robin")
    return ap.parse_args(argv)


if __name__ == "__main__":
    run(parse_args())
