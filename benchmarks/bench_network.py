"""Paper Figs. 11/12/13: accuracy vs bandwidth / frame rate / latency for all
seven approaches."""
from __future__ import annotations

import json

import numpy as np

from benchmarks.approaches import APPROACHES, NetCfg, build_trace
from benchmarks.common import build_stack, out_path


def _sweep(trace, cfgs: list[NetCfg], xkey: str) -> list[dict]:
    rows = []
    for net in cfgs:
        row = {xkey: getattr(net, xkey if xkey != "bandwidth" else "bandwidth_mbps")}
        for name, fn in APPROACHES.items():
            row[name] = round(fn(trace, net), 4)
        rows.append(row)
        print("bench_network," + ",".join(f"{k}={v}" for k, v in row.items()), flush=True)
    return rows


def run() -> dict:
    stack = build_stack()
    trace = build_trace(stack)

    fig11 = _sweep(trace, [NetCfg(bandwidth_mbps=b) for b in (0.25, 0.5, 1, 2, 5, 10, 20, 40)], "bandwidth")
    fig12 = _sweep(trace, [NetCfg(frame_rate=f) for f in (5, 10, 15, 20, 25, 30)], "frame_rate")
    fig13 = _sweep(trace, [NetCfg(latency=l) for l in (0.0, 0.05, 0.1, 0.15, 0.18)], "latency")

    out = {"fig11_bandwidth": fig11, "fig12_frame_rate": fig12, "fig13_latency": fig13}
    with open(out_path("fig11_12_13_network.json"), "w") as f:
        json.dump(out, f, indent=2)
    return out


if __name__ == "__main__":
    run()
