"""Paper Table III: measured per-frame runtimes of the tiers + calibration
(on this CPU; the paper's NPU/GPU absolute numbers are quoted alongside)."""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from benchmarks.common import build_stack, out_path
from repro.models import api
from repro.models.transformer import ParallelPlan


def _time(fn, *args, n=20):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def run() -> dict:
    stack = build_stack()
    fh = api.build(C.FAST_CFG, ParallelPlan(remat=False))
    sh = api.build(C.SLOW_CFG, ParallelPlan(remat=False))
    imgs = jnp.asarray(stack.test["frames"][:32])

    fast_fn = jax.jit(lambda p, x: fh.forward(p, x))
    slow_fn = jax.jit(lambda p, x: sh.forward(p, x))
    t_fast = _time(fast_fn, stack.fast_params, imgs) / 32
    t_slow = _time(slow_fn, stack.slow_params, imgs) / 32

    logits = fast_fn(stack.fast_params, imgs)
    from repro.core.confidence import max_softmax

    calib_fn = jax.jit(lambda lg: stack.platt(max_softmax(lg)))
    t_calib = _time(calib_fn, logits) / 32

    out = {
        "measured_cpu_ms_per_frame": {
            "fast_tier": round(t_fast * 1e3, 3),
            "slow_tier": round(t_slow * 1e3, 3),
            "calibration": round(t_calib * 1e3, 4),
        },
        "paper_table3_ms": {"alexnet_npu": 20, "resnet152_server": 37, "calibration": 8},
        "ratio_slow_over_fast": round(t_slow / max(t_fast, 1e-9), 2),
    }
    with open(out_path("table3_tiers.json"), "w") as f:
        json.dump(out, f, indent=2)
    for k, v in out["measured_cpu_ms_per_frame"].items():
        print(f"bench_tiers/{k},ms_per_frame={v}")
    return out


if __name__ == "__main__":
    run()
