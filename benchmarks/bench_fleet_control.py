"""Fleet control plane: batched ``FleetRunner.plan_all`` vs S looped
``PolicyRunner.plan`` calls on identical backlogs.

The data plane has been one batched call per round since the multi-stream
engine landed; this benchmark measures the *decision* plane — the part
that was still O(S) Python — before/after the struct-of-arrays refactor.
For each fleet size S it builds S random ragged backlogs in the paper's
link regime (0.5-10 Mbps per-stream estimates, 200 ms deadline) with
per-stream bandwidth estimates, plans them both ways, asserts the batched
plans equal the looped ones (offload schedules, theta, r° — exactly;
gains to 1e-9), and reports interleaved best-of wall-clock speedup.
Target is >=10x at S=256; measured speedup is hardware-dependent (the
batched planner trades ~30x fewer interpreter dispatches for more raw
element traffic, so narrow containers land lower than wide hosts).

  PYTHONPATH=src:benchmarks python benchmarks/bench_fleet_control.py
  PYTHONPATH=src:benchmarks python benchmarks/bench_fleet_control.py --smoke

``--backend jax`` benchmarks the compiled round loop instead
(``serving/engine_jax.py``): after an exact-integer parity gate at small S
(both ``MultiStreamServer`` backends replay the same workload and must
agree on every offload/schedule/miss count — and, under ``--devices N``,
the mesh-sharded jax run must agree with both), it scans synthetic
``RoundInputs`` through the jitted ``lax.scan`` engine at fleet sizes up
to S=10^6 (max_backlog=8 — the CPU-feasible regime the paper's fleets
run in) and reports rounds/sec and frames/sec.  The engine is AOT-lowered
(``lower().compile()``) so ``compile_s`` and ``steady_s`` are measured
separately, never inferred by subtraction.  Results land in
``results/bench/BENCH_fleet.json``.

``--devices N`` forces N XLA host devices (the flag must land before jax
imports, so pass it on the command line, not from a REPL that already
imported jax) and runs the scan with the ``"streams"`` axis sharded over
an (N, 1) mesh; ``--streams`` overrides the fleet-size sweep.

  PYTHONPATH=src:benchmarks python benchmarks/bench_fleet_control.py --backend jax
  PYTHONPATH=src:benchmarks python benchmarks/bench_fleet_control.py --smoke --backend jax
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python benchmarks/bench_fleet_control.py --backend jax --devices 8 --streams 1000000
"""
from __future__ import annotations

import argparse
import contextlib
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

FLEET_SIZES = (16, 64, 256, 1024)
JAX_FLEET_SIZES = (1000, 10000, 100000, 1000000)


def _force_host_devices(n: int) -> None:
    """Make sure this process sees >= n XLA devices.  The host-platform
    device count only takes effect before jax initializes, so set the flag
    when jax is not yet imported and fail with a recipe when it is."""
    if n <= 1:
        return
    flag = f"--xla_force_host_platform_device_count={n}"
    if "jax" in sys.modules:
        import jax

        if len(jax.devices()) < n:
            raise SystemExit(
                f"--devices {n}: jax is already initialized with "
                f"{len(jax.devices())} device(s); relaunch with "
                f"XLA_FLAGS={flag}")
        return
    cur = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in cur:
        os.environ["XLA_FLAGS"] = (cur + " " + flag).strip()


def build_fleet(policy: str, S: int, seed: int, backlog: int = 16):
    """One FleetRunner plus S equivalent PolicyRunners, same backlogs."""
    from repro.core.netsim import mbps, png_size_model
    from repro.policy import BandwidthEstimator, FleetRunner, PolicyRunner
    from repro.policy.registry import make_policy

    rng = np.random.default_rng(seed)
    resolutions = (45, 90, 134, 179, 224)
    acc = (0.6, 0.75, 0.85, 0.92, 0.96)
    kw = dict(resolutions=resolutions, acc_server=acc, deadline=0.2,
              latency=0.05, server_time=0.037, size_of=png_size_model)
    fleet = FleetRunner([make_policy(policy) for _ in range(S)], bw_init=1.0, **kw)
    runners = [PolicyRunner(make_policy(policy),
                            bw=BandwidthEstimator(estimate_bps=1.0), **kw)
               for _ in range(S)]
    bw = rng.uniform(mbps(0.5), mbps(10.0), size=S)
    fleet.bw_est[:] = bw
    lens = rng.integers(backlog // 2, backlog + 1, size=S)
    for s in range(S):
        runners[s].bw.estimate_bps = bw[s]
        for i in range(int(lens[s])):
            a, c = i / 30.0, float(rng.uniform(0.2, 0.99))
            runners[s].add_frame(a, c)
            fleet.add_frame(s, a, c)
    return fleet, runners


def check_equal(batch, runners, now: float) -> None:
    for s, runner in enumerate(runners):
        ref = runner.plan(now=now)
        got = batch.plan(s)
        assert got.offloads == ref.offloads, (s, got.offloads, ref.offloads)
        assert got.theta == ref.theta and got.resolution == ref.resolution, s
        assert abs(got.total_gain - ref.total_gain) <= 1e-9, s


def bench_one(policy: str, S: int, seed: int, repeats: int, backlog: int = 16) -> dict:
    fleet, runners = build_fleet(policy, S, seed, backlog=backlog)
    now = np.zeros(S)
    # correctness first: batched == looped on this instance
    batch = fleet.plan_all(now)
    check_equal(batch, runners, 0.0)

    # interleaved best-of: per-pass pairs resist scheduler noise better
    # than two long back-to-back loops
    t_batched, t_looped = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fleet.plan_all(now)
        t_batched.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        for r in runners:
            r.plan(now=0.0)
        t_looped.append(time.perf_counter() - t0)

    tb, tl = min(t_batched), min(t_looped)
    return {"policy": policy, "n_streams": S, "backlog": backlog,
            "looped_ms": round(tl * 1e3, 3),
            "batched_ms": round(tb * 1e3, 3),
            "speedup": round(tl / max(tb, 1e-12), 2)}


def check_jax_parity(S: int = 4, n_frames: int = 64, seed: int = 0,
                     devices: int = 1) -> dict:
    """Exact-integer gate: both ``MultiStreamServer`` backends replay the
    same seeded workload and must agree on every aggregate decision count
    (frame_rate=32 — the tie-free grid, see tests/_diff.py).  With
    ``devices > 1`` the jax backend runs a THIRD time under a streams mesh
    and must match decision-for-decision too."""
    from repro.core.netsim import Uplink, mbps
    from repro.net import EdgeFabric
    from repro.serving import MultiStreamServer, ServeConfig
    from repro.serving.synthetic import synthetic_streams, synthetic_tiers

    fast, slow, cal = synthetic_tiers()
    cfg = ServeConfig(resolutions=(4, 8), acc_server=(0.7, 0.99), batch_size=16,
                      frame_rate=32.0, deadline=0.2)
    imgs, labels = synthetic_streams(S, n_frames, seed=seed)

    def run(backend, mesh=None):
        from repro.sharding.axes import sharding_ctx

        fab = EdgeFabric.degenerate(
            Uplink(bandwidth_bps=mbps(50.0), latency=0.05,
                   server_time=cfg.server_time), n_streams=S)
        srv = MultiStreamServer(cfg, fast, slow, cal, None, n_streams=S,
                                fabric=fab, backend=backend)
        with sharding_ctx(mesh) if mesh is not None else contextlib.nullcontext():
            return srv.process_streams(imgs, labels)

    runs = {"numpy": run("numpy"), "jax": run("jax")}
    if devices > 1:
        from repro.launch.mesh import make_streams_mesh

        runs[f"jax@{devices}dev"] = run("jax", make_streams_mesh(devices))
    mn = runs["numpy"]
    for name, mj in runs.items():
        for k in ("n_frames", "n_offloaded", "n_deadline_miss"):
            assert getattr(mn, k) == getattr(mj, k), (
                name, k, getattr(mn, k), getattr(mj, k))
        assert mn.accuracy == mj.accuracy, (name, mn.accuracy, mj.accuracy)
    return {"parity": "exact", "runs": "==".join(runs), "n_streams": S,
            "n_frames": int(mn.n_frames), "n_offloaded": int(mn.n_offloaded)}


def bench_jax_one(S: int, n_rounds: int, seed: int, backlog: int = 8,
                  batch: int = 8, devices: int = 1, collect: str = "none",
                  telemetry: bool = False, repeats: int = 1) -> dict:
    """Round-loop throughput of the jitted engine on synthetic inputs.

    ``collect="none"`` (the default) so the scan carries nothing per round
    beyond the fleet state — the S=1e6 regime the numpy loop cannot reach;
    ``collect="metrics"`` / ``telemetry=True`` measure the cost of the
    per-round outputs (the ``--telemetry`` overhead gate compares them).
    With ``devices > 1`` the (S,) stream arrays are placed sharded over an
    (N, 1) mesh (S rounds up to a device multiple) and the jitted scan
    runs SPMD.  The engine is AOT-compiled (``repro.obs.profile
    .aot_split``) so the reported ``compile_s`` is the real lower+compile
    wall-clock, not a first-call subtraction; ``repeats`` takes the best
    of N steady-state executions (fresh carry each — the scan donates)."""
    import jax
    import jax.numpy as jnp

    from repro.core.netsim import mbps, payload_sizes, png_size_model
    from repro.launch.mesh import make_streams_mesh
    from repro.obs.profile import aot_split
    from repro.policy.fleet_jax import spec_for_policy
    from repro.policy.registry import make_policy
    from repro.serving import engine_jax as ej
    from repro.sharding.axes import host_shard, sharding_ctx

    S = -(-S // devices) * devices  # pad to a whole number of shards
    ctx = (sharding_ctx(make_streams_mesh(devices)) if devices > 1
           else contextlib.nullcontext())
    resolutions = (4, 8)
    sizes = payload_sizes(png_size_model, np.asarray(resolutions))
    pspec = spec_for_policy(make_policy("cbo", max_backlog=backlog),
                            sizes=sizes, acc_server=(0.7, 0.99), deadline=0.2,
                            latency=0.05, server_time=0.037)
    spec = ej.EngineSpec(n_streams=S, batch=batch, n_cells=1, n_replicas=1,
                         planner=pspec, collect=collect, telemetry=telemetry)
    bw = mbps(6.0)
    rng = np.random.default_rng(seed)
    fr = 32.0
    base = (np.arange(n_rounds * batch, dtype=np.float32) / fr).reshape(
        n_rounds, 1, batch)
    m = len(resolutions)
    with ctx:
        params = ej.EngineParams(
            sizes=jnp.asarray(sizes, dtype=jnp.float32),
            cell_bw=jnp.asarray([bw], dtype=jnp.float32),
            cell_of=host_shard(jnp.zeros(S, dtype=jnp.int32), "streams"),
            replica_st=jnp.asarray([0.037], dtype=jnp.float32),
            stream_bw=host_shard(jnp.full((S,), bw, dtype=jnp.float32),
                                 "streams"),
            weights=host_shard(jnp.ones(S, dtype=jnp.float32), "streams"),
            bw_init=host_shard(jnp.full((S,), bw, dtype=jnp.float32),
                               "streams"))
        inputs = ej.RoundInputs(
            arr=host_shard(jnp.asarray(np.broadcast_to(base, (n_rounds, S, batch))),
                           None, "streams", None),
            valid=host_shard(jnp.ones((n_rounds, S, batch), dtype=bool),
                             None, "streams", None),
            conf=host_shard(jnp.asarray(rng.uniform(0.0, 1.0, (n_rounds, S, batch)),
                                        dtype=jnp.float32),
                            None, "streams", None),
            fast_ok=host_shard(jnp.asarray(rng.random((n_rounds, S, batch)) < 0.7),
                               None, "streams", None),
            slow_ok=host_shard(jnp.asarray(rng.random((n_rounds, S, batch, m)) < 0.9),
                               None, "streams", None, None))

        step = ej.make_engine(spec)
        carry0 = ej.init_carry(spec, params)
        jax.block_until_ready((params, carry0, inputs))
        compiled, t_compile = aot_split(step, params, carry0, inputs)
        # the engine donates its carry buffers (make_engine, donate_argnums):
        # each call needs a freshly built carry, rebuilt outside the timed
        # region; one warm-up execution absorbs first-dispatch costs, but
        # at >10^7 frames a run is minutes long and dwarfs dispatch noise,
        # so the warm-up pass is skipped rather than doubling the wall-clock
        if n_rounds * S * batch <= 20_000_000:
            carry, _ = compiled(params, carry0, inputs)
            jax.block_until_ready(carry)
            carry0 = ej.init_carry(spec, params)
            jax.block_until_ready(carry0)
        times, ys = [], None
        for r in range(max(int(repeats), 1)):
            t0 = time.perf_counter()
            carry, ys = compiled(params, carry0, inputs)
            jax.block_until_ready(carry)
            times.append(time.perf_counter() - t0)
            if r + 1 < repeats:
                carry0 = ej.init_carry(spec, params)
                jax.block_until_ready(carry0)
        t_steady = min(times)
    # rounds actually emitted through the ys pytree — the telemetry gate
    # asserts this equals the requested round count
    rounds_emitted = None
    if ys is not None:
        col = ys.ts_bw_est if telemetry else ys.off_counts
        rounds_emitted = int(col.shape[0])
    return {"backend": "jax", "n_streams": S, "devices": devices,
            "rounds": n_rounds, "batch": batch, "backlog": backlog,
            "collect": collect, "telemetry": bool(telemetry),
            "rounds_emitted": rounds_emitted,
            "compile_s": round(t_compile, 3),
            "steady_s": round(t_steady, 4),
            "rounds_per_s": round(n_rounds / max(t_steady, 1e-12), 2),
            "frames_per_s": round(n_rounds * S * batch / max(t_steady, 1e-12), 1)}


def _telemetry_server(backend, S, cfg, fab, telemetry):
    from repro.serving import MultiStreamServer
    from repro.serving.synthetic import synthetic_tiers

    fast, slow, cal = synthetic_tiers()
    return MultiStreamServer(cfg, fast, slow, cal, None, n_streams=S,
                             fabric=fab, backend=backend, telemetry=telemetry)


def check_telemetry_parity(S: int = 8, n_frames: int = 64, seed: int = 0) -> dict:
    """Recorder gate: both backends replay one seeded workload with the
    recorder on; the recorded series must agree round-for-round under the
    exactness policy (integer series bit-equal, floats at tolerance)."""
    from repro.core.netsim import Uplink, mbps
    from repro.net import EdgeFabric
    from repro.obs import Telemetry
    from repro.serving import ServeConfig
    from repro.serving.synthetic import synthetic_streams

    cfg = ServeConfig(resolutions=(4, 8), acc_server=(0.7, 0.99), batch_size=16,
                      frame_rate=32.0, deadline=0.2)
    imgs, labels = synthetic_streams(S, n_frames, seed=seed)

    def run(backend):
        tel = Telemetry(record=True)
        fab = EdgeFabric.degenerate(
            Uplink(bandwidth_bps=mbps(50.0), latency=0.05,
                   server_time=cfg.server_time), n_streams=S)
        _telemetry_server(backend, S, cfg, fab, tel).process_streams(imgs, labels)
        return tel.recorder

    rec_np, rec_jx = run("numpy"), run("jax")
    expected = n_frames // cfg.batch_size
    assert rec_np.n_rounds == rec_jx.n_rounds == expected, (
        rec_np.n_rounds, rec_jx.n_rounds, expected)
    rec_np.assert_close(rec_jx, ctx="telemetry parity")
    return {"telemetry_parity": "exact", "n_streams": S,
            "rounds": rec_np.n_rounds, "series": len(rec_np.as_dict())}


def bench_telemetry_overhead(S: int, n_rounds: int, seed: int) -> dict:
    """Recorder-on vs recorder-off steady-state cost of the compiled round
    loop at identical collect level.  The gate allows 5% relative plus a
    50 ms absolute slack (CI scheduler noise on sub-second runs); best of
    two executions each side."""
    base = bench_jax_one(S, n_rounds, seed, collect="metrics", repeats=2)
    tele = bench_jax_one(S, n_rounds, seed, collect="metrics",
                         telemetry=True, repeats=2)
    assert tele["rounds_emitted"] == n_rounds, tele["rounds_emitted"]
    limit = base["steady_s"] * 1.05 + 0.05
    assert tele["steady_s"] <= limit, (
        f"telemetry overhead: {tele['steady_s']}s vs off "
        f"{base['steady_s']}s (limit {limit:.4f}s)")
    over = tele["steady_s"] / max(base["steady_s"], 1e-12) - 1.0
    return {"n_streams": S, "rounds": n_rounds,
            "steady_off_s": base["steady_s"], "steady_on_s": tele["steady_s"],
            "overhead_pct": round(over * 100.0, 2), "gate": "<=5% + 50ms"}


def telemetry_fairness_demo(S: int = 64, n_frames: int = 128,
                            seed: int = 0) -> dict:
    """The N=64 fairness collapse as a recorded trajectory: one shared
    starved cell (0.12 Mbps for 64 streams), Jain's index over cumulative
    landed offloads per round.  The end-of-run scalar only says fairness
    degraded; the series shows WHEN the collapse sets in (round 1, Jain
    ~0.46 on the canonical seed) and the partial recovery as the bandwidth
    EWMAs learn the contended share and the policies back off."""
    from repro.core.netsim import Uplink, mbps
    from repro.net import EdgeFabric
    from repro.obs import Telemetry
    from repro.serving import ServeConfig
    from repro.serving.synthetic import synthetic_streams

    cfg = ServeConfig(resolutions=(4, 8), acc_server=(0.7, 0.99), batch_size=16,
                      frame_rate=32.0, deadline=0.2)
    imgs, labels = synthetic_streams(S, n_frames, seed=seed)
    tel = Telemetry(record=True)
    fab = EdgeFabric.degenerate(
        Uplink(bandwidth_bps=mbps(0.12), latency=0.05,
               server_time=cfg.server_time), n_streams=S)
    _telemetry_server("numpy", S, cfg, fab, tel).process_streams(imgs, labels)
    jain = tel.recorder.jain_series()
    onset = next((int(i) for i, j in enumerate(jain) if j < 0.9), None)
    return {"n_streams": S, "rounds": int(tel.recorder.n_rounds),
            "jain_trajectory": [round(float(j), 4) for j in jain],
            "onset_round": onset,
            "jain_first": round(float(jain[0]), 4),
            "jain_last": round(float(jain[-1]), 4)}


def telemetry_relock_demo(S: int = 8, seed: int = 0) -> dict:
    """EWMA re-lock lag on the square-wave regime trace: the recorded
    ``bw_est`` vs ``bw_true`` series make the estimator's recovery time
    after each 20<->2 Mbps shift a measured number (``relock_lags``)."""
    from repro.core.netsim import Uplink, mbps
    from repro.net import EdgeFabric
    from repro.net.traces import regime_shift_trace
    from repro.obs import Telemetry, relock_lags
    from repro.serving import ServeConfig
    from repro.serving.synthetic import synthetic_streams

    cfg = ServeConfig(resolutions=(4, 8), acc_server=(0.7, 0.99), batch_size=16,
                      frame_rate=32.0, deadline=0.2)
    n_frames = 256  # 16 rounds x 0.5 s — two shifts per 4 s period leg
    imgs, labels = synthetic_streams(S, n_frames, seed=seed)
    tel = Telemetry(record=True)
    trace = regime_shift_trace((20.0, 2.0), period=4.0)
    fab = EdgeFabric.degenerate(
        Uplink(bandwidth_bps=mbps(20.0), latency=0.05,
               server_time=cfg.server_time, trace=trace), n_streams=S)
    _telemetry_server("numpy", S, cfg, fab, tel).process_streams(imgs, labels)
    rec = tel.recorder
    lags = relock_lags(rec, rtol=0.25, shift_rtol=0.2)
    err = rec.bw_error()
    return {"n_streams": S, "rounds": int(rec.n_rounds),
            "trace": "regime_shift 20<->2 Mbps, 4 s period",
            "shifts": [{"round": int(r), "relock_lag_rounds": lag}
                       for r, lag in lags],
            "mean_bw_err_per_round": [
                round(float(np.nanmean(row)), 4) for row in err]}


def run_telemetry(args) -> dict:
    """--telemetry: recorder parity + overhead gates, then the two recorded
    scenarios (fairness collapse, EWMA re-lock); merges under the
    ``"telemetry"`` key of BENCH_fleet.json so the throughput rows survive."""
    import json

    gate = check_telemetry_parity(seed=args.seed)
    print("bench_fleet_control," +
          ",".join(f"{k}={v}" for k, v in gate.items()), flush=True)
    S_over = 256 if args.smoke else 10_000
    overhead = bench_telemetry_overhead(S_over, n_rounds=4 if args.smoke else 16,
                                        seed=args.seed)
    print("bench_fleet_control,telemetry_overhead," +
          ",".join(f"{k}={v}" for k, v in overhead.items()), flush=True)
    fairness = telemetry_fairness_demo(seed=args.seed)
    print(f"bench_fleet_control,fairness_collapse,onset_round="
          f"{fairness['onset_round']},jain_last={fairness['jain_last']}",
          flush=True)
    relock = telemetry_relock_demo(seed=args.seed)
    print(f"bench_fleet_control,ewma_relock,shifts={relock['shifts']}",
          flush=True)
    block = {"parity_gate": gate, "overhead": overhead,
             "fairness_collapse": fairness, "ewma_relock": relock,
             "smoke": bool(args.smoke)}
    from benchmarks.common import emit_bench_json, out_path

    path = out_path("BENCH_fleet.json")
    payload = {}
    if os.path.exists(path):
        with open(path) as fh:
            payload = json.load(fh)
    payload["telemetry"] = block
    emit_bench_json("BENCH_fleet.json", payload)
    if args.smoke:
        print("bench_fleet_control,telemetry_smoke=ok  "
              "(recorder series numpy == jax; overhead within gate)")
    return block


def run_jax(args) -> dict:
    gate = check_jax_parity(seed=args.seed, devices=args.devices)
    print("bench_fleet_control,backend=jax," +
          ",".join(f"{k}={v}" for k, v in gate.items()), flush=True)
    sizes = (256,) if args.smoke else args.sizes
    if sizes == FLEET_SIZES:  # backend-appropriate default scale
        sizes = JAX_FLEET_SIZES
    if args.streams:
        sizes = args.streams
    n_rounds = 4 if args.smoke else args.rounds
    rows = []
    for S in sizes:
        row = bench_jax_one(S, n_rounds, seed=args.seed, devices=args.devices)
        rows.append(row)
        print("bench_fleet_control," + ",".join(f"{k}={v}" for k, v in row.items()),
              flush=True)
    out = {"backend": "jax", "devices": args.devices, "parity_gate": gate,
           "rows": rows, "smoke": bool(args.smoke)}
    from benchmarks.common import emit_bench_json

    emit_bench_json("BENCH_fleet.json", out)
    if args.smoke:
        who = ("jax+mesh decisions == jax decisions == numpy decisions"
               if args.devices > 1 else "jax decisions == numpy decisions")
        print(f"bench_fleet_control,smoke=ok  ({who})")
    return out


def run(args=None) -> dict:
    if args is None:
        args = parse_args([])
    if args.telemetry:
        return run_telemetry(args)
    if args.backend == "jax":
        _force_host_devices(args.devices)
        return run_jax(args)
    sizes = (64,) if args.smoke else args.sizes
    repeats = 1 if args.smoke else args.repeats
    rows = []
    for policy in args.policies:
        for S in sizes:
            row = bench_one(policy, S, seed=args.seed, repeats=repeats)
            rows.append(row)
            print("bench_fleet_control," + ",".join(f"{k}={v}" for k, v in row.items()),
                  flush=True)
    if args.smoke:
        print("bench_fleet_control,smoke=ok  (batched plans == looped plans)")
        return {"smoke": "ok", "rows": rows}
    ref = [r for r in rows if r["policy"] == "cbo" and r["n_streams"] == 256]
    if ref and ref[0]["speedup"] < 10.0:
        print(f"bench_fleet_control,WARNING: cbo S=256 speedup {ref[0]['speedup']} < 10x")
    out = {"backend": "numpy", "rows": rows}
    from benchmarks.common import emit_bench_json

    emit_bench_json("BENCH_fleet.json", out, mirror="fleet_control.json")
    return out


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sizes", type=lambda s: tuple(int(x) for x in s.split(",")),
                    default=FLEET_SIZES, help="comma-separated fleet sizes")
    ap.add_argument("--policies", type=lambda s: tuple(s.split(",")),
                    default=("cbo", "threshold"), help="policies to bench")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", choices=("numpy", "jax"), default="numpy",
                    help="numpy: batched-vs-looped planner; jax: compiled round loop")
    ap.add_argument("--rounds", type=int, default=16,
                    help="rounds per lax.scan run (--backend jax)")
    ap.add_argument("--devices", type=int, default=1,
                    help="shard the streams axis over N forced XLA host "
                         "devices (--backend jax; must be set before jax "
                         "initializes — pass on the CLI, or export "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    ap.add_argument("--streams", type=lambda s: tuple(int(x) for x in s.split(",")),
                    default=(), help="fleet sizes for the jax round-loop sweep "
                                     "(overrides --sizes; e.g. 1000000)")
    ap.add_argument("--telemetry", action="store_true",
                    help="telemetry mode: recorder parity + overhead gates "
                         "plus the recorded fairness-collapse and EWMA "
                         "re-lock scenarios (merges under the 'telemetry' "
                         "key of BENCH_fleet.json)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: small S, single pass, exact parity gates")
    return ap.parse_args(argv)


if __name__ == "__main__":
    run(parse_args())
