"""Fleet control plane: batched ``FleetRunner.plan_all`` vs S looped
``PolicyRunner.plan`` calls on identical backlogs.

The data plane has been one batched call per round since the multi-stream
engine landed; this benchmark measures the *decision* plane — the part
that was still O(S) Python — before/after the struct-of-arrays refactor.
For each fleet size S it builds S random ragged backlogs in the paper's
link regime (0.5-10 Mbps per-stream estimates, 200 ms deadline) with
per-stream bandwidth estimates, plans them both ways, asserts the batched
plans equal the looped ones (offload schedules, theta, r° — exactly;
gains to 1e-9), and reports interleaved best-of wall-clock speedup.
Target is >=10x at S=256; measured speedup is hardware-dependent (the
batched planner trades ~30x fewer interpreter dispatches for more raw
element traffic, so narrow containers land lower than wide hosts).

  PYTHONPATH=src:benchmarks python benchmarks/bench_fleet_control.py
  PYTHONPATH=src:benchmarks python benchmarks/bench_fleet_control.py --smoke
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

FLEET_SIZES = (16, 64, 256, 1024)


def build_fleet(policy: str, S: int, seed: int, backlog: int = 16):
    """One FleetRunner plus S equivalent PolicyRunners, same backlogs."""
    from repro.core.netsim import mbps, png_size_model
    from repro.policy import BandwidthEstimator, FleetRunner, PolicyRunner
    from repro.policy.registry import make_policy

    rng = np.random.default_rng(seed)
    resolutions = (45, 90, 134, 179, 224)
    acc = (0.6, 0.75, 0.85, 0.92, 0.96)
    kw = dict(resolutions=resolutions, acc_server=acc, deadline=0.2,
              latency=0.05, server_time=0.037, size_of=png_size_model)
    fleet = FleetRunner([make_policy(policy) for _ in range(S)], bw_init=1.0, **kw)
    runners = [PolicyRunner(make_policy(policy),
                            bw=BandwidthEstimator(estimate_bps=1.0), **kw)
               for _ in range(S)]
    bw = rng.uniform(mbps(0.5), mbps(10.0), size=S)
    fleet.bw_est[:] = bw
    lens = rng.integers(backlog // 2, backlog + 1, size=S)
    for s in range(S):
        runners[s].bw.estimate_bps = bw[s]
        for i in range(int(lens[s])):
            a, c = i / 30.0, float(rng.uniform(0.2, 0.99))
            runners[s].add_frame(a, c)
            fleet.add_frame(s, a, c)
    return fleet, runners


def check_equal(batch, runners, now: float) -> None:
    for s, runner in enumerate(runners):
        ref = runner.plan(now=now)
        got = batch.plan(s)
        assert got.offloads == ref.offloads, (s, got.offloads, ref.offloads)
        assert got.theta == ref.theta and got.resolution == ref.resolution, s
        assert abs(got.total_gain - ref.total_gain) <= 1e-9, s


def bench_one(policy: str, S: int, seed: int, repeats: int, backlog: int = 16) -> dict:
    fleet, runners = build_fleet(policy, S, seed, backlog=backlog)
    now = np.zeros(S)
    # correctness first: batched == looped on this instance
    batch = fleet.plan_all(now)
    check_equal(batch, runners, 0.0)

    # interleaved best-of: per-pass pairs resist scheduler noise better
    # than two long back-to-back loops
    t_batched, t_looped = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fleet.plan_all(now)
        t_batched.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        for r in runners:
            r.plan(now=0.0)
        t_looped.append(time.perf_counter() - t0)

    tb, tl = min(t_batched), min(t_looped)
    return {"policy": policy, "n_streams": S, "backlog": backlog,
            "looped_ms": round(tl * 1e3, 3),
            "batched_ms": round(tb * 1e3, 3),
            "speedup": round(tl / max(tb, 1e-12), 2)}


def run(args=None) -> dict:
    if args is None:
        args = parse_args([])
    sizes = (64,) if args.smoke else args.sizes
    repeats = 1 if args.smoke else args.repeats
    rows = []
    for policy in args.policies:
        for S in sizes:
            row = bench_one(policy, S, seed=args.seed, repeats=repeats)
            rows.append(row)
            print("bench_fleet_control," + ",".join(f"{k}={v}" for k, v in row.items()),
                  flush=True)
    if args.smoke:
        print("bench_fleet_control,smoke=ok  (batched plans == looped plans)")
        return {"smoke": "ok", "rows": rows}
    ref = [r for r in rows if r["policy"] == "cbo" and r["n_streams"] == 256]
    if ref and ref[0]["speedup"] < 10.0:
        print(f"bench_fleet_control,WARNING: cbo S=256 speedup {ref[0]['speedup']} < 10x")
    out = {"rows": rows}
    from benchmarks.common import out_path

    with open(out_path("fleet_control.json"), "w") as f:
        json.dump(out, f, indent=2)
    return out


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sizes", type=lambda s: tuple(int(x) for x in s.split(",")),
                    default=FLEET_SIZES, help="comma-separated fleet sizes")
    ap.add_argument("--policies", type=lambda s: tuple(s.split(",")),
                    default=("cbo", "threshold"), help="policies to bench")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: S=64, single pass, assert batched == looped")
    return ap.parse_args(argv)


if __name__ == "__main__":
    run(parse_args())
