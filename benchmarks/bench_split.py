"""Split-computation offloading: frame-only vs split-enabled action grids
under time-varying uplinks (LTE / WiFi traces).

The regime is the one the split subsystem exists for: the slow tier is
nearly as slow as the deadline (``server_time`` close to ``T``), so a
full-frame offload's rtt eats the window — at the default settings a
full-resolution frame needs ~12 Mbps to land in time, which neither trace
sustains.  A feature cut near the end of the network (Swin stage 4) ships
~3x the bytes but pays only a suffix-scaled rtt, so it lands from
~2.5 Mbps up.  The sweep runs the same fleet twice per trace — action grid
{local} ∪ {frame@r} vs {local} ∪ {frame@r} ∪ {features@cut k} — and
records accuracy / offload mix / deadline misses.

``--smoke`` is the CI gate: on small split grids the batched planner
(``cbo_plan_many``), the looped planner (``cbo_plan``), and a brute-force
enumeration of every action assignment must agree, and a *degenerate*
(frames-only) action table must reproduce the recorded pre-split fleet
snapshot (``tests/data/fabric_snapshot.json``) bit-for-bit.

  PYTHONPATH=src:benchmarks python benchmarks/bench_split.py
  PYTHONPATH=src:benchmarks python benchmarks/bench_split.py --smoke
  PYTHONPATH=src:benchmarks python benchmarks/bench_split.py --arch vit-s16 --bw 12
"""
from __future__ import annotations

import argparse
import itertools
import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core.netsim import Uplink, mbps, png_size_model  # noqa: E402
from repro.net import EdgeFabric, lte_trace, wifi_trace  # noqa: E402
from repro.policy.frontier import cbo_plan, cbo_plan_many  # noqa: E402
from repro.policy.types import ActionTable, Env, EnvBatch, Frame  # noqa: E402
from repro.serving import FairScheduler, MultiStreamServer, ServeConfig  # noqa: E402
from repro.serving.synthetic import synthetic_streams, synthetic_tiers  # noqa: E402
from repro.split import build_action_table, catalog_for  # noqa: E402


def make_cfg(args, actions=None) -> ServeConfig:
    # base_res=16 scaling as in bench_multistream: the 8-px synthetic frames
    # carry full-upload bytes so the uplink actually binds
    return ServeConfig(deadline=args.deadline, frame_rate=args.fps,
                       batch_size=16, resolutions=(4, 8),
                       acc_server=(0.7, 0.99), server_time=args.server_time,
                       size_of=lambda r: png_size_model(r, base_res=16),
                       actions=actions)


def split_table(cfg: ServeConfig, args) -> ActionTable:
    cat = catalog_for(args.arch, max_cuts=args.cuts)
    return build_action_table(cat, resolutions=cfg.resolutions,
                              size_of=cfg.size_of, acc_server=cfg.acc_server,
                              device_peak=args.npu_peak, acc_drop=args.acc_drop)


def run_one(trace, actions, args, nominal_mbps=None) -> dict:
    cfg = make_cfg(args, actions)
    fast, slow, cal = synthetic_tiers()
    # nominal = the link's rated capacity (the estimators' optimistic
    # prior); the trace modulates the actual rate underneath it
    up = Uplink(bandwidth_bps=mbps(nominal_mbps or args.bw), latency=args.latency,
                server_time=cfg.server_time, seed=args.seed, trace=trace)
    fab = EdgeFabric.degenerate(up, n_streams=args.streams)
    srv = MultiStreamServer(cfg, fast, slow, cal, None, n_streams=args.streams,
                            scheduler=FairScheduler("round_robin"), fabric=fab)
    n_frame_off = n_split_off = 0

    def hook(rec):
        nonlocal n_frame_off, n_split_off
        k = np.asarray(rec["off_kind"])
        n_split_off += int((k == 1).sum())
        n_frame_off += int((k == 0).sum())

    srv.round_hook = hook
    imgs, labels = synthetic_streams(args.streams, args.frames, seed=args.seed)
    m = srv.process_streams(imgs, labels)
    return {"grid": "split" if actions is not None and actions.has_splits
            else "frame_only",
            "n_frame_offloads_planned": n_frame_off,
            "n_split_offloads_planned": n_split_off, **m.summary()}


# --------------------------------------------------------------------------- #
# --smoke: planner triple-agreement + degenerate-table snapshot fidelity
# --------------------------------------------------------------------------- #

_SIZES = (2500.0, 60000.0)
_ACC = (0.7, 0.99)


def _smoke_table() -> ActionTable:
    base = ActionTable.frames_only(sizes=np.asarray(_SIZES), acc=np.asarray(_ACC))
    return ActionTable(
        kind=np.r_[base.kind, np.ones(2, dtype=np.int8)],
        res=np.r_[base.res, np.full(2, 1, dtype=np.int64)],
        cut=np.r_[base.cut, np.arange(2, dtype=np.int64)],
        sizes=np.r_[base.sizes, [30000.0, 8000.0]],
        acc=np.r_[base.acc, [0.98, 0.95]],
        t_dev=np.r_[base.t_dev, [0.002, 0.004]],
        srv_frac=np.r_[base.srv_frac, [0.5, 0.1]])


def brute_force_gain(frames, env: Env) -> float:
    """Enumerate every action assignment over the DP's domain (local, or
    one positive-gain action per frame), chaining uplink busy time in the
    planner's confidence-descending order; the max total gain is the
    oracle ``cbo_plan`` must match."""
    act = env.actions
    tx = act.sizes / env.bandwidth
    rtt = act.rtt(env.server_time, env.latency)
    order = sorted(range(len(frames)), key=lambda i: (-frames[i].conf, i))
    best = 0.0
    for assign in itertools.product(range(act.n_actions + 1), repeat=len(frames)):
        t, gain, ok = 0.0, 0.0, True
        for i in order:
            a = assign[i] - 1
            if a < 0:
                continue  # local
            dA = act.acc[a] - frames[i].conf
            if dA <= 0:
                ok = False
                break
            t = max(t, frames[i].arrival + act.t_dev[a]) + tx[a]
            if t + rtt[a] > frames[i].arrival + env.deadline:
                ok = False
                break
            gain += dA
        if ok and gain > best:
            best = gain
    return best


def smoke_planner(args) -> None:
    """Batched == looped == brute force on small split grids."""
    from repro.policy.fleet import FleetState

    table = _smoke_table()
    for seed in range(args.smoke_seeds):
        rng = np.random.default_rng(seed)
        k = int(rng.integers(1, 7))
        frames = [Frame(arrival=i / 32.0, conf=float(rng.integers(20, 99)) / 100.0,
                        sizes=_SIZES) for i in range(k)]
        env = Env(bandwidth=float(rng.uniform(3e4, 4e5)), latency=0.03,
                  server_time=0.1, deadline=0.2, acc_server=_ACC, actions=table)
        plan = cbo_plan(frames, env)
        oracle = brute_force_gain(frames, env)
        assert abs(plan.total_gain - oracle) < 1e-9, \
            f"seed {seed}: DP gain {plan.total_gain} != brute force {oracle}"

        # batched fleet of clones of this instance + fresh random streams
        S = 3
        state = FleetState(S, max_backlog=64)
        for s in range(S):
            kk = k if s == 0 else int(rng.integers(0, 7))
            if kk:
                conf = (frames if s == 0 else None)
                cvals = (np.asarray([f.conf for f in frames]) if s == 0
                         else rng.integers(20, 99, size=kk) / 100.0)
                state.extend(np.full(kk, s, dtype=np.int64),
                             np.arange(kk) / 32.0, np.asarray(cvals, dtype=np.float64))
        envb = EnvBatch(bandwidth=np.full(S, env.bandwidth), latency=0.03,
                        server_time=0.1, deadline=0.2, acc_server=_ACC,
                        sizes=np.asarray(_SIZES), actions=table)
        batch = cbo_plan_many(state, envb, np.zeros(S))
        offs = state.offsets
        for s in range(S):
            fr = [Frame(arrival=float(a), conf=float(c), sizes=_SIZES)
                  for a, c in zip(state.arrival[offs[s]:offs[s + 1]],
                                  state.conf[offs[s]:offs[s + 1]])]
            p = cbo_plan(fr, envb.for_stream(s))
            assert batch.plan(s).offloads == p.offloads, f"seed {seed} stream {s}"
    print(f"bench_split,smoke_planner,seeds={args.smoke_seeds},"
          f"batched==looped==brute_force", flush=True)


def smoke_snapshot(args) -> None:
    """A degenerate (frames-only) table through the full serving stack must
    pin the recorded pre-split snapshot bit-for-bit."""
    from repro.core.netsim import payload_sizes

    snap_path = os.path.join(os.path.dirname(__file__), "..", "tests", "data",
                             "fabric_snapshot.json")
    with open(snap_path) as f:
        snap = json.load(f)["degenerate"]
    cfg = ServeConfig(resolutions=(4, 8), acc_server=(0.7, 0.99), batch_size=16,
                      frame_rate=32.0, deadline=0.2,
                      actions=ActionTable.frames_only(
                          sizes=payload_sizes(png_size_model, np.asarray((4, 8))),
                          acc=np.asarray((0.7, 0.99))))
    fast, slow, cal = synthetic_tiers()
    up = Uplink(bandwidth_bps=mbps(50.0), latency=0.05, server_time=cfg.server_time)
    fab = EdgeFabric.degenerate(up, n_streams=4)
    imgs, labels = synthetic_streams(4, 64, seed=0)
    agg = MultiStreamServer(cfg, fast, slow, cal, None, n_streams=4,
                            fabric=fab).process_streams(imgs, labels)
    assert agg.accuracy == snap["accuracy"]
    assert int(agg.n_offloaded) == snap["n_offloaded"]
    assert int(agg.n_deadline_miss) == snap["n_deadline_miss"]
    for m, ref in zip(agg.per_stream, snap["per_stream"]):
        assert m.n_frames == ref["n_frames"]
        assert m.accuracy == ref["accuracy"]
        assert m.offload_frac == ref["offload_frac"]
        assert m.deadline_miss_frac == ref["deadline_miss_frac"]
    print("bench_split,smoke_snapshot,degenerate_table==fabric_snapshot",
          flush=True)


def run(args=None) -> dict:
    if args is None:
        args = parse_args([])
    if args.smoke:
        smoke_planner(args)
        smoke_snapshot(args)
        return {}

    cfg0 = make_cfg(args)
    table = split_table(cfg0, args)
    # two regimes, two stories: LTE never sustains what a full-frame
    # offload needs (the split grid is the ONLY way to the slow tier);
    # WiFi's good state admits frames but its interference bursts are
    # split-only (the bad rate clears the suffix-scaled window, not the
    # full-rtt one).  WiFi's nominal is the good-state rate — a rated-
    # capacity prior, so the frame grid gets a fair chance.
    traces = {
        "lte": (lte_trace(mean_mbps=args.bw, seed=args.seed), args.bw),
        "wifi": (wifi_trace(good_mbps=args.bw * 5, bad_mbps=args.bw * 2 / 3,
                            seed=args.seed), args.bw * 5),
    }
    out = {"config": {"arch": args.arch, "cuts": args.cuts, "bw_mbps": args.bw,
                      "latency": args.latency, "server_time": args.server_time,
                      "deadline": args.deadline, "fps": args.fps,
                      "streams": args.streams, "frames": args.frames,
                      "npu_peak": args.npu_peak, "acc_drop": args.acc_drop},
           "actions": [{"name": n, "bytes": float(b), "t_dev": float(t),
                        "srv_frac": float(f)}
                       for n, b, t, f in zip(("thumb", "full") + table.names,
                                             table.sizes, table.t_dev,
                                             table.srv_frac)],
           "traces": {}}
    for name, (trace, nominal) in traces.items():
        frame_row = run_one(trace, None, args, nominal)
        split_row = run_one(trace, table, args, nominal)
        out["traces"][name] = {"frame_only": frame_row, "split": split_row,
                               "delta_accuracy": round(split_row["accuracy"]
                                                       - frame_row["accuracy"], 4)}
        for row in (frame_row, split_row):
            print(f"bench_split,{name}," + ",".join(
                f"{k}={v}" for k, v in row.items()
                if k in ("grid", "accuracy", "offload_frac", "deadline_miss_frac",
                         "n_frame_offloads_planned", "n_split_offloads_planned")),
                flush=True)
    from benchmarks.common import emit_bench_json

    emit_bench_json("BENCH_split.json", out)
    return out


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="swin-b",
                    help="catalog family for the split actions "
                         "(vit-s16 / resnet-50 / swin-b)")
    ap.add_argument("--cuts", type=int, default=4, help="max cut points kept")
    ap.add_argument("--bw", type=float, default=6.0,
                    help="nominal uplink Mbps (trace mean)")
    ap.add_argument("--latency", type=float, default=0.03)
    ap.add_argument("--server-time", type=float, default=0.16,
                    help="full-model slow-tier seconds (close to the deadline "
                         "— the regime where only suffix offloads fit)")
    ap.add_argument("--deadline", type=float, default=0.2)
    ap.add_argument("--fps", type=float, default=30.0)
    ap.add_argument("--streams", type=int, default=2)
    ap.add_argument("--frames", type=int, default=512)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--npu-peak", type=float, default=7e12)
    ap.add_argument("--acc-drop", type=float, default=0.0,
                    help="int8 feature-degradation penalty on split accuracy")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: batched == looped == brute force on small "
                         "split grids; degenerate table == fabric snapshot")
    ap.add_argument("--smoke-seeds", type=int, default=8)
    return ap.parse_args(argv)


if __name__ == "__main__":
    run(parse_args())
