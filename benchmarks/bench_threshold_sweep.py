"""Paper Fig. 4 (uncalibrated) + Fig. 7a (calibrated): accuracy and offload
fraction vs confidence threshold theta."""
from __future__ import annotations

import json

import jax.numpy as jnp
import numpy as np

from benchmarks.common import build_stack, out_path
from repro.models import api
from repro.models.transformer import ParallelPlan
from benchmarks import common as C


def run() -> dict:
    stack = build_stack()
    frames, labels = stack.test["frames"], stack.test["labels"]
    fh = api.build(C.FAST_CFG, ParallelPlan(remat=False))
    sh = api.build(C.SLOW_CFG, ParallelPlan(remat=False))

    # precompute both tiers' predictions + calibrated/uncalibrated conf
    from benchmarks.common import _accuracy

    _, fl = _accuracy(fh.forward, stack.fast_params, frames, labels)
    _, sl = _accuracy(sh.forward, stack.slow_params, frames, labels)
    fast_pred, slow_pred = np.argmax(fl, -1), np.argmax(sl, -1)
    from repro.core.confidence import max_softmax

    conf_raw = np.asarray(max_softmax(jnp.asarray(fl)))
    conf_cal = np.asarray(stack.platt(conf_raw))

    def sweep(conf):
        rows = []
        for theta in np.linspace(0, 1, 21):
            offload = conf < theta
            pred = np.where(offload, slow_pred, fast_pred)
            rows.append({"theta": round(float(theta), 3),
                         "accuracy": float((pred == labels).mean()),
                         "offload_frac": float(offload.mean())})
        return rows

    out = {"uncalibrated_fig4": sweep(conf_raw), "calibrated_fig7a": sweep(conf_cal)}
    with open(out_path("fig4_7_threshold_sweep.json"), "w") as f:
        json.dump(out, f, indent=2)

    # paper claim: to reach a mid accuracy target, calibrated needs far less
    # offload than uncalibrated at matched accuracy
    for name, rows in out.items():
        for r in rows[::4]:
            print(f"bench_threshold/{name},theta={r['theta']},acc={r['accuracy']:.3f},offload={r['offload_frac']:.3f}")
    return out


if __name__ == "__main__":
    run()
