"""Trace-replay evaluation of all §V approaches: Local / Server / FastVA /
Compress / CBO-w/o-calibration / CBO / Optimal.

The replay precomputes both tiers' predictions (slow tier at every ladder
resolution) into a ``Trace``; the uplink/deadline simulation itself is the
*unified* policy replay engine (``repro.policy.replay_trace``) — every
approach here is just a registered policy name plus replay-physics knobs
(fallback predictions, local-tier occupancy, planning cadence).  Adding an
approach means registering a policy, not writing another simulation loop.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core.cascade import degrade_resolution
from repro.core.confidence import max_softmax
from repro.core.netsim import mbps, png_size_model
from repro.models import api
from repro.models.transformer import ParallelPlan
from repro.policy import Env, make_policy, replay_trace

FAST_TIME = 0.020  # Table III (s/frame): NPU tier
CALIB_TIME = 0.008  # Table III: calibration
SERVER_TIME = 0.037  # Table III: slow tier
COMPRESS_TIME = 0.080  # compressed DNN on CPU (~4x NPU; paper §V)


@dataclass
class Trace:
    labels: np.ndarray
    fast_pred: np.ndarray
    fast_fp_pred: np.ndarray  # unquantized fast model (the Compress local tier)
    slow_pred_by_res: dict  # res -> preds
    conf_raw: np.ndarray
    conf_cal: np.ndarray
    sizes: dict  # res -> payload bytes
    # planning tables, measured on the CALIBRATION split (no test peeking):
    plan_acc_by_res: tuple = ()  # A^o_r conditioned on low-confidence frames
    local_acc_mean: float = 0.5  # population fast-tier accuracy

    def __len__(self):
        return len(self.labels)


def build_trace(stack, max_frames: int = 1200) -> Trace:
    frames = stack.test["frames"][:max_frames]
    labels = stack.test["labels"][:max_frames]
    fh = api.build(C.FAST_CFG, ParallelPlan(remat=False))
    sh = api.build(C.SLOW_CFG, ParallelPlan(remat=False))

    _, fl = C._accuracy(fh.forward, stack.fast_params, frames, labels)
    conf_raw = np.asarray(max_softmax(jnp.asarray(fl)))
    conf_cal = np.asarray(stack.platt(conf_raw))

    # unquantized fast model = the "Compress" baseline's local tier
    fp_params = stack.fast_params_fp if stack.fast_params_fp is not None else stack.fast_params
    _, ffl = C._accuracy(fh.forward, fp_params, frames, labels)
    fast_fp_pred = np.argmax(ffl, -1)

    slow_by_res = {}
    for r in C.RESOLUTIONS:
        preds = []
        for i in range(0, len(labels), 256):
            imgs = degrade_resolution(jnp.asarray(frames[i : i + 256]), r)
            preds.append(np.argmax(np.asarray(sh.forward(stack.slow_params, imgs)), -1))
        slow_by_res[r] = np.concatenate(preds)

    # planning tables from the calibration split: A^o_r conditioned on the
    # low-confidence population (the frames CBO actually offloads). The
    # paper's population-mean A^o_r overestimates — difficulty correlates
    # with low confidence — and made CBO lose to Local at low bandwidth
    # (EXPERIMENTS.md §Paper-claims, finding F3).
    calib_frames = stack.calib.get("frames")
    if calib_frames is None:
        from repro.data.video import make_dataset

        calib_d = make_dataset(C.DATA_CFG, 120, seed=1)
        calib_frames, calib_labels = calib_d["frames"], calib_d["labels"]
    else:
        calib_labels = stack.calib["labels"]
    calib_cal_conf = np.asarray(stack.platt(stack.calib["conf"]))
    lowmask = calib_cal_conf <= np.median(calib_cal_conf)
    plan_acc = []
    for r in C.RESOLUTIONS:
        preds = []
        for i in range(0, len(calib_labels), 256):
            imgs = degrade_resolution(jnp.asarray(calib_frames[i : i + 256]), r)
            preds.append(np.argmax(np.asarray(sh.forward(stack.slow_params, imgs)), -1))
        pr = np.concatenate(preds)
        plan_acc.append(float((pr == calib_labels)[lowmask].mean()))

    sizes = {r: png_size_model(r, base_res=32, base_bytes=60000.0) for r in C.RESOLUTIONS}
    return Trace(labels=labels, fast_pred=np.argmax(fl, -1), fast_fp_pred=fast_fp_pred,
                 slow_pred_by_res=slow_by_res, conf_raw=conf_raw, conf_cal=conf_cal, sizes=sizes,
                 plan_acc_by_res=tuple(plan_acc),
                 local_acc_mean=float(stack.calib["correct"].mean()))


@dataclass
class NetCfg:
    bandwidth_mbps: float = 5.0
    latency: float = 0.1
    frame_rate: float = 30.0
    deadline: float = 0.2

    @property
    def gamma(self):
        return 1.0 / self.frame_rate

    @property
    def bw(self):
        return mbps(self.bandwidth_mbps)


# --------------------------- unified replay ------------------------------- #


def _replay(trace: Trace, net: NetCfg, policy, *, conf=None, acc_server=None,
            local_pred=None, local_time: float = 0.0, **kw) -> float:
    """Run one policy through the shared replay engine; returns accuracy."""
    env = Env(bandwidth=net.bw, latency=net.latency, server_time=SERVER_TIME,
              deadline=net.deadline,
              acc_server=acc_server if acc_server is not None else trace.plan_acc_by_res)
    result = replay_trace(
        policy,
        conf=conf if conf is not None else trace.conf_cal,
        slow_pred=np.stack([trace.slow_pred_by_res[r] for r in C.RESOLUTIONS]),
        sizes=[trace.sizes[r] for r in C.RESOLUTIONS],
        env=env,
        frame_interval=net.gamma,
        local_pred=local_pred,
        local_time=local_time,
        **kw,
    )
    return result.accuracy(trace.labels)


def _pop_acc(trace: Trace) -> tuple:
    """Population server accuracy per resolution (the greedy rules' table)."""
    return tuple(float((trace.slow_pred_by_res[r] == trace.labels).mean())
                 for r in C.RESOLUTIONS)


# ------------------------------ approaches --------------------------------- #


def run_local(trace: Trace, net: NetCfg) -> float:
    return _replay(trace, net, make_policy("local"), local_pred=trace.fast_pred)


def run_server(trace: Trace, net: NetCfg) -> float:
    """All frames offloaded; unanswered frames score wrong (no fallback)."""
    return _replay(trace, net, make_policy("server", frame_interval=net.gamma),
                   local_pred=None)


def run_fastva(trace: Trace, net: NetCfg) -> float:
    return _replay(trace, net, make_policy("greedy-rate", local_acc=trace.local_acc_mean),
                   acc_server=_pop_acc(trace), local_pred=trace.fast_pred,
                   local_time=FAST_TIME)


def run_compress(trace: Trace, net: NetCfg) -> float:
    fp_acc = float((trace.fast_fp_pred == trace.labels).mean())
    return _replay(trace, net, make_policy("greedy-rate", local_acc=fp_acc),
                   acc_server=_pop_acc(trace), local_pred=trace.fast_fp_pred,
                   local_time=COMPRESS_TIME)


def run_cbo(trace: Trace, net: NetCfg) -> float:
    return _replay(trace, net, make_policy("cbo", max_backlog=None),
                   conf=trace.conf_cal, local_pred=trace.fast_pred)


def run_cbo_wo(trace: Trace, net: NetCfg) -> float:
    return _replay(trace, net, make_policy("cbo", max_backlog=None),
                   conf=trace.conf_raw, local_pred=trace.fast_pred)


def run_optimal(trace: Trace, net: NetCfg) -> float:
    """Offline optimal, planned over 60-frame windows (replay, as in the
    paper) so the DP state stays small."""
    return _replay(trace, net, make_policy("optimal"), conf=trace.conf_cal,
                   local_pred=trace.fast_pred, window=60)


APPROACHES = {
    "Local": run_local,
    "Server": run_server,
    "FastVA": run_fastva,
    "Compress": run_compress,
    "CBO-w/o": run_cbo_wo,
    "CBO": run_cbo,
    "Optimal": run_optimal,
}
