"""Trace-replay evaluation of all §V approaches: Local / Server / FastVA /
Compress / CBO-w/o-calibration / CBO / Optimal.

The replay precomputes both tiers' predictions (slow tier at every ladder
resolution), then simulates the serial uplink + deadlines per approach and
scores *realized* accuracy — the paper's methodology, offline.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core.cascade import degrade_resolution
from repro.core.cbo import Env, Frame, cbo_plan, optimal_schedule
from repro.core.confidence import max_softmax
from repro.core.netsim import Uplink, mbps, png_size_model
from repro.models import api
from repro.models.transformer import ParallelPlan

FAST_TIME = 0.020  # Table III (s/frame): NPU tier
CALIB_TIME = 0.008  # Table III: calibration
SERVER_TIME = 0.037  # Table III: slow tier
COMPRESS_TIME = 0.080  # compressed DNN on CPU (~4x NPU; paper §V)


@dataclass
class Trace:
    labels: np.ndarray
    fast_pred: np.ndarray
    fast_fp_pred: np.ndarray  # unquantized fast model (the Compress local tier)
    slow_pred_by_res: dict  # res -> preds
    conf_raw: np.ndarray
    conf_cal: np.ndarray
    sizes: dict  # res -> payload bytes
    # planning tables, measured on the CALIBRATION split (no test peeking):
    plan_acc_by_res: tuple = ()  # A^o_r conditioned on low-confidence frames
    local_acc_mean: float = 0.5  # population fast-tier accuracy

    def __len__(self):
        return len(self.labels)


def build_trace(stack, max_frames: int = 1200) -> Trace:
    frames = stack.test["frames"][:max_frames]
    labels = stack.test["labels"][:max_frames]
    fh = api.build(C.FAST_CFG, ParallelPlan(remat=False))
    sh = api.build(C.SLOW_CFG, ParallelPlan(remat=False))

    _, fl = C._accuracy(fh.forward, stack.fast_params, frames, labels)
    conf_raw = np.asarray(max_softmax(jnp.asarray(fl)))
    conf_cal = np.asarray(stack.platt(conf_raw))

    # unquantized fast model = the "Compress" baseline's local tier
    fp_params = stack.fast_params_fp if stack.fast_params_fp is not None else stack.fast_params
    _, ffl = C._accuracy(fh.forward, fp_params, frames, labels)
    fast_fp_pred = np.argmax(ffl, -1)

    slow_by_res = {}
    for r in C.RESOLUTIONS:
        preds = []
        for i in range(0, len(labels), 256):
            imgs = degrade_resolution(jnp.asarray(frames[i : i + 256]), r)
            preds.append(np.argmax(np.asarray(sh.forward(stack.slow_params, imgs)), -1))
        slow_by_res[r] = np.concatenate(preds)

    # planning tables from the calibration split: A^o_r conditioned on the
    # low-confidence population (the frames CBO actually offloads). The
    # paper's population-mean A^o_r overestimates — difficulty correlates
    # with low confidence — and made CBO lose to Local at low bandwidth
    # (EXPERIMENTS.md §Paper-claims, finding F3).
    calib_frames = stack.calib.get("frames")
    if calib_frames is None:
        from repro.data.video import make_dataset

        calib_d = make_dataset(C.DATA_CFG, 120, seed=1)
        calib_frames, calib_labels = calib_d["frames"], calib_d["labels"]
    else:
        calib_labels = stack.calib["labels"]
    calib_cal_conf = np.asarray(stack.platt(stack.calib["conf"]))
    lowmask = calib_cal_conf <= np.median(calib_cal_conf)
    plan_acc = []
    for r in C.RESOLUTIONS:
        preds = []
        for i in range(0, len(calib_labels), 256):
            imgs = degrade_resolution(jnp.asarray(calib_frames[i : i + 256]), r)
            preds.append(np.argmax(np.asarray(sh.forward(stack.slow_params, imgs)), -1))
        pr = np.concatenate(preds)
        plan_acc.append(float((pr == calib_labels)[lowmask].mean()))

    sizes = {r: png_size_model(r, base_res=32, base_bytes=60000.0) for r in C.RESOLUTIONS}
    return Trace(labels=labels, fast_pred=np.argmax(fl, -1), fast_fp_pred=fast_fp_pred,
                 slow_pred_by_res=slow_by_res, conf_raw=conf_raw, conf_cal=conf_cal, sizes=sizes,
                 plan_acc_by_res=tuple(plan_acc),
                 local_acc_mean=float(stack.calib["correct"].mean()))


@dataclass
class NetCfg:
    bandwidth_mbps: float = 5.0
    latency: float = 0.1
    frame_rate: float = 30.0
    deadline: float = 0.2

    @property
    def gamma(self):
        return 1.0 / self.frame_rate

    @property
    def bw(self):
        return mbps(self.bandwidth_mbps)


def _acc(trace: Trace, results: np.ndarray) -> float:
    return float((results == trace.labels).mean())


# ------------------------------ approaches --------------------------------- #


def run_local(trace: Trace, net: NetCfg) -> float:
    return _acc(trace, trace.fast_pred)


def run_server(trace: Trace, net: NetCfg) -> float:
    """All frames offloaded; resolution capped so transmission fits both the
    frame interval (keep up with the stream) and the per-frame deadline."""
    tx_budget = min(net.gamma, net.deadline - SERVER_TIME - net.latency)
    res_ok = [r for r in C.RESOLUTIONS if trace.sizes[r] / max(net.bw, 1e-9) <= tx_budget]
    results = np.full(len(trace), -1)  # unanswered = wrong
    if not res_ok:
        return _acc(trace, results)
    r = max(res_ok)
    busy = 0.0
    for i in range(len(trace)):
        arr = i * net.gamma
        busy = max(busy, arr) + trace.sizes[r] / net.bw
        if busy + SERVER_TIME + net.latency <= arr + net.deadline:
            results[i] = trace.slow_pred_by_res[r][i]
    return _acc(trace, results)


def _greedy_offload(trace: Trace, net: NetCfg, local_pred: np.ndarray, local_time: float,
                    local_acc: float) -> float:
    """FastVA/Compress-style: offload when the best deadline-feasible
    resolution beats the local tier's (population) accuracy; no per-frame
    confidence. Rest handled locally if the local tier keeps up."""
    pop_acc = {r: float((trace.slow_pred_by_res[r] == trace.labels).mean()) for r in C.RESOLUTIONS}
    results = local_pred.copy()
    busy = 0.0
    local_busy = 0.0
    for i in range(len(trace)):
        arr = i * net.gamma
        done = False
        for r in sorted(C.RESOLUTIONS, reverse=True):
            if pop_acc[r] <= local_acc:
                break  # lower resolutions are worse than answering locally
            t_land = max(busy, arr) + trace.sizes[r] / net.bw + SERVER_TIME + net.latency
            if t_land <= arr + net.deadline:
                busy = max(busy, arr) + trace.sizes[r] / net.bw
                results[i] = trace.slow_pred_by_res[r][i]
                done = True
                break
        if not done:
            if local_busy <= arr:  # local tier free: process
                local_busy = arr + local_time
            else:  # load shedding: skip frames while the local tier is busy
                results[i] = -1
    return _acc(trace, results)


def run_fastva(trace: Trace, net: NetCfg) -> float:
    return _greedy_offload(trace, net, trace.fast_pred, FAST_TIME, trace.local_acc_mean)


def run_compress(trace: Trace, net: NetCfg) -> float:
    return _greedy_offload(trace, net, trace.fast_fp_pred, COMPRESS_TIME,
                           float((trace.fast_fp_pred == trace.labels).mean()))


def _run_cbo(trace: Trace, net: NetCfg, conf: np.ndarray, replan_every: int = 1) -> float:
    """Algorithm 1 deployment loop: re-plan over the backlog, offload the
    planned set, deadline-missed replies fall back to the fast answer.
    Planning table = calibration-split A^o_r conditioned on low confidence."""
    env = Env(bandwidth=net.bw, latency=net.latency, server_time=SERVER_TIME,
              deadline=net.deadline, acc_server=trace.plan_acc_by_res)
    results = trace.fast_pred.copy()
    busy = 0.0
    backlog: list[int] = []
    for i in range(len(trace)):
        arr = i * net.gamma
        backlog.append(i)
        backlog = [j for j in backlog if j * net.gamma + net.deadline > max(arr, busy)]
        if i % replan_every:
            continue
        frames = [Frame(arrival=j * net.gamma, conf=float(conf[j]),
                        sizes=tuple(trace.sizes[r] for r in C.RESOLUTIONS)) for j in backlog]
        plan = cbo_plan(frames, env, now=max(busy, arr))
        done = set()
        for bi, r in plan.offloads:
            j = backlog[bi]
            res = C.RESOLUTIONS[r]
            t_land = max(busy, j * net.gamma) + trace.sizes[res] / net.bw + SERVER_TIME + net.latency
            if t_land <= j * net.gamma + net.deadline:
                busy = max(busy, j * net.gamma) + trace.sizes[res] / net.bw
                results[j] = trace.slow_pred_by_res[res][j]
            done.add(j)  # planned but late -> fast answer stands (fallback)
        backlog = [j for j in backlog if j not in done]
    return _acc(trace, results)


def run_cbo(trace: Trace, net: NetCfg) -> float:
    return _run_cbo(trace, net, trace.conf_cal)


def run_cbo_wo(trace: Trace, net: NetCfg) -> float:
    return _run_cbo(trace, net, trace.conf_raw)


def run_optimal(trace: Trace, net: NetCfg) -> float:
    """Offline optimal on the full trace (replay, as in the paper)."""
    env = Env(bandwidth=net.bw, latency=net.latency, server_time=SERVER_TIME,
              deadline=net.deadline, acc_server=trace.plan_acc_by_res)
    # chunk the trace so the DP state stays small (windows of 60 frames)
    results = trace.fast_pred.copy()
    busy = 0.0
    W = 60
    for s in range(0, len(trace), W):
        idx = list(range(s, min(s + W, len(trace))))
        frames = [Frame(arrival=j * net.gamma, conf=float(trace.conf_cal[j]),
                        sizes=tuple(trace.sizes[r] for r in C.RESOLUTIONS)) for j in idx]
        plan = optimal_schedule(frames, env)
        for bi, r in sorted(plan.offloads):
            j = idx[bi]
            res = C.RESOLUTIONS[r]
            t_land = max(busy, j * net.gamma) + trace.sizes[res] / net.bw + SERVER_TIME + net.latency
            if t_land <= j * net.gamma + net.deadline:
                busy = max(busy, j * net.gamma) + trace.sizes[res] / net.bw
                results[j] = trace.slow_pred_by_res[res][j]
    return _acc(trace, results)


APPROACHES = {
    "Local": run_local,
    "Server": run_server,
    "FastVA": run_fastva,
    "Compress": run_compress,
    "CBO-w/o": run_cbo_wo,
    "CBO": run_cbo,
    "Optimal": run_optimal,
}
