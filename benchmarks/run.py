"""Benchmark orchestrator: one experiment per paper table/figure.

Prints ``name,us_per_call,derived``-style CSV lines per experiment and
writes JSON artifacts under results/bench/.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    import os

    sys.path.insert(0, os.path.dirname(__file__))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

    from benchmarks import (
        bench_calibration,
        bench_kernels,
        bench_multistream,
        bench_network,
        bench_optimal_gap,
        bench_policy_planner,
        bench_reliability,
        bench_resolution,
        bench_threshold_sweep,
        bench_tiers,
    )
    from benchmarks.common import build_stack

    t0 = time.time()
    build_stack()  # train/cache the two-tier stack once
    results = {}
    for mod in (bench_calibration, bench_reliability, bench_threshold_sweep,
                bench_resolution, bench_tiers, bench_kernels,
                bench_network, bench_optimal_gap, bench_policy_planner,
                bench_multistream):
        name = mod.__name__.split(".")[-1]
        print(f"=== {name} ===", flush=True)
        t = time.time()
        results[name] = mod.run()
        print(f"=== {name} done in {time.time()-t:.1f}s ===", flush=True)
    print(f"all benchmarks done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
