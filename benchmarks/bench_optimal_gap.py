"""Paper Fig. 14: Optimal accuracy over (bandwidth x frame rate), and the
Optimal-minus-CBO gap (the paper's claim: ~zero almost everywhere)."""
from __future__ import annotations

import json

import numpy as np

from benchmarks.approaches import NetCfg, build_trace, run_cbo, run_optimal
from benchmarks.common import build_stack, out_path


def run() -> dict:
    stack = build_stack()
    trace = build_trace(stack, max_frames=720)
    bws = (1, 2, 5, 10, 20)
    fps = (10, 20, 30)
    grid = []
    gaps = []
    for b in bws:
        for f in fps:
            net = NetCfg(bandwidth_mbps=b, frame_rate=f)
            a_opt = run_optimal(trace, net)
            a_cbo = run_cbo(trace, net)
            gap = round(a_opt - a_cbo, 4)
            gaps.append(gap)
            grid.append({"bandwidth_mbps": b, "frame_rate": f,
                         "optimal": round(a_opt, 4), "cbo": round(a_cbo, 4), "gap": gap})
            print(f"bench_optimal_gap,bw={b},fps={f},opt={a_opt:.4f},cbo={a_cbo:.4f},gap={gap}", flush=True)
    out = {"grid": grid, "mean_gap": round(float(np.mean(gaps)), 4), "max_gap": round(float(np.max(gaps)), 4)}
    with open(out_path("fig14_optimal_gap.json"), "w") as f:
        json.dump(out, f, indent=2)
    return out


if __name__ == "__main__":
    run()
