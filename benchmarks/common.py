"""Shared benchmark substrate: the two-tier stack on synthetic video.

Reproduces the paper's experimental *mechanics* offline (DESIGN.md §8):
  * slow tier = larger ResNet trained on the synthetic video dataset
    (plays ResNet-152-on-server);
  * fast tier = small ResNet, int8-quantized post-training
    (plays AlexNet-on-NPU: lower capacity AND lower precision);
  * both trained with the framework's own Trainer; cached under results/.

Everything is deterministic; `build_stack(force=True)` retrains.
"""
from __future__ import annotations

import json
import os
import pickle
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ResNetConfig
from repro.core.calibration import PlattCalibrator, ece
from repro.core.confidence import max_softmax
from repro.data.pipeline import DeterministicPipeline, PipelineConfig
from repro.data.video import VideoDataConfig, make_dataset
from repro.models import api
from repro.models.transformer import ParallelPlan
from repro.quant.quantize import qdq_tree
from repro.train import optim
from repro.train.trainer import TrainConfig, Trainer

CACHE = os.path.join(os.path.dirname(__file__), "..", "results", "bench_stack.pkl")

DATA_CFG = VideoDataConfig(
    n_classes=10, img_res=32, frames_per_video=12, noise_floor=0.3,
    class_difficulty=tuple(float(x) for x in np.clip(np.linspace(0.25, 1.05, 10), 0, 1)),
)
FAST_CFG = ResNetConfig(name="fast-tier", img_res=32, depths=(1,), width=6, n_classes=10)
SLOW_CFG = ResNetConfig(name="slow-tier", img_res=32, depths=(2, 2), width=48, n_classes=10)
RESOLUTIONS = (8, 12, 18, 24, 32)  # the paper's 45..224 ladder, scaled to 32px
# NPU numerics: int4 per-tensor QDQ. Finding (EXPERIMENTS.md): per-channel
# int8 is nearly lossless on this stack; reproducing the paper's 11-30% NPU
# accuracy loss requires the crude per-tensor low-bit regime of 2019-era
# NPU compilers.
NPU_QUANT = dict(bits=4, axis=None)


@dataclass
class TierStack:
    fast_params: dict
    slow_params: dict
    platt: PlattCalibrator
    acc_fast: float
    acc_slow: float
    acc_server_by_res: tuple
    calib: dict  # calibration split: conf/correct/labels/preds
    test: dict  # test split: frames/labels/video_id
    fast_params_fp: dict = None  # unquantized fast model (Compress baseline)

    def fast_forward(self, images):
        h = api.build(FAST_CFG, ParallelPlan(remat=False))
        return h.forward(self.fast_params, images)

    def slow_forward(self, images):
        h = api.build(SLOW_CFG, ParallelPlan(remat=False))
        return h.forward(self.slow_params, images)


def _train_tier(cfg: ResNetConfig, data, n_steps: int, lr: float, seed: int, *, res_augment: bool = False):
    h = api.build(cfg, ParallelPlan(remat=False))
    params = h.init(jax.random.PRNGKey(seed), dtype=jnp.float32)
    from repro.data.pipeline import image_batch_fn

    base_fn = image_batch_fn(data)
    if res_augment:
        # the server model sees degraded uploads in deployment (paper Fig 10):
        # train it resolution-robust by randomly degrading half of each batch
        from repro.core.cascade import degrade_resolution

        def batch_fn(rng, idx):
            b = base_fn(rng, idx)
            imgs = jnp.asarray(b["images"])
            r = RESOLUTIONS[int(rng.integers(len(RESOLUTIONS)))]
            n_aug = len(idx) // 2
            aug = degrade_resolution(imgs[:n_aug], r)
            return {"images": np.concatenate([np.asarray(aug), np.asarray(imgs[n_aug:])]),
                    "labels": b["labels"]}
    else:
        batch_fn = base_fn

    pipe = DeterministicPipeline(PipelineConfig(global_batch=128, seed=seed), batch_fn, len(data["labels"]))
    tcfg = TrainConfig(n_steps=n_steps, ckpt_every=10**9, ckpt_dir=f"/tmp/bench_ckpt_{cfg.name}",
                       log_every=max(n_steps // 4, 1), ocfg=optim.OptimConfig(lr=lr, weight_decay=1e-4))
    trainer = Trainer(tcfg, lambda p, b: h.loss(p, b), params, pipe)
    trainer.run(start_step=0)
    return trainer.state["params"]


def _accuracy(forward, params, frames, labels, bs=256):
    correct = 0
    logits_all = []
    for i in range(0, len(labels), bs):
        lg = forward(params, jnp.asarray(frames[i : i + bs]))
        logits_all.append(np.asarray(lg))
        correct += int((np.argmax(np.asarray(lg), -1) == labels[i : i + bs]).sum())
    return correct / len(labels), np.concatenate(logits_all)


def build_stack(force: bool = False, verbose: bool = True) -> TierStack:
    if os.path.exists(CACHE) and not force:
        with open(CACHE, "rb") as f:
            return pickle.load(f)

    os.makedirs(os.path.dirname(CACHE), exist_ok=True)
    train = make_dataset(DATA_CFG, 360, seed=0)
    calib_d = make_dataset(DATA_CFG, 120, seed=1)
    test = make_dataset(DATA_CFG, 120, seed=2)

    if verbose:
        print("[common] training slow tier ...", flush=True)
    slow_params = _train_tier(SLOW_CFG, train, n_steps=700, lr=3e-3, seed=0, res_augment=True)
    if verbose:
        print("[common] training fast tier ...", flush=True)
    fast_params_fp = _train_tier(FAST_CFG, train, n_steps=500, lr=4e-3, seed=1)
    fast_params = qdq_tree(fast_params_fp, **NPU_QUANT)  # "NPU" numerics

    fh = api.build(FAST_CFG, ParallelPlan(remat=False))
    sh = api.build(SLOW_CFG, ParallelPlan(remat=False))

    acc_fast, fast_logits = _accuracy(fh.forward, fast_params, calib_d["frames"], calib_d["labels"])
    acc_slow, _ = _accuracy(sh.forward, slow_params, calib_d["frames"], calib_d["labels"])

    conf = np.asarray(max_softmax(jnp.asarray(fast_logits)))
    preds = np.argmax(fast_logits, -1)
    correct = (preds == calib_d["labels"]).astype(float)
    platt = PlattCalibrator.fit(conf, correct)

    # server accuracy per resolution (paper Fig. 10) on the calib split
    from repro.core.cascade import degrade_resolution

    acc_by_res = []
    for r in RESOLUTIONS:
        acc_r = 0
        n = len(calib_d["labels"])
        for i in range(0, n, 256):
            imgs = degrade_resolution(jnp.asarray(calib_d["frames"][i : i + 256]), r)
            lg = sh.forward(slow_params, imgs)
            acc_r += int((np.argmax(np.asarray(lg), -1) == calib_d["labels"][i : i + 256]).sum())
        acc_by_res.append(acc_r / n)

    stack = TierStack(
        fast_params=fast_params,
        slow_params=slow_params,
        platt=platt,
        acc_fast=acc_fast,
        acc_slow=acc_slow,
        acc_server_by_res=tuple(acc_by_res),
        calib={"conf": conf, "correct": correct, "logits": fast_logits, "labels": calib_d["labels"]},
        test=test,
        fast_params_fp=fast_params_fp,
    )
    with open(CACHE, "wb") as f:
        pickle.dump(stack, f)
    if verbose:
        print(f"[common] fast(int8)={acc_fast:.3f} slow={acc_slow:.3f} acc_by_res={np.round(acc_by_res,3)}", flush=True)
    return stack


def out_path(name: str) -> str:
    d = os.path.join(os.path.dirname(__file__), "..", "results", "bench")
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, name)


def provenance() -> dict:
    """Reproducibility block attached to every bench artifact: where and
    when the numbers came from.  Every probe is guarded — a missing git
    checkout or jax install degrades to ``None``, never an exception."""
    import datetime
    import subprocess

    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        sha = None
    try:
        import jax

        jax_version = jax.__version__
        devices = jax.device_count()
    except Exception:
        jax_version, devices = None, None
    return {
        "git_sha": sha,
        "timestamp_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "numpy": np.__version__,
        "jax": jax_version,
        "devices": devices,
    }


def emit_bench_json(name: str, payload: dict, *, mirror: str = None) -> str:
    """Single emission point for benchmark artifacts under ``results/bench/``.

    Every ``BENCH_*.json`` goes through here so the artifacts share one
    serialization policy (indent=2, trailing newline, numpy scalars coerced
    to plain floats) and one ``provenance`` block (git sha, UTC timestamp,
    library versions, device count).  When the process-wide default phase
    profiler (``repro.obs.profile.DEFAULT``) holds samples, its summary is
    attached under ``"profile"``.  ``mirror`` writes the same payload under
    a second name — used by benches that keep a legacy filename alongside
    the canonical ``BENCH_*`` one.  Returns the primary path.
    """
    payload = dict(payload)
    payload.setdefault("provenance", provenance())
    try:
        from repro.obs.profile import DEFAULT

        if DEFAULT and "profile" not in payload:
            payload["profile"] = DEFAULT.summarize()
    except ImportError:
        pass
    path = out_path(name)
    for p in (path,) + ((out_path(mirror),) if mirror else ()):
        with open(p, "w") as f:
            json.dump(payload, f, indent=2, default=float)
            f.write("\n")
    return path
