"""Micro-benchmark: tuple-chain reference vs vectorized frontier `cbo_plan`.

The serving loop re-plans every frame, so planner wall time is control-plane
latency.  Benchmarks the paper's Algorithm 1 at backlog k=64, m=5 in the
regime where such a backlog actually accumulates (frames arriving faster
than the deadline window drains, saturated uplink), plus lighter regimes,
and records old-vs-new wall time + speedup.  Run directly or via
``benchmarks/run.py``.
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.policy import Env, Frame
from repro.policy.frontier import cbo_plan
from repro.policy.reference import cbo_plan_reference


def make_instance(k: int, m: int, *, fps: float, deadline: float,
                  bandwidth: float, seed: int = 0):
    rng = np.random.default_rng(seed)
    sizes_base = np.sort(rng.uniform(2e3, 6e4, size=m))
    frames = [Frame(arrival=i / fps, conf=float(rng.uniform(0.2, 0.99)),
                    sizes=tuple(sizes_base * rng.uniform(0.8, 1.2)))
              for i in range(k)]
    env = Env(bandwidth=bandwidth, latency=0.03, server_time=0.037,
              deadline=deadline, acc_server=tuple(np.sort(rng.uniform(0.6, 0.99, size=m))))
    return frames, env


def _time(fn, frames, env, repeats: int) -> float:
    fn(frames, env)  # warm-up
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(frames, env)
        best = min(best, time.perf_counter() - t0)
    return best


SCENARIOS = (
    # (name, k, m, fps, deadline) — "deep" is the acceptance regime
    ("deep_backlog_k64", 64, 5, 120.0, 1.0),
    ("mid_backlog_k32", 32, 5, 60.0, 0.5),
    ("shallow_k8", 8, 5, 30.0, 0.2),
)


def run(repeats: int = 15) -> dict:
    rows = []
    for name, k, m, fps, deadline in SCENARIOS:
        frames, env = make_instance(k, m, fps=fps, deadline=deadline, bandwidth=1.5e6)
        a = cbo_plan_reference(frames, env)
        b = cbo_plan(frames, env)
        assert a.offloads == b.offloads and a.total_gain == b.total_gain, name
        t_ref = _time(cbo_plan_reference, frames, env, repeats)
        t_vec = _time(cbo_plan, frames, env, repeats)
        row = {"scenario": name, "k": k, "m": m,
               "ref_us": round(t_ref * 1e6, 1), "vec_us": round(t_vec * 1e6, 1),
               "speedup": round(t_ref / t_vec, 2), "n_offloads": len(b.offloads)}
        rows.append(row)
        print(f"bench_policy_planner,{name},ref_us={row['ref_us']},"
              f"vec_us={row['vec_us']},speedup={row['speedup']}", flush=True)
    from benchmarks.common import emit_bench_json

    out = {"rows": rows}
    emit_bench_json("BENCH_planner.json", out, mirror="policy_planner.json")
    return out


if __name__ == "__main__":
    run()
