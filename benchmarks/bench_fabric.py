"""Edge fabric: where does the fleet collapse, and how do cells/replicas move it?

The single-uplink sweeps (``bench_multistream``) show the N=64+ collapse:
per-stream offloads starve once one serial link and one implicit server
saturate.  This bench puts the same fleet behind an ``EdgeFabric``
(``src/repro/net/``) and sweeps the topology instead of the fleet:

  * **replica sweep** — S fixed (default 256), slow tier sharded across
    K ∈ {1, 2, 4, 8} serial replicas: the *contention-collapse point* —
    the smallest fleet size whose deadline-miss fraction crosses the
    collapse threshold — moves up monotonically with K;
  * **cell sweep** — streams partitioned across C ∈ {1, 2, 4} cells (one
    serial uplink each, same per-cell rate): aggregate radio capacity
    scales with C and the collapse point moves the same way;
  * **placement column** — round_robin / jsq / least_land at the largest
    sweep point, showing queue-aware placement's margin on tail latency.

``--smoke`` is the CI gate: asserts (1) the degenerate fabric (1 cell,
1 replica, constant bandwidth) reproduces ``tests/data/
multistream_snapshot.json`` bit-for-bit through the fabric code path, and
(2) batched ``Placement.assign`` equals the looped per-row reference for
every policy.

  PYTHONPATH=src:benchmarks python benchmarks/bench_fabric.py
  PYTHONPATH=src:benchmarks python benchmarks/bench_fabric.py --smoke
  PYTHONPATH=src:benchmarks python benchmarks/bench_fabric.py --replicas 1,4 --cells 1,2
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.serving.synthetic import synthetic_streams, synthetic_tiers  # noqa: E402

REPLICA_COUNTS = (1, 2, 4, 8)
CELL_COUNTS = (1, 2, 4)
FLEET_SIZES = (16, 32, 64, 128, 256)
COLLAPSE_MISS_FRAC = 0.05  # a fleet has collapsed when >5% of frames miss


def synthetic_cfg(args):
    from repro.core.netsim import png_size_model
    from repro.serving import ServeConfig

    # same scaling as bench_multistream: make the 8-px synthetic frames carry
    # full-frame bytes so the shared resources actually contend
    return ServeConfig(
        deadline=args.deadline, frame_rate=args.fps, batch_size=16,
        resolutions=(4, 8), acc_server=(0.9, 0.99),
        server_time=args.server_time,
        size_of=lambda r: png_size_model(r, base_res=16),
    )


def build_fabric(args, cfg, S, n_cells, n_replicas, placement="round_robin",
                 bw_mbps=None, het_replicas=False):
    from repro.core.netsim import Uplink, mbps
    from repro.net import EdgeFabric, ReplicaPool

    bw = mbps(args.bw if bw_mbps is None else bw_mbps)
    if not het_replicas:
        return EdgeFabric.build(
            n_streams=S, n_cells=n_cells, n_replicas=n_replicas,
            bandwidth_bps=bw, latency=args.latency,
            server_time=cfg.server_time, placement=placement,
            seed=args.seed, serial_replicas=True)
    # heterogeneous slow tier: service times spread geometrically over
    # [st/2, 2*st] — the regime where least_land and jsq actually differ
    st = cfg.server_time * np.geomspace(0.5, 2.0, n_replicas)
    ups = [Uplink(bandwidth_bps=bw, latency=args.latency,
                  server_time=cfg.server_time, seed=args.seed + c)
           for c in range(n_cells)]
    return EdgeFabric(ups, ReplicaPool(n_replicas, st), n_streams=S,
                      placement=placement)


def run_point(args, cfg, S, n_cells, n_replicas, placement="round_robin",
              bw_mbps=None, het_replicas=False):
    from repro.serving import FairScheduler, MultiStreamServer

    fast, slow, calibrate = synthetic_tiers()
    frames, labels = synthetic_streams(S, args.frames, seed=args.seed)
    fab = build_fabric(args, cfg, S, n_cells, n_replicas, placement,
                       bw_mbps=bw_mbps, het_replicas=het_replicas)
    srv = MultiStreamServer(cfg, fast, slow, calibrate, None, n_streams=S,
                            scheduler=FairScheduler(args.scheduler), fabric=fab)
    m = srv.process_streams(frames, labels)
    s = m.summary()
    return {
        "n_streams": S, "cells": n_cells, "replicas": n_replicas,
        "placement": placement,
        "accuracy": s["accuracy"], "offload_frac": s["offload_frac"],
        "deadline_miss_frac": s["deadline_miss_frac"],
        "p99_latency_ms": s["p99_latency_ms"],
        "offload_fairness": s["offload_fairness"],
        "replica_queued_s": round(float(fab.pool.queued_seconds.sum()), 2),
        "cell_queued_s": round(float(sum(c.uplink.queued_seconds for c in fab.cells)), 2),
    }


def collapse_point(rows):
    """Smallest fleet size whose miss fraction crosses the threshold
    (None = never collapsed within the sweep)."""
    for r in rows:
        if r["deadline_miss_frac"] > COLLAPSE_MISS_FRAC:
            return r["n_streams"]
    return None


def run(args=None) -> dict:
    if args is None:
        args = parse_args([])
    cfg = synthetic_cfg(args)

    out = {"config": {"bw_mbps": args.bw, "latency": args.latency, "fps": args.fps,
                      "deadline": args.deadline, "frames": args.frames,
                      "server_time": args.server_time, "scheduler": args.scheduler},
           "replica_sweep": [], "cell_sweep": [], "placement": []}

    # -- replica sweep: collapse point vs K (C fixed at 1) ----------------- #
    for K in args.replicas:
        rows = [run_point(args, cfg, S, 1, K) for S in args.fleets]
        cp = collapse_point(rows)
        out["replica_sweep"].append({"replicas": K, "collapse_at": cp, "rows": rows})
        for r in rows:
            print("bench_fabric,sweep=replica," +
                  ",".join(f"{k}={v}" for k, v in r.items()), flush=True)
        print(f"bench_fabric,replicas={K},collapse_at={cp}", flush=True)

    # -- cell sweep: collapse point vs C (K fixed at max sweep value, and a
    # lower per-cell rate so the *radio*, not the slow tier, binds) -------- #
    K = max(args.replicas)
    for C in args.cells:
        rows = [run_point(args, cfg, S, C, K, bw_mbps=args.cell_bw)
                for S in args.fleets]
        cp = collapse_point(rows)
        out["cell_sweep"].append({"cells": C, "collapse_at": cp, "rows": rows})
        for r in rows:
            print("bench_fabric,sweep=cell," +
                  ",".join(f"{k}={v}" for k, v in r.items()), flush=True)
        print(f"bench_fabric,cells={C},replicas={K},collapse_at={cp}", flush=True)

    # -- placement shoot-out: heterogeneous replicas at the hottest point -- #
    S = max(args.fleets)
    for pol in ("round_robin", "jsq", "least_land"):
        r = run_point(args, cfg, S, max(args.cells), K, placement=pol,
                      het_replicas=True)
        out["placement"].append(r)
        print("bench_fabric,sweep=placement," +
              ",".join(f"{k}={v}" for k, v in r.items()), flush=True)

    # monotonicity headline: more replicas never lowers successful offloads
    # at the largest fleet, and the collapse point never moves down
    heads = [next(r for r in e["rows"] if r["n_streams"] == max(args.fleets))
             for e in out["replica_sweep"]]
    out["monotone_offload_at_max_fleet"] = all(
        b["offload_frac"] >= a["offload_frac"] - 1e-9
        for a, b in zip(heads, heads[1:]))
    print("bench_fabric,monotone_offload_at_max_fleet="
          f"{out['monotone_offload_at_max_fleet']}", flush=True)

    from benchmarks.common import emit_bench_json

    # machine-readable CI name + legacy sweep filename
    emit_bench_json("BENCH_fabric.json", out, mirror="fabric_sweep.json")
    return out


# ---------------------------- smoke (CI gate) ------------------------------ #


def smoke() -> None:
    from repro.core.netsim import Uplink, mbps
    from repro.net import EdgeFabric, Placement, ReplicaPool, assign_looped
    from repro.serving import MultiStreamServer, ServeConfig

    # 1) degenerate fabric reproduces the recorded snapshot bit-for-bit
    here = os.path.dirname(os.path.abspath(__file__))
    with open(os.path.join(here, "..", "tests", "data", "multistream_snapshot.json")) as f:
        snapshot = json.load(f)
    fast, slow, cal = synthetic_tiers()
    cfg = ServeConfig(resolutions=(4, 8), acc_server=(0.7, 0.99), batch_size=16,
                      frame_rate=30.0, deadline=0.2)
    imgs, labels = synthetic_streams(4, 64)
    up = Uplink(bandwidth_bps=mbps(50.0), latency=0.05, server_time=cfg.server_time)
    fab = EdgeFabric.degenerate(up, n_streams=4)
    agg = MultiStreamServer(cfg, fast, slow, cal, None, n_streams=4,
                            fabric=fab).process_streams(imgs, labels)
    for m, ref in zip(agg.per_stream, snapshot["per_stream"]):
        assert m.accuracy == ref["accuracy"], (m.accuracy, ref["accuracy"])
        assert m.offload_frac == ref["offload_frac"]
        assert m.deadline_miss_frac == ref["deadline_miss_frac"]
    assert agg.n_offloaded == snapshot["n_offloaded"]
    print("bench_fabric,smoke=degenerate_snapshot,status=ok", flush=True)

    # 2) batched placement == looped reference, every policy
    rng = np.random.default_rng(0)
    for pol in ("round_robin", "jsq", "least_land"):
        for trial in range(10):
            K = int(rng.integers(1, 6))
            pool = ReplicaPool(K, rng.uniform(0.01, 0.2, K))
            pool.busy_until[:] = rng.uniform(0, 0.5, K)
            arrive = rng.uniform(0, 2, int(rng.integers(0, 40)))
            got = Placement(pol).assign(pool, arrive)
            want = assign_looped(pol, pool, arrive)
            assert np.array_equal(got, want), (pol, trial)
    print("bench_fabric,smoke=placement_equivalence,status=ok", flush=True)


def parse_args(argv=None):
    csv = lambda s: tuple(int(x) for x in s.split(","))
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fleets", type=csv, default=FLEET_SIZES,
                    help="comma-separated fleet sizes per sweep point")
    ap.add_argument("--replicas", type=csv, default=REPLICA_COUNTS)
    ap.add_argument("--cells", type=csv, default=CELL_COUNTS)
    ap.add_argument("--frames", type=int, default=128, help="frames per stream")
    ap.add_argument("--bw", type=float, default=80.0,
                    help="per-cell uplink Mbps (replica sweep: radio "
                         "overprovisioned so the slow tier binds)")
    ap.add_argument("--cell-bw", type=float, default=4.0,
                    help="per-cell uplink Mbps for the cell sweep (radio "
                         "scarce so the cell count binds)")
    ap.add_argument("--latency", type=float, default=0.05)
    ap.add_argument("--fps", type=float, default=30.0)
    ap.add_argument("--deadline", type=float, default=0.2)
    ap.add_argument("--server-time", type=float, default=0.020,
                    help="per-replica service time (s)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scheduler", choices=("round_robin", "fifo"), default="round_robin")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: degenerate==snapshot + placement equivalence")
    return ap.parse_args(argv)


if __name__ == "__main__":
    args = parse_args()
    if args.smoke:
        smoke()
    else:
        run(args)
