"""Kernel micro-benchmarks: correctness (interpret) + CPU-reference timings.

Wall-clock here times the jnp reference path (the Pallas kernels target TPU;
interpret mode is a correctness tool, not a perf path). The derived column
reports the ideal v5e kernel time from the roofline model for context.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import out_path
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.int8_matmul import ref as i8ref
from repro.kernels.fused_calib_gate.ref import calib_gate_ref
from repro.launch.roofline import HBM_BW, PEAK_FLOPS_BF16, PEAK_FLOPS_INT8


def _time(fn, *args, n=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def run() -> dict:
    rows = []

    M, K, N = 1024, 4096, 4096
    x = jax.random.normal(jax.random.PRNGKey(0), (M, K), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32)
    t = _time(jax.jit(i8ref.matmul_ref), x, w)
    ideal = 2 * M * K * N / PEAK_FLOPS_INT8
    rows.append({"kernel": "int8_matmul_ref", "shape": f"{M}x{K}x{N}",
                 "us_per_call": round(t * 1e6, 1), "v5e_ideal_us": round(ideal * 1e6, 2)})

    B, S, H, D = 2, 2048, 8, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, D), jnp.bfloat16)
    t = _time(jax.jit(lambda q: attention_ref(q, q, q, causal=True)), q)
    flops = 4 * B * H * S * S * D / 2
    rows.append({"kernel": "flash_attention_ref", "shape": f"b{B}s{S}h{H}d{D}",
                 "us_per_call": round(t * 1e6, 1), "v5e_ideal_us": round(flops / PEAK_FLOPS_BF16 * 1e6, 2)})

    Bv, V = 256, 102_400
    lg = jax.random.normal(jax.random.PRNGKey(0), (Bv, V), jnp.float32)
    t = _time(jax.jit(lambda l: calib_gate_ref(l, -6.0, 2.0, 0.7)), lg)
    ideal = Bv * V * 4 / HBM_BW  # memory-bound single pass
    rows.append({"kernel": "fused_calib_gate_ref", "shape": f"{Bv}x{V}",
                 "us_per_call": round(t * 1e6, 1), "v5e_ideal_us": round(ideal * 1e6, 2)})

    with open(out_path("kernels_micro.json"), "w") as f:
        json.dump(rows, f, indent=2)
    for r in rows:
        print(f"bench_kernels/{r['kernel']},us_per_call={r['us_per_call']},derived=v5e_ideal_us:{r['v5e_ideal_us']}")
    return {"rows": rows}


if __name__ == "__main__":
    run()
