"""Kernel micro-benchmarks: correctness (interpret) + CPU-reference timings.

Wall-clock here times the jnp reference path (the Pallas kernels target TPU;
interpret mode is a correctness tool, not a perf path). The derived column
reports the ideal v5e kernel time from the roofline model for context.

``--batch-sweep`` additionally times a slow-tier-shaped forward pass
(flash-attention + int8 matmul at small serving shapes) across batch
sizes and fits the f(batch) latency curves from ``repro.slowtier.calibrate``
to the measurements — the calibration source for ``ContinuousBatching``
(docs/network.md has the recipe).  The winning fit lands in
``results/bench/BENCH_kernels.json`` under ``batch_fit``, ready for
``bench_slowtier.py --coeffs-from``.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit_bench_json, out_path
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.int8_matmul import ref as i8ref
from repro.kernels.fused_calib_gate.ref import calib_gate_ref
from repro.launch.roofline import HBM_BW, PEAK_FLOPS_BF16, PEAK_FLOPS_INT8


def _time(fn, *args, n=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


# batch-sweep shapes: one "request" is a small serving-sized forward slice
# (seq=256 attention + a 512x512 projection); batch stacks requests along
# the leading axis exactly the way a continuous-batching replica would
BATCH_SIZES = (1, 2, 4, 8, 16, 32)
SWEEP_S, SWEEP_H, SWEEP_D = 256, 4, 64
SWEEP_ROWS, SWEEP_K, SWEEP_N = 32, 512, 512


def batch_sweep(n_timing: int = 5) -> dict:
    """Time f(batch) on the reference tiers and fit the latency curves."""
    from repro.slowtier import fit_latency_model, model_coeffs

    attn = jax.jit(lambda q: attention_ref(q, q, q, causal=True))
    mm = jax.jit(i8ref.matmul_ref)
    rows = []
    for b in BATCH_SIZES:
        q = jax.random.normal(jax.random.PRNGKey(0),
                              (b, SWEEP_S, SWEEP_H, SWEEP_D), jnp.bfloat16)
        x = jax.random.normal(jax.random.PRNGKey(0),
                              (b * SWEEP_ROWS, SWEEP_K), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1),
                              (SWEEP_K, SWEEP_N), jnp.float32)
        t_attn = _time(attn, q, n=n_timing)
        t_mm = _time(mm, x, w, n=n_timing)
        rows.append({"batch": b, "attn_us": round(t_attn * 1e6, 1),
                     "matmul_us": round(t_mm * 1e6, 1),
                     "total_s": t_attn + t_mm})
    ns = np.array([r["batch"] for r in rows], dtype=np.float64)
    ys = np.array([r["total_s"] for r in rows])
    fits = {}
    for kind in ("flat", "linear", "step"):
        model, rmse = fit_latency_model(ns, ys, kind=kind)
        k, coeffs = model_coeffs(model)
        fits[kind] = {"kind": k, "coeffs": [float(c) for c in coeffs],
                      "rmse_us": round(rmse * 1e6, 2)}
    best_kind = min(fits, key=lambda k: fits[k]["rmse_us"])
    out = {"batch_sizes": list(BATCH_SIZES), "rows": rows,
           "fits": fits, "batch_fit": fits[best_kind]}
    for r in rows:
        print(f"bench_kernels/batch_sweep,batch={r['batch']},"
              f"attn_us={r['attn_us']},matmul_us={r['matmul_us']},"
              f"total_us={round(r['total_s'] * 1e6, 1)}")
    print(f"bench_kernels/batch_fit,kind={best_kind},"
          f"coeffs={fits[best_kind]['coeffs']},"
          f"rmse_us={fits[best_kind]['rmse_us']}")
    return out


def run(args=None) -> dict:
    rows = []

    M, K, N = 1024, 4096, 4096
    x = jax.random.normal(jax.random.PRNGKey(0), (M, K), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32)
    t = _time(jax.jit(i8ref.matmul_ref), x, w)
    ideal = 2 * M * K * N / PEAK_FLOPS_INT8
    rows.append({"kernel": "int8_matmul_ref", "shape": f"{M}x{K}x{N}",
                 "us_per_call": round(t * 1e6, 1), "v5e_ideal_us": round(ideal * 1e6, 2)})

    B, S, H, D = 2, 2048, 8, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, D), jnp.bfloat16)
    t = _time(jax.jit(lambda q: attention_ref(q, q, q, causal=True)), q)
    flops = 4 * B * H * S * S * D / 2
    rows.append({"kernel": "flash_attention_ref", "shape": f"b{B}s{S}h{H}d{D}",
                 "us_per_call": round(t * 1e6, 1), "v5e_ideal_us": round(flops / PEAK_FLOPS_BF16 * 1e6, 2)})

    Bv, V = 256, 102_400
    lg = jax.random.normal(jax.random.PRNGKey(0), (Bv, V), jnp.float32)
    t = _time(jax.jit(lambda l: calib_gate_ref(l, -6.0, 2.0, 0.7)), lg)
    ideal = Bv * V * 4 / HBM_BW  # memory-bound single pass
    rows.append({"kernel": "fused_calib_gate_ref", "shape": f"{Bv}x{V}",
                 "us_per_call": round(t * 1e6, 1), "v5e_ideal_us": round(ideal * 1e6, 2)})

    with open(out_path("kernels_micro.json"), "w") as f:
        json.dump(rows, f, indent=2)
    for r in rows:
        print(f"bench_kernels/{r['kernel']},us_per_call={r['us_per_call']},derived=v5e_ideal_us:{r['v5e_ideal_us']}")

    out = {"rows": rows}
    if args is not None and args.batch_sweep:
        out.update(batch_sweep(n_timing=args.timing_reps))
    emit_bench_json("BENCH_kernels.json", out)
    return out


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--batch-sweep", action="store_true",
                    help="also sweep f(batch) and fit the slow-tier curves")
    ap.add_argument("--timing-reps", type=int, default=5)
    return ap.parse_args(argv)


if __name__ == "__main__":
    run(parse_args())
