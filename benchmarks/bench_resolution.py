"""Paper Fig. 10: slow-tier accuracy vs offload resolution ladder."""
from __future__ import annotations

import json

from benchmarks.common import RESOLUTIONS, build_stack, out_path


def run() -> dict:
    stack = build_stack()
    rows = [{"resolution": r, "accuracy": round(a, 4)}
            for r, a in zip(RESOLUTIONS, stack.acc_server_by_res)]
    out = {"ladder": rows, "fast_tier_acc": stack.acc_fast, "slow_tier_acc": stack.acc_slow}
    with open(out_path("fig10_resolution.json"), "w") as f:
        json.dump(out, f, indent=2)
    for r in rows:
        print(f"bench_resolution,res={r['resolution']},acc={r['accuracy']}")
    # monotone non-decreasing ladder is the paper's premise
    accs = [r["accuracy"] for r in rows]
    assert all(b >= a - 0.03 for a, b in zip(accs, accs[1:])), accs
    return out


if __name__ == "__main__":
    run()
