"""Paper Fig. 5 (uncalibrated) + Fig. 7b (calibrated): accuracy vs confidence
bins — the reliability diagram that motivates calibration."""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import build_stack, out_path
from repro.core.calibration import reliability_bins


def run() -> dict:
    stack = build_stack()
    conf, correct = stack.calib["conf"], stack.calib["correct"]
    cal = np.asarray(stack.platt(conf))

    def bins(c):
        count, acc, mean_conf = reliability_bins(c, correct, 10)
        return [{"bin": i, "count": int(count[i]), "accuracy": round(float(acc[i]), 4),
                 "mean_conf": round(float(mean_conf[i]), 4)} for i in range(10)]

    out = {"uncalibrated_fig5": bins(conf), "calibrated_fig7b": bins(cal)}

    # paper claim: calibrated accuracy spans a much wider range across bins
    def span(rows):
        a = [r["accuracy"] for r in rows if r["count"] > 5]
        return (max(a) - min(a)) if a else 0.0

    out["span_uncalibrated"] = round(span(out["uncalibrated_fig5"]), 4)
    out["span_calibrated"] = round(span(out["calibrated_fig7b"]), 4)
    with open(out_path("fig5_7b_reliability.json"), "w") as f:
        json.dump(out, f, indent=2)
    print(f"bench_reliability/span,uncal={out['span_uncalibrated']},cal={out['span_calibrated']}")
    return out


if __name__ == "__main__":
    run()
