"""CI smoke: run EVERY registered policy through the unified replay engine.

Guards the registry against silently-broken entries: each policy must
construct via ``make_policy(name)``, replay a tiny synthetic trace without
raising, and return sane results.  Pure numpy + the policy plane — no JAX,
no model training — so it runs in seconds on a CI box.

  PYTHONPATH=src python benchmarks/smoke_policies.py
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.policy import Env, available_policies, make_policy, replay_trace


def tiny_trace(n: int = 90, m: int = 3, seed: int = 0):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 5, size=n)
    local_pred = np.where(rng.uniform(size=n) < 0.6, labels, (labels + 1) % 5)
    slow_pred = np.stack([np.where(rng.uniform(size=n) < acc, labels, (labels + 2) % 5)
                          for acc in (0.7, 0.8, 0.9)])
    conf = rng.uniform(0.3, 0.99, size=n)
    sizes = [2e3, 8e3, 2e4]
    env = Env(bandwidth=5e5, latency=0.05, server_time=0.037, deadline=0.25,
              acc_server=(0.65, 0.78, 0.88))
    return labels, local_pred, slow_pred, conf, sizes, env


# registry entries that need constructor arguments in a live deployment get
# them here; everything else must work with defaults
POLICY_CFG = {"server": dict(frame_interval=1.0 / 30.0),
              "greedy-rate": dict(local_acc=0.6),
              "threshold": dict(theta=0.6)}


def main() -> int:
    labels, local_pred, slow_pred, conf, sizes, env = tiny_trace()
    failures = []
    for name in available_policies():
        try:
            policy = make_policy(name, **POLICY_CFG.get(name, {}))
            result = replay_trace(policy, conf=conf, slow_pred=slow_pred, sizes=sizes,
                                  env=env, frame_interval=1.0 / 30.0,
                                  local_pred=local_pred,
                                  window=30 if name == "optimal" else 0)
            acc = result.accuracy(labels)
            assert 0.0 <= acc <= 1.0
            assert len(result.results) == len(labels)
            print(f"smoke_policies,{name},acc={acc:.4f},"
                  f"offloaded={result.n_offloaded},late={result.n_late}", flush=True)
        except Exception as e:  # noqa: BLE001 — report every broken entry
            failures.append((name, repr(e)))
            print(f"smoke_policies,{name},FAILED: {e!r}", flush=True)
    if failures:
        print(f"{len(failures)} broken registry entries: {[n for n, _ in failures]}")
        return 1
    print(f"all {len(available_policies())} registered policies replay cleanly")
    return 0


if __name__ == "__main__":
    sys.exit(main())
