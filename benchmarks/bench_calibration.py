"""Paper Table I: ECE/MCE for uncalibrated vs Platt vs Isotonic
(+temperature scaling as a beyond-paper extra)."""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import build_stack, out_path
from repro.core.calibration import IsotonicCalibrator, PlattCalibrator, TemperatureCalibrator, ece, mce


def run() -> dict:
    stack = build_stack()
    conf, correct = stack.calib["conf"], stack.calib["correct"]
    logits, labels = stack.calib["logits"], stack.calib["labels"]
    # fit on one half, evaluate on the other (holdout, as deployed)
    n = len(conf) // 2
    platt = PlattCalibrator.fit(conf[:n], correct[:n])
    iso = IsotonicCalibrator.fit(conf[:n], correct[:n])
    temp = TemperatureCalibrator.fit(logits[:n], labels[:n])

    rows = {}
    rows["uncalibrated"] = {"ece": ece(conf[n:], correct[n:]), "mce": mce(conf[n:], correct[n:])}
    rows["platt"] = {"ece": ece(np.asarray(platt(conf[n:])), correct[n:]),
                     "mce": mce(np.asarray(platt(conf[n:])), correct[n:])}
    rows["isotonic"] = {"ece": ece(np.asarray(iso(conf[n:])), correct[n:]),
                        "mce": mce(np.asarray(iso(conf[n:])), correct[n:])}
    rows["temperature"] = {"ece": ece(np.asarray(temp(logits[n:])), correct[n:]),
                           "mce": mce(np.asarray(temp(logits[n:])), correct[n:])}
    out = {"table": rows, "paper": {"uncalibrated": {"ece": 0.27, "mce": 0.48},
                                    "platt": {"ece": 0.07, "mce": 0.29},
                                    "isotonic": {"ece": 0.16, "mce": 0.41}}}
    with open(out_path("table1_calibration.json"), "w") as f:
        json.dump(out, f, indent=2)
    for k, v in rows.items():
        print(f"bench_calibration/{k},ece={v['ece']:.4f},mce={v['mce']:.4f}")
    return out


if __name__ == "__main__":
    run()
