"""Continuous-batching slow tier: where does batching move the collapse point?

``bench_fabric`` shows the contention-collapse point — the smallest fleet
whose deadline-miss fraction crosses 5% — moving right with more replicas
and cells.  This bench holds the topology fixed and changes the *replica
service discipline* instead (``src/repro/slowtier/``): each replica runs
TGI-style continuous batching with an admission window, and the batch
cost follows a calibrated latency curve f(n) = base + per_item*n instead
of the paper's constant T^o.  Because the marginal item cost is far below
the flat service time, a replica that coalesces its queue into batches
serves a congested fleet at a multiple of its serial throughput — so the
collapse point moves right, further with a longer admission window:

  * **window sweep** — K ∈ {1, 2, 4} serial replicas × admission window
    ∈ {none, 0 ms, 5 ms, 20 ms} ("none" = the serial FlatService
    baseline), fleet sizes swept until collapse;
  * the headline assertion: at every K, every batching column's collapse
    point is >= the FlatService baseline's.

The batching curve defaults to ``LinearBatch(base, per_item)`` with
coefficients matched to the sweep's ``--server-time`` (f(1) ~= T^o, so
an idle fleet behaves like the paper's model and only congestion changes
anything).  ``--coeffs-from`` loads coefficients fitted by
``bench_kernels.py --batch-sweep`` (results/bench/BENCH_kernels.json)
instead — the calibration recipe in docs/network.md.

``--smoke`` is the CI gate, no sweeps: asserts (1) vectorized
``form_batches`` equals the one-request-at-a-time looped reference
bit-for-bit on seeded fuzz workloads, (2) a *degenerate* batching config
(FlatService, window=0, cap=1) drives ``MultiStreamServer`` to the exact
per-stream metrics of the plain serial ``ReplicaPool``, and (3) that
degenerate path still reproduces ``tests/data/fabric_snapshot.json``
bit-for-bit.

  PYTHONPATH=src:benchmarks python benchmarks/bench_slowtier.py
  PYTHONPATH=src:benchmarks python benchmarks/bench_slowtier.py --smoke
  PYTHONPATH=src:benchmarks python benchmarks/bench_slowtier.py --replicas 1,2 --windows 0,0.02
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.serving.synthetic import synthetic_streams, synthetic_tiers  # noqa: E402

REPLICA_COUNTS = (1, 2, 4)
WINDOWS_S = (0.0, 0.005, 0.020)
FLEET_SIZES = (8, 16, 32, 64, 128, 256)
COLLAPSE_MISS_FRAC = 0.05


def synthetic_cfg(args):
    from repro.core.netsim import png_size_model
    from repro.serving import ServeConfig

    return ServeConfig(
        deadline=args.deadline, frame_rate=args.fps, batch_size=16,
        resolutions=(4, 8), acc_server=(0.9, 0.99),
        server_time=args.server_time,
        size_of=lambda r: png_size_model(r, base_res=16),
    )


def latency_model(args):
    """The batching curve for the sweep: calibrated coefficients when
    ``--coeffs-from`` points at a ``bench_kernels --batch-sweep`` artifact,
    else a LinearBatch anchored at f(1) ~= T^o."""
    from repro.slowtier import LinearBatch, model_from_coeffs

    if args.coeffs_from:
        with open(args.coeffs_from) as f:
            fit = json.load(f)["batch_fit"]
        kind, coeffs = fit["kind"], fit["coeffs"]
        # rescale the kernel-time curve so f(1) lands on the sweep's T^o:
        # the *shape* (marginal item cost vs fixed cost) is the calibrated
        # part; the absolute scale belongs to the simulated server
        m = model_from_coeffs(kind, coeffs)
        scale = args.server_time / float(m.batch_latency(1))
        m = model_from_coeffs(kind, tuple(c * scale for c in coeffs))
        return m, {"kind": kind, "coeffs": [float(c) for c in coeffs],
                   "scale": scale, "source": args.coeffs_from}
    base = args.server_time * 0.8
    per_item = args.server_time * 0.2
    return (LinearBatch(base, per_item),
            {"kind": "linear", "coeffs": [base, per_item], "source": "default"})


def build_fabric(args, cfg, S, n_replicas, batching=None):
    from repro.core.netsim import Uplink, mbps
    from repro.net import EdgeFabric, ReplicaPool

    up = Uplink(bandwidth_bps=mbps(args.bw), latency=args.latency,
                server_time=cfg.server_time, seed=args.seed)
    pool = ReplicaPool(n_replicas, cfg.server_time, serial=True,
                       batching=batching)
    return EdgeFabric([up], pool, n_streams=S, placement="jsq")


def run_point(args, cfg, S, n_replicas, batching=None):
    from repro.serving import FairScheduler, MultiStreamServer

    fast, slow, calibrate = synthetic_tiers()
    frames, labels = synthetic_streams(S, args.frames, seed=args.seed)
    fab = build_fabric(args, cfg, S, n_replicas, batching=batching)
    srv = MultiStreamServer(cfg, fast, slow, calibrate, None, n_streams=S,
                            scheduler=FairScheduler("round_robin"), fabric=fab)
    m = srv.process_streams(frames, labels)
    s = m.summary()
    return {
        "n_streams": S, "replicas": n_replicas,
        "window_ms": None if batching is None else batching.window_s * 1e3,
        "accuracy": s["accuracy"], "offload_frac": s["offload_frac"],
        "deadline_miss_frac": s["deadline_miss_frac"],
        "p99_latency_ms": s["p99_latency_ms"],
        "avg_batch": round(float(fab.pool.avg_batch), 3),
        "replica_queued_s": round(float(fab.pool.queued_seconds.sum()), 2),
    }


def collapse_point(rows):
    for r in rows:
        if r["deadline_miss_frac"] > COLLAPSE_MISS_FRAC:
            return r["n_streams"]
    return None


def run(args=None) -> dict:
    from repro.slowtier import ContinuousBatching, model_coeffs

    if args is None:
        args = parse_args([])
    if args.smoke:
        smoke()
        return {"smoke": "ok"}
    cfg = synthetic_cfg(args)
    model, fit_info = latency_model(args)
    kind, coeffs = model_coeffs(model)

    out = {"config": {"bw_mbps": args.bw, "latency": args.latency,
                      "fps": args.fps, "deadline": args.deadline,
                      "frames": args.frames, "server_time": args.server_time,
                      "model": {"kind": kind, "coeffs": list(coeffs)},
                      "fit": fit_info},
           "window_sweep": []}

    shift_ok = True
    for K in args.replicas:
        cols = []
        # FlatService baseline: the paper's constant-T^o serial replica
        rows = [run_point(args, cfg, S, K) for S in args.fleets]
        base_cp = collapse_point(rows)
        cols.append({"window_ms": None, "collapse_at": base_cp, "rows": rows})
        for w in args.windows:
            b = ContinuousBatching(model, window_s=w, max_batch=args.max_batch)
            rows = [run_point(args, cfg, S, K, batching=b) for S in args.fleets]
            cp = collapse_point(rows)
            cols.append({"window_ms": w * 1e3, "collapse_at": cp, "rows": rows})
            # None = never collapsed in the sweep — treat as +inf
            if (cp or 10**9) < (base_cp or 10**9):
                shift_ok = False
        out["window_sweep"].append({"replicas": K, "columns": cols})
        for c in cols:
            for r in c["rows"]:
                print("bench_slowtier,sweep=window," +
                      ",".join(f"{k}={v}" for k, v in r.items()), flush=True)
            print(f"bench_slowtier,replicas={K},window_ms={c['window_ms']},"
                  f"collapse_at={c['collapse_at']}", flush=True)
    out["collapse_never_moves_left"] = shift_ok
    print(f"bench_slowtier,collapse_never_moves_left={shift_ok}", flush=True)

    from benchmarks.common import emit_bench_json

    emit_bench_json("BENCH_slowtier.json", out)
    return out


# ---------------------------- smoke (CI gate) ------------------------------ #


def smoke() -> None:
    from repro.core.netsim import Uplink, mbps
    from repro.net import EdgeFabric, ReplicaPool
    from repro.serving import MultiStreamServer, ServeConfig
    from repro.slowtier import (ContinuousBatching, FlatService, LinearBatch,
                                StepBatch, form_batches, form_batches_looped)

    # 1) vectorized batch formation == looped reference, bit-for-bit
    rng = np.random.default_rng(0)
    models = [FlatService(0.02), LinearBatch(0.015, 0.004),
              StepBatch(0.01, 0.008, page_size=4, max_pages=3)]
    n_cases = 0
    for trial in range(60):
        n = int(rng.integers(1, 40))
        arr = np.sort(rng.exponential(0.02, size=n).cumsum())
        if rng.random() < 0.3:  # coincident arrivals stress window ties
            arr = np.round(arr, 2)
        cfg_b = ContinuousBatching(
            models[trial % len(models)],
            window_s=float(rng.choice([0.0, 0.005, 0.02])),
            max_batch=int(rng.integers(1, 9)) if rng.random() < 0.5 else None)
        busy0 = float(rng.uniform(0.0, 0.1))
        got = form_batches(arr, cfg_b, busy0=busy0)
        ref = form_batches_looped(arr, cfg_b, busy0=busy0)
        for g, r in zip(got, ref):
            assert np.array_equal(g, r), (trial, cfg_b, arr, got, ref)
        n_cases += 1
    print(f"bench_slowtier,smoke=batch_formation,cases={n_cases},exact=True",
          flush=True)

    # 2) degenerate batching (FlatService, window=0, cap=1) == the plain
    # serial ReplicaPool through the full server, bit-for-bit
    fast, slow, cal = synthetic_tiers()
    cfg = ServeConfig(resolutions=(4, 8), acc_server=(0.7, 0.99), batch_size=16,
                      frame_rate=32.0, deadline=0.2)
    S = 12
    imgs, labels = synthetic_streams(S, 64)
    degen = ContinuousBatching(FlatService(cfg.server_time), window_s=0.0,
                               max_batch=1)
    assert degen.degenerate

    def run_server(batching):
        ups = [Uplink(bandwidth_bps=mbps(50.0 * 0.6), latency=0.05,
                      server_time=cfg.server_time, seed=c)
               for c in range(2)]
        pool = ReplicaPool(2, np.array([cfg.server_time, cfg.server_time * 1.5]),
                           serial=True, batching=batching)
        fab = EdgeFabric(ups, pool, n_streams=S, placement="jsq")
        srv = MultiStreamServer(cfg, fast, slow, cal, None, n_streams=S,
                                fabric=fab)
        return srv.process_streams(imgs, labels), fab

    agg_plain, fab_plain = run_server(None)
    agg_degen, fab_degen = run_server(degen)
    assert agg_plain.accuracy == agg_degen.accuracy
    assert agg_plain.n_offloaded == agg_degen.n_offloaded
    assert agg_plain.n_deadline_miss == agg_degen.n_deadline_miss
    assert np.array_equal(fab_plain.pool.busy_until, fab_degen.pool.busy_until)
    for a, b in zip(agg_plain.per_stream, agg_degen.per_stream):
        assert a.accuracy == b.accuracy and a.offload_frac == b.offload_frac
        assert a.deadline_miss_frac == b.deadline_miss_frac
    print("bench_slowtier,smoke=degenerate_pool,exact=True", flush=True)

    # 3) ... and that degenerate path still pins the recorded golden run
    here = os.path.dirname(os.path.abspath(__file__))
    with open(os.path.join(here, "..", "tests", "data",
                           "fabric_snapshot.json")) as f:
        snap = json.load(f)["fabric"]
    assert agg_degen.accuracy == snap["accuracy"]
    assert int(agg_degen.n_offloaded) == snap["n_offloaded"]
    assert int(agg_degen.n_deadline_miss) == snap["n_deadline_miss"]
    for m, ref in zip(agg_degen.per_stream, snap["per_stream"]):
        assert m.accuracy == ref["accuracy"]
    print("bench_slowtier,smoke=fabric_snapshot,exact=True", flush=True)
    print("bench_slowtier,smoke=ok  (batched==looped; degenerate==serial)")


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fleets", type=lambda s: tuple(int(x) for x in s.split(",")),
                    default=FLEET_SIZES)
    ap.add_argument("--replicas", type=lambda s: tuple(int(x) for x in s.split(",")),
                    default=REPLICA_COUNTS)
    ap.add_argument("--windows", type=lambda s: tuple(float(x) for x in s.split(",")),
                    default=WINDOWS_S, help="admission windows (seconds)")
    ap.add_argument("--max-batch", type=int, default=16,
                    help="occupancy cap per batch")
    ap.add_argument("--bw", type=float, default=80.0, help="uplink Mbps")
    ap.add_argument("--latency", type=float, default=0.05)
    ap.add_argument("--fps", type=float, default=30.0)
    ap.add_argument("--deadline", type=float, default=0.2)
    ap.add_argument("--server-time", type=float, default=0.020,
                    help="flat T^o; the batching curve is anchored at f(1)~=T^o")
    ap.add_argument("--frames", type=int, default=96)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--coeffs-from", type=str, default=None,
                    help="BENCH_kernels.json with a batch_fit entry")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: formation + degenerate-path exactness, no sweeps")
    return ap.parse_args(argv)


if __name__ == "__main__":
    run(parse_args())
