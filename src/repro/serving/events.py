"""Vectorized event model for multi-stream serving.

Two struct-of-arrays event containers replace the per-frame Python loop the
single-stream engine used:

  * ``ArrivalSchedule`` — the (S, N) matrix of frame-arrival times for S
    streams over N global frame slots, plus a validity mask. Lockstep
    replay (``interleaved``) fills every slot: streams run at the same
    frame rate, phase-staggered (camera clocks are not synchronized), so
    within a round the S*B arrivals interleave on the shared uplink
    instead of landing as S simultaneous bursts.  ``churn`` adds dynamic
    fleets: per-stream join slots and ragged lengths, so clients can be
    admitted and retired mid-run; slots outside a stream's lifetime are
    masked invalid (arrival = +inf).  ``rounds`` yields every round
    including the trailing partial batch — nothing is silently truncated.

  * ``EscalationBatch`` — one round's gathered low-confidence frames across
    every stream: (stream, slot, t_ready, payload, res) as flat
    numpy arrays. The scheduler permutes it (uplink order) and the edge
    fabric transmits it in one call — each row is routed to its stream's
    cell uplink and then to a slow-tier replica (``EdgeFabric.transmit``;
    the degenerate fabric is the legacy one-``transmit_batch`` pipeline) —
    and the engine scatters the slow-tier answers back with boolean masks,
    no per-frame control flow.

``select_escalations`` is the vectorized gate: for each stream s it picks
the K_s lowest-confidence frames below theta_s, using one argsort over the
whole (S, B) confidence matrix.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np


@dataclass(frozen=True)
class ArrivalSchedule:
    arrival: np.ndarray  # (S, N) seconds; +inf where the slot is invalid
    deadline: float  # per-frame window T
    valid: Optional[np.ndarray] = None  # (S, N) bool; None = every slot valid

    @classmethod
    def interleaved(cls, n_streams: int, n_frames: int, frame_rate: float,
                    deadline: float, stagger: bool = True) -> "ArrivalSchedule":
        """Lockstep fleet: S streams at the same rate; stream s
        phase-shifted by s*gamma/S."""
        gamma = 1.0 / frame_rate
        base = np.arange(n_frames, dtype=np.float64) * gamma  # (N,)
        phase = (np.arange(n_streams, dtype=np.float64) * gamma / max(n_streams, 1)
                 if stagger else np.zeros(n_streams))
        return cls(arrival=phase[:, None] + base[None, :], deadline=float(deadline))

    @classmethod
    def churn(cls, n_streams: int, n_frames: int, frame_rate: float, deadline: float,
              *, join=0, length=None, stagger: bool = True) -> "ArrivalSchedule":
        """Dynamic fleet: stream s joins at global slot ``join[s]`` and
        leaves after ``length[s]`` frames (ragged lifetimes).  With
        join=0 and length=n_frames this degenerates to ``interleaved`` —
        the lockstep-equivalence anchor the regression tests pin.
        """
        join = np.broadcast_to(np.asarray(join, dtype=np.int64), (n_streams,))
        length = (np.full(n_streams, n_frames, dtype=np.int64) if length is None
                  else np.broadcast_to(np.asarray(length, dtype=np.int64), (n_streams,)))
        if (join < 0).any() or (length < 0).any():
            raise ValueError("join slots and lengths must be >= 0")
        if (join + length > n_frames).any():
            raise ValueError("stream lifetime exceeds the schedule horizon")
        gamma = 1.0 / frame_rate
        base = np.arange(n_frames, dtype=np.float64) * gamma
        phase = (np.arange(n_streams, dtype=np.float64) * gamma / max(n_streams, 1)
                 if stagger else np.zeros(n_streams))
        slots = np.arange(n_frames)[None, :]
        valid = (slots >= join[:, None]) & (slots < (join + length)[:, None])
        arrival = np.where(valid, phase[:, None] + base[None, :], np.inf)
        return cls(arrival=arrival, deadline=float(deadline), valid=valid)

    @property
    def n_streams(self) -> int:
        return self.arrival.shape[0]

    @property
    def n_frames(self) -> int:
        return self.arrival.shape[1]

    @property
    def valid_mask(self) -> np.ndarray:
        return (np.ones(self.arrival.shape, dtype=bool) if self.valid is None
                else self.valid)

    @property
    def frames_per_stream(self) -> np.ndarray:
        return self.valid_mask.sum(axis=1)

    @property
    def horizon(self) -> float:
        """Last possible reply time: final valid arrival plus the deadline."""
        if self.valid is None:
            return float(self.arrival.max()) + self.deadline
        if not self.valid.any():
            return 0.0
        return float(self.arrival[self.valid].max()) + self.deadline

    def rounds(self, batch_size: int):
        """Yield (start_slot, arrivals (S, b), valid (S, b)) per round.

        Every slot is covered: the last round may be a partial batch
        (b < batch_size) — the engines process it instead of dropping it.
        """
        valid = self.valid_mask
        for start in range(0, self.n_frames, batch_size):
            sl = slice(start, start + batch_size)
            yield start, self.arrival[:, sl], valid[:, sl]


@dataclass
class EscalationBatch:
    """One round's cross-stream escalations, struct-of-arrays."""

    stream: np.ndarray  # (E,) int — owning stream
    slot: np.ndarray  # (E,) int — index within the round's batch
    t_ready: np.ndarray  # (E,) when the frame is ready to transmit
    payload: np.ndarray  # (E,) upload bytes at the planned resolution
    res: np.ndarray  # (E,) int — planned upload resolution (pixels)

    def __len__(self) -> int:
        return len(self.stream)

    def permuted(self, order: np.ndarray) -> "EscalationBatch":
        return EscalationBatch(self.stream[order], self.slot[order],
                               self.t_ready[order], self.payload[order], self.res[order])


def select_escalations(conf_sb: np.ndarray, theta: np.ndarray, capacity: np.ndarray):
    """Vectorized per-stream gate over an (S, B) confidence matrix.

    For each stream s, select up to ``capacity[s]`` frames with
    ``conf < theta[s]``, lowest confidence first — the same rule the jit
    cascade's masked top-k applies, but across S streams at once.
    Invalid slots must carry ``conf = +inf`` so they never gate.

    Returns (stream_idx, slot_idx) flat arrays of the selected frames.
    """
    conf_sb = np.asarray(conf_sb)
    theta = np.asarray(theta, dtype=np.float64).reshape(-1, 1)  # (S, 1)
    cap = np.asarray(capacity, dtype=np.int64).reshape(-1, 1)
    order = np.argsort(conf_sb, axis=1, kind="stable")  # ascending conf
    gate_sorted = np.take_along_axis(conf_sb < theta, order, axis=1)
    take = gate_sorted & (np.cumsum(gate_sorted, axis=1) <= cap)
    s_idx, j_idx = np.nonzero(take)
    return s_idx, order[s_idx, j_idx]
