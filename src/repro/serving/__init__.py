"""CBO serving subsystem (paper §IV-D, generalized to many streams).

Modules:
  * ``engine``    — ``CascadeServer`` (single stream) and
                    ``MultiStreamServer`` (N streams routed through an
                    edge fabric — cells x slow-tier replicas, see
                    ``repro.net`` / docs/network.md — with a batched
                    ``FleetRunner`` control plane);
  * ``events``    — vectorized arrival/escalation event queues, incl.
                    dynamic-fleet churn schedules (``ArrivalSchedule.churn``);
  * ``scheduler`` — fair uplink scheduling across streams;
  * ``metrics``   — per-stream and aggregate serving metrics (SoA counters
                    folded once per round).

See docs/serving.md for the event-queue model, the fleet control plane,
and scheduler knobs.
"""
from repro.serving.engine import CascadeServer, MultiStreamServer, ServeConfig
from repro.serving.events import ArrivalSchedule, EscalationBatch, select_escalations
from repro.serving.metrics import AggregateMetrics, ServeMetrics, jain_index
from repro.serving.scheduler import FairScheduler

__all__ = [
    "CascadeServer",
    "MultiStreamServer",
    "ServeConfig",
    "ArrivalSchedule",
    "EscalationBatch",
    "select_escalations",
    "AggregateMetrics",
    "ServeMetrics",
    "jain_index",
    "FairScheduler",
]
