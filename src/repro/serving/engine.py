"""CBO serving engine: deadline-aware two-tier cascade over a request stream.

The control loop per batch:
  1. fast tier classifies the batch (int8 "NPU" model) — instant answers;
  2. calibrated confidences go to the AdaptiveController (Algorithm 1),
     which returns (theta, resolution, capacity) from current bandwidth;
  3. the data plane escalates the K lowest-confidence frames;
  4. replies that would land after the frame's deadline are *dropped* and
     the fast-tier answer stands — the paper's fallback, which doubles as
     straggler mitigation (a slow/failed slow-tier node degrades accuracy,
     never correctness or latency).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.cascade import cascade_classify
from repro.core.netsim import Uplink, png_size_model
from repro.core.policy import AdaptiveController, BandwidthEstimator


@dataclass
class ServeConfig:
    deadline: float = 0.2  # T (paper: 200 ms)
    frame_rate: float = 30.0
    resolutions: tuple = (45, 90, 134, 179, 224)
    acc_server: tuple = ()  # measured offline (bench_resolution)
    batch_size: int = 16
    fast_time: float = 0.020  # Table III: fast tier per frame
    calib_time: float = 0.008  # Table III: calibration
    server_time: float = 0.037  # Table III: slow tier per frame


@dataclass
class ServeMetrics:
    n_frames: int = 0
    n_offloaded: int = 0
    n_deadline_miss: int = 0  # escalations that fell back
    n_correct: int = 0
    latencies: list = field(default_factory=list)

    @property
    def accuracy(self) -> float:
        return self.n_correct / max(self.n_frames, 1)

    @property
    def offload_frac(self) -> float:
        return self.n_offloaded / max(self.n_frames, 1)

    def summary(self) -> dict:
        lat = np.asarray(self.latencies) if self.latencies else np.zeros(1)
        return {
            "frames": self.n_frames,
            "accuracy": round(self.accuracy, 4),
            "offload_frac": round(self.offload_frac, 4),
            "deadline_miss_frac": round(self.n_deadline_miss / max(self.n_frames, 1), 4),
            "p50_latency_ms": round(float(np.percentile(lat, 50)) * 1e3, 2),
            "p99_latency_ms": round(float(np.percentile(lat, 99)) * 1e3, 2),
        }


class CascadeServer:
    def __init__(self, cfg: ServeConfig, fast_forward: Callable, slow_forward: Callable,
                 calibrate: Callable, uplink: Uplink):
        self.cfg = cfg
        self.fast_forward = fast_forward
        self.slow_forward = slow_forward
        self.calibrate = calibrate
        self.uplink = uplink
        self.controller = AdaptiveController(
            resolutions=cfg.resolutions,
            acc_server=cfg.acc_server,
            deadline=cfg.deadline,
            latency=uplink.latency,
            server_time=cfg.server_time,
            size_of=png_size_model,
            bw=BandwidthEstimator(estimate_bps=uplink.bandwidth_bps),
        )
        self.metrics = ServeMetrics()

    def process_stream(self, frames: np.ndarray, labels: Optional[np.ndarray] = None) -> ServeMetrics:
        """Replay a frame stream at cfg.frame_rate through the cascade."""
        cfg = self.cfg
        gamma = 1.0 / cfg.frame_rate
        B = cfg.batch_size
        n = len(frames) - len(frames) % B
        for start in range(0, n, B):
            batch = jnp.asarray(frames[start : start + B])
            arrivals = (start + np.arange(B)) * gamma
            t_done_fast = arrivals + cfg.fast_time + cfg.calib_time

            # plan from current backlog + bandwidth estimate
            plan = self.controller.plan(now=float(arrivals[0]))
            capacity = max(len(plan.offloads), 1)
            theta = plan.theta if plan.offloads else 0.0
            res = cfg.resolutions[plan.resolution]

            out = cascade_classify(
                self.fast_forward, self.slow_forward, self.calibrate, batch,
                threshold=theta, capacity=capacity, resolution=res,
            )
            conf = np.asarray(out.conf)
            escalated = np.asarray(out.escalated)
            preds = np.asarray(out.preds)
            fast_preds = np.asarray(out.fast_preds)

            # simulate the uplink for escalated frames; late replies fall back
            final = fast_preds.copy()
            for i in range(B):
                self.controller.add_frame(float(arrivals[i]), float(conf[i]))
                if not escalated[i]:
                    self.metrics.latencies.append(cfg.fast_time + cfg.calib_time)
                    continue
                payload = png_size_model(res)
                t_land = self.uplink.transmit(payload, float(t_done_fast[i]))
                self.controller.bw.observe(payload, t_land - float(t_done_fast[i]) - self.uplink.latency - self.uplink.server_time)
                if t_land <= arrivals[i] + cfg.deadline:
                    final[i] = preds[i]
                    self.metrics.n_offloaded += 1
                    self.metrics.latencies.append(t_land - arrivals[i])
                else:  # straggler / over-deadline: keep the fast answer
                    self.metrics.n_deadline_miss += 1
                    self.metrics.latencies.append(cfg.deadline)
            self.metrics.n_frames += B
            if labels is not None:
                self.metrics.n_correct += int((final == labels[start : start + B]).sum())
        return self.metrics
