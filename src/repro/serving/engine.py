"""CBO serving engines: deadline-aware two-tier cascade over request streams.

Single-stream control loop (``CascadeServer``, paper §IV-D) per batch:
  1. fast tier classifies the batch (int8 "NPU" model) — instant answers;
  2. calibrated confidences go to the offload policy (``policy=`` registry
     name or instance — default ``"cbo"``, Algorithm 1) via a
     ``PolicyRunner`` that owns the bandwidth estimate; the plan returns
     (theta, resolution, capacity);
  3. the data plane escalates the K lowest-confidence frames;
  4. replies that would land after the frame's deadline are *dropped* and
     the fast-tier answer stands — the paper's fallback, which doubles as
     straggler mitigation (a slow/failed slow-tier node degrades accuracy,
     never correctness or latency);
  5. planned offloads are consumed from the controller backlog (they left
     the device) so they are never re-planned.

``MultiStreamServer`` generalizes this to N concurrent client streams
sharing ONE uplink: a vectorized event queue (``serving/events.py``)
replaces the per-frame Python loop, a fair scheduler
(``serving/scheduler.py``) decides the uplink order across streams, each
stream keeps its own policy runner/bandwidth estimate (heterogeneous
fleets via a per-stream ``policy`` factory), and the
low-confidence frames of every stream are aggregated into one slow-tier
batch per round (``core.cascade.slow_pass_multires``). With n_streams=1 it
reproduces ``CascadeServer`` within tie-breaking noise (bench_multistream
checks this).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

import jax.numpy as jnp

from repro.core.cascade import cascade_classify, fast_pass, slow_pass_multires
from repro.core.netsim import Uplink, png_size_model
from repro.policy import BandwidthEstimator, PolicyRunner, resolve_policies
from repro.serving.events import ArrivalSchedule, EscalationBatch, select_escalations
from repro.serving.metrics import AggregateMetrics, ServeMetrics
from repro.serving.scheduler import FairScheduler


@dataclass
class ServeConfig:
    deadline: float = 0.2  # T (paper: 200 ms)
    frame_rate: float = 30.0
    resolutions: tuple = (45, 90, 134, 179, 224)
    acc_server: tuple = ()  # measured offline (bench_resolution)
    batch_size: int = 16
    fast_time: float = 0.020  # Table III: fast tier per frame
    calib_time: float = 0.008  # Table III: calibration
    server_time: float = 0.037  # Table III: slow tier per frame
    size_of: Callable = png_size_model  # resolution -> upload bytes


def _make_runner(policy, cfg: ServeConfig, uplink: Uplink, share: float = 1.0) -> PolicyRunner:
    """Wrap one decision policy (name or instance) for one stream."""
    return PolicyRunner(
        policy,
        resolutions=cfg.resolutions,
        acc_server=cfg.acc_server,
        deadline=cfg.deadline,
        latency=uplink.latency,
        server_time=cfg.server_time,
        size_of=cfg.size_of,
        bw=BandwidthEstimator(estimate_bps=uplink.bandwidth_bps * share),
    )


class CascadeServer:
    """Single-stream engine; ``policy`` is a registry name (``"cbo"``,
    ``"threshold"``, …) or an ``OffloadPolicy`` instance."""

    def __init__(self, cfg: ServeConfig, fast_forward: Callable, slow_forward: Callable,
                 calibrate: Callable, uplink: Uplink, policy="cbo"):
        self.cfg = cfg
        self.fast_forward = fast_forward
        self.slow_forward = slow_forward
        self.calibrate = calibrate
        self.uplink = uplink
        self.controller = _make_runner(resolve_policies(policy, 1)[0], cfg, uplink)
        self.metrics = ServeMetrics()

    def process_stream(self, frames: np.ndarray, labels: Optional[np.ndarray] = None) -> ServeMetrics:
        """Replay a frame stream at cfg.frame_rate through the cascade."""
        cfg = self.cfg
        gamma = 1.0 / cfg.frame_rate
        B = cfg.batch_size
        t_fast = cfg.fast_time + cfg.calib_time
        n = len(frames) - len(frames) % B
        for start in range(0, n, B):
            batch = jnp.asarray(frames[start : start + B])
            arrivals = (start + np.arange(B)) * gamma
            t_done_fast = arrivals + t_fast

            # plan from current backlog + bandwidth estimate
            plan = self.controller.plan(now=float(arrivals[0]))
            capacity = max(len(plan.offloads), 1)
            theta = plan.theta if plan.offloads else 0.0
            res = cfg.resolutions[plan.resolution]

            out = cascade_classify(
                self.fast_forward, self.slow_forward, self.calibrate, batch,
                threshold=theta, capacity=capacity, resolution=res,
            )
            conf = np.asarray(out.conf)
            escalated = np.asarray(out.escalated)
            preds = np.asarray(out.preds)
            fast_preds = np.asarray(out.fast_preds)

            # simulate the shared uplink for the whole round at once;
            # late replies fall back to the fast answer
            esc = np.flatnonzero(escalated)
            payloads = np.full(len(esc), cfg.size_of(res))
            lands = self.uplink.transmit_batch(payloads, t_done_fast[esc])
            for k in range(len(esc)):
                self.controller.bw.observe(
                    payloads[k],
                    lands[k] - t_done_fast[esc[k]] - self.uplink.latency - self.uplink.server_time,
                )
            ok = lands <= arrivals[esc] + cfg.deadline
            final = fast_preds.copy()
            final[esc[ok]] = preds[esc[ok]]

            # backlog bookkeeping: planned offloads left the device — consume
            # them (the re-planning bug), and this batch's escalated frames
            # never enter the backlog at all
            self.controller.consume(i for i, _ in plan.offloads)
            for i in np.flatnonzero(~escalated):
                self.controller.add_frame(float(arrivals[i]), float(conf[i]))

            lat = np.full(B, t_fast)
            lat[esc] = np.where(ok, lands - arrivals[esc], cfg.deadline)
            n_correct = int((final == labels[start : start + B]).sum()) if labels is not None else 0
            self.metrics.update_batch(B, int(ok.sum()), int((~ok).sum()), n_correct, lat)
        return self.metrics


class MultiStreamServer:
    """N concurrent client streams sharing one uplink and one slow tier.

    Per round: one batched fast-tier call over all streams' frames, one
    Algorithm-1 plan per stream, one vectorized escalation gate, one fair
    uplink schedule, one batched slow-tier call over the cross-stream
    escalations, and vectorized deadline/metric accounting.
    """

    def __init__(self, cfg: ServeConfig, fast_forward: Callable, slow_forward: Callable,
                 calibrate: Callable, uplink: Uplink, n_streams: int,
                 scheduler: Optional[FairScheduler] = None, stagger: bool = True,
                 policy="cbo"):
        if n_streams < 1:
            raise ValueError("n_streams must be >= 1")
        self.cfg = cfg
        self.fast_forward = fast_forward
        self.slow_forward = slow_forward
        self.calibrate = calibrate
        self.uplink = uplink
        self.n_streams = n_streams
        self.stagger = stagger
        self.scheduler = scheduler or FairScheduler("round_robin")
        # optimistic prior: every stream starts assuming the full link (as the
        # paper's single device does). A pessimistic 1/N prior can deadlock —
        # if B/N makes every offload look infeasible, no stream transmits, so
        # no stream ever *observes* bandwidth and the estimate never recovers.
        # Optimism self-corrects: early over-offloading shows up as queueing
        # in the observed transfer times and the EWMAs back off to the
        # contended share.
        # ``policy``: registry name (every stream gets a fresh instance) or a
        # per-stream factory ``stream_idx -> policy | name`` for
        # heterogeneous fleets.
        self.controllers = [_make_runner(p, cfg, uplink)
                            for p in resolve_policies(policy, n_streams)]
        self.metrics = AggregateMetrics.for_streams(n_streams, uplink=uplink)

    def process_streams(self, frames: np.ndarray,
                        labels: Optional[np.ndarray] = None) -> AggregateMetrics:
        """Replay S frame streams; ``frames`` is (S, N, H, W, C), ``labels`` (S, N)."""
        cfg = self.cfg
        S = self.n_streams
        if frames.shape[0] != S:
            raise ValueError(f"expected {S} streams, got frames.shape[0]={frames.shape[0]}")
        B = cfg.batch_size
        t_fast = cfg.fast_time + cfg.calib_time
        resolutions = np.asarray(cfg.resolutions)
        schedule = ArrivalSchedule.interleaved(S, frames.shape[1], cfg.frame_rate,
                                              cfg.deadline, stagger=self.stagger)
        # horizon over *simulated* frames only — rounds() trims the trailing
        # partial batch, and utilization must not be diluted by unsimulated time
        n_sim = frames.shape[1] - frames.shape[1] % B
        self.metrics.wall_time = (
            float(schedule.arrival[:, :n_sim].max()) + cfg.deadline if n_sim else 0.0
        )

        for start, arr in schedule.rounds(B):
            flat = jnp.asarray(frames[:, start : start + B].reshape(S * B, *frames.shape[2:]))
            fp, cf = fast_pass(self.fast_forward, self.calibrate, flat)
            fast_preds = np.asarray(fp).reshape(S, B)
            conf = np.asarray(cf).reshape(S, B)
            t_ready = arr + t_fast  # (S, B)

            # control plane: one Algorithm-1 plan per stream
            theta = np.zeros(S)
            cap = np.ones(S, dtype=np.int64)
            res_idx = np.zeros(S, dtype=np.int64)
            plans = []
            for s, ctrl in enumerate(self.controllers):
                plan = ctrl.plan(now=float(arr[s, 0]))
                plans.append(plan)
                cap[s] = max(len(plan.offloads), 1)
                theta[s] = plan.theta if plan.offloads else 0.0
                res_idx[s] = plan.resolution

            # vectorized gate + gathered cross-stream escalation batch
            s_idx, slot_idx = select_escalations(conf, theta, cap)
            res_px = resolutions[res_idx[s_idx]]
            esc = EscalationBatch(
                stream=s_idx, slot=slot_idx,
                t_ready=t_ready[s_idx, slot_idx],
                payload=np.asarray([cfg.size_of(int(r)) for r in res_px], dtype=np.float64),
                res=res_px,
            )

            # one batched slow-tier call for every stream's escalations
            if len(esc):
                gathered = jnp.take(flat, jnp.asarray(s_idx * B + slot_idx), axis=0)
                slow_preds = np.asarray(slow_pass_multires(self.slow_forward, gathered, esc.res))
            else:
                slow_preds = np.zeros(0, dtype=fast_preds.dtype)

            # fair uplink schedule, then one vectorized transmit for the round
            order = self.scheduler.order(esc.stream, esc.t_ready,
                                         cost=esc.payload / self.uplink.bandwidth_bps)
            q = esc.permuted(order)
            slow_q = slow_preds[order]
            lands = self.uplink.transmit_batch(q.payload, q.t_ready)
            ok = lands <= arr[q.stream, q.slot] + cfg.deadline

            final = fast_preds.copy()
            final[q.stream[ok], q.slot[ok]] = slow_q[ok]

            # per-stream bandwidth observations, in transmission order
            for k in range(len(q)):
                self.controllers[q.stream[k]].bw.observe(
                    q.payload[k],
                    lands[k] - q.t_ready[k] - self.uplink.latency - self.uplink.server_time,
                )

            # backlog bookkeeping per stream (same semantics as CascadeServer)
            esc_mask = np.zeros((S, B), dtype=bool)
            esc_mask[s_idx, slot_idx] = True
            for s, ctrl in enumerate(self.controllers):
                ctrl.consume(i for i, _ in plans[s].offloads)
                for i in np.flatnonzero(~esc_mask[s]):
                    ctrl.add_frame(float(arr[s, i]), float(conf[s, i]))

            # vectorized metrics: latency per frame, counts per stream
            lat = np.full((S, B), t_fast)
            lat[q.stream[ok], q.slot[ok]] = lands[ok] - arr[q.stream[ok], q.slot[ok]]
            lat[q.stream[~ok], q.slot[~ok]] = cfg.deadline
            off_counts = np.bincount(q.stream[ok], minlength=S)
            miss_counts = np.bincount(q.stream[~ok], minlength=S)
            correct = ((final == labels[:, start : start + B]).sum(axis=1)
                       if labels is not None else np.zeros(S, dtype=np.int64))
            for s in range(S):
                self.metrics[s].update_batch(B, off_counts[s], miss_counts[s],
                                             int(correct[s]), lat[s])
        return self.metrics
