"""CBO serving engines: deadline-aware two-tier cascade over request streams.

Single-stream control loop (``CascadeServer``, paper §IV-D) per batch:
  1. fast tier classifies the batch (int8 "NPU" model) — instant answers;
  2. calibrated confidences go to the offload policy (``policy=`` registry
     name or instance — default ``"cbo"``, Algorithm 1) via a
     ``PolicyRunner`` that owns the bandwidth estimate; the plan returns
     (theta, resolution, capacity);
  3. the data plane escalates the K lowest-confidence frames;
  4. replies that would land after the frame's deadline are *dropped* and
     the fast-tier answer stands — the paper's fallback, which doubles as
     straggler mitigation (a slow/failed slow-tier node degrades accuracy,
     never correctness or latency);
  5. planned offloads are consumed from the controller backlog (they left
     the device) so they are never re-planned.

``MultiStreamServer`` generalizes this to N concurrent client streams
sharing an **edge fabric** (``repro/net``): streams are partitioned across
cells (one serial uplink each), and escalations are placed onto a pool of
slow-tier replicas.  The default fabric — built automatically from the
``uplink`` argument — is the degenerate 1-cell/1-replica topology, which
reproduces the legacy shared-uplink pipeline bit-for-bit.  Both planes
stay batched:

  * data plane — one fast-tier call over every stream's frames per round,
    one gathered slow-tier batch, one fabric transmit (a vectorized
    Lindley recursion per cell uplink and per replica queue);
  * control plane — a ``FleetRunner`` (``policy/fleet.py``) holds all
    per-stream policy state as struct-of-arrays (flat ragged backlogs,
    (S,) EWMA bandwidth vector) and plans every stream in one batched
    ``plan_many`` call per round.

The round loop therefore contains no per-stream or per-frame Python:
planning, bandwidth observation, backlog consume/extend and metrics all
run as (S,)-vector / segment operations.  Fleets are dynamic: an
``ArrivalSchedule.churn`` schedule admits and retires clients mid-run
(staggered joins, ragged stream lengths), and trailing partial batches are
processed rather than silently dropped.  With a lockstep schedule the
engine reproduces the looped implementation's metrics exactly
(``tests/data/multistream_snapshot.json``), and with n_streams=1 it
matches ``CascadeServer`` within tie-breaking noise (bench_multistream
checks this).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

import jax.numpy as jnp

from repro.core.cascade import cascade_classify, fast_pass, slow_pass_multires
from repro.core.netsim import Uplink, payload_sizes, png_size_model, transfer_seconds
from repro.net import EdgeFabric
from repro.policy import BandwidthEstimator, FleetRunner, PolicyRunner, resolve_policies
from repro.serving.events import ArrivalSchedule, EscalationBatch, select_escalations
from repro.serving.metrics import AggregateMetrics, ServeMetrics
from repro.serving.scheduler import FairScheduler


@dataclass
class ServeConfig:
    deadline: float = 0.2  # T (paper: 200 ms)
    frame_rate: float = 30.0
    resolutions: tuple = (45, 90, 134, 179, 224)
    acc_server: tuple = ()  # measured offline (bench_resolution)
    batch_size: int = 16
    fast_time: float = 0.020  # Table III: fast tier per frame
    calib_time: float = 0.008  # Table III: calibration
    server_time: float = 0.037  # Table III: slow tier per frame
    size_of: Callable = png_size_model  # resolution (scalar or array) -> upload bytes
    use_fused: bool = False  # fused Pallas calibrate+gate kernel in the fast pass
    platt_ab: Optional[tuple] = None  # (a, b) Platt coefficients for use_fused
    # split-computation action table (policy.types.ActionTable, built via
    # repro.split.build_action_table): enlarges the planner grid with
    # features@cut actions.  None / a frames-only table keeps the paper's
    # frame-only action space — and its pinned snapshots — bit-for-bit.
    # Consumed by MultiStreamServer; CascadeServer (the single-stream paper
    # loop) stays frame-only by design.
    actions: Optional[object] = None


def _fast_pass(cfg: ServeConfig, fast_forward, calibrate, images):
    return fast_pass(fast_forward, calibrate, images,
                     use_fused=cfg.use_fused, platt_ab=cfg.platt_ab)


def _make_runner(policy, cfg: ServeConfig, uplink: Uplink, share: float = 1.0) -> PolicyRunner:
    """Wrap one decision policy (name or instance) for one stream."""
    return PolicyRunner(
        policy,
        resolutions=cfg.resolutions,
        acc_server=cfg.acc_server,
        deadline=cfg.deadline,
        latency=uplink.latency,
        server_time=cfg.server_time,
        size_of=cfg.size_of,
        bw=BandwidthEstimator(estimate_bps=uplink.bandwidth_bps * share),
    )


class CascadeServer:
    """Single-stream engine; ``policy`` is a registry name (``"cbo"``,
    ``"threshold"``, …) or an ``OffloadPolicy`` instance."""

    def __init__(self, cfg: ServeConfig, fast_forward: Callable, slow_forward: Callable,
                 calibrate: Callable, uplink: Uplink, policy="cbo"):
        self.cfg = cfg
        self.fast_forward = fast_forward
        self.slow_forward = slow_forward
        self.calibrate = calibrate
        self.uplink = uplink
        self.controller = _make_runner(resolve_policies(policy, 1)[0], cfg, uplink)
        self.metrics = ServeMetrics()

    def process_stream(self, frames: np.ndarray, labels: Optional[np.ndarray] = None) -> ServeMetrics:
        """Replay a frame stream at cfg.frame_rate through the cascade.

        Every frame is served: the trailing partial batch (when
        ``len(frames)`` is not a multiple of the batch size) runs as a
        smaller final round instead of being silently dropped.
        """
        cfg = self.cfg
        gamma = 1.0 / cfg.frame_rate
        B = cfg.batch_size
        t_fast = cfg.fast_time + cfg.calib_time
        n = len(frames)
        for start in range(0, n, B):
            b = min(B, n - start)
            batch = jnp.asarray(frames[start : start + b])
            arrivals = (start + np.arange(b)) * gamma
            t_done_fast = arrivals + t_fast

            # plan from current backlog + bandwidth estimate
            plan = self.controller.plan(now=float(arrivals[0]))
            capacity = max(len(plan.offloads), 1)
            theta = plan.theta if plan.offloads else 0.0
            res = cfg.resolutions[plan.resolution]

            out = cascade_classify(
                self.fast_forward, self.slow_forward, self.calibrate, batch,
                threshold=theta, capacity=capacity, resolution=res,
                use_fused=cfg.use_fused, platt_ab=cfg.platt_ab,
            )
            conf = np.asarray(out.conf)
            escalated = np.asarray(out.escalated)
            preds = np.asarray(out.preds)
            fast_preds = np.asarray(out.fast_preds)

            # simulate the shared uplink for the whole round at once;
            # late replies fall back to the fast answer
            esc = np.flatnonzero(escalated)
            payloads = np.full(len(esc), cfg.size_of(res))
            lands = self.uplink.transmit_batch(payloads, t_done_fast[esc])
            for k in range(len(esc)):
                self.controller.bw.observe(
                    payloads[k],
                    lands[k] - t_done_fast[esc[k]] - self.uplink.latency - self.uplink.server_time,
                )
            ok = lands <= arrivals[esc] + cfg.deadline
            final = fast_preds.copy()
            final[esc[ok]] = preds[esc[ok]]

            # backlog bookkeeping: planned offloads left the device — consume
            # them (the re-planning bug), and this batch's escalated frames
            # never enter the backlog at all
            self.controller.consume(i for i, _ in plan.offloads)
            for i in np.flatnonzero(~escalated):
                self.controller.add_frame(float(arrivals[i]), float(conf[i]))

            lat = np.full(b, t_fast)
            lat[esc] = np.where(ok, lands - arrivals[esc], cfg.deadline)
            n_correct = int((final == labels[start : start + b]).sum()) if labels is not None else 0
            self.metrics.update_batch(b, int(ok.sum()), int((~ok).sum()), n_correct, lat)
        return self.metrics


class MultiStreamServer:
    """N concurrent client streams sharing one uplink and one slow tier.

    Per round: one batched fast-tier call over all streams' frames, one
    batched ``plan_many`` over every stream's backlog (``FleetRunner``),
    one vectorized escalation gate, one fair uplink schedule, one batched
    slow-tier call over the cross-stream escalations, and vectorized
    deadline/metric accounting — no per-stream or per-frame Python.
    """

    def __init__(self, cfg: ServeConfig, fast_forward: Callable, slow_forward: Callable,
                 calibrate: Callable, uplink: Optional[Uplink], n_streams: int,
                 scheduler: Optional[FairScheduler] = None, stagger: bool = True,
                 policy="cbo", fabric: Optional[EdgeFabric] = None,
                 backend: str = "numpy", telemetry=None):
        if n_streams < 1:
            raise ValueError("n_streams must be >= 1")
        if backend not in ("numpy", "jax"):
            raise ValueError(f"backend must be 'numpy' or 'jax', got {backend!r}")
        self.backend = backend
        # optional per-round observer (the differential test harness): called
        # with one dict per round — identical keys on both backends
        self.round_hook = None
        self.cfg = cfg
        self.fast_forward = fast_forward
        self.slow_forward = slow_forward
        self.calibrate = calibrate
        # ``fabric`` is the network topology (cells x replicas, repro/net);
        # when omitted, the ``uplink`` argument becomes the degenerate
        # 1-cell/1-replica fabric — the legacy pipeline, bit-for-bit.
        # Passing both is ambiguous (the uplink would carry no traffic but
        # still feed the metrics), so it is rejected outright.
        if fabric is None:
            if uplink is None:
                raise ValueError("pass an uplink or an EdgeFabric")
            fabric = EdgeFabric.degenerate(uplink, n_streams)
        else:
            if uplink is not None:
                raise ValueError("pass either uplink or fabric, not both "
                                 "(the fabric's cells own all traffic)")
            if fabric.n_streams != n_streams:
                raise ValueError(f"fabric maps {fabric.n_streams} streams, "
                                 f"engine has {n_streams}")
        self.fabric = fabric
        self.uplink = fabric.cells[0].uplink
        self.n_streams = n_streams
        self.stagger = stagger
        self.scheduler = scheduler or FairScheduler("round_robin")
        # nominal per-stream uplink rate (each stream's own cell): the
        # scheduler's cost normalizer and the EWMA estimators' prior
        self._stream_bw = fabric.stream_bandwidth()
        # optimistic prior: every stream starts assuming the full link (as the
        # paper's single device does). A pessimistic 1/N prior can deadlock —
        # if B/N makes every offload look infeasible, no stream transmits, so
        # no stream ever *observes* bandwidth and the estimate never recovers.
        # Optimism self-corrects: early over-offloading shows up as queueing
        # in the observed transfer times and the EWMAs back off to the
        # contended share.
        # ``policy``: registry name (every stream gets a fresh instance) or a
        # per-stream factory ``stream_idx -> policy | name`` for
        # heterogeneous fleets.
        # plan against the network the fabric actually simulates: T^o is
        # the pool's nominal service time (== cfg.server_time whenever the
        # caller built the fabric from it), never a diverging copy
        self.fleet = FleetRunner(
            resolve_policies(policy, n_streams),
            resolutions=cfg.resolutions, acc_server=cfg.acc_server,
            deadline=cfg.deadline, latency=fabric.latency,
            server_time=fabric.server_time, size_of=cfg.size_of,
            bw_init=self._stream_bw, cell_id=fabric.cell_of,
            actions=cfg.actions,
        )
        self.metrics = AggregateMetrics.for_streams(n_streams, uplink=self.uplink,
                                                    fabric=fabric)
        # optional observability bundle (``repro.obs.Telemetry``): a per-round
        # time-series recorder, a frame-lifecycle tracer (numpy only) and a
        # phase profiler.  ``None`` (the default) is the zero-cost path —
        # every hook below is a ``x is not None`` check that fails fast.
        self.telemetry = telemetry
        if telemetry is not None:
            telemetry.bind(n_streams=n_streams, n_cells=fabric.n_cells,
                           n_replicas=fabric.n_replicas,
                           n_actions=self.fleet.action_table.n_actions)
            self.fleet.profiler = telemetry.profiler
        if backend == "jax":
            # fail fast on configurations the compiled path cannot express,
            # naming every unsupported feature (shared supports_jax check)
            from repro.serving.engine_jax import jax_unsupported

            reasons = jax_unsupported(self)
            if reasons:
                raise ValueError("backend='jax' cannot express this "
                                 "configuration: " + "; ".join(reasons))

    def process_streams(self, frames: np.ndarray,
                        labels: Optional[np.ndarray] = None,
                        schedule: Optional[ArrivalSchedule] = None) -> AggregateMetrics:
        """Replay S frame streams; ``frames`` is (S, N, H, W, C), ``labels``
        (S, N).  ``schedule`` defaults to the lockstep interleaved replay;
        pass an ``ArrivalSchedule.churn`` to stagger stream join/leave —
        ``frames[s, n]`` is then the frame stream s produces at global slot
        n, and only its valid slots are served."""
        cfg = self.cfg
        S = self.n_streams
        if frames.shape[0] != S:
            raise ValueError(f"expected {S} streams, got frames.shape[0]={frames.shape[0]}")
        B = cfg.batch_size
        t_fast = cfg.fast_time + cfg.calib_time
        resolutions = np.asarray(cfg.resolutions)
        if schedule is None:
            schedule = ArrivalSchedule.interleaved(S, frames.shape[1], cfg.frame_rate,
                                                  cfg.deadline, stagger=self.stagger)
        if schedule.n_streams != S or schedule.n_frames != frames.shape[1]:
            raise ValueError("schedule shape must match frames (S, N)")
        self.metrics.wall_time = schedule.horizon
        if self.backend == "jax":
            return self._process_streams_jax(frames, labels, schedule)

        # telemetry hooks: every guard below is a plain ``is not None`` so
        # the default (no telemetry) path touches no clock and no buffer
        tel = self.telemetry
        rec = tel.recorder if tel is not None else None
        tracer = tel.tracer if tel is not None else None
        prof = tel.profiler if tel is not None else None

        for start, arr, valid in schedule.rounds(B):
            b = arr.shape[1]
            active = valid.any(axis=1)  # (S,) streams with frames this round
            # retire state of streams outside their lifetime (left, or not
            # yet joined — the latter have nothing to clear)
            self.fleet.retire(~active)

            t0 = time.perf_counter() if prof is not None else 0.0
            flat = jnp.asarray(frames[:, start : start + b].reshape(S * b, *frames.shape[2:]))
            fp, cf = _fast_pass(cfg, self.fast_forward, self.calibrate, flat)
            fast_preds = np.asarray(fp).reshape(S, b)
            conf = np.asarray(cf).reshape(S, b)
            if prof is not None:
                prof.add("serve", time.perf_counter() - t0)
            t_ready = arr + t_fast  # (S, b); +inf on invalid slots

            # control plane: one batched plan over every active backlog,
            # against the slow tier's occupancy-calibrated service estimate
            # (identical to the nominal when the pool doesn't batch)
            now = np.min(arr, axis=1)  # first valid arrival (inf if none)
            pool = self.fabric.pool
            self.fleet.server_time = self.fabric.expected_server_time()
            self.fleet.occupancy = float(pool.avg_batch)
            fin = now[np.isfinite(now)]
            self.fleet.queue_depth = pool.queue_depth(
                float(fin.min()) if len(fin) else 0.0)
            batch = self.fleet.plan_all(now, active)
            theta = batch.theta
            cap = np.where(active, np.maximum(batch.n_offloads, 1), 0)
            res_idx = batch.resolution  # a° — ACTION index per stream

            # the shared action→bytes table (satellite of the split plane):
            # planner-assumed and engine-transmitted payloads come from ONE
            # array, indexed by the planned action.  For a frames-only
            # table these are exactly ``payload_sizes(size_of, resolutions)``
            # and every extra term below is + 0.0 / * 1.0 — bit-for-bit the
            # legacy pipeline.
            act = self.fleet.action_table
            act_res_px = resolutions[act.res]  # (A,) evaluation pixels

            # vectorized gate + gathered cross-stream escalation batch; a
            # split action's upload leaves the device only after the prefix
            # runs (t_dev), which also shifts its fair-schedule readiness
            conf_gate = np.where(valid, conf, np.inf)
            s_idx, slot_idx = select_escalations(conf_gate, theta, cap)
            a_esc = res_idx[s_idx]
            res_px = act_res_px[a_esc]
            esc = EscalationBatch(
                stream=s_idx, slot=slot_idx,
                t_ready=t_ready[s_idx, slot_idx] + act.t_dev[a_esc],
                payload=act.sizes[a_esc],
                res=res_px,
            )

            # one batched slow-tier call for every stream's escalations
            t0 = time.perf_counter() if prof is not None else 0.0
            if len(esc):
                gathered = jnp.take(flat, jnp.asarray(s_idx * b + slot_idx), axis=0)
                slow_preds = np.asarray(slow_pass_multires(self.slow_forward, gathered, esc.res))
            else:
                slow_preds = np.zeros(0, dtype=fast_preds.dtype)
            if prof is not None:
                prof.add("serve", time.perf_counter() - t0)

            # fair uplink schedule (cost normalized by each stream's own
            # cell rate), then one fabric transmit for the round: per-cell
            # uplink queues + replica placement + pool service
            t0 = time.perf_counter() if prof is not None else 0.0
            order = self.scheduler.order(esc.stream, esc.t_ready,
                                         cost=esc.payload / self._stream_bw[esc.stream])
            q = esc.permuted(order)
            slow_q = slow_preds[order]
            # split suffixes cost a fraction of the full-model service time
            # (frames scale by exactly 1.0 — a float no-op)
            lands = self.fabric.transmit(q.stream, q.payload, q.t_ready,
                                         service_scale=act.srv_frac[res_idx[q.stream]],
                                         collect_detail=tracer is not None)
            if prof is not None:
                prof.add("transmit", time.perf_counter() - t0)
            ok = lands <= arr[q.stream, q.slot] + cfg.deadline

            if tracer is not None and len(q):
                d = self.fabric.last_detail
                tracer.record_round(
                    stream=q.stream, slot=q.slot,
                    arrival=arr[q.stream, q.slot], t_ready=q.t_ready,
                    cell=d["cell"], up_start=d["up_start"], up_end=d["up_end"],
                    replica=d["replica"], service=d["service"],
                    batch_id=d["batch_id"], done=d["done"],
                    land=lands, ok=ok, deadline=cfg.deadline)

            t0 = time.perf_counter() if prof is not None else 0.0
            final = fast_preds.copy()
            final[q.stream[ok], q.slot[ok]] = slow_q[ok]

            # batched per-stream bandwidth observations (transmission order):
            # each reply's *actual* service time is subtracted (servers
            # report their processing time, so heterogeneous replicas do
            # not skew the estimate), but replica *queueing* is not — the
            # device cannot separate queueing from wire time, so slow-tier
            # contention surfaces to the EWMAs as reduced effective
            # bandwidth and the policies back off
            self.fleet.observe_bandwidth(
                q.stream, q.payload,
                transfer_seconds(lands, q.t_ready, latency=self.fabric.latency,
                                 server_time=self.fabric.last_service_time))

            # backlog bookkeeping, batched (same semantics as CascadeServer):
            # planned offloads left the device; non-escalated valid frames
            # join their stream's backlog in slot order
            self.fleet.consume(batch)
            esc_mask = np.zeros((S, b), dtype=bool)
            esc_mask[s_idx, slot_idx] = True
            add = valid & ~esc_mask
            add_s, _ = np.nonzero(add)
            self.fleet.observe_frames(add_s, arr[add], conf[add].astype(np.float64))

            # vectorized metrics: latency per frame, counts per stream
            lat = np.full((S, b), t_fast)
            lat[q.stream[ok], q.slot[ok]] = lands[ok] - arr[q.stream[ok], q.slot[ok]]
            lat[q.stream[~ok], q.slot[~ok]] = cfg.deadline
            off_counts = np.bincount(q.stream[ok], minlength=S)
            miss_counts = np.bincount(q.stream[~ok], minlength=S)
            correct = (((final == labels[:, start : start + b]) & valid).sum(axis=1)
                       if labels is not None else np.zeros(S, dtype=np.int64))
            self.metrics.update_round(valid.sum(axis=1), off_counts, miss_counts,
                                      correct, lat, valid)
            if prof is not None:
                prof.add("fold", time.perf_counter() - t0)

            if rec is not None:
                # cumulative counters (the metrics SoA is exactly the jax
                # carry's semantics), planner state as used THIS round, and
                # the contention cursors post-round
                t_round = float(fin.min()) if len(fin) else np.nan
                hist = np.zeros(rec.n_actions, dtype=np.int64)
                np.add.at(hist, res_idx, np.where(active, batch.n_offloads, 0))
                m, fab = self.metrics, self.fabric
                rec.record_round(
                    t=t_round,
                    frames=m._frames, offloads=m._offloaded,
                    misses=m._missed, correct=m._correct,
                    bw_est=self.fleet.bw_est,
                    bw_true=fab.true_bandwidth(t_round),
                    cell_busy_s=[c.uplink.busy_seconds for c in fab.cells],
                    cell_queued_s=[c.uplink.queued_seconds for c in fab.cells],
                    rep_busy_s=pool.busy_seconds,
                    rep_queued_s=pool.queued_seconds,
                    avg_batch=pool.avg_batch,
                    server_time=self.fleet.server_time,
                    action_off=hist,
                )

            if self.round_hook is not None:
                ok_grid = np.zeros((S, b), dtype=bool)
                ok_grid[q.stream[ok], q.slot[ok]] = True
                self.round_hook({
                    "start": start,
                    "theta": theta.copy(), "res_idx": res_idx.copy(),
                    "cap": cap.copy(), "n_off": batch.n_offloads.copy(),
                    "n_frames": batch.n_frames.copy(),
                    "off_stream": batch.off_stream.copy(),
                    "off_pos": batch.off_pos.copy(),
                    "off_res": batch.off_res.copy(),
                    "off_kind": batch.off_kind.copy(),
                    "off_cut": batch.off_cut.copy(),
                    "esc": esc_mask, "ok": ok_grid, "lat": lat.copy(),
                    "valid": valid.copy(), "correct": np.asarray(correct).copy(),
                    "bw_est": self.fleet.bw_est.copy(),
                    "lengths": self.fleet.state.lengths.copy(),
                })
        return self.metrics

    def _process_streams_jax(self, frames, labels, schedule) -> AggregateMetrics:
        """Compiled backend: precompute the neural tiers per round on the
        host, then advance the whole replay as one jitted ``lax.scan``
        (``serving/engine_jax.py``).  Decision/schedule semantics are pinned
        to the numpy path by ``tests/test_fleet_jax.py``."""
        import jax.numpy as jnp

        from repro.serving import engine_jax as ej
        from repro.sharding.axes import host_shard, logical_axis_multiple

        cfg = self.cfg
        S, B = self.n_streams, cfg.batch_size
        resolutions = np.asarray(cfg.resolutions)
        m = len(resolutions)
        collect = "trace" if self.round_hook is not None else "metrics"
        tel = self.telemetry
        rec = tel.recorder if tel is not None else None
        prof = tel.profiler if tel is not None else None
        # under a mesh, pad the stream axis to the device multiple so the
        # "streams" logical axis actually splits; the pad rows never see a
        # valid frame, so every output below is sliced back to [:S]
        mult = logical_axis_multiple("streams")
        S_pad = -(-S // mult) * mult
        spad = S_pad - S
        spec = ej.spec_from_server(self, collect=collect, pad_streams=S_pad,
                                   telemetry=rec is not None)
        params = ej.params_from_server(self, spec)

        # host precompute: confidences + per-resolution slow-tier
        # correctness for every (frame, res) — both tiers are deterministic
        # per frame, so this equals the numpy path's escalated-only batching
        t0 = time.perf_counter() if prof is not None else 0.0
        rounds = []
        per_round = []
        for start, arr, valid in schedule.rounds(B):
            b = arr.shape[1]
            flat = jnp.asarray(frames[:, start : start + b].reshape(
                S * b, *frames.shape[2:]))
            fp, cf = _fast_pass(cfg, self.fast_forward, self.calibrate, flat)
            fast_preds = np.asarray(fp).reshape(S, b)
            conf = np.asarray(cf).reshape(S, b)
            lab = labels[:, start : start + b] if labels is not None else None
            fast_ok = (fast_preds == lab) if lab is not None else np.zeros((S, b), bool)
            slow_ok = np.zeros((S, b, m), dtype=bool)
            if lab is not None:
                for r in range(m):
                    sp = np.asarray(slow_pass_multires(
                        self.slow_forward, flat,
                        np.full(S * b, resolutions[r]))).reshape(S, b)
                    slow_ok[:, :, r] = sp == lab
            pad = B - b
            if pad:
                arr = np.pad(arr, ((0, 0), (0, pad)), constant_values=np.inf)
                valid = np.pad(valid, ((0, 0), (0, pad)))
                conf = np.pad(conf, ((0, 0), (0, pad)), constant_values=np.inf)
                fast_ok = np.pad(fast_ok, ((0, 0), (0, pad)))
                slow_ok = np.pad(slow_ok, ((0, 0), (0, pad), (0, 0)))
            if spad:
                arr = np.pad(arr, ((0, spad), (0, 0)), constant_values=np.inf)
                valid = np.pad(valid, ((0, spad), (0, 0)))
                conf = np.pad(conf, ((0, spad), (0, 0)), constant_values=np.inf)
                fast_ok = np.pad(fast_ok, ((0, spad), (0, 0)))
                slow_ok = np.pad(slow_ok, ((0, spad), (0, 0), (0, 0)))
            rounds.append((arr, valid, conf, fast_ok, slow_ok))
            per_round.append((start, b))
        if prof is not None:
            prof.add("precompute", time.perf_counter() - t0)
        if not rounds:
            return self.metrics
        # place the stacked (R, S, B[, m]) inputs pre-split over the mesh
        # (no-op off-mesh) so the scan reads local shards from round one
        t0 = time.perf_counter() if prof is not None else 0.0
        inputs = ej.RoundInputs(*(
            host_shard(jnp.asarray(col), *((None, "streams", None, None)[:col.ndim]))
            for col in (np.stack(c) for c in zip(*rounds))))
        carry, ys = ej.simulate(spec, params, inputs)
        if prof is not None:
            import jax

            jax.block_until_ready(carry)
            prof.add("scan", time.perf_counter() - t0)
        if carry.fp_bad is not None and bool(carry.fp_bad):
            import warnings

            warnings.warn(
                "a time-varying uplink fixed point failed to settle inside "
                "the compiled scan; the numpy reference would have used its "
                "exact serial fallback — results may diverge", RuntimeWarning)

        # fold per-round counters/latencies into the same AggregateMetrics
        # (everything stream-indexed is sliced back to the real S rows)
        t0 = time.perf_counter() if prof is not None else 0.0
        # host baselines of the cumulative second counters — the carry
        # accumulates deltas from zero, the recorder (and numpy) report
        # absolute values, so the pre-scan state is added back per round
        base_cb = np.asarray([c.uplink.busy_seconds for c in self.fabric.cells])
        base_cq = np.asarray([c.uplink.queued_seconds for c in self.fabric.cells])
        base_rb = self.fabric.pool.busy_seconds.copy()
        base_rq = self.fabric.pool.queued_seconds.copy()
        base_ctr = (self.metrics._frames.copy(), self.metrics._offloaded.copy(),
                    self.metrics._missed.copy(), self.metrics._correct.copy())
        off = np.asarray(ys.off_counts)[:, :S]
        miss = np.asarray(ys.miss_counts)[:, :S]
        corr = np.asarray(ys.correct)[:, :S]
        lat = np.asarray(ys.lat, dtype=np.float64)[:, :S]
        for i, (start, b) in enumerate(per_round):
            valid_i = rounds[i][1][:S, :b]
            self.metrics.update_round(valid_i.sum(axis=1), off[i], miss[i],
                                      corr[i], lat[i][:, :b], valid_i)

        # fold device state back into the host objects so summaries,
        # contention counters and follow-on numpy rounds stay correct
        for c, cell in enumerate(self.fabric.cells):
            cell.uplink._busy_until = float(carry.cell_busy[c])
            cell.uplink.n_transfers += int(carry.cell_n[c])
            cell.uplink.busy_seconds += float(carry.cell_busy_s[c])
            cell.uplink.queued_seconds += float(carry.cell_queued_s[c])
        pool = self.fabric.pool
        pool.busy_until[:] = np.asarray(carry.rep_busy, dtype=np.float64)
        pool.n_jobs += np.asarray(carry.rep_n, dtype=np.int64)
        pool.busy_seconds += np.asarray(carry.rep_busy_s, dtype=np.float64)
        pool.queued_seconds += np.asarray(carry.rep_queued_s, dtype=np.float64)
        pool.avg_batch = float(carry.avg_batch)  # occupancy EWMA (1.0 = serial)
        self.fabric.placement._next = int(carry.rr_next)
        self.fleet.bw_est[:] = np.asarray(carry.bw_est, dtype=np.float64)[:S]
        from repro.policy.fleet_jax import unpad_fleet

        fleet_c = carry.fleet
        if spad:  # drop the inert pad rows (always empty backlogs)
            fleet_c = type(fleet_c)(fleet_c.arrival[:S], fleet_c.conf[:S],
                                    fleet_c.length[:S])
        arr_f, conf_f, lens = unpad_fleet(fleet_c)
        st = self.fleet.state
        st.arrival = arr_f.astype(np.float64)
        st.conf = conf_f.astype(np.float64)
        st.stream_id = np.repeat(np.arange(S), lens)
        st._rebuild_offsets()
        if prof is not None:
            prof.add("fold", time.perf_counter() - t0)

        if rec is not None:
            # replay the scan's stacked telemetry columns into the recorder.
            # Cumulative counters come from host cumsums of the per-round
            # integer columns (bit-exact — same int arithmetic as numpy's
            # running SoA); t and bw_true are recomputed host-side from the
            # same float64 arrival grid, so they are bit-equal by
            # construction; the rest compares at the tolerance policy.
            frames_c = base_ctr[0] + np.cumsum(
                [r[1][:S].sum(axis=1) for r in rounds], axis=0)
            off_c = base_ctr[1] + np.cumsum(off, axis=0, dtype=np.int64)
            miss_c = base_ctr[2] + np.cumsum(miss, axis=0, dtype=np.int64)
            corr_c = base_ctr[3] + np.cumsum(corr, axis=0, dtype=np.int64)
            bw_ts = np.asarray(ys.ts_bw_est, dtype=np.float64)[:, :S]
            hist_ts = np.asarray(ys.ts_off_hist, dtype=np.int64)
            cb = base_cb + np.asarray(ys.ts_cell_busy_s, dtype=np.float64)
            cq = base_cq + np.asarray(ys.ts_cell_queued_s, dtype=np.float64)
            rb = base_rb + np.asarray(ys.ts_rep_busy_s, dtype=np.float64)
            rq = base_rq + np.asarray(ys.ts_rep_queued_s, dtype=np.float64)
            ab = np.asarray(ys.ts_avg_batch, dtype=np.float64)
            st_ts = np.asarray(ys.ts_st_est, dtype=np.float64)
            for i in range(len(per_round)):
                arr_i = rounds[i][0][:S]
                fin = arr_i[np.isfinite(arr_i)]
                t_round = float(fin.min()) if len(fin) else np.nan
                rec.record_round(
                    t=t_round, frames=frames_c[i], offloads=off_c[i],
                    misses=miss_c[i], correct=corr_c[i], bw_est=bw_ts[i],
                    bw_true=self.fabric.true_bandwidth(t_round),
                    cell_busy_s=cb[i], cell_queued_s=cq[i],
                    rep_busy_s=rb[i], rep_queued_s=rq[i],
                    avg_batch=ab[i], server_time=st_ts[i],
                    action_off=hist_ts[i])

        if self.round_hook is not None:
            act = self.fleet.action_table
            for i, (start, b) in enumerate(per_round):
                dec = np.asarray(ys.dec[i])[:S]
                off_s, off_p = np.nonzero(dec >= 0)
                self.round_hook({
                    "start": start,
                    "theta": np.asarray(ys.theta[i], dtype=np.float64)[:S],
                    "res_idx": np.asarray(ys.res_idx[i], dtype=np.int64)[:S],
                    "cap": np.asarray(ys.cap[i], dtype=np.int64)[:S],
                    "n_off": np.asarray(ys.n_off[i], dtype=np.int64)[:S],
                    "n_frames": np.asarray(ys.n_frames[i], dtype=np.int64)[:S],
                    "off_stream": off_s.astype(np.int64),
                    "off_pos": off_p.astype(np.int64),
                    "off_res": dec[off_s, off_p].astype(np.int64),
                    # derived host-side from the shared table: the scan's
                    # decision grid already carries the ACTION index
                    "off_kind": act.kind[dec[off_s, off_p]].astype(np.int8),
                    "off_cut": act.cut[dec[off_s, off_p]].astype(np.int64),
                    "esc": np.asarray(ys.esc[i])[:S, :b],
                    "ok": np.asarray(ys.ok[i])[:S, :b],
                    "lat": lat[i][:, :b],
                    "valid": rounds[i][1][:S, :b],
                    "correct": corr[i].astype(np.int64),
                    "bw_est": np.asarray(ys.bw_est[i], dtype=np.float64)[:S],
                    "lengths": np.asarray(ys.lengths[i], dtype=np.int64)[:S],
                    "overflow": np.asarray(ys.overflow[i])[:S],
                    "inexact": np.asarray(ys.inexact[i])[:S],
                })
        return self.metrics
