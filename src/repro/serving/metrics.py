"""Serving metrics: per-stream counters and multi-stream aggregation.

``ServeMetrics`` is the single-stream record the original engine kept (and
still keeps — it is re-exported from ``serving.engine`` for compatibility).
``AggregateMetrics`` wraps one ``ServeMetrics`` per stream plus the shared
uplink's contention counters, and adds the cross-stream views that only
exist in the multi-stream regime: aggregate accuracy (frame-weighted),
per-stream accuracy spread, and Jain's fairness index over per-stream
offload counts.

Semantics (documented in docs/serving.md):
  * ``accuracy``            — frame-weighted over all streams;
  * ``offload_frac``        — escalations whose reply landed in time;
  * ``deadline_miss_frac``  — escalations that fell back to the fast answer;
  * latencies               — per frame: fast path for locals, land time for
                              offloads, clipped at the deadline for misses.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class ServeMetrics:
    n_frames: int = 0
    n_offloaded: int = 0
    n_deadline_miss: int = 0  # escalations that fell back
    n_correct: int = 0
    latencies: list = field(default_factory=list)

    @property
    def accuracy(self) -> float:
        return self.n_correct / max(self.n_frames, 1)

    @property
    def offload_frac(self) -> float:
        return self.n_offloaded / max(self.n_frames, 1)

    @property
    def deadline_miss_frac(self) -> float:
        return self.n_deadline_miss / max(self.n_frames, 1)

    def update_batch(self, n_frames: int, n_offloaded: int, n_deadline_miss: int,
                     n_correct: int, latencies) -> None:
        """Vectorized-round update: fold one round's numpy results in."""
        self.n_frames += int(n_frames)
        self.n_offloaded += int(n_offloaded)
        self.n_deadline_miss += int(n_deadline_miss)
        self.n_correct += int(n_correct)
        self.latencies.extend(float(x) for x in np.atleast_1d(latencies))

    def summary(self) -> dict:
        # no latencies observed → the percentiles do not exist; reporting
        # them as null keeps "no data" distinguishable from "0 ms"
        lat = np.asarray(self.latencies, dtype=np.float64)
        return {
            "frames": self.n_frames,
            "accuracy": round(self.accuracy, 4),
            "offload_frac": round(self.offload_frac, 4),
            "deadline_miss_frac": round(self.deadline_miss_frac, 4),
            "p50_latency_ms": (round(float(np.percentile(lat, 50)) * 1e3, 2)
                               if lat.size else None),
            "p99_latency_ms": (round(float(np.percentile(lat, 99)) * 1e3, 2)
                               if lat.size else None),
        }


def jain_index(x) -> float:
    """Jain's fairness index: 1.0 = perfectly even, 1/n = one stream hogs."""
    x = np.asarray(x, dtype=np.float64)
    if x.size == 0 or x.sum() <= 0:
        return 1.0
    return float(x.sum() ** 2 / (x.size * (x**2).sum()))


class AggregateMetrics:
    """Struct-of-arrays fleet metrics: (S,) counter vectors folded once per
    round (``update_round``) so the serving engine's inner loop carries no
    per-stream Python.  ``per_stream`` materializes the familiar
    ``ServeMetrics`` views lazily (tests, reports); latencies are kept as
    per-round (S, B) chunks plus validity masks until then."""

    def __init__(self, n_streams: int, uplink=None, fabric=None):
        self.n_streams = int(n_streams)
        self.uplink = uplink  # the shared Uplink (for contention counters)
        self.fabric = fabric  # EdgeFabric (per-cell / per-replica counters)
        self.wall_time: float = 0.0  # simulated horizon (last arrival + deadline)
        self._frames = np.zeros(n_streams, dtype=np.int64)
        self._offloaded = np.zeros(n_streams, dtype=np.int64)
        self._missed = np.zeros(n_streams, dtype=np.int64)
        self._correct = np.zeros(n_streams, dtype=np.int64)
        self._lat_chunks: list = []  # [(lat (S, b), valid (S, b))]
        self._cache: list | None = None

    @classmethod
    def for_streams(cls, n_streams: int, uplink=None, fabric=None) -> "AggregateMetrics":
        return cls(n_streams, uplink=uplink, fabric=fabric)

    def update_round(self, n_frames, n_offloaded, n_missed, n_correct,
                     latencies, valid) -> None:
        """Fold one round's (S,)-vector counters and (S, b) latencies in."""
        self._frames += np.asarray(n_frames, dtype=np.int64)
        self._offloaded += np.asarray(n_offloaded, dtype=np.int64)
        self._missed += np.asarray(n_missed, dtype=np.int64)
        self._correct += np.asarray(n_correct, dtype=np.int64)
        self._lat_chunks.append((np.asarray(latencies, dtype=np.float64),
                                 np.asarray(valid, dtype=bool)))
        self._cache = None

    @property
    def per_stream(self) -> list:
        """Per-stream ``ServeMetrics`` views (index = stream id)."""
        if self._cache is None:
            out = []
            for s in range(self.n_streams):
                m = ServeMetrics(
                    n_frames=int(self._frames[s]), n_offloaded=int(self._offloaded[s]),
                    n_deadline_miss=int(self._missed[s]), n_correct=int(self._correct[s]))
                m.latencies = [float(x) for lat, ok in self._lat_chunks
                               for x in lat[s][ok[s]]]
                out.append(m)
            self._cache = out
        return self._cache

    def __getitem__(self, s: int) -> ServeMetrics:
        return self.per_stream[s]

    # -- aggregate (frame-weighted) views -------------------------------- #
    @property
    def n_frames(self) -> int:
        return int(self._frames.sum())

    @property
    def n_offloaded(self) -> int:
        return int(self._offloaded.sum())

    @property
    def n_deadline_miss(self) -> int:
        return int(self._missed.sum())

    @property
    def accuracy(self) -> float:
        return int(self._correct.sum()) / max(self.n_frames, 1)

    @property
    def offload_frac(self) -> float:
        return self.n_offloaded / max(self.n_frames, 1)

    @property
    def deadline_miss_frac(self) -> float:
        return self.n_deadline_miss / max(self.n_frames, 1)

    @property
    def offload_fairness(self) -> float:
        """Jain index over per-stream successful-offload counts."""
        return jain_index(self._offloaded)

    def summary(self) -> dict:
        lats = (np.concatenate([lat[ok] for lat, ok in self._lat_chunks])
                if self._lat_chunks else np.zeros(0))
        # straight from the SoA counters — no per-stream materialization
        acc = self._correct / np.maximum(self._frames, 1)
        out = {
            "streams": self.n_streams,
            "frames": self.n_frames,
            "accuracy": round(self.accuracy, 4),
            "offload_frac": round(self.offload_frac, 4),
            "deadline_miss_frac": round(self.deadline_miss_frac, 4),
            "p50_latency_ms": (round(float(np.percentile(lats, 50)) * 1e3, 2)
                               if lats.size else None),
            "p99_latency_ms": (round(float(np.percentile(lats, 99)) * 1e3, 2)
                               if lats.size else None),
            "stream_acc_min": round(float(min(acc)), 4),
            "stream_acc_max": round(float(max(acc)), 4),
            "offload_fairness": round(self.offload_fairness, 4),
        }
        fs = self.fabric.summary() if self.fabric is not None else None
        multi_cell = self.fabric is not None and self.fabric.n_cells > 1
        if multi_cell:
            # the uplink_* keys stay fabric-wide under a multi-cell fabric:
            # totals over every cell, utilization averaged per cell (1.0 =
            # every radio saturated) — never just cell 0's counters
            out["uplink_queued_s"] = round(sum(fs["cell_queued_s"]), 4)
            out["uplink_busy_s"] = round(sum(fs["cell_busy_s"]), 4)
            if self.wall_time > 0:
                out["uplink_utilization"] = round(
                    sum(fs["cell_busy_s"]) / (self.fabric.n_cells * self.wall_time), 4)
        elif self.uplink is not None:
            out["uplink_queued_s"] = round(float(self.uplink.queued_seconds), 4)
            out["uplink_busy_s"] = round(float(self.uplink.busy_seconds), 4)
            if self.wall_time > 0:
                out["uplink_utilization"] = round(self.uplink.utilization(self.wall_time), 4)
        if self.fabric is not None and (self.fabric.n_cells > 1
                                        or self.fabric.n_replicas > 1):
            # topology contention: where escalations queued — on the radio
            # (cell uplinks) or at the slow tier (replica pool)
            out["cells"] = fs["cells"]
            out["replicas"] = fs["replicas"]
            out["placement"] = fs["placement"]
            out["cell_queued_s"] = [round(x, 4) for x in fs["cell_queued_s"]]
            out["cell_busy_s"] = [round(x, 4) for x in fs["cell_busy_s"]]
            out["replica_queued_s"] = [round(x, 4) for x in fs["replica_queued_s"]]
            out["replica_busy_s"] = [round(x, 4) for x in fs["replica_busy_s"]]
            # utilization only means "overload when > 1" for serial queues;
            # an infinite-capacity (serial=False) pool never queues, so the
            # ratio would misread as saturation
            if self.wall_time > 0 and self.fabric.pool.serial:
                out["replica_utilization"] = [
                    round(float(x), 4)
                    for x in self.fabric.pool.utilization(self.wall_time)]
        return out
