"""Serving metrics: per-stream counters and multi-stream aggregation.

``ServeMetrics`` is the single-stream record the original engine kept (and
still keeps — it is re-exported from ``serving.engine`` for compatibility).
``AggregateMetrics`` wraps one ``ServeMetrics`` per stream plus the shared
uplink's contention counters, and adds the cross-stream views that only
exist in the multi-stream regime: aggregate accuracy (frame-weighted),
per-stream accuracy spread, and Jain's fairness index over per-stream
offload counts.

Semantics (documented in docs/serving.md):
  * ``accuracy``            — frame-weighted over all streams;
  * ``offload_frac``        — escalations whose reply landed in time;
  * ``deadline_miss_frac``  — escalations that fell back to the fast answer;
  * latencies               — per frame: fast path for locals, land time for
                              offloads, clipped at the deadline for misses.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class ServeMetrics:
    n_frames: int = 0
    n_offloaded: int = 0
    n_deadline_miss: int = 0  # escalations that fell back
    n_correct: int = 0
    latencies: list = field(default_factory=list)

    @property
    def accuracy(self) -> float:
        return self.n_correct / max(self.n_frames, 1)

    @property
    def offload_frac(self) -> float:
        return self.n_offloaded / max(self.n_frames, 1)

    @property
    def deadline_miss_frac(self) -> float:
        return self.n_deadline_miss / max(self.n_frames, 1)

    def update_batch(self, n_frames: int, n_offloaded: int, n_deadline_miss: int,
                     n_correct: int, latencies) -> None:
        """Vectorized-round update: fold one round's numpy results in."""
        self.n_frames += int(n_frames)
        self.n_offloaded += int(n_offloaded)
        self.n_deadline_miss += int(n_deadline_miss)
        self.n_correct += int(n_correct)
        self.latencies.extend(float(x) for x in np.atleast_1d(latencies))

    def summary(self) -> dict:
        lat = np.asarray(self.latencies) if self.latencies else np.zeros(1)
        return {
            "frames": self.n_frames,
            "accuracy": round(self.accuracy, 4),
            "offload_frac": round(self.offload_frac, 4),
            "deadline_miss_frac": round(self.deadline_miss_frac, 4),
            "p50_latency_ms": round(float(np.percentile(lat, 50)) * 1e3, 2),
            "p99_latency_ms": round(float(np.percentile(lat, 99)) * 1e3, 2),
        }


def jain_index(x) -> float:
    """Jain's fairness index: 1.0 = perfectly even, 1/n = one stream hogs."""
    x = np.asarray(x, dtype=np.float64)
    if x.size == 0 or x.sum() <= 0:
        return 1.0
    return float(x.sum() ** 2 / (x.size * (x**2).sum()))


@dataclass
class AggregateMetrics:
    per_stream: list  # list[ServeMetrics], index = stream id
    uplink: object = None  # the shared Uplink (for contention counters)
    wall_time: float = 0.0  # simulated horizon (last arrival + deadline)

    @classmethod
    def for_streams(cls, n_streams: int, uplink=None) -> "AggregateMetrics":
        return cls(per_stream=[ServeMetrics() for _ in range(n_streams)], uplink=uplink)

    def __getitem__(self, s: int) -> ServeMetrics:
        return self.per_stream[s]

    # -- aggregate (frame-weighted) views -------------------------------- #
    @property
    def n_frames(self) -> int:
        return sum(m.n_frames for m in self.per_stream)

    @property
    def n_offloaded(self) -> int:
        return sum(m.n_offloaded for m in self.per_stream)

    @property
    def n_deadline_miss(self) -> int:
        return sum(m.n_deadline_miss for m in self.per_stream)

    @property
    def accuracy(self) -> float:
        return sum(m.n_correct for m in self.per_stream) / max(self.n_frames, 1)

    @property
    def offload_frac(self) -> float:
        return self.n_offloaded / max(self.n_frames, 1)

    @property
    def deadline_miss_frac(self) -> float:
        return self.n_deadline_miss / max(self.n_frames, 1)

    @property
    def offload_fairness(self) -> float:
        """Jain index over per-stream successful-offload counts."""
        return jain_index([m.n_offloaded for m in self.per_stream])

    def summary(self) -> dict:
        lats = np.asarray([x for m in self.per_stream for x in m.latencies]) \
            if any(m.latencies for m in self.per_stream) else np.zeros(1)
        acc = [m.accuracy for m in self.per_stream]
        out = {
            "streams": len(self.per_stream),
            "frames": self.n_frames,
            "accuracy": round(self.accuracy, 4),
            "offload_frac": round(self.offload_frac, 4),
            "deadline_miss_frac": round(self.deadline_miss_frac, 4),
            "p50_latency_ms": round(float(np.percentile(lats, 50)) * 1e3, 2),
            "p99_latency_ms": round(float(np.percentile(lats, 99)) * 1e3, 2),
            "stream_acc_min": round(float(min(acc)), 4),
            "stream_acc_max": round(float(max(acc)), 4),
            "offload_fairness": round(self.offload_fairness, 4),
        }
        if self.uplink is not None:
            out["uplink_queued_s"] = round(float(self.uplink.queued_seconds), 4)
            out["uplink_busy_s"] = round(float(self.uplink.busy_seconds), 4)
            if self.wall_time > 0:
                out["uplink_utilization"] = round(self.uplink.utilization(self.wall_time), 4)
        return out
