"""Synthetic planted-signal workload for serving tests, benchmarks, examples.

One canonical definition of the toy two-tier stack (weak fast tier reading a
signal+noise channel, near-oracle slow tier) and the planted-signal frame
streams, so tests and benchmarks exercise the *same* workload — previously
each had its own copy and they could drift.
"""
from __future__ import annotations

import numpy as np


def synthetic_tiers():
    """(fast, slow, calibrate): closed-form tiers over (B, H, W, 4) frames."""

    def fast(images):  # weak: signal + noise channel
        return images[:, 0, 0, :4] + images[:, 1, 1, :4]

    def slow(images):  # near-oracle
        return images[:, 0, 0, :4] * 10.0

    return fast, slow, (lambda s: s)


def synthetic_streams(n_streams: int, n_frames: int, res: int = 8, seed: int = 0):
    """(S, N, res, res, 4) float32 frames + (S, N) labels with planted signal."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 4, size=(n_streams, n_frames))
    imgs = rng.normal(size=(n_streams, n_frames, res, res, 4)) * 0.8
    s_idx, f_idx = np.meshgrid(np.arange(n_streams), np.arange(n_frames), indexing="ij")
    imgs[s_idx, f_idx, 0, 0, labels] = 2.0
    return imgs.astype(np.float32), labels
