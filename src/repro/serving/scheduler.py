"""Fair scheduling of escalations onto the shared uplink.

With one stream the uplink order is trivial (FIFO by readiness). With N
streams contending for one serial link, the order frames enter the queue
decides who eats the head-of-line blocking: pure FIFO lets a bursty stream
park its whole batch ahead of everyone else's first frame, starving the
others' deadlines. The scheduler therefore permutes each round's
``EscalationBatch`` before it hits ``Uplink.transmit_batch``.

Policies (the ``policy`` knob, see docs/serving.md):
  * ``"fifo"``        — global readiness order; max-throughput, unfair under
                        asymmetric load;
  * ``"round_robin"`` — start-time fair queueing (default): each frame gets
                        a virtual tag ``max(t_ready, prev_tag + cost/w)``
                        computed per stream, and the queue is sorted by tag.
                        Tags never precede readiness, so the wire is not
                        idled waiting for an unready frame; a stream that
                        dumps a burst accumulates cost and its tail yields
                        to other streams' earlier frames. ``weights`` makes
                        it weighted fair queueing (stream s gets ~w_s of the
                        link under contention).

Everything is vectorized: per-stream tag recurrences are the same max-plus
(Lindley) form the uplink uses, computed with cumsum + running max.

Under a multi-cell edge fabric the one global ordering still works: only
*within-cell* relative order matters (each cell's uplink serializes just
its own rows, in the order given), and restricting an SFQ-sorted sequence
to one cell's rows preserves their tag order.  The engine normalizes
``cost`` by each stream's own cell rate (``payload / cell_bandwidth``), so
tags stay comparable across heterogeneous cells.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np


def sfq_tags(stream: np.ndarray, t_ready: np.ndarray, cost: np.ndarray) -> np.ndarray:
    """Per-stream virtual start tags: tag_k = max(t_ready_k, tag_{k-1} + cost_{k-1}).

    Unrolled, tag_k = runmax_j(t_ready_j - excl_cumsum_j) + excl_cumsum_k over
    the stream's frames in readiness order — one cumsum and one running max
    per stream group.
    """
    n = len(stream)
    if n == 0:
        return np.zeros(0, dtype=np.float64)
    idx = np.lexsort((t_ready, stream))  # grouped by stream, ready-ascending
    r, c = t_ready[idx], cost[idx]
    starts = np.r_[0, np.flatnonzero(np.diff(stream[idx])) + 1]
    group_len = np.diff(np.r_[starts, n])
    excl = np.cumsum(c) - c
    excl -= np.repeat(excl[starts], group_len)  # per-group exclusive prefix sum
    eff = r - excl
    for a, l in zip(starts, group_len):  # running max restarts per group (S iterations)
        eff[a : a + l] = np.maximum.accumulate(eff[a : a + l])
    tags = np.empty(n, dtype=np.float64)
    tags[idx] = eff + excl
    return tags


@dataclass
class FairScheduler:
    policy: str = "round_robin"  # "round_robin" | "fifo"
    weights: Optional[np.ndarray] = None  # per-stream weights (round_robin only)

    def __post_init__(self):
        if self.policy not in ("round_robin", "fifo"):
            raise ValueError(f"unknown scheduler policy: {self.policy!r}")
        if self.weights is not None:
            self.weights = np.asarray(self.weights, dtype=np.float64)
            if np.any(self.weights <= 0):
                raise ValueError("scheduler weights must be positive")

    def order(self, stream: np.ndarray, t_ready: np.ndarray,
              cost: Optional[np.ndarray] = None) -> np.ndarray:
        """Permutation giving the uplink transmission order for one round.

        ``cost`` is each frame's nominal wire time (payload / bandwidth);
        it drives the fair-queueing tags. Without it, tags degenerate to
        readiness order (== fifo).

        ``stream`` ids are global (stable under churn: a stream keeps its
        id across join/leave), so per-stream ``weights`` stay aligned for
        dynamic fleets — absent streams simply contribute no frames.
        """
        stream = np.asarray(stream)
        t_ready = np.asarray(t_ready, dtype=np.float64)
        if self.policy == "fifo" or len(stream) == 0:
            return np.lexsort((stream, t_ready))
        cost = np.zeros(len(stream)) if cost is None else np.asarray(cost, dtype=np.float64)
        if self.weights is not None:
            if int(stream.max()) >= len(self.weights):
                raise ValueError(
                    f"scheduler weights cover {len(self.weights)} streams but "
                    f"stream id {int(stream.max())} appeared in this round")
            cost = cost / self.weights[stream]
        tags = sfq_tags(stream, t_ready, cost)
        return np.lexsort((stream, t_ready, tags))
