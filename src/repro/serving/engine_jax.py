"""JAX serving engine: the whole fleet round as one jitted ``lax.scan`` step.

``MultiStreamServer.process_streams`` runs plan -> transmit -> observe ->
consume per round in host numpy (``serving/engine.py``).  This module is
the same round, re-expressed in fixed shapes so ``jax.jit`` compiles it
once and ``lax.scan`` advances it across rounds with zero host round
trips.  The numpy engine stays the semantic reference: every ordering
rule (escalation gate, SFQ tags, per-cell Lindley, placement, per-replica
Lindley, EWMA fold) is reproduced with the same tie-breaks, and the
differential tests (``tests/test_fleet_jax.py``) pin the two paths round
by round.

Shape/masking scheme (docs/jax_backend.md):

  * rounds are padded to the batch size B — trailing partial rounds get
    ``valid=False`` slots with ``arrival=+inf`` (never gate, never count);
  * backlogs are a ``PaddedFleet`` of pad L == ``max_backlog``;
  * one round's escalations live in the flat (S*B,) row space
    (``flat = s*B + slot``); masked rows ride through every recursion as
    no-ops — tx=0 / submit=-inf rows provably cannot perturb the running
    max a Lindley recursion takes over live rows;
  * the neural tiers run OUTSIDE the scan: confidences and per-resolution
    slow-tier correctness are precomputed per round (deterministic per
    frame, so identical to the numpy path's escalated-only batching) and
    fed to the scan as (R, S, B[, m]) inputs.

Stream-axis sharding: the carry's (S,)/(S, L)/(S, B) arrays are
constrained to the ``"streams"`` logical axis (``sharding/axes.py``), so
under a mesh the fleet splits across devices; off-mesh the constraint is
a no-op and the engine runs identically on one CPU.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.policy.fleet_jax import (PaddedFleet, PlannerSpec, clear_fleet,
                                    consume_fleet, ewma_fold, extend_fleet,
                                    plan_fleet, prune_fleet)
from repro.sharding.axes import shard

__all__ = ["EngineSpec", "EngineParams", "RoundInputs", "EngineCarry",
           "RoundTrace", "init_carry", "make_engine", "simulate",
           "spec_from_server", "params_from_server"]

_NEG = -jnp.inf


# --------------------------------------------------------------------------- #
# static spec + pytrees
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class EngineSpec:
    """Everything the compiled round step specializes on."""

    n_streams: int
    batch: int  # B — round batch size (rounds are padded to it)
    n_cells: int
    n_replicas: int
    planner: PlannerSpec
    placement: str = "round_robin"  # round_robin | jsq | least_land
    serial_replicas: bool = False
    scheduler: str = "round_robin"  # round_robin | fifo
    prune: bool = True  # BacklogPolicy.prune_expired
    oneshot: bool = False  # OneShotPolicy consume semantics
    t_fast: float = 0.028  # fast_time + calib_time
    bw_alpha: float = 0.3
    collect: str = "metrics"  # none | metrics | trace
    # continuous-batching slow tier (repro.slowtier); "none" = per-request
    # service exactly as before.  coeffs: flat=(st,); linear=(base, per_item);
    # step=(base, per_page, page_size)
    batch_kind: str = "none"  # none | flat | linear | step
    batch_coeffs: tuple = ()
    batch_window: float = 0.0  # admission window (s)
    batch_cap: int = 0  # occupancy cap per batch; 0 = unbounded
    batch_beta: float = 0.25  # occupancy EWMA fold

    @property
    def m(self) -> int:
        return self.planner.m

    @property
    def deadline(self) -> float:
        return self.planner.deadline

    @property
    def latency(self) -> float:
        return self.planner.latency


class EngineParams(NamedTuple):
    """Per-run device arrays the step closes over (not traced per round)."""

    sizes: jnp.ndarray  # (m,) payload bytes per resolution
    cell_bw: jnp.ndarray  # (C,) bytes/s (constant-rate uplinks only)
    cell_of: jnp.ndarray  # (S,) int32
    replica_st: jnp.ndarray  # (K,) per-replica service time
    stream_bw: jnp.ndarray  # (S,) nominal cell rate (scheduler normalizer)
    weights: jnp.ndarray  # (S,) scheduler weights (ones = unweighted)
    bw_init: jnp.ndarray  # (S,) EWMA prior


class RoundInputs(NamedTuple):
    """One round of precomputed data-plane inputs (stack to (R, ...) for scan)."""

    arr: jnp.ndarray  # (S, B) arrival seconds; +inf on invalid slots
    valid: jnp.ndarray  # (S, B) bool
    conf: jnp.ndarray  # (S, B) calibrated confidence (fast pass)
    fast_ok: jnp.ndarray  # (S, B) bool — fast prediction correct
    slow_ok: jnp.ndarray  # (S, B, m) bool — slow prediction correct per res


class EngineCarry(NamedTuple):
    fleet: PaddedFleet
    bw_est: jnp.ndarray  # (S,)
    cell_busy: jnp.ndarray  # (C,) uplink busy-until cursors
    cell_n: jnp.ndarray  # (C,) int32 transfer counts
    cell_busy_s: jnp.ndarray  # (C,)
    cell_queued_s: jnp.ndarray  # (C,)
    rep_busy: jnp.ndarray  # (K,)
    rep_n: jnp.ndarray  # (K,) int32
    rep_busy_s: jnp.ndarray  # (K,)
    rep_queued_s: jnp.ndarray  # (K,)
    rr_next: jnp.ndarray  # () int32 round-robin placement cursor
    frames: jnp.ndarray  # (S,) int32
    offloaded: jnp.ndarray  # (S,) int32
    missed: jnp.ndarray  # (S,) int32
    correct: jnp.ndarray  # (S,) int32
    avg_batch: jnp.ndarray  # () slow-tier occupancy EWMA (1.0 = serial)


class RoundTrace(NamedTuple):
    """Per-round outputs (``collect`` >= "metrics"; trace adds decisions)."""

    off_counts: jnp.ndarray  # (S,) int32
    miss_counts: jnp.ndarray  # (S,) int32
    correct: jnp.ndarray  # (S,) int32
    lat: jnp.ndarray  # (S, B)
    # -- collect == "trace" extras (zero-size placeholders otherwise) ----- #
    theta: jnp.ndarray
    res_idx: jnp.ndarray
    cap: jnp.ndarray
    n_off: jnp.ndarray
    n_frames: jnp.ndarray  # post-prune backlog lengths at plan time
    dec: jnp.ndarray  # (S, L) int8
    esc: jnp.ndarray  # (S, B) bool
    ok: jnp.ndarray  # (S, B) bool
    bw_est: jnp.ndarray  # (S,) after the round's EWMA fold
    lengths: jnp.ndarray  # (S,) backlog lengths after extend
    overflow: jnp.ndarray  # (S,) bool
    inexact: jnp.ndarray  # (S,) bool


def init_carry(spec: EngineSpec, params: EngineParams) -> EngineCarry:
    S, C, K, L = spec.n_streams, spec.n_cells, spec.n_replicas, spec.planner.L
    dt = spec.planner.dtype
    z = lambda *s: jnp.zeros(s, dtype=dt)
    zi = lambda *s: jnp.zeros(s, dtype=jnp.int32)
    fleet = PaddedFleet(z(S, L), z(S, L), zi(S))
    # copy=True: same-dtype astype would alias params.bw_init's buffer, and
    # the engine donates its carry (make_engine) — an aliased buffer would
    # be deleted out from under params on the first step
    return EngineCarry(
        fleet=fleet, bw_est=jnp.array(params.bw_init, dtype=dt, copy=True),
        cell_busy=z(C), cell_n=zi(C), cell_busy_s=z(C), cell_queued_s=z(C),
        rep_busy=z(K), rep_n=zi(K), rep_busy_s=z(K), rep_queued_s=z(K),
        rr_next=jnp.zeros((), jnp.int32),
        frames=zi(S), offloaded=zi(S), missed=zi(S), correct=zi(S),
        avg_batch=jnp.ones((), dtype=dt))


# --------------------------------------------------------------------------- #
# masked recursions
# --------------------------------------------------------------------------- #


def _masked_lindley(sub, tx, mask, busy0):
    """end_i = max(sub_i, end_{i-1}) + tx_i over the masked rows, with
    masked rows as exact no-ops: tx=0 / sub=-inf rows contribute the
    candidate ``busy0 - excl <= busy0``, which the first live row's
    ``max(sub, busy0) - 0 >= busy0`` already dominates, so the running max
    over live rows is untouched.  Returns (end, new_busy, wire, queued)."""
    txm = jnp.where(mask, tx, 0.0)
    subm = jnp.where(mask, sub, _NEG)
    csum = jnp.cumsum(txm)
    eff = jnp.maximum(subm, busy0) - (csum - txm)
    end = jax.lax.cummax(eff) + csum
    any_live = mask.any()
    new_busy = jnp.where(any_live, jnp.where(mask, end, _NEG).max(), busy0)
    wire = txm.sum()
    queued = jnp.where(mask, jnp.clip(end - txm - subm, 0.0, None), 0.0).sum()
    return end, new_busy, wire, queued


def _lexsort2(primary, rows_sorted_by_secondary):
    """Stable argsort by ``primary`` applied on top of an existing stable
    secondary order — the composed-argsort form of ``np.lexsort``."""
    o = rows_sorted_by_secondary
    return o[jnp.argsort(primary[o])]


def _batch_latency(spec: EngineSpec, n):
    """The slow tier's latency curve f(n) from the flat static coefficients
    (mirrors ``repro.slowtier``'s LatencyModel classes in jnp)."""
    c = spec.batch_coeffs
    if spec.batch_kind == "flat":
        return c[0] * n
    if spec.batch_kind == "linear":
        return c[0] + c[1] * n
    if spec.batch_kind == "step":
        return c[0] + c[1] * jnp.ceil(n / c[2])
    raise ValueError(f"unknown batch_kind {spec.batch_kind!r}")


# --------------------------------------------------------------------------- #
# the round step
# --------------------------------------------------------------------------- #


def _round_step(spec: EngineSpec, params: EngineParams,
                carry: EngineCarry, x: RoundInputs):
    S, B, C, K = spec.n_streams, spec.batch, spec.n_cells, spec.n_replicas
    L, m = spec.planner.L, spec.m
    dt = spec.planner.dtype
    N = S * B
    inf = jnp.inf
    arr = shard(x.arr.astype(dt), "streams", None)
    valid, conf = x.valid, x.conf.astype(dt)

    # (1) active streams; retire the rest (FleetRunner.retire)
    active = valid.any(axis=1)
    fleet = clear_fleet(carry.fleet, ~active)

    # (2) control plane: prune + one batched plan (FleetRunner.plan_all)
    now = arr.min(axis=1)  # first valid arrival; +inf when none
    prune_mask = active if spec.prune else jnp.zeros_like(active)
    fleet = prune_fleet(fleet, now, spec.deadline, prune_mask)
    fleet = PaddedFleet(shard(fleet.arrival, "streams", None),
                        shard(fleet.conf, "streams", None),
                        shard(fleet.length, "streams"))
    bw_plan = jnp.maximum(carry.bw_est, 1.0)  # same dead-link floor
    if spec.batch_kind == "none":
        plan = plan_fleet(spec.planner, fleet, now, bw_plan)
    else:
        # occupancy-calibrated T^o = f(expected_batch)/expected_batch at the
        # observed occupancy EWMA (ReplicaPool.expected_server_time)
        nb = jnp.maximum(carry.avg_batch, 1.0)
        st_eff = (_batch_latency(spec, nb) / nb).astype(dt)
        plan = plan_fleet(spec.planner, fleet, now, bw_plan, st_eff)
    theta = jnp.where(active, plan.theta, 0.0)
    res_idx = jnp.where(active, plan.resolution, m - 1)
    n_off = jnp.where(active, plan.n_offloads, 0)
    dec = jnp.where(active[:, None], plan.dec, jnp.int8(-1))
    cap = jnp.where(active, jnp.maximum(n_off, 1), 0)

    # (3) escalation gate (select_escalations): per stream the cap lowest
    # confidences below theta — stable conf argsort + cumsum gate
    conf_gate = jnp.where(valid, conf, inf)
    o_slot = jnp.argsort(conf_gate, axis=1)
    gate_sorted = jnp.take_along_axis(conf_gate < theta[:, None], o_slot, axis=1)
    take_sorted = gate_sorted & (jnp.cumsum(gate_sorted, axis=1) <= cap[:, None])
    esc = jnp.zeros((S, B), bool).at[
        jnp.arange(S)[:, None], o_slot].set(take_sorted)

    payload_s = params.sizes[res_idx].astype(dt)  # (S,) planned upload bytes
    t_ready = arr + spec.t_fast

    # (4) fair uplink schedule (FairScheduler.order).  Cost is constant per
    # stream within a round, so the SFQ tag recurrence unrolls over slots
    # (per-stream arrivals strictly ascend, so slot order == t_ready order).
    esc_flat = esc.reshape(-1)
    t_ready_flat = jnp.where(esc, t_ready, inf).reshape(-1)
    o = jnp.argsort(t_ready_flat)  # stable: ties keep (stream, slot) order
    if spec.scheduler == "round_robin":
        cost_s = payload_s / params.stream_bw / params.weights
        tags = jnp.full((S, B), inf, dtype=dt)
        prev = jnp.full((S,), _NEG, dtype=dt)
        for d in range(B):
            cand = jnp.maximum(t_ready[:, d], prev + cost_s)
            tags = tags.at[:, d].set(jnp.where(esc[:, d], cand, inf))
            prev = jnp.where(esc[:, d], cand, prev)
        o = _lexsort2(tags.reshape(-1), o)

    # (5) fabric transmit: per-cell masked Lindley over the scheduled rows
    stream_flat = jnp.repeat(jnp.arange(S, dtype=jnp.int32), B)
    s_o = stream_flat[o]
    m_o = esc_flat[o]
    sub_o = x.arr.reshape(-1)[o] + spec.t_fast  # real t_ready per row
    pay_o = params.sizes[res_idx[s_o]].astype(dt)
    cell_o = params.cell_of[s_o]
    end_tx = jnp.zeros((N,), dtype=dt)
    cell_busy, cell_n = carry.cell_busy, carry.cell_n
    cell_busy_s, cell_queued_s = carry.cell_busy_s, carry.cell_queued_s
    for c in range(C):
        mk = m_o & (cell_o == c)
        end_c, busy_c, wire_c, queued_c = _masked_lindley(
            sub_o, pay_o / params.cell_bw[c], mk, cell_busy[c])
        end_tx = jnp.where(mk, end_c, end_tx)
        cell_busy = cell_busy.at[c].set(busy_c)
        cell_n = cell_n.at[c].add(mk.sum(dtype=jnp.int32))
        cell_busy_s = cell_busy_s.at[c].add(wire_c)
        cell_queued_s = cell_queued_s.at[c].add(queued_c)

    # (6) replica placement in upload-arrival order (Placement.assign)
    end_m = jnp.where(m_o, end_tx, inf)
    o2 = jnp.argsort(end_m)  # stable: ties keep scheduler order
    m2 = m_o[o2]
    rr_next = carry.rr_next
    if spec.placement == "round_robin":
        rank = jnp.cumsum(m2.astype(jnp.int32)) - 1
        rep2 = (rr_next + rank) % K
        rr_next = (rr_next + m_o.sum(dtype=jnp.int32)) % K
    else:
        st = params.replica_st.astype(dt)

        def pstep(busy, inp):
            t_i, live = inp
            if spec.placement == "jsq":
                k = jnp.argmin(busy)
            else:  # least_land
                k = jnp.argmin(jnp.maximum(t_i, busy) + st)
            upd = busy.at[k].set(jnp.maximum(t_i, busy[k]) + st[k])
            return jnp.where(live, upd, busy), jnp.where(live, k, 0).astype(jnp.int32)

        _, rep2 = jax.lax.scan(pstep, carry.rep_busy.astype(dt), (end_m[o2], m2))
    replica_o = jnp.zeros((N,), jnp.int32).at[o2].set(rep2.astype(jnp.int32))

    # (7) replica pool service (ReplicaPool.process)
    rep_busy, rep_n = carry.rep_busy, carry.rep_n
    rep_busy_s, rep_queued_s = carry.rep_busy_s, carry.rep_queued_s
    st_row = params.replica_st[replica_o].astype(dt)
    service_o = st_row  # per-row reported processing time (= whole-batch
    # f(n) under continuous batching — ReplicaPool.last_service semantics)
    avg_batch = carry.avg_batch
    if spec.batch_kind != "none":
        # continuous batching (ReplicaPool._process_batched): per replica,
        # admission-window batch formation over arrival-sorted rows.  Each
        # fori_loop iteration forms ONE batch via a rank-space pointer —
        # O(N) iterations x O(N) work per replica, the same opt-in cost
        # class as the per-row jsq/least_land scan above.
        w = spec.batch_window
        bcap = spec.batch_cap if spec.batch_cap > 0 else N
        repk = jnp.where(m_o, replica_o, K)
        o3 = _lexsort2(repk.astype(dt), jnp.argsort(jnp.where(m_o, end_tx, inf)))
        m3 = m_o[o3]
        a3, k3 = end_tx[o3], repk[o3]
        done3 = jnp.zeros((N,), dtype=dt)
        serv3 = jnp.zeros((N,), dtype=dt)
        size3 = jnp.zeros((N,), dtype=dt)
        for k in range(K):
            mk = m3 & (k3 == k)
            n_k = mk.sum(dtype=jnp.int32)
            rk = jnp.cumsum(mk.astype(jnp.int32)) - 1  # rank within replica

            def bstep(i, st7, mk=mk, rk=rk, n_k=n_k):
                p, busy, done_k, serv_k, size_k, wire_k, queued_k = st7
                live = p < n_k
                rem = mk & (rk >= p)  # not-yet-batched rows, a3 ascending
                a0 = jnp.min(jnp.where(rem, a3, inf))
                t_open = jnp.maximum(busy, a0)
                nwin = (rem & (a3 <= t_open + w)).sum(dtype=jnp.int32)
                count = jnp.minimum(nwin, bcap)
                member = rem & (rk < p + count)  # smallest-a3 rows first
                arr_last = jnp.max(jnp.where(member, a3, _NEG))
                # cap binding: launch at the last member's landing; else
                # when the admission window closes
                t_start = jnp.where(nwin > bcap,
                                    jnp.maximum(t_open, arr_last), t_open + w)
                fb = _batch_latency(spec, count.astype(dt))
                done_v = t_start + fb
                upd = member & live
                done_k = jnp.where(upd, done_v, done_k)
                serv_k = jnp.where(upd, fb, serv_k)
                size_k = jnp.where(upd, count.astype(dt), size_k)
                wire_k = wire_k + jnp.where(live, fb, 0.0)
                queued_k = queued_k + jnp.where(upd, t_start - a3, 0.0).sum()
                busy = jnp.where(live, done_v, busy)
                p = p + jnp.where(live, count, 0)
                return p, busy, done_k, serv_k, size_k, wire_k, queued_k

            init = (jnp.zeros((), jnp.int32), rep_busy[k].astype(dt),
                    done3, serv3, size3, jnp.zeros((), dt), jnp.zeros((), dt))
            (_, busy_k, done3, serv3, size3, wire_k,
             queued_k) = jax.lax.fori_loop(0, N, bstep, init)
            rep_busy = rep_busy.at[k].set(busy_k)
            rep_n = rep_n.at[k].add(n_k)
            rep_busy_s = rep_busy_s.at[k].add(wire_k)
            rep_queued_s = rep_queued_s.at[k].add(queued_k)
        done_o = jnp.zeros((N,), dtype=dt).at[o3].set(done3)
        service_o = jnp.zeros((N,), dtype=dt).at[o3].set(serv3)
        size_o = jnp.zeros((N,), dtype=dt).at[o3].set(size3)
        n_live = m_o.sum(dtype=jnp.int32)
        obs = jnp.where(m_o, size_o, 0.0).sum() / jnp.maximum(n_live, 1)
        avg_batch = jnp.where(
            n_live > 0,
            (1.0 - spec.batch_beta) * carry.avg_batch + spec.batch_beta * obs,
            carry.avg_batch)
    elif spec.serial_replicas:
        repk = jnp.where(m_o, replica_o, K)
        o3 = _lexsort2(repk.astype(dt), jnp.argsort(jnp.where(m_o, end_tx, inf)))
        m3 = m_o[o3]
        a3, k3 = end_tx[o3], repk[o3]
        done3 = jnp.zeros((N,), dtype=dt)
        for k in range(K):
            mk = m3 & (k3 == k)
            end_k, busy_k, wire_k, queued_k = _masked_lindley(
                a3, jnp.full((N,), params.replica_st[k], dtype=dt), mk, rep_busy[k])
            done3 = jnp.where(mk, end_k, done3)
            rep_busy = rep_busy.at[k].set(busy_k)
            rep_n = rep_n.at[k].add(mk.sum(dtype=jnp.int32))
            rep_busy_s = rep_busy_s.at[k].add(wire_k)
            rep_queued_s = rep_queued_s.at[k].add(queued_k)
        done_o = jnp.zeros((N,), dtype=dt).at[o3].set(done3)
    else:  # infinite-capacity fixed delay (paper semantics)
        done_o = end_tx + st_row
        for k in range(K):
            mk = m_o & (replica_o == k)
            rep_n = rep_n.at[k].add(mk.sum(dtype=jnp.int32))
            rep_busy_s = rep_busy_s.at[k].add(
                jnp.where(mk, st_row, 0.0).sum())
            rep_busy = rep_busy.at[k].set(jnp.maximum(
                rep_busy[k], jnp.where(mk, done_o, _NEG).max()))
    lands_o = done_o + spec.latency

    # (8) deadline check + final correctness
    arr_o = x.arr.reshape(-1)[o].astype(dt)
    ok_o = m_o & (lands_o <= arr_o + spec.deadline)
    lands_grid = jnp.zeros((N,), dtype=dt).at[o].set(lands_o).reshape(S, B)
    ok_grid = jnp.zeros((N,), bool).at[o].set(ok_o).reshape(S, B)
    slow_sel = jnp.take_along_axis(
        x.slow_ok, res_idx[:, None, None].astype(jnp.int32), axis=2)[..., 0]
    final_ok = jnp.where(ok_grid, slow_sel, x.fast_ok)
    correct_r = (final_ok & valid).sum(axis=1, dtype=jnp.int32)

    # (9) EWMA bandwidth observations in transmission order
    # (FleetRunner.observe_bandwidth; replica queueing deliberately included;
    # replies report their actual processing time — the whole-batch f(n)
    # under continuous batching, per-request service time otherwise)
    seconds_o = lands_o - sub_o - spec.latency - service_o
    okbw = m_o & (seconds_o > 1e-9)
    rate_o = pay_o / jnp.where(okbw, seconds_o, 1.0)
    bw_est = ewma_fold(carry.bw_est, spec.bw_alpha, s_o, rate_o, okbw, S, B)
    bw_est = shard(bw_est, "streams")

    # (10) backlog bookkeeping: consume planned offloads, extend the rest
    if spec.oneshot:
        fleet = clear_fleet(fleet, active)
    else:
        fleet = consume_fleet(fleet, dec >= 0, jnp.zeros((S,), bool))
    add = valid & ~esc
    fleet = extend_fleet(fleet, arr, conf, add, spec.planner.L)

    # (11) metrics (AggregateMetrics.update_round inputs)
    lat = jnp.full((S, B), spec.t_fast, dtype=dt)
    lat = jnp.where(ok_grid, lands_grid - arr, lat)
    miss_grid = esc & ~ok_grid
    lat = jnp.where(miss_grid, spec.deadline, lat)
    off_counts = ok_grid.sum(axis=1, dtype=jnp.int32)
    miss_counts = miss_grid.sum(axis=1, dtype=jnp.int32)

    out = EngineCarry(
        fleet=fleet, bw_est=bw_est,
        cell_busy=cell_busy, cell_n=cell_n, cell_busy_s=cell_busy_s,
        cell_queued_s=cell_queued_s,
        rep_busy=rep_busy, rep_n=rep_n, rep_busy_s=rep_busy_s,
        rep_queued_s=rep_queued_s, rr_next=rr_next,
        frames=carry.frames + valid.sum(axis=1, dtype=jnp.int32),
        offloaded=carry.offloaded + off_counts,
        missed=carry.missed + miss_counts,
        correct=carry.correct + correct_r,
        avg_batch=avg_batch)

    if spec.collect == "none":
        return out, None
    z0 = jnp.zeros((0,))
    extras = dict(theta=z0, res_idx=z0, cap=z0, n_off=z0, n_frames=z0,
                  dec=z0, esc=z0, ok=z0, bw_est=z0, lengths=z0,
                  overflow=z0, inexact=z0)
    if spec.collect == "trace":
        extras = dict(theta=theta, res_idx=res_idx, cap=cap, n_off=n_off,
                      n_frames=plan.n_frames, dec=dec, esc=esc, ok=ok_grid,
                      bw_est=bw_est, lengths=fleet.length,
                      overflow=plan.overflow, inexact=plan.inexact)
    ys = RoundTrace(off_counts=off_counts, miss_counts=miss_counts,
                    correct=correct_r, lat=lat, **extras)
    return out, ys


def make_engine(spec: EngineSpec):
    """jit-compiled ``lax.scan`` over rounds, closed over the static spec.

    Returns ``run(params, carry, inputs) -> (carry, RoundTrace | None)``
    where ``inputs`` is a ``RoundInputs`` of (R, ...) stacked rounds.

    The carry is DONATED: its buffers are reused for the output carry, so
    the S=10^5 fleet state never round-trips through fresh allocations
    between calls.  Callers must not reuse a carry after passing it in —
    build a fresh one via ``init_carry`` (or thread the returned carry).
    """

    def run(params: EngineParams, carry: EngineCarry, inputs: RoundInputs):
        step = lambda c, x: _round_step(spec, params, c, x)
        return jax.lax.scan(step, carry, inputs)

    return jax.jit(run, donate_argnums=(1,))


def simulate(spec: EngineSpec, params: EngineParams, inputs: RoundInputs,
             carry: Optional[EngineCarry] = None):
    """One-shot convenience: init carry (unless given), run the scan."""
    if carry is None:
        carry = init_carry(spec, params)
    return make_engine(spec)(params, carry, inputs)


# --------------------------------------------------------------------------- #
# bridges from the numpy serving stack
# --------------------------------------------------------------------------- #


def spec_from_server(server, collect: str = "metrics") -> EngineSpec:
    """Build the static spec from a ``MultiStreamServer`` (validating that
    the configuration is expressible in fixed shapes)."""
    from repro.policy.base import OneShotPolicy
    from repro.policy.fleet_jax import spec_for_policy

    fleet = server.fleet
    if len(fleet.groups) != 1:
        raise ValueError("backend='jax' needs a homogeneous fleet "
                         f"(one policy group); got {len(fleet.groups)}")
    policy = fleet.groups[0][0]
    for cell in server.fabric.cells:
        up = cell.uplink
        if up.jitter > 0 or up.trace is not None:
            raise ValueError("backend='jax' supports constant-rate cell "
                             "uplinks only (no jitter/trace)")
    pool = server.fabric.pool
    batch_kind, batch_coeffs, batch_window, batch_cap = "none", (), 0.0, 0
    batch_beta = 0.25
    if getattr(pool, "batching", None) is not None and pool._batching_live:
        # live continuous batching: flatten the latency model into static
        # coefficients; a degenerate config stays on the per-request path
        # (bit-for-bit with the pre-batching engine, like numpy's routing)
        from repro.slowtier import model_coeffs

        batch_kind, batch_coeffs = model_coeffs(pool.batching.model)
        batch_window = float(pool.batching.window_s)
        cap = pool.batching.cap
        batch_cap = 0 if np.isinf(cap) else int(cap)
        batch_beta = pool.batch_beta
    planner = spec_for_policy(
        policy, sizes=fleet.sizes, acc_server=fleet.acc_server,
        deadline=fleet.deadline, latency=fleet.latency,
        server_time=fleet.server_time)
    return EngineSpec(
        n_streams=server.n_streams, batch=server.cfg.batch_size,
        n_cells=server.fabric.n_cells, n_replicas=server.fabric.n_replicas,
        planner=planner, placement=server.fabric.placement.policy,
        serial_replicas=server.fabric.pool.serial,
        scheduler=server.scheduler.policy,
        prune=bool(getattr(policy, "prune_expired", True)),
        oneshot=isinstance(policy, OneShotPolicy),
        t_fast=float(server.cfg.fast_time + server.cfg.calib_time),
        bw_alpha=fleet.bw_alpha, collect=collect,
        batch_kind=batch_kind, batch_coeffs=batch_coeffs,
        batch_window=batch_window, batch_cap=batch_cap,
        batch_beta=batch_beta)


def params_from_server(server, spec: EngineSpec) -> EngineParams:
    dt = spec.planner.dtype
    sched_w = server.scheduler.weights
    weights = (np.ones(server.n_streams) if sched_w is None
               else np.asarray(sched_w, dtype=np.float64))
    return EngineParams(
        sizes=jnp.asarray(server.fleet.sizes, dtype=dt),
        cell_bw=jnp.asarray([c.uplink.bandwidth_bps for c in server.fabric.cells],
                            dtype=dt),
        cell_of=jnp.asarray(server.fabric.cell_of, dtype=jnp.int32),
        replica_st=jnp.asarray(server.fabric.pool.server_time, dtype=dt),
        stream_bw=jnp.asarray(server._stream_bw, dtype=dt),
        weights=jnp.asarray(weights, dtype=dt),
        bw_init=jnp.asarray(server.fleet.bw_est, dtype=dt))
