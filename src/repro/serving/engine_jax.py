"""JAX serving engine: the whole fleet round as one jitted ``lax.scan`` step.

``MultiStreamServer.process_streams`` runs plan -> transmit -> observe ->
consume per round in host numpy (``serving/engine.py``).  This module is
the same round, re-expressed in fixed shapes so ``jax.jit`` compiles it
once and ``lax.scan`` advances it across rounds with zero host round
trips.  The numpy engine stays the semantic reference: every ordering
rule (escalation gate, SFQ tags, per-cell Lindley, placement, per-replica
Lindley, EWMA fold) is reproduced with the same tie-breaks, and the
differential tests (``tests/test_fleet_jax.py``) pin the two paths round
by round.

Shape/masking scheme (docs/jax_backend.md):

  * rounds are padded to the batch size B — trailing partial rounds get
    ``valid=False`` slots with ``arrival=+inf`` (never gate, never count);
  * backlogs are a ``PaddedFleet`` of pad L == ``max_backlog``;
  * one round's escalations live in the flat (S*B,) row space
    (``flat = s*B + slot``); masked rows ride through every recursion as
    no-ops — tx=0 / submit=-inf rows provably cannot perturb the running
    max a Lindley recursion takes over live rows;
  * the neural tiers run OUTSIDE the scan: confidences and per-resolution
    slow-tier correctness are precomputed per round (deterministic per
    frame, so identical to the numpy path's escalated-only batching) and
    fed to the scan as (R, S, B[, m]) inputs.

Stream-axis sharding: the carry's (S,)/(S, L)/(S, B) arrays are
constrained to the ``"streams"`` logical axis (``sharding/axes.py``), so
under a mesh the fleet splits across devices; off-mesh the constraint is
a no-op and the engine runs identically on one CPU.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.netsim import _FIXED_POINT_SWEEPS
from repro.policy.fleet_jax import (PaddedFleet, PlannerSpec, PlanOut,
                                    clear_fleet, consume_fleet, ewma_fold,
                                    extend_fleet, plan_fleet, prune_fleet)
from repro.sharding.axes import shard

__all__ = ["EngineSpec", "EngineGroup", "EngineParams", "RoundInputs",
           "EngineCarry", "RoundTrace", "init_carry", "make_engine",
           "simulate", "trace_lookup", "jax_unsupported", "supports_jax",
           "spec_from_server", "params_from_server"]

_NEG = -jnp.inf


# --------------------------------------------------------------------------- #
# static spec + pytrees
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class EngineGroup:
    """One policy group of a heterogeneous fleet (static).

    Mirrors one ``FleetRunner.groups`` entry: the group's planner (padded
    to the fleet-wide backlog width via ``spec_for_policy(pad_L=...)``),
    the global stream indices it owns, and the per-policy consume/prune
    semantics the engine otherwise reads from spec-level flags.
    """

    planner: PlannerSpec
    streams: tuple  # global stream indices (FleetRunner group order)
    prune: bool = True  # BacklogPolicy.prune_expired
    oneshot: bool = False  # OneShotPolicy consume semantics
    mb: int = 0  # the group's own max_backlog (<= planner.L)


@dataclass(frozen=True)
class EngineSpec:
    """Everything the compiled round step specializes on."""

    n_streams: int
    batch: int  # B — round batch size (rounds are padded to it)
    n_cells: int
    n_replicas: int
    planner: PlannerSpec
    placement: str = "round_robin"  # round_robin | jsq | least_land
    serial_replicas: bool = False
    scheduler: str = "round_robin"  # round_robin | fifo
    prune: bool = True  # BacklogPolicy.prune_expired
    oneshot: bool = False  # OneShotPolicy consume semantics
    t_fast: float = 0.028  # fast_time + calib_time
    bw_alpha: float = 0.3
    collect: str = "metrics"  # none | metrics | trace
    # continuous-batching slow tier (repro.slowtier); "none" = per-request
    # service exactly as before.  coeffs: flat=(st,); linear=(base, per_item);
    # step=(base, per_page, page_size)
    batch_kind: str = "none"  # none | flat | linear | step
    batch_coeffs: tuple = ()
    batch_window: float = 0.0  # admission window (s)
    batch_cap: int = 0  # occupancy cap per batch; 0 = unbounded
    batch_beta: float = 0.25  # occupancy EWMA fold
    # heterogeneous fleets: one EngineGroup per policy group; () keeps the
    # homogeneous single-planner graph (and spec-level prune/oneshot) as-is
    groups: tuple = ()
    # time-varying uplinks: in-scan BandwidthTrace replay and/or counter-
    # mode jitter.  False keeps the constant-rate Lindley graph untouched.
    varying: bool = False
    cell_jitter: tuple = ()  # (C,) per-cell jitter amplitude (0.0 = none)
    cell_seed: tuple = ()  # (C,) per-cell jitter seeds
    cell_trace: tuple = ()  # (C,) bool — cell replays a BandwidthTrace
    cell_loop: tuple = ()  # (C,) bool — trace wraps at trace_dur
    # split-computation action table (full A-length static vectors, frames
    # first; () = frames-only, which keeps the legacy compiled graph — and
    # the snapshot goldens pinned to it — untouched).  ``params.sizes`` is
    # (A,) either way; frame actions occupy [0, m) so frame-only decision
    # grids index it identically.
    act_t_dev: tuple = ()  # (A,) device prefix seconds per action
    act_srv_frac: tuple = ()  # (A,) fraction of replica service per action
    act_res: tuple = ()  # (A,) evaluation resolution index per action
    # telemetry: emit the FleetRecorder's per-round series as extra stacked
    # ``ys`` (obs/timeseries.py).  False keeps the RoundTrace pytree — and
    # therefore the compiled graph the snapshot goldens pin — unchanged
    # (the ts_* fields stay None and vanish as pytree leaves).
    telemetry: bool = False

    @property
    def has_splits(self) -> bool:
        return bool(self.act_t_dev)

    @property
    def m(self) -> int:
        return self.planner.m

    @property
    def deadline(self) -> float:
        return self.planner.deadline

    @property
    def latency(self) -> float:
        return self.planner.latency


class EngineParams(NamedTuple):
    """Per-run device arrays the step closes over (not traced per round).

    The trailing trace grids are ``None`` unless some cell replays a
    ``BandwidthTrace`` (``spec.cell_trace``); ``None`` leaves vanish from
    the pytree, so constant-rate runs keep the original structure.
    """

    sizes: jnp.ndarray  # (A,) payload bytes per action (== (m,) frames-only)
    cell_bw: jnp.ndarray  # (C,) base bytes/s (trace cells: nominal base)
    cell_of: jnp.ndarray  # (S,) int32
    replica_st: jnp.ndarray  # (K,) per-replica service time
    stream_bw: jnp.ndarray  # (S,) nominal cell rate (scheduler normalizer)
    weights: jnp.ndarray  # (S,) scheduler weights (ones = unweighted)
    bw_init: jnp.ndarray  # (S,) EWMA prior
    trace_t: Optional[jnp.ndarray] = None  # (C, T) breakpoints, +inf-padded
    trace_bps: Optional[jnp.ndarray] = None  # (C, T) rates, last-repeated
    trace_dur: Optional[jnp.ndarray] = None  # (C,) loop periods


class RoundInputs(NamedTuple):
    """One round of precomputed data-plane inputs (stack to (R, ...) for scan)."""

    arr: jnp.ndarray  # (S, B) arrival seconds; +inf on invalid slots
    valid: jnp.ndarray  # (S, B) bool
    conf: jnp.ndarray  # (S, B) calibrated confidence (fast pass)
    fast_ok: jnp.ndarray  # (S, B) bool — fast prediction correct
    slow_ok: jnp.ndarray  # (S, B, m) bool — slow prediction correct per res


class EngineCarry(NamedTuple):
    fleet: PaddedFleet
    bw_est: jnp.ndarray  # (S,)
    cell_busy: jnp.ndarray  # (C,) uplink busy-until cursors
    cell_n: jnp.ndarray  # (C,) int32 transfer counts
    cell_busy_s: jnp.ndarray  # (C,)
    cell_queued_s: jnp.ndarray  # (C,)
    rep_busy: jnp.ndarray  # (K,)
    rep_n: jnp.ndarray  # (K,) int32
    rep_busy_s: jnp.ndarray  # (K,)
    rep_queued_s: jnp.ndarray  # (K,)
    rr_next: jnp.ndarray  # () int32 round-robin placement cursor
    frames: jnp.ndarray  # (S,) int32
    offloaded: jnp.ndarray  # (S,) int32
    missed: jnp.ndarray  # (S,) int32
    correct: jnp.ndarray  # (S,) int32
    avg_batch: jnp.ndarray  # () slow-tier occupancy EWMA (1.0 = serial)
    # time-varying uplinks only (None leaves vanish from the pytree):
    jit_key: Optional[jnp.ndarray] = None  # (C, 2) uint32 per-cell PRNG keys
    fp_bad: Optional[jnp.ndarray] = None  # () bool — a fixed point never settled


class RoundTrace(NamedTuple):
    """Per-round outputs (``collect`` >= "metrics"; trace adds decisions)."""

    off_counts: jnp.ndarray  # (S,) int32
    miss_counts: jnp.ndarray  # (S,) int32
    correct: jnp.ndarray  # (S,) int32
    lat: jnp.ndarray  # (S, B)
    # -- collect == "trace" extras (zero-size placeholders otherwise) ----- #
    theta: jnp.ndarray
    res_idx: jnp.ndarray
    cap: jnp.ndarray
    n_off: jnp.ndarray
    n_frames: jnp.ndarray  # post-prune backlog lengths at plan time
    dec: jnp.ndarray  # (S, L) int8
    esc: jnp.ndarray  # (S, B) bool
    ok: jnp.ndarray  # (S, B) bool
    bw_est: jnp.ndarray  # (S,) after the round's EWMA fold
    lengths: jnp.ndarray  # (S,) backlog lengths after extend
    overflow: jnp.ndarray  # (S,) bool
    inexact: jnp.ndarray  # (S,) bool
    # -- spec.telemetry extras (None leaves vanish from the pytree) ------- #
    ts_bw_est: Optional[jnp.ndarray] = None  # (S,) post-fold EWMA
    ts_off_hist: Optional[jnp.ndarray] = None  # (A,) int32 planned offloads
    ts_cell_busy_s: Optional[jnp.ndarray] = None  # (C,) carry-relative
    ts_cell_queued_s: Optional[jnp.ndarray] = None  # (C,)
    ts_rep_busy_s: Optional[jnp.ndarray] = None  # (K,)
    ts_rep_queued_s: Optional[jnp.ndarray] = None  # (K,)
    ts_avg_batch: Optional[jnp.ndarray] = None  # () post-round EWMA
    ts_st_est: Optional[jnp.ndarray] = None  # () planner's T^o this round


def init_carry(spec: EngineSpec, params: EngineParams) -> EngineCarry:
    S, C, K, L = spec.n_streams, spec.n_cells, spec.n_replicas, spec.planner.L
    dt = spec.planner.dtype
    z = lambda *s: jnp.zeros(s, dtype=dt)
    zi = lambda *s: jnp.zeros(s, dtype=jnp.int32)
    fleet = PaddedFleet(z(S, L), z(S, L), zi(S))
    # copy=True: same-dtype astype would alias params.bw_init's buffer, and
    # the engine donates its carry (make_engine) — an aliased buffer would
    # be deleted out from under params on the first step
    extra = {}
    if spec.varying:
        extra["fp_bad"] = jnp.zeros((), bool)
        if any(j > 0 for j in spec.cell_jitter):
            extra["jit_key"] = jnp.stack(
                [jax.random.PRNGKey(int(s)) for s in spec.cell_seed])
    return EngineCarry(
        fleet=fleet, bw_est=jnp.array(params.bw_init, dtype=dt, copy=True),
        cell_busy=z(C), cell_n=zi(C), cell_busy_s=z(C), cell_queued_s=z(C),
        rep_busy=z(K), rep_n=zi(K), rep_busy_s=z(K), rep_queued_s=z(K),
        rr_next=jnp.zeros((), jnp.int32),
        frames=zi(S), offloaded=zi(S), missed=zi(S), correct=zi(S),
        avg_batch=jnp.ones((), dtype=dt), **extra)


# --------------------------------------------------------------------------- #
# masked recursions
# --------------------------------------------------------------------------- #


def _masked_lindley(sub, tx, mask, busy0):
    """end_i = max(sub_i, end_{i-1}) + tx_i over the masked rows, with
    masked rows as exact no-ops: tx=0 / sub=-inf rows contribute the
    candidate ``busy0 - excl <= busy0``, which the first live row's
    ``max(sub, busy0) - 0 >= busy0`` already dominates, so the running max
    over live rows is untouched.  Returns (end, new_busy, wire, queued)."""
    txm = jnp.where(mask, tx, 0.0)
    subm = jnp.where(mask, sub, _NEG)
    csum = jnp.cumsum(txm)
    eff = jnp.maximum(subm, busy0) - (csum - txm)
    end = jax.lax.cummax(eff) + csum
    any_live = mask.any()
    new_busy = jnp.where(any_live, jnp.where(mask, end, _NEG).max(), busy0)
    wire = txm.sum()
    queued = jnp.where(mask, jnp.clip(end - txm - subm, 0.0, None), 0.0).sum()
    return end, new_busy, wire, queued


def _lexsort2(primary, rows_sorted_by_secondary):
    """Stable argsort by ``primary`` applied on top of an existing stable
    secondary order — the composed-argsort form of ``np.lexsort``."""
    o = rows_sorted_by_secondary
    return o[jnp.argsort(primary[o])]


def trace_lookup(t_grid, bps_grid, ts):
    """Rate in effect at each time over one padded breakpoint grid — the
    jnp mirror of ``BandwidthTrace.bandwidth_at``'s right-``searchsorted``
    minus one.  Callers mod looping times by the period first; the +inf
    pad breakpoints (``BandwidthTrace.grid``) never capture a finite time."""
    idx = jnp.searchsorted(t_grid, ts, side="right") - 1
    return bps_grid[jnp.clip(idx, 0, t_grid.shape[0] - 1)]


def _cell_bw_at(spec: EngineSpec, params: EngineParams, c: int, key_c, ts):
    """Instantaneous bandwidth of cell ``c`` at times ``ts`` — in-scan
    ``Uplink.bandwidth_at``: trace replay (looping times mod the period)
    times counter-mode jitter factors drawn at the raw integer second.
    The factors are float32 on both backends (``_counter_jitter_factors``),
    so host and device derive the same per-second channel bit-for-bit."""
    dt = spec.planner.dtype
    if spec.cell_trace[c]:
        tm = jnp.mod(ts, params.trace_dur[c]) if spec.cell_loop[c] else ts
        base = trace_lookup(params.trace_t[c], params.trace_bps[c], tm)
    else:
        base = jnp.full(ts.shape, params.cell_bw[c], dtype=dt)
    if spec.cell_jitter[c] > 0:
        secs = ts.astype(jnp.int32)
        keys = jax.vmap(lambda s: jax.random.fold_in(key_c, s))(secs)
        normals = jax.vmap(lambda k: jax.random.normal(k, dtype=jnp.float32))(keys)
        fac = jnp.clip(jnp.float32(1.0)
                       + jnp.float32(spec.cell_jitter[c]) * normals,
                       jnp.float32(0.2), jnp.float32(2.0))
        base = base * fac.astype(dt)
    return base


def _masked_lindley_varying(spec: EngineSpec, params: EngineParams, c: int,
                            key_c, sub, mask, payload, busy0):
    """Time-varying masked Lindley: each row's rate depends on its start
    time, which depends on the previous row's end — a serial chain.
    Mirrors ``Uplink.upload_batch``'s fixed-point iteration under jit
    (``lax.while_loop``, same sweep cap): guess the starts, look every
    row's rate up in one pass, re-run the Lindley recursion, repeat until
    the starts stop moving.  The numpy path falls back to an exact serial
    loop if the iteration never settles; that has no fixed-shape analogue,
    so this raises the sticky ``fp_bad`` carry flag instead (the bridge
    warns, the differential tests assert it stays clean).  Returns
    ``(end, new_busy, wire, queued, fp_bad)``."""
    subm = jnp.where(mask, sub, _NEG)
    base = jnp.maximum(subm, busy0)  # eff numerator == the start guess

    def sweep(starts):
        ts = jnp.where(mask, starts, 0.0)  # guard masked +inf/-inf rows
        bw = _cell_bw_at(spec, params, c, key_c, ts)
        tx = jnp.where(mask, payload / bw, 0.0)
        csum = jnp.cumsum(tx)
        end = jax.lax.cummax(base - (csum - tx)) + csum
        return end, tx

    def settled(a, b):  # np.array_equal over the live rows
        return (jnp.where(mask, a, 0.0) == jnp.where(mask, b, 0.0)).all()

    end0, tx0 = sweep(base)
    state0 = (jnp.ones((), jnp.int32), end0 - tx0, end0, tx0,
              settled(end0 - tx0, base))

    def cond(state):
        i, _, _, _, conv = state
        return ~conv & (i < _FIXED_POINT_SWEEPS)

    def body(state):
        i, starts, _, _, _ = state
        end, tx = sweep(starts)
        return i + 1, end - tx, end, tx, settled(end - tx, starts)

    _, _, end, tx, conv = jax.lax.while_loop(cond, body, state0)
    any_live = mask.any()
    new_busy = jnp.where(any_live, jnp.where(mask, end, _NEG).max(), busy0)
    wire = tx.sum()
    queued = jnp.where(mask, jnp.clip(end - tx - subm, 0.0, None), 0.0).sum()
    return end, new_busy, wire, queued, any_live & ~conv


def _plan_groups(spec: EngineSpec, fleet: PaddedFleet, now, bw, st_eff):
    """Heterogeneous control plane: gather each policy group's streams,
    run the group's own planner, scatter the outputs back into fleet-wide
    arrays — ``FleetRunner.plan_all``'s group loop with static index sets,
    compiling one planner subgraph per group.  Stream order inside the
    engine is never permuted (the SFQ/argsort tie-breaks key on global
    stream ids); streams outside every group (S-padding) keep the
    inactive-row defaults (dec=-1, theta=0, r°=m-1)."""
    S, L, m = spec.n_streams, spec.planner.L, spec.m
    dt = spec.planner.dtype
    out = PlanOut(
        dec=jnp.full((S, L), -1, dtype=jnp.int8),
        theta=jnp.zeros((S,), dtype=dt),
        resolution=jnp.full((S,), m - 1, dtype=jnp.int32),
        n_offloads=jnp.zeros((S,), jnp.int32),
        total_gain=jnp.zeros((S,), dtype=dt),
        base_acc=jnp.zeros((S,), dtype=dt),
        n_frames=fleet.length,
        overflow=jnp.zeros((S,), bool),
        inexact=jnp.zeros((S,), bool))
    for g in spec.groups:
        idx = jnp.asarray(g.streams, dtype=jnp.int32)
        sub = PaddedFleet(fleet.arrival[idx], fleet.conf[idx], fleet.length[idx])
        p = plan_fleet(g.planner, sub, now[idx], bw[idx], st_eff)
        out = PlanOut(
            dec=out.dec.at[idx].set(p.dec),
            theta=out.theta.at[idx].set(p.theta),
            resolution=out.resolution.at[idx].set(p.resolution),
            n_offloads=out.n_offloads.at[idx].set(p.n_offloads),
            total_gain=out.total_gain.at[idx].set(p.total_gain),
            base_acc=out.base_acc.at[idx].set(p.base_acc),
            n_frames=out.n_frames,
            overflow=out.overflow.at[idx].set(p.overflow),
            inexact=out.inexact.at[idx].set(p.inexact))
    return out


def _group_flags(spec: EngineSpec):
    """Static per-stream (prune, oneshot, max_backlog) rows from the group
    table; padded/ungrouped streams get (False, False, 0) — their backlogs
    are provably empty, so every choice is a no-op."""
    S = spec.n_streams
    prune = np.zeros(S, dtype=bool)
    oneshot = np.zeros(S, dtype=bool)
    mb = np.zeros(S, dtype=np.int32)
    for g in spec.groups:
        ss = list(g.streams)
        prune[ss] = g.prune
        oneshot[ss] = g.oneshot
        mb[ss] = g.mb
    return prune, oneshot, mb


def _batch_latency(spec: EngineSpec, n):
    """The slow tier's latency curve f(n) from the flat static coefficients
    (mirrors ``repro.slowtier``'s LatencyModel classes in jnp)."""
    c = spec.batch_coeffs
    if spec.batch_kind == "flat":
        return c[0] * n
    if spec.batch_kind == "linear":
        return c[0] + c[1] * n
    if spec.batch_kind == "step":
        return c[0] + c[1] * jnp.ceil(n / c[2])
    raise ValueError(f"unknown batch_kind {spec.batch_kind!r}")


# --------------------------------------------------------------------------- #
# the round step
# --------------------------------------------------------------------------- #


def _round_step(spec: EngineSpec, params: EngineParams,
                carry: EngineCarry, x: RoundInputs):
    S, B, C, K = spec.n_streams, spec.batch, spec.n_cells, spec.n_replicas
    L, m = spec.planner.L, spec.m
    dt = spec.planner.dtype
    N = S * B
    inf = jnp.inf
    arr = shard(x.arr.astype(dt), "streams", None)
    valid, conf = x.valid, x.conf.astype(dt)

    # (1) active streams; retire the rest (FleetRunner.retire)
    active = valid.any(axis=1)
    fleet = clear_fleet(carry.fleet, ~active)

    # (2) control plane: prune + one batched plan (FleetRunner.plan_all);
    # heterogeneous fleets prune per group's policy and plan group by group
    now = arr.min(axis=1)  # first valid arrival; +inf when none
    if spec.groups:
        g_prune, g_oneshot, g_mb = _group_flags(spec)
        prune_mask = active & jnp.asarray(g_prune)
    else:
        prune_mask = active if spec.prune else jnp.zeros_like(active)
    fleet = prune_fleet(fleet, now, spec.deadline, prune_mask)
    fleet = PaddedFleet(shard(fleet.arrival, "streams", None),
                        shard(fleet.conf, "streams", None),
                        shard(fleet.length, "streams"))
    bw_plan = jnp.maximum(carry.bw_est, 1.0)  # same dead-link floor
    st_eff = None
    if spec.batch_kind != "none":
        # occupancy-calibrated T^o = f(expected_batch)/expected_batch at the
        # observed occupancy EWMA (ReplicaPool.expected_server_time)
        nb = jnp.maximum(carry.avg_batch, 1.0)
        st_eff = (_batch_latency(spec, nb) / nb).astype(dt)
    if spec.groups:
        plan = _plan_groups(spec, fleet, now, bw_plan, st_eff)
    elif st_eff is None:
        plan = plan_fleet(spec.planner, fleet, now, bw_plan)
    else:
        plan = plan_fleet(spec.planner, fleet, now, bw_plan, st_eff)
    theta = jnp.where(active, plan.theta, 0.0)
    res_idx = jnp.where(active, plan.resolution, m - 1)
    n_off = jnp.where(active, plan.n_offloads, 0)
    dec = jnp.where(active[:, None], plan.dec, jnp.int8(-1))
    cap = jnp.where(active, jnp.maximum(n_off, 1), 0)

    # (3) escalation gate (select_escalations): per stream the cap lowest
    # confidences below theta — stable conf argsort + cumsum gate
    conf_gate = jnp.where(valid, conf, inf)
    o_slot = jnp.argsort(conf_gate, axis=1)
    gate_sorted = jnp.take_along_axis(conf_gate < theta[:, None], o_slot, axis=1)
    take_sorted = gate_sorted & (jnp.cumsum(gate_sorted, axis=1) <= cap[:, None])
    esc = jnp.zeros((S, B), bool).at[
        jnp.arange(S)[:, None], o_slot].set(take_sorted)

    payload_s = params.sizes[res_idx].astype(dt)  # (S,) planned upload bytes
    t_ready = arr + spec.t_fast
    if spec.has_splits:
        # a split action's upload leaves the device only after the model
        # prefix runs — shifts SFQ readiness AND the wire submit below
        t_dev_s = jnp.asarray(spec.act_t_dev, dtype=dt)[res_idx]  # (S,)
        t_ready = t_ready + t_dev_s[:, None]

    # (4) fair uplink schedule (FairScheduler.order).  Cost is constant per
    # stream within a round, so the SFQ tag recurrence unrolls over slots
    # (per-stream arrivals strictly ascend, so slot order == t_ready order).
    esc_flat = esc.reshape(-1)
    t_ready_flat = jnp.where(esc, t_ready, inf).reshape(-1)
    o = jnp.argsort(t_ready_flat)  # stable: ties keep (stream, slot) order
    if spec.scheduler == "round_robin":
        cost_s = payload_s / params.stream_bw / params.weights
        tags = jnp.full((S, B), inf, dtype=dt)
        prev = jnp.full((S,), _NEG, dtype=dt)
        for d in range(B):
            cand = jnp.maximum(t_ready[:, d], prev + cost_s)
            tags = tags.at[:, d].set(jnp.where(esc[:, d], cand, inf))
            prev = jnp.where(esc[:, d], cand, prev)
        o = _lexsort2(tags.reshape(-1), o)

    # (5) fabric transmit: per-cell masked Lindley over the scheduled rows
    stream_flat = jnp.repeat(jnp.arange(S, dtype=jnp.int32), B)
    s_o = stream_flat[o]
    m_o = esc_flat[o]
    sub_o = x.arr.reshape(-1)[o] + spec.t_fast  # real t_ready per row
    if spec.has_splits:
        sub_o = sub_o + t_dev_s[s_o]  # prefix runs before the upload
    pay_o = params.sizes[res_idx[s_o]].astype(dt)
    cell_o = params.cell_of[s_o]
    end_tx = jnp.zeros((N,), dtype=dt)
    cell_busy, cell_n = carry.cell_busy, carry.cell_n
    cell_busy_s, cell_queued_s = carry.cell_busy_s, carry.cell_queued_s
    fp_bad = carry.fp_bad
    for c in range(C):
        mk = m_o & (cell_o == c)
        if spec.varying and (spec.cell_trace[c] or spec.cell_jitter[c] > 0):
            key_c = None if carry.jit_key is None else carry.jit_key[c]
            end_c, busy_c, wire_c, queued_c, bad_c = _masked_lindley_varying(
                spec, params, c, key_c, sub_o, mk, pay_o, cell_busy[c])
            fp_bad = fp_bad | bad_c
        else:
            end_c, busy_c, wire_c, queued_c = _masked_lindley(
                sub_o, pay_o / params.cell_bw[c], mk, cell_busy[c])
        end_tx = jnp.where(mk, end_c, end_tx)
        cell_busy = cell_busy.at[c].set(busy_c)
        cell_n = cell_n.at[c].add(mk.sum(dtype=jnp.int32))
        cell_busy_s = cell_busy_s.at[c].add(wire_c)
        cell_queued_s = cell_queued_s.at[c].add(queued_c)

    # (6) replica placement in upload-arrival order (Placement.assign)
    end_m = jnp.where(m_o, end_tx, inf)
    o2 = jnp.argsort(end_m)  # stable: ties keep scheduler order
    m2 = m_o[o2]
    rr_next = carry.rr_next
    if spec.placement == "round_robin":
        rank = jnp.cumsum(m2.astype(jnp.int32)) - 1
        rep2 = (rr_next + rank) % K
        rr_next = (rr_next + m_o.sum(dtype=jnp.int32)) % K
    else:
        st = params.replica_st.astype(dt)

        def pstep(busy, inp):
            t_i, live = inp
            if spec.placement == "jsq":
                k = jnp.argmin(busy)
            else:  # least_land
                k = jnp.argmin(jnp.maximum(t_i, busy) + st)
            upd = busy.at[k].set(jnp.maximum(t_i, busy[k]) + st[k])
            return jnp.where(live, upd, busy), jnp.where(live, k, 0).astype(jnp.int32)

        _, rep2 = jax.lax.scan(pstep, carry.rep_busy.astype(dt), (end_m[o2], m2))
    replica_o = jnp.zeros((N,), jnp.int32).at[o2].set(rep2.astype(jnp.int32))

    # (7) replica pool service (ReplicaPool.process)
    rep_busy, rep_n = carry.rep_busy, carry.rep_n
    rep_busy_s, rep_queued_s = carry.rep_busy_s, carry.rep_queued_s
    st_row = params.replica_st[replica_o].astype(dt)
    if spec.has_splits:
        # split suffixes cost srv_frac of the replica's service time
        # (ReplicaPool.process's per-request service_scale); incompatible
        # with continuous batching — jax_unsupported rejects that pairing
        srv_o = jnp.asarray(spec.act_srv_frac, dtype=dt)[res_idx[s_o]]  # (N,)
        st_row = st_row * srv_o
    service_o = st_row  # per-row reported processing time (= whole-batch
    # f(n) under continuous batching — ReplicaPool.last_service semantics)
    avg_batch = carry.avg_batch
    if spec.batch_kind != "none":
        # continuous batching (ReplicaPool._process_batched): per replica,
        # admission-window batch formation over arrival-sorted rows.  Each
        # fori_loop iteration forms ONE batch via a rank-space pointer —
        # O(N) iterations x O(N) work per replica, the same opt-in cost
        # class as the per-row jsq/least_land scan above.
        w = spec.batch_window
        bcap = spec.batch_cap if spec.batch_cap > 0 else N
        repk = jnp.where(m_o, replica_o, K)
        o3 = _lexsort2(repk.astype(dt), jnp.argsort(jnp.where(m_o, end_tx, inf)))
        m3 = m_o[o3]
        a3, k3 = end_tx[o3], repk[o3]
        done3 = jnp.zeros((N,), dtype=dt)
        serv3 = jnp.zeros((N,), dtype=dt)
        size3 = jnp.zeros((N,), dtype=dt)
        for k in range(K):
            mk = m3 & (k3 == k)
            n_k = mk.sum(dtype=jnp.int32)
            rk = jnp.cumsum(mk.astype(jnp.int32)) - 1  # rank within replica

            def bstep(i, st7, mk=mk, rk=rk, n_k=n_k):
                p, busy, done_k, serv_k, size_k, wire_k, queued_k = st7
                live = p < n_k
                rem = mk & (rk >= p)  # not-yet-batched rows, a3 ascending
                a0 = jnp.min(jnp.where(rem, a3, inf))
                t_open = jnp.maximum(busy, a0)
                nwin = (rem & (a3 <= t_open + w)).sum(dtype=jnp.int32)
                count = jnp.minimum(nwin, bcap)
                member = rem & (rk < p + count)  # smallest-a3 rows first
                arr_last = jnp.max(jnp.where(member, a3, _NEG))
                # cap binding: launch at the last member's landing; else
                # when the admission window closes
                t_start = jnp.where(nwin > bcap,
                                    jnp.maximum(t_open, arr_last), t_open + w)
                fb = _batch_latency(spec, count.astype(dt))
                done_v = t_start + fb
                upd = member & live
                done_k = jnp.where(upd, done_v, done_k)
                serv_k = jnp.where(upd, fb, serv_k)
                size_k = jnp.where(upd, count.astype(dt), size_k)
                wire_k = wire_k + jnp.where(live, fb, 0.0)
                queued_k = queued_k + jnp.where(upd, t_start - a3, 0.0).sum()
                busy = jnp.where(live, done_v, busy)
                p = p + jnp.where(live, count, 0)
                return p, busy, done_k, serv_k, size_k, wire_k, queued_k

            init = (jnp.zeros((), jnp.int32), rep_busy[k].astype(dt),
                    done3, serv3, size3, jnp.zeros((), dt), jnp.zeros((), dt))
            (_, busy_k, done3, serv3, size3, wire_k,
             queued_k) = jax.lax.fori_loop(0, N, bstep, init)
            rep_busy = rep_busy.at[k].set(busy_k)
            rep_n = rep_n.at[k].add(n_k)
            rep_busy_s = rep_busy_s.at[k].add(wire_k)
            rep_queued_s = rep_queued_s.at[k].add(queued_k)
        done_o = jnp.zeros((N,), dtype=dt).at[o3].set(done3)
        service_o = jnp.zeros((N,), dtype=dt).at[o3].set(serv3)
        size_o = jnp.zeros((N,), dtype=dt).at[o3].set(size3)
        n_live = m_o.sum(dtype=jnp.int32)
        obs = jnp.where(m_o, size_o, 0.0).sum() / jnp.maximum(n_live, 1)
        avg_batch = jnp.where(
            n_live > 0,
            (1.0 - spec.batch_beta) * carry.avg_batch + spec.batch_beta * obs,
            carry.avg_batch)
    elif spec.serial_replicas:
        repk = jnp.where(m_o, replica_o, K)
        o3 = _lexsort2(repk.astype(dt), jnp.argsort(jnp.where(m_o, end_tx, inf)))
        m3 = m_o[o3]
        a3, k3 = end_tx[o3], repk[o3]
        done3 = jnp.zeros((N,), dtype=dt)
        for k in range(K):
            mk = m3 & (k3 == k)
            st_k = (params.replica_st[k].astype(dt) * srv_o[o3]
                    if spec.has_splits
                    else jnp.full((N,), params.replica_st[k], dtype=dt))
            end_k, busy_k, wire_k, queued_k = _masked_lindley(
                a3, st_k, mk, rep_busy[k])
            done3 = jnp.where(mk, end_k, done3)
            rep_busy = rep_busy.at[k].set(busy_k)
            rep_n = rep_n.at[k].add(mk.sum(dtype=jnp.int32))
            rep_busy_s = rep_busy_s.at[k].add(wire_k)
            rep_queued_s = rep_queued_s.at[k].add(queued_k)
        done_o = jnp.zeros((N,), dtype=dt).at[o3].set(done3)
    else:  # infinite-capacity fixed delay (paper semantics)
        done_o = end_tx + st_row
        for k in range(K):
            mk = m_o & (replica_o == k)
            rep_n = rep_n.at[k].add(mk.sum(dtype=jnp.int32))
            rep_busy_s = rep_busy_s.at[k].add(
                jnp.where(mk, st_row, 0.0).sum())
            rep_busy = rep_busy.at[k].set(jnp.maximum(
                rep_busy[k], jnp.where(mk, done_o, _NEG).max()))
    lands_o = done_o + spec.latency

    # (8) deadline check + final correctness
    arr_o = x.arr.reshape(-1)[o].astype(dt)
    ok_o = m_o & (lands_o <= arr_o + spec.deadline)
    lands_grid = jnp.zeros((N,), dtype=dt).at[o].set(lands_o).reshape(S, B)
    ok_grid = jnp.zeros((N,), bool).at[o].set(ok_o).reshape(S, B)
    eval_res = (jnp.asarray(spec.act_res, jnp.int32)[res_idx]
                if spec.has_splits else res_idx)  # action -> eval resolution
    slow_sel = jnp.take_along_axis(
        x.slow_ok, eval_res[:, None, None].astype(jnp.int32), axis=2)[..., 0]
    final_ok = jnp.where(ok_grid, slow_sel, x.fast_ok)
    correct_r = (final_ok & valid).sum(axis=1, dtype=jnp.int32)

    # (9) EWMA bandwidth observations in transmission order
    # (FleetRunner.observe_bandwidth; replica queueing deliberately included;
    # replies report their actual processing time — the whole-batch f(n)
    # under continuous batching, per-request service time otherwise)
    seconds_o = lands_o - sub_o - spec.latency - service_o
    okbw = m_o & (seconds_o > 1e-9)
    rate_o = pay_o / jnp.where(okbw, seconds_o, 1.0)
    bw_est = ewma_fold(carry.bw_est, spec.bw_alpha, s_o, rate_o, okbw, S, B)
    bw_est = shard(bw_est, "streams")

    # (10) backlog bookkeeping: consume planned offloads, extend the rest
    add = valid & ~esc
    if spec.groups:
        # mixed per-policy semantics: one consume pass takes the non-
        # oneshot offloads and clears the oneshot streams (FleetRunner
        # .consume), then extend trims each stream to its group's bound
        osh = jnp.asarray(g_oneshot)
        fleet = consume_fleet(fleet, (dec >= 0) & ~osh[:, None], osh & active)
        fleet = extend_fleet(fleet, arr, conf, add, jnp.asarray(g_mb))
    else:
        if spec.oneshot:
            fleet = clear_fleet(fleet, active)
        else:
            fleet = consume_fleet(fleet, dec >= 0, jnp.zeros((S,), bool))
        fleet = extend_fleet(fleet, arr, conf, add, spec.planner.L)

    # (11) metrics (AggregateMetrics.update_round inputs)
    lat = jnp.full((S, B), spec.t_fast, dtype=dt)
    lat = jnp.where(ok_grid, lands_grid - arr, lat)
    miss_grid = esc & ~ok_grid
    lat = jnp.where(miss_grid, spec.deadline, lat)
    off_counts = ok_grid.sum(axis=1, dtype=jnp.int32)
    miss_counts = miss_grid.sum(axis=1, dtype=jnp.int32)

    out = EngineCarry(
        fleet=fleet, bw_est=bw_est,
        cell_busy=cell_busy, cell_n=cell_n, cell_busy_s=cell_busy_s,
        cell_queued_s=cell_queued_s,
        rep_busy=rep_busy, rep_n=rep_n, rep_busy_s=rep_busy_s,
        rep_queued_s=rep_queued_s, rr_next=rr_next,
        frames=carry.frames + valid.sum(axis=1, dtype=jnp.int32),
        offloaded=carry.offloaded + off_counts,
        missed=carry.missed + miss_counts,
        correct=carry.correct + correct_r,
        avg_batch=avg_batch, jit_key=carry.jit_key, fp_bad=fp_bad)

    if spec.collect == "none":
        if spec.telemetry:
            raise ValueError("spec.telemetry needs collect >= 'metrics' — "
                             "the recorder's series ride on the ys pytree")
        return out, None
    z0 = jnp.zeros((0,))
    extras = dict(theta=z0, res_idx=z0, cap=z0, n_off=z0, n_frames=z0,
                  dec=z0, esc=z0, ok=z0, bw_est=z0, lengths=z0,
                  overflow=z0, inexact=z0)
    if spec.collect == "trace":
        extras = dict(theta=theta, res_idx=res_idx, cap=cap, n_off=n_off,
                      n_frames=plan.n_frames, dec=dec, esc=esc, ok=ok_grid,
                      bw_est=bw_est, lengths=fleet.length,
                      overflow=plan.overflow, inexact=plan.inexact)
    if spec.telemetry:
        # the FleetRecorder's per-round record (obs/timeseries.py): the
        # cumulative per-stream counters come from host cumsums of the
        # off/miss/correct columns above (integer-exact), so only the
        # simulated-state series are emitted here.  The histogram over the
        # action table is exact: every planned offload of stream s carries
        # action res_idx[s], and inactive/pad rows plan n_off == 0.
        A = params.sizes.shape[0]
        extras.update(
            ts_bw_est=bw_est,
            ts_off_hist=jnp.zeros((A,), jnp.int32).at[res_idx].add(
                n_off.astype(jnp.int32)),
            ts_cell_busy_s=cell_busy_s, ts_cell_queued_s=cell_queued_s,
            ts_rep_busy_s=rep_busy_s, ts_rep_queued_s=rep_queued_s,
            ts_avg_batch=avg_batch,
            ts_st_est=(st_eff if st_eff is not None
                       else jnp.asarray(spec.planner.server_time, dtype=dt)))
    ys = RoundTrace(off_counts=off_counts, miss_counts=miss_counts,
                    correct=correct_r, lat=lat, **extras)
    return out, ys


def make_engine(spec: EngineSpec):
    """jit-compiled ``lax.scan`` over rounds, closed over the static spec.

    Returns ``run(params, carry, inputs) -> (carry, RoundTrace | None)``
    where ``inputs`` is a ``RoundInputs`` of (R, ...) stacked rounds.

    The carry is DONATED: its buffers are reused for the output carry, so
    the S=10^5 fleet state never round-trips through fresh allocations
    between calls.  Callers must not reuse a carry after passing it in —
    build a fresh one via ``init_carry`` (or thread the returned carry).
    """

    def run(params: EngineParams, carry: EngineCarry, inputs: RoundInputs):
        step = lambda c, x: _round_step(spec, params, c, x)
        return jax.lax.scan(step, carry, inputs)

    return jax.jit(run, donate_argnums=(1,))


def simulate(spec: EngineSpec, params: EngineParams, inputs: RoundInputs,
             carry: Optional[EngineCarry] = None):
    """One-shot convenience: init carry (unless given), run the scan."""
    if carry is None:
        carry = init_carry(spec, params)
    return make_engine(spec)(params, carry, inputs)


# --------------------------------------------------------------------------- #
# bridges from the numpy serving stack
# --------------------------------------------------------------------------- #


def jax_unsupported(server) -> list:
    """Every reason this ``MultiStreamServer`` cannot run on
    ``backend="jax"`` — the one shared capability check (used by the
    server constructor, ``FleetRunner``, and callers probing via
    ``supports_jax``).  Returns an empty list when fully supported;
    otherwise one entry per unsupported feature, so the error names all
    of them instead of the first one hit."""
    from repro.policy.fleet_jax import jax_unsupported_policies

    reasons = jax_unsupported_policies([g[0] for g in server.fleet.groups])
    for c, cell in enumerate(server.fabric.cells):
        up = cell.uplink
        if up.jitter > 0 and up.jitter_mode != "counter":
            reasons.append(
                f"cell {c}: jitter_mode='pcg' draws from a host rng the "
                "compiled scan cannot reproduce — construct the Uplink "
                "with jitter_mode='counter' for in-scan jitter")
    if server.fleet.actions is not None:
        at = server.fleet.action_table
        if at.n_actions > 127:
            reasons.append(
                f"split action table with {at.n_actions} actions exceeds the "
                "int8 decision grid (subsample the cut catalog to <= 127)")
        pool = server.fabric.pool
        if getattr(pool, "batching", None) is not None and pool._batching_live:
            reasons.append(
                "split actions with a live continuous-batching slow tier: "
                "batches share one f(n) latency curve, so per-request "
                "srv_frac scaling is not expressible (numpy raises too)")
    tel = getattr(server, "telemetry", None)
    if tel is not None and (tel.tracer is not None or getattr(tel, "trace", False)):
        reasons.append(
            "frame-lifecycle tracing (Telemetry.trace) needs per-frame host "
            "visibility the compiled scan does not have — use the numpy "
            "backend for traces (the per-round recorder works on both)")
    return reasons


def supports_jax(server) -> bool:
    """True iff every feature of this server's configuration is
    expressible in the compiled round scan (shared predicate; the
    per-feature reasons come from ``jax_unsupported``)."""
    return not jax_unsupported(server)


def spec_from_server(server, collect: str = "metrics",
                     pad_streams: Optional[int] = None,
                     telemetry: bool = False) -> EngineSpec:
    """Build the static spec from a ``MultiStreamServer`` (validating that
    the configuration is expressible in fixed shapes).  ``pad_streams``
    widens the stream axis to a device multiple for mesh sharding — the
    extra rows never see a valid frame, so they are provably inert."""
    from repro.policy.base import OneShotPolicy
    from repro.policy.fleet_jax import spec_for_policy

    reasons = jax_unsupported(server)
    if reasons:
        raise ValueError("backend='jax' cannot express this configuration: "
                         + "; ".join(reasons))
    if telemetry and collect == "none":
        collect = "metrics"  # the recorder's series ride on the ys pytree
    fleet = server.fleet
    S = server.n_streams if pad_streams is None else int(pad_streams)
    if S < server.n_streams:
        raise ValueError(f"pad_streams={S} < n_streams={server.n_streams}")
    pool = server.fabric.pool
    batch_kind, batch_coeffs, batch_window, batch_cap = "none", (), 0.0, 0
    batch_beta = 0.25
    if getattr(pool, "batching", None) is not None and pool._batching_live:
        # live continuous batching: flatten the latency model into static
        # coefficients; a degenerate config stays on the per-request path
        # (bit-for-bit with the pre-batching engine, like numpy's routing)
        from repro.slowtier import model_coeffs

        batch_kind, batch_coeffs = model_coeffs(pool.batching.model)
        batch_window = float(pool.batching.window_s)
        cap = pool.batching.cap
        batch_cap = 0 if np.isinf(cap) else int(cap)
        batch_beta = pool.batch_beta
    common = dict(sizes=fleet.sizes, acc_server=fleet.acc_server,
                  deadline=fleet.deadline, latency=fleet.latency,
                  server_time=fleet.server_time, actions=fleet.actions)
    if len(fleet.groups) == 1:
        # homogeneous: spec-level prune/oneshot, groups=() — the exact
        # single-planner compiled graph (snapshot goldens pin it)
        policy = fleet.groups[0][0]
        planner = spec_for_policy(policy, **common)
        groups = ()
        prune = bool(getattr(policy, "prune_expired", True))
        oneshot = isinstance(policy, OneShotPolicy)
    else:
        # heterogeneous: every group shares one (S, L) grid padded to the
        # largest max_backlog; each group trims to its own bound
        L = max(int(p.max_backlog) for p, _ in fleet.groups)
        groups = tuple(
            EngineGroup(planner=spec_for_policy(p, pad_L=L, **common),
                        streams=tuple(int(s) for s in ss),
                        prune=bool(getattr(p, "prune_expired", True)),
                        oneshot=isinstance(p, OneShotPolicy),
                        mb=int(p.max_backlog))
            for p, ss in fleet.groups)
        planner = groups[0].planner  # shared L/m/deadline/latency/dtype
        prune, oneshot = True, False  # unused: per-group flags govern
    uplinks = [c.uplink for c in server.fabric.cells]
    varying = any(u.jitter > 0 or u.trace is not None for u in uplinks)
    at = fleet.action_table
    has_splits = fleet.actions is not None
    return EngineSpec(
        n_streams=S, batch=server.cfg.batch_size,
        n_cells=server.fabric.n_cells, n_replicas=server.fabric.n_replicas,
        planner=planner, placement=server.fabric.placement.policy,
        serial_replicas=server.fabric.pool.serial,
        scheduler=server.scheduler.policy,
        prune=prune, oneshot=oneshot,
        t_fast=float(server.cfg.fast_time + server.cfg.calib_time),
        bw_alpha=fleet.bw_alpha, collect=collect,
        batch_kind=batch_kind, batch_coeffs=batch_coeffs,
        batch_window=batch_window, batch_cap=batch_cap,
        batch_beta=batch_beta, groups=groups, varying=varying,
        cell_jitter=tuple(float(u.jitter) for u in uplinks) if varying else (),
        cell_seed=tuple(int(u.seed) for u in uplinks) if varying else (),
        cell_trace=tuple(u.trace is not None for u in uplinks) if varying else (),
        cell_loop=tuple(bool(u.trace.loop) if u.trace is not None else False
                        for u in uplinks) if varying else (),
        act_t_dev=tuple(float(x) for x in at.t_dev) if has_splits else (),
        act_srv_frac=tuple(float(x) for x in at.srv_frac) if has_splits else (),
        act_res=tuple(int(r) for r in at.res) if has_splits else (),
        telemetry=bool(telemetry))


def params_from_server(server, spec: EngineSpec) -> EngineParams:
    dt = spec.planner.dtype
    S0 = server.n_streams
    pad = spec.n_streams - S0
    sched_w = server.scheduler.weights
    weights = np.ones(S0) if sched_w is None else np.asarray(sched_w,
                                                             dtype=np.float64)

    def pad1(a, fill):
        # pad rows are inert (no valid frames), but keep their values
        # finite and nonzero so no division inside the step produces nans
        a = np.asarray(a, dtype=np.float64)
        return a if pad == 0 else np.concatenate([a, np.full(pad, fill)])

    cell_of = np.asarray(server.fabric.cell_of, dtype=np.int64)
    if pad:
        cell_of = np.concatenate([cell_of, np.zeros(pad, dtype=np.int64)])
    uplinks = [c.uplink for c in server.fabric.cells]
    extra = {}
    if spec.varying and any(spec.cell_trace):
        # one fixed-shape breakpoint grid per cell, padded to the longest
        # trace; constant cells get a single all-time segment
        T = max(len(u.trace) for u in uplinks if u.trace is not None)
        ts, rates, durs = [], [], []
        for u in uplinks:
            if u.trace is not None:
                t, bps = u.trace.grid(pad_to=T)
                durs.append(float(u.trace.duration))
            else:
                t = np.r_[0.0, np.full(T - 1, np.inf)]
                bps = np.full(T, u.bandwidth_bps)
                durs.append(np.inf)
            ts.append(t)
            rates.append(bps)
        extra = dict(trace_t=jnp.asarray(np.stack(ts), dtype=dt),
                     trace_bps=jnp.asarray(np.stack(rates), dtype=dt),
                     trace_dur=jnp.asarray(durs, dtype=dt))
    return EngineParams(
        # the shared action→bytes table, full width: (A,) with splits, the
        # legacy (m,) resolution grid otherwise (identical values — the
        # frames-only table IS payload_sizes(size_of, resolutions))
        sizes=jnp.asarray(server.fleet.action_table.sizes, dtype=dt),
        cell_bw=jnp.asarray([u.bandwidth_bps for u in uplinks], dtype=dt),
        cell_of=jnp.asarray(cell_of, dtype=jnp.int32),
        replica_st=jnp.asarray(server.fabric.pool.server_time, dtype=dt),
        stream_bw=jnp.asarray(pad1(server._stream_bw, 1.0), dtype=dt),
        weights=jnp.asarray(pad1(weights, 1.0), dtype=dt),
        bw_init=jnp.asarray(pad1(server.fleet.bw_est, 1.0), dtype=dt),
        **extra)
