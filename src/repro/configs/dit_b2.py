"""dit-b2 — Diffusion Transformer DiT-B/2. [arXiv:2212.09748]

img_res=256 (latent 32 via f=8 VAE), patch=2, 12L d_model=768 12H,
adaLN-Zero conditioning, class-conditional (1000), learn_sigma.
"""
from repro.configs.base import ArchSpec, DiTConfig, diffusion_shapes, register

FULL = DiTConfig(
    name="dit-b2",
    img_res=256,
    patch=2,
    n_layers=12,
    d_model=768,
    n_heads=12,
)

SMOKE = DiTConfig(
    name="dit-smoke",
    img_res=32,
    patch=2,
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_classes=10,
)


@register("dit-b2")
def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="dit-b2",
        family="diffusion",
        full=FULL,
        smoke=SMOKE,
        shapes=diffusion_shapes(),
        source="arXiv:2212.09748",
    )
