"""swin-b — Swin Transformer Base. [arXiv:2103.14030]

img_res=224 patch=4 window=7, depths 2-2-18-2, dims 128-256-512-1024.
"""
from repro.configs.base import ArchSpec, SwinConfig, register, vision_shapes

FULL = SwinConfig(
    name="swin-b",
    img_res=224,
    patch=4,
    window=7,
    depths=(2, 2, 18, 2),
    dims=(128, 256, 512, 1024),
)

SMOKE = SwinConfig(
    name="swin-smoke",
    img_res=32,
    patch=2,
    window=4,
    depths=(1, 1),
    dims=(32, 64),
    n_classes=10,
)


@register("swin-b")
def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="swin-b",
        family="vision",
        full=FULL,
        smoke=SMOKE,
        shapes=vision_shapes(),
        source="arXiv:2103.14030",
    )
