"""Architecture / shape configuration dataclasses and the arch registry.

Every assigned architecture provides:
  * ``full``    — the exact published configuration (dry-run only; never allocated)
  * ``smoke``   — a reduced same-family configuration for CPU smoke tests
  * ``shapes``  — the assigned (shape-name -> ShapeSpec) set for the family

Shape *kinds* determine which step function the launcher lowers:
  train    -> train_step(params, opt_state, batch)
  prefill  -> prefill_step(params, tokens)          (LM)
  decode   -> decode_step(params, kv_cache, token)  (LM; 1 new token)
  gen      -> denoise_step(params, x_t, t, cond)    (diffusion; 1 of `steps`)
  serve    -> serve_step(params, images)            (vision forward)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Optional


# --------------------------------------------------------------------------- #
# Shape specs
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class ShapeSpec:
    """One (input-shape) cell for an architecture."""

    name: str
    kind: str  # train | prefill | decode | gen | serve
    # LM fields
    seq_len: int = 0
    global_batch: int = 0
    # vision / diffusion fields
    img_res: int = 0
    batch: int = 0
    steps: int = 0  # diffusion sampler steps (loop is host-level; 1 step lowered)
    skip: bool = False
    skip_reason: str = ""


def lm_shapes(*, full_attention: bool) -> dict[str, ShapeSpec]:
    """The assigned LM-family shape set (4 shapes)."""
    return {
        "train_4k": ShapeSpec("train_4k", "train", seq_len=4096, global_batch=256),
        "prefill_32k": ShapeSpec("prefill_32k", "prefill", seq_len=32768, global_batch=32),
        "decode_32k": ShapeSpec("decode_32k", "decode", seq_len=32768, global_batch=128),
        "long_500k": ShapeSpec(
            "long_500k",
            "decode",
            seq_len=524288,
            global_batch=1,
            skip=full_attention,
            skip_reason=(
                "pure full-attention arch; assignment mandates sub-quadratic "
                "attention for long_500k (see DESIGN.md §Arch-applicability)"
            ),
        ),
    }


def diffusion_shapes() -> dict[str, ShapeSpec]:
    return {
        "train_256": ShapeSpec("train_256", "train", img_res=256, batch=256, steps=1000),
        "gen_1024": ShapeSpec("gen_1024", "gen", img_res=1024, batch=4, steps=50),
        "gen_fast": ShapeSpec("gen_fast", "gen", img_res=512, batch=16, steps=4),
        "train_1024": ShapeSpec("train_1024", "train", img_res=1024, batch=32, steps=1000),
    }


def vision_shapes() -> dict[str, ShapeSpec]:
    return {
        "cls_224": ShapeSpec("cls_224", "train", img_res=224, batch=256),
        "cls_384": ShapeSpec("cls_384", "train", img_res=384, batch=64),
        "serve_b1": ShapeSpec("serve_b1", "serve", img_res=224, batch=1),
        "serve_b128": ShapeSpec("serve_b128", "serve", img_res=224, batch=128),
    }


# --------------------------------------------------------------------------- #
# Model configs
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class MoEConfig:
    n_routed: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    dense_residual_ff: int = 0  # arctic-style parallel dense FFN (0 = off)
    first_k_dense: int = 0  # first K layers use a dense FFN instead
    first_dense_ff: int = 0
    router_jitter: float = 0.0
    aux_loss_coef: float = 0.01


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    qkv_bias: bool = False
    ffn_act: str = "swiglu"  # swiglu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    rope_theta: float = 10_000.0
    rope_pct: float = 1.0  # fraction of head dim rotated (stablelm: 0.25)
    tie_embeddings: bool = False
    # MLA (DeepSeek-V2) — when set, n_kv_heads is ignored
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0  # 0 = direct q projection
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    moe: Optional[MoEConfig] = None
    family: str = "lm"

    @property
    def param_count(self) -> int:
        """Total parameter count (embedding + layers), exact for our layout."""
        return sum(int(x) for x in _lm_param_breakdown(self).values())

    @property
    def active_param_count(self) -> int:
        """Params active per token (MoE: top_k + shared experts only)."""
        br = _lm_param_breakdown(self)
        total = sum(int(v) for v in br.values())
        if self.moe is None:
            return total
        m = self.moe
        routed_all = br["moe_routed"]
        routed_active = routed_all * m.top_k // max(m.n_routed, 1)
        return total - routed_all + routed_active


def _lm_param_breakdown(c: LMConfig) -> dict[str, int]:
    d = c.d_model
    emb = c.vocab_size * d * (1 if c.tie_embeddings else 2)
    if c.use_mla:
        qk_head = c.qk_nope_head_dim + c.qk_rope_head_dim
        q = (d * c.q_lora_rank + c.q_lora_rank * c.n_heads * qk_head) if c.q_lora_rank else d * c.n_heads * qk_head
        kv = d * (c.kv_lora_rank + c.qk_rope_head_dim) + c.kv_lora_rank * c.n_heads * (
            c.qk_nope_head_dim + c.v_head_dim
        )
        o = c.n_heads * c.v_head_dim * d
        attn = q + kv + o
    else:
        attn = d * c.n_heads * c.d_head + 2 * d * c.n_kv_heads * c.d_head + c.n_heads * c.d_head * d
        if c.qkv_bias:
            attn += (c.n_heads + 2 * c.n_kv_heads) * c.d_head
    ff_mult = 3 if c.ffn_act == "swiglu" else 2
    out: dict[str, int] = {"embedding": emb, "attention": attn * c.n_layers, "moe_routed": 0, "ffn_dense": 0}
    if c.moe is None:
        out["ffn_dense"] = ff_mult * d * c.d_ff * c.n_layers
    else:
        m = c.moe
        n_moe_layers = c.n_layers - m.first_k_dense
        out["moe_routed"] = ff_mult * d * m.d_ff_expert * m.n_routed * n_moe_layers
        shared = ff_mult * d * m.d_ff_expert * m.n_shared * n_moe_layers
        router = d * m.n_routed * n_moe_layers
        dense_res = ff_mult * d * m.dense_residual_ff * n_moe_layers if m.dense_residual_ff else 0
        first = ff_mult * d * (m.first_dense_ff or c.d_ff) * m.first_k_dense
        out["ffn_dense"] = shared + router + dense_res + first
    out["norms"] = (2 * c.n_layers + 1) * d
    return out


@dataclass(frozen=True)
class DiTConfig:
    name: str
    img_res: int  # nominal training resolution
    patch: int  # patch size on the latent grid
    n_layers: int
    d_model: int
    n_heads: int
    in_channels: int = 4
    latent_factor: int = 8  # img -> latent downsampling (SD VAE)
    n_classes: int = 1000
    learn_sigma: bool = True
    family: str = "diffusion"

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model

    @property
    def param_count(self) -> int:
        d = self.d_model
        per_layer = 4 * d * d + 2 * d * self.d_ff + 6 * d * d + 2 * d  # attn + mlp + adaLN mod
        x_emb = self.in_channels * self.patch**2 * d
        t_emb = 256 * d + d * d
        y_emb = (self.n_classes + 1) * d
        out_ch = self.in_channels * (2 if self.learn_sigma else 1)
        final = d * self.patch**2 * out_ch + 2 * d * d
        return per_layer * self.n_layers + x_emb + t_emb + y_emb + final

    active_param_count = param_count


@dataclass(frozen=True)
class UNetConfig:
    name: str
    img_res: int
    latent_res: int
    in_channels: int = 4
    ch: int = 320
    ch_mult: tuple[int, ...] = (1, 2, 4)
    n_res_blocks: int = 2
    transformer_depth: tuple[int, ...] = (1, 2, 10)
    ctx_dim: int = 2048
    head_dim: int = 64
    latent_factor: int = 8
    family: str = "diffusion"

    @property
    def param_count(self) -> int:
        # computed from the instantiated tree in models/unet.py; this analytic
        # figure is only used for roofline MODEL_FLOPS and is filled by the
        # launcher via models.count_params when available.
        return unet_param_estimate(self)

    active_param_count = param_count


def unet_param_estimate(c: UNetConfig) -> int:
    """Analytic estimate (resblocks + transformer blocks + in/out)."""

    def res_block(cin, cout):
        return 9 * cin * cout + 9 * cout * cout + (cin * cout if cin != cout else 0) + 4 * c.ch * cout

    def tf_block(ch):
        # self-attn + cross-attn + geglu ff (4x)
        return 4 * ch * ch + 2 * ch * c.ctx_dim + 2 * ch * ch + 8 * ch * ch + 4 * ch * ch

    total = 9 * c.in_channels * c.ch + 9 * c.ch * c.in_channels  # conv in/out
    total += c.ch * 4 * c.ch + 4 * c.ch * 4 * c.ch  # time embed MLP
    chans = [c.ch * m for m in c.ch_mult]
    prev = c.ch
    for i, ch in enumerate(chans):
        for _ in range(c.n_res_blocks):
            total += res_block(prev, ch)
            total += c.transformer_depth[i] * tf_block(ch)
            prev = ch
        if i < len(chans) - 1:
            total += 9 * ch * ch  # downsample conv
    # mid
    total += 2 * res_block(prev, prev) + c.transformer_depth[-1] * tf_block(prev)
    # up path (mirror, with skip concat)
    for i, ch in reversed(list(enumerate(chans))):
        for _ in range(c.n_res_blocks + 1):
            total += res_block(prev + ch, ch)
            total += c.transformer_depth[i] * tf_block(ch)
            prev = ch
        if i > 0:
            total += 9 * ch * ch
    return int(total)


@dataclass(frozen=True)
class ViTConfig:
    name: str
    img_res: int
    patch: int
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    n_classes: int = 1000
    distill_token: bool = False  # DeiT
    family: str = "vision"

    @property
    def param_count(self) -> int:
        d = self.d_model
        per_layer = 4 * d * d + 2 * d * self.d_ff + 4 * d
        stem = 3 * self.patch**2 * d
        n_tok = (self.img_res // self.patch) ** 2 + 1 + (1 if self.distill_token else 0)
        pos = n_tok * d
        head = d * self.n_classes * (2 if self.distill_token else 1)
        return per_layer * self.n_layers + stem + pos + head + 2 * d

    active_param_count = param_count


@dataclass(frozen=True)
class SwinConfig:
    name: str
    img_res: int
    patch: int
    window: int
    depths: tuple[int, ...]
    dims: tuple[int, ...]
    n_classes: int = 1000
    family: str = "vision"

    @property
    def heads(self) -> tuple[int, ...]:
        return tuple(d // 32 for d in self.dims)

    @property
    def param_count(self) -> int:
        total = 3 * self.patch**2 * self.dims[0]
        for i, (dep, dim) in enumerate(zip(self.depths, self.dims)):
            per = 4 * dim * dim + 2 * dim * 4 * dim + 4 * dim + (2 * self.window - 1) ** 2 * self.heads[i]
            total += dep * per
            if i < len(self.dims) - 1:
                total += 4 * dim * self.dims[i + 1]  # patch merging
        total += self.dims[-1] * self.n_classes
        return int(total)

    active_param_count = param_count


@dataclass(frozen=True)
class ResNetConfig:
    name: str
    img_res: int
    depths: tuple[int, ...]
    width: int = 64
    n_classes: int = 1000
    family: str = "vision"

    @property
    def param_count(self) -> int:
        total = 3 * 49 * self.width  # stem 7x7
        cin = self.width
        for i, dep in enumerate(self.depths):
            mid = self.width * 2**i
            cout = mid * 4
            for b in range(dep):
                total += cin * mid + 9 * mid * mid + mid * cout
                if cin != cout:
                    total += cin * cout
                cin = cout
        total += cin * self.n_classes
        return int(total)

    active_param_count = param_count


# --------------------------------------------------------------------------- #
# Arch spec + registry
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str  # lm | moe-lm | diffusion | vision
    full: object
    smoke: object
    shapes: dict[str, ShapeSpec]
    source: str  # public citation
    notes: str = ""


_REGISTRY: dict[str, Callable[[], ArchSpec]] = {}


def register(arch_id: str):
    def deco(fn):
        _REGISTRY[arch_id] = fn
        return fn

    return deco


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in _REGISTRY:
        _load_all()
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]()


def list_archs() -> list[str]:
    _load_all()
    return sorted(_REGISTRY)


def _load_all() -> None:
    # import all config modules so their @register decorators run
    from repro.configs import (  # noqa: F401
        arctic_480b,
        deepseek_v2_lite_16b,
        deit_b,
        dit_b2,
        qwen15_32b,
        resnet_50,
        stablelm_12b,
        swin_b,
        unet_sdxl,
        vit_s16,
    )
