"""arctic-480b — dense-MoE hybrid decoder LM (Snowflake Arctic).

[hf:Snowflake/snowflake-arctic-base]
35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 with a parallel dense residual FFN per layer.
"""
from repro.configs.base import ArchSpec, LMConfig, MoEConfig, lm_shapes, register

FULL = LMConfig(
    name="arctic-480b",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,
    d_ff=4864,
    vocab_size=32000,
    ffn_act="swiglu",
    norm="rmsnorm",
    moe=MoEConfig(
        n_routed=128,
        top_k=2,
        d_ff_expert=4864,
        n_shared=0,
        dense_residual_ff=4864,
    ),
)

SMOKE = LMConfig(
    name="arctic-smoke",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_head=8,
    d_ff=96,
    vocab_size=256,
    ffn_act="swiglu",
    moe=MoEConfig(n_routed=8, top_k=2, d_ff_expert=96, n_shared=0, dense_residual_ff=96),
)


@register("arctic-480b")
def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="arctic-480b",
        family="moe-lm",
        full=FULL,
        smoke=SMOKE,
        shapes=lm_shapes(full_attention=True),
        source="hf:Snowflake/snowflake-arctic-base",
        notes="56 heads not divisible by model=16 -> sequence-parallel attention (DESIGN.md §5)",
    )
