"""unet-sdxl — SDXL UNet backbone. [arXiv:2307.01952]

img_res=1024 (latent 128), ch=320, ch_mult=1-2-4, 2 res blocks/stage,
transformer_depth=1-2-10 (per assignment), cross-attn ctx_dim=2048.
"""
from repro.configs.base import ArchSpec, UNetConfig, diffusion_shapes, register

FULL = UNetConfig(
    name="unet-sdxl",
    img_res=1024,
    latent_res=128,
    ch=320,
    ch_mult=(1, 2, 4),
    n_res_blocks=2,
    transformer_depth=(1, 2, 10),
    ctx_dim=2048,
)

SMOKE = UNetConfig(
    name="unet-smoke",
    img_res=64,
    latent_res=8,
    ch=32,
    ch_mult=(1, 2),
    n_res_blocks=1,
    transformer_depth=(1, 1),
    ctx_dim=64,
    head_dim=16,
)


@register("unet-sdxl")
def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="unet-sdxl",
        family="diffusion",
        full=FULL,
        smoke=SMOKE,
        shapes=diffusion_shapes(),
        source="arXiv:2307.01952",
    )
