"""deepseek-v2-lite-16b — MLA + fine-grained MoE decoder LM.

[arXiv:2405.04434; hf:deepseek-ai/DeepSeek-V2-Lite]
27L d_model=2048 16H d_ff(expert)=1408 vocab=102400, MLA kv_lora=512
(qk_nope=128, qk_rope=64, v_head=128), MoE: 2 shared + 64 routed, top-6,
first layer dense (d_ff=10944) per the HF config.

NOTE: the assignment line reads "2 shared+160 routed"; 160 contradicts both
the "64e" field on the same line and the HF config. We implement 64 routed
(see DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ArchSpec, LMConfig, MoEConfig, lm_shapes, register

FULL = LMConfig(
    name="deepseek-v2-lite-16b",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=10944,  # dense first layer width
    vocab_size=102400,
    ffn_act="swiglu",
    norm="rmsnorm",
    use_mla=True,
    kv_lora_rank=512,
    q_lora_rank=0,  # lite variant projects q directly
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    moe=MoEConfig(
        n_routed=64,
        top_k=6,
        d_ff_expert=1408,
        n_shared=2,
        first_k_dense=1,
        first_dense_ff=10944,
    ),
)

SMOKE = LMConfig(
    name="deepseek-v2-lite-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=128,
    vocab_size=256,
    ffn_act="swiglu",
    use_mla=True,
    kv_lora_rank=32,
    qk_nope_head_dim=16,
    qk_rope_head_dim=8,
    v_head_dim=16,
    moe=MoEConfig(n_routed=8, top_k=2, d_ff_expert=32, n_shared=1, first_k_dense=1, first_dense_ff=128),
)


@register("deepseek-v2-lite-16b")
def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="deepseek-v2-lite-16b",
        family="moe-lm",
        full=FULL,
        smoke=SMOKE,
        shapes=lm_shapes(full_attention=True),  # MLA is still full softmax attention
        source="arXiv:2405.04434; hf",
    )
