"""resnet-50 — ResNet-50 (bottleneck). [arXiv:1512.03385]

img_res=224, depths 3-4-6-3, width=64, bottleneck blocks.
"""
from repro.configs.base import ArchSpec, ResNetConfig, register, vision_shapes

FULL = ResNetConfig(
    name="resnet-50",
    img_res=224,
    depths=(3, 4, 6, 3),
    width=64,
)

SMOKE = ResNetConfig(
    name="resnet-smoke",
    img_res=32,
    depths=(1, 1),
    width=16,
    n_classes=10,
)


@register("resnet-50")
def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="resnet-50",
        family="vision",
        full=FULL,
        smoke=SMOKE,
        shapes=vision_shapes(),
        source="arXiv:1512.03385",
    )
