"""deit-b — DeiT-Base with distillation token. [arXiv:2012.12877]

img_res=224 patch=16, 12L d_model=768 12H d_ff=3072, +1 distill token.
"""
from repro.configs.base import ArchSpec, ViTConfig, register, vision_shapes

FULL = ViTConfig(
    name="deit-b",
    img_res=224,
    patch=16,
    n_layers=12,
    d_model=768,
    n_heads=12,
    d_ff=3072,
    distill_token=True,
)

SMOKE = ViTConfig(
    name="deit-smoke",
    img_res=32,
    patch=8,
    n_layers=2,
    d_model=64,
    n_heads=4,
    d_ff=128,
    n_classes=10,
    distill_token=True,
)


@register("deit-b")
def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="deit-b",
        family="vision",
        full=FULL,
        smoke=SMOKE,
        shapes=vision_shapes(),
        source="arXiv:2012.12877",
    )
