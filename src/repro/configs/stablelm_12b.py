"""stablelm-12b — dense decoder LM (StableLM-2 family).

[hf:stabilityai/stablelm-2-12b (family config per assignment)]
40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352; partial rotary
(rope_pct=0.25 per the StableLM-2 family).
"""
from repro.configs.base import ArchSpec, LMConfig, lm_shapes, register

FULL = LMConfig(
    name="stablelm-12b",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_head=160,
    d_ff=13824,
    vocab_size=100352,
    ffn_act="swiglu",
    norm="layernorm",
    rope_pct=0.25,
)

SMOKE = LMConfig(
    name="stablelm-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=192,
    vocab_size=256,
    ffn_act="swiglu",
    norm="layernorm",
    rope_pct=0.25,
)


@register("stablelm-12b")
def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="stablelm-12b",
        family="lm",
        full=FULL,
        smoke=SMOKE,
        shapes=lm_shapes(full_attention=True),
        source="hf:stabilityai/stablelm-2-1_6b (scaled per assignment)",
    )
