"""qwen1.5-32b — dense decoder LM with QKV bias (Qwen1.5 family).

[hf:Qwen/Qwen1.5-32B (family config per assignment)]
64L d_model=5120 40H (kv=40, i.e. MHA) d_ff=27392 vocab=152064, QKV bias.
"""
from repro.configs.base import ArchSpec, LMConfig, lm_shapes, register

FULL = LMConfig(
    name="qwen1.5-32b",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_head=128,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    ffn_act="swiglu",
    norm="rmsnorm",
)

SMOKE = LMConfig(
    name="qwen-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=192,
    vocab_size=256,
    qkv_bias=True,
    ffn_act="swiglu",
)


@register("qwen1.5-32b")
def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="qwen1.5-32b",
        family="lm",
        full=FULL,
        smoke=SMOKE,
        shapes=lm_shapes(full_attention=True),
        source="hf:Qwen/Qwen1.5-0.5B (scaled per assignment)",
        notes="40 heads not divisible by model=16 -> sequence-parallel attention (DESIGN.md §5)",
    )
