"""vit-s16 — ViT-Small/16. [arXiv:2010.11929]

img_res=224 patch=16, 12L d_model=384 6H d_ff=1536.
"""
from repro.configs.base import ArchSpec, ViTConfig, register, vision_shapes

FULL = ViTConfig(
    name="vit-s16",
    img_res=224,
    patch=16,
    n_layers=12,
    d_model=384,
    n_heads=6,
    d_ff=1536,
)

SMOKE = ViTConfig(
    name="vit-smoke",
    img_res=32,
    patch=8,
    n_layers=2,
    d_model=48,
    n_heads=2,
    d_ff=96,
    n_classes=10,
)


@register("vit-s16")
def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="vit-s16",
        family="vision",
        full=FULL,
        smoke=SMOKE,
        shapes=vision_shapes(),
        source="arXiv:2010.11929",
    )
