"""Checkpointing: async, atomic, resharding-aware.

Layout:  <dir>/step_<N>/{manifest.json, leaf_<i>.npy ...}
Commit protocol: write into `step_<N>.tmp`, fsync manifest, atomic rename —
a crash mid-save never corrupts the latest checkpoint. Saves run on a
background thread (training continues); `wait()` joins before exit.
Restore puts leaves onto any sharding (elastic re-mesh: a checkpoint
written on one mesh restores onto another).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from dataclasses import dataclass
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


@dataclass
class CheckpointManager:
    directory: str
    keep_last: int = 3

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state, *, blocking: bool = False):
        self.wait()
        leaves, treedef = _flatten(state)
        # device -> host copy happens here (cheap on CPU; async D2H on TPU)
        host_leaves = [np.asarray(l) for l in leaves]
        paths_keys = [str(p) for p, _ in jax.tree_util.tree_flatten_with_path(state)[0]]

        def _write():
            try:
                tmp = os.path.join(self.directory, f"step_{step}.tmp")
                final = os.path.join(self.directory, f"step_{step}")
                shutil.rmtree(tmp, ignore_errors=True)
                os.makedirs(tmp)
                manifest = {"step": step, "n_leaves": len(host_leaves), "keys": paths_keys,
                            "time": time.time()}
                for i, arr in enumerate(host_leaves):
                    np.save(os.path.join(tmp, f"leaf_{i}.npy"), arr)
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                    f.flush()
                    os.fsync(f.fileno())
                shutil.rmtree(final, ignore_errors=True)
                os.rename(tmp, final)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if blocking:
            _write()
            self._raise_if_failed()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise RuntimeError(f"async checkpoint failed: {e}") from e

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep_last]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.directory, name, "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like, shardings=None):
        """Restore into the structure of ``like`` (values ignored). With
        ``shardings`` (a matching tree), leaves are placed sharded — this is
        the elastic re-mesh path."""
        d = os.path.join(self.directory, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaves, treedef = _flatten(like)
        assert manifest["n_leaves"] == len(leaves), "tree structure changed"
        out = []
        shard_leaves = _flatten(shardings)[0] if shardings is not None else [None] * len(leaves)
        for i, (ref, sh) in enumerate(zip(leaves, shard_leaves)):
            arr = np.load(os.path.join(d, f"leaf_{i}.npy"))
            if hasattr(ref, "dtype") and arr.dtype != ref.dtype:
                arr = arr.astype(ref.dtype)
            out.append(jax.device_put(arr, sh) if sh is not None else jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out)
