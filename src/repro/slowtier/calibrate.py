"""Fit slow-tier latency curves f(batch) from measured (batch, seconds) pairs.

The intended source of measurements is ``benchmarks/bench_kernels.py
--batch-sweep``: it times the real Pallas reference tiers
(``kernels/flash_attention``, ``kernels/int8_matmul``) across batch sizes and
feeds the (n, seconds) rows here.  Each fitter returns a
``repro.slowtier.batching`` latency model plus its RMSE on the sample, so the
calibration recipe is: sweep → ``fit_latency_model`` → pass the winning model
into ``ContinuousBatching`` / ``ReplicaPool(batching=...)``.

All fits are least squares on the *batch* latency (not amortized
per-request), matching how ``form_batches`` consumes the model.  Intercepts
are clamped at zero — timer noise can produce a small negative base, which
would make f non-physical (negative latency at n=0).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from .batching import FlatService, LatencyModel, LinearBatch, StepBatch

__all__ = ["fit_flat", "fit_linear", "fit_step", "fit_latency_model"]


def _as_samples(batch_sizes, seconds):
    n = np.asarray(batch_sizes, dtype=np.float64)
    y = np.asarray(seconds, dtype=np.float64)
    if n.shape != y.shape or n.ndim != 1 or n.size == 0:
        raise ValueError("batch_sizes and seconds must be equal-length 1-D")
    if np.any(n < 1):
        raise ValueError("batch sizes must be >= 1")
    return n, y


def _rmse(model: LatencyModel, n, y) -> float:
    return float(np.sqrt(np.mean((model.batch_latency(n) - y) ** 2)))


def fit_flat(batch_sizes, seconds) -> Tuple[FlatService, float]:
    """Best constant per-request time: minimizes ||st·n - y||² (through the
    origin — a flat server has no fixed per-pass cost by definition)."""
    n, y = _as_samples(batch_sizes, seconds)
    st = float(np.dot(n, y) / np.dot(n, n))
    model = FlatService(max(st, 0.0))
    return model, _rmse(model, n, y)


def fit_linear(batch_sizes, seconds) -> Tuple[LinearBatch, float]:
    """Affine fit f(n) = base + per_item·n (base clamped at 0)."""
    n, y = _as_samples(batch_sizes, seconds)
    A = np.stack([np.ones_like(n), n], axis=1)
    (base, per_item), *_ = np.linalg.lstsq(A, y, rcond=None)
    model = LinearBatch(max(float(base), 0.0), max(float(per_item), 0.0))
    return model, _rmse(model, n, y)


def fit_step(batch_sizes, seconds, *, page_size: int = 8,
             max_pages=None) -> Tuple[StepBatch, float]:
    """Staircase fit f(n) = base + per_page·ceil(n / page_size)."""
    n, y = _as_samples(batch_sizes, seconds)
    pages = np.ceil(n / page_size)
    A = np.stack([np.ones_like(n), pages], axis=1)
    (base, per_page), *_ = np.linalg.lstsq(A, y, rcond=None)
    model = StepBatch(max(float(base), 0.0), max(float(per_page), 0.0),
                      page_size, max_pages)
    return model, _rmse(model, n, y)


def fit_latency_model(batch_sizes, seconds, kind: str = "linear", *,
                      page_size: int = 8,
                      max_pages=None) -> Tuple[LatencyModel, float]:
    """Dispatch on curve family; ``kind='best'`` returns the lowest-RMSE fit
    among flat/linear/step."""
    if kind == "flat":
        return fit_flat(batch_sizes, seconds)
    if kind == "linear":
        return fit_linear(batch_sizes, seconds)
    if kind == "step":
        return fit_step(batch_sizes, seconds, page_size=page_size,
                        max_pages=max_pages)
    if kind == "best":
        fits = [fit_flat(batch_sizes, seconds),
                fit_linear(batch_sizes, seconds),
                fit_step(batch_sizes, seconds, page_size=page_size,
                         max_pages=max_pages)]
        return min(fits, key=lambda mr: mr[1])
    raise ValueError(f"unknown latency curve kind: {kind!r}")
