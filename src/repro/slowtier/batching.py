"""Continuous-batching slow tier: latency curves, admission windows, batch formation.

The paper's edge server charges a flat ``server_time`` per offloaded frame.
Real inference servers (TGI-style continuous batching with paged KV memory)
serve *batches*: requests that land close together share one forward pass and
amortize to far cheaper than the same count serialized.  This module models
one replica of such a server:

* a **latency curve** ``f(n)`` — wall-clock to serve one batch of ``n``
  requests (``FlatService`` is the paper's constant, ``LinearBatch`` a fitted
  affine curve, ``StepBatch`` a paged-memory staircase with an occupancy cap);
* an **admission window** — a batch opens when the replica frees up (or the
  first request arrives, whichever is later) and admits every request that
  lands within ``window_s`` of that opening, up to the occupancy cap;
  over-cap requests *spill* to the next batch;
* **batch formation** — ``form_batches`` runs the whole per-replica Lindley
  recursion over a sorted arrival vector in one pass per batch (numpy);
  ``form_batches_looped`` is the one-request-at-a-time reference oracle the
  fuzz tests pin it against.

``ReplicaPool`` (``repro.net.replicas``) delegates here when constructed with
``batching=``; the **degenerate** configuration (``FlatService``, zero window,
cap 1) is routed back through the pool's legacy serial recursion so it stays
bit-for-bit identical to the pre-batching slow tier.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

__all__ = [
    "LatencyModel", "FlatService", "LinearBatch", "StepBatch",
    "ContinuousBatching", "BatchingReplica",
    "form_batches", "form_batches_looped",
    "model_coeffs", "model_from_coeffs",
]


# --------------------------------------------------------------------------- #
# latency curves f(n)
# --------------------------------------------------------------------------- #


class LatencyModel:
    """f(batch): wall-clock seconds to serve one batch of ``n`` requests."""

    capacity = None  # max requests per batch imposed by the model (None = ∞)

    def batch_latency(self, n):
        raise NotImplementedError

    def per_request(self, n):
        """Amortized per-request cost at (possibly fractional) occupancy
        ``n`` — the planner's calibrated ``server_time`` estimate."""
        n = np.maximum(np.asarray(n, dtype=np.float64), 1.0)
        return self.batch_latency(n) / n


@dataclass(frozen=True)
class FlatService(LatencyModel):
    """The paper's constant server: a batch of n costs n back-to-back passes.

    Batching never amortizes anything here — ``per_request`` is flat — which
    makes this the degenerate curve the legacy ``ReplicaPool`` semantics
    correspond to.
    """

    server_time: float

    def batch_latency(self, n):
        return np.asarray(n, dtype=np.float64) * self.server_time


@dataclass(frozen=True)
class LinearBatch(LatencyModel):
    """Affine curve f(n) = base + per_item·n.

    ``base`` is the fixed per-pass cost (kernel launch, weight streaming,
    attention over the shared prefix); ``per_item`` the marginal cost of one
    more batch row.  ``base > 0`` is what makes batching pay.
    """

    base: float
    per_item: float

    def batch_latency(self, n):
        return self.base + self.per_item * np.asarray(n, dtype=np.float64)


@dataclass(frozen=True)
class StepBatch(LatencyModel):
    """Paged-memory staircase: f(n) = base + per_page·ceil(n / page_size).

    Models a server whose marginal cost is per memory *page*, not per
    request (paged attention): latency steps up each time a batch spills
    into a new page.  ``max_pages`` bounds occupancy — a batch can hold at
    most ``max_pages * page_size`` requests; the rest spill to the next
    batch.
    """

    base: float
    per_page: float
    page_size: int = 8
    max_pages: Optional[int] = None

    def __post_init__(self):
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {self.page_size}")
        if self.max_pages is not None and self.max_pages < 1:
            raise ValueError(f"max_pages must be >= 1, got {self.max_pages}")

    @property
    def capacity(self):
        if self.max_pages is None:
            return None
        return self.max_pages * self.page_size

    def batch_latency(self, n):
        pages = np.ceil(np.asarray(n, dtype=np.float64) / self.page_size)
        return self.base + self.per_page * pages


def model_coeffs(model: LatencyModel) -> Tuple[str, Tuple[float, ...]]:
    """Flatten a latency model to ``(kind, coeffs)`` for backends that can't
    carry Python objects (the jitted jax engine keeps these in its static
    spec and re-evaluates f with ``jnp``)."""
    if isinstance(model, FlatService):
        return "flat", (float(model.server_time),)
    if isinstance(model, LinearBatch):
        return "linear", (float(model.base), float(model.per_item))
    if isinstance(model, StepBatch):
        return "step", (float(model.base), float(model.per_page),
                        float(model.page_size))
    raise ValueError(f"unknown latency model: {model!r}")


def model_from_coeffs(kind: str, coeffs) -> LatencyModel:
    """Inverse of :func:`model_coeffs`."""
    if kind == "flat":
        return FlatService(coeffs[0])
    if kind == "linear":
        return LinearBatch(coeffs[0], coeffs[1])
    if kind == "step":
        return StepBatch(coeffs[0], coeffs[1], int(coeffs[2]))
    raise ValueError(f"unknown latency model kind: {kind!r}")


# --------------------------------------------------------------------------- #
# replica configuration
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class ContinuousBatching:
    """Per-replica continuous-batching configuration.

    ``window_s``: a batch opening at ``t_open`` admits every request with
    arrival ``<= t_open + window_s`` (boundary ties join).  ``max_batch``
    caps occupancy on top of whatever cap the model imposes
    (``StepBatch.max_pages``); the effective cap is the min of both.
    """

    model: LatencyModel
    window_s: float = 0.0
    max_batch: Optional[int] = None

    def __post_init__(self):
        if self.window_s < 0:
            raise ValueError(f"window_s must be >= 0, got {self.window_s}")
        if self.max_batch is not None and self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")

    @property
    def cap(self) -> float:
        caps = [c for c in (self.max_batch, self.model.capacity)
                if c is not None]
        return float(min(caps)) if caps else np.inf

    @property
    def degenerate(self) -> bool:
        """True when this config is exactly the legacy serial queue: flat
        curve, zero window, one request per batch.  ``ReplicaPool`` routes
        degenerate configs through its original recursion so they stay
        bit-for-bit with the pre-batching slow tier (the vectorized batch
        path computes the same reals via a different float expression)."""
        return (self.window_s == 0.0 and self.cap == 1.0
                and isinstance(self.model, FlatService))


# Alias matching the modeling vocabulary in ISSUE/ROADMAP: one replica of a
# continuous-batching inference server *is* its batching config.
BatchingReplica = ContinuousBatching


# --------------------------------------------------------------------------- #
# batch formation (the per-replica Lindley recursion over batches)
# --------------------------------------------------------------------------- #


def form_batches(arrival, cfg: ContinuousBatching, *, busy0: float = 0.0):
    """Form batches over one replica's pending requests; one pass per batch.

    ``arrival`` must be sorted ascending (ties allowed).  Returns four arrays
    aligned with ``arrival``:

    * ``done[i]`` — completion time of request i's batch,
    * ``service[i]`` — that batch's ``f(n)`` (the processing time the server
      reports for every member),
    * ``batch_size[i]`` — ``n`` of the batch serving request i,
    * ``batch_id[i]`` — 0-based batch ordinal on this replica.

    Semantics per batch: the batch *opens* at ``t_open = max(busy, arrival of
    the first pending request)``; every pending request with ``arrival <=
    t_open + window_s`` is admitted (boundary ties join), up to the occupancy
    cap.  If the cap binds, the batch *launches* as soon as its last admitted
    member has landed (``max(t_open, arrival[last])`` — no point waiting out
    the window for requests that can't join) and the excess spills to the
    next batch; otherwise it launches when the window closes
    (``t_open + window_s``).  The batch completes at ``launch + f(n)`` and
    the replica is busy until then.
    """
    arr = np.asarray(arrival, dtype=np.float64)
    n = arr.shape[0]
    done = np.empty(n, dtype=np.float64)
    service = np.empty(n, dtype=np.float64)
    batch_size = np.empty(n, dtype=np.int64)
    batch_id = np.empty(n, dtype=np.int64)
    model, w, cap = cfg.model, cfg.window_s, cfg.cap
    busy = float(busy0)
    p = 0
    b = 0
    while p < n:
        t_open = max(busy, arr[p])
        close = t_open + w
        hi = int(np.searchsorted(arr, close, side="right"))
        count = int(min(hi - p, cap))
        if hi - p > count:  # cap binds: spill, launch at last member's landing
            t_start = max(t_open, float(arr[p + count - 1]))
        else:
            t_start = close
        f = float(model.batch_latency(count))
        done[p:p + count] = t_start + f
        service[p:p + count] = f
        batch_size[p:p + count] = count
        batch_id[p:p + count] = b
        busy = t_start + f
        p += count
        b += 1
    return done, service, batch_size, batch_id


def form_batches_looped(arrival, cfg: ContinuousBatching, *, busy0: float = 0.0):
    """One-request-at-a-time reference for :func:`form_batches`.

    Implements the admission rules literally (walk requests, admit while
    within the window and under the cap) with the same float expressions, so
    the two must agree *bit-for-bit* — the fuzz oracle in
    ``tests/test_slowtier.py`` and ``bench_slowtier.py --smoke``.
    """
    arr = [float(a) for a in np.asarray(arrival, dtype=np.float64)]
    n = len(arr)
    done = [0.0] * n
    service = [0.0] * n
    batch_size = [0] * n
    batch_id = [0] * n
    busy = float(busy0)
    i = 0
    b = 0
    while i < n:
        t_open = max(busy, arr[i])
        close = t_open + cfg.window_s
        members = [i]
        j = i + 1
        while j < n and arr[j] <= close and len(members) < cfg.cap:
            members.append(j)
            j += 1
        spilled = j < n and arr[j] <= close  # admission stopped by the cap
        t_start = max(t_open, arr[members[-1]]) if spilled else close
        f = float(cfg.model.batch_latency(len(members)))
        for k in members:
            done[k] = t_start + f
            service[k] = f
            batch_size[k] = len(members)
            batch_id[k] = b
        busy = t_start + f
        i = j
        b += 1
    return (np.asarray(done), np.asarray(service),
            np.asarray(batch_size, dtype=np.int64),
            np.asarray(batch_id, dtype=np.int64))
