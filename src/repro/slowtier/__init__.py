"""Continuous-batching slow tier.

Models each slow-tier replica as a continuous-batching inference server
(TGI-style): batch-size-dependent latency curves, admission windows,
paged-memory occupancy caps, and least-squares calibration of the curve
from kernel microbenchmarks.  ``repro.net.replicas.ReplicaPool`` delegates
its service model here when constructed with ``batching=``.
"""
from .batching import (BatchingReplica, ContinuousBatching, FlatService,
                       LatencyModel, LinearBatch, StepBatch, form_batches,
                       form_batches_looped, model_coeffs, model_from_coeffs)
from .calibrate import fit_flat, fit_latency_model, fit_linear, fit_step

__all__ = [
    "LatencyModel", "FlatService", "LinearBatch", "StepBatch",
    "ContinuousBatching", "BatchingReplica",
    "form_batches", "form_batches_looped",
    "model_coeffs", "model_from_coeffs",
    "fit_flat", "fit_linear", "fit_step", "fit_latency_model",
]
