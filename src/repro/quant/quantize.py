"""Post-training quantization: the "NPU" substrate (DESIGN.md §2).

Two uses:
  * ``qdq_tree``   — quantize->dequantize round trip: injects exactly the
                     precision error of the fast tier while keeping plain
                     arrays, so any model runs "as if on the NPU" on CPU.
                     (On TPU the real int8 path is kernels/int8_matmul.)
  * ``quantize_tree`` — true int8 storage (values + per-channel scales) for
                     the serving fast tier and the int8 kernel path.
Weight-only by default (W8); ``fp16_tree`` reproduces the paper's FP16-NPU.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

F32 = jnp.float32


@dataclass(frozen=True)
class QTensor:
    values: Any  # int8
    scale: Any  # f32, broadcastable to values

    def dequantize(self, dtype=jnp.bfloat16):
        return (self.values.astype(F32) * self.scale).astype(dtype)


jax.tree_util.register_pytree_node(
    QTensor, lambda q: ((q.values, q.scale), None), lambda _, ch: QTensor(*ch)
)


def quantize_tensor(w, *, axis=-1, bits: int = 8) -> QTensor:
    """Symmetric quantization: per-channel along ``axis``, or per-tensor
    (``axis=None`` — the crude NPU-compiler regime; much larger error)."""
    qmax = 2 ** (bits - 1) - 1
    if axis is None:
        amax = jnp.max(jnp.abs(w.astype(F32)), keepdims=True)
    else:
        amax = jnp.max(jnp.abs(w.astype(F32)), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / qmax
    q = jnp.clip(jnp.round(w.astype(F32) / scale), -qmax, qmax).astype(jnp.int8)
    return QTensor(q, scale)


def _is_weight(path: tuple, x) -> bool:
    """Quantize matmul/conv weights; keep norms, biases, tables in fp."""
    if not hasattr(x, "ndim") or x.ndim < 2:
        return False
    name = str(path[-1]) if path else ""
    if any(s in name for s in ("scale", "bias", "norm", "pos_embed", "cls", "rel_bias")):
        return False
    return x.size >= 64


def qdq_tree(params, *, bits: int = 8, axis: int = -1):
    """Quantization-error injection (QDQ). Same tree structure/dtypes."""

    def f(path, x):
        if _is_weight(path, x):
            return quantize_tensor(x, axis=axis, bits=bits).dequantize(x.dtype)
        return x

    return _tree_map_with_path(f, params)


def quantize_tree(params, *, bits: int = 8, axis: int = -1):
    """True int8 tree: weights become QTensor leaves, the rest pass through."""

    def f(path, x):
        if _is_weight(path, x):
            return quantize_tensor(x, axis=axis, bits=bits)
        return x

    return _tree_map_with_path(f, params)


def dequantize_tree(qparams, dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda x: x.dequantize(dtype) if isinstance(x, QTensor) else x,
        qparams,
        is_leaf=lambda x: isinstance(x, QTensor),
    )


def fp16_tree(params):
    """The paper's NPU numerics: FP16 weights (cast round trip)."""
    return jax.tree.map(lambda x: x.astype(jnp.float16).astype(x.dtype) if hasattr(x, "astype") else x, params)


def _tree_map_with_path(f, tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    treedef = jax.tree_util.tree_structure(tree)
    vals = [f(tuple(str(getattr(k, "key", k)) for k in path), v) for path, v in flat]
    return jax.tree_util.tree_unflatten(treedef, vals)


def quantization_error(params, qparams_deq) -> float:
    """Mean relative weight error (sanity metric for tests)."""
    errs = []
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(qparams_deq)):
        if hasattr(a, "ndim") and a.ndim >= 2 and a.size >= 4096:
            na = float(jnp.linalg.norm(a.astype(F32)))
            errs.append(float(jnp.linalg.norm(a.astype(F32) - b.astype(F32))) / max(na, 1e-9))
    return float(np.mean(errs)) if errs else 0.0
