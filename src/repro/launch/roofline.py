"""Roofline accounting from compiled dry-run artifacts (assignment §Roofline).

Hardware model: TPU v5e — 197 TFLOP/s bf16 (394 int8) per chip, 819 GB/s HBM,
~50 GB/s/link ICI, 16 GB HBM. The compiled module under GSPMD is the
*per-device* program, so per-device cost_analysis numbers divide the
assignment's ``chips ×`` out already.

XLA counts while-bodies once, so callers must hand this module *unrolled*
compiles (or diff-extrapolated totals — see dryrun.py).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS_BF16 = 197e12
PEAK_FLOPS_INT8 = 394e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_BYTES = 16 * 1024**3

_DT_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|s32|s16|s8|u64|u32|u16|u8|pred|c64|c128)\[([0-9,]*)\]")
_LINE_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+(all-gather-start|all-reduce-start|all-gather|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)\("
)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DT_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict[str, int]:
    """Sum result-shape bytes per collective op type in a compiled HLO module.

    Skips computations reached only via `while` bodies? No — the dry-run path
    guarantees unrolled programs; every listed op executes once.
    """
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        op = op.replace("-start", "")
        out[op] = out.get(op, 0) + _shape_bytes(type_str)
    return out


def cost_summary(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return {"flops": float(ca.get("flops", 0.0)), "bytes": float(ca.get("bytes accessed", 0.0))}


def memory_summary(compiled) -> dict:
    ma = compiled.memory_analysis()
    return {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_estimate_bytes": int(ma.argument_size_in_bytes + ma.output_size_in_bytes + ma.temp_size_in_bytes - ma.alias_size_in_bytes),
    }


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s, "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def fraction_of_roofline(self) -> float:
        """useful-time / bound-time if perfectly overlapped = compute/bound."""
        return self.compute_s / max(self.bound_s, 1e-30)


def roofline_terms(flops: float, bytes_: float, coll_bytes: float, *, peak=PEAK_FLOPS_BF16) -> Roofline:
    return Roofline(flops / peak, bytes_ / HBM_BW, coll_bytes / ICI_BW)


def model_flops(family: str, kind: str, *, n_active: int, tokens: int = 0, batch: int = 0,
                decode_attn: float = 0.0) -> float:
    """The 'useful FLOPs' convention (DESIGN.md §6):
      LM train: 6·N·tokens; prefill: 2·N·tokens (+causal attn not counted);
      decode:   2·N·batch + explicit attention term (dominates at 32k);
      vision/diffusion: 2·N·batch fwd, 6·N·batch train (conv reuse makes the
      HLO/model ratio > 1 by design — reported, not hidden).
    """
    if family in ("lm", "moe-lm"):
        if kind == "train":
            return 6.0 * n_active * tokens
        if kind == "prefill":
            return 2.0 * n_active * tokens
        return 2.0 * n_active * batch + decode_attn
    return (6.0 if kind == "train" else 2.0) * n_active * batch
