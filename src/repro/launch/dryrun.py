import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (assignment: MULTI-POD DRY-RUN + ROOFLINE ANALYSIS).

For one (arch × shape × mesh) cell:
  * lower + compile the step under the production mesh (proves sharding);
  * memory_analysis()  -> fits-in-HBM proof (runtime scan/remat path);
  * cost_analysis() + HLO collective parse -> roofline terms.

XLA counts while-bodies once, so FLOP/byte/collective totals come from
*unrolled* compiles. Deep LMs use the two-point diff method: compile
unrolled depth L_a and L_a+1; the delta is the exact per-layer cost and
total = cost(L_a) + (L - L_a)·delta. Everything else compiles fully
unrolled directly.

Usage:
  python -m repro.launch.dryrun --arch qwen1.5-32b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out-dir results/dryrun
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import numpy as np


def _merge_coll(a: dict, b: dict, fb: float = 1.0) -> dict:
    out = dict(a)
    for k, v in b.items():
        out[k] = out.get(k, 0) + fb * v
    return out


def run_cell(arch_id: str, shape_name: str, mesh_kind: str, *, plan_overrides=None, memory_check=True) -> dict:
    from repro.configs.base import get_arch
    from repro.launch import roofline as rl
    from repro.launch.cells import build_cell, lower_cell
    from repro.launch.mesh import make_production_mesh

    spec = get_arch(arch_id)
    shape = spec.shapes[shape_name]
    rec: dict = {"arch": arch_id, "shape": shape_name, "mesh": mesh_kind, "kind": shape.kind}
    if shape.skip:
        rec.update(status="skipped", reason=shape.skip_reason)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = int(np.prod(mesh.devices.shape))
    full_cfg = spec.full
    is_lm = spec.family in ("lm", "moe-lm")
    t0 = time.time()

    # ---------- cost path (unrolled / diff) ----------
    if is_lm and shape.kind in ("train", "prefill"):
        fkd = full_cfg.moe.first_k_dense if full_cfg.moe is not None else 0
        La, Lb = fkd + 1, fkd + 2
        costs, colls = [], []
        for L in (La, Lb):
            cfg_L = dataclasses.replace(full_cfg, n_layers=L)
            cell = build_cell(arch_id, shape_name, mesh, analysis=True,
                              plan_overrides=plan_overrides, cfg_override=cfg_L)
            lowered, compiled = lower_cell(cell)
            costs.append(rl.cost_summary(compiled))
            colls.append(rl.parse_collectives(compiled.as_text()))
            del lowered, compiled
        d_flops = costs[1]["flops"] - costs[0]["flops"]
        d_bytes = costs[1]["bytes"] - costs[0]["bytes"]
        n_extra = full_cfg.n_layers - La
        flops = costs[0]["flops"] + n_extra * d_flops
        bytes_ = costs[0]["bytes"] + n_extra * d_bytes
        d_coll = {k: colls[1].get(k, 0) - colls[0].get(k, 0) for k in set(colls[0]) | set(colls[1])}
        coll = _merge_coll(colls[0], d_coll, fb=n_extra)
        rec["cost_method"] = f"diff(L={La},{Lb})x{full_cfg.n_layers}"
    else:
        cell = build_cell(arch_id, shape_name, mesh, analysis=True, plan_overrides=plan_overrides)
        lowered, compiled = lower_cell(cell)
        cs = rl.cost_summary(compiled)
        flops, bytes_ = cs["flops"], cs["bytes"]
        coll = rl.parse_collectives(compiled.as_text())
        rec["cost_method"] = "direct"
        if not (is_lm and memory_check and shape.kind in ("train", "prefill")):
            rec["memory"] = rl.memory_summary(compiled)
        del lowered, compiled
    rec["compile_cost_s"] = round(time.time() - t0, 1)

    # ---------- memory path (runtime scan/remat at full depth) ----------
    if "memory" not in rec and memory_check:
        t1 = time.time()
        cell_m = build_cell(arch_id, shape_name, mesh, analysis=False, plan_overrides=plan_overrides)
        lowered_m, compiled_m = lower_cell(cell_m)
        rec["memory"] = rl.memory_summary(compiled_m)
        rec["compile_memory_s"] = round(time.time() - t1, 1)
        del lowered_m, compiled_m

    # ---------- roofline ----------
    from repro.models import api as mapi

    n_params = mapi.build(full_cfg).n_params()
    n_active = full_cfg.active_param_count if hasattr(full_cfg, "active_param_count") else n_params

    coll_bytes = float(sum(coll.values()))
    terms = rl.roofline_terms(flops, bytes_, coll_bytes)
    tokens = shape.global_batch * shape.seq_len if shape.seq_len else 0
    batch = shape.global_batch or shape.batch
    if is_lm:
        decode_attn = 0.0
        if shape.kind == "decode":
            hd = full_cfg.n_heads * (full_cfg.v_head_dim or full_cfg.d_head)
            decode_attn = 4.0 * shape.seq_len * hd * full_cfg.n_layers * batch
        mf = rl.model_flops(spec.family, shape.kind, n_active=n_active, tokens=tokens,
                            batch=batch, decode_attn=decode_attn)
    else:
        # vision/diffusion: useful FLOPs = single-device batch-1 reference
        # compile of the same forward (token/spatial reuse counted exactly).
        ref = _ref_flops_per_sample(arch_id, shape_name)
        mf = ref * batch * (3.0 if shape.kind == "train" else 1.0)  # bwd ≈ 2x fwd
        rec["ref_fwd_flops_per_sample"] = ref

    rec.update(
        status="ok",
        n_chips=n_chips,
        n_params=n_params,
        n_active_params=int(n_active),
        hlo_flops_per_chip=flops,
        hlo_bytes_per_chip=bytes_,
        collective_bytes_per_chip=coll_bytes,
        collectives=coll,
        compute_s=terms.compute_s,
        memory_s=terms.memory_s,
        collective_s=terms.collective_s,
        dominant=terms.dominant,
        model_flops_global=mf,
        model_flops_per_chip=mf / n_chips,
        useful_ratio=(mf / n_chips) / max(flops, 1e-30),
        roofline_fraction=(mf / n_chips / rl.PEAK_FLOPS_BF16) / max(terms.bound_s, 1e-30),
    )
    return rec


def _ref_flops_per_sample(arch_id: str, shape_name: str) -> float:
    """Unsharded single-sample forward cost on one device (no mesh)."""
    import dataclasses as dc

    import jax.numpy as jnp

    from repro.configs.base import get_arch
    from repro.launch import roofline as rl
    from repro.models import api as mapi
    from repro.models.transformer import ParallelPlan

    spec = get_arch(arch_id)
    shape = dc.replace(spec.shapes[shape_name], batch=1, global_batch=1)
    cfg = mapi.config_for_shape(spec.full, shape)
    handle = mapi.build(cfg, ParallelPlan(model_axis=1, analysis_unroll=True, remat=False))
    ins = mapi.input_specs(cfg, shape, handle.plan)
    pstruct = handle.struct()
    if shape.kind == "train":
        b = ins["batch"]
        if "images" in b:
            fwd = lambda p, bb: handle.forward(p, bb["images"])
        else:
            fwd = lambda p, bb: handle.forward(p, bb["latents"], bb["t"], bb["cond"])
        compiled = jax.jit(fwd).lower(pstruct, b).compile()
    elif shape.kind == "gen":
        compiled = jax.jit(handle.forward).lower(pstruct, ins["latents"], ins["t"], ins["cond"]).compile()
    else:
        compiled = jax.jit(handle.forward).lower(pstruct, ins["images"]).compile()
    return rl.cost_summary(compiled)["flops"]


ALL_CELLS = "__all__"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out-dir", default="results/dryrun")
    ap.add_argument("--no-memory", action="store_true")
    ap.add_argument("--plan", nargs="*", default=[], help="k=v ParallelPlan overrides")
    args = ap.parse_args()

    from repro.configs.base import get_arch, list_archs

    overrides = {}
    for kv in args.plan:
        k, v = kv.split("=")
        overrides[k] = {"true": True, "false": False}.get(v.lower(), v if not v.lstrip("-").isdigit() else int(v))

    os.makedirs(args.out_dir, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for a in list_archs():
            for s in get_arch(a).shapes:
                cells.append((a, s))
    else:
        cells.append((args.arch, args.shape))

    n_ok = n_skip = n_fail = 0
    for a, s in cells:
        for mk in meshes:
            tag = f"{a}__{s}__{mk}"
            out_path = os.path.join(args.out_dir, tag + ".json")
            if os.path.exists(out_path):
                print(f"[skip-cached] {tag}", flush=True)
                continue
            t0 = time.time()
            try:
                rec = run_cell(a, s, mk, plan_overrides=overrides or None, memory_check=not args.no_memory)
            except Exception as e:  # record the failure; the sweep continues
                rec = {"arch": a, "shape": s, "mesh": mk, "status": "error",
                       "error": f"{type(e).__name__}: {e}", "trace": traceback.format_exc()[-2000:]}
            rec["wall_s"] = round(time.time() - t0, 1)
            with open(out_path, "w") as f:
                json.dump(rec, f, indent=2)
            st = rec["status"]
            n_ok += st == "ok"
            n_skip += st == "skipped"
            n_fail += st == "error"
            print(f"[{st}] {tag} ({rec['wall_s']}s)"
                  + (f" dominant={rec.get('dominant')}" if st == "ok" else "")
                  + (f" err={rec.get('error','')[:120]}" if st == "error" else ""), flush=True)
    print(f"done: ok={n_ok} skipped={n_skip} failed={n_fail}", flush=True)


if __name__ == "__main__":
    main()
