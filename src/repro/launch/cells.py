"""Per-(arch × shape × mesh) cell construction: plans, rules, step functions,
input specs and shardings. Shared by dryrun / train / serve launchers.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (
    ArchSpec,
    DiTConfig,
    LMConfig,
    ResNetConfig,
    ShapeSpec,
    SwinConfig,
    UNetConfig,
    ViTConfig,
    get_arch,
)
from repro.models import api
from repro.models import transformer as tr
from repro.models.transformer import ParallelPlan
from repro.sharding import axes as ax
from repro.sharding.fsdp import tree_fsdp
from repro.train import optim

F32 = jnp.float32


# --------------------------------------------------------------------------- #
# Plans / rules / optimizer policy per cell
# --------------------------------------------------------------------------- #


def make_plan(cfg, shape: ShapeSpec, mesh, *, analysis: bool = False, overrides: dict | None = None) -> ParallelPlan:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_axis = sizes.get("model", 1)
    data_axis = sizes.get("data", 1) * sizes.get("pod", 1)
    kw: dict = dict(model_axis=model_axis, data_axis=data_axis, analysis_unroll=analysis)
    if isinstance(cfg, LMConfig):
        kw["attn_mode"] = "tp"  # padded-head TP baseline (DESIGN.md §5)
        if shape.kind == "train" and shape.seq_len >= 4096:
            kw["attn_chunk"] = 1024  # caps f32 score temps under remat
        elif shape.kind == "prefill" and shape.seq_len >= 8192:
            kw["attn_chunk"] = 2048
        if shape.kind == "decode" and cfg.n_kv_heads == cfg.n_heads and shape.seq_len >= 32768:
            kw["kv_cache_dtype"] = "int8"  # MHA KV does not fit in bf16 (qwen)
        kw["remat"] = shape.kind == "train"
        # optimized defaults adopted from the §Perf hillclimb (EXPERIMENTS.md);
        # pass explicit overrides to reproduce the paper-faithful baselines.
        if cfg.moe is not None and shape.kind in ("train", "prefill"):
            kw["moe_grouped_dispatch"] = True  # gather-only grouped dispatch: 3.8x
        if shape.kind == "decode":
            kw["pad_attention_heads"] = False  # decode never head-shards: -25% KV bytes
            if cfg.use_mla:
                kw["mla_absorb"] = True  # latent-space MLA decode: -46% bytes
        if cfg.n_kv_heads == cfg.n_heads and shape.kind in ("train", "prefill"):
            kw["fuse_qkv"] = True  # single stacked QKV projection
    if overrides:
        kw.update(overrides)
    return ParallelPlan(**kw)


def make_rules(cfg, shape: ShapeSpec, mesh) -> dict:
    axes_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    multi = "pod" in axes_sizes
    rules = dict(ax.multipod_rules() if multi else ax.DEFAULT_RULES)
    data_total = axes_sizes.get("data", 1) * axes_sizes.get("pod", 1)
    batch = shape.global_batch or shape.batch
    if batch and batch % data_total != 0:
        # tiny-batch serving cells: replicate batch; use data axis spatially
        rules["batch"] = None
        rules["spatial"] = ("pod", "data") if multi else "data"
        rules["seq_sp"] = (("pod", "data", "model") if multi else ("data", "model"))
    if isinstance(cfg, LMConfig) and shape.kind == "train":
        rules["seq_res"] = "model"  # Megatron-SP residual stream sharding
    return rules


def optim_policy(cfg) -> optim.OptimConfig:
    n = api.build(cfg).n_params()
    if n > 100e9:  # arctic: bf16 moments or it does not fit (DESIGN.md §5)
        return optim.OptimConfig(m_dtype="bfloat16", v_dtype="bfloat16")
    return optim.OptimConfig()


def param_dtype_policy(cfg, shape: ShapeSpec):
    """Training stores fp32 masters unless the model is huge; serving bf16."""
    if shape.kind != "train":
        return jnp.bfloat16
    n = api.build(cfg).n_params()
    return jnp.bfloat16 if n > 100e9 else F32


# --------------------------------------------------------------------------- #
# Step functions per shape kind
# --------------------------------------------------------------------------- #


def _bf16(params):
    return jax.tree.map(lambda p: p.astype(jnp.bfloat16) if p.dtype == F32 and p.ndim >= 2 else p, params)


def make_step(handle: api.ModelHandle, cfg, shape: ShapeSpec, ocfg: optim.OptimConfig):
    """Returns (step_fn, donate_argnums). Signature per kind:

      train  : step(state, batch)            state={params,opt}
      prefill: step(params, tokens)
      decode : step(params, cache, token)
      gen    : step(params, latents, t, cond)
      serve  : step(params, images)
    """
    plan = handle.plan

    if shape.kind == "train":

        def train_step(state, batch):
            params = state["params"]

            def loss_fn(p):
                return handle.loss(_bf16(p), batch)

            loss, grads = jax.value_and_grad(loss_fn)(params)
            new_params, new_opt = optim.apply_updates(ocfg, params, grads, state["opt"])
            return {"params": new_params, "opt": new_opt}, loss

        return train_step, (0,)

    if shape.kind == "prefill":

        def prefill_step(params, tokens):
            return tr.lm_prefill(params, tokens, cfg, plan)

        return prefill_step, ()

    if shape.kind == "decode":
        pos = shape.seq_len - 1

        def decode_step(params, cache, token):
            return tr.lm_decode(params, cache, token, pos, cfg, plan)

        return decode_step, (1,)

    if shape.kind == "gen":

        def denoise_step(params, latents, t, cond):
            """One DDIM step (of shape.steps) — the sampler loop is host-side."""
            eps = handle.forward(params, latents, t, cond).astype(F32)
            eps = eps[..., : latents.shape[-1]]  # drop sigma channels if any
            tt = t.astype(F32).reshape(-1, 1, 1, 1)
            abar = jnp.cos(0.5 * jnp.pi * (tt / 1000.0)) ** 2
            t_prev = jnp.maximum(tt - 1000.0 / shape.steps, 0.0)
            abar_prev = jnp.cos(0.5 * jnp.pi * (t_prev / 1000.0)) ** 2
            x0 = (latents.astype(F32) - jnp.sqrt(1 - abar) * eps) / jnp.sqrt(jnp.maximum(abar, 1e-8))
            x_prev = jnp.sqrt(abar_prev) * x0 + jnp.sqrt(1 - abar_prev) * eps
            return x_prev.astype(latents.dtype)

        return denoise_step, ()

    if shape.kind == "serve":

        def serve_step(params, images):
            return handle.forward(params, images)

        return serve_step, ()

    raise ValueError(shape.kind)


# --------------------------------------------------------------------------- #
# Input shardings
# --------------------------------------------------------------------------- #


def _batch_axes(rules):
    b = rules.get("batch")
    return b if b else None


def input_shardings(cfg, shape: ShapeSpec, mesh, rules, plan: ParallelPlan) -> dict:
    """PartitionSpec tree matching api.input_specs."""
    bax = _batch_axes(rules)
    kv = rules.get("kv_seq")
    sp = rules.get("spatial") if rules.get("batch") is None else None
    if isinstance(cfg, LMConfig):
        if shape.kind == "train":
            return {"batch": {"tokens": P(bax, None), "labels": P(bax, None)}}
        if shape.kind == "prefill":
            return {"tokens": P(bax, None)}
        if shape.kind == "decode":
            specs = api.input_specs(cfg, shape, plan)
            cache_ps = {}
            for name, sds in specs["cache"].items():
                cache_ps[name] = P(*((None, bax, kv) + (None,) * (len(sds.shape) - 3)))
            return {"cache": cache_ps, "token": P(bax)}
    if isinstance(cfg, (DiTConfig, UNetConfig)):
        lat_ps = P(bax, sp, None, None)
        cond_ps = P(bax) if isinstance(cfg, DiTConfig) else P(bax, None, None)
        if shape.kind == "train":
            return {"batch": {"latents": lat_ps, "t": P(bax), "noise": lat_ps, "cond": cond_ps}}
        return {"latents": lat_ps, "t": P(bax), "cond": cond_ps}
    if isinstance(cfg, (ViTConfig, SwinConfig, ResNetConfig)):
        img_ps = P(bax, None, None, None)
        if shape.kind == "train":
            return {"batch": {"images": img_ps, "labels": P(bax)}}
        return {"images": img_ps}
    raise TypeError(type(cfg))


# --------------------------------------------------------------------------- #
# Cell assembly
# --------------------------------------------------------------------------- #


@dataclass
class Cell:
    arch_id: str
    shape: ShapeSpec
    mesh: Any
    cfg: Any
    plan: ParallelPlan
    rules: dict
    handle: api.ModelHandle
    step: Callable
    donate: tuple
    arg_structs: tuple  # ordered args for step
    arg_shardings: tuple
    n_params: int
    n_active_params: int
    out_shardings: Any = None  # pins outputs sharded (keeps grads scattered)


def build_cell(arch_id: str, shape_name: str, mesh, *, analysis: bool = False,
               plan_overrides: dict | None = None, cfg_override=None,
               ocfg_overrides: dict | None = None) -> Cell:
    spec = get_arch(arch_id)
    shape = spec.shapes[shape_name]
    base_cfg = cfg_override if cfg_override is not None else spec.full
    cfg = api.config_for_shape(base_cfg, shape)
    plan = make_plan(cfg, shape, mesh, analysis=analysis, overrides=plan_overrides)
    rules = make_rules(cfg, shape, mesh)
    sizes = {name: size for name, size in zip(mesh.axis_names, mesh.devices.shape)}
    rules["_sizes"] = sizes
    handle = api.build(cfg, plan)

    ocfg = optim_policy(base_cfg) if shape.kind == "train" else optim.OptimConfig()
    if ocfg_overrides:
        ocfg = dataclasses.replace(ocfg, **ocfg_overrides)
    step, donate = make_step(handle, cfg, shape, ocfg)

    # ---- arg structs ----
    pdt = param_dtype_policy(base_cfg, shape)
    pstruct = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, pdt if s.dtype == jnp.bfloat16 else s.dtype),
        handle.struct(),
    )
    pspec_tree = handle.pspecs(rules)
    inputs = api.input_specs(cfg, shape, plan)
    in_ps = input_shardings(cfg, shape, mesh, rules, plan)

    out_shardings = None
    if shape.kind == "train":
        pspec_tree = tree_fsdp(pspec_tree, pstruct, mesh)
        ostruct = optim.state_struct(ocfg, pstruct)
        ospec = {
            "step": P(),
            "m": pspec_tree,
            "v": pspec_tree,
        }
        if ocfg.compress_grads:
            ospec["err"] = pspec_tree
        state_struct = {"params": pstruct, "opt": ostruct}
        state_spec = {"params": pspec_tree, "opt": ospec}
        arg_structs = (state_struct, inputs["batch"])
        arg_shardings = (state_spec, in_ps["batch"])
        out_shardings = (state_spec, P())
    elif shape.kind == "prefill":
        arg_structs = (pstruct, inputs["tokens"])
        arg_shardings = (pspec_tree, in_ps["tokens"])
    elif shape.kind == "decode":
        arg_structs = (pstruct, inputs["cache"], inputs["token"])
        arg_shardings = (pspec_tree, in_ps["cache"], in_ps["token"])
    elif shape.kind == "gen":
        arg_structs = (pstruct, inputs["latents"], inputs["t"], inputs["cond"])
        arg_shardings = (pspec_tree, in_ps["latents"], in_ps["t"], in_ps["cond"])
    else:  # serve
        arg_structs = (pstruct, inputs["images"])
        arg_shardings = (pspec_tree, in_ps["images"])

    return Cell(
        arch_id=arch_id,
        shape=shape,
        mesh=mesh,
        cfg=cfg,
        plan=plan,
        rules=rules,
        handle=handle,
        step=step,
        donate=donate,
        arg_structs=arg_structs,
        arg_shardings=arg_shardings,
        n_params=handle.n_params(),
        n_active_params=getattr(base_cfg, "active_param_count", handle.n_params())
        if isinstance(base_cfg, LMConfig)
        else handle.n_params(),
        out_shardings=out_shardings,
    )


def lower_cell(cell: Cell):
    """lower + compile the cell's step under its mesh/rules context."""
    shardings = jax.tree.map(
        lambda ps: NamedSharding(cell.mesh, ps),
        cell.arg_shardings,
        is_leaf=lambda x: isinstance(x, P),
    )
    out_shardings = None
    if cell.out_shardings is not None:
        out_shardings = jax.tree.map(
            lambda ps: NamedSharding(cell.mesh, ps),
            cell.out_shardings,
            is_leaf=lambda x: isinstance(x, P),
        )
    with cell.mesh, ax.sharding_ctx(cell.mesh, cell.rules):
        jitted = jax.jit(cell.step, in_shardings=shardings, donate_argnums=cell.donate,
                         out_shardings=out_shardings)
        lowered = jitted.lower(*cell.arg_structs)
        compiled = lowered.compile()
    return lowered, compiled
