"""Production mesh construction (assignment-mandated shapes).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1, data_axis: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    model_axis = min(model_axis, n)
    data_axis = min(data_axis, n // model_axis)
    return jax.make_mesh((data_axis, model_axis), ("data", "model"))


def make_streams_mesh(n_devices: int | None = None):
    """Pure data-parallel mesh for fleet serving: the ``"streams"`` logical
    axis maps to ``"data"`` (sharding/axes.py), so an (n, 1) mesh splits
    the (S,) fleet arrays n ways while every per-cell/per-replica shared
    reduction stays replicated.  On CPU hosts, force n devices by setting
    ``XLA_FLAGS=--xla_force_host_platform_device_count=n`` *before* jax
    imports (see benchmarks/bench_fleet_control.py ``--devices``)."""
    n = len(jax.devices()) if n_devices is None else int(n_devices)
    if n > len(jax.devices()):
        raise ValueError(f"asked for {n} devices, host has {len(jax.devices())} "
                         "(set --xla_force_host_platform_device_count before "
                         "jax imports)")
    return jax.make_mesh((n, 1), ("data", "model"))
