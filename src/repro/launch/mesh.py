"""Production mesh construction (assignment-mandated shapes).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1, data_axis: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    model_axis = min(model_axis, n)
    data_axis = min(data_axis, n // model_axis)
    return jax.make_mesh((data_axis, model_axis), ("data", "model"))
