"""Device-prefix / server-suffix compute costs for split offloading.

Layered on ``launch/roofline.py``: the device runs the prefix at the NPU's
int8 peak (mobile NPUs quantize anyway — that is the whole premise of the
paper's fast tier), the server runs the suffix at the TPU bf16 peak.  Two
numbers fall out per cut:

  * ``t_dev``    — absolute device-prefix seconds
    (``roofline_terms(prefix_flops, ..., peak=device_peak)``), which the
    planner *adds* to a frame's arrival before its upload can start;
  * ``srv_frac`` — suffix FLOPs / total FLOPs, which *scales* whatever
    server time the serving stack currently believes (flat ``T^o``, or the
    occupancy-calibrated estimate from the slow tier) — so split costs
    compose with server-time calibration instead of fighting it.

``build_action_table`` is the glue: frame actions (index == resolution
index, byte-for-byte the legacy ``payload_sizes`` table) plus one action
per catalog cut, packed into ``policy.types.ActionTable`` for the frontier
DP, both serving engines, and the jax planner spec.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.launch.roofline import PEAK_FLOPS_BF16, roofline_terms
from repro.split.points import CutCatalog

# Mobile-NPU int8 peak (order of a Hexagon/ANE-class accelerator, ~7 TOPS).
# The absolute value only sets the device-prefix timescale; sweeps override.
DEFAULT_NPU_PEAK = 7e12


@dataclass(frozen=True)
class SplitCost:
    """Costs for one cut point."""

    cut_id: int
    t_dev: float  # device prefix seconds at the NPU peak
    srv_frac: float  # fraction of full-model server time the suffix costs
    t_srv_peak: float  # suffix seconds at the *server* roofline peak (reference)


def split_costs(catalog: CutCatalog, *, device_peak: float = DEFAULT_NPU_PEAK,
                server_peak: float = PEAK_FLOPS_BF16) -> tuple:
    """Roofline costs for every cut in the catalog."""
    out = []
    for p in catalog:
        t_dev = roofline_terms(p.prefix_flops, 0.0, 0.0, peak=device_peak).bound_s
        t_srv = roofline_terms(p.suffix_flops, 0.0, 0.0, peak=server_peak).bound_s
        out.append(SplitCost(cut_id=p.cut_id, t_dev=t_dev,
                             srv_frac=p.suffix_fraction, t_srv_peak=t_srv))
    return tuple(out)


def build_action_table(catalog: Optional[CutCatalog], *,
                       resolutions: Sequence[int],
                       size_of,
                       acc_server: Sequence[float],
                       device_peak: float = DEFAULT_NPU_PEAK,
                       acc_drop: float = 0.0):
    """Pack frames + cuts into the planner's ``ActionTable``.

    Frame actions occupy indices ``[0, m)`` with action index == resolution
    index and bytes from ``payload_sizes(size_of, resolutions)`` — exactly
    the legacy table, so an empty/None catalog reproduces the frame-only
    system bit-for-bit.  Each cut becomes one extra action: payload = int8
    feature bytes, evaluated at full resolution (the device prefix sees the
    native input), accuracy = top-resolution server accuracy minus
    ``acc_drop`` (int8 feature degradation; 0 unless calibrated).
    """
    from repro.core.netsim import payload_sizes
    from repro.policy.types import ActionTable

    res = np.asarray(list(resolutions))
    frame_sizes = payload_sizes(size_of, res).astype(np.float64)
    table = ActionTable.frames_only(sizes=frame_sizes,
                                    acc=np.asarray(acc_server, dtype=np.float64))
    if catalog is None or len(catalog) == 0:
        return table
    costs = split_costs(catalog, device_peak=device_peak)
    m = len(res)
    return ActionTable(
        kind=np.concatenate([table.kind, np.ones(len(costs), dtype=np.int8)]),
        res=np.concatenate([table.res, np.full(len(costs), m - 1, dtype=np.int64)]),
        cut=np.concatenate([table.cut, np.arange(len(costs), dtype=np.int64)]),
        sizes=np.concatenate([table.sizes, catalog.payload_bytes()]),
        acc=np.concatenate([table.acc,
                            np.full(len(costs), float(acc_server[-1]) - acc_drop)]),
        t_dev=np.concatenate([table.t_dev,
                              np.array([c.t_dev for c in costs], dtype=np.float64)]),
        srv_frac=np.concatenate([table.srv_frac,
                                 np.array([c.srv_frac for c in costs], dtype=np.float64)]),
        names=table.names + tuple(p.name for p in catalog),
    )
