"""Partition-point catalog: where a model can be cut, and what a cut ships.

A *cut point* is a block boundary: the device runs blocks ``[0, block)``,
quantizes the activation tensor at the boundary to int8 (the
``quant/quantize.py`` wire format: int8 values + one float32 scale per
leading row, ``axis=-1`` symmetric), ships it, and the server runs blocks
``[block, n_blocks)``.  Each ``CutPoint`` therefore carries

  * the activation shape at the boundary (for the given input resolution),
  * ``raw_nbytes``      — the float32 activation size (what a naive split
    would ship),
  * ``payload_nbytes``  — the exact int8+scales wire size (what we ship),
  * ``prefix_flops`` / ``total_flops`` — per-block FLOP accounting in the
    repo's ``2 * params * positions`` forward convention
    (``launch/roofline.model_flops``), which ``split/costs.py`` turns into
    device-prefix time and a server-suffix fraction.

Catalogs are derived from the existing model configs (``repro.configs``):
ViT blocks are homogeneous, ResNet bottleneck stages shrink spatially as
channels grow, Swin stages merge patches — so the three families give
genuinely different payload/compute frontiers.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Union

import numpy as np

from repro.configs.base import ResNetConfig, SwinConfig, ViTConfig, get_arch

_SCALE_BYTES = 4  # float32 scale per quantization group


def activation_payload_nbytes(shape: Sequence[int], *, bits: int = 8,
                              scale_bytes: int = _SCALE_BYTES) -> int:
    """Exact wire bytes for ``quantize_tensor(x, axis=-1)`` of an activation.

    int8 stores one byte per element; symmetric per-channel quantization
    along the last axis keeps one float32 scale per *leading row*
    (``scale.shape == shape[:-1] + (1,)``), so the payload is

        prod(shape) * (bits/8)  +  prod(shape[:-1]) * scale_bytes
    """
    shape = tuple(int(s) for s in shape)
    n = int(np.prod(shape)) if shape else 1
    rows = int(np.prod(shape[:-1])) if len(shape) > 1 else 1
    return n * bits // 8 + rows * scale_bytes


def qtensor_nbytes(q) -> int:
    """Wire bytes of a materialized ``quant.quantize.QTensor`` (values +
    scales).  ``activation_payload_nbytes`` is the analytic twin; tests pin
    them equal on real tensors."""
    return int(np.asarray(q.values).nbytes + np.asarray(q.scale).nbytes)


@dataclass(frozen=True)
class CutPoint:
    """One block boundary of one model at one input resolution."""

    cut_id: int  # index within the catalog
    name: str  # e.g. "vit-s16/block4"
    block: int  # device runs blocks [0, block)
    n_blocks: int
    act_shape: tuple  # activation tensor shape at the boundary
    raw_nbytes: int  # float32 activation bytes
    payload_nbytes: int  # int8 + per-row f32 scales (the wire format)
    prefix_flops: float  # forward FLOPs of blocks [0, block)
    total_flops: float  # forward FLOPs of all blocks

    @property
    def suffix_flops(self) -> float:
        return self.total_flops - self.prefix_flops

    @property
    def suffix_fraction(self) -> float:
        return self.suffix_flops / max(self.total_flops, 1e-30)

    @property
    def compression(self) -> float:
        """raw float32 bytes / shipped bytes (≈4 for int8+scales)."""
        return self.raw_nbytes / max(self.payload_nbytes, 1)


@dataclass(frozen=True)
class CutCatalog:
    model: str
    family: str  # "vit" | "resnet" | "swin"
    img_res: int
    points: tuple  # tuple[CutPoint, ...]
    total_flops: float

    def __len__(self) -> int:
        return len(self.points)

    def __iter__(self):
        return iter(self.points)

    def payload_bytes(self) -> np.ndarray:
        return np.array([p.payload_nbytes for p in self.points], dtype=np.float64)

    def subsample(self, max_cuts: int) -> "CutCatalog":
        """Evenly thin the catalog to at most ``max_cuts`` points (planner
        action grids are O(A) per frontier state; a handful of well-spread
        cuts captures the frontier)."""
        if max_cuts >= len(self.points) or max_cuts <= 0:
            return self
        idx = np.unique(np.linspace(0, len(self.points) - 1, max_cuts).round().astype(int))
        pts = tuple(
            CutPoint(cut_id=i, name=p.name, block=p.block, n_blocks=p.n_blocks,
                     act_shape=p.act_shape, raw_nbytes=p.raw_nbytes,
                     payload_nbytes=p.payload_nbytes, prefix_flops=p.prefix_flops,
                     total_flops=p.total_flops)
            for i, p in enumerate(self.points[j] for j in idx))
        return CutCatalog(self.model, self.family, self.img_res, pts, self.total_flops)


# --------------------------------------------------------------------------- #
# Per-family block walks.  Each yields (name, act_shape, block_flops) in
# forward order; a cut is legal after every block except the last (cutting
# after the final block would ship logits — that is just "run locally").
# --------------------------------------------------------------------------- #


def _walk_vit(cfg: ViTConfig, img_res: int):
    n_tok = (img_res // cfg.patch) ** 2 + 1 + (1 if cfg.distill_token else 0)
    d = cfg.d_model
    per_layer = 4 * d * d + 2 * d * cfg.d_ff
    for b in range(cfg.n_layers):
        yield f"{cfg.name}/block{b + 1}", (n_tok, d), 2.0 * per_layer * n_tok


def _walk_resnet(cfg: ResNetConfig, img_res: int):
    cin = cfg.width
    for i, dep in enumerate(cfg.depths):
        mid = cfg.width * 2 ** i
        cout = mid * 4
        h = img_res // (4 * 2 ** i)  # stem /4, then /2 per stage
        for b in range(dep):
            params = cin * mid + 9 * mid * mid + mid * cout
            if cin != cout:
                params += cin * cout  # downsample projection
            yield f"{cfg.name}/s{i + 1}b{b + 1}", (h, h, cout), 2.0 * params * h * h
            cin = cout


def _walk_swin(cfg: SwinConfig, img_res: int):
    r0 = img_res // cfg.patch
    for i, (dep, dim) in enumerate(zip(cfg.depths, cfg.dims)):
        r = r0 // 2 ** i
        tokens = r * r
        per_block = 4 * dim * dim + 2 * dim * 4 * dim
        merge = 2.0 * (4 * cfg.dims[i - 1] * dim) * tokens if i > 0 else 0.0
        for b in range(dep):
            flops = 2.0 * per_block * tokens + (merge if b == 0 else 0.0)
            yield f"{cfg.name}/s{i + 1}b{b + 1}", (tokens, dim), flops


_WALKS = {ViTConfig: ("vit", _walk_vit), ResNetConfig: ("resnet", _walk_resnet),
          SwinConfig: ("swin", _walk_swin)}


def catalog_for(arch: Union[str, ViTConfig, ResNetConfig, SwinConfig], *,
                img_res: Optional[int] = None, smoke: bool = False,
                max_cuts: Optional[int] = None) -> CutCatalog:
    """Build the cut catalog for a model family.

    ``arch`` is a registry id (``"vit-s16"``, ``"resnet-50"``, ``"swin-b"``)
    or a config instance; ``img_res`` defaults to the config's native
    resolution.  ``max_cuts`` evenly thins the catalog (the planner's action
    grid is {local} ∪ {frame@r} ∪ {features@cut}, so every kept cut is a
    planner column).
    """
    if isinstance(arch, str):
        spec = get_arch(arch)
        cfg = spec.smoke if smoke else spec.full
    else:
        cfg = arch
    try:
        family, walk = _WALKS[type(cfg)]
    except KeyError:
        raise ValueError(
            f"no split catalog for {type(cfg).__name__}; supported families: "
            f"ViT, ResNet, Swin") from None
    res = int(img_res or cfg.img_res)

    blocks = list(walk(cfg, res))
    total = float(sum(f for _, _, f in blocks))
    points, prefix = [], 0.0
    for k, (name, shape, flops) in enumerate(blocks):
        prefix += flops
        if k == len(blocks) - 1:
            break  # cut after the last block == run locally
        raw = int(np.prod(shape)) * 4
        points.append(CutPoint(
            cut_id=len(points), name=name, block=k + 1, n_blocks=len(blocks),
            act_shape=tuple(int(s) for s in shape), raw_nbytes=raw,
            payload_nbytes=activation_payload_nbytes(shape),
            prefix_flops=prefix, total_flops=total))
    cat = CutCatalog(model=cfg.name, family=family, img_res=res,
                     points=tuple(points), total_flops=total)
    return cat.subsample(max_cuts) if max_cuts else cat
