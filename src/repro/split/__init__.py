"""Split-computation offloading: ship intermediate features, not frames.

The paper's action space is binary — return the NPU result, or upload the
frame at resolution r.  DynO and the calibration-aided partitioning line of
work (PAPERS.md) add a third family of actions: run the first k blocks
on-device, quantize the intermediate activation to int8, ship *that*, and
let the server finish the remaining blocks.  Under a constrained uplink the
feature payload is often far smaller than any acceptable frame encoding,
and the server only pays for the suffix of the network.

  * ``points``  — the partition-point catalog: block boundaries per model
    family (ViT / ResNet / Swin, from the existing configs) with activation
    shapes, raw bytes, and int8 payload bytes under the
    ``quant/quantize.py`` scale+int8 wire format.
  * ``costs``   — device-prefix / server-suffix compute costs from
    per-block FLOP accounting layered on ``launch/roofline.py`` (NPU peak
    vs server peak), and the ``build_action_table`` glue that turns a
    catalog into the planner's ``policy.types.ActionTable``.
"""
from repro.split.points import (
    CutCatalog,
    CutPoint,
    activation_payload_nbytes,
    catalog_for,
)
from repro.split.costs import (
    DEFAULT_NPU_PEAK,
    SplitCost,
    build_action_table,
    split_costs,
)

__all__ = [
    "CutCatalog",
    "CutPoint",
    "SplitCost",
    "DEFAULT_NPU_PEAK",
    "activation_payload_nbytes",
    "build_action_table",
    "catalog_for",
    "split_costs",
]
