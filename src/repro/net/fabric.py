"""The edge fabric: cells x replicas topology behind the serving engines.

``core/netsim.py`` models ONE uplink feeding ONE implicit server — the
paper's single-phone testbed.  Real edge deployments are a topology: many
radio cells (each a serial uplink shared by the streams attached to it),
feeding a pool of slow-tier replicas behind a placement policy.
``EdgeFabric`` is that topology as one object:

  * ``Cell``        — a per-cell ``Uplink`` plus the subset of streams
                      attached to it; the partition is an (S,) cell-id
                      vector (geography: a stream keeps its cell);
  * ``ReplicaPool`` — K slow-tier replicas, per-replica queues
                      (``net/replicas.py``);
  * ``Placement``   — round_robin / jsq / least_land assignment of each
                      escalation to a replica (``net/placement.py``).

``transmit`` is the fabric's one data-plane verb: a round's escalation
batch goes in (already in scheduler order), per-cell upload batches run
through their own uplinks (one vectorized Lindley recursion per cell),
completed uploads are placed onto replicas, the pool serves them, and
reply-land times come out.  The round loop stays free of per-stream
Python: the only loops are over C cells and K replicas.

``EdgeFabric.degenerate(uplink)`` — 1 cell, 1 replica, infinite-capacity
service — reproduces the legacy shared-uplink pipeline bit-for-bit; it is
what ``MultiStreamServer`` builds when no fabric is passed, so every
pre-fabric test and snapshot still pins the same floats.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from repro.core.netsim import Uplink
from repro.net.placement import Placement
from repro.net.replicas import ReplicaPool

__all__ = ["Cell", "EdgeFabric"]


@dataclass
class Cell:
    """One radio cell: a serial uplink and the streams attached to it."""

    cell_id: int
    uplink: Uplink
    streams: np.ndarray  # (s_c,) global stream ids attached to this cell

    @property
    def n_streams(self) -> int:
        return len(self.streams)


class EdgeFabric:
    """Cells + replica pool + placement, wired for batched rounds."""

    def __init__(self, uplinks: Uplink | Sequence[Uplink], pool: ReplicaPool, *,
                 n_streams: int | None = None, cell_of=None,
                 placement: str | Placement = "round_robin"):
        ups = [uplinks] if isinstance(uplinks, Uplink) else list(uplinks)
        if not ups:
            raise ValueError("fabric needs at least one cell uplink")
        self.pool = pool
        self.placement = (placement if isinstance(placement, Placement)
                          else Placement(placement))
        C = len(ups)
        if cell_of is None:
            if n_streams is None:
                raise ValueError("pass cell_of or n_streams")
            cell_of = np.arange(int(n_streams)) % C  # balanced default partition
        self.cell_of = np.asarray(cell_of, dtype=np.int64)
        if len(self.cell_of) == 0 or (self.cell_of < 0).any() or (self.cell_of >= C).any():
            raise ValueError(f"cell_of must map every stream to one of {C} cells")
        if n_streams is not None and len(self.cell_of) != int(n_streams):
            raise ValueError("cell_of length must equal n_streams")
        lats = {u.latency for u in ups}
        if len(lats) != 1:
            # the decision plane's Env carries one scalar latency; relax this
            # when policies learn per-stream latency
            raise ValueError("all cell uplinks must share one latency")
        self.latency = float(lats.pop())
        self.cells = [Cell(c, u, np.flatnonzero(self.cell_of == c))
                      for c, u in enumerate(ups)]
        # per-row actual service times of the most recent transmit batch —
        # replies carry their own processing time (servers timestamp it),
        # so estimators can subtract the true service component even on
        # heterogeneous pools
        self.last_service_time = np.zeros(0, dtype=np.float64)
        # per-row lifecycle detail of the most recent transmit batch when
        # requested (``transmit(collect_detail=True)``, telemetry tracing)
        self.last_detail = None

    # -- shape ------------------------------------------------------------- #

    @property
    def n_cells(self) -> int:
        return len(self.cells)

    @property
    def n_streams(self) -> int:
        return len(self.cell_of)

    @property
    def n_replicas(self) -> int:
        return self.pool.n_replicas

    @property
    def server_time(self) -> float:
        """Nominal T^o the planners/estimators assume."""
        return self.pool.nominal_server_time

    def expected_server_time(self) -> float:
        """Occupancy-calibrated T^o: with a continuous-batching pool this is
        the amortized f(expected_batch)/expected_batch at the observed
        occupancy EWMA; otherwise the nominal mean (bit-equal to
        ``server_time``)."""
        return self.pool.expected_server_time()

    @property
    def occupancy(self) -> float:
        """Observed per-request batch-occupancy EWMA of the slow tier
        (1.0 = serial regime / no batching)."""
        return float(self.pool.avg_batch)

    @property
    def n_transfers(self) -> int:
        return int(sum(c.uplink.n_transfers for c in self.cells))

    def stream_bandwidth(self) -> np.ndarray:
        """(S,) nominal uplink rate of each stream's cell — the optimistic
        full-link prior the fleet's EWMA estimators start from, and the
        scheduler's cost normalizer.  Trace-driven cells use the trace's
        time-weighted mean."""
        bw = np.asarray([c.uplink.trace.mean_bps if c.uplink.trace is not None
                         else c.uplink.bandwidth_bps for c in self.cells])
        return bw[self.cell_of]

    def true_bandwidth(self, t: float) -> np.ndarray:
        """(S,) true instantaneous uplink rate of each stream's cell at
        time ``t`` — the telemetry recorder's ground truth against the
        fleet's EWMA estimates.  Pure: ``Uplink.bandwidth_at`` derives
        jitter per (seed, second) deterministically, so sampling here
        never perturbs the simulation."""
        if not np.isfinite(t):
            return np.full(self.n_streams, np.nan)
        bw = np.asarray([c.uplink.current_bandwidth(float(t))
                         for c in self.cells])
        return bw[self.cell_of]

    # -- data plane --------------------------------------------------------- #

    def transmit(self, stream, payload_bytes, t_submit, *,
                 service_scale=None, collect_detail: bool = False) -> np.ndarray:
        """Route one round's escalations: per-cell uplink upload (rows keep
        their scheduler order within each cell), replica placement on the
        upload-completion times, pool service, reply latency.  Returns
        reply-land times aligned with the input rows.

        ``service_scale`` (optional, per-row) scales each job's replica
        service time — split-computation offloads run only the model suffix
        server-side (``srv_frac``); 1.0 rows are a float no-op.

        ``collect_detail`` additionally stores per-row lifecycle detail in
        ``self.last_detail`` (upload start/end, replica, batch id, service
        completion) for the frame tracer; off is the default and costs
        nothing."""
        stream = np.asarray(stream, dtype=np.int64)
        payloads = np.asarray(payload_bytes, dtype=np.float64)
        subs = np.asarray(t_submit, dtype=np.float64)
        self.last_detail = None
        if len(stream) == 0:
            self.last_service_time = np.zeros(0, dtype=np.float64)
            return np.zeros(0, dtype=np.float64)
        end_tx = np.empty(len(stream), dtype=np.float64)
        up_start = np.empty(len(stream), dtype=np.float64) if collect_detail else None
        rows_cell = self.cell_of[stream]
        for cell in self.cells:
            rows = np.flatnonzero(rows_cell == cell.cell_id)
            if len(rows):
                end_tx[rows] = cell.uplink.upload_batch(payloads[rows], subs[rows])
                if collect_detail:
                    up_start[rows] = cell.uplink.last_starts
        replica = self.placement.assign(self.pool, end_tx)
        done = self.pool.process(end_tx, replica, service_scale=service_scale)
        # batched service reports the member's whole-batch f(n); without
        # batching this is exactly server_time[replica] as before
        self.last_service_time = self.pool.last_service
        if collect_detail:
            self.last_detail = {
                "cell": rows_cell, "up_start": up_start, "up_end": end_tx.copy(),
                "replica": replica, "service": self.pool.last_service.copy(),
                "batch_id": self.pool.last_batch_id.copy(), "done": done.copy(),
            }
        return done + self.latency

    def reset(self):
        for cell in self.cells:
            cell.uplink.reset()
        self.pool.reset()
        self.placement.reset()

    # -- contention counters ------------------------------------------------ #

    def summary(self) -> dict:
        """Per-cell and per-replica contention counters (metrics embed a
        rounded view of this)."""
        return {
            "cells": self.n_cells,
            "replicas": self.n_replicas,
            "placement": self.placement.policy,
            "cell_transfers": [int(c.uplink.n_transfers) for c in self.cells],
            "cell_busy_s": [float(c.uplink.busy_seconds) for c in self.cells],
            "cell_queued_s": [float(c.uplink.queued_seconds) for c in self.cells],
            "replica_jobs": self.pool.n_jobs.tolist(),
            "replica_busy_s": self.pool.busy_seconds.tolist(),
            "replica_queued_s": self.pool.queued_seconds.tolist(),
        }

    # -- constructors -------------------------------------------------------- #

    @classmethod
    def degenerate(cls, uplink: Uplink, n_streams: int) -> "EdgeFabric":
        """1 cell, 1 replica, infinite-capacity service: the legacy
        single-uplink pipeline, bit-for-bit (snapshot-pinned)."""
        pool = ReplicaPool(1, uplink.server_time, serial=False)
        return cls(uplink, pool, n_streams=n_streams, placement="round_robin")

    @classmethod
    def build(cls, *, n_streams: int, n_cells: int = 1, n_replicas: int = 1,
              bandwidth_bps: float = 1e6, latency: float = 0.05,
              server_time: float = 0.037, placement: str = "round_robin",
              jitter: float = 0.0, seed: int = 0, traces=None,
              serial_replicas: bool = True, batching=None) -> "EdgeFabric":
        """Convenience constructor for benchmarks/examples: C homogeneous
        cells (optionally each replaying its own bandwidth trace) in front
        of K serial replicas (optionally continuous-batching ones).  Cell c
        gets seed ``seed + c`` so jittered cells decorrelate."""
        traces = list(traces) if traces is not None else [None] * n_cells
        if len(traces) != n_cells:
            raise ValueError("need one trace (or None) per cell")
        ups = [Uplink(bandwidth_bps=bandwidth_bps, latency=latency,
                      server_time=server_time, jitter=jitter, seed=seed + c,
                      trace=traces[c])
               for c in range(n_cells)]
        pool = ReplicaPool(n_replicas, server_time, serial=serial_replicas,
                           batching=batching)
        return cls(ups, pool, n_streams=n_streams, placement=placement)
