"""The edge fabric: network topology for fleet-scale serving.

Generalizes ``core/netsim.py``'s single shared uplink into the shape real
edge deployments have — many radio cells, a sharded slow tier, and
non-stationary bandwidth:

  * ``fabric``    — ``EdgeFabric`` / ``Cell``: the topology object the
                    serving engines route escalations through;
  * ``replicas``  — ``ReplicaPool``: K slow-tier replicas, per-replica
                    serial queues (vectorized Lindley recursion each);
  * ``placement`` — ``Placement``: round_robin / jsq / least_land
                    replica assignment (+ ``assign_looped`` reference);
  * ``traces``    — ``BandwidthTrace`` replay + synthetic LTE / WiFi /
                    regime-shift generators.

``EdgeFabric.degenerate(uplink)`` (1 cell, 1 replica, constant bandwidth)
reproduces the legacy single-uplink pipeline bit-for-bit — the regression
anchor that lets every pre-fabric snapshot keep pinning the same floats.
See docs/network.md.
"""
from repro.net.fabric import Cell, EdgeFabric
from repro.net.placement import PLACEMENT_POLICIES, Placement, assign_looped
from repro.net.replicas import ReplicaPool
from repro.net.traces import BandwidthTrace, lte_trace, regime_shift_trace, wifi_trace

__all__ = [
    "Cell",
    "EdgeFabric",
    "ReplicaPool",
    "Placement",
    "PLACEMENT_POLICIES",
    "assign_looped",
    "BandwidthTrace",
    "lte_trace",
    "wifi_trace",
    "regime_shift_trace",
]
