"""Trace-driven bandwidth: piecewise-constant replay + synthetic generators.

The paper's uplink is constant (or OU-jittered) bandwidth; real cellular
and WiFi links are neither — they fade, burst, and shift regime when a
device hands over between cells or an interferer appears, and that
non-stationarity is exactly what stresses the EWMA bandwidth estimators
the deployment loop plans with (FastVA and DynO both report it dominating
offload behavior).  ``BandwidthTrace`` replays a piecewise-constant rate
profile through ``Uplink.current_bandwidth`` / ``bandwidth_at``: lookup is
one vectorized ``searchsorted`` over the breakpoint grid, so batched
transfers pay O(log T) per element, not a Python call.

Checked-in generators (all deterministic given a seed):

  * ``lte_trace``         — log-space random walk with occasional deep
                            fades, the shape of drive-test LTE datasets;
  * ``wifi_trace``        — two-state good/bad channel (interference
                            bursts) with in-state wobble;
  * ``regime_shift_trace``— square wave between rate levels; the
                            controlled stimulus the EWMA tracking tests
                            use.

Values are bytes/s internally (like ``Uplink.bandwidth_bps``); the
generators take megabits/s at the API surface like the rest of the repo.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.netsim import mbps

__all__ = ["BandwidthTrace", "lte_trace", "wifi_trace", "regime_shift_trace"]


@dataclass(frozen=True)
class BandwidthTrace:
    """Piecewise-constant bandwidth profile.

    ``bps[i]`` is the rate over ``[t[i], t[i+1])``; the last segment holds
    forever unless ``loop`` is set, in which case the profile repeats with
    period ``duration``.  ``t`` must be ascending and start at 0.0 so every
    simulated instant is covered.
    """

    t: np.ndarray  # (T,) segment start times, ascending, t[0] == 0.0
    bps: np.ndarray  # (T,) bytes/s per segment
    loop: bool = False
    duration: float = 0.0  # loop period; defaults to t[-1] + median segment

    def __post_init__(self):
        t = np.asarray(self.t, dtype=np.float64)
        bps = np.asarray(self.bps, dtype=np.float64)
        if t.ndim != 1 or t.shape != bps.shape or len(t) == 0:
            raise ValueError("trace needs matching 1-D t and bps arrays")
        if t[0] != 0.0 or (np.diff(t) <= 0).any():
            raise ValueError("trace times must be ascending and start at 0.0")
        if (bps <= 0).any():
            raise ValueError("trace bandwidths must be positive")
        object.__setattr__(self, "t", t)
        object.__setattr__(self, "bps", bps)
        if self.duration <= 0:
            # default period: last breakpoint plus one median segment length
            # (== the grid step for the uniform grids the generators emit);
            # pass duration explicitly for non-uniform hand-built traces
            gap = float(np.median(np.diff(t))) if len(t) > 1 else 1.0
            object.__setattr__(self, "duration", float(t[-1]) + gap)
        elif self.duration < t[-1]:
            raise ValueError("loop duration must cover every breakpoint")

    def __len__(self) -> int:
        return len(self.t)

    def bandwidth_at(self, ts) -> np.ndarray:
        """Vectorized lookup: rate in effect at each time (bytes/s)."""
        ts = np.asarray(ts, dtype=np.float64)
        if self.loop:
            ts = np.mod(ts, self.duration)
        idx = np.searchsorted(self.t, ts, side="right") - 1
        return self.bps[np.clip(idx, 0, len(self.t) - 1)]

    def grid(self, pad_to: int | None = None):
        """Fixed-shape breakpoint grid for device-side lookup: ``(t, bps)``
        float64 arrays padded to ``pad_to`` segments.  Pad breakpoints sit
        at ``+inf`` (no finite time ever lands in them) and repeat the last
        rate, so a right-``searchsorted`` minus one over the padded grid
        returns exactly what ``bandwidth_at`` returns over the ragged one —
        this is the shape the JAX engine stores in ``EngineParams``."""
        n = len(self.t) if pad_to is None else int(pad_to)
        if n < len(self.t):
            raise ValueError(f"pad_to={n} < {len(self.t)} trace segments")
        pad = n - len(self.t)
        t = np.concatenate([self.t, np.full(pad, np.inf)])
        bps = np.concatenate([self.bps, np.full(pad, self.bps[-1])])
        return t, bps

    @property
    def mean_bps(self) -> float:
        """Time-weighted mean rate over one period (segment-length weighted)."""
        seg = np.diff(np.r_[self.t, self.duration])
        return float((self.bps * seg).sum() / max(seg.sum(), 1e-12))

    @classmethod
    def from_mbps(cls, t, rates_mbps, **kw) -> "BandwidthTrace":
        return cls(t=np.asarray(t, dtype=np.float64),
                   bps=np.asarray([mbps(float(r)) for r in np.asarray(rates_mbps).ravel()]),
                   **kw)


def lte_trace(duration: float = 120.0, *, mean_mbps: float = 6.0, step: float = 1.0,
              sigma: float = 0.25, fade_prob: float = 0.03, fade_depth: float = 8.0,
              seed: int = 0, loop: bool = True) -> BandwidthTrace:
    """Cellular-shaped trace: mean-reverting log-space walk + deep fades.

    The walk keeps the rate log-normally distributed around ``mean_mbps``;
    with probability ``fade_prob`` per step the channel drops by
    ``fade_depth``x for one step (handover / shadowing), the signature that
    makes LTE drive tests so much burstier than their mean suggests.
    """
    rng = np.random.default_rng(seed)
    n = max(int(np.ceil(duration / step)), 1)
    log_r = np.empty(n)
    x = 0.0
    for i in range(n):
        x = 0.85 * x + sigma * rng.standard_normal()  # AR(1) around the mean
        log_r[i] = x
    rates = mean_mbps * np.exp(log_r - log_r.mean())
    fades = rng.random(n) < fade_prob
    rates = np.where(fades, rates / fade_depth, rates)
    return BandwidthTrace.from_mbps(np.arange(n) * step, np.maximum(rates, 0.05),
                                    loop=loop, duration=n * step)


def wifi_trace(duration: float = 120.0, *, good_mbps: float = 30.0, bad_mbps: float = 3.0,
               step: float = 0.5, p_bad: float = 0.08, p_recover: float = 0.4,
               wobble: float = 0.15, seed: int = 0, loop: bool = True) -> BandwidthTrace:
    """WiFi-shaped trace: two-state Gilbert channel with in-state wobble.

    Good state near ``good_mbps``; interference bursts drop to ``bad_mbps``
    and persist geometrically (``p_recover`` per step to heal)."""
    rng = np.random.default_rng(seed)
    n = max(int(np.ceil(duration / step)), 1)
    rates = np.empty(n)
    bad = False
    for i in range(n):
        bad = (not bad and rng.random() < p_bad) or (bad and rng.random() >= p_recover)
        base = bad_mbps if bad else good_mbps
        rates[i] = base * float(np.clip(1.0 + wobble * rng.standard_normal(), 0.3, 1.7))
    return BandwidthTrace.from_mbps(np.arange(n) * step, rates,
                                    loop=loop, duration=n * step)


def regime_shift_trace(levels_mbps=(20.0, 2.0), *, period: float = 10.0,
                       loop: bool = True) -> BandwidthTrace:
    """Square wave cycling through ``levels_mbps``, ``period`` seconds each —
    the deterministic stimulus for testing how fast EWMA estimators re-lock
    after an abrupt regime change (cell handover, mmWave blockage)."""
    levels = np.asarray(levels_mbps, dtype=np.float64)
    if len(levels) < 2:
        raise ValueError("need at least two levels to shift between")
    t = np.arange(len(levels)) * float(period)
    return BandwidthTrace.from_mbps(t, levels, loop=loop,
                                    duration=len(levels) * float(period))
