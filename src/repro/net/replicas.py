"""Sharded slow tier: K server replicas, each a serial queue.

The paper's edge server is an infinite-capacity fixed delay — every
offload pays ``server_time`` and nothing ever queues behind another
request.  That abstraction is what breaks first at fleet scale: the N=64+
sweeps hammer one implicit server with hundreds of escalations per round.
``ReplicaPool`` makes the slow tier a real resource: K replicas, each with
its own busy-until cursor and its own ``server_time`` (heterogeneous
replicas allowed), processing assigned requests in arrival order via the
same vectorized max-plus (Lindley) recursion the uplink uses — grouped by
replica, one recursion per replica, no per-request Python.

``serial=False`` recovers the paper's infinite-capacity abstraction
(``done = arrive + server_time``, nothing queues): the degenerate edge
fabric uses it so a 1-cell/1-replica fabric reproduces the legacy
single-uplink metrics bit-for-bit.

``batching=ContinuousBatching(...)`` upgrades each replica to a
continuous-batching inference server (``repro.slowtier``): requests landing
within an admission window share a batch whose cost is a latency curve
f(batch) rather than per-request service times.  The *degenerate* batching
config (``FlatService``, zero window, cap 1) routes back through the legacy
serial recursion above and stays bit-for-bit with a batching-free pool.
"""
from __future__ import annotations

import numpy as np

__all__ = ["ReplicaPool"]


class ReplicaPool:
    """K slow-tier replicas with per-replica queues and service times."""

    def __init__(self, n_replicas: int, server_time, *, serial: bool = True,
                 batching=None, batch_beta: float = 0.25):
        if n_replicas < 1:
            raise ValueError("need at least one replica")
        self.n_replicas = int(n_replicas)
        st = np.broadcast_to(np.asarray(server_time, dtype=np.float64),
                             (self.n_replicas,)).copy()
        if (st < 0).any():
            raise ValueError("server_time must be >= 0")
        self.server_time = st
        self.serial = bool(serial)
        if batching is not None and not serial:
            raise ValueError("batching implies serial replicas "
                             "(batches run back-to-back on each replica)")
        if not (0.0 < batch_beta <= 1.0):
            raise ValueError(f"batch_beta must be in (0, 1], got {batch_beta}")
        self.batching = batching
        self.batch_beta = float(batch_beta)
        # EWMA of observed per-request batch occupancy; 1.0 = serial regime
        self.avg_batch = 1.0
        # per-request service time of the most recent ``process`` batch (for
        # batched service this is the member's whole-batch f(n))
        self.last_service = np.zeros(0, dtype=np.float64)
        # per-request batch id of the most recent ``process`` batch:
        # pool-unique, monotone ids for batched service, -1 for unbatched
        # requests (telemetry: which escalations shared one f(n) launch)
        self.last_batch_id = np.zeros(0, dtype=np.int64)
        self._bid_seq = 0  # next global batch id
        self.busy_until = np.zeros(self.n_replicas, dtype=np.float64)
        # contention accounting, per replica
        self.n_jobs = np.zeros(self.n_replicas, dtype=np.int64)
        self.busy_seconds = np.zeros(self.n_replicas, dtype=np.float64)
        self.queued_seconds = np.zeros(self.n_replicas, dtype=np.float64)

    @property
    def nominal_server_time(self) -> float:
        """The scalar T^o planners/estimators assume (mean over replicas)."""
        return float(self.server_time.mean())

    @property
    def _batching_live(self) -> bool:
        return self.batching is not None and not self.batching.degenerate

    def expected_server_time(self) -> float:
        """Occupancy-calibrated T^o: amortized per-request cost
        f(expected_batch)/expected_batch under the configured latency curve
        at the observed occupancy EWMA; the nominal mean without batching
        (bit-equal to the pre-batching estimate)."""
        if not self._batching_live:
            return self.nominal_server_time
        return float(self.batching.model.per_request(self.avg_batch))

    def queue_depth(self, now: float) -> float:
        """Mean pending work (seconds of busy-until beyond ``now``) across
        replicas — the decision plane's congestion observable."""
        return float(np.clip(self.busy_until - now, 0.0, None).mean())

    def process(self, t_arrive, replica, *, service_scale=None) -> np.ndarray:
        """Serve one batch: each request lands on ``replica[i]`` when its
        upload finishes at ``t_arrive[i]``; returns service-completion
        times (reply latency is the fabric's concern, not the pool's).

        Serial replicas serve their requests in arrival order (ties keep
        batch order): within each replica the completion times follow
        ``done_i = max(arrive_i, done_{i-1}) + server_time`` — one Lindley
        recursion per replica over the batch, carried across batches by
        ``busy_until``.  With live (non-degenerate) ``batching``, requests
        are instead grouped into admission-window batches and each batch
        costs f(n) (``repro.slowtier.form_batches``).

        ``service_scale`` (optional, per-request) multiplies each job's
        service time — split-computation offloads run only a suffix of the
        model, so their cost is ``srv_frac * server_time``.  Scale 1.0 is a
        float no-op, so frame-only batches stay bit-for-bit.  Live batching
        shares one f(n) across a batch and cannot price per-request
        suffixes; mixing the two is rejected.
        """
        t_arrive = np.asarray(t_arrive, dtype=np.float64)
        replica = np.asarray(replica, dtype=np.int64)
        if t_arrive.shape != replica.shape:
            raise ValueError("t_arrive and replica must have matching shapes")
        if len(t_arrive) == 0:
            self.last_service = np.zeros(0, dtype=np.float64)
            self.last_batch_id = np.zeros(0, dtype=np.int64)
            return np.zeros(0, dtype=np.float64)
        if (replica < 0).any() or (replica >= self.n_replicas).any():
            raise ValueError("replica id out of range")
        self.last_batch_id = np.full(len(t_arrive), -1, dtype=np.int64)
        st = self.server_time[replica]
        if service_scale is not None:
            scale = np.broadcast_to(
                np.asarray(service_scale, dtype=np.float64), t_arrive.shape)
            if self._batching_live and (scale != 1.0).any():
                raise ValueError(
                    "per-request service_scale (split offloading) is not "
                    "supported with continuous batching — batches share one "
                    "f(n) latency curve")
            st = st * scale
        if self._batching_live:
            return self._process_batched(t_arrive, replica)
        if not self.serial:  # infinite-capacity fixed delay (paper semantics)
            done = t_arrive + st
            self.n_jobs += np.bincount(replica, minlength=self.n_replicas)
            self.busy_seconds += np.bincount(replica, weights=st,
                                             minlength=self.n_replicas)
            np.maximum.at(self.busy_until, replica, done)  # last-completion marker
            self.last_service = st
            return done
        done = np.empty(len(t_arrive), dtype=np.float64)
        order = np.lexsort((np.arange(len(t_arrive)), t_arrive, replica))
        r_s, a_s, s_s = replica[order], t_arrive[order], st[order]
        seg = np.r_[0, np.flatnonzero(np.diff(r_s)) + 1]  # segment starts
        csum = np.cumsum(s_s)
        excl = csum - s_s
        excl -= np.repeat(excl[seg], np.diff(np.r_[seg, len(r_s)]))
        csum_seg = excl + s_s  # per-replica inclusive service cumsum
        eff = np.maximum(a_s, self.busy_until[r_s]) - excl
        for a, b in zip(seg, np.r_[seg[1:], len(r_s)]):  # runmax per replica
            eff[a:b] = np.maximum.accumulate(eff[a:b])
        done_s = eff + csum_seg
        starts = done_s - s_s
        done[order] = done_s
        # fold the batch into the persistent per-replica state
        last = np.r_[seg[1:], len(r_s)] - 1
        self.busy_until[r_s[last]] = done_s[last]
        self.n_jobs += np.bincount(replica, minlength=self.n_replicas)
        self.busy_seconds += np.bincount(r_s, weights=s_s, minlength=self.n_replicas)
        self.queued_seconds += np.bincount(
            r_s, weights=np.clip(starts - a_s, 0.0, None), minlength=self.n_replicas)
        self.last_service = st
        return done

    def _process_batched(self, t_arrive, replica) -> np.ndarray:
        """Continuous-batching service: group by replica (arrival order, ties
        keep batch order — same lexsort as the serial path), run admission-
        window batch formation per replica, fold occupancy into the EWMA."""
        from repro.slowtier import form_batches

        n = len(t_arrive)
        done = np.empty(n, dtype=np.float64)
        service = np.empty(n, dtype=np.float64)
        bsize = np.empty(n, dtype=np.int64)
        order = np.lexsort((np.arange(n), t_arrive, replica))
        r_s, a_s = replica[order], t_arrive[order]
        seg = np.r_[0, np.flatnonzero(np.diff(r_s)) + 1]
        for a, b in zip(seg, np.r_[seg[1:], len(r_s)]):
            k = int(r_s[a])
            d, f, nb, bid = form_batches(a_s[a:b], self.batching,
                                         busy0=self.busy_until[k])
            done[order[a:b]] = d
            service[order[a:b]] = f
            bsize[order[a:b]] = nb
            self.last_batch_id[order[a:b]] = self._bid_seq + bid
            self._bid_seq += int(bid[-1]) + 1
            self.busy_until[k] = d[-1]  # last batch's completion
            first = np.r_[True, bid[1:] != bid[:-1]]  # one row per batch
            self.busy_seconds[k] += float(f[first].sum())
            self.queued_seconds[k] += float(((d - f) - a_s[a:b]).sum())
        self.n_jobs += np.bincount(replica, minlength=self.n_replicas)
        self.last_service = service
        obs = float(bsize.mean())  # per-request mean occupancy this round
        self.avg_batch = (1.0 - self.batch_beta) * self.avg_batch \
            + self.batch_beta * obs
        return done

    def utilization(self, horizon: float) -> np.ndarray:
        """Per-replica service time over [0, horizon].  For serial replicas
        > 1.0 means overload; a ``serial=False`` pool serves concurrently,
        so its ratio measures offered load, not saturation."""
        return self.busy_seconds / max(horizon, 1e-12)

    def reset(self):
        self.busy_until[:] = 0.0
        self.n_jobs[:] = 0
        self.busy_seconds[:] = 0.0
        self.queued_seconds[:] = 0.0
        self.avg_batch = 1.0
        self.last_service = np.zeros(0, dtype=np.float64)
        self.last_batch_id = np.zeros(0, dtype=np.int64)
        self._bid_seq = 0
