"""Placement: which slow-tier replica serves each escalation.

The fabric's per-round decision: a batch of uploads finishes on the cells'
uplinks at times ``t_arrive``; each row must be assigned a replica before
``ReplicaPool.process`` computes completion times.  Assignment happens in
arrival order (the order requests actually reach the tier), ties broken by
batch position, so a policy's view of the queues is causally consistent.

Policies:

  * ``round_robin`` — cyclic over replicas in arrival order, counter
    carried across rounds; state-oblivious, fully vectorized, the right
    default when replicas are homogeneous.
  * ``jsq``         — join-shortest-queue: each request goes to the
    replica with the least pending work (earliest ``busy_until`` in the
    simulated schedule), the classic load balancer.
  * ``least_land``  — least-expected-land-time: minimizes this request's
    own completion ``max(arrive, busy_k) + server_time_k``; differs from
    JSQ exactly when replicas are heterogeneous (a short queue on a slow
    replica can still lose).

``assign`` never mutates the pool — it simulates queue growth on a copy so
the subsequent ``pool.process`` call is the single source of truth.
``assign_looped`` is the obviously-correct per-row reference the
equivalence tests and the bench smoke gate compare against.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.net.replicas import ReplicaPool

__all__ = ["Placement", "assign_looped", "PLACEMENT_POLICIES"]

PLACEMENT_POLICIES = ("round_robin", "jsq", "least_land")


def assign_looped(policy: str, pool: ReplicaPool, t_arrive: np.ndarray,
                  start: int = 0) -> np.ndarray:
    """Reference implementation: one Python decision per request, in
    arrival order, against an explicitly simulated queue state."""
    t_arrive = np.asarray(t_arrive, dtype=np.float64)
    busy = pool.busy_until.copy()
    st = pool.server_time
    out = np.empty(len(t_arrive), dtype=np.int64)
    nxt = start
    for i in np.lexsort((np.arange(len(t_arrive)), t_arrive)):
        if policy == "round_robin":
            k = nxt % pool.n_replicas
            nxt += 1
        elif policy == "jsq":
            k = int(np.argmin(busy))
        elif policy == "least_land":
            k = int(np.argmin(np.maximum(t_arrive[i], busy) + st))
        else:
            raise ValueError(f"unknown placement policy: {policy!r}")
        busy[k] = max(t_arrive[i], busy[k]) + st[k]
        out[i] = k
    return out


@dataclass
class Placement:
    policy: str = "round_robin"
    _next: int = field(default=0, repr=False)  # round-robin cursor across rounds

    def __post_init__(self):
        if self.policy not in PLACEMENT_POLICIES:
            raise ValueError(f"unknown placement policy: {self.policy!r} "
                             f"(choose from {PLACEMENT_POLICIES})")

    def assign(self, pool: ReplicaPool, t_arrive) -> np.ndarray:
        """Replica id per request.  Round-robin is pure index arithmetic;
        the queue-aware policies run one greedy decision per request (the
        recurrence is inherently serial — each choice changes the queue the
        next one sees) but operate on (K,) vectors per step."""
        t_arrive = np.asarray(t_arrive, dtype=np.float64)
        n = len(t_arrive)
        out = np.empty(n, dtype=np.int64)
        if n == 0:
            return out
        order = np.lexsort((np.arange(n), t_arrive))  # arrival order, stable
        if self.policy == "round_robin":
            out[order] = (self._next + np.arange(n)) % pool.n_replicas
            self._next = (self._next + n) % pool.n_replicas
            return out
        busy = pool.busy_until.copy()
        st = pool.server_time
        for i in order:
            if self.policy == "jsq":
                k = int(np.argmin(busy))
            else:  # least_land
                k = int(np.argmin(np.maximum(t_arrive[i], busy) + st))
            busy[k] = max(t_arrive[i], busy[k]) + st[k]
            out[i] = k
        return out

    def reset(self):
        self._next = 0
