"""AdamW with configurable state dtypes + ZeRO/FSDP sharding helpers.

Distributed-optimization features (DESIGN.md §5):
  * low-precision moments (bf16 m/v) — required to fit arctic-480b;
  * params may act as their own master copy (fp32) or train pure-bf16;
  * gradient clipping by global norm;
  * optional int8 gradient compression with error feedback (``compress``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclass(frozen=True)
class OptimConfig:
    lr: float = 1e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    m_dtype: str = "float32"  # float32 | bfloat16
    v_dtype: str = "float32"
    compress_grads: bool = False  # int8 + error feedback (beyond-paper)


def init_state(cfg: OptimConfig, params) -> dict:
    dt_m = jnp.dtype(cfg.m_dtype)
    dt_v = jnp.dtype(cfg.v_dtype)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, dt_m), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, dt_v), params),
    }
    if cfg.compress_grads:
        state["err"] = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)
    return state


def state_struct(cfg: OptimConfig, param_struct) -> dict:
    """ShapeDtypeStruct mirror of init_state (dry-run)."""
    dt_m = jnp.dtype(cfg.m_dtype)
    dt_v = jnp.dtype(cfg.v_dtype)
    st = {
        "step": jax.ShapeDtypeStruct((), jnp.int32),
        "m": jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, dt_m), param_struct),
        "v": jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, dt_v), param_struct),
    }
    if cfg.compress_grads:
        st["err"] = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16), param_struct)
    return st


def _compress_decompress(g, err):
    """int8 round trip with error feedback: returns (g_hat, new_err)."""
    gf = g.astype(F32) + err.astype(F32)
    amax = jnp.max(jnp.abs(gf))
    scale = jnp.maximum(amax, 1e-20) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127)
    g_hat = q * scale
    return g_hat.astype(g.dtype), (gf - g_hat).astype(jnp.bfloat16)


def apply_updates(cfg: OptimConfig, params, grads, state) -> tuple:
    """One AdamW step; returns (new_params, new_state)."""
    step = state["step"] + 1
    if cfg.compress_grads:
        pairs = jax.tree.map(_compress_decompress, grads, state["err"])
        grads = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    # global-norm clip (clip_norm=0 disables — see EXPERIMENTS.md §Perf)
    if cfg.clip_norm > 0:
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(F32))) for g in jax.tree.leaves(grads)))
        clip = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
    else:
        clip = jnp.ones((), F32)

    bc1 = 1.0 - cfg.b1 ** step.astype(F32)
    bc2 = 1.0 - cfg.b2 ** step.astype(F32)

    def upd(p, g, m, v):
        gf = g.astype(F32) * clip
        m_new = cfg.b1 * m.astype(F32) + (1 - cfg.b1) * gf
        v_new = cfg.b2 * v.astype(F32) + (1 - cfg.b2) * jnp.square(gf)
        delta = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.eps)
        p_new = p.astype(F32) - cfg.lr * (delta + cfg.weight_decay * p.astype(F32))
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"step": step, "m": new_m, "v": new_v}
    if cfg.compress_grads:
        new_state["err"] = new_err
    return new_params, new_state
