"""Training loop with checkpoint/restart fault tolerance.

Features per DESIGN.md §5: jit'd train step on a local mesh, deterministic
data pipeline (resume = seek by step), async atomic checkpoints, failure
injection (`fail_at_step` simulates a node crash; `run_with_restarts`
demonstrates recovery), gradient accumulation.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.manager import CheckpointManager
from repro.data.pipeline import DeterministicPipeline
from repro.train import optim


class InjectedFailure(RuntimeError):
    """Simulated node failure (fault-tolerance drills)."""


@dataclass
class TrainConfig:
    n_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    grad_accum: int = 1
    fail_at_step: int = -1  # inject a crash once at this step (drills)
    ocfg: optim.OptimConfig = field(default_factory=optim.OptimConfig)


class Trainer:
    def __init__(self, cfg: TrainConfig, loss_fn: Callable, init_params, pipeline: DeterministicPipeline):
        self.cfg = cfg
        self.loss_fn = loss_fn
        self.pipeline = pipeline
        self.ckpt = CheckpointManager(cfg.ckpt_dir)
        self.state = {"params": init_params, "opt": optim.init_state(cfg.ocfg, init_params), "data_step": jnp.zeros((), jnp.int32)}
        self._step_fn = jax.jit(self._make_step(), donate_argnums=(0,))
        self.losses: list[float] = []
        self._failed_once = False

    def _make_step(self):
        ocfg = self.cfg.ocfg
        accum = self.cfg.grad_accum

        def step(state, batch):
            def loss(p, b):
                return self.loss_fn(p, b)

            if accum == 1:
                l, grads = jax.value_and_grad(loss)(state["params"], batch)
            else:
                def micro(i, carry):
                    tot_l, tot_g = carry
                    mb = jax.tree.map(lambda x: x.reshape(accum, -1, *x.shape[1:])[i], batch)
                    l, g = jax.value_and_grad(loss)(state["params"], mb)
                    return tot_l + l / accum, jax.tree.map(lambda a, b: a + b / accum, tot_g, g)

                zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
                l, grads = jax.lax.fori_loop(0, accum, micro, (jnp.zeros((), jnp.float32), zeros))
            new_p, new_o = optim.apply_updates(ocfg, state["params"], grads, state["opt"])
            return {"params": new_p, "opt": new_o, "data_step": state["data_step"] + 1}, l

        return step

    # ------------------------------------------------------------------ API
    def resume_if_possible(self) -> int:
        step = self.ckpt.latest_step()
        if step is None:
            return 0
        self.state = self.ckpt.restore(step, self.state)
        return step

    def run(self, start_step: Optional[int] = None) -> dict:
        cfg = self.cfg
        step = self.resume_if_possible() if start_step is None else start_step
        t0 = time.time()
        while step < cfg.n_steps:
            if step == cfg.fail_at_step and not self._failed_once:
                self._failed_once = True
                raise InjectedFailure(f"simulated node failure at step {step}")
            batch = jax.tree.map(jnp.asarray, self.pipeline.batch_at(step))
            self.state, loss = self._step_fn(self.state, batch)
            step += 1
            if step % cfg.log_every == 0 or step == cfg.n_steps:
                l = float(loss)
                self.losses.append(l)
                print(f"step {step}: loss={l:.4f} ({(time.time()-t0)/max(step,1):.2f}s/step)", flush=True)
            if step % cfg.ckpt_every == 0 or step == cfg.n_steps:
                self.ckpt.save(step, self.state)
        self.ckpt.wait()
        return {"final_loss": self.losses[-1] if self.losses else None, "steps": step}

    def run_with_restarts(self, max_restarts: int = 2) -> dict:
        """Supervisor loop: restart from the last checkpoint on failure."""
        for attempt in range(max_restarts + 1):
            try:
                return self.run()
            except InjectedFailure as e:
                print(f"[supervisor] {e}; restarting from last checkpoint", flush=True)
                self.ckpt.wait()
        raise RuntimeError("exceeded max restarts")
