"""Confidence-score calibration (paper §III-B) + ECE/MCE metrics.

Implements the paper's two calibration families plus temperature scaling:

  * Platt scaling        — parametric logistic  P(y=1|s) = sigmoid(-(A s + B))
                           (paper Eq. form 1/(1+e^{A f(x)+B})), trained by
                           Newton-Raphson on binary NLL in JAX.
  * Isotonic regression  — non-parametric PAVA fit of a monotone step
                           function, predicted via searchsorted.
  * Temperature scaling  — single T on the logits (Guo et al. 2017), Newton.

Metrics follow the paper exactly: 10 equal-width bins on [0,1],
ECE = sum |B_i|/n * |acc(B_i) - conf(B_i)|, MCE = max_i |acc - conf|.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

F32 = jnp.float32


# --------------------------------------------------------------------------- #
# Metrics (paper's definitions, 10 bins of width 0.1)
# --------------------------------------------------------------------------- #


def reliability_bins(conf, correct, n_bins: int = 10):
    """Returns (bin_count, bin_accuracy, bin_mean_conf) per bin."""
    conf = np.asarray(conf, np.float64)
    correct = np.asarray(correct, np.float64)
    edges = np.linspace(0.0, 1.0, n_bins + 1)
    idx = np.clip(np.digitize(conf, edges[1:-1]), 0, n_bins - 1)
    count = np.zeros(n_bins)
    acc = np.zeros(n_bins)
    mc = np.zeros(n_bins)
    for b in range(n_bins):
        m = idx == b
        count[b] = m.sum()
        if count[b]:
            acc[b] = correct[m].mean()
            mc[b] = conf[m].mean()
    return count, acc, mc


def ece(conf, correct, n_bins: int = 10) -> float:
    count, acc, mc = reliability_bins(conf, correct, n_bins)
    n = count.sum()
    return float(np.sum(count / max(n, 1) * np.abs(acc - mc)))


def mce(conf, correct, n_bins: int = 10) -> float:
    count, acc, mc = reliability_bins(conf, correct, n_bins)
    gaps = np.abs(acc - mc)[count > 0]
    return float(gaps.max()) if gaps.size else 0.0


# --------------------------------------------------------------------------- #
# Platt scaling
# --------------------------------------------------------------------------- #


@dataclass
class PlattCalibrator:
    a: float = -1.0
    b: float = 0.0

    def __call__(self, s):
        return jax.nn.sigmoid(-(self.a * jnp.asarray(s, F32) + self.b))

    @staticmethod
    def fit(scores, correct, n_iter: int = 50) -> "PlattCalibrator":
        s = jnp.asarray(scores, F32)
        # Platt's target smoothing (avoids overconfident saturation)
        n_pos = float(np.sum(np.asarray(correct) > 0.5))
        n_neg = float(len(correct) - n_pos)
        y = jnp.where(jnp.asarray(correct) > 0.5, (n_pos + 1) / (n_pos + 2), 1.0 / (n_neg + 2))

        def nll(ab):
            z = -(ab[0] * s + ab[1])
            p = jax.nn.sigmoid(z)
            return -jnp.mean(y * jnp.log(jnp.clip(p, 1e-12, 1)) + (1 - y) * jnp.log(jnp.clip(1 - p, 1e-12, 1)))

        ab = jnp.array([-1.0, 0.0], F32)
        g_fn = jax.jit(jax.grad(nll))
        h_fn = jax.jit(jax.hessian(nll))
        for _ in range(n_iter):
            g, h = g_fn(ab), h_fn(ab)
            h = h + 1e-6 * jnp.eye(2)
            ab = ab - jnp.linalg.solve(h, g)
        return PlattCalibrator(float(ab[0]), float(ab[1]))


# --------------------------------------------------------------------------- #
# Isotonic regression (PAVA)
# --------------------------------------------------------------------------- #


@dataclass
class IsotonicCalibrator:
    thresholds: np.ndarray | None = None  # sorted score knots
    values: np.ndarray | None = None  # monotone fitted values

    def __call__(self, s):
        idx = jnp.clip(jnp.searchsorted(jnp.asarray(self.thresholds), jnp.asarray(s, F32), side="right") - 1, 0, len(self.values) - 1)
        return jnp.asarray(self.values, F32)[idx]

    @staticmethod
    def fit(scores, correct) -> "IsotonicCalibrator":
        s = np.asarray(scores, np.float64)
        y = np.asarray(correct, np.float64)
        order = np.argsort(s, kind="stable")
        s, y = s[order], y[order]
        # pool adjacent violators (stack-based, O(n))
        vals: list[float] = []
        wts: list[float] = []
        starts: list[int] = []
        for i, yi in enumerate(y):
            vals.append(float(yi))
            wts.append(1.0)
            starts.append(i)
            while len(vals) > 1 and vals[-2] >= vals[-1]:
                v = (vals[-2] * wts[-2] + vals[-1] * wts[-1]) / (wts[-2] + wts[-1])
                w = wts[-2] + wts[-1]
                st = starts[-2]
                vals = vals[:-2] + [v]
                wts = wts[:-2] + [w]
                starts = starts[:-2] + [st]
        thresholds = np.array([s[st] for st in starts])
        return IsotonicCalibrator(thresholds, np.asarray(vals))


# --------------------------------------------------------------------------- #
# Temperature scaling
# --------------------------------------------------------------------------- #


@dataclass
class TemperatureCalibrator:
    temperature: float = 1.0

    def scale_logits(self, logits):
        return logits / jnp.asarray(self.temperature, F32)

    def __call__(self, logits):
        """Calibrated max-softmax straight from logits."""
        return jnp.max(jax.nn.softmax(logits.astype(F32) / self.temperature, axis=-1), axis=-1)

    @staticmethod
    def fit(logits, labels, n_iter: int = 50) -> "TemperatureCalibrator":
        lg = jnp.asarray(logits, F32)
        lb = jnp.asarray(labels)

        def nll(log_t):
            z = lg / jnp.exp(log_t)
            lse = jax.nn.logsumexp(z, axis=-1)
            gold = jnp.take_along_axis(z, lb[:, None], axis=-1)[:, 0]
            return jnp.mean(lse - gold)

        log_t = jnp.zeros(())
        g_fn = jax.jit(jax.grad(nll))
        h_fn = jax.jit(jax.hessian(nll))
        for _ in range(n_iter):
            g, h = g_fn(log_t), h_fn(log_t)
            log_t = log_t - g / jnp.maximum(jnp.abs(h), 1e-6) * jnp.sign(h + 1e-12)
        return TemperatureCalibrator(float(jnp.exp(log_t)))


@dataclass
class ScoreTemperatureCalibrator:
    """Scores→scores adapter for temperature scaling.

    ``TemperatureCalibrator`` consumes logits, which the serving engines
    (and every other calibrator) never see — they calibrate max-softmax
    *scores*.  This wrapper applies the fitted temperature to the
    equivalent two-class logit gap: s = sigmoid(z) ⇒ sigmoid(z / T).
    Exact for binary problems; the standard monotone approximation
    otherwise.  Makes temperature scaling interchangeable with Platt /
    isotonic wherever a score→score map is expected.
    """

    temperature: float = 1.0

    def __call__(self, s):
        p = jnp.clip(jnp.asarray(s, F32), 1e-6, 1.0 - 1e-6)
        z = jnp.log(p) - jnp.log1p(-p)
        return jax.nn.sigmoid(z / self.temperature)

    @staticmethod
    def fit(logits, labels, n_iter: int = 50) -> "ScoreTemperatureCalibrator":
        t = TemperatureCalibrator.fit(logits, labels, n_iter=n_iter)
        return ScoreTemperatureCalibrator(t.temperature)


def fit_all(scores, correct, logits=None, labels=None) -> dict:
    """Fit every calibrator; returns {name: calibrator} (paper Table I set).

    Every entry has the uniform signature the engines expect: a callable
    mapping confidence scores → calibrated scores.  Temperature scaling
    (logit-based) is wrapped in ``ScoreTemperatureCalibrator`` so it is
    interchangeable with the score-based calibrators.
    """
    out = {
        "uncalibrated": lambda s: jnp.asarray(s, F32),
        "platt": PlattCalibrator.fit(scores, correct),
        "isotonic": IsotonicCalibrator.fit(scores, correct),
    }
    if logits is not None and labels is not None:
        out["temperature"] = ScoreTemperatureCalibrator.fit(logits, labels)
    return out
