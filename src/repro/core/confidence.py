"""Confidence scores from classifier outputs (paper §III-A).

The paper's score is max-softmax over the (unnormalized) feature vector.
We also provide margin and entropy scores (used in ablations).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def max_softmax(logits) -> jnp.ndarray:
    """The paper's confidence score: max_i sigma(x_i). logits: (..., N)."""
    return jnp.max(jax.nn.softmax(logits.astype(F32), axis=-1), axis=-1)


def margin(logits) -> jnp.ndarray:
    """Top-1 minus top-2 softmax probability."""
    p = jax.nn.softmax(logits.astype(F32), axis=-1)
    top2 = jax.lax.top_k(p, 2)[0]
    return top2[..., 0] - top2[..., 1]


def neg_entropy(logits) -> jnp.ndarray:
    """Normalized negative entropy in [0, 1] (1 = fully confident)."""
    p = jax.nn.softmax(logits.astype(F32), axis=-1)
    h = -jnp.sum(p * jnp.log(jnp.clip(p, 1e-12, 1.0)), axis=-1)
    return 1.0 - h / jnp.log(p.shape[-1])


def sequence_confidence(token_logits, mask=None) -> jnp.ndarray:
    """LM adaptation: mean per-token max-softmax over a sequence.

    token_logits: (B, S, V); mask: (B, S) optional validity mask.
    """
    c = max_softmax(token_logits)  # (B, S)
    if mask is None:
        return c.mean(-1)
    m = mask.astype(F32)
    return (c * m).sum(-1) / jnp.maximum(m.sum(-1), 1.0)


SCORES = {"max_softmax": max_softmax, "margin": margin, "neg_entropy": neg_entropy}
