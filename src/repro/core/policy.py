"""Adaptive CBO controller (paper §IV-D deployment loop).

Maintains the backlog of locally-classified frames, estimates bandwidth with
an EWMA over observed transfers, and re-runs Algorithm 1 to refresh
(theta, resolution, capacity) — the knobs the data plane consumes.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.cbo import Env, Frame, Plan, cbo_plan


@dataclass
class BandwidthEstimator:
    alpha: float = 0.3
    estimate_bps: float = 1e6

    def observe(self, payload_bytes: float, seconds: float):
        if seconds > 1e-9:
            self.estimate_bps = (1 - self.alpha) * self.estimate_bps + self.alpha * (payload_bytes / seconds)


@dataclass
class AdaptiveController:
    resolutions: tuple[int, ...]
    acc_server: tuple[float, ...]  # A^o_r, measured offline (paper Fig. 10)
    deadline: float
    latency: float
    server_time: float
    size_of: callable  # res -> payload bytes
    bw: BandwidthEstimator = field(default_factory=BandwidthEstimator)
    backlog: list = field(default_factory=list)
    max_backlog: int = 64

    def add_frame(self, arrival: float, conf: float):
        self.backlog.append(Frame(arrival, float(conf), tuple(self.size_of(r) for r in self.resolutions)))
        if len(self.backlog) > self.max_backlog:
            self.backlog = self.backlog[-self.max_backlog :]

    def plan(self, now: float) -> Plan:
        env = Env(
            # floor at 1 byte/s: a dead link must plan "all local", not
            # divide by zero inside the DP
            bandwidth=max(self.bw.estimate_bps, 1.0),
            latency=self.latency,
            server_time=self.server_time,
            deadline=self.deadline,
            acc_server=self.acc_server,
        )
        # drop frames whose window already expired
        self.backlog = [f for f in self.backlog if f.arrival + self.deadline > now]
        return cbo_plan(self.backlog, env, now=now)

    def consume(self, frame_indices) -> int:
        """Remove frames that were actually offloaded.

        ``frame_indices`` are backlog indices as seen by the most recent
        ``plan()`` call (which prunes expired frames before planning, so the
        indices stay aligned as long as consume runs before new ``add_frame``
        calls — appends only ever extend the tail). Returns the number of
        frames removed; out-of-range indices are ignored.
        """
        drop = {int(i) for i in frame_indices}
        kept = [f for i, f in enumerate(self.backlog) if i not in drop]
        removed = len(self.backlog) - len(kept)
        self.backlog = kept
        return removed
