"""Compatibility facade for the paper §IV-D deployment loop.

The decision plane moved to ``repro.policy``: policies implement
``observe / plan / consume`` (``repro/policy/base.py``), ``PolicyRunner``
owns the EWMA bandwidth estimate, and serving engines select policies by
name (``policy="cbo"``).  ``AdaptiveController`` — the old hardwired
backlog+EWMA+Algorithm-1 bundle — survives here as a thin shim over
``PolicyRunner`` + ``CBOPolicy`` with its historical constructor and
attributes, so existing callers and tests keep working.  New code should
use ``repro.policy`` directly.
"""
from __future__ import annotations

from typing import Callable, Iterable

from repro.policy.policies import CBOPolicy
from repro.policy.runner import BandwidthEstimator, PolicyRunner
from repro.policy.types import Frame

__all__ = ["AdaptiveController", "BandwidthEstimator"]


class AdaptiveController(PolicyRunner):
    """Deprecated alias: a ``PolicyRunner`` hardwired to the ``cbo`` policy.

    Keeps the pre-policy-plane constructor signature and the ``backlog`` /
    ``add_frame`` / ``plan(now)`` / ``consume`` surface.
    """

    def __init__(self, resolutions: tuple, acc_server: tuple, deadline: float,
                 latency: float, server_time: float, size_of: Callable,
                 bw: BandwidthEstimator | None = None,
                 backlog: Iterable[Frame] | None = None, max_backlog: int = 64):
        super().__init__(
            CBOPolicy(max_backlog=max_backlog),
            resolutions=resolutions,
            acc_server=acc_server,
            deadline=deadline,
            latency=latency,
            server_time=server_time,
            size_of=size_of,
            bw=bw,
        )
        self.max_backlog = max_backlog
        if backlog:
            self.policy.observe(list(backlog))
