"""The CBO data plane: jit-able two-tier cascade execution (DESIGN.md §2).

Per batch of inputs:
  1. fast tier (quantized "NPU" model) classifies everything;
  2. confidence = calibrated max-softmax;
  3. the K lowest-confidence inputs *below threshold* are gathered
     (static capacity K — chosen by the CBO planner) and re-run on the
     slow tier at the planned fidelity (resolution);
  4. slow predictions are scattered back over the fast ones.

Static shapes throughout: escalation uses `top_k` + gather with a validity
mask, the same relaxation capacity-based MoE dispatch makes. Reduced
resolution r is realised as downsample(r) -> upsample(native): exactly what
an edge server does with a low-resolution upload, and it keeps one compiled
slow-tier signature per batch shape.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.confidence import max_softmax

F32 = jnp.float32


@dataclass(frozen=True)
class CascadeOut:
    preds: jnp.ndarray  # (B,) final predictions
    fast_preds: jnp.ndarray  # (B,) fast-tier predictions
    conf: jnp.ndarray  # (B,) calibrated confidence
    escalated: jnp.ndarray  # (B,) bool — actually re-run on slow tier
    esc_idx: jnp.ndarray  # (K,) gathered indices (padded)


jax.tree_util.register_pytree_node(
    CascadeOut,
    lambda c: ((c.preds, c.fast_preds, c.conf, c.escalated, c.esc_idx), None),
    lambda _, ch: CascadeOut(*ch),
)


def degrade_resolution(images, res: int):
    """Simulate offloading at resolution ``res``: down- then up-sample."""
    B, H, W, C = images.shape
    if res >= H:
        return images
    small = jax.image.resize(images, (B, res, res, C), "bilinear")
    return jax.image.resize(small, (B, H, W, C), "bilinear").astype(images.dtype)


def cascade_classify(
    fast_forward: Callable,
    slow_forward: Callable,
    calibrate: Callable,
    images,
    *,
    threshold,
    capacity: int,
    resolution: int,
    use_fused: bool = False,
    platt_ab=None,
):
    """Run the two-tier cascade on one batch of images.

    ``threshold`` may be a python float or a traced scalar (adaptive theta).
    ``capacity`` and ``resolution`` are static (from the CBO plan).
    """
    B = images.shape[0]
    K = min(capacity, B)
    fast_preds, conf = fast_pass(fast_forward, calibrate, images,
                                 use_fused=use_fused, platt_ab=platt_ab)

    gate = conf < threshold
    score = jnp.where(gate, -conf, -jnp.inf)  # lowest confidence first
    _, esc_idx = jax.lax.top_k(score, K)
    valid = jnp.take(gate, esc_idx)

    esc_imgs = degrade_resolution(jnp.take(images, esc_idx, axis=0), resolution)
    slow_logits = slow_forward(esc_imgs)
    slow_preds = jnp.argmax(slow_logits, axis=-1)

    merged = fast_preds.at[esc_idx].set(jnp.where(valid, slow_preds, jnp.take(fast_preds, esc_idx)))
    escalated = jnp.zeros((B,), bool).at[esc_idx].set(valid)
    return CascadeOut(merged, fast_preds, conf, escalated, esc_idx)


def fast_pass(fast_forward, calibrate, images, *, use_fused: bool = False, platt_ab=None):
    """Fast-tier half of the cascade: predictions + calibrated confidence.

    The multi-stream engine runs this once over the *concatenated* frames of
    every stream (one batched NPU call), then lets each stream's controller
    gate its own slice — the slow-tier half is ``slow_pass_multires``.

    ``use_fused=True`` opts into the fused Pallas softmax-max → Platt →
    gate kernel (``kernels/fused_calib_gate``): the full softmax vector is
    never materialized to HBM.  It needs the Platt coefficients
    ``platt_ab=(a, b)`` (the generic ``calibrate`` callable is bypassed);
    off-TPU the same kernel runs in interpret mode, so results are
    backend-independent.  ``tests/test_cascade.py`` pins parity against
    the unfused path.
    """
    logits = fast_forward(images)
    if use_fused:
        if platt_ab is None:
            raise ValueError("use_fused=True requires platt_ab=(a, b) Platt coefficients")
        from repro.kernels.fused_calib_gate.kernel import calib_gate

        a, b = platt_ab
        B, V = logits.shape
        # block sizes must tile the operand exactly; fall back to one block
        # on ragged batch/vocab extents (trailing partial rounds)
        bb = 128 if B % 128 == 0 else B
        bv = 2048 if V % 2048 == 0 else V
        # theta=0: the gate output is unused here — thresholds come from the
        # planner after confidences are known, via select_escalations/top_k
        conf, _ = calib_gate(logits, float(a), float(b), 0.0, bb=bb, bv=bv,
                             interpret=jax.default_backend() != "tpu")
        return jnp.argmax(logits, axis=-1), conf.astype(F32)
    conf = calibrate(max_softmax(logits)).astype(F32)
    return jnp.argmax(logits, axis=-1), conf


def slow_pass_multires(slow_forward, images, resolutions):
    """Slow-tier half for a gathered cross-stream escalation batch.

    ``images`` are the low-confidence frames aggregated across all streams;
    ``resolutions`` gives each frame's planned upload resolution (streams may
    plan different fidelities). Each frame is degraded at its own resolution,
    then the whole batch runs through ONE slow-tier call — that batching is
    the point: N streams cost one server invocation per round, not N.
    """
    res = np.asarray(resolutions)
    if len(res) != images.shape[0]:
        raise ValueError("one resolution per gathered image")
    degraded = images
    for r in np.unique(res):
        sel = np.flatnonzero(res == r)
        degraded = degraded.at[sel].set(
            degrade_resolution(jnp.take(images, sel, axis=0), int(r))
        )
    return jnp.argmax(slow_forward(degraded), axis=-1)


def make_cascade_fn(fast_forward, slow_forward, calibrate, *, capacity: int, resolution: int):
    """jit-compiled cascade with traced threshold (re-plan without recompile)."""

    @partial(jax.jit, static_argnames=())
    def fn(images, threshold):
        return cascade_classify(
            fast_forward,
            slow_forward,
            calibrate,
            images,
            threshold=threshold,
            capacity=capacity,
            resolution=resolution,
        )

    return fn
