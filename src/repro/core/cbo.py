"""CBO scheduling (paper §IV): online Algorithm 1, offline Optimal, brute oracle.

Problem: frames arrive at rate f (interval gamma = 1/f). Each is classified
on the fast tier ("NPU") instantly with calibrated confidence p_i (which,
being calibrated, *is* the expected accuracy A^npu_{p_i}). A frame may be
offloaded over a serial uplink of bandwidth B (bytes/s) at one of m
resolutions r (payload S(i, r) bytes, server accuracy A^o_r); the reply
arrives after + T^o (server time) + L (network latency) and must land within
the frame's window [arr_i, arr_i + T].

Objective: maximize mean accuracy. The decision per frame is (offload?,
resolution). The paper proves the offline problem NP-hard (subset-sum
reduction, Thm. 1) and solves it with a dominance-pruned DP over a
time-windowed solution graph; the online Algorithm 1 re-plans over the
backlog of locally-processed frames sorted by confidence and emits
(theta, r°) — the threshold and resolution for the next offload.

This module is a compatibility facade: the planners and their value types
now live in ``repro.policy`` (the pluggable decision plane — vectorized
struct-of-arrays frontier DP in ``repro/policy/frontier.py``) and are
re-exported here under their historical names.  The brute-force oracle
(tests only) remains local.  The data plane (batched masked escalation in
JAX) is ``core/cascade.py``.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.policy.frontier import cbo_plan, optimal_schedule
from repro.policy.types import Env, Frame, Plan

__all__ = ["Frame", "Env", "Plan", "cbo_plan", "optimal_schedule", "brute_force"]


# --------------------------------------------------------------------------- #
# Brute-force oracle (tests only)
# --------------------------------------------------------------------------- #


def brute_force(frames: Sequence[Frame], env: Env) -> float:
    """Max achievable total accuracy by exhaustive enumeration (small n)."""
    import itertools

    m = len(env.acc_server)
    n = len(frames)
    order = sorted(range(n), key=lambda i: frames[i].arrival)
    best = -np.inf
    for choice in itertools.product(range(m + 1), repeat=n):  # m = local
        t = 0.0
        acc = 0.0
        ok = True
        for idx in order:
            f, c = frames[idx], choice[idx]
            if c == m:
                acc += f.conf
                continue
            t = max(t, f.arrival) + f.sizes[c] / env.bandwidth
            if t + env.server_time + env.latency > f.arrival + env.deadline:
                ok = False
                break
            acc += env.acc_server[c]
        if ok:
            best = max(best, acc)
    return float(best)
