"""CBO scheduling (paper §IV): online Algorithm 1, offline Optimal, brute oracle.

Problem: frames arrive at rate f (interval gamma = 1/f). Each is classified
on the fast tier ("NPU") instantly with calibrated confidence p_i (which,
being calibrated, *is* the expected accuracy A^npu_{p_i}). A frame may be
offloaded over a serial uplink of bandwidth B (bytes/s) at one of m
resolutions r (payload S(i, r) bytes, server accuracy A^o_r); the reply
arrives after + T^o (server time) + L (network latency) and must land within
the frame's window [arr_i, arr_i + T].

Objective: maximize mean accuracy. The decision per frame is (offload?,
resolution). The paper proves the offline problem NP-hard (subset-sum
reduction, Thm. 1) and solves it with a dominance-pruned DP over a
time-windowed solution graph; the online Algorithm 1 re-plans over the
backlog of locally-processed frames sorted by confidence and emits
(theta, r°) — the threshold and resolution for the next offload.

This module is the host-side control plane (numpy; O(k²m) as in the paper).
The data plane (batched masked escalation in JAX) is ``core/cascade.py``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

import numpy as np


@dataclass(frozen=True)
class Frame:
    arrival: float  # seconds
    conf: float  # calibrated confidence = expected fast-tier accuracy
    sizes: tuple[float, ...]  # payload bytes per resolution (ascending res)


@dataclass(frozen=True)
class Env:
    bandwidth: float  # uplink bytes/s
    latency: float  # one-way-ish network latency L (s)
    server_time: float  # T^o (s)
    deadline: float  # T (s), per-frame window
    acc_server: tuple[float, ...]  # A^o_r per resolution (ascending res)


@dataclass
class Plan:
    """Result of a CBO planning pass."""

    theta: float  # confidence threshold for offloading
    resolution: int  # r° — resolution index for the next offload
    offloads: list[tuple[int, int]]  # (frame index, resolution index)
    total_gain: float  # sum of (A^o_r - p_i) over planned offloads
    base_acc: float  # sum of p_i (all local)
    n_frames: int = 0

    @property
    def mean_acc(self) -> float:
        return (self.base_acc + self.total_gain) / max(self.n_frames, 1)


# --------------------------------------------------------------------------- #
# Algorithm 1 (online) — DP over confidence-sorted backlog, dominance pruning
# --------------------------------------------------------------------------- #


def cbo_plan(frames: Sequence[Frame], env: Env, *, now: float = 0.0) -> Plan:
    """Paper Algorithm 1 with parent pointers instead of equality backtracking
    (identical schedule; the pointers just make the chain reconstruction
    O(k) and exact under float arithmetic).

    Frames are sorted by descending confidence; the DP decides, frame by
    frame, whether to append its transmission to the serial uplink schedule.
    Returns theta = max confidence among planned offloads (0 if none) and the
    resolution of the highest-confidence planned offload.
    """
    k = len(frames)
    m = len(env.acc_server)
    order = sorted(range(k), key=lambda i: -frames[i].conf)

    # pair: (t_busy, gain, parent_pair, decision)  decision = (frame, r) | None
    pairs: list[tuple] = [(now, 0.0, None, None)]
    for j in order:
        f = frames[j]
        cand = list(pairs)  # "no offload" carries every pair over unchanged
        for p in pairs:
            t, gain = p[0], p[1]
            for r in range(m):
                t_new = max(t, f.arrival) + f.sizes[r] / env.bandwidth
                if t_new + env.server_time + env.latency <= f.arrival + env.deadline:
                    dA = env.acc_server[r] - f.conf
                    if dA > 0:
                        cand.append((t_new, gain + dA, p, (j, r)))
        # dominance pruning: Pareto frontier over (t ascending, gain ascending)
        cand.sort(key=lambda p: (p[0], -p[1]))
        pairs = []
        best = -np.inf
        for p in cand:
            if p[1] > best + 1e-12:
                pairs.append(p)
                best = p[1]
    best_pair = max(pairs, key=lambda p: p[1])
    chain: list[tuple[int, int]] = []
    node = best_pair
    while node is not None and node[3] is not None:
        chain.append(node[3])
        node = node[2]
    base = sum(f.conf for f in frames)
    if not chain:
        return Plan(theta=0.0, resolution=m - 1, offloads=[], total_gain=0.0, base_acc=base, n_frames=k)
    theta = max(frames[i].conf for i, _ in chain)
    r0 = next(r for i, r in chain if frames[i].conf == theta)
    return Plan(
        theta=theta, resolution=r0, offloads=sorted(chain),
        total_gain=best_pair[1], base_acc=base, n_frames=k,
    )


# --------------------------------------------------------------------------- #
# Offline Optimal — arrival-order DP over the time-windowed solution graph
# --------------------------------------------------------------------------- #


def optimal_schedule(frames: Sequence[Frame], env: Env) -> Plan:
    """The paper's offline optimal (§IV-C): full knowledge of all frames,
    DP over levels (= frames in arrival order), m+1 options per level,
    dominance-pruned (T, C) path attributes. Least cost = max accuracy.
    (The paper's c(V^npu)=+A^npu is treated as the obvious typo for -A.)
    """
    m = len(env.acc_server)
    order = sorted(range(len(frames)), key=lambda i: frames[i].arrival)
    # state: (busy_time, total_acc, parent_state, decision)
    states: list[tuple] = [(0.0, 0.0, None, None)]
    for i in order:
        f = frames[i]
        nxt: list = []
        for st in states:
            t, acc = st[0], st[1]
            nxt.append((t, acc + f.conf, st, None))  # NPU option
            for r in range(m):
                t_new = max(t, f.arrival) + f.sizes[r] / env.bandwidth
                if t_new + env.server_time + env.latency <= f.arrival + env.deadline:
                    nxt.append((t_new, acc + env.acc_server[r], st, (i, r)))
        nxt.sort(key=lambda p: (p[0], -p[1]))
        states = []
        best = -np.inf
        for p in nxt:
            if p[1] > best + 1e-12:
                states.append(p)
                best = p[1]
    best_state = max(states, key=lambda p: p[1])
    chain = []
    node = best_state
    while node is not None:
        if node[3] is not None:
            chain.append(node[3])
        node = node[2]
    base = sum(f.conf for f in frames)
    gain = best_state[1] - base
    theta = max((frames[i].conf for i, _ in chain), default=0.0)
    r0 = next((r for i, r in chain if frames[i].conf == theta), m - 1)
    return Plan(
        theta=theta, resolution=r0, offloads=sorted(chain), total_gain=gain,
        base_acc=base, n_frames=len(frames),
    )


# --------------------------------------------------------------------------- #
# Brute-force oracle (tests only)
# --------------------------------------------------------------------------- #


def brute_force(frames: Sequence[Frame], env: Env) -> float:
    """Max achievable total accuracy by exhaustive enumeration (small n)."""
    import itertools

    m = len(env.acc_server)
    n = len(frames)
    order = sorted(range(n), key=lambda i: frames[i].arrival)
    best = -np.inf
    for choice in itertools.product(range(m + 1), repeat=n):  # m = local
        t = 0.0
        acc = 0.0
        ok = True
        for idx in order:
            f, c = frames[idx], choice[idx]
            if c == m:
                acc += f.conf
                continue
            t = max(t, f.arrival) + f.sizes[c] / env.bandwidth
            if t + env.server_time + env.latency > f.arrival + env.deadline:
                ok = False
                break
            acc += env.acc_server[c]
        if ok:
            best = max(best, acc)
    return float(best)
