"""Network simulator for the paper's testbed regime (benchmarks §V).

Serial uplink with (possibly time-varying) bandwidth, fixed latency, and a
server processing time. Deterministic given a seed. Bandwidths are in
megabits/s at the API surface (as in the paper's figures); bytes internally.

The uplink is the shared, contended resource in multi-stream serving: every
transfer — whichever stream submitted it — serializes through the same
queue. ``transmit`` handles one transfer; ``transmit_batch`` handles a whole
round of transfers at once (vectorized Lindley recursion, including the
time-varying-bandwidth case via a fixed-point iteration) and is what the
multi-stream engine uses. Both update the same ``_busy_until`` cursor and
the same contention counters, so they can be freely mixed.

Bandwidth can vary with time two ways, composable:

  * ``jitter`` — a deterministic pseudo-random per-second factor (OU-ish
    walk indexed by the integer second, seeded);
  * ``trace``  — a ``repro.net.traces.BandwidthTrace`` (piecewise-constant
    replay of a recorded/synthetic cellular or WiFi trace); when set it
    replaces ``bandwidth_bps`` as the base rate and jitter multiplies on
    top.

``upload_batch`` is the wire-only primitive (returns transmission-complete
times, no server/latency added); the edge fabric (``repro.net.fabric``)
uses it to route uploads through per-cell uplinks and then through a
sharded slow tier.  ``transmit_batch`` is exactly ``upload_batch`` plus the
lumped ``server_time + latency`` — the paper's single-server abstraction.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

# cap on fixed-point sweeps before falling back to the exact serial loop;
# real traces converge in 2-4 sweeps, the cap only guards adversarial cases
_FIXED_POINT_SWEEPS = 50


def mbps(x: float) -> float:
    """Megabits/s -> bytes/s."""
    return x * 1e6 / 8.0


def _counter_jitter_factors(seed: int, seconds: np.ndarray, jitter: float) -> np.ndarray:
    """Counter-mode per-second jitter factors: ``fold_in(PRNGKey(seed), s)``
    -> standard normal -> ``clip(1 + jitter*n, 0.2, 2.0)``, all in float32.

    These are the exact bits the JAX engine derives *inside* the jitted
    round scan (``serving/engine_jax.py``), so an ``Uplink`` in
    ``jitter_mode="counter"`` sees the same per-second channel on both
    backends.  The default "pcg" mode (host ``default_rng((seed, s))``)
    stays untouched — it is not reproducible under ``jit``.
    """
    import jax
    import jax.numpy as jnp

    key = jax.random.PRNGKey(int(seed))
    secs = jnp.asarray(np.asarray(seconds, dtype=np.int64).astype(np.int32))
    keys = jax.vmap(lambda s: jax.random.fold_in(key, s))(secs)
    normals = jax.vmap(lambda k: jax.random.normal(k, dtype=jnp.float32))(keys)
    fac = jnp.clip(jnp.float32(1.0) + jnp.float32(jitter) * normals,
                   jnp.float32(0.2), jnp.float32(2.0))
    return np.asarray(fac, dtype=np.float64)


@dataclass
class Uplink:
    bandwidth_bps: float  # bytes per second (base rate; trace overrides)
    latency: float  # seconds (one-way + reply, lumped as L in the paper)
    server_time: float  # T^o
    jitter: float = 0.0  # relative bandwidth jitter (OU-ish random walk)
    seed: int = 0
    # "pcg": host numpy rng (legacy, not expressible under jit);
    # "counter": stateless jax fold_in(seed, second) — bit-identical to the
    # in-scan factors the compiled backend derives, so jittered uplinks can
    # run on backend="jax"
    jitter_mode: str = "pcg"
    trace: Optional[object] = None  # BandwidthTrace (duck-typed: .bandwidth_at)
    _busy_until: float = 0.0
    # per-second jitter factors, cached for exactly the seconds touched
    # (sorted keys + values, so lookups stay vectorized and a transfer at
    # t=1e8 costs one entry, not a dense 0..1e8 table)
    _jit_keys: Optional[np.ndarray] = field(default=None, repr=False)
    _jit_vals: Optional[np.ndarray] = field(default=None, repr=False)
    # contention accounting (updated by transmit / transmit_batch)
    n_transfers: int = 0
    busy_seconds: float = 0.0  # total wire time
    queued_seconds: float = 0.0  # total head-of-line blocking across transfers
    # per-row start times of the most recent upload_batch (telemetry: the
    # queued-at-cell -> on-the-wire transition per transfer)
    last_starts: Optional[np.ndarray] = field(default=None, repr=False)

    def __post_init__(self):
        if self.jitter_mode not in ("pcg", "counter"):
            raise ValueError(f"jitter_mode must be 'pcg' or 'counter', "
                             f"got {self.jitter_mode!r}")
        self._jit_keys = np.zeros(0, dtype=np.int64)
        self._jit_vals = np.zeros(0, dtype=np.float64)

    # -- bandwidth model -------------------------------------------------- #

    def _jitter_factors(self, seconds: np.ndarray) -> np.ndarray:
        """Per-second factors for the requested integer seconds, cached.

        Each second's factor is drawn from its own ``default_rng((seed, s))``
        — a deterministic stream per (seed, second) pair — so growing the
        cache never changes previously observed values, and uplinks with
        different seeds get *independent* channels (additive ``seed + s``
        would make seed c a c-second time shift of seed 0, turning
        multi-cell jitter sweeps into copies of one channel).  Only the
        seconds actually touched are materialized (sorted key/value
        arrays, ``searchsorted`` lookup), keeping cost independent of how
        far into simulated time a transfer lands.
        """
        if len(seconds) == 0:
            return np.zeros(0, dtype=np.float64)
        uniq = np.unique(seconds)
        new = uniq[~np.isin(uniq, self._jit_keys)]
        if len(new):
            if self.jitter_mode == "counter":
                vals = _counter_jitter_factors(self.seed, new, self.jitter)
            else:
                vals = np.asarray([
                    np.clip(1.0 + self.jitter *
                            np.random.default_rng((self.seed, int(s))).standard_normal(),
                            0.2, 2.0)
                    for s in new])
            keys = np.concatenate([self._jit_keys, new])
            order = np.argsort(keys)
            self._jit_keys = keys[order]
            self._jit_vals = np.concatenate([self._jit_vals, vals])[order]
        return self._jit_vals[np.searchsorted(self._jit_keys, seconds)]

    def bandwidth_at(self, t) -> np.ndarray:
        """Vectorized instantaneous bandwidth (bytes/s) at times ``t``."""
        t = np.asarray(t, dtype=np.float64)
        base = (np.asarray(self.trace.bandwidth_at(t), dtype=np.float64)
                if self.trace is not None
                else np.full(t.shape, self.bandwidth_bps))
        if self.jitter > 0:
            base = base * self._jitter_factors(t.astype(np.int64))
        return base

    @property
    def _varying(self) -> bool:
        return self.jitter > 0 or self.trace is not None

    def current_bandwidth(self, t: float) -> float:
        return float(self.bandwidth_at(np.asarray([t]))[0])

    # -- transfers --------------------------------------------------------- #

    def transmit(self, payload_bytes: float, t_submit: float) -> float:
        """Queue a transfer; returns the time the *reply* lands."""
        start = max(t_submit, self._busy_until)
        bw = self.current_bandwidth(start)
        end_tx = start + payload_bytes / bw
        self._busy_until = end_tx
        self.n_transfers += 1
        self.busy_seconds += end_tx - start
        self.queued_seconds += start - t_submit
        return end_tx + self.server_time + self.latency

    def _lindley(self, tx: np.ndarray, subs: np.ndarray) -> np.ndarray:
        """end_i = max(t_submit_i, end_{i-1}) + tx_i with end_{-1} = busy,
        as one cumsum + running max (max-plus / Lindley recursion)."""
        csum = np.cumsum(tx)
        # max(t_submit_j, busy_0) - csum_{j-1}, then running max restores it
        eff = np.maximum(subs, self._busy_until) - (csum - tx)
        return np.maximum.accumulate(eff) + csum

    def upload_batch(self, payload_bytes, t_submit) -> np.ndarray:
        """Queue many transfers in the given order; returns the times each
        *transmission* completes (no server/latency) and updates the busy
        cursor + contention counters.

        Transfers serialize in array order (the scheduler decides that
        order — see ``serving/scheduler.py``), exactly as if ``transmit``
        had been called once per element.  Constant bandwidth is one
        Lindley recursion.  Time-varying bandwidth (jitter and/or trace)
        makes each transfer's rate depend on its start time, which depends
        on the previous end — a serial chain.  We solve it by fixed-point
        iteration: guess the starts, look every transfer's rate up in one
        vectorized pass, re-run the Lindley recursion, repeat until the
        starts stop moving.  Any fixed point satisfies the forward
        recursion exactly, so the result equals the serial loop's; traces
        and jitter change rates only at piecewise boundaries, so 2-4
        sweeps converge.  (The pre-vectorization fallback — a Python loop
        per transfer — survives only as the safety net if the iteration
        fails to settle.)
        """
        payloads = np.asarray(payload_bytes, dtype=np.float64)
        subs = np.asarray(t_submit, dtype=np.float64)
        if payloads.size == 0:
            self.last_starts = np.zeros(0, dtype=np.float64)
            return np.zeros(0, dtype=np.float64)
        if not self._varying:
            tx = payloads / self.bandwidth_bps
            end_tx = self._lindley(tx, subs)
        else:
            starts = np.maximum(subs, self._busy_until)
            end_tx = None
            for _ in range(_FIXED_POINT_SWEEPS):
                tx = payloads / self.bandwidth_at(starts)
                end_tx = self._lindley(tx, subs)
                new_starts = end_tx - tx
                if np.array_equal(new_starts, starts):
                    break
                starts = new_starts
            else:  # did not settle: fall back to the exact serial loop
                end_tx = np.empty(len(payloads), dtype=np.float64)
                busy = self._busy_until
                for i in range(len(payloads)):
                    s = max(subs[i], busy)
                    busy = s + payloads[i] / self.current_bandwidth(s)
                    end_tx[i] = busy
                tx = end_tx - np.maximum(subs, np.r_[self._busy_until, end_tx[:-1]])
        starts = end_tx - tx
        self.last_starts = starts
        self._busy_until = float(end_tx[-1])
        self.n_transfers += payloads.size
        self.busy_seconds += float(tx.sum())
        self.queued_seconds += float(np.clip(starts - subs, 0.0, None).sum())
        return end_tx

    def transmit_batch(self, payload_bytes, t_submit) -> np.ndarray:
        """``upload_batch`` plus the lumped server+latency tail: reply-land
        times under the paper's single-server abstraction."""
        end_tx = self.upload_batch(payload_bytes, t_submit)
        if end_tx.size == 0:
            return end_tx
        return end_tx + self.server_time + self.latency

    def would_land_at(self, payload_bytes: float, t_submit: float) -> float:
        """Predicted reply-land time of the *next* transfer, without queueing
        it: the clamped start is computed once and the bandwidth is sampled
        at that same instant — exactly what ``transmit`` will do."""
        start = max(t_submit, self._busy_until)
        bw = self.current_bandwidth(start)
        return start + payload_bytes / bw + self.server_time + self.latency

    def utilization(self, horizon: float) -> float:
        """Wire time over [0, horizon]. Values > 1.0 mean overload: queued
        transfers were still draining after the horizon ended."""
        return self.busy_seconds / max(horizon, 1e-12)

    def reset(self):
        self._busy_until = 0.0
        self.n_transfers = 0
        self.busy_seconds = 0.0
        self.queued_seconds = 0.0


def png_size_model(res, *, base_res: int = 224, base_bytes: float = 60_000.0):
    """Approximate lossless-PNG payload size vs resolution (scales ~ r²).

    Accepts a scalar resolution (returns a float, as before) or an array
    of resolutions (returns a float64 array) — the vectorized ``size_of``
    contract the serving engines rely on (``ServeConfig.size_of``).
    """
    res = np.asarray(res, dtype=np.float64)
    out = base_bytes * (res / base_res) ** 2
    return float(out) if out.ndim == 0 else out


def payload_sizes(size_of, res) -> np.ndarray:
    """Vectorized ``size_of`` with a per-element fallback.

    The ``ServeConfig.size_of`` contract is "accepts resolution arrays"
    (``png_size_model`` does); user-supplied scalar-only callables are
    mapped element-wise so existing configs keep working.
    """
    res = np.asarray(res)
    try:
        out = np.asarray(size_of(res), dtype=np.float64)
        if out.shape == res.shape:
            return out
    except (TypeError, ValueError):
        pass
    return np.asarray([float(size_of(int(r))) for r in res.ravel()],
                      dtype=np.float64).reshape(res.shape)


def transfer_seconds(lands, t_submit, *, latency: float, server_time) -> np.ndarray:
    """Observed wire time per transfer: reply-land minus submit minus the
    known RTT components — what bandwidth estimators feed on, batched.
    ``server_time`` may be a scalar (the paper's fixed T^o) or a
    per-transfer array (each reply reporting its replica's actual service
    time, as the edge fabric does for heterogeneous pools).

    With a sharded slow tier the replies also carry server *queueing*
    delay, which this deliberately does not separate out: a device can
    only measure round-trip time, so replica contention surfaces to the
    estimators as reduced effective bandwidth (and the policies back off),
    exactly as a congested cell would."""
    return np.asarray(lands, dtype=np.float64) - np.asarray(t_submit, dtype=np.float64) \
        - latency - server_time
