"""Network simulator for the paper's testbed regime (benchmarks §V).

Serial uplink with (possibly time-varying) bandwidth, fixed latency, and a
server processing time. Deterministic given a seed. Bandwidths are in
megabits/s at the API surface (as in the paper's figures); bytes internally.

The uplink is the shared, contended resource in multi-stream serving: every
transfer — whichever stream submitted it — serializes through the same
queue. ``transmit`` handles one transfer; ``transmit_batch`` handles a whole
round of transfers at once (vectorized Lindley recursion when the bandwidth
is constant) and is what the multi-stream engine uses. Both update the same
``_busy_until`` cursor and the same contention counters, so they can be
freely mixed.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


def mbps(x: float) -> float:
    """Megabits/s -> bytes/s."""
    return x * 1e6 / 8.0


@dataclass
class Uplink:
    bandwidth_bps: float  # bytes per second
    latency: float  # seconds (one-way + reply, lumped as L in the paper)
    server_time: float  # T^o
    jitter: float = 0.0  # relative bandwidth jitter (OU-ish random walk)
    seed: int = 0
    _busy_until: float = 0.0
    _rng: np.random.Generator = field(default=None, repr=False)
    # contention accounting (updated by transmit / transmit_batch)
    n_transfers: int = 0
    busy_seconds: float = 0.0  # total wire time
    queued_seconds: float = 0.0  # total head-of-line blocking across transfers

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def current_bandwidth(self, t: float) -> float:
        if self.jitter <= 0:
            return self.bandwidth_bps
        # deterministic pseudo-random walk indexed by the integer second
        step = int(t)
        g = np.random.default_rng(self.seed + step)
        factor = float(np.clip(1.0 + self.jitter * g.standard_normal(), 0.2, 2.0))
        return self.bandwidth_bps * factor

    def transmit(self, payload_bytes: float, t_submit: float) -> float:
        """Queue a transfer; returns the time the *reply* lands."""
        start = max(t_submit, self._busy_until)
        bw = self.current_bandwidth(start)
        end_tx = start + payload_bytes / bw
        self._busy_until = end_tx
        self.n_transfers += 1
        self.busy_seconds += end_tx - start
        self.queued_seconds += start - t_submit
        return end_tx + self.server_time + self.latency

    def transmit_batch(self, payload_bytes, t_submit) -> np.ndarray:
        """Queue many transfers in the given order; returns reply-land times.

        Transfers serialize in array order (the scheduler decides that order
        — see ``serving/scheduler.py``), exactly as if ``transmit`` had been
        called once per element. With constant bandwidth the whole queue is
        one vectorized max-plus (Lindley) recursion:

            end_i = max_{j<=i}( max(t_submit_j, busy_0) + sum_{k=j..i} tx_k )

        computed with a cumsum + running max. With jitter the bandwidth
        depends on each transfer's start time, so we fall back to the serial
        loop (still a single call at the API surface).
        """
        payloads = np.asarray(payload_bytes, dtype=np.float64)
        subs = np.asarray(t_submit, dtype=np.float64)
        if payloads.size == 0:
            return np.zeros(0, dtype=np.float64)
        if self.jitter > 0:
            return np.asarray([self.transmit(float(p), float(t)) for p, t in zip(payloads, subs)])
        tx = payloads / self.bandwidth_bps
        csum = np.cumsum(tx)
        # max(t_submit_j, busy_0) - csum_{j-1}, then running max restores the recursion
        eff = np.maximum(subs, self._busy_until) - (csum - tx)
        end_tx = np.maximum.accumulate(eff) + csum
        starts = end_tx - tx
        self._busy_until = float(end_tx[-1])
        self.n_transfers += payloads.size
        self.busy_seconds += float(tx.sum())
        self.queued_seconds += float(np.clip(starts - subs, 0.0, None).sum())
        return end_tx + self.server_time + self.latency

    def would_land_at(self, payload_bytes: float, t_submit: float) -> float:
        bw = self.current_bandwidth(max(t_submit, self._busy_until))
        start = max(t_submit, self._busy_until)
        return start + payload_bytes / bw + self.server_time + self.latency

    def utilization(self, horizon: float) -> float:
        """Wire time over [0, horizon]. Values > 1.0 mean overload: queued
        transfers were still draining after the horizon ended."""
        return self.busy_seconds / max(horizon, 1e-12)

    def reset(self):
        self._busy_until = 0.0
        self.n_transfers = 0
        self.busy_seconds = 0.0
        self.queued_seconds = 0.0


def png_size_model(res, *, base_res: int = 224, base_bytes: float = 60_000.0):
    """Approximate lossless-PNG payload size vs resolution (scales ~ r²).

    Accepts a scalar resolution (returns a float, as before) or an array
    of resolutions (returns a float64 array) — the vectorized ``size_of``
    contract the serving engines rely on (``ServeConfig.size_of``).
    """
    res = np.asarray(res, dtype=np.float64)
    out = base_bytes * (res / base_res) ** 2
    return float(out) if out.ndim == 0 else out


def payload_sizes(size_of, res) -> np.ndarray:
    """Vectorized ``size_of`` with a per-element fallback.

    The ``ServeConfig.size_of`` contract is "accepts resolution arrays"
    (``png_size_model`` does); user-supplied scalar-only callables are
    mapped element-wise so existing configs keep working.
    """
    res = np.asarray(res)
    try:
        out = np.asarray(size_of(res), dtype=np.float64)
        if out.shape == res.shape:
            return out
    except (TypeError, ValueError):
        pass
    return np.asarray([float(size_of(int(r))) for r in res.ravel()],
                      dtype=np.float64).reshape(res.shape)


def transfer_seconds(lands, t_submit, *, latency: float, server_time: float) -> np.ndarray:
    """Observed wire time per transfer: reply-land minus submit minus the
    fixed RTT components — what bandwidth estimators feed on, batched."""
    return np.asarray(lands, dtype=np.float64) - np.asarray(t_submit, dtype=np.float64) \
        - latency - server_time
