"""Network simulator for the paper's testbed regime (benchmarks §V).

Serial uplink with (possibly time-varying) bandwidth, fixed latency, and a
server processing time. Deterministic given a seed. Bandwidths are in
megabits/s at the API surface (as in the paper's figures); bytes internally.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


def mbps(x: float) -> float:
    """Megabits/s -> bytes/s."""
    return x * 1e6 / 8.0


@dataclass
class Uplink:
    bandwidth_bps: float  # bytes per second
    latency: float  # seconds (one-way + reply, lumped as L in the paper)
    server_time: float  # T^o
    jitter: float = 0.0  # relative bandwidth jitter (OU-ish random walk)
    seed: int = 0
    _busy_until: float = 0.0
    _rng: np.random.Generator = field(default=None, repr=False)

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def current_bandwidth(self, t: float) -> float:
        if self.jitter <= 0:
            return self.bandwidth_bps
        # deterministic pseudo-random walk indexed by the integer second
        step = int(t)
        g = np.random.default_rng(self.seed + step)
        factor = float(np.clip(1.0 + self.jitter * g.standard_normal(), 0.2, 2.0))
        return self.bandwidth_bps * factor

    def transmit(self, payload_bytes: float, t_submit: float) -> float:
        """Queue a transfer; returns the time the *reply* lands."""
        bw = self.current_bandwidth(max(t_submit, self._busy_until))
        start = max(t_submit, self._busy_until)
        end_tx = start + payload_bytes / bw
        self._busy_until = end_tx
        return end_tx + self.server_time + self.latency

    def would_land_at(self, payload_bytes: float, t_submit: float) -> float:
        bw = self.current_bandwidth(max(t_submit, self._busy_until))
        start = max(t_submit, self._busy_until)
        return start + payload_bytes / bw + self.server_time + self.latency

    def reset(self):
        self._busy_until = 0.0


def png_size_model(res: int, *, base_res: int = 224, base_bytes: float = 60_000.0) -> float:
    """Approximate lossless-PNG payload size vs resolution (scales ~ r²)."""
    return base_bytes * (res / base_res) ** 2
