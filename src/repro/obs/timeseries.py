"""Per-round fleet time series: the SoA recorder behind ``telemetry=``.

``FleetRecorder`` captures, once per serving round, exactly the signals
the ROADMAP's overload-control loop needs to observe (and that today
vanish into end-of-run scalars):

  * cumulative per-stream counters — frames, offloads (landed), misses,
    correct — as ``(S,)`` int64 rows (bit-equal across backends);
  * the planner's view of the world: per-stream bandwidth EWMA
    (``bw_est``) NEXT TO the true instantaneous cell bandwidth at the
    round start (``bw_true``), so estimation error is a recorded series
    rather than a post-hoc guess;
  * contention state: per-cell busy/queued seconds, per-replica
    busy/queued seconds, the slow tier's occupancy EWMA (``avg_batch``)
    and the occupancy-calibrated ``server_time`` estimate the planner
    used this round;
  * the decision mix: a per-round histogram of planned offloads over the
    ``ActionTable`` grid (``action_off``; frames planned local are the
    round's frames minus the histogram total).

Buffers are preallocated struct-of-arrays, grown by doubling — recording
a round is a handful of row writes, no Python per stream.  Both engines
feed the same recorder: the numpy engine writes rows inline; the JAX
engine emits the per-round record as stacked ``ys`` of its ``lax.scan``
step and the bridge replays them into the recorder host-side, so a
recorded series is backend-comparable under the established tolerance
policy (integers bit-equal, floats at tolerance — ``assert_close``).
"""
from __future__ import annotations

import numpy as np

__all__ = ["FleetRecorder", "relock_lags"]

# integer-exact series (the cross-backend regression gate) vs tolerance
# floats — mirrors tests/_diff.py's EXACT_KEYS policy for round records
INT_KEYS = ("frames", "offloads", "misses", "correct", "action_off")
# host-derived floats (computed identically outside the compiled scan on
# both backends, so they compare bit-for-bit)
HOST_KEYS = ("t", "bw_true")


class FleetRecorder:
    """Growable SoA ring of per-round fleet records."""

    def __init__(self, n_streams: int, n_cells: int = 1, n_replicas: int = 1,
                 n_actions: int = 1, capacity: int = 64):
        self.n_streams = int(n_streams)
        self.n_cells = int(n_cells)
        self.n_replicas = int(n_replicas)
        self.n_actions = int(n_actions)
        self._n = 0
        self._buf = {name: np.zeros((int(capacity),) + shape, dtype=dtype)
                     for name, (shape, dtype) in self._schema().items()}

    def _schema(self) -> dict:
        S, C, K, A = self.n_streams, self.n_cells, self.n_replicas, self.n_actions
        f8, i8 = np.float64, np.int64
        return {
            "t": ((), f8),              # round start (first finite arrival)
            "frames": ((S,), i8),       # cumulative valid frames served
            "offloads": ((S,), i8),     # cumulative landed escalations
            "misses": ((S,), i8),       # cumulative deadline misses
            "correct": ((S,), i8),      # cumulative correct answers
            "bw_est": ((S,), f8),       # post-fold EWMA bandwidth (bytes/s)
            "bw_true": ((S,), f8),      # true cell bandwidth at round start
            "cell_busy_s": ((C,), f8),  # cumulative wire seconds per cell
            "cell_queued_s": ((C,), f8),
            "rep_busy_s": ((K,), f8),   # cumulative service seconds per replica
            "rep_queued_s": ((K,), f8),
            "avg_batch": ((), f8),      # slow-tier occupancy EWMA post-round
            "server_time": ((), f8),    # planner's T^o estimate this round
            "action_off": ((A,), i8),   # planned offloads per action this round
        }

    # -- writing ---------------------------------------------------------- #

    def record_round(self, **fields) -> None:
        """Append one round's record; every schema key must be supplied."""
        schema = self._schema()
        missing = set(schema) - set(fields)
        unknown = set(fields) - set(schema)
        if missing or unknown:
            raise ValueError(f"recorder fields mismatch: missing={sorted(missing)} "
                             f"unknown={sorted(unknown)}")
        n = self._n
        cap = len(self._buf["t"])
        if n == cap:  # grow by doubling; views handed out earlier stay valid
            for name, buf in self._buf.items():
                new = np.zeros((cap * 2,) + buf.shape[1:], dtype=buf.dtype)
                new[:cap] = buf
                self._buf[name] = new
        for name, value in fields.items():
            self._buf[name][n] = np.asarray(value, dtype=schema[name][1])
        self._n = n + 1

    # -- reading ---------------------------------------------------------- #

    @property
    def n_rounds(self) -> int:
        return self._n

    def series(self, name: str) -> np.ndarray:
        """The recorded ``(n_rounds, ...)`` series for one field (a view)."""
        return self._buf[name][: self._n]

    def as_dict(self) -> dict:
        return {name: self.series(name).copy() for name in self._buf}

    # -- derived views ---------------------------------------------------- #

    def jain_series(self) -> np.ndarray:
        """Per-round Jain fairness index over cumulative landed offloads —
        the fairness-collapse trajectory the end-of-run scalar hides."""
        from repro.serving.metrics import jain_index

        off = self.series("offloads")
        return np.asarray([jain_index(row) for row in off])

    def bw_error(self) -> np.ndarray:
        """(n_rounds, S) relative bandwidth estimation error
        ``|bw_est - bw_true| / bw_true`` (nan where bw_true is unknown)."""
        est, true = self.series("bw_est"), self.series("bw_true")
        with np.errstate(invalid="ignore", divide="ignore"):
            return np.abs(est - true) / np.where(true > 0, true, np.nan)

    def summary(self) -> dict:
        """End-of-run digest (small enough to embed in bench payloads)."""
        if self._n == 0:
            return {"rounds": 0}
        off = self.series("action_off")
        frames_total = int(self.series("frames")[-1].sum())
        off_total = int(off.sum())
        err = self.bw_error()
        last_err = err[-1][np.isfinite(err[-1])]
        jain = self.jain_series()
        return {
            "rounds": self._n,
            "streams": self.n_streams,
            "frames": frames_total,
            "offloads_planned": off_total,
            "local_frac": round(1.0 - off_total / max(frames_total, 1), 4),
            "action_mix": [int(x) for x in off.sum(axis=0)],
            "jain_first": round(float(jain[0]), 4),
            "jain_last": round(float(jain[-1]), 4),
            "jain_min": round(float(jain.min()), 4),
            "bw_err_last": (round(float(last_err.mean()), 4)
                            if last_err.size else None),
            "avg_batch_last": round(float(self.series("avg_batch")[-1]), 4),
        }

    # -- cross-backend comparison ----------------------------------------- #

    def assert_close(self, other: "FleetRecorder", *, bw_rtol: float = 1e-2,
                     time_rtol: float = 1e-2, time_atol: float = 1e-4,
                     ctx: str = "") -> None:
        """Pin two recorded series to each other under the exactness
        policy: integer series bit-equal, host-derived floats bit-equal,
        simulated-float series at tolerance (the jax engine accumulates
        float32 timestamps — same bounds as the round-record tests)."""
        assert self._n == other._n, (
            f"{ctx}: round counts differ: {self._n} vs {other._n}")
        for k in INT_KEYS:
            a, b = self.series(k), other.series(k)
            assert np.array_equal(a, b), (
                f"{ctx}: integer series mismatch on {k!r}")
        for k in HOST_KEYS:
            np.testing.assert_allclose(
                other.series(k), self.series(k), rtol=1e-12, equal_nan=True,
                err_msg=f"{ctx}: host-derived series {k}")
        np.testing.assert_allclose(other.series("bw_est"), self.series("bw_est"),
                                   rtol=bw_rtol, err_msg=f"{ctx}: bw_est")
        for k in ("cell_busy_s", "cell_queued_s", "rep_busy_s", "rep_queued_s",
                  "avg_batch", "server_time"):
            np.testing.assert_allclose(other.series(k), self.series(k),
                                       rtol=time_rtol, atol=time_atol,
                                       err_msg=f"{ctx}: {k}")


def relock_lags(recorder: FleetRecorder, *, rtol: float = 0.25,
                shift_rtol: float = 0.2) -> list:
    """EWMA re-lock lag per bandwidth regime shift.

    Detects rounds where the fleet-mean true bandwidth jumps by more than
    ``shift_rtol`` relative (a trace regime shift, a handover), then counts
    how many rounds the mean ``|bw_est - bw_true| / bw_true`` needs to drop
    back under ``rtol``.  Returns ``[(shift_round, lag_rounds | None)]`` —
    ``None`` when the estimate never re-locked before the run ended.
    """
    true = recorder.series("bw_true")
    if len(true) == 0:
        return []
    mean_true = np.nanmean(true, axis=1)
    err = recorder.bw_error()
    mean_err = np.nanmean(err, axis=1)
    out = []
    prev = mean_true[0]
    for r in range(1, len(mean_true)):
        cur = mean_true[r]
        if np.isfinite(prev) and np.isfinite(cur) and prev > 0 \
                and abs(cur - prev) / prev > shift_rtol:
            lag = None
            for d in range(r, len(mean_err)):
                if np.isfinite(mean_err[d]) and mean_err[d] < rtol:
                    lag = d - r
                    break
            out.append((r, lag))
        prev = cur
    return out
