"""Frame-lifecycle tracing: span events for the escalation path.

Every escalated frame walks the same pipeline:

    planned -> queued-at-cell -> uploaded -> placed -> (batched) ->
    served -> landed | missed

``FrameTracer`` records one structured record per escalation (numpy
engine only — the compiled scan has no per-frame host visibility by
design), carrying the cell, replica and batch ids the fabric assigned.
``export_chrome_trace`` renders the records as Chrome trace-event JSON —
open the file at https://ui.perfetto.dev (or chrome://tracing) to see,
per stream / cell / replica track, exactly where a miss spent its
deadline: radio queueing, wire time, replica queueing, or service.

Tracing is per-frame detail and therefore opt-in (``Telemetry(trace=
True)``); the recorder (``obs/timeseries.py``) stays the cheap
always-viable layer.
"""
from __future__ import annotations

import json

import numpy as np

__all__ = ["FrameTracer", "export_chrome_trace"]


class FrameTracer:
    """Per-escalation lifecycle records with cell/replica/batch ids."""

    def __init__(self):
        self.frames: list = []  # one dict per escalated frame

    def record_round(self, *, stream, slot, arrival, t_ready, cell, up_start,
                     up_end, replica, service, done, batch_id, land, ok,
                     deadline: float) -> None:
        """Fold one round's fabric detail in (row-aligned arrays, the
        fabric's transmission order)."""
        stream = np.asarray(stream)
        n = len(stream)
        if n == 0:
            return
        slot = np.asarray(slot)
        arrival = np.asarray(arrival, dtype=np.float64)
        srv_start = np.asarray(done, dtype=np.float64) - np.asarray(
            service, dtype=np.float64)
        for i in range(n):
            self.frames.append({
                "stream": int(stream[i]), "slot": int(slot[i]),
                "cell": int(np.asarray(cell)[i]),
                "replica": int(np.asarray(replica)[i]),
                "batch": int(np.asarray(batch_id)[i]),
                "arrival": float(arrival[i]),
                "t_ready": float(np.asarray(t_ready)[i]),
                "up_start": float(np.asarray(up_start)[i]),
                "up_end": float(np.asarray(up_end)[i]),
                "srv_start": float(srv_start[i]),
                "done": float(np.asarray(done)[i]),
                "land": float(np.asarray(land)[i]),
                "ok": bool(np.asarray(ok)[i]),
                "deadline": float(arrival[i]) + float(deadline),
            })

    @property
    def n_frames(self) -> int:
        return len(self.frames)

    def miss_attribution(self) -> dict:
        """Where missed frames spent their budget: dominant wait per miss
        (``radio`` = cell queue + wire vs ``slow_tier`` = replica queue +
        service), plus mean seconds per phase over the misses."""
        misses = [f for f in self.frames if not f["ok"]]
        out = {"misses": len(misses), "radio": 0, "slow_tier": 0,
               "mean_radio_s": 0.0, "mean_slow_s": 0.0}
        if not misses:
            return out
        radio = np.asarray([f["up_end"] - f["t_ready"] for f in misses])
        slow = np.asarray([f["done"] - f["up_end"] for f in misses])
        out["radio"] = int((radio >= slow).sum())
        out["slow_tier"] = int((radio < slow).sum())
        out["mean_radio_s"] = round(float(radio.mean()), 6)
        out["mean_slow_s"] = round(float(slow.mean()), 6)
        return out

    # -- export ------------------------------------------------------------ #

    def chrome_trace(self) -> dict:
        """Chrome trace-event JSON object (``{"traceEvents": [...]}``).

        Track layout: pid 1 = client streams (one tid per stream), pid 2 =
        radio cells, pid 3 = slow-tier replicas.  Durations are "X"
        complete events with microsecond timestamps; land/miss outcomes are
        "i" instants on the stream track.
        """
        us = 1e6
        ev = [
            {"ph": "M", "name": "process_name", "pid": 1,
             "args": {"name": "client streams"}},
            {"ph": "M", "name": "process_name", "pid": 2,
             "args": {"name": "radio cells"}},
            {"ph": "M", "name": "process_name", "pid": 3,
             "args": {"name": "slow-tier replicas"}},
        ]

        def span(name, pid, tid, t0, t1, args=None, cat="frame"):
            if t1 < t0:  # numerical guard; spans are non-negative by design
                t1 = t0
            e = {"ph": "X", "name": name, "cat": cat, "pid": pid, "tid": tid,
                 "ts": t0 * us, "dur": (t1 - t0) * us}
            if args:
                e["args"] = args
            return e

        for f in self.frames:
            fid = f"s{f['stream']}#{f['slot']}"
            args = {"frame": fid, "cell": f["cell"], "replica": f["replica"],
                    "batch": f["batch"], "deadline": f["deadline"]}
            s = f["stream"]
            # stream track: device prefix, then the end-to-end offload span
            ev.append(span("device", 1, s, f["arrival"], f["t_ready"], args))
            ev.append(span("offload" + ("" if f["ok"] else " [miss]"),
                           1, s, f["t_ready"], f["land"], args))
            # cell track: head-of-line queueing then the wire time
            ev.append(span("queued@cell", 2, f["cell"], f["t_ready"],
                           f["up_start"], args))
            ev.append(span("upload", 2, f["cell"], f["up_start"],
                           f["up_end"], args))
            # replica track: placement queueing then (batched) service
            ev.append(span("queued@replica", 3, f["replica"], f["up_end"],
                           f["srv_start"], args))
            name = ("serve" if f["batch"] < 0
                    else f"serve[batch {f['batch']}]")
            ev.append(span(name, 3, f["replica"], f["srv_start"], f["done"],
                           args))
            ev.append({"ph": "i", "name": "landed" if f["ok"] else "MISSED",
                       "pid": 1, "tid": s, "ts": f["land"] * us, "s": "t",
                       "args": args})
        return {"traceEvents": ev, "displayTimeUnit": "ms"}

    def export_chrome_trace(self, path: str) -> str:
        """Write the Chrome trace-event JSON to ``path``; returns it."""
        with open(path, "w") as fh:
            json.dump(self.chrome_trace(), fh, indent=1)
            fh.write("\n")
        return path


def export_chrome_trace(tracer: FrameTracer, path: str) -> str:
    """Module-level convenience mirror of ``FrameTracer.export_chrome_trace``."""
    return tracer.export_chrome_trace(path)
