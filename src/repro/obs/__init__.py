"""Fleet telemetry: per-round time series, frame tracing, profiling.

The observability layer behind ``MultiStreamServer(..., telemetry=...)``
— always available, zero-cost when off (the engines hold ``None`` and
skip every hook).  Three parts (docs/observability.md):

  * ``timeseries.FleetRecorder`` — per-round SoA time series of the
    control loop's observables (counters, bandwidth EWMA vs truth,
    cell/replica contention, occupancy, decision histograms); fed by the
    numpy engine inline and by the JAX engine through stacked ``lax.scan``
    outputs, backend-comparable under the exactness policy;
  * ``trace.FrameTracer`` — per-escalation lifecycle spans with
    cell/replica/batch ids, exported as Chrome trace-event / Perfetto
    JSON (numpy engine only);
  * ``profile.PhaseProfiler`` — wall-clock phase breakdown (plan /
    serve / transmit / fold) plus the AOT compile-vs-steady split for
    jitted entry points.

``Telemetry`` is the bundle the engines consume: pick the parts with
flags, the server binds dimensions at construction.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.obs.profile import DEFAULT, PhaseProfiler, aot_split
from repro.obs.timeseries import FleetRecorder, relock_lags
from repro.obs.trace import FrameTracer, export_chrome_trace

__all__ = ["Telemetry", "FleetRecorder", "FrameTracer", "PhaseProfiler",
           "aot_split", "export_chrome_trace", "relock_lags", "DEFAULT"]


@dataclass
class Telemetry:
    """What to observe: ``record`` (per-round series, cheap, default on),
    ``trace`` (per-frame lifecycle spans, numpy engine only), ``profile``
    (per-phase wall-clock).  Pass to ``MultiStreamServer(telemetry=...)``;
    the server calls ``bind`` with the fleet's dimensions and the parts
    materialize lazily (pre-built parts are kept)."""

    record: bool = True
    trace: bool = False
    profile: bool = False
    recorder: Optional[FleetRecorder] = None
    tracer: Optional[FrameTracer] = None
    profiler: Optional[PhaseProfiler] = None

    def bind(self, *, n_streams: int, n_cells: int, n_replicas: int,
             n_actions: int) -> "Telemetry":
        if self.record and self.recorder is None:
            self.recorder = FleetRecorder(n_streams, n_cells, n_replicas,
                                          n_actions)
        if self.trace and self.tracer is None:
            self.tracer = FrameTracer()
        if self.profile and self.profiler is None:
            self.profiler = PhaseProfiler()
        return self
