"""Profiling hooks: phase timers for the engines and benches.

Two timing regimes, one reporting surface:

  * ``PhaseProfiler`` — wall-clock accumulators for the numpy engine's
    per-round phases (plan / serve / transmit / fold) and the jax
    bridge's host phases (precompute / scan / fold).  Zero-cost when
    off: the engines hold ``prof = None`` and never touch a clock.
  * ``aot_split`` — the compile-vs-steady split for jitted entry points
    (``fn.lower(*args).compile()`` timed as one explicit step), so
    ``compile_s`` is a measured wall-clock, never a first-call
    subtraction.  ``bench_fleet_control.py`` reports both numbers
    through it.

``summarize()`` is the shared reporting format; ``emit_bench_json``
attaches the module-level ``DEFAULT`` profiler's summary to every
``BENCH_*.json`` payload whenever it holds any samples.
"""
from __future__ import annotations

import time
from contextlib import contextmanager

__all__ = ["PhaseProfiler", "aot_split", "DEFAULT"]


class PhaseProfiler:
    """Named wall-clock accumulators (total seconds + call counts)."""

    def __init__(self):
        self.totals: dict[str, float] = {}
        self.counts: dict[str, int] = {}

    def add(self, name: str, seconds: float) -> None:
        self.totals[name] = self.totals.get(name, 0.0) + float(seconds)
        self.counts[name] = self.counts.get(name, 0) + 1

    @contextmanager
    def phase(self, name: str):
        """``with prof.phase("plan"): ...`` — one timed region."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.add(name, time.perf_counter() - t0)

    def __bool__(self) -> bool:  # "does it hold samples" (DEFAULT gating)
        return bool(self.totals)

    def summarize(self) -> dict:
        """Per-phase ``{total_s, calls, mean_ms}`` plus the grand total —
        the block ``emit_bench_json`` embeds under ``"profile"``."""
        out = {}
        for name in self.totals:
            t, c = self.totals[name], self.counts[name]
            out[name] = {"total_s": round(t, 6), "calls": c,
                         "mean_ms": round(t / max(c, 1) * 1e3, 4)}
        if out:
            out["total_s"] = round(sum(self.totals.values()), 6)
        return out

    def reset(self) -> None:
        self.totals.clear()
        self.counts.clear()


def aot_split(fn, *args, profiler: PhaseProfiler | None = None):
    """AOT-compile a jitted callable and time the lower+compile step.

    Returns ``(compiled, compile_s)``.  The caller times steady-state
    executions of ``compiled`` itself (donated buffers make that
    caller-specific); when ``profiler`` is given the compile time is also
    folded in under ``"compile"``.
    """
    t0 = time.perf_counter()
    compiled = fn.lower(*args).compile()
    dt = time.perf_counter() - t0
    if profiler is not None:
        profiler.add("compile", dt)
    return compiled, dt


# benches fold into this one by default so emit_bench_json can attach a
# profile block without threading a profiler through every bench signature
DEFAULT = PhaseProfiler()
