"""Reference (pre-vectorization) planners, kept verbatim for testing.

These are the original Python tuple-chain implementations of Algorithm 1
and the offline optimal.  They are the ground truth the vectorized
``frontier`` planners are checked against (``tests/test_policy.py``) and
the baseline for the ``bench_policy_planner`` micro-benchmark.  Do not use
them in serving paths — they are the slow thing the frontier replaced.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.policy.types import Env, Frame, Plan, plan_from_chain


def cbo_plan_reference(frames: Sequence[Frame], env: Env, *, now: float = 0.0) -> Plan:
    """Original Algorithm 1: Python list of (t, gain, parent, decision)."""
    k = len(frames)
    m = len(env.acc_server)
    order = sorted(range(k), key=lambda i: -frames[i].conf)

    pairs: list[tuple] = [(now, 0.0, None, None)]
    for j in order:
        f = frames[j]
        cand = list(pairs)  # "no offload" carries every pair over unchanged
        for p in pairs:
            t, gain = p[0], p[1]
            for r in range(m):
                t_new = max(t, f.arrival) + f.sizes[r] / env.bandwidth
                if t_new + env.server_time + env.latency <= f.arrival + env.deadline:
                    dA = env.acc_server[r] - f.conf
                    if dA > 0:
                        cand.append((t_new, gain + dA, p, (j, r)))
        cand.sort(key=lambda p: (p[0], -p[1]))
        pairs = []
        best = -np.inf
        for p in cand:
            if p[1] > best + 1e-12:
                pairs.append(p)
                best = p[1]
    best_pair = max(pairs, key=lambda p: p[1])
    chain: list[tuple[int, int]] = []
    node = best_pair
    while node is not None and node[3] is not None:
        chain.append(node[3])
        node = node[2]
    return plan_from_chain(chain, frames, best_pair[1] if chain else 0.0, m)


def optimal_schedule_reference(frames: Sequence[Frame], env: Env) -> Plan:
    """Original offline optimal: arrival-order DP over tuple-chain states."""
    m = len(env.acc_server)
    order = sorted(range(len(frames)), key=lambda i: frames[i].arrival)
    states: list[tuple] = [(0.0, 0.0, None, None)]
    for i in order:
        f = frames[i]
        nxt: list = []
        for st in states:
            t, acc = st[0], st[1]
            nxt.append((t, acc + f.conf, st, None))  # NPU option
            for r in range(m):
                t_new = max(t, f.arrival) + f.sizes[r] / env.bandwidth
                if t_new + env.server_time + env.latency <= f.arrival + env.deadline:
                    nxt.append((t_new, acc + env.acc_server[r], st, (i, r)))
        nxt.sort(key=lambda p: (p[0], -p[1]))
        states = []
        best = -np.inf
        for p in nxt:
            if p[1] > best + 1e-12:
                states.append(p)
                best = p[1]
    best_state = max(states, key=lambda p: p[1])
    chain = []
    node = best_state
    while node is not None:
        if node[3] is not None:
            chain.append(node[3])
        node = node[2]
    base = sum(f.conf for f in frames)
    return plan_from_chain(chain, frames, best_state[1] - base, m)
