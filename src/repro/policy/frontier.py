"""Vectorized dominance-pruned DP — the decision plane's hot path.

Both planners (paper Algorithm 1 online, §IV-C offline optimal) are the
same Pareto-frontier recursion: walk the frames in some order; each
frontier state is (uplink busy time, accuracy); every frame expands each
state by "keep local" plus one candidate per deadline-feasible resolution;
dominated states (later AND no better) are pruned.

The old implementation kept the frontier as a Python list of tuple chains
(``(t, gain, parent, decision)``) — O(frontier · m) Python-object churn per
frame, re-run every frame by the serving loop.  Here the frontier is a
struct-of-arrays (t, gain, node-id): candidate expansion is one broadcast
over (frontier × statically-feasible resolutions), pruning is one stable
sort + running max (lexsort only when busy-times tie), and schedules are
reconstructed through integer parent indices into an append-only node pool
that only ever stores frontier survivors.  Candidate *ordering and float
accumulation* are kept identical to the old code so tie-breaking (and
therefore the returned schedule) is bit-for-bit the same;
``tests/test_policy.py`` checks this against the reference implementation
on randomized instances.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.policy.types import Env, Frame, Plan, plan_from_chain

_EPS = 1e-12


def _soa(frames: Sequence[Frame]):
    arr = np.asarray([f.arrival for f in frames], dtype=np.float64)
    conf = np.asarray([f.conf for f in frames], dtype=np.float64)
    sizes = np.asarray([f.sizes for f in frames], dtype=np.float64)
    return arr, conf, sizes


def _prune_positions(cand_t: np.ndarray, cand_gain: np.ndarray) -> np.ndarray:
    """Pareto frontier over (t ascending, gain ascending): stable sort by
    (t, -gain), keep a state iff its gain strictly exceeds the best *kept*
    gain so far (by more than eps) — the old loop, vectorized.  Returns the
    surviving candidate positions in sorted order."""
    order = np.argsort(cand_t, kind="stable")
    t = cand_t[order]
    if len(t) > 1 and (t[1:] == t[:-1]).any():
        # busy-time ties: fall back to the full (t, -gain) key so the
        # tie-break matches the reference sort exactly
        order = np.lexsort((-cand_gain, cand_t))
    g = cand_gain[order]
    n = len(g)
    keep = np.empty(n, dtype=bool)
    keep[0] = True
    if n > 1:
        # prefix-max shortcut: threshold on the max of ALL prior gains.  The
        # reference advances its bar only on KEPT gains, which differs only
        # when a pruned gain sits within eps of a later one — verify
        # self-consistency and fall back to the sequential rule if violated.
        keep[1:] = g[1:] > np.maximum.accumulate(g)[:-1] + _EPS
        last_kept = np.maximum.accumulate(np.where(keep, g, -np.inf))
        if (g[1:] > last_kept[:-1] + _EPS)[~keep[1:]].any():
            best = -np.inf
            for i in range(n):
                keep[i] = g[i] > best + _EPS
                if keep[i]:
                    best = g[i]
    return order[keep]


class _NodePool:
    """Append-only SoA pool of (parent, frame, res) decisions; parent
    indices instead of object chains make reconstruction O(depth)."""

    def __init__(self):
        self._chunks: list[tuple[np.ndarray, int, np.ndarray]] = []
        self.n = 1  # node 0 = root

    def append(self, parent: np.ndarray, frame_idx: int, res: np.ndarray) -> np.ndarray:
        self._chunks.append((parent, frame_idx, res))
        first = self.n
        self.n += len(parent)
        return np.arange(first, self.n, dtype=np.int64)

    def chain(self, node: int) -> list[tuple[int, int]]:
        """Walk parent indices back to the root, collecting offload
        decisions (nodes with frame >= 0; carry nodes are skipped)."""
        parent = np.concatenate([np.asarray([-1], dtype=np.int64)]
                                + [c[0] for c in self._chunks])
        frame = np.concatenate([np.asarray([-1], dtype=np.int64)]
                               + [np.full(len(c[0]), c[1], dtype=np.int64) for c in self._chunks])
        res = np.concatenate([np.asarray([-1], dtype=np.int64)]
                             + [c[2] for c in self._chunks])
        out: list[tuple[int, int]] = []
        while node >= 0:
            if frame[node] >= 0:
                out.append((int(frame[node]), int(res[node])))
            node = int(parent[node])
        return out


def cbo_plan(frames: Sequence[Frame], env: Env, *, now: float = 0.0) -> Plan:
    """Paper Algorithm 1 (online): DP over the confidence-sorted backlog.

    Only offloads with a strictly positive accuracy gain are candidates;
    "keep local" carries a state over unchanged.  Returns theta = max
    confidence among planned offloads and r° selected by frame index
    (see ``plan_from_chain``).
    """
    k = len(frames)
    m = len(env.acc_server)
    if k == 0:
        return plan_from_chain([], frames, 0.0, m)
    arr, conf, sizes = _soa(frames)
    order = np.argsort(-conf, kind="stable")
    tx = sizes / env.bandwidth  # (k, m)
    rtt = env.server_time + env.latency
    acc = np.asarray(env.acc_server, dtype=np.float64)
    # static feasibility: even an idle uplink (start = arrival) cannot make
    # a transmission with tx > deadline - rtt land in time, and dA <= 0
    # never helps — drop those (frame, resolution) pairs up front
    dA_all = acc[None, :] - conf[:, None]  # (k, m)
    static = (tx <= env.deadline - rtt) & (dA_all > 0)

    pool = _NodePool()
    f_t = np.asarray([now])
    f_gain = np.asarray([0.0])
    f_id = np.zeros(1, dtype=np.int64)
    for j in order:
        j = int(j)
        cols = np.flatnonzero(static[j])
        if len(cols) == 0:
            continue
        P = len(f_t)
        # Collapse: every state with t <= arrival starts transmitting at the
        # arrival, so their expansions tie in t; frontier gain is strictly
        # ascending in t, so only the last such state's expansions can
        # survive pruning — expand from it alone.  (Survivor set, and hence
        # the schedule, is provably identical to expanding them all.)
        lo = max(int(np.searchsorted(f_t, arr[j], side="right")) - 1, 0)
        dA = dA_all[j, cols]
        start = np.maximum(f_t[lo:], arr[j])
        t_new = start[:, None] + tx[j, cols][None, :]  # (P - lo, C)
        good = t_new + rtt <= arr[j] + env.deadline
        if good.all():  # fast path: every (state, resolution) pair lands
            new_t = t_new.ravel()
            new_gain = (f_gain[lo:, None] + dA[None, :]).ravel()
            pi = lo + np.repeat(np.arange(P - lo), len(cols))
            ri = np.tile(cols, P - lo)
        else:
            if not good.any():
                continue  # pure carry-over: the frontier is already pruned
            pi, ci = np.nonzero(good)  # row-major: frontier outer, res inner
            new_t = t_new[pi, ci]
            new_gain = f_gain[lo + pi] + dA[ci]
            ri = cols[ci]
            pi = lo + pi
        # candidates: every carried-over state first, then the expansions —
        # the old list order, which pruning tie-breaks depend on
        cand_t = np.concatenate([f_t, new_t])
        cand_gain = np.concatenate([f_gain, new_gain])
        pos = _prune_positions(cand_t, cand_gain)
        new = pos >= P  # surviving expansions get pool nodes; pruned ones never do
        sel = pos[new] - P
        new_ids = pool.append(f_id[pi[sel]], j, ri[sel])
        nxt_id = np.empty(len(pos), dtype=np.int64)
        nxt_id[~new] = f_id[pos[~new]]
        nxt_id[new] = new_ids
        f_id = nxt_id
        f_t, f_gain = cand_t[pos], cand_gain[pos]
    best = int(np.argmax(f_gain))
    return plan_from_chain(pool.chain(int(f_id[best])), frames, float(f_gain[best]), m)


def optimal_schedule(frames: Sequence[Frame], env: Env) -> Plan:
    """The paper's offline optimal (§IV-C): DP over frames in arrival order,
    m+1 options per level (local + every feasible resolution, gain sign
    unconstrained), dominance-pruned (T, C) path attributes.

    Accumulates total *accuracy* (local frames contribute their confidence)
    exactly as the reference did, so pruning near the epsilon boundary makes
    identical decisions; the returned gain is accuracy minus the all-local
    base.
    """
    k = len(frames)
    m = len(env.acc_server)
    if k == 0:
        return plan_from_chain([], frames, 0.0, m)
    arr, conf, sizes = _soa(frames)
    order = np.argsort(arr, kind="stable")
    tx = sizes / env.bandwidth
    rtt = env.server_time + env.latency
    acc = np.asarray(env.acc_server, dtype=np.float64)
    static = tx <= env.deadline - rtt  # (k, m): feasible from an idle uplink

    pool = _NodePool()
    f_t = np.asarray([0.0])
    f_gain = np.asarray([0.0])
    f_id = np.zeros(1, dtype=np.int64)
    for j in order:
        j = int(j)
        P = len(f_t)
        cols = np.flatnonzero(static[j])
        C = len(cols)
        carry_g = f_gain + conf[j]  # "NPU option": accuracy + conf_j
        if C == 0:
            cand_t, cand_gain = f_t, carry_g
            pos = _prune_positions(cand_t, cand_gain)
            src_state, is_off, off_res = pos, np.zeros(len(pos), dtype=bool), None
        else:
            # collapse (see cbo_plan): states with t <= arrival tie in
            # expansion t; only the last (max-gain) one's expansions can
            # survive, so expand from states lo.. only.  Carries never tie.
            lo = max(int(np.searchsorted(f_t, arr[j], side="right")) - 1, 0)
            start = np.maximum(f_t[lo:], arr[j])
            t_new = start[:, None] + tx[j, cols][None, :]
            good = t_new + rtt <= arr[j] + env.deadline
            # old candidate order interleaves per state: carry, then its
            # feasible offload expansions, state by state; states below the
            # collapse point contribute their carry only
            grid_t = np.empty((P - lo, C + 1))
            grid_g = np.full((P - lo, C + 1), -np.inf)
            grid_t[:, 0] = f_t[lo:]
            grid_g[:, 0] = carry_g[lo:]
            np.copyto(grid_t[:, 1:], t_new, where=good)
            np.copyto(grid_g[:, 1:], (f_gain[lo:, None] + acc[cols][None, :]), where=good)
            flat = np.flatnonzero(grid_g.reshape(-1) > -np.inf)
            cand_t = np.concatenate([f_t[:lo], grid_t.reshape(-1)[flat]])
            cand_gain = np.concatenate([carry_g[:lo], grid_g.reshape(-1)[flat]])
            pos = _prune_positions(cand_t, cand_gain)
            in_grid = pos >= lo
            src = flat[pos[in_grid] - lo]  # position in the (P - lo, C+1) grid
            src_state = np.empty(len(pos), dtype=np.int64)
            src_state[~in_grid] = pos[~in_grid]  # prefix carries
            src_state[in_grid] = lo + src // (C + 1)
            src_col = src % (C + 1) - 1  # -1 = carry
            is_off = np.zeros(len(pos), dtype=bool)
            is_off[in_grid] = src_col >= 0
            off_res = cols[src_col[src_col >= 0]]
        nxt_id = np.empty(len(pos), dtype=np.int64)
        if is_off.any():
            nxt_id[is_off] = pool.append(f_id[src_state[is_off]], j, off_res)
        # carries record no decision — chain() would skip them — so they
        # keep their parent's node id instead of minting dead pool nodes
        nxt_id[~is_off] = f_id[src_state[~is_off]]
        f_id = nxt_id
        f_t, f_gain = cand_t[pos], cand_gain[pos]
    best = int(np.argmax(f_gain))
    base = sum(f.conf for f in frames)
    return plan_from_chain(pool.chain(int(f_id[best])), frames,
                           float(f_gain[best]) - base, m)
