"""Vectorized dominance-pruned DP — the decision plane's hot path.

Both planners (paper Algorithm 1 online, §IV-C offline optimal) are the
same Pareto-frontier recursion: walk the frames in some order; each
frontier state is (uplink busy time, accuracy); every frame expands each
state by "keep local" plus one candidate per deadline-feasible resolution;
dominated states (later AND no better) are pruned.

The old implementation kept the frontier as a Python list of tuple chains
(``(t, gain, parent, decision)``) — O(frontier · m) Python-object churn per
frame, re-run every frame by the serving loop.  Here the frontier is a
struct-of-arrays (t, gain, node-id): candidate expansion is one broadcast
over (frontier × statically-feasible resolutions), pruning is one stable
sort + running max (lexsort only when busy-times tie), and schedules are
reconstructed through integer parent indices into an append-only node pool
that only ever stores frontier survivors.  Candidate *ordering and float
accumulation* are kept identical to the old code so tie-breaking (and
therefore the returned schedule) is bit-for-bit the same;
``tests/test_policy.py`` checks this against the reference implementation
on randomized instances.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.policy.types import Env, Frame, Plan, plan_from_chain

_EPS = 1e-12


def _action_vectors(env):
    """Per-action planner columns: (sizes, rtt, t_dev, acc, m_frame).

    Frame-only (``env.actions is None``): ``sizes`` is None (callers use
    their legacy payload source), rtt is the scalar server+latency
    broadcast over the m resolutions, device time is zero.  With an
    ``ActionTable`` the columns are actions — frames first (action index ==
    resolution index), splits after, with per-action rtt (suffix-scaled
    server time) and device-prefix seconds.  For a degenerate table the
    extra vectors are all-zero / all-equal, and ``x + 0.0`` / ``t * 1.0``
    keep every float bit-identical to the frame-only path.
    ``m_frame`` is the frame-action count — plan defaults (r° = m-1) stay
    on the top *resolution*, never a split action.
    """
    if env.actions is None:
        acc = np.asarray(env.acc_server, dtype=np.float64)
        m = len(acc)
        return None, np.full(m, env.server_time + env.latency), np.zeros(m), acc, m
    act = env.actions
    return (np.asarray(act.sizes, dtype=np.float64),
            act.rtt(env.server_time, env.latency),
            np.asarray(act.t_dev, dtype=np.float64),
            np.asarray(act.acc, dtype=np.float64),
            act.n_frame_actions)


def _soa(frames: Sequence[Frame]):
    arr = np.asarray([f.arrival for f in frames], dtype=np.float64)
    conf = np.asarray([f.conf for f in frames], dtype=np.float64)
    sizes = np.asarray([f.sizes for f in frames], dtype=np.float64)
    return arr, conf, sizes


def _prune_positions(cand_t: np.ndarray, cand_gain: np.ndarray) -> np.ndarray:
    """Pareto frontier over (t ascending, gain ascending): stable sort by
    (t, -gain), keep a state iff its gain strictly exceeds the best *kept*
    gain so far (by more than eps) — the old loop, vectorized.  Returns the
    surviving candidate positions in sorted order."""
    order = np.argsort(cand_t, kind="stable")
    t = cand_t[order]
    if len(t) > 1 and (t[1:] == t[:-1]).any():
        # busy-time ties: fall back to the full (t, -gain) key so the
        # tie-break matches the reference sort exactly
        order = np.lexsort((-cand_gain, cand_t))
    g = cand_gain[order]
    n = len(g)
    keep = np.empty(n, dtype=bool)
    keep[0] = True
    if n > 1:
        # prefix-max shortcut: threshold on the max of ALL prior gains.  The
        # reference advances its bar only on KEPT gains, which differs only
        # when a pruned gain sits within eps of a later one — verify
        # self-consistency and fall back to the sequential rule if violated.
        keep[1:] = g[1:] > np.maximum.accumulate(g)[:-1] + _EPS
        last_kept = np.maximum.accumulate(np.where(keep, g, -np.inf))
        if (g[1:] > last_kept[:-1] + _EPS)[~keep[1:]].any():
            best = -np.inf
            for i in range(n):
                keep[i] = g[i] > best + _EPS
                if keep[i]:
                    best = g[i]
    return order[keep]


class _NodePool:
    """Append-only SoA pool of (parent, frame, res) decisions; parent
    indices instead of object chains make reconstruction O(depth)."""

    def __init__(self):
        self._chunks: list[tuple[np.ndarray, int, np.ndarray]] = []
        self.n = 1  # node 0 = root

    def append(self, parent: np.ndarray, frame_idx: int, res: np.ndarray) -> np.ndarray:
        self._chunks.append((parent, frame_idx, res))
        first = self.n
        self.n += len(parent)
        return np.arange(first, self.n, dtype=np.int64)

    def chain(self, node: int) -> list[tuple[int, int]]:
        """Walk parent indices back to the root, collecting offload
        decisions (nodes with frame >= 0; carry nodes are skipped)."""
        parent = np.concatenate([np.asarray([-1], dtype=np.int64)]
                                + [c[0] for c in self._chunks])
        frame = np.concatenate([np.asarray([-1], dtype=np.int64)]
                               + [np.full(len(c[0]), c[1], dtype=np.int64) for c in self._chunks])
        res = np.concatenate([np.asarray([-1], dtype=np.int64)]
                             + [c[2] for c in self._chunks])
        out: list[tuple[int, int]] = []
        while node >= 0:
            if frame[node] >= 0:
                out.append((int(frame[node]), int(res[node])))
            node = int(parent[node])
        return out


def cbo_plan(frames: Sequence[Frame], env: Env, *, now: float = 0.0) -> Plan:
    """Paper Algorithm 1 (online): DP over the confidence-sorted backlog.

    Only offloads with a strictly positive accuracy gain are candidates;
    "keep local" carries a state over unchanged.  Returns theta = max
    confidence among planned offloads and r° selected by frame index
    (see ``plan_from_chain``).

    With ``env.actions`` set, columns are the full action grid (frames ∪
    feature cuts): a split column's upload starts no earlier than
    ``arrival + t_dev`` (device prefix) and pays a suffix-scaled rtt.
    """
    k = len(frames)
    if k == 0:
        return plan_from_chain([], frames, 0.0, len(env.acc_server))
    arr, conf, sizes = _soa(frames)
    order = np.argsort(-conf, kind="stable")
    act_sizes, rtt, t_dev, acc, m = _action_vectors(env)
    if act_sizes is None:
        tx = sizes / env.bandwidth  # (k, m) from per-frame sizes
    else:
        tx = np.broadcast_to(act_sizes / env.bandwidth, (k, len(act_sizes)))
    # static feasibility: even an idle uplink (start = arrival + t_dev)
    # cannot make a transmission with t_dev + tx > deadline - rtt land in
    # time, and dA <= 0 never helps — drop those (frame, action) pairs
    dA_all = acc[None, :] - conf[:, None]  # (k, A)
    static = (tx <= (env.deadline - rtt - t_dev)[None, :]) & (dA_all > 0)

    pool = _NodePool()
    f_t = np.asarray([now])
    f_gain = np.asarray([0.0])
    f_id = np.zeros(1, dtype=np.int64)
    for j in order:
        j = int(j)
        cols = np.flatnonzero(static[j])
        if len(cols) == 0:
            continue
        P = len(f_t)
        # Collapse: every state with t <= arrival starts transmitting at the
        # (effective) arrival, so their expansions tie in t; frontier gain
        # is strictly ascending in t, so only the last such state's
        # expansions can survive pruning — expand from it alone.  (Survivor
        # set, and hence the schedule, is provably identical to expanding
        # them all.  With device time, arrival <= arrival + t_dev for every
        # column, so collapsing on the raw arrival stays conservative.)
        lo = max(int(np.searchsorted(f_t, arr[j], side="right")) - 1, 0)
        dA = dA_all[j, cols]
        start = np.maximum(f_t[lo:, None], arr[j] + t_dev[cols][None, :])
        t_new = start + tx[j, cols][None, :]  # (P - lo, C)
        good = t_new + rtt[cols][None, :] <= arr[j] + env.deadline
        if good.all():  # fast path: every (state, resolution) pair lands
            new_t = t_new.ravel()
            new_gain = (f_gain[lo:, None] + dA[None, :]).ravel()
            pi = lo + np.repeat(np.arange(P - lo), len(cols))
            ri = np.tile(cols, P - lo)
        else:
            if not good.any():
                continue  # pure carry-over: the frontier is already pruned
            pi, ci = np.nonzero(good)  # row-major: frontier outer, res inner
            new_t = t_new[pi, ci]
            new_gain = f_gain[lo + pi] + dA[ci]
            ri = cols[ci]
            pi = lo + pi
        # candidates: every carried-over state first, then the expansions —
        # the old list order, which pruning tie-breaks depend on
        cand_t = np.concatenate([f_t, new_t])
        cand_gain = np.concatenate([f_gain, new_gain])
        pos = _prune_positions(cand_t, cand_gain)
        new = pos >= P  # surviving expansions get pool nodes; pruned ones never do
        sel = pos[new] - P
        new_ids = pool.append(f_id[pi[sel]], j, ri[sel])
        nxt_id = np.empty(len(pos), dtype=np.int64)
        nxt_id[~new] = f_id[pos[~new]]
        nxt_id[new] = new_ids
        f_id = nxt_id
        f_t, f_gain = cand_t[pos], cand_gain[pos]
    best = int(np.argmax(f_gain))
    return plan_from_chain(pool.chain(int(f_id[best])), frames, float(f_gain[best]), m)


def _merge_prune(f_t, f_gain, f_seg, f_offs, fkey, e_t, e_gain, e_seg, K):
    """Prune candidates = [sorted frontier] + [expansions] WITHOUT re-sorting
    the frontier: the expansions (few) are sorted among themselves and their
    merge positions into the carries (many, already (t, gain)-ascending per
    segment) come from one searchsorted over the segment-offset keys
    ``fkey = f_t + f_seg*K``.  The keep-if-gain-beats-running-max rule then
    needs only O(F + E) vector ops: a carry's prior-max is its predecessor's
    gain (frontier gains ascend) vs the prefix max of expansions inserted
    before it, and vice versa.  Exact busy-time ties place the expansion
    before the carry iff its gain is higher (candidate order is
    gain-descending on ties); eps-near gains — where this all-prior
    shortcut may disagree with the reference's kept-only bar — are rerun
    per affected segment with the sequential rule (few and small).

    Returns (e_order, keep_carry, keep_exp_sorted, merge_positions,
    exp_count_cumsum).
    """
    F, E = len(f_t), len(e_t)
    eo = np.lexsort((-e_gain, e_t, e_seg))
    et, eg, es = e_t[eo], e_gain[eo], e_seg[eo]
    ekey = et + es * K
    insL = np.searchsorted(fkey, ekey, side="left")
    ins = np.searchsorted(fkey, ekey, side="right")
    tie = insL != ins
    if tie.any():
        # key-equal carr(ies): usually one carry with an exactly equal busy
        # time (frontier t strictly ascends per segment) — candidate order
        # is gain-descending on ties, so the expansion goes before the
        # carry iff its gain is higher.  Key rounding can only merge
        # sub-ulp-distinct busy-times; verify exact equality and resolve
        # the (pathological) collapsed windows by scalar comparison.
        cL = np.minimum(insL, F - 1)
        simple = tie & (ins - insL == 1) & (et == f_t[cL])
        before = simple & (eg > f_gain[cL])
        ins = np.where(before, insL, ins)
        odd = tie & ~simple
        for k in np.flatnonzero(odd):
            n_before = 0
            for j in range(int(insL[k]), int(ins[k])):
                if f_t[j] < et[k] or (f_t[j] == et[k] and f_gain[j] >= eg[k]):
                    n_before += 1
            ins[k] = insL[k] + n_before
    # prefix max of expansion gains per segment (sorted order); dense
    # (S, Le) pad when segments are balanced, flat log-pass scan when one
    # segment dominates (the pad would mostly be padding)
    e_counts = np.bincount(es, minlength=len(f_offs) - 1)
    e_starts = np.concatenate([[0], np.cumsum(e_counts)[:-1]])
    Le = int(e_counts.max())
    if len(e_counts) * Le <= 4 * E:
        ecols = np.arange(Le)
        evalid = ecols[None, :] < e_counts[:, None]
        eidx = np.minimum(e_starts[:, None] + ecols[None, :], E - 1)
        edense = np.where(evalid, eg[eidx], -np.inf)
        erun = np.maximum.accumulate(edense, axis=1)
        pm = erun[evalid]
    else:
        from repro.policy.fleet import segment_cummax

        pm = segment_cummax(eg, e_starts[es])
    pm_prev = np.empty(E)
    pm_prev[0] = -np.inf
    pm_prev[1:] = pm[:-1]
    pm_prev[e_starts[e_counts > 0]] = -np.inf
    # expansion keep: beat the last carry before it and all prior expansions
    cstar = ins - 1
    c_ok = cstar >= f_offs[es]
    prev_all_e = np.maximum(np.where(c_ok, f_gain[np.maximum(cstar, 0)], -np.inf), pm_prev)
    keep_e = eg > prev_all_e + _EPS
    # carry keep: beat its predecessor carry and expansions inserted before.
    # cum[j] = #expansions merged at or before carry j (bincount + cumsum —
    # no O(F log E) search)
    cum = np.cumsum(np.bincount(ins, minlength=F + 1))
    nb = cum[:F] - 1  # index of the last expansion before carry j
    e_ok = (nb >= 0) & (es[np.maximum(nb, 0)] == f_seg)
    # a carry's predecessor carry can never veto it (frontier gains ascend
    # by more than eps within a segment), so only the prefix max of the
    # expansions inserted before it matters
    prev_all_c = np.where(e_ok, pm[np.maximum(nb, 0)], -np.inf)
    keep_c = f_gain > prev_all_c + _EPS
    # eps-near gains: a dropped candidate strictly above the prior max (but
    # within eps) means the all-prior shortcut may disagree with the
    # reference's kept-only bar — rerun just those segments sequentially.
    # Cheap screen first: counts of (g > prev) vs (g > prev + eps) differ
    # only when a near gain exists.
    over_e = eg > prev_all_e
    over_c = f_gain > prev_all_c
    if int(over_e.sum()) == int(keep_e.sum()) and int(over_c.sum()) == int(keep_c.sum()):
        near = ()
    else:
        near = np.union1d(es[over_e & ~keep_e], f_seg[over_c & ~keep_c])
    for s in near:
        # verify against the kept-only bar, vectorized; drop to the true
        # sequential rule only on an actual disagreement (rarer still than
        # the conservative screen above)
        ci = np.arange(f_offs[s], f_offs[s + 1])
        ei = np.flatnonzero(es == s)
        pc = ci + cum[ci]
        pe = ins[ei] + ei
        order = np.argsort(np.concatenate([pc, pe]), kind="stable")
        gg = np.concatenate([f_gain[ci], eg[ei]])[order]
        kk = np.concatenate([keep_c[ci], keep_e[ei]])[order]
        last_kept = np.maximum.accumulate(np.where(kk, gg, -np.inf))
        prev_kept = np.empty(len(gg))
        prev_kept[0] = -np.inf
        prev_kept[1:] = last_kept[:-1]
        if ((~kk) & (gg > prev_kept + _EPS)).any():
            best = -np.inf
            for i in range(len(gg)):
                kk[i] = gg[i] > best + _EPS
                if kk[i]:
                    best = gg[i]
        back = np.empty(len(gg), dtype=bool)
        back[order] = kk
        keep_c[ci] = back[: len(ci)]
        keep_e[ei] = back[len(ci):]
    return eo, keep_c, keep_e, ins, cum


class _BatchNodePool:
    """Shared append-only decision pool for S concurrent DPs: (parent,
    backlog position, resolution) per node; node 0 is every stream's root."""

    def __init__(self):
        self._parents: list[np.ndarray] = [np.asarray([-1], dtype=np.int64)]
        self._pos: list[np.ndarray] = [np.asarray([-1], dtype=np.int64)]
        self._res: list[np.ndarray] = [np.asarray([-1], dtype=np.int64)]
        self.n = 1

    def append(self, parent: np.ndarray, pos: np.ndarray, res: np.ndarray) -> np.ndarray:
        self._parents.append(parent.astype(np.int64))
        self._pos.append(pos.astype(np.int64))
        self._res.append(res.astype(np.int64))
        first = self.n
        self.n += len(parent)
        return np.arange(first, self.n, dtype=np.int64)

    def chains(self, nodes: np.ndarray):
        """Walk all S chains to the root in parallel; returns flat
        (stream, pos, res) arrays of every offload decision."""
        parent = np.concatenate(self._parents)
        pos = np.concatenate(self._pos)
        res = np.concatenate(self._res)
        node = np.asarray(nodes, dtype=np.int64).copy()
        streams = np.arange(len(node), dtype=np.int64)
        out_s, out_p, out_r = [], [], []
        while True:
            live = node > 0
            if not live.any():
                break
            out_s.append(streams[live])
            out_p.append(pos[node[live]])
            out_r.append(res[node[live]])
            node[live] = parent[node[live]]
        if not out_s:
            z = np.zeros(0, dtype=np.int64)
            return z, z.copy(), z.copy()
        return (np.concatenate(out_s), np.concatenate(out_p), np.concatenate(out_r))


def cbo_plan_many(state, env, now: np.ndarray):
    """Algorithm 1 over S independent backlogs in one set of segment ops.

    Each stream runs exactly the ``cbo_plan`` recursion — same candidate
    ordering, same float accumulation, same tie-breaks — but all S
    frontiers live in one flat struct-of-arrays keyed by stream id, so a
    planning round is O(max backlog depth) numpy passes instead of O(S)
    Python DPs.  ``tests/test_fleet.py`` fuzzes bit-equality of the
    returned offload schedules against the per-stream planner.
    """
    from repro.policy.fleet import ragged_rank
    from repro.policy.types import PlanBatch

    S = state.n_streams
    arr, conf, sid, offs = state.arrival, state.conf, state.stream_id, state.offsets
    lens = np.diff(offs)
    now = np.asarray(now, dtype=np.float64)
    act_sizes, rtt, t_dev, acc, m = _action_vectors(env)
    sizes_a = env.sizes if act_sizes is None else act_sizes  # (A,)
    base_acc = np.bincount(sid, weights=conf, minlength=S) if len(arr) else np.zeros(S)
    out_empty = PlanBatch.empty(S, m)
    out_empty.n_frames = lens.copy()
    out_empty.base_acc = base_acc
    out_empty.planned = np.ones(S, dtype=bool)
    if len(arr) == 0:
        return out_empty

    tx_sm = sizes_a[None, :] / env.bandwidth[:, None]  # (S, A)
    dA = acc[None, :] - conf[:, None]  # (T, A)
    static = (tx_sm[sid] <= (env.deadline - rtt - t_dev)[None, :]) & (dA > 0)

    # per-stream confidence-descending stable order (== argsort(-conf))
    sort_idx = np.lexsort((-conf, sid))

    pool = _BatchNodePool()
    f_t = now.copy()
    f_gain = np.zeros(S)
    f_node = np.zeros(S, dtype=np.int64)
    f_seg = np.arange(S, dtype=np.int64)
    # one segment-offset key scale for the whole DP: every busy time and
    # deadline bound lives in [t_lo, t_hi], so K separates segments in all
    # the searchsorted-based merges below
    t_hi = float(max(now.max(), arr.max() + env.deadline))
    t_lo = float(min(now.min(), arr.min()))
    K = t_hi - t_lo + 1.0
    # per-depth frame grids, gathered once up front: row d holds each
    # stream's depth-d frame (conf-sorted order), padded where the backlog
    # is shorter
    D = int(lens.max())
    depth_rng = np.arange(D)
    fi_mat = sort_idx[np.minimum(offs[:-1][None, :] + depth_rng[:, None],
                                 np.maximum(offs[1:] - 1, 0)[None, :])]  # (D, S)
    static_mat = static[fi_mat] & (depth_rng[:, None] < lens[None, :])[:, :, None]
    any_mat = static_mat.any(axis=(1, 2))  # (D,)
    arr_mat = arr[fi_mat]  # (D, S) — garbage where padded, never used there
    pos_mat = fi_mat - offs[:-1][None, :]
    dA_mat = dA[fi_mat]  # (D, S, m)
    for d in range(D):
        if not any_mat[d]:
            continue
        frame_static = static_mat[d]  # (S, m)
        arr_d = arr_mat[d]
        pos_d = pos_mat[d]
        f_counts = np.bincount(f_seg, minlength=S)
        f_offs = np.empty(S + 1, dtype=np.int64)
        f_offs[0] = 0
        np.cumsum(f_counts, out=f_offs[1:])
        # collapse (see cbo_plan): only states from the last one with
        # t <= arrival onward can produce surviving expansions
        below = np.bincount(f_seg, weights=f_t <= arr_d[f_seg], minlength=S)
        lo = np.maximum(below.astype(np.int64) - 1, 0)
        # deadline-feasible states form a PREFIX of each (stream, col)'s
        # t-ascending frontier segment: start <= arr + deadline - rtt - tx.
        # One searchsorted over segment-offset keys finds every cutoff, so
        # the (mostly infeasible) full expansion grid is never
        # materialized; offset rounding can only over-include, and the
        # exact ``good`` check below re-filters the stragglers.
        cs, cc = np.nonzero(frame_static)  # (stream, col) pairs, s-major
        hi = arr_d[cs] + (env.deadline - rtt[cc]) - tx_sm[cs, cc]
        fkey = f_t + f_seg * K
        cut = np.searchsorted(fkey, hi + cs * K, side="right")
        first = f_offs[cs] + lo[cs]
        n_sc = np.maximum(cut - first, 0)
        blk = np.repeat(np.arange(len(cs)), n_sc)
        state_rep = first[blk] + ragged_rank(n_sc)
        seg_rep, col_rep = cs[blk], cc[blk]
        # candidate order is state-major with columns ascending — restore it
        # (the construction above is column-major); ties downstream depend
        # on the original candidate order
        o = np.lexsort((col_rep, state_rep))
        state_rep, seg_rep, col_rep = state_rep[o], seg_rep[o], col_rep[o]
        start = np.maximum(f_t[state_rep], arr_d[seg_rep] + t_dev[col_rep])
        t_new = start + tx_sm[seg_rep, col_rep]
        good = t_new + rtt[col_rep] <= arr_d[seg_rep] + env.deadline
        e_t = t_new[good]
        e_parent = state_rep[good]
        e_seg = seg_rep[good]
        e_col = col_rep[good]
        e_gain = f_gain[e_parent] + dA_mat[d][e_seg, e_col]
        if not len(e_t):
            continue  # pure carry-over everywhere: frontier already pruned
        # pre-filter: an expansion whose gain does not strictly beat the
        # best carry with strictly smaller busy-time is certain to be
        # pruned (the kept bar is within eps of the carry prefix max), so
        # drop it before the merge's per-expansion machinery.  Offset
        # rounding can only weaken the filter (monotone), never mis-drop.
        cpos = np.searchsorted(fkey, e_t + e_seg * K, side="right") - 1
        cpos_c = np.maximum(cpos, 0)
        # exact t compare guards against sub-ulp key collapses: only a
        # carry at or before the expansion's busy time may veto it (an
        # equal-t carry precedes the expansion iff its gain is >= — which
        # is exactly when the veto condition holds)
        covered = (cpos >= f_offs[e_seg]) & (f_t[cpos_c] <= e_t)
        weak = covered & (e_gain <= f_gain[cpos_c])
        if weak.any():
            strong = ~weak
            e_t, e_gain, e_parent = e_t[strong], e_gain[strong], e_parent[strong]
            e_seg, e_col = e_seg[strong], e_col[strong]
            if not len(e_t):
                continue
        # merge the (few) expansions into the already-sorted frontier
        # without re-sorting it
        eo, keep_c, keep_e, ins, cum = _merge_prune(
            f_t, f_gain, f_seg, f_offs, fkey, e_t, e_gain, e_seg, K)
        all_c = bool(keep_c.all())
        kc = np.arange(len(f_t)) if all_c else np.flatnonzero(keep_c)
        ke = np.flatnonzero(keep_e)
        orig_e = eo[ke]
        new_ids = pool.append(f_node[e_parent[orig_e]], pos_d[e_seg[orig_e]],
                              e_col[orig_e])
        # interleave kept carries/expansions by merged position (positions
        # on both sides are already sorted)
        pos_c = kc + cum[kc] if not all_c else kc + cum[:len(kc)]
        pos_e = ins[ke] + ke
        rc = np.arange(len(kc)) + np.searchsorted(pos_e, pos_c)
        re = np.arange(len(ke)) + np.searchsorted(pos_c, pos_e)
        n_new = len(kc) + len(ke)
        nt, ng = np.empty(n_new), np.empty(n_new)
        ns, nn = np.empty(n_new, dtype=np.int64), np.empty(n_new, dtype=np.int64)
        if all_c:
            nt[rc], ng[rc], ns[rc], nn[rc] = f_t, f_gain, f_seg, f_node
        else:
            nt[rc], ng[rc], ns[rc], nn[rc] = f_t[kc], f_gain[kc], f_seg[kc], f_node[kc]
        nt[re], ng[re], ns[re] = e_t[orig_e], e_gain[orig_e], e_seg[orig_e]
        nn[re] = new_ids
        f_t, f_gain, f_seg, f_node = nt, ng, ns, nn

    # best state per stream: max gain, first occurrence (np.argmax order)
    f_counts = np.bincount(f_seg, minlength=S)
    f_offs = np.r_[0, np.cumsum(f_counts)]
    best_gain = np.maximum.reduceat(f_gain, f_offs[:-1])
    hit = f_gain == best_gain[f_seg]
    first_hit = np.minimum.reduceat(np.where(hit, np.arange(len(f_gain)), len(f_gain)),
                                    f_offs[:-1])
    off_s, off_p, off_r = pool.chains(f_node[first_hit])
    return PlanBatch.from_offloads(
        S, m, off_stream=off_s, off_pos=off_p, off_res=off_r,
        off_conf=conf[offs[:-1][off_s] + off_p], total_gain=best_gain,
        base_acc=base_acc, n_frames=lens).annotate_actions(env.actions)


def optimal_schedule(frames: Sequence[Frame], env: Env) -> Plan:
    """The paper's offline optimal (§IV-C): DP over frames in arrival order,
    m+1 options per level (local + every feasible resolution, gain sign
    unconstrained), dominance-pruned (T, C) path attributes.

    Accumulates total *accuracy* (local frames contribute their confidence)
    exactly as the reference did, so pruning near the epsilon boundary makes
    identical decisions; the returned gain is accuracy minus the all-local
    base.
    """
    k = len(frames)
    m = len(env.acc_server)
    if k == 0:
        return plan_from_chain([], frames, 0.0, m)
    arr, conf, sizes = _soa(frames)
    order = np.argsort(arr, kind="stable")
    tx = sizes / env.bandwidth
    rtt = env.server_time + env.latency
    acc = np.asarray(env.acc_server, dtype=np.float64)
    static = tx <= env.deadline - rtt  # (k, m): feasible from an idle uplink

    pool = _NodePool()
    f_t = np.asarray([0.0])
    f_gain = np.asarray([0.0])
    f_id = np.zeros(1, dtype=np.int64)
    for j in order:
        j = int(j)
        P = len(f_t)
        cols = np.flatnonzero(static[j])
        C = len(cols)
        carry_g = f_gain + conf[j]  # "NPU option": accuracy + conf_j
        if C == 0:
            cand_t, cand_gain = f_t, carry_g
            pos = _prune_positions(cand_t, cand_gain)
            src_state, is_off, off_res = pos, np.zeros(len(pos), dtype=bool), None
        else:
            # collapse (see cbo_plan): states with t <= arrival tie in
            # expansion t; only the last (max-gain) one's expansions can
            # survive, so expand from states lo.. only.  Carries never tie.
            lo = max(int(np.searchsorted(f_t, arr[j], side="right")) - 1, 0)
            start = np.maximum(f_t[lo:], arr[j])
            t_new = start[:, None] + tx[j, cols][None, :]
            good = t_new + rtt <= arr[j] + env.deadline
            # old candidate order interleaves per state: carry, then its
            # feasible offload expansions, state by state; states below the
            # collapse point contribute their carry only
            grid_t = np.empty((P - lo, C + 1))
            grid_g = np.full((P - lo, C + 1), -np.inf)
            grid_t[:, 0] = f_t[lo:]
            grid_g[:, 0] = carry_g[lo:]
            np.copyto(grid_t[:, 1:], t_new, where=good)
            np.copyto(grid_g[:, 1:], (f_gain[lo:, None] + acc[cols][None, :]), where=good)
            flat = np.flatnonzero(grid_g.reshape(-1) > -np.inf)
            cand_t = np.concatenate([f_t[:lo], grid_t.reshape(-1)[flat]])
            cand_gain = np.concatenate([carry_g[:lo], grid_g.reshape(-1)[flat]])
            pos = _prune_positions(cand_t, cand_gain)
            in_grid = pos >= lo
            src = flat[pos[in_grid] - lo]  # position in the (P - lo, C+1) grid
            src_state = np.empty(len(pos), dtype=np.int64)
            src_state[~in_grid] = pos[~in_grid]  # prefix carries
            src_state[in_grid] = lo + src // (C + 1)
            src_col = src % (C + 1) - 1  # -1 = carry
            is_off = np.zeros(len(pos), dtype=bool)
            is_off[in_grid] = src_col >= 0
            off_res = cols[src_col[src_col >= 0]]
        nxt_id = np.empty(len(pos), dtype=np.int64)
        if is_off.any():
            nxt_id[is_off] = pool.append(f_id[src_state[is_off]], j, off_res)
        # carries record no decision — chain() would skip them — so they
        # keep their parent's node id instead of minting dead pool nodes
        nxt_id[~is_off] = f_id[src_state[~is_off]]
        f_id = nxt_id
        f_t, f_gain = cand_t[pos], cand_gain[pos]
    best = int(np.argmax(f_gain))
    base = sum(f.conf for f in frames)
    return plan_from_chain(pool.chain(int(f_id[best])), frames,
                           float(f_gain[best]) - base, m)
