"""Shared value types of the offload decision plane.

``Frame`` / ``Env`` / ``Plan`` are the vocabulary every ``OffloadPolicy``
speaks: a policy observes ``Frame``s, is asked to ``plan`` against an
``Env`` (the network/deadline regime at that instant), and answers with a
``Plan``.  They used to live in ``core/cbo.py``; they are re-exported from
there for backward compatibility.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Frame:
    arrival: float  # seconds
    conf: float  # calibrated confidence = expected fast-tier accuracy
    sizes: tuple[float, ...]  # payload bytes per resolution (ascending res)
    fid: int = -1  # caller-side frame id (e.g. global trace index); -1 = unset


@dataclass(frozen=True)
class Env:
    bandwidth: float  # uplink bytes/s
    latency: float  # one-way-ish network latency L (s)
    server_time: float  # T^o (s)
    deadline: float  # T (s), per-frame window
    acc_server: tuple[float, ...]  # A^o_r per resolution (ascending res)


@dataclass
class Plan:
    """Result of a planning pass over a policy's backlog."""

    theta: float  # confidence threshold for offloading
    resolution: int  # r° — resolution index for the next offload
    offloads: list[tuple[int, int]]  # (backlog/frame index, resolution index)
    total_gain: float  # sum of (A^o_r - p_i) over planned offloads
    base_acc: float  # sum of p_i (all local)
    n_frames: int = 0

    @property
    def mean_acc(self) -> float:
        return (self.base_acc + self.total_gain) / max(self.n_frames, 1)


def plan_from_chain(chain: list[tuple[int, int]], frames, gain: float, m: int) -> Plan:
    """Assemble a ``Plan`` from a planner's offload chain.

    theta is the max confidence among planned offloads and r° the resolution
    of the frame attaining it — selected by frame *index* (highest
    confidence, ties broken toward the earliest frame), never by float
    equality on the confidence itself.
    """
    base = sum(f.conf for f in frames)
    k = len(frames)
    if not chain:
        return Plan(theta=0.0, resolution=m - 1, offloads=[], total_gain=0.0,
                    base_acc=base, n_frames=k)
    i_star, r_star = max(chain, key=lambda ij: (frames[ij[0]].conf, -ij[0]))
    return Plan(theta=frames[i_star].conf, resolution=r_star, offloads=sorted(chain),
                total_gain=gain, base_acc=base, n_frames=k)
