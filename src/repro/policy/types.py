"""Shared value types of the offload decision plane.

``Frame`` / ``Env`` / ``Plan`` are the vocabulary every ``OffloadPolicy``
speaks: a policy observes ``Frame``s, is asked to ``plan`` against an
``Env`` (the network/deadline regime at that instant), and answers with a
``Plan``.  They used to live in ``core/cbo.py``; they are re-exported from
there for backward compatibility.

``EnvBatch`` / ``PlanBatch`` are their struct-of-arrays fleet
counterparts: one env snapshot and one plan for S streams at once, the
vocabulary of the batched ``plan_many`` path (see ``policy/fleet.py``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np


@dataclass(frozen=True)
class Frame:
    arrival: float  # seconds
    conf: float  # calibrated confidence = expected fast-tier accuracy
    sizes: tuple[float, ...]  # payload bytes per resolution (ascending res)
    fid: int = -1  # caller-side frame id (e.g. global trace index); -1 = unset


@dataclass(frozen=True)
class ActionTable:
    """The planner's action grid: {frame@res r} ∪ {features@cut k}.

    Frame actions occupy indices ``[0, m)`` with **action index ==
    resolution index** — so every legacy consumer that treats a plan's
    ``resolution`` as an index into ``cfg.resolutions`` keeps working, and
    a table with no split actions is byte-for-byte the old ``(m,)`` payload
    vector.  Split actions (``kind == 1``) follow: the device runs the
    first k blocks (``t_dev`` seconds, from ``split/costs.py``), ships
    int8 features (``sizes`` bytes), and the server runs the suffix
    (``srv_frac`` × its current full-model time estimate).  ``res`` is the
    resolution index the action's *prediction* is evaluated at (full
    resolution for splits); ``cut`` is the catalog cut id (-1 for frames).

    Invariants (checked): frame actions first with ``res == arange(m)``,
    ``t_dev == 0`` and ``srv_frac == 1`` for frames — those identities are
    what make a degenerate table reproduce the frame-only system
    bit-for-bit (``x + 0.0`` and ``t * 1.0`` are float no-ops).
    """

    kind: np.ndarray  # (A,) int8 — 0 = frame upload, 1 = feature (split)
    res: np.ndarray  # (A,) int — evaluation resolution index
    cut: np.ndarray  # (A,) int — catalog cut id; -1 for frame actions
    sizes: np.ndarray  # (A,) float64 — payload bytes on the wire
    acc: np.ndarray  # (A,) float64 — server-side accuracy if offloaded
    t_dev: np.ndarray  # (A,) float64 — device prefix seconds (0 for frames)
    srv_frac: np.ndarray  # (A,) float64 — fraction of server_time (1 for frames)
    names: tuple = ()  # optional per-split-action labels

    def __post_init__(self):
        m = self.n_frame_actions
        assert m >= 1 and np.array_equal(self.kind[:m], np.zeros(m, dtype=np.int8))
        assert np.array_equal(self.res[:m], np.arange(m))
        assert not np.any(self.t_dev[:m]) and np.all(self.srv_frac[:m] == 1.0)
        assert np.all(self.cut[:m] == -1)

    @property
    def n_actions(self) -> int:
        return len(self.sizes)

    @property
    def n_frame_actions(self) -> int:
        return int(np.sum(self.kind == 0))

    @property
    def has_splits(self) -> bool:
        return self.n_actions > self.n_frame_actions

    @classmethod
    def frames_only(cls, *, sizes, acc) -> "ActionTable":
        """The degenerate table: the legacy (m,) resolution grid."""
        m = len(sizes)
        return cls(kind=np.zeros(m, dtype=np.int8), res=np.arange(m),
                   cut=np.full(m, -1, dtype=np.int64),
                   sizes=np.asarray(sizes, dtype=np.float64),
                   acc=np.asarray(acc, dtype=np.float64),
                   t_dev=np.zeros(m), srv_frac=np.ones(m))

    def rtt(self, server_time: float, latency: float) -> np.ndarray:
        """(A,) per-action server+latency time: split suffixes scale the
        current server-time estimate, frames pay it in full."""
        return server_time * self.srv_frac + latency


@dataclass(frozen=True)
class Env:
    bandwidth: float  # uplink bytes/s
    latency: float  # one-way-ish network latency L (s)
    server_time: float  # T^o (s)
    deadline: float  # T (s), per-frame window
    acc_server: tuple[float, ...]  # A^o_r per resolution (ascending res)
    actions: Optional[ActionTable] = None  # split-aware grid; None = frame-only


@dataclass
class Plan:
    """Result of a planning pass over a policy's backlog."""

    theta: float  # confidence threshold for offloading
    resolution: int  # r° — resolution index for the next offload
    offloads: list[tuple[int, int]]  # (backlog/frame index, resolution index)
    total_gain: float  # sum of (A^o_r - p_i) over planned offloads
    base_acc: float  # sum of p_i (all local)
    n_frames: int = 0

    @property
    def mean_acc(self) -> float:
        return (self.base_acc + self.total_gain) / max(self.n_frames, 1)


@dataclass(frozen=True)
class EnvBatch:
    """One ``Env`` snapshot for S streams: per-stream bandwidth estimates,
    shared link/deadline scalars, and the (m,) payload-size vector that
    every stream's frames share (``Frame.sizes`` is per-config, not
    per-frame).

    Under an edge fabric the (S,) bandwidth vector is per-*cell* in
    spirit: each stream's EWMA tracks its own cell's uplink (that is where
    its transfers serialize), so ``plan_many`` automatically plans against
    the stream's cell.  ``cell_id`` carries the partition for policies
    that want topology awareness; ``None`` means the single-uplink world.

    With a continuous-batching slow tier, ``server_time`` is already the
    *calibrated* amortized estimate f(expected_batch)/expected_batch;
    ``occupancy`` (the batch-occupancy EWMA behind it) and ``queue_depth``
    (mean seconds of pending replica work) are the raw observables for
    policies that want to reason about congestion directly.
    """

    bandwidth: np.ndarray  # (S,) uplink bytes/s, floored at 1.0
    latency: float
    server_time: float
    deadline: float
    acc_server: tuple[float, ...]
    sizes: np.ndarray  # (m,) payload bytes per resolution
    cell_id: Optional[np.ndarray] = None  # (S,) int cell per stream; None = one cell
    occupancy: float = 1.0  # slow-tier batch-occupancy EWMA (1.0 = serial)
    queue_depth: float = 0.0  # mean pending replica work (s) at plan time
    actions: Optional[ActionTable] = None  # split-aware grid; None = frame-only

    @property
    def n_streams(self) -> int:
        return len(self.bandwidth)

    @property
    def sizes_tuple(self) -> tuple[float, ...]:
        return tuple(float(x) for x in self.sizes)

    def for_stream(self, s: int) -> Env:
        return Env(bandwidth=float(self.bandwidth[s]), latency=self.latency,
                   server_time=self.server_time, deadline=self.deadline,
                   acc_server=self.acc_server, actions=self.actions)

    def subset(self, streams: np.ndarray) -> "EnvBatch":
        return EnvBatch(bandwidth=self.bandwidth[streams], latency=self.latency,
                        server_time=self.server_time, deadline=self.deadline,
                        acc_server=self.acc_server, sizes=self.sizes,
                        cell_id=None if self.cell_id is None else self.cell_id[streams],
                        occupancy=self.occupancy, queue_depth=self.queue_depth,
                        actions=self.actions)


@dataclass
class PlanBatch:
    """S ``Plan``s as struct-of-arrays: per-stream scalars plus one flat
    (stream, backlog position, resolution) offload list sorted by
    (stream, pos).  ``plan(s)`` materializes the per-stream ``Plan`` —
    identical to what the looped path returns (gains/base accuracies may
    differ from the looped floats only by summation order)."""

    theta: np.ndarray  # (S,)
    resolution: np.ndarray  # (S,) int — a° per stream (m-1 when no offloads)
    n_offloads: np.ndarray  # (S,) int
    total_gain: np.ndarray  # (S,)
    base_acc: np.ndarray  # (S,)
    n_frames: np.ndarray  # (S,) int — backlog length at plan time
    off_stream: np.ndarray  # (E,) int
    off_pos: np.ndarray  # (E,) int — position within the stream's backlog
    off_res: np.ndarray  # (E,) int — ACTION index (== resolution index for frames)
    planned: np.ndarray = None  # (S,) bool — streams this batch planned for
    off_kind: np.ndarray = None  # (E,) int8 — 0 frame, 1 features (from ActionTable)
    off_cut: np.ndarray = None  # (E,) int — catalog cut id; -1 for frame actions

    def __post_init__(self):
        if self.off_kind is None:
            self.off_kind = np.zeros(len(self.off_res), dtype=np.int8)
        if self.off_cut is None:
            self.off_cut = np.full(len(self.off_res), -1, dtype=np.int64)

    def annotate_actions(self, actions: Optional[ActionTable]) -> "PlanBatch":
        """Fill the (kind, cut) columns from the action table ``off_res``
        indexes into.  A ``None``/degenerate table is all frames."""
        if actions is not None and len(self.off_res):
            self.off_kind = actions.kind[self.off_res]
            self.off_cut = actions.cut[self.off_res]
        return self

    def __len__(self) -> int:
        return len(self.theta)

    @classmethod
    def empty(cls, n_streams: int, m: int) -> "PlanBatch":
        z = np.zeros(n_streams)
        zi = np.zeros(n_streams, dtype=np.int64)
        return cls(theta=z.copy(), resolution=np.full(n_streams, m - 1, dtype=np.int64),
                   n_offloads=zi.copy(), total_gain=z.copy(), base_acc=z.copy(),
                   n_frames=zi.copy(), off_stream=np.zeros(0, dtype=np.int64),
                   off_pos=np.zeros(0, dtype=np.int64), off_res=np.zeros(0, dtype=np.int64),
                   planned=np.zeros(n_streams, dtype=bool))

    @classmethod
    def from_plans(cls, plans: list[Plan], m: int) -> "PlanBatch":
        """Pack per-stream ``Plan``s (the looped fallback) into one batch."""
        out = cls.empty(len(plans), m)
        offs = []
        for s, p in enumerate(plans):
            out.theta[s] = p.theta
            out.resolution[s] = p.resolution
            out.n_offloads[s] = len(p.offloads)
            out.total_gain[s] = p.total_gain
            out.base_acc[s] = p.base_acc
            out.n_frames[s] = p.n_frames
            out.planned[s] = True
            offs.extend((s, i, r) for i, r in p.offloads)
        if offs:
            a = np.asarray(offs, dtype=np.int64)
            out.off_stream, out.off_pos, out.off_res = a[:, 0], a[:, 1], a[:, 2]
            out.off_kind = np.zeros(len(out.off_res), dtype=np.int8)
            out.off_cut = np.full(len(out.off_res), -1, dtype=np.int64)
        return out

    @classmethod
    def from_offloads(cls, n_streams: int, m: int, *, off_stream, off_pos, off_res,
                      off_conf, total_gain, base_acc, n_frames) -> "PlanBatch":
        """Assemble from a flat offload list — the batched counterpart of
        ``plan_from_chain``: theta is the max confidence among each stream's
        offloads, r° that frame's resolution, ties broken toward the
        earliest backlog position."""
        out = cls.empty(n_streams, m)
        out.total_gain = np.asarray(total_gain, dtype=np.float64)
        out.base_acc = np.asarray(base_acc, dtype=np.float64)
        out.n_frames = np.asarray(n_frames, dtype=np.int64)
        out.planned = np.ones(n_streams, dtype=bool)
        off_stream = np.asarray(off_stream, dtype=np.int64)
        off_pos = np.asarray(off_pos, dtype=np.int64)
        off_res = np.asarray(off_res, dtype=np.int64)
        if len(off_stream) == 0:
            return out
        order = np.lexsort((off_pos, off_stream))
        out.off_stream = off_stream[order]
        out.off_pos = off_pos[order]
        out.off_res = off_res[order]
        out.off_kind = np.zeros(len(out.off_res), dtype=np.int8)
        out.off_cut = np.full(len(out.off_res), -1, dtype=np.int64)
        out.n_offloads = np.bincount(out.off_stream, minlength=n_streams)
        conf = np.asarray(off_conf, dtype=np.float64)[order]
        # theta/r° selection: per stream, highest conf, earliest pos on ties
        pick = np.lexsort((out.off_pos, -conf, out.off_stream))
        first = np.r_[True, out.off_stream[pick][1:] != out.off_stream[pick][:-1]]
        sel = pick[first]
        out.theta[out.off_stream[sel]] = conf[sel]
        out.resolution[out.off_stream[sel]] = out.off_res[sel]
        return out

    def scatter(self, streams: np.ndarray, sub: "PlanBatch") -> None:
        """Merge a group-local batch (stream ids local to ``streams``) in."""
        for name in ("theta", "resolution", "n_offloads", "total_gain",
                     "base_acc", "n_frames", "planned"):
            getattr(self, name)[streams] = getattr(sub, name)
        if len(sub.off_stream):
            self.off_stream = np.concatenate([self.off_stream, streams[sub.off_stream]])
            self.off_pos = np.concatenate([self.off_pos, sub.off_pos])
            self.off_res = np.concatenate([self.off_res, sub.off_res])
            self.off_kind = np.concatenate([self.off_kind, sub.off_kind])
            self.off_cut = np.concatenate([self.off_cut, sub.off_cut])

    def sort_offloads(self) -> None:
        order = np.lexsort((self.off_pos, self.off_stream))
        self.off_stream = self.off_stream[order]
        self.off_pos = self.off_pos[order]
        self.off_res = self.off_res[order]
        self.off_kind = self.off_kind[order]
        self.off_cut = self.off_cut[order]

    def plan(self, s: int) -> Plan:
        """Materialize stream ``s``'s per-stream ``Plan`` view."""
        sel = self.off_stream == s
        return Plan(theta=float(self.theta[s]), resolution=int(self.resolution[s]),
                    offloads=sorted(zip(self.off_pos[sel].tolist(), self.off_res[sel].tolist())),
                    total_gain=float(self.total_gain[s]), base_acc=float(self.base_acc[s]),
                    n_frames=int(self.n_frames[s]))


def plan_from_chain(chain: list[tuple[int, int]], frames, gain: float, m: int) -> Plan:
    """Assemble a ``Plan`` from a planner's offload chain.

    theta is the max confidence among planned offloads and r° the resolution
    of the frame attaining it — selected by frame *index* (highest
    confidence, ties broken toward the earliest frame), never by float
    equality on the confidence itself.
    """
    base = sum(f.conf for f in frames)
    k = len(frames)
    if not chain:
        return Plan(theta=0.0, resolution=m - 1, offloads=[], total_gain=0.0,
                    base_acc=base, n_frames=k)
    i_star, r_star = max(chain, key=lambda ij: (frames[ij[0]].conf, -ij[0]))
    return Plan(theta=frames[i_star].conf, resolution=r_star, offloads=sorted(chain),
                total_gain=gain, base_acc=base, n_frames=k)
