"""JAX fleet control plane: fixed-shape padded/masked port of ``fleet.py``.

The numpy control plane (``policy/fleet.py`` + ``policy/frontier.py``) is
the semantic reference; this module re-expresses it in shapes ``jax.jit``
can compile:

  * ragged backlogs become a ``PaddedFleet`` — ``(S, L)`` arrival/conf
    grids plus an ``(S,)`` length vector; slot ``j`` of stream ``s`` is
    valid iff ``j < length[s]``, and valid slots are always packed at the
    front in insertion order (the same order a ``FleetState`` segment or a
    ``BacklogPolicy.backlog`` list would have, so backlog *positions* mean
    the same thing on every path);
  * the segment ops (``prune_expired`` / ``consume`` / ``extend`` /
    ``clear``) become per-stream mask-and-compact passes, vmapped over the
    fleet — compaction is one stable ``argsort(~keep)``, which moves kept
    slots to the front without reordering them;
  * the planners become per-stream fixed-shape functions, vmapped: the
    CBO frontier DP runs with a capped frontier of ``F`` states and
    reports an ``overflow`` flag when the cap would have truncated it
    (the differential tests assert the flag stays clean), plus an
    ``inexact`` flag for the one epsilon corner where the vectorized
    prune shortcut could disagree with the reference's sequential rule.

Exactness policy (see docs/jax_backend.md): the numpy path plans in
float64, this one in ``spec.dtype`` (float32 by default).  Integer
decisions — which frames offload, at which resolution, in which order —
are compared exactly; accumulated floats (gains, busy times, EWMA) at
tolerance.  Candidate ordering and tie-breaks are kept identical to
``frontier.py``: confidence-descending stable frame order, carries before
expansions (state-major, resolution-minor), pruning by a stable
``(t asc, gain desc, candidate idx asc)`` sort with the strictly-beats-
the-kept-bar rule.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Optional

import numpy as np

import jax
import jax.numpy as jnp

__all__ = [
    "PaddedFleet", "PlanOut", "PlannerSpec",
    "pad_fleet", "unpad_fleet", "fleet_from_state", "plan_batch_from_out",
    "prune_fleet", "consume_fleet", "extend_fleet", "clear_fleet",
    "plan_fleet", "make_planner", "spec_for_policy", "planner_kind",
    "jax_unsupported_policies", "ewma_fold", "JAX_PLANNABLE",
]

_EPS = 1e-12  # same dominance epsilon as policy/frontier.py

#: policy registry names the JAX planner supports (homogeneous fleets)
JAX_PLANNABLE = ("cbo", "threshold", "local", "server", "greedy-rate")


# --------------------------------------------------------------------------- #
# padded fleet state
# --------------------------------------------------------------------------- #


class PaddedFleet(NamedTuple):
    """Fixed-shape fleet backlog: valid slots packed at the front."""

    arrival: jnp.ndarray  # (S, L)
    conf: jnp.ndarray  # (S, L)
    length: jnp.ndarray  # (S,) int32 — slots < length are valid


def pad_fleet(arrival, conf, lengths, L: int, dtype=jnp.float32) -> PaddedFleet:
    """Host constructor from flat ragged arrays (``FleetState`` layout)."""
    lengths = np.asarray(lengths, dtype=np.int64)
    S = len(lengths)
    if lengths.max(initial=0) > L:
        raise ValueError(f"backlog length {int(lengths.max())} exceeds pad L={L}")
    arr = np.zeros((S, L), dtype=np.float64)
    cf = np.zeros((S, L), dtype=np.float64)
    offsets = np.r_[0, np.cumsum(lengths)]
    flat_a = np.asarray(arrival, dtype=np.float64)
    flat_c = np.asarray(conf, dtype=np.float64)
    if len(flat_a):
        sid = np.repeat(np.arange(S), lengths)
        pos = np.arange(len(flat_a)) - offsets[:-1][sid]
        arr[sid, pos] = flat_a
        cf[sid, pos] = flat_c
    return PaddedFleet(jnp.asarray(arr, dtype=dtype), jnp.asarray(cf, dtype=dtype),
                       jnp.asarray(lengths, dtype=jnp.int32))


def fleet_from_state(state, L: int, dtype=jnp.float32) -> PaddedFleet:
    """Pad a ``FleetState`` (numpy, ragged) into device arrays."""
    return pad_fleet(state.arrival, state.conf, state.lengths, L, dtype=dtype)


def unpad_fleet(fleet: PaddedFleet):
    """Back to host ragged arrays: (arrival, conf, lengths) numpy tuples."""
    arr = np.asarray(fleet.arrival)
    conf = np.asarray(fleet.conf)
    lens = np.asarray(fleet.length, dtype=np.int64)
    L = arr.shape[1]
    valid = np.arange(L)[None, :] < lens[:, None]
    return arr[valid], conf[valid], lens


# --------------------------------------------------------------------------- #
# segment ops (mask-and-compact, vmapped)
# --------------------------------------------------------------------------- #


def _compact(arr, conf, keep):
    """Move kept slots to the front, preserving order (stable argsort)."""
    o = jnp.argsort(~keep)  # False < True; stable, so kept order survives
    return arr[o], conf[o], keep.sum().astype(jnp.int32)


def _prune_single(arr, conf, length, now, deadline, do):
    valid = jnp.arange(arr.shape[0]) < length
    # same float compare as FleetState.prune_expired / BacklogPolicy.plan
    keep = valid & jnp.where(do, arr + deadline > now, True)
    return _compact(arr, conf, keep)


def _consume_single(arr, conf, length, take, clear):
    valid = jnp.arange(arr.shape[0]) < length
    keep = valid & ~take & ~clear
    return _compact(arr, conf, keep)


def _extend_single(arr, conf, length, new_arr, new_conf, new_ok, mb):
    """Append the round's new frames (slot order) then trim to the newest
    ``mb`` — list-``observe`` semantics with static shapes.  ``mb`` is a
    static int on homogeneous fleets or a per-stream scalar (vmapped) on
    heterogeneous ones, where groups trim to their own ``max_backlog``
    while sharing one pad width L."""
    L = arr.shape[0]
    B = new_arr.shape[0]
    po = jnp.argsort(~new_ok)  # pack new frames, slot order preserved
    na, nc = new_arr[po], new_conf[po]
    n_new = new_ok.sum().astype(jnp.int32)
    total = length + n_new
    start = jnp.maximum(total - mb, 0)
    idx = start + jnp.arange(L, dtype=jnp.int32)
    from_old = idx < length
    oi = jnp.clip(idx, 0, L - 1)
    ni = jnp.clip(idx - length, 0, B - 1)
    out_a = jnp.where(from_old, arr[oi], na[ni])
    out_c = jnp.where(from_old, conf[oi], nc[ni])
    return out_a, out_c, jnp.minimum(total, mb).astype(jnp.int32)


def prune_fleet(fleet: PaddedFleet, now, deadline: float, do_mask) -> PaddedFleet:
    """Batched ``FleetState.prune_expired``: drop expired frames of the
    streams where ``do_mask`` is set."""
    a, c, n = jax.vmap(_prune_single, in_axes=(0, 0, 0, 0, None, 0))(
        fleet.arrival, fleet.conf, fleet.length, now, deadline, do_mask)
    return PaddedFleet(a, c, n)


def consume_fleet(fleet: PaddedFleet, take, clear) -> PaddedFleet:
    """Batched ``FleetState.consume``: ``take`` is an (S, L) mask of backlog
    positions that left the device; ``clear`` empties whole streams."""
    a, c, n = jax.vmap(_consume_single)(fleet.arrival, fleet.conf, fleet.length,
                                        take, clear)
    return PaddedFleet(a, c, n)


def extend_fleet(fleet: PaddedFleet, new_arr, new_conf, new_ok, mb) -> PaddedFleet:
    """Batched ``FleetState.extend``: append each stream's (B,) new frames
    (mask ``new_ok``, slot order) and trim to the ``mb`` newest.  ``mb`` is
    either one static int (homogeneous fleet) or an (S,) per-stream bound
    (heterogeneous policy groups with distinct ``max_backlog``)."""
    mb_ax = None if np.ndim(mb) == 0 else 0
    a, c, n = jax.vmap(_extend_single, in_axes=(0, 0, 0, 0, 0, 0, mb_ax))(
        fleet.arrival, fleet.conf, fleet.length, new_arr, new_conf, new_ok, mb)
    return PaddedFleet(a, c, n)


def clear_fleet(fleet: PaddedFleet, mask) -> PaddedFleet:
    """Batched ``FleetState.clear``: empty the masked streams' backlogs."""
    return PaddedFleet(fleet.arrival, fleet.conf,
                       jnp.where(mask, 0, fleet.length).astype(jnp.int32))


# --------------------------------------------------------------------------- #
# planners
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class PlannerSpec:
    """Static planner configuration — everything jit specializes on."""

    kind: str  # "cbo" | "threshold" | "local" | "server" | "greedy-rate"
    sizes: tuple  # (m,) payload bytes per resolution
    acc_server: tuple  # (m,)
    deadline: float
    latency: float
    server_time: float  # nominal T^o; plan_fleet can override per call
    L: int  # backlog pad (== max_backlog on the jax path)
    F: int = 0  # CBO frontier cap; 0 -> 1 + L*m
    theta: float = 0.5  # threshold policy
    resolution: int = -1  # threshold policy (index, -1 = highest)
    frame_interval: float = 1.0 / 30.0  # server policy
    local_acc: float = 0.5  # greedy-rate policy
    dtype: object = jnp.float32
    # split-computation actions appended after the m frame actions
    # (repro.split / policy.types.ActionTable).  Empty tuples keep every
    # frame-only code path — and its compiled graph — untouched.
    split_sizes: tuple = ()  # payload bytes per split action
    split_acc: tuple = ()  # server accuracy per split action
    split_t_dev: tuple = ()  # device prefix seconds per split action
    split_srv_frac: tuple = ()  # fraction of T^o the suffix costs

    @property
    def m(self) -> int:
        return len(self.acc_server)

    @property
    def n_actions(self) -> int:
        return self.m + len(self.split_sizes)

    @property
    def rtt(self) -> float:
        return self.server_time + self.latency

    @property
    def frontier(self) -> int:
        return self.F if self.F > 0 else 1 + self.L * self.n_actions


class PlanOut(NamedTuple):
    """One fleet planning pass, fixed shapes (the ``PlanBatch`` analogue).

    ``dec[s, j]`` is the planned resolution index for backlog slot ``j``
    of stream ``s``, or -1 to keep it local — the offload set and the
    consume mask in one array.
    """

    dec: jnp.ndarray  # (S, L) int8
    theta: jnp.ndarray  # (S,)
    resolution: jnp.ndarray  # (S,) int32
    n_offloads: jnp.ndarray  # (S,) int32
    total_gain: jnp.ndarray  # (S,)
    base_acc: jnp.ndarray  # (S,)
    n_frames: jnp.ndarray  # (S,) int32
    overflow: jnp.ndarray  # (S,) bool — frontier cap would have truncated
    inexact: jnp.ndarray  # (S,) bool — eps-window prune disagreement possible


def _summarize(dec, conf, length, gain, spec: PlannerSpec):
    """theta / r° / counters from a decision row — ``plan_from_chain`` and
    ``PlanBatch.from_offloads`` semantics: theta is the max confidence among
    offloads, r° that frame's resolution, ties to the earliest position."""
    L = spec.L
    valid = jnp.arange(L) < length
    take = dec >= 0
    n_off = take.sum().astype(jnp.int32)
    confm = jnp.where(take, conf, -jnp.inf)
    mx = confm.max()
    has = take.any()
    first = jnp.argmax(confm == mx)  # earliest slot attaining the max
    theta = jnp.where(has, mx, jnp.asarray(0.0, dtype=conf.dtype))
    r0 = jnp.where(has, dec[first].astype(jnp.int32), spec.m - 1)
    base = jnp.where(valid, conf, 0.0).sum()
    return theta, r0, n_off, gain, base


def _plan_local_single(arr, conf, length, now, bw, st, spec: PlannerSpec):
    dec = jnp.full((spec.L,), -1, dtype=jnp.int8)
    return dec, jnp.asarray(0.0, dtype=arr.dtype), jnp.asarray(False), jnp.asarray(False)


def _plan_server_single(arr, conf, length, now, bw, st, spec: PlannerSpec):
    """ServerPolicy.plan_many: highest resolution sustainable within both
    the frame interval and the deadline budget; offload every frame."""
    L, m = spec.L, spec.m
    sizes = jnp.asarray(spec.sizes, dtype=arr.dtype)
    acc = jnp.asarray(spec.acc_server, dtype=arr.dtype)
    if isinstance(st, float):  # static T^o: Python-float math, as before
        tx_budget = min(spec.frame_interval, spec.deadline - st - spec.latency)
    else:  # occupancy-calibrated T^o traced per round
        tx_budget = jnp.minimum(spec.frame_interval,
                                spec.deadline - st - spec.latency)
    feas = sizes / jnp.maximum(bw, 1e-9) <= tx_budget  # (m,)
    has_res = feas.any()
    r_s = (m - 1) - jnp.argmax(feas[::-1]).astype(jnp.int32)
    valid = jnp.arange(L) < length
    take = valid & has_res
    dec = jnp.where(take, r_s.astype(jnp.int8), jnp.int8(-1))
    gain = jnp.where(take, acc[r_s] - conf, 0.0).sum()
    return dec, gain, jnp.asarray(False), jnp.asarray(False)


def _plan_threshold_single(arr, conf, length, now, bw, st, spec: PlannerSpec):
    """ThresholdPolicy.plan_many: serial acceptance in backlog order at a
    fixed resolution — same max-plus accumulation, same order."""
    L, m = spec.L, spec.m
    r = spec.resolution % m
    rtt = st + spec.latency
    tx = jnp.asarray(spec.sizes[r], dtype=arr.dtype) / bw
    dacc = jnp.asarray(spec.acc_server[r], dtype=arr.dtype) - conf  # (L,)
    valid = jnp.arange(L) < length

    def body(d, carry):
        t, gain, dec = carry
        cand = valid[d] & (conf[d] < spec.theta)
        t_new = jnp.maximum(t, arr[d]) + tx
        ok = cand & (t_new + rtt <= arr[d] + spec.deadline)
        t = jnp.where(ok, t_new, t)
        gain = jnp.where(ok, gain + dacc[d], gain)
        dec = dec.at[d].set(jnp.where(ok, jnp.int8(r), jnp.int8(-1)))
        return t, gain, dec

    t0 = now.astype(arr.dtype)
    _, gain, dec = jax.lax.fori_loop(
        0, L, body, (t0, jnp.asarray(0.0, dtype=arr.dtype),
                     jnp.full((L,), -1, dtype=jnp.int8)))
    return dec, gain, jnp.asarray(False), jnp.asarray(False)


def _plan_cbo_single(arr, conf, length, now, bw, st, spec: PlannerSpec):
    """``cbo_plan`` (paper Algorithm 1) with a capped fixed-shape frontier.

    Semantics notes vs ``frontier.py``:
      * frames walk in confidence-descending stable order; invalid slots
        sort last (conf key -inf) so depths >= length are pure carries;
      * candidates are [frontier carries] ++ [expansions, state-major /
        resolution-minor] — infeasible rows are masked (t=+inf, gain=-inf)
        instead of removed, which the stable (t, -gain, idx) sort sends to
        the tail without disturbing the relative order of live rows;
      * the reference's "collapse" shortcut (expand only from the last
        state with t <= arrival) is omitted: expansions from earlier such
        states tie in t with strictly lower gain, so the prune drops them
        — the surviving frontier is provably identical;
      * pruning keeps a candidate iff its gain beats the running max of
        all prior gains by > eps.  The reference advances its bar on KEPT
        gains only; the two rules can disagree only when a gain lands in
        an (eps, 2*eps] window above the bar — unrepresentable at float32
        resolution, but flagged (``inexact``) and rechecked by the tests;
      * instead of a node pool, every frontier state carries its full
        decision row (``(F, L)`` int8): survivors copy their parent's row
        and stamp their own (slot, resolution) — reconstruction-free.

    Split action tables dispatch to ``_plan_cbo_actions`` (the same DP over
    the enlarged {frame@res} ∪ {features@cut} grid); the frame-only body
    below stays byte-identical so its compiled graph — and the snapshot
    goldens pinned to it — never changes.
    """
    if spec.split_sizes:
        return _plan_cbo_actions(arr, conf, length, now, bw, st, spec)
    L, m, F = spec.L, spec.m, spec.frontier
    dt = arr.dtype
    rtt = st + spec.latency
    sizes = jnp.asarray(spec.sizes, dtype=dt)
    acc = jnp.asarray(spec.acc_server, dtype=dt)
    tx = sizes / bw  # (m,)
    static_t = tx <= spec.deadline - rtt  # (m,)
    valid = jnp.arange(L) < length
    # confidence-descending stable order, invalid slots last
    order = jnp.argsort(-jnp.where(valid, conf, -jnp.inf))

    eps = jnp.asarray(_EPS, dtype=dt)
    neg = jnp.asarray(-jnp.inf, dtype=dt)
    cand_parent = jnp.concatenate([jnp.arange(F), jnp.repeat(jnp.arange(F), m)])
    cand_res = jnp.concatenate([jnp.full((F,), -1, dtype=jnp.int32),
                                jnp.tile(jnp.arange(m, dtype=jnp.int32), F)])

    def body(d, carry):
        f_t, f_gain, f_valid, f_dec, overflow, inexact = carry
        j = order[d]
        arr_j, conf_j = arr[j], conf[j]
        live = d < length
        feas_j = static_t & (acc > conf_j) & live  # (m,)
        start = jnp.maximum(f_t, arr_j)  # (F,)
        t_exp = start[:, None] + tx[None, :]  # (F, m)
        g_exp = f_gain[:, None] + (acc - conf_j)[None, :]
        ok_exp = (f_valid[:, None] & feas_j[None, :]
                  & (t_exp + rtt <= arr_j + spec.deadline))
        cand_t = jnp.concatenate([f_t, t_exp.reshape(-1)])
        cand_g = jnp.concatenate([f_gain, g_exp.reshape(-1)])
        cand_ok = jnp.concatenate([f_valid, ok_exp.reshape(-1)])
        tkey = jnp.where(cand_ok, cand_t, jnp.inf)
        gkey = jnp.where(cand_ok, cand_g, neg)
        # stable (t asc, gain desc, candidate idx asc) via composed sorts
        o = jnp.argsort(-gkey)
        o = o[jnp.argsort(tkey[o])]
        ts, gs, oks = tkey[o], gkey[o], cand_ok[o]
        run = jax.lax.cummax(gs)
        prev_all = jnp.concatenate([neg[None], run[:-1]])
        keep = oks & (gs > prev_all + eps)
        # reference bar advances on kept gains only — flag the eps window
        kept_bar = jax.lax.cummax(jnp.where(keep, gs, neg))
        prev_kept = jnp.concatenate([neg[None], kept_bar[:-1]])
        inexact = inexact | (oks & ~keep & (gs > prev_kept + eps)).any()
        overflow = overflow | (keep.sum() > F)
        sel = jnp.argsort(~keep)[:F]  # kept-first, sorted order preserved
        new_valid = keep[sel]
        new_t = jnp.where(new_valid, ts[sel], jnp.inf).astype(dt)
        new_g = jnp.where(new_valid, gs[sel], neg)
        src = o[sel]
        par, res = cand_parent[src], cand_res[src]
        dec_par = f_dec[par]  # (F, L)
        col = dec_par[jnp.arange(F), j]
        new_col = jnp.where(res >= 0, res.astype(jnp.int8), col)
        new_dec = dec_par.at[:, j].set(new_col)
        return new_t, new_g, new_valid, new_dec, overflow, inexact

    f_t = jnp.full((F,), jnp.inf, dtype=dt).at[0].set(now.astype(dt))
    f_gain = jnp.full((F,), -jnp.inf, dtype=dt).at[0].set(0.0)
    f_valid = jnp.zeros((F,), dtype=bool).at[0].set(True)
    f_dec = jnp.full((F, L), -1, dtype=jnp.int8)
    f_t, f_gain, f_valid, f_dec, overflow, inexact = jax.lax.fori_loop(
        0, L, body, (f_t, f_gain, f_valid, f_dec,
                     jnp.asarray(False), jnp.asarray(False)))
    best = jnp.argmax(jnp.where(f_valid, f_gain, neg))  # first max, np.argmax order
    gain = jnp.where(f_valid[best], f_gain[best], 0.0)
    return f_dec[best], gain, overflow, inexact


def _plan_cbo_actions(arr, conf, length, now, bw, st, spec: PlannerSpec):
    """``cbo_plan`` over the full action grid — ``_plan_cbo_single`` with
    per-action columns instead of per-resolution ones (the jnp mirror of
    ``frontier._action_vectors``):

      * payload/accuracy become (A,) vectors (frames first, splits after);
      * a split action's upload leaves the device only after the prefix
        runs: effective start ``max(f_t, arr_j + t_dev[a])``;
      * its reply pays only the model suffix: per-action
        ``rtt[a] = st * srv_frac[a] + latency`` (frames: ``* 1.0``);
      * static feasibility subtracts ``t_dev`` too — the transmission must
        fit even when the uplink is idle at the *effective* ready time.

    Decision rows store ACTION indices (int8 — ``spec_for_policy`` bounds
    A at 127); frame actions occupy [0, m) so downstream consumers index
    shared action tables directly.
    """
    L, A, F = spec.L, spec.n_actions, spec.frontier
    dt = arr.dtype
    sizes = jnp.asarray(spec.sizes + spec.split_sizes, dtype=dt)
    acc = jnp.asarray(spec.acc_server + spec.split_acc, dtype=dt)
    t_dev = jnp.asarray((0.0,) * spec.m + spec.split_t_dev, dtype=dt)
    srv_frac = jnp.asarray((1.0,) * spec.m + spec.split_srv_frac, dtype=dt)
    rtt = st * srv_frac + spec.latency  # (A,)
    tx = sizes / bw  # (A,)
    static_t = tx <= spec.deadline - rtt - t_dev  # (A,)
    valid = jnp.arange(L) < length
    order = jnp.argsort(-jnp.where(valid, conf, -jnp.inf))

    eps = jnp.asarray(_EPS, dtype=dt)
    neg = jnp.asarray(-jnp.inf, dtype=dt)
    cand_parent = jnp.concatenate([jnp.arange(F), jnp.repeat(jnp.arange(F), A)])
    cand_res = jnp.concatenate([jnp.full((F,), -1, dtype=jnp.int32),
                                jnp.tile(jnp.arange(A, dtype=jnp.int32), F)])

    def body(d, carry):
        f_t, f_gain, f_valid, f_dec, overflow, inexact = carry
        j = order[d]
        arr_j, conf_j = arr[j], conf[j]
        live = d < length
        feas_j = static_t & (acc > conf_j) & live  # (A,)
        start = jnp.maximum(f_t[:, None], arr_j + t_dev[None, :])  # (F, A)
        t_exp = start + tx[None, :]  # (F, A)
        g_exp = f_gain[:, None] + (acc - conf_j)[None, :]
        ok_exp = (f_valid[:, None] & feas_j[None, :]
                  & (t_exp + rtt[None, :] <= arr_j + spec.deadline))
        cand_t = jnp.concatenate([f_t, t_exp.reshape(-1)])
        cand_g = jnp.concatenate([f_gain, g_exp.reshape(-1)])
        cand_ok = jnp.concatenate([f_valid, ok_exp.reshape(-1)])
        tkey = jnp.where(cand_ok, cand_t, jnp.inf)
        gkey = jnp.where(cand_ok, cand_g, neg)
        o = jnp.argsort(-gkey)
        o = o[jnp.argsort(tkey[o])]
        ts, gs, oks = tkey[o], gkey[o], cand_ok[o]
        run = jax.lax.cummax(gs)
        prev_all = jnp.concatenate([neg[None], run[:-1]])
        keep = oks & (gs > prev_all + eps)
        kept_bar = jax.lax.cummax(jnp.where(keep, gs, neg))
        prev_kept = jnp.concatenate([neg[None], kept_bar[:-1]])
        inexact = inexact | (oks & ~keep & (gs > prev_kept + eps)).any()
        overflow = overflow | (keep.sum() > F)
        sel = jnp.argsort(~keep)[:F]
        new_valid = keep[sel]
        new_t = jnp.where(new_valid, ts[sel], jnp.inf).astype(dt)
        new_g = jnp.where(new_valid, gs[sel], neg)
        src = o[sel]
        par, res = cand_parent[src], cand_res[src]
        dec_par = f_dec[par]
        col = dec_par[jnp.arange(F), j]
        new_col = jnp.where(res >= 0, res.astype(jnp.int8), col)
        new_dec = dec_par.at[:, j].set(new_col)
        return new_t, new_g, new_valid, new_dec, overflow, inexact

    f_t = jnp.full((F,), jnp.inf, dtype=dt).at[0].set(now.astype(dt))
    f_gain = jnp.full((F,), -jnp.inf, dtype=dt).at[0].set(0.0)
    f_valid = jnp.zeros((F,), dtype=bool).at[0].set(True)
    f_dec = jnp.full((F, L), -1, dtype=jnp.int8)
    f_t, f_gain, f_valid, f_dec, overflow, inexact = jax.lax.fori_loop(
        0, L, body, (f_t, f_gain, f_valid, f_dec,
                     jnp.asarray(False), jnp.asarray(False)))
    best = jnp.argmax(jnp.where(f_valid, f_gain, neg))
    gain = jnp.where(f_valid[best], f_gain[best], 0.0)
    return f_dec[best], gain, overflow, inexact


def _plan_greedy_rate_single(arr, conf, length, now, bw, st, spec: PlannerSpec):
    """GreedyRatePolicy._plan: per frame in backlog order, walk resolutions
    from the highest down, stop at the first whose server accuracy no longer
    beats the local tier, offload at the first that also meets the deadline;
    the uplink finish time carries serially across frames (max-plus)."""
    L, m = spec.L, spec.m
    dt = arr.dtype
    rtt = st + spec.latency
    # candidate resolutions: the descending prefix from m-1 down to (but
    # excluding) the first r with acc_server[r] <= local_acc — static, the
    # reference's inner break depends only on config
    cand = []
    for r in range(m - 1, -1, -1):
        if spec.acc_server[r] <= spec.local_acc:
            break
        cand.append(r)
    if not cand:
        dec = jnp.full((L,), -1, dtype=jnp.int8)
        return dec, jnp.asarray(0.0, dtype=dt), jnp.asarray(False), jnp.asarray(False)
    cand_idx = jnp.asarray(cand, dtype=jnp.int32)  # descending r
    sizes = jnp.asarray(spec.sizes, dtype=dt)
    acc = jnp.asarray(spec.acc_server, dtype=dt)
    tx = sizes[cand_idx] / bw  # (n_cand,)
    valid = jnp.arange(L) < length

    def body(d, carry):
        t, gain, dec = carry
        t_new = jnp.maximum(t, arr[d]) + tx  # (n_cand,) — t untouched until pick
        ok = t_new + rtt <= arr[d] + spec.deadline
        pick = jnp.argmax(ok)  # first feasible candidate = highest feasible r
        has = ok.any() & valid[d]
        r_sel = cand_idx[pick]
        t = jnp.where(has, t_new[pick], t)
        gain = jnp.where(has, gain + acc[r_sel] - conf[d], gain)
        dec = dec.at[d].set(jnp.where(has, r_sel.astype(jnp.int8), jnp.int8(-1)))
        return t, gain, dec

    _, gain, dec = jax.lax.fori_loop(
        0, L, body, (now.astype(dt), jnp.asarray(0.0, dtype=dt),
                     jnp.full((L,), -1, dtype=jnp.int8)))
    return dec, gain, jnp.asarray(False), jnp.asarray(False)


_PLANNERS = {
    "cbo": _plan_cbo_single,
    "threshold": _plan_threshold_single,
    "local": _plan_local_single,
    "server": _plan_server_single,
    "greedy-rate": _plan_greedy_rate_single,
}


def plan_fleet(spec: PlannerSpec, fleet: PaddedFleet, now, bw,
               server_time=None) -> PlanOut:
    """One planning pass over every stream, vmapped single-stream planners.

    ``bw`` must already carry the 1 byte/s floor (``FleetRunner.env_batch``
    applies it); ``now`` is each stream's first valid arrival this round.
    ``server_time`` overrides the spec's static nominal T^o with a traced
    scalar (the occupancy-calibrated estimate under a batching slow tier);
    ``None`` keeps the original static-constant compiled graph.
    """
    single = _PLANNERS[spec.kind]
    st = spec.server_time if server_time is None \
        else jnp.asarray(server_time, dtype=spec.dtype)

    def one(arr, conf, length, now_s, bw_s):
        dec, gain, overflow, inexact = single(arr, conf, length, now_s, bw_s,
                                              st, spec)
        theta, r0, n_off, gain, base = _summarize(dec, conf, length, gain, spec)
        return dec, theta, r0, n_off, gain, base, overflow, inexact

    dec, theta, r0, n_off, gain, base, ovf, inx = jax.vmap(one)(
        fleet.arrival, fleet.conf, fleet.length, now, bw)
    return PlanOut(dec=dec, theta=theta, resolution=r0, n_offloads=n_off,
                   total_gain=gain, base_acc=base,
                   n_frames=fleet.length, overflow=ovf, inexact=inx)


def make_planner(spec: PlannerSpec):
    """jit-compiled ``plan_fleet`` closed over the static spec.  The
    optional 4th arg is a traced ``server_time`` override (pass ``None``
    for the static spec constant; each choice compiles once)."""
    return jax.jit(lambda fleet, now, bw, server_time=None:
                   plan_fleet(spec, fleet, now, bw, server_time))


def planner_kind(policy) -> Optional[str]:
    """Registry kind of the JAX planner that covers ``policy`` (None when
    the compiled path has no equivalent)."""
    from repro.policy.policies import (CBOPolicy, GreedyRatePolicy, LocalPolicy,
                                       ServerPolicy, ThresholdPolicy)

    for cls, kind in ((CBOPolicy, "cbo"), (ThresholdPolicy, "threshold"),
                      (ServerPolicy, "server"), (GreedyRatePolicy, "greedy-rate"),
                      (LocalPolicy, "local")):
        if isinstance(policy, cls):
            return kind
    return None


def jax_unsupported_policies(policies) -> list:
    """Every reason the given policy instances (one per fleet group) cannot
    run on ``backend="jax"`` — empty list means fully supported.  Collects
    ALL blockers instead of raising on the first, so callers can surface
    one complete error message (``serving.engine_jax.jax_unsupported``)."""
    reasons = []
    for p in policies:
        name = type(p).__name__
        if planner_kind(p) is None:
            reasons.append(f"policy {name} has no JAX planner "
                           f"(supported kinds: {', '.join(JAX_PLANNABLE)})")
        if getattr(p, "max_backlog", None) is None:
            reasons.append(f"policy {name}: unbounded max_backlog cannot be "
                           "padded to fixed shapes (pass a finite max_backlog)")
    seen: set = set()
    return [r for r in reasons if not (r in seen or seen.add(r))]


def spec_for_policy(policy, *, sizes, acc_server, deadline, latency,
                    server_time, dtype=jnp.float32, F: int = 0,
                    pad_L: Optional[int] = None, actions=None) -> PlannerSpec:
    """Build the static spec for one policy instance (one fleet group).

    ``pad_L`` overrides the backlog pad width: heterogeneous fleets share
    one (S, L) grid padded to the largest group's ``max_backlog``, while
    each group still trims to its own bound (``extend_fleet``'s per-stream
    ``mb``).  Raises for policies the JAX path does not support — the
    numpy path is always available for those.

    ``actions`` is a split-computation ``ActionTable`` (or None): its split
    rows become the spec's static ``split_*`` tuples — consumed by the cbo
    planner only, exactly as on the numpy path (the baselines are
    frame-only by design and ignore the table).
    """
    mb = getattr(policy, "max_backlog", None)
    if mb is None:
        raise ValueError("backend='jax' needs a finite max_backlog "
                         "(fixed-shape backlogs); got None (unbounded)")
    L = int(mb) if pad_L is None else int(pad_L)
    if L < int(mb):
        raise ValueError(f"pad_L={L} is below the policy's max_backlog={mb}")
    common = dict(sizes=tuple(float(x) for x in sizes),
                  acc_server=tuple(float(x) for x in acc_server),
                  deadline=float(deadline), latency=float(latency),
                  server_time=float(server_time), L=L, F=F, dtype=dtype)
    kind = planner_kind(policy)
    if (actions is not None and getattr(actions, "has_splits", False)
            and kind == "cbo"):
        if actions.n_actions > 127:
            raise ValueError(
                f"backend='jax' stores decisions as int8: {actions.n_actions} "
                "actions exceed 127 (subsample the cut catalog)")
        k0 = actions.n_frame_actions
        common.update(
            split_sizes=tuple(float(x) for x in actions.sizes[k0:]),
            split_acc=tuple(float(x) for x in actions.acc[k0:]),
            split_t_dev=tuple(float(x) for x in actions.t_dev[k0:]),
            split_srv_frac=tuple(float(x) for x in actions.srv_frac[k0:]))
    if kind == "cbo":
        return PlannerSpec(kind="cbo", **common)
    if kind == "threshold":
        return PlannerSpec(kind="threshold", theta=policy.theta,
                           resolution=policy.resolution, **common)
    if kind == "server":
        return PlannerSpec(kind="server", frame_interval=policy.frame_interval,
                           **common)
    if kind == "greedy-rate":
        return PlannerSpec(kind="greedy-rate", local_acc=policy.local_acc,
                           **common)
    if kind == "local":
        return PlannerSpec(kind="local", **common)
    raise ValueError(f"backend='jax' supports policies {JAX_PLANNABLE}; "
                     f"got {type(policy).__name__}")


def plan_batch_from_out(out: PlanOut, n_streams: int, m: int):
    """Host bridge: materialize a numpy ``PlanBatch`` from a ``PlanOut``.

    Offloads come out of the (S, L) decision grid row-major, which IS
    (stream, pos) order — the order ``PlanBatch.sort_offloads`` produces.
    """
    from repro.policy.types import PlanBatch

    dec = np.asarray(out.dec)
    off_s, off_p = np.nonzero(dec >= 0)
    pb = PlanBatch(
        theta=np.asarray(out.theta, dtype=np.float64),
        resolution=np.asarray(out.resolution, dtype=np.int64),
        n_offloads=np.asarray(out.n_offloads, dtype=np.int64),
        total_gain=np.asarray(out.total_gain, dtype=np.float64),
        base_acc=np.asarray(out.base_acc, dtype=np.float64),
        n_frames=np.asarray(out.n_frames, dtype=np.int64),
        off_stream=off_s.astype(np.int64), off_pos=off_p.astype(np.int64),
        off_res=dec[off_s, off_p].astype(np.int64),
        planned=np.ones(n_streams, dtype=bool))
    return pb


# --------------------------------------------------------------------------- #
# EWMA bandwidth fold
# --------------------------------------------------------------------------- #


def ewma_fold(bw_est, alpha: float, stream, rate, ok, n_streams: int, depth: int):
    """Fold one round's transfer observations into the (S,) EWMA vector —
    ``FleetRunner.observe_bandwidth`` with static shapes.

    ``stream`` / ``rate`` / ``ok`` are flat rows in *transmission order*;
    each stream's valid observations are folded depth-wise in that order,
    bit-matching the scalar estimator's update sequence.  ``depth`` bounds
    observations per stream (the round's batch size).
    """
    o = jnp.argsort(jnp.where(ok, stream, n_streams))  # group by stream, stable
    s_sorted, r_sorted, ok_sorted = stream[o], rate[o], ok[o]
    # rank within stream = position - first position of the stream's group
    idx = jnp.arange(stream.shape[0])
    is_first = jnp.concatenate([jnp.ones((1,), bool),
                                s_sorted[1:] != s_sorted[:-1]])
    group_start = jax.lax.cummax(jnp.where(is_first, idx, 0))
    rank = idx - group_start
    counts = jnp.zeros((n_streams,), jnp.int32).at[s_sorted].add(
        ok_sorted.astype(jnp.int32), mode="drop")
    grid = jnp.zeros((n_streams, depth), dtype=bw_est.dtype)
    # non-ok tail rows scatter out of bounds (dropped) so their ranks can
    # never collide with a valid stream/rank cell
    grid = grid.at[jnp.where(ok_sorted, s_sorted, n_streams),
                   jnp.minimum(rank, depth - 1)].set(r_sorted, mode="drop")
    a = alpha

    def body(k, bw):
        m = counts > k
        return jnp.where(m, (1 - a) * bw + a * grid[:, k], bw)

    return jax.lax.fori_loop(0, depth, body, bw_est)
