"""String-keyed policy registry: ``@register("name")`` / ``make_policy``.

The registry is how consumers stay decoupled from implementations: serving
engines take ``policy="cbo"``, the replay evaluator iterates
``available_policies()``, and a new policy becomes servable + benchable the
moment its module registers it.
"""
from __future__ import annotations

from typing import Callable, Sequence

_REGISTRY: dict[str, type] = {}


def register(name: str) -> Callable[[type], type]:
    """Class decorator: register an ``OffloadPolicy`` under ``name``."""

    def deco(cls: type) -> type:
        if name in _REGISTRY and _REGISTRY[name] is not cls:
            raise ValueError(f"policy name {name!r} already registered to {_REGISTRY[name]!r}")
        _REGISTRY[name] = cls
        cls.policy_name = name
        return cls

    return deco


def make_policy(name_or_policy, **cfg):
    """Build a policy from a registry name (``make_policy("cbo", ...)``);
    an already-constructed policy instance passes through unchanged (in
    which case ``cfg`` must be empty)."""
    if not isinstance(name_or_policy, str):
        if cfg:
            raise TypeError("cfg kwargs only apply when constructing by name")
        return name_or_policy
    try:
        cls = _REGISTRY[name_or_policy]
    except KeyError:
        raise KeyError(
            f"unknown policy {name_or_policy!r}; registered: {sorted(_REGISTRY)}"
        ) from None
    return cls(**cfg)


def available_policies() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def resolve_policies(spec, n_streams: int) -> list:
    """Expand a policy spec into one policy instance per stream.

    ``spec`` may be a registry name (each stream gets a fresh instance), a
    callable factory ``stream_idx -> policy | name`` (heterogeneous
    fleets), or — for a single stream only — a policy instance.
    """
    if isinstance(spec, str):
        return [make_policy(spec) for _ in range(n_streams)]
    if callable(spec) and not isinstance(spec, type) and not hasattr(spec, "plan"):
        return [make_policy(spec(s)) for s in range(n_streams)]
    if n_streams != 1:
        raise ValueError(
            "a single policy instance cannot serve multiple streams (shared "
            "backlog); pass a registry name or a per-stream factory"
        )
    return [make_policy(spec)]
