"""Struct-of-arrays fleet control plane: S streams' policy state, batched.

The per-stream path keeps one ``PolicyRunner`` per stream — a Python list
of ``Frame`` objects per backlog and one EWMA estimator per link — and the
serving engine loops them.  At fleet scale the control plane becomes the
bottleneck: the frontier DP underneath is vectorized, but everything
around it is O(S) Python per round.

``FleetState`` replaces the object lists with flat numpy arrays:

  * ragged backlogs as flat ``conf`` / ``arrival`` / ``stream_id`` arrays,
    grouped by stream with ``offsets`` (segment boundaries), each segment
    in insertion (arrival) order — exactly the per-stream list semantics;
  * EWMA bandwidth estimates as one ``(S,)`` vector;
  * an ``active`` mask so streams can join and leave mid-run (churn).

``FleetRunner`` is the batched counterpart of ``PolicyRunner``: it owns
the fleet state, materializes an ``EnvBatch`` per round, groups streams by
(policy class, config) and drives each group through the policy's
``plan_many`` (vectorized where the policy provides one, a per-stream loop
over ``_plan`` otherwise), then applies consume/observe as segment
operations.  Per-stream and batched paths are interchangeable: the fuzz
tests in ``tests/test_fleet.py`` assert ``plan_all`` reproduces looped
``plan`` for every registered policy.
"""
from __future__ import annotations

from typing import Iterable, Sequence

import numpy as np

from repro.policy.base import OneShotPolicy
from repro.policy.types import Env, EnvBatch, Frame, PlanBatch

__all__ = ["FleetState", "FleetRunner", "segment_cummax", "looped_plan_many"]


# --------------------------------------------------------------------------- #
# segment primitives
# --------------------------------------------------------------------------- #


def segment_cummax(values: np.ndarray, seg_start_idx: np.ndarray) -> np.ndarray:
    """Inclusive running max within contiguous segments, vectorized.

    ``seg_start_idx[i]`` is the global index where element i's segment
    begins.  Hillis–Steele doubling: O(log n) passes of exact ``maximum``
    (no arithmetic on the values, so float comparisons downstream are
    unaffected — unlike offset-per-segment tricks).
    """
    out = np.asarray(values, dtype=np.float64).copy()
    n = len(out)
    idx = np.arange(n)
    shift = 1
    while shift < n:
        ok = idx - shift >= seg_start_idx
        out[ok] = np.maximum(out[ok], out[idx[ok] - shift])
        shift *= 2
    return out


def ragged_rank(counts: np.ndarray) -> np.ndarray:
    """0..c-1 within each block of a ragged layout given block ``counts``."""
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    excl = np.cumsum(counts) - counts
    return np.arange(total, dtype=np.int64) - np.repeat(excl, counts)


# --------------------------------------------------------------------------- #
# FleetState
# --------------------------------------------------------------------------- #


class FleetState:
    """Ragged per-stream backlogs as one flat struct-of-arrays.

    Invariants: entries are grouped by stream (``stream_id`` ascending),
    and within a stream keep insertion order — the same order a
    ``BacklogPolicy.backlog`` list would have, so backlog positions mean
    the same thing on both paths.
    """

    def __init__(self, n_streams: int, max_backlog=64, cell_id=None):
        self.n_streams = int(n_streams)
        self.arrival = np.zeros(0, dtype=np.float64)
        self.conf = np.zeros(0, dtype=np.float64)
        self.stream_id = np.zeros(0, dtype=np.int64)
        self.offsets = np.zeros(n_streams + 1, dtype=np.int64)
        mb = np.asarray(max_backlog if np.ndim(max_backlog) else
                        [max_backlog] * n_streams)
        # None (unbounded) is encoded as a negative sentinel
        self.max_backlog = np.asarray(
            [-1 if b is None else int(b) for b in mb], dtype=np.int64)
        # the fleet's topology partition: stream s lives in cell_id[s]
        # (all zeros = the single-uplink world; set by the serving engine
        # when an EdgeFabric is attached)
        self.cell_id = (np.zeros(n_streams, dtype=np.int64) if cell_id is None
                        else np.asarray(cell_id, dtype=np.int64))
        if len(self.cell_id) != self.n_streams:
            raise ValueError("cell_id must have one entry per stream")

    def __len__(self) -> int:
        return len(self.arrival)

    @property
    def lengths(self) -> np.ndarray:
        return np.diff(self.offsets)

    def _rebuild_offsets(self) -> None:
        counts = np.bincount(self.stream_id, minlength=self.n_streams)
        self.offsets = np.r_[0, np.cumsum(counts)].astype(np.int64)

    def filter(self, keep: np.ndarray) -> None:
        """Drop entries where ``keep`` is False (order preserved)."""
        self.arrival = self.arrival[keep]
        self.conf = self.conf[keep]
        self.stream_id = self.stream_id[keep]
        self._rebuild_offsets()

    def prune_expired(self, now: np.ndarray, deadline: float, streams_mask: np.ndarray) -> None:
        """Drop frames whose deadline window expired — the vectorized form
        of ``BacklogPolicy.plan``'s prune (same float compare per frame)."""
        if not len(self) or not streams_mask.any():
            return
        expired = ~(self.arrival + deadline > now[self.stream_id])
        drop = expired & streams_mask[self.stream_id]
        if drop.any():
            self.filter(~drop)

    def extend(self, stream: np.ndarray, arrival: np.ndarray, conf: np.ndarray) -> None:
        """Batched ``add_frame``: append frames (grouped per stream in the
        given order) then trim each stream to its ``max_backlog`` newest
        entries — list ``observe`` semantics, as segment ops."""
        if len(stream) == 0:
            return
        sid = np.concatenate([self.stream_id, np.asarray(stream, dtype=np.int64)])
        arr = np.concatenate([self.arrival, np.asarray(arrival, dtype=np.float64)])
        cf = np.concatenate([self.conf, np.asarray(conf, dtype=np.float64)])
        order = np.argsort(sid, kind="stable")  # regroup; old-before-new per stream
        self.stream_id, self.arrival, self.conf = sid[order], arr[order], cf[order]
        self._rebuild_offsets()
        mb = self.max_backlog[self.stream_id]
        # keep the last max_backlog entries of each segment
        over = (self.offsets[self.stream_id + 1] - np.arange(len(self))) > mb
        drop = (mb >= 0) & over
        if drop.any():
            self.filter(~drop)

    def clear(self, streams_mask: np.ndarray) -> None:
        """Empty the backlogs of the masked streams (retired clients)."""
        if len(self) and streams_mask.any():
            self.filter(~streams_mask[self.stream_id])

    def consume(self, off_stream: np.ndarray, off_pos: np.ndarray,
                clear_streams: np.ndarray) -> int:
        """Remove planned offloads (backlog positions as of the last plan)
        plus the entire backlog of ``clear_streams`` (one-shot policies)."""
        keep = np.ones(len(self), dtype=bool)
        if len(off_stream):
            keep[self.offsets[off_stream] + off_pos] = False
        if clear_streams.any():
            keep &= ~clear_streams[self.stream_id]
        removed = int((~keep).sum())
        if removed:
            self.filter(keep)
        return removed

    # -- views ----------------------------------------------------------- #

    def subset(self, streams: np.ndarray) -> "FleetState":
        """View restricted to ``streams`` (local ids 0..len(streams)-1).

        Returns ``self`` (an alias, not a copy) when ``streams`` covers the
        whole fleet in order; a fresh copy otherwise.  ``plan_many``
        implementations must treat the received state as read-only.
        """
        streams = np.asarray(streams, dtype=np.int64)
        if len(streams) == self.n_streams and np.array_equal(streams, np.arange(self.n_streams)):
            return self
        sub = FleetState(len(streams), max_backlog=self.max_backlog[streams],
                         cell_id=self.cell_id[streams])
        local = np.full(self.n_streams, -1, dtype=np.int64)
        local[streams] = np.arange(len(streams))
        sel = local[self.stream_id] >= 0
        sub.arrival = self.arrival[sel]
        sub.conf = self.conf[sel]
        sub.stream_id = local[self.stream_id[sel]]
        sub._rebuild_offsets()
        return sub

    def padded(self, pad_conf: float = np.inf):
        """Dense (S, L) views of the ragged backlogs plus a validity mask.
        Invalid slots get ``inf`` arrival/confidence so vectorized policies
        can keep static shapes without per-stream branches."""
        lens = self.lengths
        L = int(lens.max()) if len(self) else 0
        if L == 0:
            z = np.zeros((self.n_streams, 0))
            return z, z.copy(), np.zeros((self.n_streams, 0), dtype=bool)
        idx = self.offsets[:-1, None] + np.arange(L)[None, :]
        valid = np.arange(L)[None, :] < lens[:, None]
        idx = np.minimum(idx, len(self) - 1)
        arr = np.where(valid, self.arrival[idx], np.inf)
        conf = np.where(valid, self.conf[idx], pad_conf)
        return arr, conf, valid

    def frames_list(self, s: int, sizes: tuple) -> list[Frame]:
        """Materialize stream ``s``'s backlog as ``Frame`` objects — the
        bridge to per-stream ``plan`` for policies without a vectorized
        ``plan_many``."""
        lo, hi = int(self.offsets[s]), int(self.offsets[s + 1])
        return [Frame(arrival=float(self.arrival[i]), conf=float(self.conf[i]), sizes=sizes)
                for i in range(lo, hi)]


# --------------------------------------------------------------------------- #
# looped fallback
# --------------------------------------------------------------------------- #


def looped_plan_many(policy, now: np.ndarray, state: FleetState, env: EnvBatch) -> PlanBatch:
    """Default ``plan_many``: loop per-stream ``_plan`` over materialized
    ``Frame`` lists.  Correct for any policy; the vectorized overrides in
    ``policies.py`` / ``frontier.py`` exist because this is O(S) Python.

    Expired frames must already be pruned (``FleetRunner`` does this), so
    ``_plan`` sees the same backlog the per-stream path would after its
    own prune.
    """
    sizes = env.sizes_tuple
    step = getattr(policy, "_plan", policy.plan)  # plan() would just re-prune
    plans = []
    saved = policy.backlog
    try:
        for s in range(state.n_streams):
            policy.backlog = state.frames_list(s, sizes)
            plans.append(step(float(now[s]), env.for_stream(s)))
    finally:
        policy.backlog = saved
    return PlanBatch.from_plans(plans, len(env.acc_server))


# --------------------------------------------------------------------------- #
# FleetRunner
# --------------------------------------------------------------------------- #


def _group_key(policy) -> tuple:
    cfg = tuple(sorted((k, repr(v)) for k, v in vars(policy).items() if k != "backlog"))
    return (type(policy), cfg)


class FleetRunner:
    """Batched ``PolicyRunner``: one object drives S streams' policies.

    Owns what deployment measures per stream — the ``(S,)`` EWMA bandwidth
    vector — plus the shared link/deadline parameters, and keeps all
    backlog state in a ``FleetState``.  Heterogeneous fleets are grouped
    by (policy class, config); each group plans all of its streams in one
    ``plan_many`` call.
    """

    def __init__(self, policies: Sequence, *, resolutions: tuple, acc_server: tuple,
                 deadline: float, latency: float, server_time: float, size_of,
                 bw_init: float | np.ndarray = 1e6, bw_alpha: float = 0.3,
                 cell_id: np.ndarray | None = None, backend: str = "numpy",
                 actions=None):
        from repro.core.netsim import payload_sizes
        from repro.policy.types import ActionTable

        self.policies = list(policies)
        S = len(self.policies)
        self.n_streams = S
        self.resolutions = tuple(resolutions)
        self.acc_server = tuple(acc_server)
        self.deadline = float(deadline)
        self.latency = float(latency)
        self.server_time = float(server_time)
        # slow-tier congestion observables, refreshed each round by the
        # serving engine when the pool batches (see EnvBatch docs); the
        # engine also refreshes ``server_time`` with the calibrated
        # amortized estimate — identical to the nominal without batching
        self.occupancy = 1.0
        self.queue_depth = 0.0
        # THE action→bytes table: one source of truth for planner-assumed
        # and engine-transmitted payloads (numpy and jax alike).  With no
        # split actions the table is the legacy (m,) resolution grid and
        # ``self.actions`` stays None so every frame-only code path — and
        # its pinned snapshots — is untouched.
        if actions is None:
            actions = ActionTable.frames_only(
                sizes=payload_sizes(size_of, np.asarray(self.resolutions)),
                acc=np.asarray(self.acc_server, dtype=np.float64))
        if actions.n_frame_actions != len(self.resolutions):
            raise ValueError(
                f"action table has {actions.n_frame_actions} frame actions "
                f"but {len(self.resolutions)} resolutions")
        self.action_table = actions
        self.actions = actions if actions.has_splits else None
        self.sizes = actions.sizes[:actions.n_frame_actions]
        self.bw_alpha = float(bw_alpha)
        # telemetry hook (repro.obs.PhaseProfiler): when set, plan_all
        # folds its wall-clock into the "plan" phase; None costs nothing
        self.profiler = None
        # under an edge fabric, ``bw_init`` is the (S,) per-cell prior and
        # each stream's EWMA tracks its own cell's uplink from then on
        self.bw_est = np.broadcast_to(np.asarray(bw_init, dtype=np.float64), (S,)).copy()
        self.state = FleetState(
            S, max_backlog=[getattr(p, "max_backlog", None) for p in self.policies],
            cell_id=cell_id)
        self._prune = np.asarray([getattr(p, "prune_expired", True) for p in self.policies])
        self._oneshot = np.asarray([isinstance(p, OneShotPolicy) for p in self.policies])
        groups: dict[tuple, list[int]] = {}
        for s, p in enumerate(self.policies):
            groups.setdefault(_group_key(p), []).append(s)
        self.groups = [(self.policies[ss[0]], np.asarray(ss, dtype=np.int64))
                       for ss in groups.values()]
        if backend not in ("numpy", "jax"):
            raise ValueError(f"backend must be 'numpy' or 'jax', got {backend!r}")
        self.backend = backend
        self._jax_planner = None
        if backend == "jax":
            from repro.policy.fleet_jax import (jax_unsupported_policies,
                                                make_planner, spec_for_policy)

            reasons = jax_unsupported_policies([p for p, _ in self.groups])
            if reasons:
                raise ValueError("backend='jax' cannot express this fleet: "
                                 + "; ".join(reasons))
            # heterogeneous fleets share one pad width L (the largest
            # group's max_backlog); a homogeneous fleet keeps pad_L=None so
            # its planner spec — and compiled graph — is unchanged
            het = len(self.groups) != 1
            L = max(int(p.max_backlog) for p, _ in self.groups)
            self._jax_planner = []
            for policy, streams in self.groups:
                spec = spec_for_policy(
                    policy, sizes=self.sizes, acc_server=self.acc_server,
                    deadline=self.deadline, latency=self.latency,
                    server_time=self.server_time, pad_L=L if het else None,
                    actions=self.actions)
                self._jax_planner.append((spec, make_planner(spec), streams))

    # -- env ------------------------------------------------------------- #

    def env_batch(self) -> EnvBatch:
        # same 1 byte/s floor as PolicyRunner.env: a dead link plans
        # "all local" instead of dividing by zero inside the DP
        return EnvBatch(bandwidth=np.maximum(self.bw_est, 1.0), latency=self.latency,
                        server_time=self.server_time, deadline=self.deadline,
                        acc_server=self.acc_server, sizes=self.sizes,
                        cell_id=self.state.cell_id,
                        occupancy=self.occupancy, queue_depth=self.queue_depth,
                        actions=self.actions)

    def env(self, s: int) -> Env:
        return self.env_batch().for_stream(s)

    # -- control-plane ops (all batched) --------------------------------- #

    def plan_all(self, now: np.ndarray, active: np.ndarray | None = None) -> PlanBatch:
        """One planning pass over every active stream's backlog."""
        if self.profiler is None:
            return self._plan_all(now, active)
        with self.profiler.phase("plan"):
            return self._plan_all(now, active)

    def _plan_all(self, now: np.ndarray, active: np.ndarray | None = None) -> PlanBatch:
        S = self.n_streams
        now = np.asarray(now, dtype=np.float64)
        active = np.ones(S, dtype=bool) if active is None else np.asarray(active, dtype=bool)
        self.state.prune_expired(now, self.deadline, active & self._prune)
        if self.backend == "jax":
            return self._plan_all_jax(now, active)
        env = self.env_batch()
        batch = PlanBatch.empty(S, len(self.acc_server))
        batch.n_frames = self.state.lengths.copy()
        for policy, streams in self.groups:
            sel = streams[active[streams]]
            if len(sel) == 0:
                continue
            sub_state = self.state.subset(sel)
            sub_env = env.subset(sel) if len(sel) != S else env
            plan_many = getattr(policy, "plan_many", None)
            if plan_many is None:
                pb = looped_plan_many(policy, now[sel], sub_state, sub_env)
            else:
                pb = plan_many(now[sel], sub_state, sub_env)
            batch.scatter(sel, pb)
        batch.sort_offloads()
        batch.planned = active.copy()
        return batch.annotate_actions(self.actions)

    def _plan_all_jax(self, now: np.ndarray, active: np.ndarray) -> PlanBatch:
        """Compiled planning pass: pad the (already pruned) ragged state to
        fixed shapes, run each group's jitted planner, bridge back to one
        ``PlanBatch``.  Decisions are pinned integer-exact to the numpy
        path by ``tests/test_fleet_jax.py``; heterogeneous fleets reuse the
        numpy path's group scatter/sort machinery on the host side."""
        import jax.numpy as jnp

        from repro.policy.fleet_jax import fleet_from_state, plan_batch_from_out

        spec0 = self._jax_planner[0][0]
        fleet = fleet_from_state(self.state, spec0.L, dtype=spec0.dtype)
        now_j = jnp.asarray(np.where(np.isfinite(now), now, np.inf),
                            dtype=spec0.dtype)
        bw_j = jnp.asarray(np.maximum(self.bw_est, 1.0), dtype=spec0.dtype)
        # occupancy-aware T^o: pass the calibrated estimate as a traced
        # scalar only when it deviates from the spec's static nominal, so
        # batching-free runs keep the original (bit-pinned) compiled graph
        st = (None if float(self.server_time) == spec0.server_time
              else jnp.asarray(self.server_time, dtype=spec0.dtype))
        m = len(self.acc_server)
        if len(self._jax_planner) == 1:
            _, planner, _ = self._jax_planner[0]
            out = planner(fleet, now_j, bw_j, st)
            batch = plan_batch_from_out(out, self.n_streams, m)
        else:
            batch = PlanBatch.empty(self.n_streams, m)
            for spec, planner, streams in self._jax_planner:
                idx = jnp.asarray(streams, dtype=jnp.int32)
                sub = type(fleet)(fleet.arrival[idx], fleet.conf[idx],
                                  fleet.length[idx])
                out = planner(sub, now_j[idx], bw_j[idx], st)
                batch.scatter(streams, plan_batch_from_out(out, len(streams), m))
            batch.sort_offloads()
        if not active.all():  # inactive streams keep PlanBatch.empty rows
            batch.theta[~active] = 0.0
            batch.resolution[~active] = len(self.acc_server) - 1
            batch.n_offloads[~active] = 0
            batch.total_gain[~active] = 0.0
            batch.base_acc[~active] = 0.0
            sel = active[batch.off_stream]
            batch.off_stream = batch.off_stream[sel]
            batch.off_pos = batch.off_pos[sel]
            batch.off_res = batch.off_res[sel]
            batch.off_kind = batch.off_kind[sel]
            batch.off_cut = batch.off_cut[sel]
        batch.n_frames = self.state.lengths.copy()
        batch.planned = active.copy()
        return batch.annotate_actions(self.actions)

    def consume(self, batch: PlanBatch) -> int:
        """Planned offloads left the device; one-shot streams clear fully."""
        clear = batch.planned & self._oneshot
        osh = self._oneshot[batch.off_stream]
        return self.state.consume(batch.off_stream[~osh], batch.off_pos[~osh], clear)

    def observe_frames(self, stream: np.ndarray, arrival: np.ndarray, conf: np.ndarray) -> None:
        """Batched ``add_frame`` for one round's locally-answered frames."""
        self.state.extend(stream, arrival, conf)

    def observe_bandwidth(self, stream: np.ndarray, payload: np.ndarray,
                          seconds: np.ndarray) -> None:
        """Fold one round's transfer observations into the EWMA vector.

        Bit-identical to calling ``BandwidthEstimator.observe`` per
        transfer in array order: observations are grouped by stream
        (stably, preserving transmission order) and folded depth-wise, so
        each stream's estimate sees the same sequence of
        ``(1-a)*est + a*rate`` updates the scalar path applies.
        """
        stream = np.asarray(stream, dtype=np.int64)
        payload = np.asarray(payload, dtype=np.float64)
        seconds = np.asarray(seconds, dtype=np.float64)
        ok = seconds > 1e-9  # same guard as the scalar estimator
        if not ok.any():
            return
        stream, rate = stream[ok], payload[ok] / seconds[ok]
        order = np.argsort(stream, kind="stable")
        s_sorted, rate = stream[order], rate[order]
        counts = np.bincount(s_sorted, minlength=self.n_streams)
        starts = np.r_[0, np.cumsum(counts)[:-1]]
        rank = np.arange(len(s_sorted)) - starts[s_sorted]
        K = int(counts.max())
        grid = np.zeros((self.n_streams, K))
        grid[s_sorted, rank] = rate
        a = self.bw_alpha
        for k in range(K):
            m = counts > k
            self.bw_est[m] = (1 - a) * self.bw_est[m] + a * grid[m, k]

    def retire(self, streams_mask: np.ndarray) -> None:
        """Drop all state of streams that left the fleet."""
        self.state.clear(np.asarray(streams_mask, dtype=bool))

    # -- conveniences for tests / benchmarks ------------------------------ #

    def add_frame(self, s: int, arrival: float, conf: float) -> None:
        self.observe_frames(np.asarray([s]), np.asarray([float(arrival)]),
                            np.asarray([float(conf)]))
