"""The offload decision plane (paper §IV, made pluggable).

One protocol (``OffloadPolicy``: observe / plan / consume), one registry
(``@register`` / ``make_policy``), six built-in policies, and the two
harnesses that drive them: ``PolicyRunner`` (live serving, owns the
bandwidth estimate) and ``replay_trace`` (offline §V evaluation).  Serving
engines, benchmarks, and examples all select behavior by policy name —
see docs/policies.md for how to add one.
"""
from repro.policy.base import BacklogPolicy, OffloadPolicy, OneShotPolicy
from repro.policy.fleet import FleetRunner, FleetState
from repro.policy.frontier import cbo_plan, cbo_plan_many, optimal_schedule
from repro.policy.policies import (
    CBOPolicy,
    GreedyRatePolicy,
    LocalPolicy,
    OptimalPolicy,
    ServerPolicy,
    ThresholdPolicy,
)
from repro.policy.registry import available_policies, make_policy, register, resolve_policies
from repro.policy.replay import ReplayResult, replay_trace
from repro.policy.runner import BandwidthEstimator, PolicyRunner
from repro.policy.types import Env, EnvBatch, Frame, Plan, PlanBatch

__all__ = [
    "FleetRunner",
    "FleetState",
    "EnvBatch",
    "PlanBatch",
    "cbo_plan_many",
    "OffloadPolicy",
    "BacklogPolicy",
    "OneShotPolicy",
    "register",
    "make_policy",
    "available_policies",
    "resolve_policies",
    "CBOPolicy",
    "OptimalPolicy",
    "ThresholdPolicy",
    "LocalPolicy",
    "ServerPolicy",
    "GreedyRatePolicy",
    "PolicyRunner",
    "BandwidthEstimator",
    "replay_trace",
    "ReplayResult",
    "cbo_plan",
    "optimal_schedule",
    "Frame",
    "Env",
    "Plan",
]
