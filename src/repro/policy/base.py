"""The ``OffloadPolicy`` protocol and backlog base classes.

An offload policy is the *decision plane* of the two-tier cascade: it
watches locally-classified frames accumulate (``observe``), is asked —
against the network/deadline regime of the moment — which of them to send
to the server and at which resolution (``plan``), and is told which frames
actually left the device (``consume``).  Everything else (bandwidth
estimation, uplink simulation, tier inference, metrics) is the data plane's
job; serving engines, the trace-replay evaluator, and benchmarks all drive
policies through this one interface.

Implementations register under a string key (``@register("cbo")``) and are
constructed with ``make_policy(name, **cfg)`` — see ``registry.py``.
"""
from __future__ import annotations

from typing import Iterable, Protocol, Sequence, runtime_checkable

from repro.policy.types import Env, Frame, Plan, plan_from_chain


@runtime_checkable
class OffloadPolicy(Protocol):
    """Structural interface every offload policy implements."""

    backlog: list[Frame]

    def observe(self, frames: Sequence[Frame]) -> None:
        """Append locally-classified frames to the decision backlog."""
        ...

    def plan(self, now: float, env: Env) -> Plan:
        """Decide (theta, r°, offload set) over the backlog at time ``now``
        under ``env``.  ``Plan.offloads`` indexes the backlog as it stands
        when ``plan`` returns (policies may prune expired frames first)."""
        ...

    def consume(self, indices: Iterable[int]) -> int:
        """Remove frames that left the device.  ``indices`` are backlog
        indices as seen by the most recent ``plan`` call.  Returns the
        number of frames removed."""
        ...

    # Policies MAY additionally implement the batched fleet path
    #   plan_many(now: (S,), state: FleetState, env: EnvBatch) -> PlanBatch
    # planning S independent backlogs in one call (``policy/fleet.py``).
    # ``BacklogPolicy`` provides a looped default, so every policy is
    # fleet-servable; the built-ins override it with genuinely vectorized
    # implementations.  ``FleetRunner`` falls back to the loop for
    # policies without it.


class BacklogPolicy:
    """Base: a bounded backlog with the index-stable observe/consume dance.

    ``consume`` must run before the next ``observe`` for indices to stay
    aligned with the last ``plan`` (appends only ever extend the tail —
    the same invariant the old ``AdaptiveController`` documented).
    """

    #: prune frames whose deadline window has expired before planning
    prune_expired: bool = True

    def __init__(self, max_backlog: int | None = 64):
        self.backlog: list[Frame] = []
        self.max_backlog = max_backlog

    def observe(self, frames: Sequence[Frame]) -> None:
        self.backlog.extend(frames)
        if self.max_backlog is not None and len(self.backlog) > self.max_backlog:
            self.backlog = self.backlog[-self.max_backlog :]

    def plan(self, now: float, env: Env) -> Plan:
        if self.prune_expired:
            self.backlog = [f for f in self.backlog if f.arrival + env.deadline > now]
        return self._plan(now, env)

    def _plan(self, now: float, env: Env) -> Plan:
        raise NotImplementedError

    def plan_many(self, now, state, env):
        """Batched fleet path: plan S independent backlogs at once.

        Default falls back to looping ``_plan`` per stream (``state`` must
        already be pruned — ``FleetRunner`` does this); vectorized policies
        override.  See ``policy/fleet.py``.
        """
        from repro.policy.fleet import looped_plan_many

        return looped_plan_many(self, now, state, env)

    def consume(self, indices: Iterable[int]) -> int:
        drop = {int(i) for i in indices}
        kept = [f for i, f in enumerate(self.backlog) if i not in drop]
        removed = len(self.backlog) - len(kept)
        self.backlog = kept
        return removed


class OneShotPolicy(BacklogPolicy):
    """Base for policies that decide each frame exactly once at arrival
    (Server, greedy rate rules): whatever ``plan`` does not offload is
    answered locally forever, so ``consume`` clears the whole backlog."""

    def consume(self, indices: Iterable[int]) -> int:
        removed = len(self.backlog)
        self.backlog = []
        return removed


def empty_plan(frames: Sequence[Frame], m: int) -> Plan:
    return plan_from_chain([], frames, 0.0, m)
