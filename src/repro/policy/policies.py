"""The built-in offload policies — every §V approach as a registry entry.

  * ``cbo``         — paper Algorithm 1 (vectorized frontier DP)
  * ``optimal``     — the paper's offline optimal (full-knowledge DP)
  * ``threshold``   — fixed confidence threshold θ at a fixed resolution
  * ``local``       — never offload (fast tier answers everything)
  * ``server``      — offload everything at the highest sustainable resolution
  * ``greedy-rate`` — the FastVA/Compress rule: offload whenever the best
                      deadline-feasible resolution beats the local tier's
                      population accuracy; no per-frame confidence

All of them speak ``observe / plan / consume`` (see ``base.py``); serving
engines and the trace-replay evaluator cannot tell them apart.
All of them speak the batched fleet path too (``plan_many`` — see
``policy/fleet.py``): ``cbo``, ``threshold``, ``local`` and ``server``
plan S backlogs in one set of numpy segment operations; the others fall
back to the looped default in ``BacklogPolicy``.

Under an edge fabric (``repro/net``) no policy needs topology code: the
``EnvBatch.bandwidth`` vector each ``plan_many`` consumes is per-stream,
and each stream's EWMA tracks its own cell's uplink, so every policy
below automatically plans against the stream's cell (``EnvBatch.cell_id``
exposes the partition for policies that want more).

Split-computation action tables (``Env.actions`` / ``EnvBatch.actions``,
see ``repro.split``) are consumed by ``cbo`` only: the frontier DP plans
over the full {frame@res r} ∪ {features@cut k} grid.  The simpler
baselines deliberately keep the paper's frame-only resolution grid — a
fixed-θ or rate rule has no way to trade device-prefix time against
payload bytes, so handing them split actions would silently change their
meaning.  They ignore ``actions``; frame actions occupy indices [0, m) of
every table, so their plans stay valid action indices.
"""
from __future__ import annotations

import numpy as np

from repro.policy.base import BacklogPolicy, OneShotPolicy, empty_plan
from repro.policy.frontier import cbo_plan, cbo_plan_many, optimal_schedule
from repro.policy.registry import register
from repro.policy.types import Env, Plan, PlanBatch, plan_from_chain


@register("cbo")
class CBOPolicy(BacklogPolicy):
    """Algorithm 1: re-plan the confidence-sorted backlog every call."""

    def _plan(self, now: float, env: Env) -> Plan:
        return cbo_plan(self.backlog, env, now=now)

    def plan_many(self, now, state, env) -> PlanBatch:
        """S frontier DPs in one set of segment operations (bit-identical
        offload schedules to looping ``plan`` — see ``cbo_plan_many``)."""
        return cbo_plan_many(state, env, now)


@register("optimal")
class OptimalPolicy(BacklogPolicy):
    """Offline optimal over whatever window of frames has been observed.

    Full-knowledge baseline: plans as if the uplink were free at t=0 and
    never prunes (the DP itself handles deadline feasibility); the caller
    replays the schedule against the real uplink.  Unbounded backlog by
    default — the caller picks the window.
    """

    prune_expired = False

    def __init__(self, max_backlog: int | None = None):
        super().__init__(max_backlog=max_backlog)

    def _plan(self, now: float, env: Env) -> Plan:
        return optimal_schedule(self.backlog, env)


@register("threshold")
class ThresholdPolicy(BacklogPolicy):
    """Fixed θ: offload every backlog frame with conf < θ, serially, at a
    fixed resolution index (-1 = highest), skipping infeasible frames."""

    def __init__(self, theta: float = 0.5, resolution: int = -1,
                 max_backlog: int | None = 64):
        super().__init__(max_backlog=max_backlog)
        self.theta = float(theta)
        self.resolution = int(resolution)

    def _plan(self, now: float, env: Env) -> Plan:
        m = len(env.acc_server)
        r = self.resolution % m
        chain: list[tuple[int, int]] = []
        gain = 0.0
        t = now
        for i, f in enumerate(self.backlog):
            if f.conf >= self.theta:
                continue
            t_new = max(t, f.arrival) + f.sizes[r] / env.bandwidth
            if t_new + env.server_time + env.latency <= f.arrival + env.deadline:
                chain.append((i, r))
                gain += env.acc_server[r] - f.conf
                t = t_new
        return plan_from_chain(chain, self.backlog, gain, m)

    def plan_many(self, now, state, env) -> PlanBatch:
        """Vectorized across streams: the serial-uplink acceptance
        recursion runs one backlog *depth* per pass with (S,) vector ops —
        the same max-plus accumulation per stream, in the same order."""
        m = len(env.acc_server)
        r = self.resolution % m
        arr_p, conf_p, valid = state.padded()
        tx = env.sizes[r] / env.bandwidth  # (S,)
        rtt = env.server_time + env.latency
        dacc = env.acc_server[r] - conf_p  # (S, L)
        t = np.asarray(now, dtype=np.float64).copy()
        gain = np.zeros(state.n_streams)
        take = np.zeros_like(valid)
        for d in range(arr_p.shape[1]):
            cand = valid[:, d] & (conf_p[:, d] < self.theta)
            t_new = np.maximum(t, arr_p[:, d]) + tx
            ok = cand & (t_new + rtt <= arr_p[:, d] + env.deadline)
            t = np.where(ok, t_new, t)
            gain = np.where(ok, gain + dacc[:, d], gain)
            take[:, d] = ok
        off_s, off_p = np.nonzero(take)
        return PlanBatch.from_offloads(
            state.n_streams, m, off_stream=off_s, off_pos=off_p,
            off_res=np.full(len(off_s), r, dtype=np.int64),
            off_conf=conf_p[off_s, off_p], total_gain=gain,
            base_acc=(np.bincount(state.stream_id, weights=state.conf,
                                  minlength=state.n_streams)
                      if len(state) else np.zeros(state.n_streams)),
            n_frames=state.lengths)


@register("local")
class LocalPolicy(OneShotPolicy):
    """Never offload: the fast tier's answer always stands."""

    def _plan(self, now: float, env: Env) -> Plan:
        return empty_plan(self.backlog, len(env.acc_server))

    def plan_many(self, now, state, env) -> PlanBatch:
        out = PlanBatch.empty(state.n_streams, len(env.acc_server))
        out.n_frames = state.lengths.copy()
        out.base_acc = (np.bincount(state.stream_id, weights=state.conf,
                                    minlength=state.n_streams)
                        if len(state) else out.base_acc)
        out.planned = np.ones(state.n_streams, dtype=bool)
        return out


@register("server")
class ServerPolicy(OneShotPolicy):
    """Offload every frame at the highest resolution whose transmission fits
    both the frame interval (keep up with the stream) and the per-frame
    deadline budget; frames are sent even if queueing will make them late
    (there is no local fallback to save them for)."""

    transmit_late = True

    def __init__(self, frame_interval: float = 1.0 / 30.0,
                 max_backlog: int | None = 64):
        super().__init__(max_backlog=max_backlog)
        self.frame_interval = float(frame_interval)

    def _plan(self, now: float, env: Env) -> Plan:
        m = len(env.acc_server)
        if not self.backlog:
            return empty_plan(self.backlog, m)
        tx_budget = min(self.frame_interval,
                        env.deadline - env.server_time - env.latency)
        sizes = self.backlog[0].sizes
        res_ok = [r for r in range(m) if sizes[r] / max(env.bandwidth, 1e-9) <= tx_budget]
        if not res_ok:
            return empty_plan(self.backlog, m)
        r = max(res_ok)
        chain = [(i, r) for i in range(len(self.backlog))]
        gain = sum(env.acc_server[r] - f.conf for f in self.backlog)
        return plan_from_chain(chain, self.backlog, gain, m)

    def plan_many(self, now, state, env) -> PlanBatch:
        """Vectorized: one (S, m) feasibility table picks each stream's
        highest sustainable resolution; every backlog frame offloads."""
        m = len(env.acc_server)
        S = state.n_streams
        acc = np.asarray(env.acc_server, dtype=np.float64)
        tx_budget = min(self.frame_interval,
                        env.deadline - env.server_time - env.latency)
        feas = env.sizes[None, :] / np.maximum(env.bandwidth, 1e-9)[:, None] <= tx_budget
        has_res = feas.any(axis=1)
        r_s = (m - 1) - np.argmax(feas[:, ::-1], axis=1)  # highest feasible
        lens = state.lengths
        send = has_res[state.stream_id] if len(state) else np.zeros(0, dtype=bool)
        off_s = state.stream_id[send]
        off_p = (np.arange(len(state)) - state.offsets[:-1][state.stream_id])[send]
        rr = r_s[off_s]
        gain = np.bincount(off_s, weights=acc[rr] - state.conf[send], minlength=S)
        return PlanBatch.from_offloads(
            S, m, off_stream=off_s, off_pos=off_p, off_res=rr,
            off_conf=state.conf[send], total_gain=gain,
            base_acc=(np.bincount(state.stream_id, weights=state.conf, minlength=S)
                      if len(state) else np.zeros(S)),
            n_frames=lens)


@register("greedy-rate")
class GreedyRatePolicy(OneShotPolicy):
    """FastVA/Compress-style greedy rate rule: per frame, walk resolutions
    from the highest down; stop as soon as the server's (population)
    accuracy at that resolution no longer beats the local tier's; offload
    at the first resolution that also meets the deadline.  No per-frame
    confidence — ``local_acc`` is the local tier's population accuracy."""

    def __init__(self, local_acc: float = 0.5, max_backlog: int | None = 64):
        super().__init__(max_backlog=max_backlog)
        self.local_acc = float(local_acc)

    def _plan(self, now: float, env: Env) -> Plan:
        m = len(env.acc_server)
        chain: list[tuple[int, int]] = []
        gain = 0.0
        t = now
        for i, f in enumerate(self.backlog):
            for r in range(m - 1, -1, -1):
                if env.acc_server[r] <= self.local_acc:
                    break  # lower resolutions are worse than answering locally
                t_new = max(t, f.arrival) + f.sizes[r] / env.bandwidth
                if t_new + env.server_time + env.latency <= f.arrival + env.deadline:
                    chain.append((i, r))
                    gain += env.acc_server[r] - f.conf
                    t = t_new
                    break
        return plan_from_chain(chain, self.backlog, gain, m)
