"""Unified trace replay: one uplink/deadline simulation for every policy.

The paper's §V methodology, factored out once: predictions for both tiers
are precomputed over a frame trace; the replay walks the trace at the
stream's frame rate, lets the policy plan against the real ``Env``, and
scores *realized* accuracy under the serial uplink and per-frame deadlines.
Every approach — Local, Server, FastVA, Compress, CBO(±calibration),
Optimal, and whatever gets registered next — runs through this one loop;
the hand-rolled per-approach simulations it replaced each re-implemented
(and subtly diverged on) the same mechanics.

Semantics knobs (all policy-independent replay physics):

  * ``local_pred``/``local_time`` — what a non-offloaded frame falls back
    to, and how long the local tier is busy per frame (0 = always keeps
    up; ``None`` pred = unanswered, scored wrong — the Server baseline);
  * ``replan_every`` — online planning cadence in frames;
  * ``window`` — offline mode: plan whole windows with full knowledge
    (the Optimal baseline) instead of frame-by-frame;
  * ``transmit_late`` — send planned frames even when they will land past
    the deadline (a policy with no local fallback keeps the uplink busy;
    policies may declare this, e.g. ``server``).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.policy.registry import make_policy
from repro.policy.types import Env, Frame


@dataclass
class ReplayResult:
    results: np.ndarray  # final answer per frame (-1 = unanswered)
    offloaded: np.ndarray  # bool: reply landed within the deadline
    n_late: int  # planned transmissions that missed the deadline

    @property
    def n_offloaded(self) -> int:
        return int(self.offloaded.sum())

    def accuracy(self, labels) -> float:
        return float((self.results == np.asarray(labels)).mean())


def replay_trace(policy, *, conf, slow_pred, sizes, env: Env,
                 frame_interval: float, local_pred=None, local_time: float = 0.0,
                 replan_every: int = 1, window: int = 0,
                 transmit_late: bool | None = None) -> ReplayResult:
    """Replay a trace through ``policy`` (name or instance) under ``env``.

    ``conf``: (n,) per-frame confidence fed to the policy;
    ``slow_pred``: (m, n) server prediction per resolution index;
    ``sizes``: (m,) payload bytes per resolution (``env.acc_server`` is the
    policy's planning table, length m).
    """
    policy = make_policy(policy)
    if transmit_late is None:
        transmit_late = bool(getattr(policy, "transmit_late", False))
    conf = np.asarray(conf, dtype=np.float64)
    slow_pred = np.asarray(slow_pred)
    n = len(conf)
    gamma = float(frame_interval)
    sizes_t = tuple(float(s) for s in sizes)
    results = np.full(n, -1, dtype=np.int64)
    offloaded = np.zeros(n, dtype=bool)
    n_late = 0
    busy = 0.0

    def execute(plan) -> None:
        nonlocal busy, n_late
        for bi, r in plan.offloads:
            f = policy.backlog[bi]
            if f.fid < 0:
                raise ValueError(
                    "replay_trace planned a frame it never observed (fid "
                    "unset) — pass a policy with an empty backlog"
                )
            tx = f.sizes[r] / env.bandwidth
            t_land = max(busy, f.arrival) + tx + env.server_time + env.latency
            if t_land <= f.arrival + env.deadline:
                busy = max(busy, f.arrival) + tx
                results[f.fid] = slow_pred[r][f.fid]
                offloaded[f.fid] = True
            else:
                n_late += 1
                if transmit_late:
                    busy = max(busy, f.arrival) + tx

    if window:
        # offline: full-knowledge planning over fixed windows; the realized
        # uplink cursor still carries across windows
        for s in range(0, n, window):
            idx = range(s, min(s + window, n))
            policy.observe([Frame(i * gamma, float(conf[i]), sizes_t, fid=i) for i in idx])
            execute(policy.plan(max(busy, s * gamma), env))
            policy.consume(range(len(policy.backlog)))  # window closed
    else:
        for i in range(n):
            arr = i * gamma
            policy.observe([Frame(arr, float(conf[i]), sizes_t, fid=i)])
            if i % replan_every:
                continue
            plan = policy.plan(max(busy, arr), env)
            execute(plan)
            # planned frames left the device (landed or not) — never re-plan
            policy.consume(i for i, _ in plan.offloads)

    # local tier: frames that never landed a reply fall back to the local
    # answer — if the local tier kept up.  A busy local tier sheds the frame
    # (scored wrong); local_time=0 models the paper's instant NPU answers.
    if local_pred is not None:
        local_pred = np.asarray(local_pred)
        local_busy = 0.0
        for i in np.flatnonzero(~offloaded):
            arr = i * gamma
            if local_busy <= arr:
                results[i] = local_pred[i]
                local_busy = arr + local_time
    return ReplayResult(results=results, offloaded=offloaded, n_late=n_late)
