"""PolicyRunner: the glue between a pure decision policy and a live stream.

A policy decides; it does not measure.  The runner owns what deployment
measures — the EWMA bandwidth estimate and the static link/deadline
parameters — and materializes an ``Env`` snapshot for every ``plan`` call
(paper §IV-D deployment loop).  One runner per stream; heterogeneous
fleets get heterogeneous policies behind identical runners.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Iterable

from repro.policy.base import OffloadPolicy
from repro.policy.registry import make_policy
from repro.policy.types import Env, Frame, Plan


@dataclass
class BandwidthEstimator:
    alpha: float = 0.3
    estimate_bps: float = 1e6

    def observe(self, payload_bytes: float, seconds: float):
        if seconds > 1e-9:
            self.estimate_bps = (1 - self.alpha) * self.estimate_bps + self.alpha * (payload_bytes / seconds)


class PolicyRunner:
    """Drives one ``OffloadPolicy`` for one stream."""

    def __init__(self, policy, *, resolutions: tuple, acc_server: tuple,
                 deadline: float, latency: float, server_time: float,
                 size_of: Callable, bw: BandwidthEstimator | None = None):
        self.policy: OffloadPolicy = make_policy(policy)
        self.resolutions = tuple(resolutions)
        self.acc_server = tuple(acc_server)
        self.deadline = deadline
        self.latency = latency
        self.server_time = server_time
        self.size_of = size_of
        self.bw = bw if bw is not None else BandwidthEstimator()
        self._sizes = tuple(float(size_of(r)) for r in self.resolutions)

    @property
    def backlog(self) -> list[Frame]:
        return self.policy.backlog

    def env(self) -> Env:
        return Env(
            # floor at 1 byte/s: a dead link must plan "all local", not
            # divide by zero inside the DP
            bandwidth=max(self.bw.estimate_bps, 1.0),
            latency=self.latency,
            server_time=self.server_time,
            deadline=self.deadline,
            acc_server=self.acc_server,
        )

    def add_frame(self, arrival: float, conf: float):
        self.policy.observe([Frame(arrival, float(conf), self._sizes)])

    def plan(self, now: float) -> Plan:
        return self.policy.plan(now, self.env())

    def consume(self, frame_indices: Iterable[int]) -> int:
        return self.policy.consume(frame_indices)
