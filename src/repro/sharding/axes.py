"""Logical-axis sharding rules + activation constraint helper.

Models never name mesh axes directly; they call ``shard(x, *logical_axes)``.
A context-local rules table resolves logical -> mesh axes; outside a rules
context (unit tests on 1 device) ``shard`` is a no-op, so model code is
identical on a laptop and on a 512-chip mesh.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

_state = threading.local()


DEFAULT_RULES: dict[str, Optional[str]] = {
    # activations
    "batch": "data",
    "seq": None,  # sharded over "model" only in SP regions (explicit)
    "seq_sp": "model",
    "seq_res": None,  # residual-stream sequence sharding (Megatron-SP); train rules set 'model'
    "kv_seq": "model",  # decode KV cache sequence splits
    "embed": None,
    "heads_act": "model",
    "head_dim_act": None,  # kv-projection head_dim sharding (hillclimb: 'model')
    "mlp_act": "model",
    "vocab_act": "model",
    "experts_act": "model",
    "spatial": "data",  # diffusion gen small-batch spatial rows
    "streams": "data",  # serving fleet stream axis (policy/fleet_jax,
                        # serving/engine_jax): S=1e5+ fleets split across devices
    # params
    "layers": None,
    "stack": None,
    "vocab": "model",
    "embed_tbl": "model",  # token-embedding table: shard d_model, gather local
    "q_heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "kv_lora": None,
    "conv_in": None,
    "conv_out": "model",
    "classes": None,
    "ctx": None,
}


def multipod_rules() -> dict[str, Optional[str]]:
    """On the (pod, data, model) mesh, batch shards over (pod, data)."""
    r = dict(DEFAULT_RULES)
    r["batch"] = ("pod", "data")
    r["spatial"] = ("pod", "data")
    return r


@contextlib.contextmanager
def sharding_ctx(mesh: Optional[Mesh], rules: Optional[dict] = None):
    prev = getattr(_state, "ctx", None)
    if mesh is not None:
        rules = dict(rules or (multipod_rules() if "pod" in mesh.axis_names else DEFAULT_RULES))
        rules["_sizes"] = {name: size for name, size in zip(mesh.axis_names, mesh.devices.shape)}
    _state.ctx = (mesh, rules)
    try:
        yield
    finally:
        _state.ctx = prev


def current_rules() -> Optional[dict]:
    ctx = getattr(_state, "ctx", None)
    return ctx[1] if ctx else None


def current_mesh() -> Optional[Mesh]:
    ctx = getattr(_state, "ctx", None)
    return ctx[0] if ctx else None


def _resolve(rules, dim_size, ax, used):
    mesh_ax = rules.get(ax) if ax else None
    if mesh_ax is None:
        return None
    axes = mesh_ax if isinstance(mesh_ax, tuple) else (mesh_ax,)
    axes = tuple(a for a in axes if a not in used)
    if not axes:
        return None
    total = 1
    for a in axes:
        total *= rules["_sizes"].get(a, 1)
    if dim_size % total != 0:
        return None
    used.update(axes)
    return axes if len(axes) > 1 else axes[0]


def shard(x, *axes: Optional[str]):
    """Constrain activation sharding by logical axis names (no-op off-mesh)."""
    ctx = getattr(_state, "ctx", None)
    if not ctx or ctx[0] is None:
        return x
    mesh, rules = ctx
    if len(axes) != x.ndim:
        raise ValueError(f"shard(): got {len(axes)} axes for rank-{x.ndim} array")
    used: set = set()
    spec = [_resolve(rules, d, a, used) for d, a in zip(x.shape, axes)]
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, PartitionSpec(*spec)))


def logical_axis_multiple(name: str) -> int:
    """Device count a dimension must be a multiple of to shard over the
    logical axis ``name`` under the current rules context — the pad target
    callers round up to (``serving/engine.py`` pads the fleet's stream
    count to ``logical_axis_multiple("streams")``).  Returns 1 off-mesh or
    when the axis maps to no mesh axis, so padding degenerates to a no-op
    on a single device."""
    ctx = getattr(_state, "ctx", None)
    if not ctx or ctx[0] is None:
        return 1
    _, rules = ctx
    mesh_ax = rules.get(name)
    if mesh_ax is None:
        return 1
    axes = mesh_ax if isinstance(mesh_ax, tuple) else (mesh_ax,)
    total = 1
    for a in axes:
        total *= rules["_sizes"].get(a, 1)
    return total


def host_shard(x, *axes: Optional[str]):
    """``device_put`` a host array with the resolved sharding for its
    logical axes — the input-side companion to ``shard`` (which constrains
    traced values).  Placing the big (R, S, B) round inputs this way means
    the compiled step receives them already split across devices instead
    of broadcast-then-resharded.  No-op off-mesh."""
    ctx = getattr(_state, "ctx", None)
    if not ctx or ctx[0] is None:
        return x
    mesh, rules = ctx
    if len(axes) != x.ndim:
        raise ValueError(f"host_shard(): got {len(axes)} axes for rank-{x.ndim} array")
    used: set = set()
    spec = [_resolve(rules, d, a, used) for d, a in zip(x.shape, axes)]
    return jax.device_put(x, NamedSharding(mesh, PartitionSpec(*spec)))
