"""FSDP/ZeRO-3 sharding: extend model-parallel PartitionSpecs with the data
(and pod) axes on the largest still-unsharded divisible dimension.

Used for training params + optimizer states (arctic-480b does not fit
otherwise — DESIGN.md §5 napkin math) and optionally for big-model serving
weights.
"""
from __future__ import annotations

import numpy as np
from jax.sharding import PartitionSpec


def fsdp_spec(pspec: PartitionSpec, shape: tuple[int, ...], mesh) -> PartitionSpec:
    """Add ('data'[, 'pod']) to the best unsharded dim of one leaf."""
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    free = [a for a in ("pod", "data") if a in axes and not _used(pspec, a)]
    if not free:
        return pspec
    factor = int(np.prod([axes[a] for a in free]))
    entries = list(pspec) + [None] * (len(shape) - len(pspec))
    # largest unsharded dim divisible by the combined factor
    cand = [(d, i) for i, (d, e) in enumerate(zip(shape, entries)) if e is None and d % factor == 0 and d >= factor]
    if not cand:
        # try 'data' alone
        if "data" in free and len(free) > 1:
            factor = axes["data"]
            cand = [(d, i) for i, (d, e) in enumerate(zip(shape, entries)) if e is None and d % factor == 0]
            free = ["data"]
        if not cand:
            return pspec
    _, idx = max(cand)
    entries[idx] = tuple(free) if len(free) > 1 else free[0]
    return PartitionSpec(*entries)


def _used(pspec: PartitionSpec, axis: str) -> bool:
    for e in pspec:
        if e == axis or (isinstance(e, tuple) and axis in e):
            return True
    return False


def tree_fsdp(pspec_tree, struct_tree, mesh):
    import jax

    return jax.tree.map(lambda ps, st: fsdp_spec(ps, st.shape, mesh), pspec_tree, struct_tree)
