"""Dispatch wrapper: TPU -> Pallas flash kernel; elsewhere -> blockwise jnp
(the same oracle the model layer uses), so model code is backend-agnostic."""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention as _kernel
from repro.models.layers import attention_blockwise


def attention(q, k, v, *, causal: bool = True, use_kernel: str = "auto", **block_kw):
    if use_kernel == "pallas" or (use_kernel == "auto" and jax.default_backend() == "tpu"):
        return _kernel(q, k, v, causal=causal, interpret=jax.default_backend() != "tpu", **block_kw)
    return attention_blockwise(q, k, v, causal=causal, chunk=1024)
