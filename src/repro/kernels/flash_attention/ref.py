"""Pure-jnp oracle for causal flash attention (f32 softmax)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

F32 = jnp.float32


def attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """q,k,v: (B, S, H, D) -> (B, S, H, D). Full materialized reference."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(F32), k.astype(F32)) * scale
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(F32)).astype(q.dtype)
