"""Pallas TPU flash attention (prefill hot-spot).

Grid (B·H, Sq/bq, Sk/bk); the KV dimension is innermost/"arbitrary" and
carries the online-softmax state (m, l, acc) in VMEM scratch. Causal
blocks beyond the diagonal are skipped via @pl.when (the block-sparsity
that makes flash ~2× on causal prefill). Block sizes are MXU-aligned
(bq, bk multiples of 128; head dim padded by caller if needed).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale, causal, bq, bk, k_steps):
    iq, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if causal:  # skip blocks fully above the diagonal (flash block-sparsity)
        run = ik * bk <= iq * bq + bq - 1
    else:
        run = pl.program_id(2) >= 0  # always true (traced)

    @pl.when(run)
    def _block():
        q = q_ref[0].astype(F32)  # (bq, d)
        k = k_ref[0].astype(F32)  # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())), preferred_element_type=F32) * scale
        if causal:
            qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v_ref[0].astype(F32), (((1,), (0,)), ((), ())), preferred_element_type=F32
        )
        m_ref[...] = m_new

    @pl.when(ik == k_steps - 1)
    def _done():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, bq: int = 256, bk: int = 512, interpret: bool = False):
    """q,k,v: (B, S, H, D) -> (B, S, H, D)."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    bq, bk = min(bq, Sq), min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0
    scale = 1.0 / math.sqrt(D)
    k_steps = Sk // bk

    # (B,S,H,D) -> (B*H, S, D)
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kt = k.transpose(0, 2, 1, 3).reshape(B * H, Sk, D)
    vt = v.transpose(0, 2, 1, 3).reshape(B * H, Sk, D)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, bq=bq, bk=bk, k_steps=k_steps),
        grid=(B * H, Sq // bq, k_steps),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), F32),
            pltpu.VMEM((bq, 1), F32),
            pltpu.VMEM((bq, D), F32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
