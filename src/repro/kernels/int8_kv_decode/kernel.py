"""Pallas TPU kernel: flash-decode over an int8 KV cache, scales folded.

The qwen decode_32k hot-spot (EXPERIMENTS.md §Perf B): per step each chip
streams its 10.7 GB int8 KV shard once. The kernel reads int8 blocks
straight into VMEM, multiplies per-token scales into the scores/probs
(never materializing a floating-point cache copy), and carries the online
softmax over sequence blocks — the split-K structure matching the
sequence-sharded cache layout.

Grid (B, KH, S/bs); sequence innermost with (m, l, acc) VMEM carries.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG = -1e30


def _kernel(q_ref, kq_ref, ks_ref, vq_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref, *, scale, s_steps):
    js = pl.program_id(2)

    @pl.when(js == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(F32)  # (G, D)
    k = kq_ref[0].astype(F32)  # (bs, D) int8 -> f32 in VMEM only
    ks = ks_ref[0].astype(F32)  # (bs, 1)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())), preferred_element_type=F32)
    s = s * ks[:, 0][None, :] * scale  # fold per-token K scale, (G, bs)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
    vs = vs_ref[0].astype(F32)  # (bs, 1)
    pf = p * vs[:, 0][None, :]  # fold per-token V scale
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        pf, vq_ref[0].astype(F32), (((1,), (0,)), ((), ())), preferred_element_type=F32
    )
    m_ref[...] = m_new

    @pl.when(js == s_steps - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def int8_kv_decode(q, k_q, k_s, v_q, v_s, *, bs: int = 512, interpret: bool = False):
    """q (B,H,D); k_q/v_q (B,S,KH,D) int8; k_s/v_s (B,S) f32 -> (B,H,D)."""
    B, H, D = q.shape
    S, KH = k_q.shape[1], k_q.shape[2]
    G = H // KH
    bs = min(bs, S)
    assert S % bs == 0
    scale = 1.0 / math.sqrt(D)
    s_steps = S // bs

    qg = q.reshape(B, KH, G, D)
    # (B,S,KH,D) -> (B*KH, S, D)
    kt = k_q.transpose(0, 2, 1, 3).reshape(B * KH, S, D)
    vt = v_q.transpose(0, 2, 1, 3).reshape(B * KH, S, D)
    ks = jnp.repeat(k_s[:, None, :], KH, axis=1).reshape(B * KH, S, 1)
    vs = jnp.repeat(v_s[:, None, :], KH, axis=1).reshape(B * KH, S, 1)
    qx = qg.reshape(B * KH, 1, G, D)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, s_steps=s_steps),
        grid=(B, KH, s_steps),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, k, s: (b * KH + k, 0, 0, 0)),
            pl.BlockSpec((1, bs, D), lambda b, k, s: (b * KH + k, s, 0)),
            pl.BlockSpec((1, bs, 1), lambda b, k, s: (b * KH + k, s, 0)),
            pl.BlockSpec((1, bs, D), lambda b, k, s: (b * KH + k, s, 0)),
            pl.BlockSpec((1, bs, 1), lambda b, k, s: (b * KH + k, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, k, s: (b * KH + k, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KH, 1, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), F32),
            pltpu.VMEM((G, 1), F32),
            pltpu.VMEM((G, D), F32),
        ],
        interpret=interpret,
    )(qx, kt, ks, vt, vs)
    return out.reshape(B, KH, G, D).reshape(B, H, D)
