"""Oracle for int8-KV decode attention with per-token scale folding.

q: (B, H, D) bf16/f32 — one new token per sequence.
k_q/v_q: (B, S, KH, D) int8 ring caches; k_s/v_s: (B, S) f32 per-token scales.
GQA: H = KH * G. Scales fold into scores / probs — the cache is never
dequantized to a floating-point copy.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

F32 = jnp.float32


def decode_attention_ref(q, k_q, k_s, v_q, v_s):
    B, H, D = q.shape
    KH = k_q.shape[2]
    G = H // KH
    qg = q.reshape(B, KH, G, D).astype(F32)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k_q.astype(F32))
    scores = scores * k_s[:, None, None, :] / math.sqrt(D)
    probs = jax.nn.softmax(scores, axis=-1)
    probs_f = probs * v_s[:, None, None, :]
    out = jnp.einsum("bkgs,bskd->bkgd", probs_f, v_q.astype(F32))
    return out.reshape(B, H, D).astype(q.dtype)
