"""Dispatch wrapper for int8-KV decode attention."""
from __future__ import annotations

import jax

from repro.kernels.int8_kv_decode.kernel import int8_kv_decode as _kernel
from repro.kernels.int8_kv_decode.ref import decode_attention_ref


def decode_attention(q, k_q, k_s, v_q, v_s, *, use_kernel: str = "auto", **kw):
    if use_kernel == "pallas" or (use_kernel == "auto" and jax.default_backend() == "tpu"):
        return _kernel(q, k_q, k_s, v_q, v_s, interpret=jax.default_backend() != "tpu", **kw)
    return decode_attention_ref(q, k_q, k_s, v_q, v_s)
