"""Pure-jnp oracle for the W8A8 int8 matmul (per-row/per-col scales)."""
from __future__ import annotations

import jax.numpy as jnp

F32 = jnp.float32


def quantize_rows(x):
    """Per-row symmetric int8: returns (q (M,K) int8, scale (M,1) f32)."""
    amax = jnp.max(jnp.abs(x.astype(F32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(F32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def quantize_cols(w):
    """Per-column symmetric int8: returns (q (K,N) int8, scale (1,N) f32)."""
    amax = jnp.max(jnp.abs(w.astype(F32)), axis=0, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(w.astype(F32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_matmul_ref(x_q, x_scale, w_q, w_scale, out_dtype=jnp.float32):
    """(M,K)i8 × (K,N)i8 -> (M,N) with int32 accumulation, then dequant."""
    acc = jnp.dot(x_q.astype(jnp.int32), w_q.astype(jnp.int32), preferred_element_type=jnp.int32)
    return (acc.astype(F32) * x_scale.astype(F32) * w_scale.astype(F32)).astype(out_dtype)


def matmul_ref(x, w, out_dtype=jnp.float32):
    """End-to-end QDQ oracle: quantize fp inputs, int8 matmul, dequant."""
    xq, xs = quantize_rows(x)
    wq, ws = quantize_cols(w)
    return int8_matmul_ref(xq, xs, wq, ws, out_dtype)
