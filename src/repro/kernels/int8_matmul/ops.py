"""jit'd dispatch wrapper for the int8 matmul: TPU -> Pallas kernel,
CPU -> interpret (tests) or jnp reference (fast path for benchmarks)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.int8_matmul import ref
from repro.kernels.int8_matmul.kernel import int8_matmul as _kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def quantized_matmul(x, w, *, out_dtype=jnp.float32, use_kernel: str = "auto", **block_kw):
    """fp inputs -> quantize -> int8 GEMM -> dequant.

    use_kernel: "auto" (pallas on TPU, ref elsewhere) | "pallas" (interpret
    off-TPU; tests) | "ref".
    """
    xq, xs = ref.quantize_rows(x)
    wq, ws = ref.quantize_cols(w)
    if use_kernel == "ref" or (use_kernel == "auto" and not _on_tpu()):
        return ref.int8_matmul_ref(xq, xs, wq, ws, out_dtype)
    return _kernel(xq, xs, wq, ws, out_dtype=out_dtype, interpret=not _on_tpu(), **block_kw)


def quantized_dense_apply(qtensor, x, *, out_dtype=jnp.bfloat16, use_kernel: str = "auto"):
    """Apply a pre-quantized weight (quant.QTensor, per-out-channel scale) to
    activations: the serving fast-tier linear layer."""
    xq, xs = ref.quantize_rows(x.reshape(-1, x.shape[-1]))
    w_q = qtensor.values
    w_scale = qtensor.scale.reshape(1, -1) if qtensor.scale.ndim <= 2 else qtensor.scale
    if use_kernel == "ref" or (use_kernel == "auto" and not _on_tpu()):
        out = ref.int8_matmul_ref(xq, xs, w_q, w_scale, out_dtype)
    else:
        out = _kernel(xq, xs, w_q, w_scale, out_dtype=out_dtype, interpret=not _on_tpu())
    return out.reshape(x.shape[:-1] + (w_q.shape[-1],))
