"""Pallas TPU kernel: W8A8 int8 matmul with per-row/per-col dequant epilogue.

The fast-tier ("NPU") compute hot-spot: int8 × int8 -> int32 on the MXU
(2× bf16 throughput on v5e), fused dequantization on the final K step.

Grid (M/bm, N/bn, K/bk); K is the innermost ("arbitrary") dimension and
accumulates into an int32 VMEM scratch tile. Block sizes default to
MXU-aligned 256×256×512.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _kernel(x_ref, w_ref, xs_ref, ws_ref, o_ref, acc_ref, *, k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...],
        w_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == k_steps - 1)
    def _done():
        scaled = acc_ref[...].astype(F32) * xs_ref[...].astype(F32) * ws_ref[...].astype(F32)
        o_ref[...] = scaled.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype", "interpret"))
def int8_matmul(x_q, x_scale, w_q, w_scale, *, bm=256, bn=256, bk=512,
                out_dtype=jnp.float32, interpret=False):
    """x_q (M,K) int8, x_scale (M,1) f32, w_q (K,N) int8, w_scale (1,N) f32."""
    M, K = x_q.shape
    K2, N = w_q.shape
    assert K == K2, (x_q.shape, w_q.shape)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    k_steps = K // bk

    return pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps),
        grid=(M // bm, N // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, w_q, x_scale, w_scale)
