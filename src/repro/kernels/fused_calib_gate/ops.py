"""Dispatch wrapper for the fused calibrate+gate op."""
from __future__ import annotations

import jax

from repro.kernels.fused_calib_gate.kernel import calib_gate as _kernel
from repro.kernels.fused_calib_gate.ref import calib_gate_ref


def calibrated_gate(logits, a: float, b: float, theta: float, *, use_kernel: str = "auto"):
    """(B,V) logits -> (calibrated confidence (B,), offload gate (B,))."""
    if use_kernel == "pallas" or (use_kernel == "auto" and jax.default_backend() == "tpu"):
        return _kernel(logits, a, b, theta, interpret=jax.default_backend() != "tpu")
    return calib_gate_ref(logits, a, b, theta)
