"""Oracle for the fused confidence+calibration+gate op.

conf  = max softmax(logits)            (paper's confidence score)
calib = sigmoid(-(A*conf + B))         (Platt)
gate  = calib < theta                  (offload decision)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def calib_gate_ref(logits, a, b, theta):
    """logits (B, V) -> (calibrated_conf (B,), gate (B,) bool)."""
    conf = jnp.max(jax.nn.softmax(logits.astype(F32), axis=-1), axis=-1)
    calib = jax.nn.sigmoid(-(a * conf + b))
    return calib, calib < theta
