"""Pallas TPU kernel: fused softmax-max -> Platt -> threshold gate.

One pass over the vocab axis (102k-152k wide for the assigned LMs): running
(max, rescaled expsum) in VMEM scratch — max-softmax probability is
1/expsum once the row max has been absorbed, so the full softmax vector is
never materialized or written to HBM. Epilogue applies the Platt transform
and the threshold compare. Saves a (B,V) f32 round trip vs the naive path.

Grid (B/bb, V/bv), vocab innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG = -1e30


def _kernel(logits_ref, ab_ref, conf_ref, gate_ref, m_ref, s_ref, *, v_steps):
    jv = pl.program_id(1)

    @pl.when(jv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        s_ref[...] = jnp.zeros_like(s_ref)

    x = logits_ref[...].astype(F32)  # (bb, bv)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, x.max(-1, keepdims=True))
    s_ref[...] = s_ref[...] * jnp.exp(m_prev - m_new) + jnp.exp(x - m_new).sum(-1, keepdims=True)
    m_ref[...] = m_new

    @pl.when(jv == v_steps - 1)
    def _done():
        conf = 1.0 / jnp.maximum(s_ref[...], 1e-30)  # = exp(m-m)/Z = max prob
        a, b, theta = ab_ref[0, 0], ab_ref[0, 1], ab_ref[0, 2]
        calib = jax.nn.sigmoid(-(a * conf + b))
        conf_ref[...] = calib.astype(conf_ref.dtype)
        gate_ref[...] = (calib < theta).astype(gate_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bb", "bv", "interpret"))
def calib_gate(logits, a, b, theta, *, bb: int = 128, bv: int = 2048, interpret: bool = False):
    """logits (B, V) -> (calibrated conf (B,1) f32, gate (B,1) int8)."""
    B, V = logits.shape
    bb, bv = min(bb, B), min(bv, V)
    assert B % bb == 0 and V % bv == 0
    v_steps = V // bv
    ab = jnp.stack([jnp.asarray(a, F32), jnp.asarray(b, F32), jnp.asarray(theta, F32)]).reshape(1, 3)

    conf, gate = pl.pallas_call(
        functools.partial(_kernel, v_steps=v_steps),
        grid=(B // bb, v_steps),
        in_specs=[
            pl.BlockSpec((bb, bv), lambda i, j: (i, j)),
            pl.BlockSpec((1, 3), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), F32),
            jax.ShapeDtypeStruct((B, 1), jnp.int8),
        ],
        scratch_shapes=[pltpu.VMEM((bb, 1), F32), pltpu.VMEM((bb, 1), F32)],
        interpret=interpret,
    )(logits, ab)
    return conf[:, 0], gate[:, 0].astype(bool)
