"""Decoder-only transformer LM: GQA (+QKV bias), MLA (DeepSeek-V2), MoE.

Three entry points per the assigned shape kinds:
  lm_loss      — full-sequence causal LM loss (train_*)
  lm_prefill   — full-sequence forward -> (last-token logits, kv cache)
  lm_decode    — one-token step against a seq-sharded KV cache (decode_*)

Layer iteration: ``plan.analysis_unroll=True`` uses a python loop (exact
cost_analysis in the dry-run — XLA counts while-bodies once); otherwise
``lax.scan`` over stacked layer params (+ optional remat) for compile-time
and memory sanity at runtime.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.models import moe as moe_lib
from repro.models.layers import (
    F32,
    apply_mlp,
    apply_norm,
    apply_rope,
    attention_blockwise,
    attention_core,
    _expand_kv,
    mlp_spec,
    norm_spec,
    pad_heads,
)
from repro.models.ptree import ts
from repro.sharding.axes import shard


@dataclass(frozen=True)
class ParallelPlan:
    """Parallelism + analysis knobs, orthogonal to the arch config."""

    model_axis: int = 1
    data_axis: int = 1  # used by grouped MoE dispatch (hillclimb)
    attn_mode: str = "tp"  # tp | sp (sequence-parallel attention)
    pad_attention_heads: bool = True
    mla_absorb: bool = False  # absorbed MLA decode (beyond-paper opt)
    analysis_unroll: bool = False
    remat: bool = True
    attn_chunk: int = 0  # >0: blockwise attention for prefill/train
    kv_cache_dtype: str = "bf16"  # bf16 | int8 (quantized KV, beyond-paper opt)
    fused_unembed_loss: bool = False  # vocab-chunked softmax-xent (hillclimb)
    fuse_qkv: bool = False  # single stacked QKV projection (hillclimb; MHA only)
    moe_grouped_dispatch: bool = False  # per-data-shard MoE dispatch (hillclimb)
    kv_scale_fold: bool = False  # fold int8 KV scales into scores/probs (hillclimb)


def effective_heads(cfg: LMConfig, plan: ParallelPlan) -> tuple[int, int]:
    """(q_heads, kv_heads) after optional padding to the model axis."""
    if plan.attn_mode != "tp" or not plan.pad_attention_heads:
        return cfg.n_heads, cfg.n_kv_heads
    h = pad_heads(cfg.n_heads, plan.model_axis)
    kh = cfg.n_kv_heads
    if kh == cfg.n_heads:  # MHA: pad kv with q
        kh = h
    elif plan.model_axis % kh == 0 or kh % plan.model_axis == 0:
        pass  # divisible or replicated-by-rules
    return h, kh


# --------------------------------------------------------------------------- #
# Param specs
# --------------------------------------------------------------------------- #


def _attn_spec(cfg: LMConfig, plan: ParallelPlan) -> dict:
    d = cfg.d_model
    if cfg.use_mla:
        qk_head = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        spec = {
            "w_dkv": ts((d, "embed"), (cfg.kv_lora_rank + cfg.qk_rope_head_dim, "kv_lora")),
            "w_uk": ts((cfg.kv_lora_rank, "kv_lora"), (cfg.n_heads, "q_heads"), (cfg.qk_nope_head_dim, "head_dim")),
            "w_uv": ts((cfg.kv_lora_rank, "kv_lora"), (cfg.n_heads, "q_heads"), (cfg.v_head_dim, "head_dim")),
            "wo": ts((cfg.n_heads, "q_heads"), (cfg.v_head_dim, "head_dim"), (d, "embed")),
            "kv_norm": norm_spec(cfg.kv_lora_rank, "rmsnorm"),
        }
        if cfg.q_lora_rank:
            spec["w_dq"] = ts((d, "embed"), (cfg.q_lora_rank, "kv_lora"))
            spec["w_uq"] = ts((cfg.q_lora_rank, "kv_lora"), (cfg.n_heads, "q_heads"), (qk_head, "head_dim"))
            spec["q_norm"] = norm_spec(cfg.q_lora_rank, "rmsnorm")
        else:
            spec["wq"] = ts((d, "embed"), (cfg.n_heads, "q_heads"), (qk_head, "head_dim"))
        return spec
    h, kh = effective_heads(cfg, plan)
    if plan.fuse_qkv and kh == h:
        # single stacked projection: one residual all-gather, one MXU dot
        spec = {
            "wqkv": ts((3, "stack"), (d, "embed"), (h, "q_heads"), (cfg.d_head, "head_dim")),
            "wo": ts((h, "q_heads"), (cfg.d_head, "head_dim"), (d, "embed"), init="fan_in", fan_in=h * cfg.d_head),
        }
        if cfg.qkv_bias:
            spec["bqkv"] = ts((3, "stack"), (h, "q_heads"), (cfg.d_head, "head_dim"), init="zeros")
        return spec
    spec = {
        "wq": ts((d, "embed"), (h, "q_heads"), (cfg.d_head, "head_dim")),
        "wk": ts((d, "embed"), (kh, "kv_heads"), (cfg.d_head, "head_dim")),
        "wv": ts((d, "embed"), (kh, "kv_heads"), (cfg.d_head, "head_dim")),
        "wo": ts((h, "q_heads"), (cfg.d_head, "head_dim"), (d, "embed"), init="fan_in", fan_in=h * cfg.d_head),
    }
    if cfg.qkv_bias:
        spec["bq"] = ts((h, "q_heads"), (cfg.d_head, "head_dim"), init="zeros")
        spec["bk"] = ts((kh, "kv_heads"), (cfg.d_head, "head_dim"), init="zeros")
        spec["bv"] = ts((kh, "kv_heads"), (cfg.d_head, "head_dim"), init="zeros")
    return spec


def _layer_spec(cfg: LMConfig, plan: ParallelPlan, layer_idx: int) -> dict:
    spec = {
        "ln1": norm_spec(cfg.d_model, cfg.norm),
        "attn": _attn_spec(cfg, plan),
        "ln2": norm_spec(cfg.d_model, cfg.norm),
    }
    if cfg.moe is not None and layer_idx >= cfg.moe.first_k_dense:
        spec["moe"] = moe_lib.moe_spec(cfg.d_model, cfg.moe, cfg.ffn_act)
    else:
        ff = (cfg.moe.first_dense_ff or cfg.d_ff) if cfg.moe is not None else cfg.d_ff
        spec["mlp"] = mlp_spec(cfg.d_model, ff, cfg.ffn_act)
    return spec


def _stack_specs(specs: list) -> dict:
    """Stack homogeneous per-layer spec trees along a leading 'layers' dim."""
    import jax.tree_util as jtu
    from repro.models.ptree import TensorSpec

    def stack(*leaves: TensorSpec):
        l0 = leaves[0]
        return TensorSpec(
            (len(leaves),) + l0.shape,
            ("layers",) + l0.axes,
            dtype=l0.dtype,
            init=l0.init,
            init_scale=l0.init_scale,
            fan_in=l0.fan_in or (int(np.prod(l0.shape[:-1])) if len(l0.shape) > 1 else l0.shape[0]),
        )

    return jax.tree.map(stack, *specs, is_leaf=lambda x: isinstance(x, TensorSpec))


def lm_param_spec(cfg: LMConfig, plan: ParallelPlan) -> dict:
    per_layer = [_layer_spec(cfg, plan, i) for i in range(cfg.n_layers)]
    if cfg.moe is not None and cfg.moe.first_k_dense:
        k = cfg.moe.first_k_dense
        layers = {"dense": _stack_specs(per_layer[:k]), "moe": _stack_specs(per_layer[k:])}
    else:
        layers = {"all": _stack_specs(per_layer)}
    spec = {
        # table sharded on d_model (not vocab): the token gather is then
        # shard-local; vocab-sharding would make GSPMD replicate the full
        # f32 table per chip (measured 3 x 2 GB in the buffer dump).
        "embed": ts((cfg.vocab_size, None), (cfg.d_model, "embed_tbl"), scale=1.0, fan_in=cfg.d_model),
        "layers": layers,
        "final_norm": norm_spec(cfg.d_model, cfg.norm),
    }
    if not cfg.tie_embeddings:
        spec["unembed"] = ts((cfg.d_model, "embed"), (cfg.vocab_size, "vocab"))
    return spec


# --------------------------------------------------------------------------- #
# Attention application
# --------------------------------------------------------------------------- #


def _gqa_qkv(p, x, cfg: LMConfig, positions):
    if "wqkv" in p:
        qkv = jnp.einsum("bsd,cdhk->cbshk", x, p["wqkv"])
        if "bqkv" in p:
            qkv = qkv + p["bqkv"][:, None, None]
        q, k, v = qkv[0], qkv[1], qkv[2]
        rot = int(cfg.d_head * cfg.rope_pct)
        q = apply_rope(q, positions, cfg.rope_theta, rot)
        k = apply_rope(k, positions, cfg.rope_theta, rot)
        return q, k, v
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    # heads_act shards divisible head counts; head_dim_act (hillclimb rule)
    # shards the kv projection over head_dim when kv_heads < model axis,
    # avoiding a replicated kv matmul on every model shard.
    q = shard(q, "batch", None, "heads_act", "head_dim_act")
    k = shard(k, "batch", None, "heads_act", "head_dim_act")
    v = shard(v, "batch", None, "heads_act", "head_dim_act")
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    rot = int(cfg.d_head * cfg.rope_pct)
    q = apply_rope(q, positions, cfg.rope_theta, rot)
    k = apply_rope(k, positions, cfg.rope_theta, rot)
    return q, k, v


def _mla_qkv(p, x, cfg: LMConfig, positions):
    """Returns q (nope+rope), latent cache pieces, and expanded k/v."""
    if cfg.q_lora_rank:
        cq = apply_norm(p["q_norm"], jnp.einsum("bsd,dr->bsr", x, p["w_dq"]), "rmsnorm")
        q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = q[..., : cfg.qk_nope_head_dim], q[..., cfg.qk_nope_head_dim :]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta, cfg.qk_rope_head_dim)

    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])
    ckv, k_rope = ckv_full[..., : cfg.kv_lora_rank], ckv_full[..., cfg.kv_lora_rank :]
    ckv = apply_norm(p["kv_norm"], ckv, "rmsnorm")
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta, cfg.qk_rope_head_dim)[:, :, 0, :]
    return q_nope, q_rope, ckv, k_rope


def _mla_expand(p, ckv, k_rope, n_heads):
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv, p["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", ckv, p["w_uv"])
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :], k_nope.shape[:2] + (n_heads, k_rope.shape[-1]))
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    return k, v


def _self_attention(p, x, cfg: LMConfig, plan: ParallelPlan, positions, kind: str):
    """Full-sequence causal self-attention (train / prefill). Returns
    (attn_out_pre_wo @ wo, cache_pieces)."""
    B, S, _ = x.shape
    if cfg.use_mla:
        q_nope, q_rope, ckv, k_rope = _mla_qkv(p, x, cfg, positions)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k, v = _mla_expand(p, ckv, k_rope, cfg.n_heads)
        scale = 1.0 / np.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
        cache = {"ckv": ckv, "k_rope": k_rope}
    else:
        q, k, v = _gqa_qkv(p, x, cfg, positions)
        k_e, v_e = _expand_kv(k, q.shape[2]), _expand_kv(v, q.shape[2])
        scale = None
        cache = {"k": k, "v": v}
        k, v = k_e, v_e
    if plan.attn_chunk and S > 2 * plan.attn_chunk:
        out = attention_blockwise(
            q, k, v, causal=True, chunk=plan.attn_chunk,
            unroll=plan.analysis_unroll, sp=(plan.attn_mode == "sp"),
        )
    else:
        out = attention_core(q, k, v, causal=True, softmax_scale=scale, mode=plan.attn_mode)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache


def _quantize_slot(x):
    """Per-token int8 quantization of one new cache entry (B,1,...)."""
    amax = jnp.max(jnp.abs(x.astype(F32)), axis=tuple(range(2, x.ndim)), keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(F32) / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def _cache_write(cache, name, new_bf16, idx, *, layer=None):
    """Write one token's K/V at ``idx``. With ``layer`` given, the write goes
    directly into the *stacked* cache (a slot-sized dynamic_update_slice —
    alias-friendly under donation; full-slice write-backs defeat XLA's
    in-place buffer reuse, measured +50 GiB on qwen decode)."""
    def dus(buf, upd, ix):
        if layer is not None:
            return jax.lax.dynamic_update_slice(buf, upd[None], (layer,) + ix)
        return jax.lax.dynamic_update_slice(buf, upd, ix)

    if name + "_scale" in cache:
        q, s = _quantize_slot(new_bf16)
        c = dus(cache[name], q, idx)
        sc = dus(cache[name + "_scale"], s, idx)
        return {name: c, name + "_scale": sc}
    c = dus(cache[name], new_bf16.astype(cache[name].dtype), idx)
    return {name: c}


def _cache_read(cache_l, name):
    """bf16 view of one cache leaf (dequantize if int8)."""
    x = cache_l[name]
    if name + "_scale" in cache_l:
        s = cache_l[name + "_scale"].astype(F32)
        s = s.reshape(s.shape + (1,) * (x.ndim - s.ndim))
        return (x.astype(F32) * s).astype(jnp.bfloat16)
    return x.astype(jnp.bfloat16)


def _gqa_decode_attention(q, k, v):
    """Grouped GQA decode attention without expanding K/V to q-heads:
    q (B,1,H,D), k/v (B,S,KH,D) -> (B,1,H,D). Softmax over the (possibly
    seq-sharded) cache axis in f32."""
    B, T, H, Dh = q.shape
    KH = k.shape[2]
    G = H // KH
    qg = q.reshape(B, T, KH, G, Dh)
    scores = jnp.einsum("btkgd,bskd->bkgts", qg, k).astype(F32) / np.sqrt(Dh)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgts,bskd->btkgd", probs, v)
    return out.reshape(B, T, H, Dh)


def _decode_attention(p, x, cfg: LMConfig, plan: ParallelPlan, cache, pos: int, layer: int):
    """One-token attention against a fixed-length cache (len S, ring slot
    ``pos % S``). ``cache`` is the full stacked (possibly int8+scale) cache;
    this layer's slot is written in place, then its slice is read.
    Returns (out, updated stacked cache)."""
    B = x.shape[0]
    positions = jnp.full((1,), pos, jnp.int32)

    def read(c, name):
        sl = {k: jax.lax.index_in_dim(v, layer, 0, keepdims=False) for k, v in c.items() if k in (name, name + "_scale")}
        return _cache_read(sl, name)

    if cfg.use_mla:
        q_nope, q_rope, ckv_new, k_rope_new = _mla_qkv(p, x, cfg, positions)
        S = cache["ckv"].shape[2]
        slot = pos % S
        new_cache = dict(cache)
        new_cache.update(_cache_write(cache, "ckv", ckv_new, (0, slot, 0), layer=layer))
        new_cache.update(_cache_write(new_cache, "k_rope", k_rope_new, (0, slot, 0), layer=layer))
        ckv = shard(read(new_cache, "ckv"), "batch", "kv_seq", None)
        k_rope = shard(read(new_cache, "k_rope"), "batch", "kv_seq", None)
        scale = 1.0 / np.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
        if plan.mla_absorb:
            # Absorbed decode: score in latent space — never expand K/V to heads.
            q_lat = jnp.einsum("bthk,rhk->bthr", q_nope, p["w_uk"])  # (B,1,H,r)
            s_lat = jnp.einsum("bthr,bsr->bths", q_lat, ckv.astype(q_lat.dtype))
            s_rope = jnp.einsum("bthk,bsk->bths", q_rope, k_rope.astype(q_rope.dtype))
            scores = (s_lat + s_rope).astype(F32) * scale
            probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
            o_lat = jnp.einsum("bths,bsr->bthr", probs, ckv.astype(probs.dtype))
            out = jnp.einsum("bthr,rhk->bthk", o_lat, p["w_uv"])
        else:
            k, v = _mla_expand(p, ckv.astype(x.dtype), k_rope.astype(x.dtype), cfg.n_heads)
            q = jnp.concatenate([q_nope, q_rope], axis=-1)
            out = attention_core(q, k, v, causal=False, softmax_scale=scale, mode="decode")
    else:
        q, k_new, v_new = _gqa_qkv(p, x, cfg, positions)
        S = cache["k"].shape[2]
        slot = pos % S
        new_cache = dict(cache)
        new_cache.update(_cache_write(cache, "k", k_new, (0, slot, 0, 0), layer=layer))
        new_cache.update(_cache_write(new_cache, "v", v_new, (0, slot, 0, 0), layer=layer))
        if plan.kv_scale_fold and "k_scale" in new_cache:
            # fold per-token int8 scales into scores/probs: the cache is cast
            # int8->bf16 once, never materialized in f32 (hillclimb; §Perf).
            H, Dh = q.shape[2], q.shape[3]
            kq = shard(new_cache["k"][layer], "batch", "kv_seq", None, None)
            vq = shard(new_cache["v"][layer], "batch", "kv_seq", None, None)
            ks = new_cache["k_scale"][layer][:, :, 0, 0].astype(F32)  # (B, S)
            vs = new_cache["v_scale"][layer][:, :, 0, 0].astype(F32)
            kq_e = _expand_kv(kq.astype(x.dtype), H)
            vq_e = _expand_kv(vq.astype(x.dtype), H)
            scores = jnp.einsum("bqhd,bshd->bhqs", q, kq_e).astype(F32)
            scores = scores * ks[:, None, None, :] / np.sqrt(Dh)
            probs = jax.nn.softmax(scores, axis=-1)
            probs_f = (probs * vs[:, None, None, :]).astype(x.dtype)
            out = jnp.einsum("bhqs,bshd->bqhd", probs_f, vq_e)
        else:
            # grouped GQA attention: never materializes K/V at q-head width
            # (expanding 8 kv heads to 56 cost stablelm/arctic decode ~10x
            # their cache size in temps — dry-run buffer dumps).
            k_s = shard(read(new_cache, "k"), "batch", "kv_seq", None, None).astype(x.dtype)
            v_s = shard(read(new_cache, "v"), "batch", "kv_seq", None, None).astype(x.dtype)
            q = shard(q, "batch", None, None, None)
            out = _gqa_decode_attention(q, k_s, v_s)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache


# --------------------------------------------------------------------------- #
# Layer body + iteration
# --------------------------------------------------------------------------- #


def _layer_fwd(p, x, cfg, plan, positions, kind):
    h = apply_norm(p["ln1"], x, cfg.norm)
    attn_out, cache = _self_attention(p["attn"], h, cfg, plan, positions, kind)
    x = x + attn_out
    h = apply_norm(p["ln2"], x, cfg.norm)
    if "moe" in p:
        groups = plan.data_axis if plan.moe_grouped_dispatch else 1
        ff, aux = moe_lib.apply_moe(p["moe"], h, cfg.moe, cfg.ffn_act, groups=groups)
    else:
        ff, aux = apply_mlp(p["mlp"], h, cfg.ffn_act), jnp.zeros((), F32)
    x = x + ff
    x = shard(x, "batch", "seq_res", None)  # Megatron-SP residual stream
    return x, aux, cache


def _iterate_layers(params, x, cfg: LMConfig, plan: ParallelPlan, positions, kind: str, collect_cache: bool):
    """Run all layers; returns (x, total_aux, caches list-or-None)."""
    groups = params["layers"]
    total_aux = jnp.zeros((), F32)
    caches = []

    def run_group(x, total_aux, stacked, n):
        nonlocal caches
        body = lambda p, x: _layer_fwd(p, x, cfg, plan, positions, kind)
        if plan.analysis_unroll:
            for i in range(n):
                p_i = jax.tree.map(lambda a: a[i], stacked)
                fn = jax.checkpoint(body) if (plan.remat and kind == "train") else body
                x, aux, cache = fn(p_i, x)
                total_aux = total_aux + aux
                if collect_cache:
                    caches.append(cache)
        else:
            def scan_body(carry, p_i):
                x, acc = carry
                fn = jax.checkpoint(body) if (plan.remat and kind == "train") else body
                x, aux, cache = fn(p_i, x)
                return (x, acc + aux), (cache if collect_cache else ())
            (x, total_aux), ys = jax.lax.scan(scan_body, (x, total_aux), stacked)
            if collect_cache:
                caches.append(ys)  # already stacked (n, ...) along dim 0
        return x, total_aux

    if "dense" in groups:
        kd = groups["dense"]["ln1"]["scale"].shape[0]
        x, total_aux = run_group(x, total_aux, groups["dense"], kd)
        x, total_aux = run_group(x, total_aux, groups["moe"], cfg.n_layers - kd)
    else:
        x, total_aux = run_group(x, total_aux, groups["all"], cfg.n_layers)
    return x, total_aux, (caches if collect_cache else None)


# --------------------------------------------------------------------------- #
# Entry points
# --------------------------------------------------------------------------- #


def _embed(params, tokens, cfg):
    x = jnp.take(params["embed"], tokens, axis=0)
    return shard(x, "batch", "seq_res", None)


def _unembed(params, x, cfg):
    table = params.get("unembed")
    if table is None:
        table = params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", x, table.astype(x.dtype))
    return shard(logits, "batch", None, "vocab_act")


def lm_hidden(params, tokens, cfg: LMConfig, plan: ParallelPlan, *, final_norm: bool = True):
    """(B,S) -> final hidden states (B,S,D) + MoE aux loss."""
    B, S = tokens.shape
    positions = jnp.arange(S)
    x = _embed(params, tokens, cfg)
    x, aux, _ = _iterate_layers(params, x, cfg, plan, positions, "train", collect_cache=False)
    if final_norm:
        x = apply_norm(params["final_norm"], x, cfg.norm)
    return x, aux


def lm_forward(params, tokens, cfg: LMConfig, plan: ParallelPlan):
    """(B,S) int32 -> (B,S,V) logits (bf16, vocab-sharded)."""
    x, aux = lm_hidden(params, tokens, cfg, plan)
    return _unembed(params, x, cfg), aux


def _xent_chunk(params, x_c, labels_c, cfg):
    x_c = apply_norm(params["final_norm"], x_c, cfg.norm)  # f32 temps stay chunk-local
    logits = _unembed(params, x_c, cfg).astype(F32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    # gold logit via fused iota-compare mask: shard-local over the vocab axis
    # (take_along_axis on a vocab-sharded tensor would replicate full logits).
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_iota == labels_c[..., None], logits, 0.0), axis=-1)
    return jnp.sum(lse - gold)


def lm_loss(params, batch, cfg: LMConfig, plan: ParallelPlan):
    """batch = {tokens (B,S), labels (B,S)}; mean xent + MoE aux.

    The unembed+softmax runs in sequence chunks under jax.checkpoint: full
    (B,S,V) f32 logits never materialize (26 GB/chip for qwen otherwise).
    Python loop, so dry-run cost analysis stays exact.
    """
    x, aux = lm_hidden(params, batch["tokens"], cfg, plan, final_norm=False)
    B, S, _ = x.shape
    n_chunks = max(S // 2048, 1) if S >= 4096 else 1
    cs = S // n_chunks
    total = jnp.zeros((), F32)
    for i in range(n_chunks):
        x_c = x[:, i * cs : (i + 1) * cs]
        l_c = batch["labels"][:, i * cs : (i + 1) * cs]
        total = total + jax.checkpoint(_xent_chunk, static_argnums=(3,))(params, x_c, l_c, cfg)
    return total / (B * S) + aux


def lm_prefill(params, tokens, cfg: LMConfig, plan: ParallelPlan):
    """(B,S) -> (last-token logits (B,V), stacked KV cache)."""
    B, S = tokens.shape
    positions = jnp.arange(S)
    x = _embed(params, tokens, cfg)
    x, _, caches = _iterate_layers(params, x, cfg, plan, positions, "prefill", collect_cache=True)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = _unembed(params, x[:, -1:, :], cfg)[:, 0]
    if plan.analysis_unroll:
        cache = jax.tree.map(lambda *ls: jnp.stack(ls), *caches)
    else:
        # scan path: one pre-stacked tree per layer group; concat groups
        cache = caches[0] if len(caches) == 1 else jax.tree.map(
            lambda *gs: jnp.concatenate(gs, axis=0), *caches
        )
    cache = _shard_cache(_quantize_cache(cache, plan), cfg)
    return logits, cache


def cache_spec(cfg: LMConfig, plan: ParallelPlan, batch: int, seq: int) -> dict:
    """ShapeDtypeStructs for a decode KV cache of length ``seq``."""
    dt = jnp.int8 if plan.kv_cache_dtype == "int8" else jnp.bfloat16
    L = cfg.n_layers
    if cfg.use_mla:
        out = {
            "ckv": jax.ShapeDtypeStruct((L, batch, seq, cfg.kv_lora_rank), dt),
            "k_rope": jax.ShapeDtypeStruct((L, batch, seq, cfg.qk_rope_head_dim), dt),
        }
    else:
        _, kh = effective_heads(cfg, plan)
        out = {
            "k": jax.ShapeDtypeStruct((L, batch, seq, kh, cfg.d_head), dt),
            "v": jax.ShapeDtypeStruct((L, batch, seq, kh, cfg.d_head), dt),
        }
    if plan.kv_cache_dtype == "int8":
        for name in list(out):
            s = out[name].shape
            out[name + "_scale"] = jax.ShapeDtypeStruct(s[:3] + (1,) * (len(s) - 3), jnp.bfloat16)
    return out


def _quantize_cache(cache, plan):
    if plan.kv_cache_dtype != "int8":
        return cache
    out = {}
    for name, x in cache.items():
        amax = jnp.max(jnp.abs(x.astype(F32)), axis=tuple(range(3, x.ndim)), keepdims=True)
        scale = jnp.maximum(amax, 1e-6) / 127.0
        out[name] = jnp.clip(jnp.round(x.astype(F32) / scale), -127, 127).astype(jnp.int8)
        out[name + "_scale"] = scale.astype(jnp.bfloat16)
    return out


def _dequantize_cache(cache):
    if not any(k.endswith("_scale") for k in cache):
        return cache
    return {
        k: (cache[k].astype(F32) * cache[k + "_scale"].astype(F32)).astype(jnp.bfloat16)
        for k in cache
        if not k.endswith("_scale")
    }


def _shard_cache(cache, cfg):
    def s(name, x):
        if x.ndim == 4:  # (L,B,S,r)
            return shard(x, None, "batch", "kv_seq", None)
        return shard(x, None, "batch", "kv_seq", None, None)
    return {k: s(k, v) for k, v in cache.items()}


def lm_decode(params, cache, token, pos, cfg: LMConfig, plan: ParallelPlan):
    """One decode step. token: (B,) int32, pos: python int (static slot).

    cache leaves are stacked over layers (dim0). int8 caches are dequantized
    per layer on the fly (scales kept alongside); the new token's K/V is
    written back in the cache dtype.
    """
    B = token.shape[0]
    x = _embed(params, token[:, None], cfg)

    groups = params["layers"]
    stacked_list = []
    if "dense" in groups:
        kd = groups["dense"]["ln1"]["scale"].shape[0]
        for i in range(kd):
            stacked_list.append(jax.tree.map(lambda a: a[i], groups["dense"]))
        for i in range(cfg.n_layers - kd):
            stacked_list.append(jax.tree.map(lambda a: a[i], groups["moe"]))
    else:
        for i in range(cfg.n_layers):
            stacked_list.append(jax.tree.map(lambda a: a[i], groups["all"]))

    # in-place stacked-cache updates: each layer writes only the new token's
    # slot into the donated stacked cache (slot-sized dynamic_update_slice),
    # then reads its own slice — no per-layer restack copies.
    for i, p_l in enumerate(stacked_list):
        h = apply_norm(p_l["ln1"], x, cfg.norm)
        attn_out, cache = _decode_attention(p_l["attn"], h, cfg, plan, cache, pos, i)
        x = x + attn_out
        h = apply_norm(p_l["ln2"], x, cfg.norm)
        if "moe" in p_l:
            ff, _ = moe_lib.apply_moe(p_l["moe"], h, cfg.moe, cfg.ffn_act)
        else:
            ff = apply_mlp(p_l["mlp"], h, cfg.ffn_act)
        x = x + ff

    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = _unembed(params, x, cfg)[:, 0]
    cache = {k: shard(v, *((None,) + ("batch", "kv_seq") + (None,) * (v.ndim - 3))) for k, v in cache.items()}
    return logits, cache
