"""Mixture-of-Experts FFN: GShard-style capacity dispatch, sort-based (no giant
one-hots), expert-parallel over the `model` mesh axis.

Two dispatch modes (ParallelPlan.moe_grouped_dispatch):
  * global (G=1, baseline): one sort/scatter over all tokens. Simple, but the
    global scatter forces GSPMD to all-reduce full dispatch buffers
    (measured: 139 GB + 64 GB per arctic layer — EXPERIMENTS.md §Perf).
  * grouped (G=data shards, hillclimb): dispatch independently per data-shard
    group; sort/gather/scatter are shard-local and the only cross-shard
    movement is the token<->expert exchange (an all-to-all over `model`).

Supports DeepSeek-style shared experts and Arctic-style parallel dense
residual FFN. Returns (output, aux_load_balance_loss).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import apply_mlp, mlp_spec
from repro.models.ptree import ts
from repro.sharding.axes import shard

F32 = jnp.float32


def moe_spec(d: int, cfg: MoEConfig, act: str) -> dict:
    e, f = cfg.n_routed, cfg.d_ff_expert
    spec = {
        "router": ts((d, "embed"), (e, "experts"), dtype=F32),
        "wg": ts((e, "experts"), (d, "embed"), (f, "mlp")),
        "wu": ts((e, "experts"), (d, "embed"), (f, "mlp")),
        "wd": ts((e, "experts"), (f, "mlp"), (d, "embed")),
    }
    if act != "swiglu":
        spec = {
            "router": spec["router"],
            "wi": ts((e, "experts"), (d, "embed"), (f, "mlp")),
            "wo": ts((e, "experts"), (f, "mlp"), (d, "embed")),
        }
    if cfg.n_shared:
        spec["shared"] = mlp_spec(d, cfg.d_ff_expert * cfg.n_shared, act)
    if cfg.dense_residual_ff:
        spec["dense"] = mlp_spec(d, cfg.dense_residual_ff, act)
    return spec


def capacity_for(n_tokens: int, cfg: MoEConfig) -> int:
    c = math.ceil(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_routed)
    return max(8, ((c + 7) // 8) * 8)  # pad to 8 for TPU lane alignment


def apply_moe(p: dict, x, cfg: MoEConfig, act: str, *, groups: int = 1):
    """x: (B, S, D) -> (out, aux_loss)."""
    B, S, D = x.shape
    G = groups if (groups > 1 and B % groups == 0) else 1
    xf = x.reshape(G, B * S // G, D)
    xf = shard(xf, "batch", None, None)
    out, aux = _moe_tokens(p, xf, cfg, act)
    out = shard(out.reshape(B, S, D), "batch", None, None)
    if "shared" in p:
        out = out + apply_mlp(p["shared"], x, act)
    if "dense" in p:
        out = out + apply_mlp(p["dense"], x, act)
    return out, aux


def _moe_tokens(p: dict, xf, cfg: MoEConfig, act: str):
    """Batched dispatch+compute. xf: (G, T, D) -> ((G, T, D), aux)."""
    G, T, D = xf.shape
    E, K = cfg.n_routed, cfg.top_k
    C = capacity_for(T, cfg)
    g_idx = jnp.arange(G)[:, None]

    logits = jnp.einsum("gtd,de->gte", xf.astype(F32), p["router"])
    gates = jax.nn.softmax(logits, axis=-1)  # (G, T, E)
    top_v, top_i = jax.lax.top_k(gates, K)  # (G, T, K)
    top_v = top_v / jnp.maximum(top_v.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance aux loss (Switch/GShard form) ----
    me = gates.mean((0, 1))
    ce = jnp.zeros((E,), F32).at[top_i.reshape(-1)].add(1.0) / (G * T * K)
    aux = cfg.aux_loss_coef * E * jnp.sum(me * ce)

    # ---- sort-based capacity dispatch (per group, GATHER-only) ----
    # Scatter-based dispatch makes GSPMD all-reduce the full dispatch buffer
    # ("involuntary full rematerialization"); building the buffer with
    # take_along_axis gathers avoids that entirely (EXPERIMENTS.md §Perf).
    flat_e = top_i.reshape(G, T * K)
    sort_idx = jnp.argsort(flat_e, axis=-1)  # slots grouped by expert
    sorted_e = jnp.take_along_axis(flat_e, sort_idx, axis=-1)
    first_occ = jax.vmap(lambda a: jnp.searchsorted(a, a, side="left"))(sorted_e)
    pos_in_e = jnp.arange(T * K)[None] - first_occ  # rank within expert
    valid = pos_in_e < C
    slot = jnp.where(valid, sorted_e * C + pos_in_e, E * C)  # E*C == drop bin
    token_of = sort_idx // K

    # slot -> sorted position: group start + offset within capacity
    starts = jax.vmap(lambda a: jnp.searchsorted(a, jnp.arange(E), side="left"))(sorted_e)  # (G, E)
    ends = jax.vmap(lambda a: jnp.searchsorted(a, jnp.arange(E), side="right"))(sorted_e)
    cand = starts[:, :, None] + jnp.arange(C)[None, None]  # (G, E, C) sorted positions
    slot_valid = cand < ends[:, :, None]
    cand_flat = jnp.clip(cand.reshape(G, E * C), 0, T * K - 1)
    tok_for_slot = jnp.take_along_axis(token_of, cand_flat, axis=-1)  # (G, E*C)
    buf = jnp.take_along_axis(xf, tok_for_slot[..., None], axis=1)  # gather, no scatter
    buf = jnp.where(slot_valid.reshape(G, E * C)[..., None], buf, 0)
    buf = buf.reshape(G, E, C, D)
    buf = shard(buf, "batch", "experts_act", None, None)

    # ---- grouped expert FFN ----
    if "wg" in p:
        g = jnp.einsum("gecd,edf->gecf", buf, p["wg"])
        u = jnp.einsum("gecd,edf->gecf", buf, p["wu"])
        g = shard(g, "batch", "experts_act", None, "mlp_act")
        h = jax.nn.silu(g.astype(F32)).astype(xf.dtype) * u
        out_buf = jnp.einsum("gecf,efd->gecd", h, p["wd"])
    else:
        h = jnp.einsum("gecd,edf->gecf", buf, p["wi"])
        h = shard(h, "batch", "experts_act", None, "mlp_act")
        h = jax.nn.gelu(h.astype(F32), approximate=True).astype(xf.dtype)
        out_buf = jnp.einsum("gecf,efd->gecd", h, p["wo"])
    out_buf = shard(out_buf, "batch", "experts_act", None, None)
    out_flat = out_buf.reshape(G, E * C, D)
    out_flat = jnp.concatenate([out_flat, jnp.zeros((G, 1, D), xf.dtype)], axis=1)  # drop bin

    # ---- combine (gather-only: invert the sort permutation) ----
    inv_sort = jnp.argsort(sort_idx, axis=-1)
    slot_sorted = jnp.where(valid, slot, E * C).astype(jnp.int32)
    slot_unsorted = jnp.take_along_axis(slot_sorted, inv_sort, axis=-1)
    vals = jnp.take_along_axis(out_flat, slot_unsorted[..., None], axis=1).reshape(G, T, K, D)
    out = jnp.einsum("gtkd,gtk->gtd", vals.astype(F32), top_v).astype(xf.dtype)
    return out, aux
