"""Unified model API: param specs, forward/loss/step functions, input specs.

Everything the launcher needs for any assigned architecture:

  build(arch, which)            -> ModelHandle (param spec + fns)
  input_specs(arch, shape, ...) -> ShapeDtypeStruct stand-ins for the cell
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (
    ArchSpec,
    DiTConfig,
    LMConfig,
    ResNetConfig,
    ShapeSpec,
    SwinConfig,
    UNetConfig,
    ViTConfig,
)
from repro.models import dit as dit_lib
from repro.models import resnet as resnet_lib
from repro.models import swin as swin_lib
from repro.models import transformer as tr
from repro.models import unet as unet_lib
from repro.models import vit as vit_lib
from repro.models.layers import F32
from repro.models.ptree import tree_count, tree_init, tree_pspec, tree_struct
from repro.models.transformer import ParallelPlan

CTX_TOKENS = 77  # stubbed text-conditioning length for UNet (frontend stub)


@dataclass
class ModelHandle:
    cfg: Any
    plan: ParallelPlan
    param_spec: Any  # TensorSpec tree
    family: str

    # fns(params, ...) per family — see make_step_fn
    forward: Callable = None
    loss: Callable = None

    def init(self, key, dtype=None):
        return tree_init(self.param_spec, key, dtype=dtype)

    def struct(self):
        return tree_struct(self.param_spec)

    def pspecs(self, rules):
        return tree_pspec(self.param_spec, rules)

    def n_params(self) -> int:
        return tree_count(self.param_spec)


def build(cfg, plan: ParallelPlan | None = None) -> ModelHandle:
    plan = plan or ParallelPlan()
    if isinstance(cfg, LMConfig):
        spec = tr.lm_param_spec(cfg, plan)
        h = ModelHandle(cfg, plan, spec, "lm")
        h.forward = lambda p, tokens: tr.lm_forward(p, tokens, cfg, plan)[0]
        h.loss = lambda p, batch: tr.lm_loss(p, batch, cfg, plan)
        return h
    if isinstance(cfg, ViTConfig):
        spec = vit_lib.vit_param_spec(cfg)
        h = ModelHandle(cfg, plan, spec, "vision")
        h.forward = lambda p, images: vit_lib.vit_forward(p, images, cfg, unroll=plan.analysis_unroll)
        h.loss = lambda p, batch: _cls_loss(h.forward, p, batch)
        return h
    if isinstance(cfg, SwinConfig):
        spec = swin_lib.swin_param_spec(cfg)
        h = ModelHandle(cfg, plan, spec, "vision")
        h.forward = lambda p, images: swin_lib.swin_forward(p, images, cfg)
        h.loss = lambda p, batch: _cls_loss(h.forward, p, batch)
        return h
    if isinstance(cfg, ResNetConfig):
        spec = resnet_lib.resnet_param_spec(cfg)
        h = ModelHandle(cfg, plan, spec, "vision")
        h.forward = lambda p, images: resnet_lib.resnet_forward(p, images, cfg)
        h.loss = lambda p, batch: _cls_loss(h.forward, p, batch)
        return h
    if isinstance(cfg, DiTConfig):
        spec = dit_lib.dit_param_spec(cfg)
        h = ModelHandle(cfg, plan, spec, "diffusion")
        h.forward = lambda p, latents, t, cond: dit_lib.dit_forward(
            p, latents, t, cond, cfg, unroll=plan.analysis_unroll
        )
        h.loss = lambda p, batch: _diffusion_loss(h.forward, p, batch, learn_sigma=cfg.learn_sigma)
        return h
    if isinstance(cfg, UNetConfig):
        spec = unet_lib.unet_param_spec(cfg)
        h = ModelHandle(cfg, plan, spec, "diffusion")
        h.forward = lambda p, latents, t, cond: unet_lib.unet_forward(p, latents, t, cond, cfg)
        h.loss = lambda p, batch: _diffusion_loss(h.forward, p, batch, learn_sigma=False)
        return h
    raise TypeError(f"unknown config type {type(cfg)}")


def _cls_loss(forward, params, batch):
    logits = forward(params, batch["images"]).astype(F32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["labels"][:, None], axis=-1)[:, 0]
    return jnp.mean(lse - gold)


def _diffusion_loss(forward, params, batch, *, learn_sigma: bool):
    """Epsilon-prediction MSE at provided (t, noise) — DDPM objective."""
    x0, t, noise, cond = batch["latents"], batch["t"], batch["noise"], batch["cond"]
    abar = jnp.cos(0.5 * jnp.pi * (t.astype(F32) / 1000.0)) ** 2  # cosine schedule
    abar = abar.reshape(-1, 1, 1, 1)
    x_t = (jnp.sqrt(abar) * x0.astype(F32) + jnp.sqrt(1 - abar) * noise.astype(F32)).astype(x0.dtype)
    pred = forward(params, x_t, t, cond).astype(F32)
    eps = pred[..., : x0.shape[-1]] if learn_sigma else pred
    return jnp.mean(jnp.square(eps - noise.astype(F32)))


# --------------------------------------------------------------------------- #
# input specs per (arch, shape) — ShapeDtypeStructs, never allocated
# --------------------------------------------------------------------------- #


def input_specs(cfg, shape: ShapeSpec, plan: ParallelPlan | None = None) -> dict:
    plan = plan or ParallelPlan()
    i32, bf16 = jnp.int32, jnp.bfloat16
    if isinstance(cfg, LMConfig):
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "train":
            return {
                "batch": {
                    "tokens": jax.ShapeDtypeStruct((B, S), i32),
                    "labels": jax.ShapeDtypeStruct((B, S), i32),
                }
            }
        if shape.kind == "prefill":
            return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if shape.kind == "decode":
            return {
                "cache": tr.cache_spec(cfg, plan, B, S),
                "token": jax.ShapeDtypeStruct((B,), i32),
            }
    if isinstance(cfg, (DiTConfig, UNetConfig)):
        B = shape.batch
        lat = shape.img_res // cfg.latent_factor
        cond = (
            jax.ShapeDtypeStruct((B,), i32)
            if isinstance(cfg, DiTConfig)
            else jax.ShapeDtypeStruct((B, CTX_TOKENS, cfg.ctx_dim), bf16)
        )
        if shape.kind == "train":
            return {
                "batch": {
                    "latents": jax.ShapeDtypeStruct((B, lat, lat, cfg.in_channels), bf16),
                    "t": jax.ShapeDtypeStruct((B,), i32),
                    "noise": jax.ShapeDtypeStruct((B, lat, lat, cfg.in_channels), bf16),
                    "cond": cond,
                }
            }
        return {  # gen: one denoise step of `shape.steps`
            "latents": jax.ShapeDtypeStruct((B, lat, lat, cfg.in_channels), bf16),
            "t": jax.ShapeDtypeStruct((B,), i32),
            "cond": cond,
        }
    if isinstance(cfg, (ViTConfig, SwinConfig, ResNetConfig)):
        B, R = shape.batch, shape.img_res
        if shape.kind == "train":
            return {
                "batch": {
                    "images": jax.ShapeDtypeStruct((B, R, R, 3), bf16),
                    "labels": jax.ShapeDtypeStruct((B,), i32),
                }
            }
        return {"images": jax.ShapeDtypeStruct((B, R, R, 3), bf16)}
    raise TypeError(type(cfg))


def config_for_shape(cfg, shape: ShapeSpec):
    """Some archs need shape-dependent param trees (ViT pos-embed, Swin bias)."""
    import dataclasses

    if isinstance(cfg, SwinConfig) and shape.img_res and shape.img_res != cfg.img_res:
        # Swin-384 protocol: window scales with resolution (7 -> 12 @ 384)
        new_window = max(cfg.window * shape.img_res // cfg.img_res, 1)
        return dataclasses.replace(cfg, img_res=shape.img_res, window=new_window)
    if isinstance(cfg, ViTConfig) and shape.img_res and shape.img_res != cfg.img_res:
        return dataclasses.replace(cfg, img_res=shape.img_res)
    return cfg
