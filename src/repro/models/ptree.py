"""Parameter-tree specification: one source of truth for init / dry-run / sharding.

A model's parameters are described as a pytree of :class:`TensorSpec` leaves.
From that single tree we derive:

  * ``tree_init``    — materialized parameters (jax.random, fan-in scaled)
  * ``tree_struct``  — ``jax.ShapeDtypeStruct`` stand-ins (dry-run; no allocation)
  * ``tree_pspec``   — ``PartitionSpec`` per leaf via logical-axis rules
  * ``tree_bytes``   — analytic parameter bytes (memory napkin math)

Logical axis names used across the zoo (resolved by ``sharding/axes.py``):
  layers, vocab, embed, q_heads, kv_heads, head_dim, mlp, experts, kv_lora,
  conv_in, conv_out, classes, stack (never sharded), plus ``None``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec


@dataclass(frozen=True)
class TensorSpec:
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]
    dtype: Any = jnp.bfloat16
    init: str = "fan_in"  # fan_in | zeros | ones | normal(<scale via init_scale>)
    init_scale: float = 1.0
    fan_in: int = 0  # 0 => product of all dims except the last

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def ts(*shape_axes, dtype=jnp.bfloat16, init="fan_in", scale=1.0, fan_in=0) -> TensorSpec:
    """ts((n, 'embed'), (m, 'mlp'), ...) — (size, logical_axis) pairs."""
    shape = tuple(s for s, _ in shape_axes)
    axes = tuple(a for _, a in shape_axes)
    return TensorSpec(shape, axes, dtype=dtype, init=init, init_scale=scale, fan_in=fan_in)


def _is_spec(x) -> bool:
    return isinstance(x, TensorSpec)


def tree_init(spec_tree, key, dtype=None):
    """Materialize parameters. ``dtype`` overrides every leaf dtype if given."""
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = []
    for k, leaf in zip(keys, leaves):
        dt = dtype or leaf.dtype
        if leaf.init == "zeros":
            v = jnp.zeros(leaf.shape, dt)
        elif leaf.init == "ones":
            v = jnp.ones(leaf.shape, dt)
        else:
            fan = leaf.fan_in or (int(np.prod(leaf.shape[:-1])) if len(leaf.shape) > 1 else leaf.shape[0])
            std = leaf.init_scale / math.sqrt(max(fan, 1))
            v = (jax.random.normal(k, leaf.shape, jnp.float32) * std).astype(dt)
        vals.append(v)
    return jax.tree.unflatten(treedef, vals)


def tree_struct(spec_tree):
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), spec_tree, is_leaf=_is_spec
    )


def tree_pspec(spec_tree, rules: dict[str, Optional[str]]):
    """Map logical axes -> mesh axes. Axes missing from rules are unsharded.

    A mesh axis is dropped (treated as replicated) if the dim size is not
    divisible by the mesh axis size recorded in ``rules['_sizes']``.
    """
    sizes = rules.get("_sizes", {})

    def one(l: TensorSpec):
        spec, used = [], set()
        for dim, ax in zip(l.shape, l.axes):
            mesh_ax = rules.get(ax) if ax else None
            if mesh_ax is None or mesh_ax in used or dim % max(sizes.get(mesh_ax, 1), 1) != 0:
                spec.append(None)
            else:
                spec.append(mesh_ax)
                used.add(mesh_ax)
        return PartitionSpec(*spec)

    return jax.tree.map(one, spec_tree, is_leaf=_is_spec)


def tree_bytes(spec_tree, bytes_per_el: int = 2) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=_is_spec)
    return sum(int(np.prod(l.shape)) * bytes_per_el for l in leaves)


def tree_count(spec_tree) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=_is_spec)
    return sum(int(np.prod(l.shape)) for l in leaves)
