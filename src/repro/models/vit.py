"""ViT / DeiT encoders. [arXiv:2010.11929, arXiv:2012.12877]

DeiT adds a distillation token and a second classifier head; at inference
the two head outputs are averaged (the paper's protocol).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ViTConfig
from repro.models.layers import F32, apply_mlp, apply_norm, attention_core, mlp_spec, norm_spec
from repro.models.ptree import ts
from repro.sharding.axes import shard


def _enc_layer_spec(d: int, n_heads: int, d_ff: int, d_head: int) -> dict:
    return {
        "ln1": norm_spec(d, "layernorm"),
        "attn": {
            "wqkv": ts((3, "stack"), (d, "embed"), (n_heads, "q_heads"), (d_head, "head_dim")),
            "bqkv": ts((3, "stack"), (n_heads, "q_heads"), (d_head, "head_dim"), init="zeros"),
            "wo": ts((n_heads, "q_heads"), (d_head, "head_dim"), (d, "embed")),
            "bo": ts((d, "embed"), init="zeros"),
        },
        "ln2": norm_spec(d, "layernorm"),
        "mlp": mlp_spec(d, d_ff, "gelu"),
    }


def encoder_layer(p, x, *, sp: bool = False):
    d_head = p["attn"]["wqkv"].shape[-1]
    h = apply_norm(p["ln1"], x, "layernorm")
    qkv = jnp.einsum("bsd,cdhk->cbshk", h, p["attn"]["wqkv"]) + p["attn"]["bqkv"][:, None, None]
    out = attention_core(qkv[0], qkv[1], qkv[2], causal=False, mode="sp" if sp else "tp")
    x = x + jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"]) + p["attn"]["bo"]
    h = apply_norm(p["ln2"], x, "layernorm")
    return x + apply_mlp(p["mlp"], h, "gelu")


def vit_param_spec(cfg: ViTConfig) -> dict:
    d = cfg.d_model
    d_head = d // cfg.n_heads
    n_tok = (cfg.img_res // cfg.patch) ** 2 + 1 + (1 if cfg.distill_token else 0)
    spec = {
        "patch_embed": {
            "w": ts((cfg.patch * cfg.patch * 3, "conv_in"), (d, "embed")),
            "b": ts((d, "embed"), init="zeros"),
        },
        "cls_token": ts((1, None), (1, None), (d, "embed"), init="zeros"),
        "pos_embed": ts((1, None), (n_tok, None), (d, "embed"), scale=0.02, init="fan_in", fan_in=1),
        "layers": {
            "all": _stack([_enc_layer_spec(d, cfg.n_heads, cfg.d_ff, d_head) for _ in range(cfg.n_layers)])
        },
        "final_norm": norm_spec(d, "layernorm"),
        "head": {"w": ts((d, "embed"), (cfg.n_classes, "classes")), "b": ts((cfg.n_classes, "classes"), init="zeros")},
    }
    if cfg.distill_token:
        spec["dist_token"] = ts((1, None), (1, None), (d, "embed"), init="zeros")
        spec["head_dist"] = {
            "w": ts((d, "embed"), (cfg.n_classes, "classes")),
            "b": ts((cfg.n_classes, "classes"), init="zeros"),
        }
    return spec


def _stack(specs):
    from repro.models.transformer import _stack_specs

    return _stack_specs(specs)


def patchify(images, patch: int):
    """(B,H,W,3) -> (B, H/p * W/p, p*p*3)."""
    B, H, W, C = images.shape
    x = images.reshape(B, H // patch, patch, W // patch, patch, C)
    x = x.transpose(0, 1, 3, 2, 4, 5).reshape(B, (H // patch) * (W // patch), patch * patch * C)
    return x


def vit_forward(params, images, cfg: ViTConfig, *, unroll: bool = False, interpolate_pos: bool = True):
    """images: (B, R, R, 3) f32/bf16 -> logits (B, n_classes)."""
    B = images.shape[0]
    x = jnp.einsum("bsp,pd->bsd", patchify(images, cfg.patch).astype(params["patch_embed"]["w"].dtype),
                   params["patch_embed"]["w"]) + params["patch_embed"]["b"]
    x = shard(x, "batch", None, None)
    n_special = 1 + (1 if cfg.distill_token else 0)
    toks = [jnp.broadcast_to(params["cls_token"], (B, 1, x.shape[-1]))]
    if cfg.distill_token:
        toks.append(jnp.broadcast_to(params["dist_token"], (B, 1, x.shape[-1])))
    x = jnp.concatenate(toks + [x], axis=1)
    pos = params["pos_embed"]
    if pos.shape[1] != x.shape[1] and interpolate_pos:
        pos = _interp_pos(pos, n_special, x.shape[1])
    x = x + pos

    stacked = params["layers"]["all"]
    n = cfg.n_layers
    if unroll:
        for i in range(n):
            x = encoder_layer(jax.tree.map(lambda a: a[i], stacked), x)
    else:
        def body(x, p_i):
            return encoder_layer(p_i, x), ()
        x, _ = jax.lax.scan(body, x, stacked)
    x = apply_norm(params["final_norm"], x, "layernorm")
    logits = jnp.einsum("bd,dc->bc", x[:, 0], params["head"]["w"]) + params["head"]["b"]
    if cfg.distill_token:
        l2 = jnp.einsum("bd,dc->bc", x[:, 1], params["head_dist"]["w"]) + params["head_dist"]["b"]
        logits = (logits + l2) / 2
    return logits.astype(F32)


def _interp_pos(pos, n_special: int, n_tok_new: int):
    """Bilinear-resize the grid part of a position embedding (cls_384)."""
    import math

    special, grid = pos[:, :n_special], pos[:, n_special:]
    g_old = int(math.isqrt(grid.shape[1]))
    g_new = int(math.isqrt(n_tok_new - n_special))
    d = grid.shape[-1]
    grid2 = grid.reshape(1, g_old, g_old, d)
    grid2 = jax.image.resize(grid2.astype(F32), (1, g_new, g_new, d), "bilinear").astype(grid.dtype)
    return jnp.concatenate([special, grid2.reshape(1, g_new * g_new, d)], axis=1)
