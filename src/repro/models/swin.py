"""Swin Transformer: windowed + shifted-window attention, patch merging.
[arXiv:2103.14030]

Relative-position bias per head; cyclic shift on odd layers within a stage.
Input resolutions must make each stage's feature map divisible by the window
(true for 224/4 and 384/4 with window 7... 384/4=96, 96/7 is not integer —
the standard Swin-384 uses window 12; we follow that rule: window is scaled
by img_res/224 when divisible, else features are padded).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import SwinConfig
from repro.models.layers import F32, apply_mlp, apply_norm, mlp_spec, norm_spec
from repro.models.ptree import ts
from repro.sharding.axes import shard


def _rel_index(window: int) -> np.ndarray:
    coords = np.stack(np.meshgrid(np.arange(window), np.arange(window), indexing="ij"))
    flat = coords.reshape(2, -1)
    rel = flat[:, :, None] - flat[:, None, :]
    rel = rel.transpose(1, 2, 0) + window - 1
    return (rel[..., 0] * (2 * window - 1) + rel[..., 1]).astype(np.int32)  # (W², W²)


def _win_layer_spec(dim: int, n_heads: int, window: int) -> dict:
    return {
        "ln1": norm_spec(dim, "layernorm"),
        "attn": {
            "wqkv": ts((3, "stack"), (dim, "embed"), (n_heads, "q_heads"), (dim // n_heads, "head_dim")),
            "bqkv": ts((3, "stack"), (n_heads, "q_heads"), (dim // n_heads, "head_dim"), init="zeros"),
            "wo": ts((n_heads, "q_heads"), (dim // n_heads, "head_dim"), (dim, "embed")),
            "rel_bias": ts(((2 * window - 1) ** 2, None), (n_heads, "q_heads"), scale=0.02, init="fan_in", fan_in=1),
        },
        "ln2": norm_spec(dim, "layernorm"),
        "mlp": mlp_spec(dim, 4 * dim, "gelu"),
    }


def _window_attention(p, x, window: int, shift: int, H: int, W: int):
    """x: (B, H, W, C)."""
    B, _, _, C = x.shape
    n_heads = p["wqkv"].shape[2]
    d_head = p["wqkv"].shape[3]
    if shift:
        x = jnp.roll(x, (-shift, -shift), axis=(1, 2))
    nh, nw = H // window, W // window
    xw = x.reshape(B, nh, window, nw, window, C).transpose(0, 1, 3, 2, 4, 5)
    xw = xw.reshape(B * nh * nw, window * window, C)

    qkv = jnp.einsum("nsd,cdhk->cnshk", xw, p["wqkv"]) + p["bqkv"][:, None, None]
    q, k, v = qkv[0], qkv[1], qkv[2]
    scores = jnp.einsum("nqhk,nshk->nhqs", q, k).astype(F32) / np.sqrt(d_head)
    bias = p["rel_bias"][jnp.asarray(_rel_index(window))]  # (W²,W²,Hd)
    scores = scores + bias.transpose(2, 0, 1)[None].astype(F32)
    if shift:
        mask = _shift_mask(H, W, window, shift)  # (nWin, W², W²)
        scores = scores.reshape(B, nh * nw, n_heads, window**2, window**2)
        scores = jnp.where(mask[None, :, None], scores, -1e30)
        scores = scores.reshape(B * nh * nw, n_heads, window**2, window**2)
    probs = jax.nn.softmax(scores, -1).astype(x.dtype)
    out = jnp.einsum("nhqs,nshk->nqhk", probs, v)
    out = jnp.einsum("nqhk,hkd->nqd", out, p["wo"])
    out = out.reshape(B, nh, nw, window, window, C).transpose(0, 1, 3, 2, 4, 5).reshape(B, H, W, C)
    if shift:
        out = jnp.roll(out, (shift, shift), axis=(1, 2))
    return out


def _shift_mask(H: int, W: int, window: int, shift: int) -> jnp.ndarray:
    img = np.zeros((H, W), np.int32)
    cnt = 0
    for hs in (slice(0, -window), slice(-window, -shift), slice(-shift, None)):
        for ws in (slice(0, -window), slice(-window, -shift), slice(-shift, None)):
            img[hs, ws] = cnt
            cnt += 1
    img = np.roll(img, (-shift, -shift), axis=(0, 1))
    nh, nw = H // window, W // window
    wins = img.reshape(nh, window, nw, window).transpose(0, 2, 1, 3).reshape(-1, window * window)
    return jnp.asarray(wins[:, :, None] == wins[:, None, :])


def swin_window_for(cfg: SwinConfig, img_res: int) -> int:
    if img_res == cfg.img_res:
        return cfg.window
    scaled = cfg.window * img_res // cfg.img_res
    return max(scaled, 1)


def swin_param_spec(cfg: SwinConfig, img_res: int | None = None) -> dict:
    img_res = img_res or cfg.img_res
    window = swin_window_for(cfg, img_res)
    spec = {
        "patch_embed": {"w": ts((cfg.patch**2 * 3, "conv_in"), (cfg.dims[0], "embed")), "b": ts((cfg.dims[0], "embed"), init="zeros")},
        "pos_norm": norm_spec(cfg.dims[0], "layernorm"),
    }
    for i, (dep, dim) in enumerate(zip(cfg.depths, cfg.dims)):
        stage = {f"l{j}": _win_layer_spec(dim, cfg.heads[i], window) for j in range(dep)}
        if i < len(cfg.dims) - 1:
            stage["merge"] = {
                "norm": norm_spec(4 * dim, "layernorm"),
                "w": ts((4 * dim, "conv_in"), (cfg.dims[i + 1], "embed")),
            }
        spec[f"stage{i}"] = stage
    spec["final_norm"] = norm_spec(cfg.dims[-1], "layernorm")
    spec["head"] = {"w": ts((cfg.dims[-1], "embed"), (cfg.n_classes, "classes")), "b": ts((cfg.n_classes, "classes"), init="zeros")}
    return spec


def swin_forward(params, images, cfg: SwinConfig, **_):
    from repro.models.vit import patchify

    B, R = images.shape[0], images.shape[1]
    window = swin_window_for(cfg, R)
    x = jnp.einsum("bsp,pd->bsd", patchify(images, cfg.patch).astype(params["patch_embed"]["w"].dtype),
                   params["patch_embed"]["w"]) + params["patch_embed"]["b"]
    x = apply_norm(params["pos_norm"], x, "layernorm")
    H = W = R // cfg.patch
    x = x.reshape(B, H, W, -1)
    x = shard(x, "batch", None, None, None)

    for i, dep in enumerate(cfg.depths):
        stage = params[f"stage{i}"]
        for j in range(dep):
            p = stage[f"l{j}"]
            shift = window // 2 if j % 2 == 1 else 0
            h = apply_norm(p["ln1"], x, "layernorm")
            x = x + _window_attention(p["attn"], h, window, shift, H, W)
            h = apply_norm(p["ln2"], x, "layernorm")
            x = x + apply_mlp(p["mlp"], h, "gelu")
        if i < len(cfg.depths) - 1:
            m = stage["merge"]
            x = x.reshape(B, H // 2, 2, W // 2, 2, x.shape[-1]).transpose(0, 1, 3, 2, 4, 5)
            x = x.reshape(B, H // 2, W // 2, 4 * x.shape[-1])
            x = apply_norm(m["norm"], x, "layernorm")
            x = jnp.einsum("bhwd,de->bhwe", x, m["w"])
            H, W = H // 2, W // 2
            x = shard(x, "batch", None, None, None)
    x = apply_norm(params["final_norm"], x, "layernorm")
    x = jnp.mean(x.reshape(B, H * W, -1).astype(F32), axis=1)
    return jnp.einsum("bd,dc->bc", x, params["head"]["w"].astype(F32)) + params["head"]["b"]
