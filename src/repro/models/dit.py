"""DiT (Diffusion Transformer) with adaLN-Zero conditioning. [arXiv:2212.09748]

Operates on VAE latents (img_res / 8), patchified at ``cfg.patch``. The
denoiser predicts epsilon (+ sigma when ``learn_sigma``). Position embedding
is a fixed 2D sincos grid, so any latent resolution works (gen_1024 etc.).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import DiTConfig
from repro.models.layers import F32, apply_mlp, apply_norm, attention_core, mlp_spec, norm_spec, sinusoidal_embedding
from repro.models.ptree import ts
from repro.sharding.axes import shard


def _dit_layer_spec(d: int, n_heads: int) -> dict:
    return {
        "attn": {
            "wqkv": ts((3, "stack"), (d, "embed"), (n_heads, "q_heads"), (d // n_heads, "head_dim")),
            "wo": ts((n_heads, "q_heads"), (d // n_heads, "head_dim"), (d, "embed")),
        },
        "mlp": mlp_spec(d, 4 * d, "gelu"),
        "adaln": {"w": ts((d, "embed"), (6 * d, "mlp"), init="zeros"), "b": ts((6 * d, "mlp"), init="zeros")},
    }


def dit_param_spec(cfg: DiTConfig) -> dict:
    d = cfg.d_model
    out_ch = cfg.in_channels * (2 if cfg.learn_sigma else 1)
    return {
        "x_embed": {"w": ts((cfg.patch**2 * cfg.in_channels, "conv_in"), (d, "embed")), "b": ts((d, "embed"), init="zeros")},
        "t_embed": {
            "w1": ts((256, "conv_in"), (d, "embed")),
            "b1": ts((d, "embed"), init="zeros"),
            "w2": ts((d, "embed"), (d, "mlp")),
            "b2": ts((d, "mlp"), init="zeros"),
        },
        "y_embed": ts((cfg.n_classes + 1, "vocab"), (d, "embed"), scale=0.02, init="fan_in", fan_in=1),
        "layers": {"all": _stack([_dit_layer_spec(d, cfg.n_heads) for _ in range(cfg.n_layers)])},
        "final": {
            "adaln": {"w": ts((d, "embed"), (2 * d, "mlp"), init="zeros"), "b": ts((2 * d, "mlp"), init="zeros")},
            "w": ts((d, "embed"), (cfg.patch**2 * out_ch, "conv_out"), init="zeros"),
            "b": ts((cfg.patch**2 * out_ch, "conv_out"), init="zeros"),
        },
    }


def _stack(specs):
    from repro.models.transformer import _stack_specs

    return _stack_specs(specs)


def _modulate(x, shift, scale):
    return x * (1.0 + scale[:, None]) + shift[:, None]


def _sincos_pos_2d(h: int, w: int, d: int):
    def axis_emb(n):
        omega = np.arange(d // 4, dtype=np.float64) / (d / 4)
        omega = 1.0 / 10000**omega
        pos = np.arange(n, dtype=np.float64)[:, None] * omega[None]
        return np.concatenate([np.sin(pos), np.cos(pos)], axis=1)

    eh, ew = axis_emb(h), axis_emb(w)
    grid = np.concatenate(
        [np.repeat(eh, w, axis=0), np.tile(ew, (h, 1))], axis=1
    )
    return jnp.asarray(grid, jnp.float32)  # (h*w, d)


def dit_layer(p, x, c):
    """x: (B,T,D); c: (B,D) conditioning."""
    d = x.shape[-1]
    mod = jnp.einsum("bd,de->be", jax.nn.silu(c.astype(F32)).astype(x.dtype), p["adaln"]["w"]) + p["adaln"]["b"]
    sh1, sc1, g1, sh2, sc2, g2 = jnp.split(mod, 6, axis=-1)
    h = _modulate(_ln(x), sh1, sc1)
    qkv = jnp.einsum("bsd,cdhk->cbshk", h, p["attn"]["wqkv"])
    att = attention_core(qkv[0], qkv[1], qkv[2], causal=False, mode="sp")
    x = x + g1[:, None] * jnp.einsum("bshk,hkd->bsd", att, p["attn"]["wo"])
    h = _modulate(_ln(x), sh2, sc2)
    return x + g2[:, None] * apply_mlp(p["mlp"], h, "gelu")


def _ln(x, eps=1e-6):
    xf = x.astype(F32)
    mu = xf.mean(-1, keepdims=True)
    var = jnp.square(xf - mu).mean(-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)  # no affine: adaLN provides it


def dit_forward(params, latents, t, y, cfg: DiTConfig, *, unroll: bool = False):
    """latents: (B, h, w, C) on the VAE grid; t: (B,); y: (B,) class ids.

    Returns epsilon (+sigma) prediction with the same spatial shape.
    """
    from repro.models.vit import patchify

    B, h, w, C = latents.shape
    p_sz = cfg.patch
    x = jnp.einsum("bsp,pd->bsd", patchify(latents, p_sz).astype(params["x_embed"]["w"].dtype),
                   params["x_embed"]["w"]) + params["x_embed"]["b"]
    x = x + _sincos_pos_2d(h // p_sz, w // p_sz, cfg.d_model).astype(x.dtype)
    x = shard(x, "batch", "seq_sp", None)

    te = sinusoidal_embedding(t, 256).astype(x.dtype)
    te = jnp.einsum("bd,de->be", te, params["t_embed"]["w1"]) + params["t_embed"]["b1"]
    te = jnp.einsum("bd,de->be", jax.nn.silu(te.astype(F32)).astype(x.dtype), params["t_embed"]["w2"]) + params["t_embed"]["b2"]
    ye = jnp.take(params["y_embed"], y, axis=0)
    c = te + ye

    stacked = params["layers"]["all"]
    if unroll:
        for i in range(cfg.n_layers):
            x = dit_layer(jax.tree.map(lambda a: a[i], stacked), x, c)
    else:
        def body(x, p_i):
            return dit_layer(p_i, x, c), ()
        x, _ = jax.lax.scan(body, x, stacked)

    f = params["final"]
    mod = jnp.einsum("bd,de->be", jax.nn.silu(c.astype(F32)).astype(x.dtype), f["adaln"]["w"]) + f["adaln"]["b"]
    sh, sc = jnp.split(mod, 2, axis=-1)
    x = _modulate(_ln(x), sh, sc)
    x = jnp.einsum("bsd,dp->bsp", x, f["w"]) + f["b"]
    out_ch = cfg.in_channels * (2 if cfg.learn_sigma else 1)
    gh, gw = h // p_sz, w // p_sz
    x = x.reshape(B, gh, gw, p_sz, p_sz, out_ch).transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(B, h, w, out_ch)
