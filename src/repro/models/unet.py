"""SDXL-class UNet: ResBlocks + spatial transformers (self+cross attention).
[arXiv:2307.01952]

Assignment config: ch=320, ch_mult=(1,2,4), 2 res blocks/stage,
transformer_depth=(1,2,10), ctx_dim=2048, latent 128 for 1024px images.

Sharding: conv/GN channels over `model` (TP), batch over `data` (or spatial
rows for tiny-batch gen shapes — rules decided by the launcher), attention in
SP mode (tokens over `model`).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import UNetConfig
from repro.models.layers import F32, attention_core, sinusoidal_embedding
from repro.models.ptree import ts
from repro.sharding.axes import shard

GN_GROUPS = 32


# ------------------------------ primitives --------------------------------- #


def _gn_spec(c):
    return {"scale": ts((c, "conv_out"), dtype=F32, init="ones"), "bias": ts((c, "conv_out"), dtype=F32, init="zeros")}


def apply_gn(p, x, groups=GN_GROUPS, eps=1e-5):
    B, H, W, C = x.shape
    g = min(groups, C)
    xf = x.astype(F32).reshape(B, H, W, g, C // g)
    mu = xf.mean((1, 2, 4), keepdims=True)
    var = jnp.square(xf - mu).mean((1, 2, 4), keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (xf.reshape(B, H, W, C) * p["scale"] + p["bias"]).astype(x.dtype)


def _conv_spec(cin, cout, k=3):
    return {"w": ts((k, None), (k, None), (cin, "conv_in"), (cout, "conv_out"), fan_in=k * k * cin), "b": ts((cout, "conv_out"), init="zeros")}


def _conv(p, x, stride=1):
    y = jax.lax.conv_general_dilated(
        x, p["w"].astype(x.dtype), (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    return y + p["b"].astype(x.dtype)


def _lin_spec(cin, cout, axes=("embed", "mlp"), init="fan_in"):
    return {"w": ts((cin, axes[0]), (cout, axes[1]), init=init), "b": ts((cout, axes[1]), init="zeros")}


def _lin(p, x):
    return jnp.einsum("...d,de->...e", x, p["w"]) + p["b"]


def _silu(x):
    return jax.nn.silu(x.astype(F32)).astype(x.dtype)


# ------------------------------ res block ---------------------------------- #


def _res_spec(cin, cout, t_dim):
    spec = {
        "gn1": _gn_spec(cin),
        "c1": _conv_spec(cin, cout),
        "temb": _lin_spec(t_dim, cout, axes=("embed", "conv_out")),
        "gn2": _gn_spec(cout),
        "c2": _conv_spec(cout, cout),
    }
    if cin != cout:
        spec["skip"] = _conv_spec(cin, cout, k=1)
    return spec


def _res_block(p, x, temb):
    h = _conv(p["c1"], _silu(apply_gn(p["gn1"], x)))
    h = h + _lin(p["temb"], _silu(temb))[:, None, None, :]
    h = _conv(p["c2"], _silu(apply_gn(p["gn2"], h)))
    skip = _conv(p["skip"], x) if "skip" in p else x
    return skip + h


# -------------------------- spatial transformer ----------------------------- #


def _tf_block_spec(ch, ctx_dim, head_dim):
    n_heads = max(ch // head_dim, 1)
    return {
        "ln1": _ln_spec(ch),
        "self_q": ts((ch, "embed"), (n_heads, "q_heads"), (head_dim, "head_dim")),
        "self_k": ts((ch, "embed"), (n_heads, "q_heads"), (head_dim, "head_dim")),
        "self_v": ts((ch, "embed"), (n_heads, "q_heads"), (head_dim, "head_dim")),
        "self_o": ts((n_heads, "q_heads"), (head_dim, "head_dim"), (ch, "embed")),
        "ln2": _ln_spec(ch),
        "cross_q": ts((ch, "embed"), (n_heads, "q_heads"), (head_dim, "head_dim")),
        "cross_k": ts((ctx_dim, "ctx"), (n_heads, "q_heads"), (head_dim, "head_dim")),
        "cross_v": ts((ctx_dim, "ctx"), (n_heads, "q_heads"), (head_dim, "head_dim")),
        "cross_o": ts((n_heads, "q_heads"), (head_dim, "head_dim"), (ch, "embed")),
        "ln3": _ln_spec(ch),
        "ff_g": _lin_spec(ch, 4 * ch),
        "ff_u": _lin_spec(ch, 4 * ch),
        "ff_o": _lin_spec(4 * ch, ch, axes=("mlp", "embed")),
    }


def _ln_spec(c):
    return {"scale": ts((c, "embed"), dtype=F32, init="ones"), "bias": ts((c, "embed"), dtype=F32, init="zeros")}


def _ln(p, x, eps=1e-5):
    xf = x.astype(F32)
    mu = xf.mean(-1, keepdims=True)
    var = jnp.square(xf - mu).mean(-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]).astype(x.dtype)


def _tf_block(p, x, ctx):
    """x: (B,T,C); ctx: (B,Tc,ctx_dim)."""
    h = _ln(p["ln1"], x)
    q = jnp.einsum("btd,dhk->bthk", h, p["self_q"])
    k = jnp.einsum("btd,dhk->bthk", h, p["self_k"])
    v = jnp.einsum("btd,dhk->bthk", h, p["self_v"])
    a = attention_core(q, k, v, causal=False, mode="sp")
    x = x + jnp.einsum("bthk,hkd->btd", a, p["self_o"])

    h = _ln(p["ln2"], x)
    q = jnp.einsum("btd,dhk->bthk", h, p["cross_q"])
    k = jnp.einsum("bcd,dhk->bchk", ctx, p["cross_k"])
    v = jnp.einsum("bcd,dhk->bchk", ctx, p["cross_v"])
    a = attention_core(q, k, v, causal=False, mode="sp")
    x = x + jnp.einsum("bthk,hkd->btd", a, p["cross_o"])

    h = _ln(p["ln3"], x)
    g = _lin(p["ff_g"], h)
    g = shard(g, "batch", None, "mlp_act")
    h = _silu(g) * _lin(p["ff_u"], h)
    return x + _lin(p["ff_o"], h)


def _spatial_tf_spec(ch, depth, ctx_dim, head_dim):
    return {
        "gn": _gn_spec(ch),
        "proj_in": _lin_spec(ch, ch, axes=("conv_in", "embed")),
        "blocks": {f"b{i}": _tf_block_spec(ch, ctx_dim, head_dim) for i in range(depth)},
        "proj_out": _lin_spec(ch, ch, axes=("embed", "conv_out"), init="zeros"),
    }


def _spatial_tf(p, x, ctx):
    B, H, W, C = x.shape
    h = apply_gn(p["gn"], x)
    h = _lin(p["proj_in"], h.reshape(B, H * W, C))
    for name in sorted(p["blocks"], key=lambda s: int(s[1:])):
        h = _tf_block(p["blocks"][name], h, ctx)
    return x + _lin(p["proj_out"], h).reshape(B, H, W, C)


# ------------------------------ full UNet ---------------------------------- #


def unet_param_spec(cfg: UNetConfig) -> dict:
    t_dim = 4 * cfg.ch
    chans = [cfg.ch * m for m in cfg.ch_mult]
    spec: dict = {
        "temb": {"l1": _lin_spec(cfg.ch, t_dim), "l2": _lin_spec(t_dim, t_dim)},
        "conv_in": _conv_spec(cfg.in_channels, cfg.ch),
    }
    down = {}
    prev = cfg.ch
    skips = [cfg.ch]
    for i, ch in enumerate(chans):
        blocks = {}
        for b in range(cfg.n_res_blocks):
            blk = {"res": _res_spec(prev, ch, t_dim)}
            if cfg.transformer_depth[i]:
                blk["tf"] = _spatial_tf_spec(ch, cfg.transformer_depth[i], cfg.ctx_dim, cfg.head_dim)
            blocks[f"b{b}"] = blk
            prev = ch
            skips.append(ch)
        if i < len(chans) - 1:
            blocks["down"] = _conv_spec(ch, ch)
            skips.append(ch)
        down[f"stage{i}"] = blocks
    spec["down"] = down
    spec["mid"] = {
        "res1": _res_spec(prev, prev, t_dim),
        "tf": _spatial_tf_spec(prev, cfg.transformer_depth[-1], cfg.ctx_dim, cfg.head_dim),
        "res2": _res_spec(prev, prev, t_dim),
    }
    up = {}
    for i, ch in reversed(list(enumerate(chans))):
        blocks = {}
        for b in range(cfg.n_res_blocks + 1):
            skip_ch = skips.pop()
            blk = {"res": _res_spec(prev + skip_ch, ch, t_dim)}
            if cfg.transformer_depth[i]:
                blk["tf"] = _spatial_tf_spec(ch, cfg.transformer_depth[i], cfg.ctx_dim, cfg.head_dim)
            blocks[f"b{b}"] = blk
            prev = ch
        if i > 0:
            blocks["up"] = _conv_spec(ch, ch)
        up[f"stage{i}"] = blocks
    spec["up"] = up
    spec["out"] = {"gn": _gn_spec(cfg.ch), "conv": _conv_spec(cfg.ch, cfg.in_channels)}
    return spec


def unet_forward(params, latents, t, ctx, cfg: UNetConfig, **_):
    """latents: (B,h,w,4); t: (B,); ctx: (B, 77, ctx_dim) text conditioning."""
    temb = sinusoidal_embedding(t, cfg.ch).astype(latents.dtype)
    temb = _lin(params["temb"]["l2"], _silu(_lin(params["temb"]["l1"], temb)))

    chans = [cfg.ch * m for m in cfg.ch_mult]
    x = _conv(params["conv_in"], latents)
    x = shard(x, "batch", "spatial", None, None)
    skips = [x]
    for i in range(len(chans)):
        stage = params["down"][f"stage{i}"]
        for b in range(cfg.n_res_blocks):
            blk = stage[f"b{b}"]
            x = _res_block(blk["res"], x, temb)
            if "tf" in blk:
                x = _spatial_tf(blk["tf"], x, ctx)
            skips.append(x)
        if f"down" in stage:
            x = _conv(stage["down"], x, stride=2)
            x = shard(x, "batch", "spatial", None, None)
            skips.append(x)

    m = params["mid"]
    x = _res_block(m["res1"], x, temb)
    x = _spatial_tf(m["tf"], x, ctx)
    x = _res_block(m["res2"], x, temb)

    for i in reversed(range(len(chans))):
        stage = params["up"][f"stage{i}"]
        for b in range(cfg.n_res_blocks + 1):
            blk = stage[f"b{b}"]
            x = jnp.concatenate([x, skips.pop()], axis=-1)
            x = _res_block(blk["res"], x, temb)
            if "tf" in blk:
                x = _spatial_tf(blk["tf"], x, ctx)
        if "up" in stage:
            B, H, W, C = x.shape
            x = jax.image.resize(x, (B, 2 * H, 2 * W, C), "nearest")
            x = _conv(stage["up"], x)
            x = shard(x, "batch", "spatial", None, None)

    x = _silu(apply_gn(params["out"]["gn"], x))
    return _conv(params["out"]["conv"], x)
