"""Shared layers: norms, rotary embeddings, attention (TP / SP / decode), MLPs.

Pure-function style: ``*_spec`` builds a TensorSpec tree, ``apply_*`` consumes
the materialized tree. Activation sharding is expressed through logical axes
(`sharding.axes.shard`), so the same code runs on 1 CPU device and on the
(pod, data, model) production mesh.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.ptree import TensorSpec, ts
from repro.sharding.axes import shard

F32 = jnp.float32


# --------------------------------------------------------------------------- #
# Norms
# --------------------------------------------------------------------------- #


def norm_spec(d: int, kind: str) -> dict:
    if kind == "rmsnorm":
        return {"scale": ts((d, "embed"), dtype=F32, init="ones")}
    return {"scale": ts((d, "embed"), dtype=F32, init="ones"), "bias": ts((d, "embed"), dtype=F32, init="zeros")}


def apply_norm(p: dict, x, kind: str, eps: float = 1e-5):
    xf = x.astype(F32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
        return (y * p["scale"]).astype(x.dtype)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


# --------------------------------------------------------------------------- #
# Rotary position embeddings (partial rotation supported, NeoX interleaving)
# --------------------------------------------------------------------------- #


def apply_rope(x, positions, theta: float, rotate_dim: int):
    """x: (..., S, H, Dh); rotate the first ``rotate_dim`` dims of Dh."""
    if rotate_dim <= 0:
        return x
    d = rotate_dim
    xr, xp = x[..., :d], x[..., d:]
    half = d // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=F32) / half)
    ang = positions.astype(F32)[..., :, None] * freqs[None, :]  # (..., S, half)
    cos = jnp.cos(ang)[..., :, None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = xr[..., :half].astype(F32), xr[..., half:].astype(F32)
    rot = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return jnp.concatenate([rot.astype(x.dtype), xp], axis=-1)


# --------------------------------------------------------------------------- #
# Attention cores
# --------------------------------------------------------------------------- #


def _expand_kv(k, n_heads: int):
    """(B,S,KH,D) -> (B,S,H,D) by static head-group gather (GQA)."""
    kh = k.shape[2]
    if kh == n_heads:
        return k
    mapping = np.arange(n_heads) // (n_heads // kh)
    return k[:, :, mapping, :]


def attention_core(
    q,
    k,
    v,
    *,
    causal: bool,
    q_positions=None,
    kv_positions=None,
    softmax_scale: Optional[float] = None,
    mode: str = "tp",
):
    """Dense attention. q: (B,Sq,H,Dh) k/v: (B,Sk,H,Dh_v).

    mode:
      "tp"     — heads sharded over `model` (requires divisible/padded heads)
      "sp"     — q sharded over sequence (model axis), full K/V: the
                 sequence-parallel fallback for non-divisible head counts
      "decode" — K/V sharded over sequence (flash-decode style split-K;
                 GSPMD inserts the distributed-softmax collectives)
    Softmax in f32. Returns (B,Sq,H,Dh_v).
    """
    B, Sq, H, Dh = q.shape
    Sk = k.shape[1]
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(Dh)
    if mode == "sp":
        q = shard(q, "batch", "seq_sp", None, None)
        k = shard(k, "batch", None, None, None)
        v = shard(v, "batch", None, None, None)
    elif mode == "decode":
        q = shard(q, "batch", None, None, None)
        k = shard(k, "batch", "kv_seq", None, None)
        v = shard(v, "batch", "kv_seq", None, None)
    else:
        q = shard(q, "batch", None, "heads_act", None)
        k = shard(k, "batch", None, "heads_act", None)
        v = shard(v, "batch", None, "heads_act", None)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(F32) * scale
    if causal:
        if q_positions is None:
            q_positions = jnp.arange(Sq)
        if kv_positions is None:
            kv_positions = jnp.arange(Sk)
        mask = q_positions[:, None] >= kv_positions[None, :]
        scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    if mode == "sp":
        out = shard(out, "batch", "seq_sp", None, None)
    elif mode == "tp":
        out = shard(out, "batch", None, "heads_act", None)
    return out


def attention_blockwise(q, k, v, *, causal: bool, chunk: int = 1024, unroll: bool = False, sp: bool = False):
    """Flash-style blockwise attention over KV chunks (pure jnp oracle).

    Keeps peak memory at O(Sq * chunk) per head instead of O(Sq * Sk). Used
    (a) as the prefill path for long sequences and (b) as the reference for
    ``kernels/flash_attention``. ``unroll=True`` is the dry-run analysis mode
    (XLA counts while-bodies once; unrolled bodies are counted exactly).
    """
    B, Sq, H, Dh = q.shape
    Sk = k.shape[1]
    n_chunks = max(Sk // chunk, 1)
    chunk = Sk // n_chunks
    scale = 1.0 / math.sqrt(Dh)
    qpos = jnp.arange(Sq)
    if sp:
        q = shard(q, "batch", "seq_sp", None, None)
    else:
        q = shard(q, "batch", None, "heads_act", None)

    kc = k.reshape(B, n_chunks, chunk, H, Dh)
    vc = v.reshape(B, n_chunks, chunk, H, v.shape[-1])

    @jax.checkpoint  # flash-style bwd: recompute p per chunk, never store it
    def _chunk(carry, kb, vb, start):
        m, l, acc = carry
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kb).astype(F32) * scale
        if causal:
            kpos = start + jnp.arange(chunk)
            s = jnp.where((qpos[:, None] >= kpos[None, :])[None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vb.astype(F32))
        return (m_new, l_new, acc_new)

    def body(carry, inputs):
        kb, vb, start = inputs
        return _chunk(carry, kb, vb, start), ()

    init = (
        jnp.full((B, H, Sq), -jnp.inf, F32),
        jnp.zeros((B, H, Sq), F32),
        jnp.zeros((B, H, Sq, v.shape[-1]), F32),
    )
    starts = jnp.arange(n_chunks) * chunk
    xs = (kc.swapaxes(0, 1), vc.swapaxes(0, 1), starts)
    if unroll:
        carry = init
        for i in range(n_chunks):
            carry, _ = body(carry, jax.tree.map(lambda a: a[i], xs))
    else:
        carry, _ = jax.lax.scan(body, init, xs)
    m, l, acc = carry
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.swapaxes(1, 2).astype(q.dtype)  # (B,Sq,H,Dv)


# --------------------------------------------------------------------------- #
# MLPs
# --------------------------------------------------------------------------- #


def mlp_spec(d: int, d_ff: int, act: str) -> dict:
    if act == "swiglu":
        return {
            "wg": ts((d, "embed"), (d_ff, "mlp")),
            "wu": ts((d, "embed"), (d_ff, "mlp")),
            "wd": ts((d_ff, "mlp"), (d, "embed")),
        }
    return {"wi": ts((d, "embed"), (d_ff, "mlp")), "wo": ts((d_ff, "mlp"), (d, "embed"))}


def apply_mlp(p: dict, x, act: str):
    if act == "swiglu":
        g = jnp.einsum("...d,df->...f", x, p["wg"])
        u = jnp.einsum("...d,df->...f", x, p["wu"])
        g = shard(g, *(["batch"] + [None] * (g.ndim - 2) + ["mlp_act"]))
        h = (jax.nn.silu(g.astype(F32)).astype(x.dtype)) * u
        return jnp.einsum("...f,fd->...d", h, p["wd"])
    h = jnp.einsum("...d,df->...f", x, p["wi"])
    h = shard(h, *(["batch"] + [None] * (h.ndim - 2) + ["mlp_act"]))
    h = jax.nn.gelu(h.astype(F32), approximate=True).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, p["wo"])


# --------------------------------------------------------------------------- #
# Misc
# --------------------------------------------------------------------------- #


def sinusoidal_embedding(t, dim: int, max_period: float = 10_000.0):
    """Diffusion timestep embedding. t: (B,) -> (B, dim)."""
    half = dim // 2
    freqs = jnp.exp(-math.log(max_period) * jnp.arange(half, dtype=F32) / half)
    ang = t.astype(F32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.cos(ang), jnp.sin(ang)], axis=-1)


def pad_heads(n_heads: int, model_axis: int) -> int:
    """Round head count up to a multiple of the model axis (DESIGN.md §5)."""
    if model_axis <= 1 or n_heads % model_axis == 0:
        return n_heads
    return ((n_heads + model_axis - 1) // model_axis) * model_axis
