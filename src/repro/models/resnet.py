"""ResNet-v1.5 with bottleneck blocks. [arXiv:1512.03385]

BatchNorm is folded to inference-style scale/bias ("frozen BN" — standard for
serving; training uses it as a learned affine, which keeps the step function
pure without cross-device batch stats).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ResNetConfig
from repro.models.layers import F32
from repro.models.ptree import ts
from repro.sharding.axes import shard


def _conv_spec(cin, cout, k):
    return {
        "w": ts((k, None), (k, None), (cin, "conv_in"), (cout, "conv_out"), fan_in=k * k * cin),
        "scale": ts((cout, "conv_out"), dtype=F32, init="ones"),
        "bias": ts((cout, "conv_out"), dtype=F32, init="zeros"),
    }


def _conv(p, x, stride=1, act=True):
    y = jax.lax.conv_general_dilated(
        x, p["w"].astype(x.dtype), (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    y = y.astype(F32) * p["scale"] + p["bias"]
    y = jax.nn.relu(y) if act else y
    return y.astype(x.dtype)


def _bottleneck_spec(cin, mid, cout, downsample):
    spec = {"c1": _conv_spec(cin, mid, 1), "c2": _conv_spec(mid, mid, 3), "c3": _conv_spec(mid, cout, 1)}
    if downsample:
        spec["proj"] = _conv_spec(cin, cout, 1)
    return spec


def _bottleneck(p, x, stride):
    idn = x
    y = _conv(p["c1"], x)
    y = _conv(p["c2"], y, stride=stride)
    y = _conv(p["c3"], y, act=False)
    if "proj" in p:
        idn = _conv(p["proj"], x, stride=stride, act=False)
    return jnp.maximum(y + idn, 0.0).astype(x.dtype)


def resnet_param_spec(cfg: ResNetConfig) -> dict:
    spec = {"stem": _conv_spec(3, cfg.width, 7)}
    cin = cfg.width
    for i, dep in enumerate(cfg.depths):
        mid = cfg.width * 2**i
        cout = mid * 4
        blocks = {}
        for b in range(dep):
            blocks[f"b{b}"] = _bottleneck_spec(cin, mid, cout, downsample=(b == 0))
            cin = cout
        spec[f"stage{i}"] = blocks
    spec["head"] = {"w": ts((cin, "embed"), (cfg.n_classes, "classes")), "b": ts((cfg.n_classes, "classes"), init="zeros")}
    return spec


def resnet_forward(params, images, cfg: ResNetConfig, **_):
    x = shard(images, "batch", None, None, None)
    x = _conv(params["stem"], x, stride=2)
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 3, 3, 1), (1, 2, 2, 1), "SAME")
    for i, dep in enumerate(cfg.depths):
        for b in range(dep):
            stride = 2 if (b == 0 and i > 0) else 1
            x = _bottleneck(params[f"stage{i}"][f"b{b}"], x, stride)
        x = shard(x, "batch", None, None, None)
    x = jnp.mean(x.astype(F32), axis=(1, 2))
    return jnp.einsum("bd,dc->bc", x, params["head"]["w"].astype(F32)) + params["head"]["b"]
