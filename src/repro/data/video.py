"""Deterministic synthetic video dataset with controllable per-class difficulty.

Purpose (DESIGN.md §8): no FCVID/ImageNet offline, so the benchmarks need a
dataset where (a) a small quantized model shows *skewed* accuracy across
classes (the paper's airplane-vs-table observation), and (b) difficulty is
smooth enough for a bigger model to do visibly better.

Construction: each class c is a oriented grating + blob pattern; each video
fixes (class, difficulty, phase drift); each frame adds background clutter
and noise scaled by difficulty. Easy classes get low mean difficulty (the
"airplane"), hard ones high (the "table").
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class VideoDataConfig:
    n_classes: int = 10
    img_res: int = 32
    frames_per_video: int = 30
    class_difficulty: tuple = ()  # len n_classes in [0,1]; default ramp
    noise_floor: float = 0.15

    def difficulties(self) -> np.ndarray:
        if self.class_difficulty:
            return np.asarray(self.class_difficulty, np.float32)
        return np.linspace(0.05, 0.9, self.n_classes).astype(np.float32)


def _class_pattern(c: int, res: int, n_classes: int) -> np.ndarray:
    """Deterministic class template: oriented grating + offset blob."""
    yy, xx = np.mgrid[0:res, 0:res].astype(np.float32) / res
    ang = np.pi * c / n_classes
    freq = 3.0 + 2.0 * (c % 4)
    grating = np.sin(2 * np.pi * freq * (xx * np.cos(ang) + yy * np.sin(ang)))
    cx, cy = 0.3 + 0.4 * ((c * 37) % 10) / 10.0, 0.3 + 0.4 * ((c * 53) % 10) / 10.0
    blob = np.exp(-(((xx - cx) ** 2 + (yy - cy) ** 2) / 0.02))
    base = 0.6 * grating + 1.2 * blob
    rgb = np.stack([base * (0.5 + 0.5 * np.cos(c)), base * (0.5 + 0.5 * np.sin(1 + c)), base], -1)
    return rgb.astype(np.float32)


def make_video(cfg: VideoDataConfig, video_id: int, rng: np.random.Generator):
    """Returns (frames (F,R,R,3) f32, label, difficulty)."""
    label = int(rng.integers(cfg.n_classes))
    dbase = cfg.difficulties()[label]
    difficulty = float(np.clip(dbase + 0.15 * rng.standard_normal(), 0.0, 1.0))
    pattern = _class_pattern(label, cfg.img_res, cfg.n_classes)
    frames = []
    drift = rng.standard_normal(2) * 2
    for f in range(cfg.frames_per_video):
        shift = (drift * f).astype(int)
        img = np.roll(pattern, tuple(shift % cfg.img_res), axis=(0, 1))
        # clutter: a competing class pattern mixed in as difficulty grows
        distract = _class_pattern(int(rng.integers(cfg.n_classes)), cfg.img_res, cfg.n_classes)
        img = (1 - 0.75 * difficulty) * img + 0.75 * difficulty * distract
        img = img + (cfg.noise_floor + 0.6 * difficulty) * rng.standard_normal(img.shape).astype(np.float32)
        frames.append(img)
    return np.stack(frames), label, difficulty


def make_dataset(cfg: VideoDataConfig, n_videos: int, seed: int = 0):
    """Returns dict(frames (N,R,R,3), labels (N,), video_id (N,), difficulty (N,))."""
    rng = np.random.default_rng(seed)
    frames, labels, vids, diffs = [], [], [], []
    for v in range(n_videos):
        fr, lb, df = make_video(cfg, v, rng)
        frames.append(fr)
        labels += [lb] * len(fr)
        vids += [v] * len(fr)
        diffs += [df] * len(fr)
    return {
        "frames": np.concatenate(frames).astype(np.float32),
        "labels": np.asarray(labels, np.int32),
        "video_id": np.asarray(vids, np.int32),
        "difficulty": np.asarray(diffs, np.float32),
    }
