"""Deterministic sharded data pipeline.

Each step's batch is a pure function of (seed, step): any host can
reconstruct any shard of any step — which is what makes checkpoint/restart
and elastic re-sharding trivial (no reader state to save beyond the step).
Background prefetch thread keeps the accelerator fed.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Callable, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class PipelineConfig:
    global_batch: int
    seed: int = 0
    prefetch: int = 2


class DeterministicPipeline:
    """batch_fn(rng, indices) -> batch dict; indices are per-step unique."""

    def __init__(self, cfg: PipelineConfig, batch_fn: Callable, dataset_size: int,
                 shard_index: int = 0, shard_count: int = 1):
        self.cfg = cfg
        self.batch_fn = batch_fn
        self.dataset_size = dataset_size
        self.shard_index = shard_index
        self.shard_count = shard_count
        assert cfg.global_batch % shard_count == 0
        self.local_batch = cfg.global_batch // shard_count

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.cfg.seed, step))
        idx = rng.integers(0, self.dataset_size, size=self.cfg.global_batch)
        local = idx[self.shard_index * self.local_batch : (self.shard_index + 1) * self.local_batch]
        return self.batch_fn(np.random.default_rng((self.cfg.seed, step, self.shard_index)), local)

    def __iter__(self) -> Iterator[dict]:
        return self.iterate(0)

    def iterate(self, start_step: int) -> Iterator[dict]:
        q: queue.Queue = queue.Queue(maxsize=self.cfg.prefetch)
        stop = threading.Event()

        def worker():
            s = start_step
            while not stop.is_set():
                q.put(self.batch_at(s))
                s += 1

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()


def token_batch_fn(vocab_size: int, seq_len: int, *, order: int = 2):
    """Synthetic-language batches: a seeded bigram chain over a zipf vocab —
    learnable structure so training losses actually move."""

    def fn(rng: np.random.Generator, idx: np.ndarray) -> dict:
        B = len(idx)
        # per-index deterministic stream
        toks = np.empty((B, seq_len + 1), np.int32)
        for i, ix in enumerate(idx):
            r = np.random.default_rng(int(ix))
            base = r.zipf(1.5, size=seq_len + 1).astype(np.int64)
            mix = (base * 2654435761 + np.arange(seq_len + 1) * int(ix + 1)) % vocab_size
            toks[i] = mix.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    return fn


def image_batch_fn(dataset: dict):
    def fn(rng: np.random.Generator, idx: np.ndarray) -> dict:
        return {"images": dataset["frames"][idx], "labels": dataset["labels"][idx]}

    return fn
